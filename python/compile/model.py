"""Layer-2: decoder-only transformer LM with an explicit KV cache.

This is the compute graph the Rust coordinator serves.  It mirrors the
two-phase inference procedure of the paper (§II-C):

* ``prefill``  — the *initialisation phase*: embed the whole (padded) prompt
  batch, run every layer once, fill the KV cache, return the logits of each
  request's last valid token.
* ``decode``   — one *decoding phase* iteration: embed the latest token of
  every request, attend to the KV cache (via the Layer-1 Pallas kernel),
  append the new KV entries at the shared batch position, return next-token
  logits plus the updated cache.

Padding semantics follow §II-D exactly: requests are right-padded to the
batch length ``l0``; pad positions are masked out of attention; generated
tokens (positions >= ``l0``) are always attendable.  Early-finished requests
keep generating (invalid) tokens — the waste Magnus exists to minimise —
because termination is the Rust coordinator's decision, not the model's.

Weights are *runtime inputs* in the deterministic order of
``param_specs()``: ``aot.py`` serialises them to ``weights.bin`` and the
Rust runtime feeds them back as literals, so the HLO artifacts stay small
and the server genuinely "loads a model".
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.attention import decode_attention, prefill_attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture of the served LM (a miniature ChatGLM-shaped decoder)."""

    vocab: int = 512
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512
    l_max: int = 256  # KV-cache capacity = max request length + generation

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    def param_specs(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """Deterministic (name, shape) list — the weights.bin layout."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        specs: List[Tuple[str, Tuple[int, ...]]] = [("embed", (v, d))]
        for i in range(self.n_layers):
            p = f"layer{i}."
            specs += [
                (p + "ln1_scale", (d,)), (p + "ln1_bias", (d,)),
                (p + "wq", (d, d)), (p + "wk", (d, d)),
                (p + "wv", (d, d)), (p + "wo", (d, d)),
                (p + "ln2_scale", (d,)), (p + "ln2_bias", (d,)),
                (p + "w1", (d, f)), (p + "w2", (f, d)),
            ]
        specs += [("lnf_scale", (d,)), ("lnf_bias", (d,))]
        return specs

    def n_params(self) -> int:
        return sum(int(jnp.prod(jnp.array(s))) for _, s in self.param_specs())

    def kv_bytes_per_token(self) -> int:
        """Δ of Eq. (5): bytes of K+V cache per token (f32 here)."""
        return 2 * self.n_layers * self.n_heads * self.d_head * 4


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jax.Array]:
    """Deterministic parameter init (the 'small real model' we serve)."""
    key = jax.random.PRNGKey(seed)
    params: List[jax.Array] = []
    for name, shape in cfg.param_specs():
        key, sub = jax.random.split(key)
        if name.endswith(("_scale",)):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(("_bias",)):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0]
            params.append(
                jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(fan_in))
    return params


def _layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array) -> jax.Array:
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * scale + bias


def _unpack(cfg: ModelConfig, params: Tuple[jax.Array, ...]):
    names = [n for n, _ in cfg.param_specs()]
    return dict(zip(names, params))


def _split_heads(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """[B, ..., D] -> [B, H, ..., Dh]"""
    b = x.shape[0]
    mid = x.shape[1:-1]
    x = x.reshape((b,) + mid + (cfg.n_heads, cfg.d_head))
    return jnp.moveaxis(x, -2, 1)


def _merge_heads(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """[B, H, ..., Dh] -> [B, ..., D]"""
    x = jnp.moveaxis(x, 1, -2)
    return x.reshape(x.shape[:-2] + (cfg.d_model,))


def prefill(cfg: ModelConfig, tokens: jax.Array, lens: jax.Array,
            *params: jax.Array):
    """Initialisation phase over a right-padded prompt batch.

    Args:
      tokens: [B, L] int32, right-padded with the PAD token.
      lens:   [B]    int32, valid prompt length per request (1..L).
      params: flat weights in ``param_specs()`` order.

    Returns:
      (logits[B, V] of each request's last valid token,
       k[NL, B, H, Lmax, Dh], v[NL, B, H, Lmax, Dh])
    """
    p = _unpack(cfg, params)
    b, l = tokens.shape
    x = p["embed"][tokens]  # [B, L, D]

    # mask[b, q, kpos]: causal AND key is a real prompt token.
    pos = jnp.arange(l)
    causal = pos[None, :, None] >= pos[None, None, :]           # [1, L, L]
    key_valid = (pos[None, None, :] < lens[:, None, None])      # [B, 1, L]
    mask = (causal & key_valid).astype(jnp.float32)             # [B, L, L]

    ks, vs = [], []
    for i in range(cfg.n_layers):
        lp = f"layer{i}."
        h = _layer_norm(x, p[lp + "ln1_scale"], p[lp + "ln1_bias"])
        q = _split_heads(h @ p[lp + "wq"], cfg)  # [B, H, L, Dh]
        k = _split_heads(h @ p[lp + "wk"], cfg)
        v = _split_heads(h @ p[lp + "wv"], cfg)
        attn = prefill_attention(q, k, v, mask)  # Layer-1 kernel
        x = x + _merge_heads(attn, cfg) @ p[lp + "wo"]
        h = _layer_norm(x, p[lp + "ln2_scale"], p[lp + "ln2_bias"])
        x = x + jax.nn.gelu(h @ p[lp + "w1"]) @ p[lp + "w2"]
        ks.append(k)
        vs.append(v)

    # Cache: [NL, B, H, Lmax, Dh], prompt KV in [0, L), rest zeros.
    pad = cfg.l_max - l
    k_cache = jnp.pad(jnp.stack(ks), ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    v_cache = jnp.pad(jnp.stack(vs), ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))

    x = _layer_norm(x, p["lnf_scale"], p["lnf_bias"])
    last = jnp.take_along_axis(
        x, (lens - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]  # [B, D]
    logits = last @ p["embed"].T  # tied lm-head
    return logits, k_cache, v_cache


def decode(cfg: ModelConfig, token: jax.Array, pos: jax.Array, l0: jax.Array,
           lens: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
           *params: jax.Array):
    """One decoding-phase iteration for the whole batch.

    Args:
      token:   [B] int32 — token produced by the previous iteration.
      pos:     scalar int32 — cache slot the new KV entries go to.  All
               requests share it (uniform right-padding, §II-D).
      l0:      scalar int32 — padded batch (prompt) length L(B).
      lens:    [B] int32 — per-request valid prompt lengths (pad masking).
      k_cache, v_cache: [NL, B, H, Lmax, Dh].
      params:  flat weights in ``param_specs()`` order.

    Returns:
      (logits[B, V], k_cache', v_cache')
    """
    p = _unpack(cfg, params)
    b = token.shape[0]
    lmax = k_cache.shape[3]
    x = p["embed"][token]  # [B, D]

    # Attendable KV positions j for every request i:
    #   j <= pos                      (nothing from the future), AND
    #   j < lens[i]  (real prompt) OR j >= l0 (generated tokens incl. self).
    j = jnp.arange(lmax)
    attendable = (j[None, :] <= pos) & (
        (j[None, :] < lens[:, None]) | (j[None, :] >= l0))
    mask = attendable.astype(jnp.float32)  # [B, Lmax]

    for i in range(cfg.n_layers):
        lp = f"layer{i}."
        h = _layer_norm(x, p[lp + "ln1_scale"], p[lp + "ln1_bias"])
        q = _split_heads(h @ p[lp + "wq"], cfg)   # [B, H, Dh]
        kc = _split_heads(h @ p[lp + "wk"], cfg)  # [B, H, Dh]
        vc = _split_heads(h @ p[lp + "wv"], cfg)
        upd_k = kc[None, :, :, None, :]  # [1, B, H, 1, Dh]
        upd_v = vc[None, :, :, None, :]
        zero = jnp.int32(0)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, upd_k, (jnp.int32(i), zero, zero, pos, zero))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, upd_v, (jnp.int32(i), zero, zero, pos, zero))
        attn = decode_attention(q, k_cache[i], v_cache[i], mask)  # L1 kernel
        x = x + _merge_heads(attn, cfg) @ p[lp + "wo"]
        h = _layer_norm(x, p[lp + "ln2_scale"], p[lp + "ln2_bias"])
        x = x + jax.nn.gelu(h @ p[lp + "w1"]) @ p[lp + "w2"]

    x = _layer_norm(x, p["lnf_scale"], p["lnf_bias"])
    logits = x @ p["embed"].T
    return logits, k_cache, v_cache
