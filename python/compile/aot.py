"""AOT compile path: lower the Layer-2 model to HLO text artifacts.

Runs ONCE at build time (``make artifacts``); Python never appears on the
request path.  For every (batch-size, prompt-length) bucket this script
lowers ``prefill`` and for every batch-size bucket ``decode`` to **HLO
text** and writes:

    artifacts/
      manifest.json            model config + param table + bucket list
      weights.bin              f32 little-endian params, param_specs() order
      prefill_b{B}_l{L}.hlo.txt
      decode_b{B}.hlo.txt

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--quick]

``--quick`` lowers a minimal bucket set for CI-speed test runs.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile.model import ModelConfig, decode, init_params, prefill

# Tokenizer special ids shared with the Rust side (tokenizer/ module).
PAD_ID, BOS_ID, EOS_ID = 0, 1, 2

FULL_BATCH_BUCKETS = [1, 2, 4, 8, 16, 32]
FULL_PREFILL_LEN_BUCKETS = [16, 64, 128, 192]
QUICK_BATCH_BUCKETS = [1, 4]
QUICK_PREFILL_LEN_BUCKETS = [16, 128]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_prefill(cfg: ModelConfig, b: int, l: int) -> str:
    i32, f32 = jnp.int32, jnp.float32
    specs = (
        jax.ShapeDtypeStruct((b, l), i32),
        jax.ShapeDtypeStruct((b,), i32),
    ) + tuple(jax.ShapeDtypeStruct(s, f32) for _, s in cfg.param_specs())

    def fn(tokens, lens, *params):
        return prefill(cfg, tokens, lens, *params)

    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_decode(cfg: ModelConfig, b: int) -> str:
    i32, f32 = jnp.int32, jnp.float32
    nl, h, dh, lmax = cfg.n_layers, cfg.n_heads, cfg.d_head, cfg.l_max
    specs = (
        jax.ShapeDtypeStruct((b,), i32),
        jax.ShapeDtypeStruct((), i32),
        jax.ShapeDtypeStruct((), i32),
        jax.ShapeDtypeStruct((b,), i32),
        jax.ShapeDtypeStruct((nl, b, h, lmax, dh), f32),
        jax.ShapeDtypeStruct((nl, b, h, lmax, dh), f32),
    ) + tuple(jax.ShapeDtypeStruct(s, f32) for _, s in cfg.param_specs())

    def fn(token, pos, l0, lens, k, v, *params):
        return decode(cfg, token, pos, l0, lens, k, v, *params)

    return to_hlo_text(jax.jit(fn).lower(*specs))


def write_weights(cfg: ModelConfig, out_dir: str, seed: int) -> list:
    params = init_params(cfg, seed)
    table, offset = [], 0
    blobs = []
    for (name, shape), arr in zip(cfg.param_specs(), params):
        raw = np.asarray(arr, dtype="<f4").tobytes()
        table.append({"name": name, "shape": list(shape),
                      "offset": offset, "bytes": len(raw)})
        blobs.append(raw)
        offset += len(raw)
    blob = b"".join(blobs)
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        f.write(blob)
    digest = hashlib.sha256(blob).hexdigest()
    return table, digest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="minimal bucket set for fast test builds")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = ModelConfig()
    os.makedirs(args.out_dir, exist_ok=True)
    batches = QUICK_BATCH_BUCKETS if args.quick else FULL_BATCH_BUCKETS
    plens = (QUICK_PREFILL_LEN_BUCKETS if args.quick
             else FULL_PREFILL_LEN_BUCKETS)

    weight_table, weights_sha = write_weights(cfg, args.out_dir, args.seed)

    prefill_buckets, decode_buckets = [], []
    for b in batches:
        for l in plens:
            name = f"prefill_b{b}_l{l}.hlo.txt"
            text = lower_prefill(cfg, b, l)
            with open(os.path.join(args.out_dir, name), "w") as f:
                f.write(text)
            prefill_buckets.append({"batch": b, "len": l, "file": name})
            print(f"lowered {name}: {len(text)} chars", file=sys.stderr)
        name = f"decode_b{b}.hlo.txt"
        text = lower_decode(cfg, b)
        with open(os.path.join(args.out_dir, name), "w") as f:
            f.write(text)
        decode_buckets.append({"batch": b, "file": name})
        print(f"lowered {name}: {len(text)} chars", file=sys.stderr)

    manifest = {
        "model": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "d_head": cfg.d_head, "d_ff": cfg.d_ff, "l_max": cfg.l_max,
            "kv_bytes_per_token": cfg.kv_bytes_per_token(),
        },
        "specials": {"pad": PAD_ID, "bos": BOS_ID, "eos": EOS_ID},
        "weights": {"file": "weights.bin", "sha256": weights_sha,
                    "params": weight_table},
        "prefill": prefill_buckets,
        "decode": decode_buckets,
        "seed": args.seed,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(prefill_buckets)} prefill + "
          f"{len(decode_buckets)} decode buckets", file=sys.stderr)


if __name__ == "__main__":
    main()
