"""Pure-jnp oracle for the Layer-1 attention kernels.

Deliberately written in the most obvious way possible (materialise the full
score matrix, plain softmax) so that any disagreement with the Pallas
kernels points at the kernels, not at the reference.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         mask: jax.Array) -> jax.Array:
    """Reference for kernels.attention.decode_attention.

    q: [B, H, Dh]; k, v: [B, H, Lmax, Dh]; mask: [B, Lmax] (1.0 = attend).
    """
    dh = q.shape[-1]
    s = jnp.einsum("bhd,bhld->bhl", q, k) / jnp.sqrt(jnp.float32(dh))
    s = jnp.where(mask[:, None, :] > 0.0, s, _NEG_INF)
    s = s - s.max(axis=-1, keepdims=True)
    p = jnp.exp(s)
    denom = p.sum(axis=-1, keepdims=True)
    denom = jnp.where(denom > 0.0, denom, 1.0)
    return jnp.einsum("bhl,bhld->bhd", p / denom, v)


def prefill_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                          mask: jax.Array) -> jax.Array:
    """Reference for kernels.attention.prefill_attention.

    q, k, v: [B, H, L, Dh]; mask: [B, L, L] (1.0 = attend).
    """
    dh = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(dh))
    s = jnp.where(mask[:, None, :, :] > 0.0, s, _NEG_INF)
    s = s - s.max(axis=-1, keepdims=True)
    p = jnp.exp(s)
    denom = p.sum(axis=-1, keepdims=True)
    denom = jnp.where(denom > 0.0, denom, 1.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p / denom, v)
