"""Layer-1 Pallas attention kernels for the Magnus serving stack.

Two kernels cover the two phases of LLM batch serving (paper §II-C):

* ``decode_attention`` — the serving hot spot.  One query token per request
  attends to the whole KV cache.  Implemented flash-style: the KV cache is
  streamed along the sequence axis in ``LBLK``-sized blocks with an online
  softmax (running max / running sum / accumulator in VMEM scratch), so the
  kernel never materialises a ``[B, Lmax]`` score row per head in more than
  one block at a time.  On a real TPU this is exactly the HBM->VMEM schedule
  that the paper's WMA metric counts: each (head, kv-block) grid cell streams
  its KV block from HBM once per decode iteration, and blocks belonging to
  pad/invalid tokens are the "wasted" accesses Magnus minimises.

* ``prefill_attention`` — causal + padding masked attention over the full
  prompt, used once per request in the initialisation phase.

Both kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers to plain HLO that the Rust
runtime executes.  Correctness is pinned against the pure-jnp oracle in
``ref.py`` by ``python/tests/test_kernel.py`` (hypothesis sweeps shapes).

Masks are *inputs* (float 0/1 per KV position): the Layer-2 model derives
them from request lengths and the current decode position, which keeps the
kernels oblivious to serving-side padding policy and directly testable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

# KV-block size for the streamed decode kernel.  128 keeps blocks aligned to
# the TPU lane width (the (8, 128) native tile) and bounds the VMEM working
# set; see DESIGN.md §Hardware-Adaptation.
LBLK = 128

_NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, m_ref, o_ref, acc_ref, mx_ref, sm_ref,
                   *, scale: float):
    """Grid cell (head h, kv-block j): fold KV block j into the online softmax.

    Scratch refs persist across the (sequentially executed) kv-block axis:
      acc_ref [B, Dh] — un-normalised weighted value accumulator
      mx_ref  [B, 1]  — running row max of the attention scores
      sm_ref  [B, 1]  — running softmax denominator
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        mx_ref[...] = jnp.full_like(mx_ref, _NEG_INF)
        sm_ref[...] = jnp.zeros_like(sm_ref)

    q = q_ref[...]  # [B, Dh]      (head dim squeezed by BlockSpec)
    k = k_ref[...]  # [B, LBLK, Dh]
    v = v_ref[...]  # [B, LBLK, Dh]
    m = m_ref[...]  # [B, LBLK]    1.0 = attend, 0.0 = masked (pad / future)

    s = jnp.einsum("bd,bld->bl", q, k) * scale
    s = jnp.where(m > 0.0, s, _NEG_INF)

    mx_new = jnp.maximum(mx_ref[...], s.max(axis=-1, keepdims=True))
    corr = jnp.exp(mx_ref[...] - mx_new)
    # Multiply by m so fully-masked rows contribute exactly zero (otherwise
    # exp(-inf - (-inf)) == 1 would leak junk into the accumulator).
    p = jnp.exp(s - mx_new) * m
    sm_ref[...] = sm_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.einsum("bl,bld->bd", p, v)
    mx_ref[...] = mx_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _finalize():
        # Fully-masked rows (a request whose mask is all zero) keep sm == 0;
        # guard the division so they emit zeros instead of NaN.
        denom = jnp.where(sm_ref[...] > 0.0, sm_ref[...], 1.0)
        o_ref[...] = acc_ref[...] / denom


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     mask: jax.Array) -> jax.Array:
    """Single-token attention against the KV cache.

    Args:
      q:    [B, H, Dh]      query for the current decode position.
      k, v: [B, H, Lmax, Dh] KV cache (positions >= valid length are junk).
      mask: [B, Lmax]       1.0 where the KV position is attendable.

    Returns:
      [B, H, Dh] attention output.
    """
    b, h, dh = q.shape
    lmax = k.shape[2]
    if lmax % LBLK == 0:
        lblk = LBLK
    else:  # small test shapes: single block
        lblk = lmax
    grid = (h, lmax // lblk)
    scale = 1.0 / (dh ** 0.5)
    kernel = functools.partial(_decode_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, None, dh), lambda hh, jj: (0, hh, 0)),
            pl.BlockSpec((b, None, lblk, dh), lambda hh, jj: (0, hh, jj, 0)),
            pl.BlockSpec((b, None, lblk, dh), lambda hh, jj: (0, hh, jj, 0)),
            pl.BlockSpec((b, lblk), lambda hh, jj: (0, jj)),
        ],
        out_specs=pl.BlockSpec((b, None, dh), lambda hh, jj: (0, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((b, dh), jnp.float32),
            pltpu.VMEM((b, 1), jnp.float32),
            pltpu.VMEM((b, 1), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, mask)


def _prefill_kernel(q_ref, k_ref, v_ref, m_ref, o_ref, *, scale: float):
    """Grid cell (head h): full causal+pad masked attention for one head."""
    q = q_ref[...]  # [B, L, Dh]
    k = k_ref[...]  # [B, L, Dh]
    v = v_ref[...]  # [B, L, Dh]
    m = m_ref[...]  # [B, L, L]

    s = jnp.einsum("bqd,bkd->bqk", q, k) * scale
    s = jnp.where(m > 0.0, s, _NEG_INF)
    s = s - s.max(axis=-1, keepdims=True)
    p = jnp.exp(s)
    denom = p.sum(axis=-1, keepdims=True)
    denom = jnp.where(denom > 0.0, denom, 1.0)
    o_ref[...] = jnp.einsum("bqk,bkd->bqd", p / denom, v)


def prefill_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      mask: jax.Array) -> jax.Array:
    """Causal + padding masked attention over the whole prompt.

    Args:
      q, k, v: [B, H, L, Dh]
      mask:    [B, L, L]  1.0 where query position may attend key position
               (the Layer-2 model bakes causality AND pad masking into it).

    Returns:
      [B, H, L, Dh]
    """
    b, h, l, dh = q.shape
    scale = 1.0 / (dh ** 0.5)
    kernel = functools.partial(_prefill_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((b, None, l, dh), lambda hh: (0, hh, 0, 0)),
            pl.BlockSpec((b, None, l, dh), lambda hh: (0, hh, 0, 0)),
            pl.BlockSpec((b, None, l, dh), lambda hh: (0, hh, 0, 0)),
            pl.BlockSpec((b, l, l), lambda hh: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((b, None, l, dh), lambda hh: (0, hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, l, dh), q.dtype),
        interpret=True,
    )(q, k, v, mask)
