"""Make `pytest python/tests` work from the repo root as well as from
python/ (the compile package is imported as `compile.*`)."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
