"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle.

This is the CORE correctness signal for the compute stack: hypothesis
sweeps shapes/lengths/dtypes and every case must match ref.py to float32
tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import LBLK, decode_attention, prefill_attention
from compile.kernels.ref import decode_attention_ref, prefill_attention_ref

TOL = dict(rtol=2e-5, atol=2e-5)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------- decode ---

@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 8),
    h=st.integers(1, 4),
    dh=st.sampled_from([8, 16, 32]),
    nblk=st.integers(1, 3),
    seed=st.integers(0, 10_000),
)
def test_decode_matches_ref_swept(b, h, dh, nblk, seed):
    lmax = LBLK * nblk
    q = _rand(seed, (b, h, dh))
    k = _rand(seed + 1, (b, h, lmax, dh))
    v = _rand(seed + 2, (b, h, lmax, dh))
    rng = np.random.default_rng(seed)
    lens = rng.integers(1, lmax + 1, size=b)
    mask = (np.arange(lmax)[None, :] < lens[:, None]).astype(np.float32)
    mask = jnp.asarray(mask)
    out = decode_attention(q, k, v, mask)
    ref = decode_attention_ref(q, k, v, mask)
    np.testing.assert_allclose(out, ref, **TOL)


def test_decode_single_block_small_shape():
    # lmax not a multiple of LBLK -> single-block fallback path.
    b, h, lmax, dh = 3, 2, 24, 16
    q, k, v = _rand(0, (b, h, dh)), _rand(1, (b, h, lmax, dh)), _rand(2, (b, h, lmax, dh))
    mask = jnp.ones((b, lmax))
    np.testing.assert_allclose(
        decode_attention(q, k, v, mask),
        decode_attention_ref(q, k, v, mask), **TOL)


def test_decode_noncontiguous_mask():
    """Serving mask shape: prompt valid + generated region, pad hole between."""
    b, h, lmax, dh = 4, 2, 2 * LBLK, 16
    q, k, v = _rand(3, (b, h, dh)), _rand(4, (b, h, lmax, dh)), _rand(5, (b, h, lmax, dh))
    lens = np.array([10, 40, 25, 3])
    l0, pos = 40, 50  # batch prompt length 40, 10 tokens generated
    j = np.arange(lmax)
    mask = ((j[None, :] <= pos) &
            ((j[None, :] < lens[:, None]) | (j[None, :] >= l0)))
    mask = jnp.asarray(mask.astype(np.float32))
    np.testing.assert_allclose(
        decode_attention(q, k, v, mask),
        decode_attention_ref(q, k, v, mask), **TOL)


def test_decode_fully_masked_row_is_finite():
    b, h, lmax, dh = 2, 2, LBLK, 8
    q, k, v = _rand(6, (b, h, dh)), _rand(7, (b, h, lmax, dh)), _rand(8, (b, h, lmax, dh))
    mask = jnp.zeros((b, lmax)).at[1].set(1.0)
    out = decode_attention(q, k, v, mask)
    assert bool(jnp.isfinite(out).all())
    np.testing.assert_allclose(out[0], np.zeros((h, dh)), atol=1e-6)


def test_decode_mask_zero_tail_ignores_cache_garbage():
    """Junk beyond the valid length must not affect the output."""
    b, h, lmax, dh = 2, 2, LBLK, 16
    q = _rand(9, (b, h, dh))
    k = _rand(10, (b, h, lmax, dh))
    v = _rand(11, (b, h, lmax, dh))
    valid = 17
    mask = (jnp.arange(lmax) < valid).astype(jnp.float32)[None, :].repeat(b, 0)
    out1 = decode_attention(q, k, v, mask)
    k2 = k.at[:, :, valid:, :].set(1e6)
    v2 = v.at[:, :, valid:, :].set(-1e6)
    out2 = decode_attention(q, k2, v2, mask)
    np.testing.assert_allclose(out1, out2, **TOL)


# --------------------------------------------------------------- prefill ---

@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 6),
    h=st.integers(1, 4),
    l=st.sampled_from([4, 16, 33, 64]),
    dh=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 10_000),
)
def test_prefill_matches_ref_swept(b, h, l, dh, seed):
    q = _rand(seed, (b, h, l, dh))
    k = _rand(seed + 1, (b, h, l, dh))
    v = _rand(seed + 2, (b, h, l, dh))
    rng = np.random.default_rng(seed)
    lens = rng.integers(1, l + 1, size=b)
    pos = np.arange(l)
    causal = pos[None, :, None] >= pos[None, None, :]
    key_valid = pos[None, None, :] < lens[:, None, None]
    mask = jnp.asarray((causal & key_valid).astype(np.float32))
    np.testing.assert_allclose(
        prefill_attention(q, k, v, mask),
        prefill_attention_ref(q, k, v, mask), **TOL)


def test_prefill_causality():
    """Perturbing a future token must not change earlier outputs."""
    b, h, l, dh = 2, 2, 16, 8
    q, k, v = _rand(20, (b, h, l, dh)), _rand(21, (b, h, l, dh)), _rand(22, (b, h, l, dh))
    pos = np.arange(l)
    mask = jnp.asarray((pos[:, None] >= pos[None, :]).astype(np.float32))
    mask = mask[None].repeat(b, 0)
    out1 = prefill_attention(q, k, v, mask)
    k2 = k.at[:, :, l - 1, :].add(100.0)
    v2 = v.at[:, :, l - 1, :].add(-50.0)
    out2 = prefill_attention(q, k2, v2, mask)
    np.testing.assert_allclose(out1[:, :, : l - 1], out2[:, :, : l - 1], **TOL)


def test_prefill_pad_key_excluded():
    b, h, l, dh = 2, 2, 12, 8
    q, k, v = _rand(23, (b, h, l, dh)), _rand(24, (b, h, l, dh)), _rand(25, (b, h, l, dh))
    lens = np.array([5, 12])
    pos = np.arange(l)
    causal = pos[None, :, None] >= pos[None, None, :]
    key_valid = pos[None, None, :] < lens[:, None, None]
    mask = jnp.asarray((causal & key_valid).astype(np.float32))
    out1 = prefill_attention(q, k, v, mask)
    k2 = k.at[0, :, 5:, :].set(999.0)
    v2 = v.at[0, :, 5:, :].set(-999.0)
    out2 = prefill_attention(q, k2, v2, mask)
    np.testing.assert_allclose(out1[0, :, :5], out2[0, :, :5], **TOL)
