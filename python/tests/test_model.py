"""Layer-2 model invariants: shapes, cache round-trip, padding semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import ModelConfig, decode, init_params, prefill

CFG = ModelConfig(vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=64,
                  l_max=32)
PARAMS = init_params(CFG, seed=0)


def _prefill(tokens, lens):
    return prefill(CFG, jnp.asarray(tokens, jnp.int32),
                   jnp.asarray(lens, jnp.int32), *PARAMS)


def test_prefill_shapes():
    b, l = 3, 8
    logits, k, v = _prefill(np.ones((b, l)), [8, 3, 5])
    assert logits.shape == (b, CFG.vocab)
    assert k.shape == (CFG.n_layers, b, CFG.n_heads, CFG.l_max, CFG.d_head)
    assert v.shape == k.shape


def test_prefill_pad_invariance():
    """Extending the pad tail must not change a request's logits."""
    rng = np.random.default_rng(0)
    raw = rng.integers(3, CFG.vocab, size=5)
    t1 = np.zeros((1, 8), np.int64); t1[0, :5] = raw
    t2 = np.zeros((1, 16), np.int64); t2[0, :5] = raw
    l1, _, _ = _prefill(t1, [5])
    l2, _, _ = _prefill(t2, [5])
    np.testing.assert_allclose(l1, l2, rtol=2e-4, atol=2e-4)


def test_prefill_batch_independence():
    """A request's logits must not depend on its batch-mates."""
    rng = np.random.default_rng(1)
    a = rng.integers(3, CFG.vocab, size=6)
    b_ = rng.integers(3, CFG.vocab, size=4)
    ta = np.zeros((1, 8), np.int64); ta[0, :6] = a
    tb = np.zeros((2, 8), np.int64); tb[0, :6] = a; tb[1, :4] = b_
    la, _, _ = _prefill(ta, [6])
    lab, _, _ = _prefill(tb, [6, 4])
    np.testing.assert_allclose(la[0], lab[0], rtol=2e-4, atol=2e-4)


def test_decode_cache_roundtrip_matches_long_prefill():
    """prefill(x[:n]) + decode steps == prefill(x[:n+k]) for the last token.

    This is the KV-cache correctness invariant the whole serving path
    relies on.
    """
    rng = np.random.default_rng(2)
    full = rng.integers(3, CFG.vocab, size=7)
    n = 4
    t = np.zeros((1, 8), np.int64)
    t[0, :n] = full[:n]
    logits, k, v = _prefill(t, [n])
    l0 = jnp.int32(8)
    lens = jnp.asarray([n], jnp.int32)
    # feed full[n:] one token at a time at positions l0, l0+1, ...
    for step, tok in enumerate(full[n:]):
        pos = jnp.int32(8 + step)
        logits, k, v = decode(CFG, jnp.asarray([tok], jnp.int32), pos, l0,
                              lens, k, v, *PARAMS)

    # Oracle: one prefill over the full 7-token sequence.
    t_full = np.zeros((1, 8), np.int64)
    t_full[0, :7] = full
    ref_logits, _, _ = _prefill(t_full, [7])
    # The decode path keeps the pad hole [n, l0) masked, the oracle has the
    # tokens contiguous — so compare the argmax distributions via a direct
    # contiguous decode instead: re-run decode with lens equal to prompt.
    # Contiguous variant: prompt occupies [0, n), generated at [n, ...).
    logits2, k2, v2 = _prefill(t, [n])
    l0c = jnp.int32(n)
    for step, tok in enumerate(full[n:]):
        pos = jnp.int32(n + step)
        logits2, k2, v2 = decode(CFG, jnp.asarray([tok], jnp.int32), pos,
                                 l0c, lens, k2, v2, *PARAMS)
    np.testing.assert_allclose(logits2, ref_logits, rtol=5e-4, atol=5e-4)


def test_decode_shapes_and_finiteness():
    b = 2
    t = np.zeros((b, 8), np.int64); t[:, :3] = 5
    logits, k, v = _prefill(t, [3, 3])
    out, k2, v2 = decode(CFG, jnp.asarray([7, 9], jnp.int32), jnp.int32(8),
                         jnp.int32(8), jnp.asarray([3, 3], jnp.int32),
                         k, v, *PARAMS)
    assert out.shape == (b, CFG.vocab)
    assert bool(jnp.isfinite(out).all())
    # cache updated exactly at position 8
    assert not np.allclose(np.asarray(k2[:, :, :, 8]), 0.0)
    np.testing.assert_allclose(np.asarray(k2[:, :, :, 9:]), 0.0)


def test_decode_batch_independence():
    rng = np.random.default_rng(3)
    t = np.zeros((2, 8), np.int64)
    t[0, :5] = rng.integers(3, CFG.vocab, size=5)
    t[1, :2] = rng.integers(3, CFG.vocab, size=2)
    _, k, v = _prefill(t, [5, 2])
    out, _, _ = decode(CFG, jnp.asarray([4, 6], jnp.int32), jnp.int32(8),
                       jnp.int32(8), jnp.asarray([5, 2], jnp.int32),
                       k, v, *PARAMS)
    t_solo = t[:1]
    _, ks, vs = _prefill(t_solo, [5])
    out_solo, _, _ = decode(CFG, jnp.asarray([4], jnp.int32), jnp.int32(8),
                            jnp.int32(8), jnp.asarray([5], jnp.int32),
                            ks, vs, *PARAMS)
    np.testing.assert_allclose(out[0], out_solo[0], rtol=2e-4, atol=2e-4)


def test_param_specs_deterministic_and_complete():
    cfg = ModelConfig()
    specs = cfg.param_specs()
    assert specs == cfg.param_specs()
    names = [n for n, _ in specs]
    assert len(names) == len(set(names))
    assert names[0] == "embed" and names[-1] == "lnf_bias"
    assert cfg.kv_bytes_per_token() == 2 * cfg.n_layers * cfg.d_model * 4
