//! Differential trace-I/O suite: the binary trace format and its two
//! file backings must be indistinguishable from the in-memory store.
//!
//! * **Round-trip properties** — random traces written to a file and
//!   reopened via the mmap route and the read-into-memory fallback must
//!   be byte-identical to the generated store (metas, arena bytes,
//!   instruction table, re-serialised bytes) and produce bit-identical
//!   `run_magnus_store` output — also versus the JSON route, the
//!   pre-binary load path.
//! * **Corrupt-input rejection** — a table of mutated valid files
//!   (truncations, bad magic/version, inflated counts, spans past the
//!   arena or splitting a UTF-8 sequence, bad indices, non-UTF-8 text)
//!   must all reject with errors: never a panic, never a store that
//!   could alias text.  The open itself is O(1)-lazy (header, section
//!   bounds and instruction table only), so every route runs the
//!   one-shot `validate_all` sweep — the combination a tool that
//!   distrusts its input uses.  Driven through `from_binary_bytes` AND
//!   both file-open routes, which share one decode.
//! * **Sharded traces** — a manifest-opened shard set must be bitwise
//!   equal to the single-file and JSON routes (views AND
//!   `run_magnus_store` output), and a matrix of corrupt manifests
//!   (missing shard, checksum mismatch, overlapping or out-of-order
//!   ranges, count drift) must error, never panic.
//! * **Concurrency smoke** — N threads resolving `RequestView`s out of
//!   one shared mmap-backed `Arc<TraceStore>` while a Magnus sim runs
//!   over the same store; results must match the single-threaded run.
//! * **Provenance** — a meta resolved against the wrong live store must
//!   panic loudly (debug builds) even when the two stores hold
//!   identical bytes, where aliasing would be silent.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use magnus::config::ServingConfig;
use magnus::engine::cost::CostModelEngine;
use magnus::sim::{run_magnus_store, trained_predictor, MagnusPolicy};
use magnus::util::prop::prop_check;
use magnus::util::Json;
use magnus::workload::{
    open_any, open_manifest, shard_store, LoadedTrace, TaskId, TraceSource, TraceSpec,
    TraceStore, TRACE_HEADER_BYTES, TRACE_META_BYTES, TRACE_VERSION,
};

mod common;
use common::assert_identical;

/// Collision-free temp path (unique per process AND per call, so
/// parallel tests never race on a file).
fn temp_path(tag: &str) -> PathBuf {
    static NEXT: AtomicU32 = AtomicU32::new(0);
    let n = NEXT.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "magnus_trace_io_{}_{n}_{tag}.mtr",
        std::process::id()
    ))
}

/// Representation equality of a loaded store against the original:
/// every byte the format carries.
fn assert_same_store(loaded: &TraceStore, original: &TraceStore, ctx: &str) {
    loaded
        .validate_all()
        .unwrap_or_else(|e| panic!("{ctx}: honest file failed validate_all: {e}"));
    assert_eq!(loaded.metas(), original.metas(), "{ctx}: metas");
    assert_eq!(loaded.arena_str(), original.arena_str(), "{ctx}: arena");
    assert_eq!(
        loaded.instruction_table(),
        original.instruction_table(),
        "{ctx}: instruction table"
    );
    assert_eq!(
        loaded.to_binary().unwrap(),
        original.to_binary().unwrap(),
        "{ctx}: bytes"
    );
}

#[test]
fn mmap_and_read_backings_replay_the_in_memory_store_bitwise() {
    prop_check(8, |rng| {
        let cfg = ServingConfig::default();
        let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
        let spec = TraceSpec {
            rate: rng.range_f64(2.0, 12.0),
            n_requests: rng.range_usize(20, 160),
            l_cap: if rng.range_u64(0, 2) == 0 {
                0
            } else {
                rng.range_u64(8, 200) as u32
            },
            seed: rng.next_u64(),
            ..Default::default()
        };
        let store = TraceStore::generate(&spec);
        let path = temp_path("prop");
        store.write_file(&path).unwrap();

        let mmap = TraceStore::open_mmap(&path).unwrap();
        let read = TraceStore::open_read(&path).unwrap();
        assert!(mmap.is_file_backed());
        assert!(read.is_file_backed() && !read.is_mmap_backed());
        assert_same_store(&mmap, &store, "mmap");
        assert_same_store(&read, &store, "read fallback");

        // The JSON route (pre-binary load path) must agree too.
        let json_store =
            TraceStore::from_json(&Json::parse(&store.to_json().to_string()).unwrap())
                .unwrap();
        assert_eq!(json_store.metas(), store.metas(), "json: metas");
        assert_eq!(json_store.arena_str(), store.arena_str(), "json: arena");

        // Bit-identical serving behaviour on every backing.
        let run = |s: &TraceStore| {
            run_magnus_store(
                &cfg,
                &MagnusPolicy::magnus(),
                trained_predictor(&cfg, 120),
                &engine,
                s,
            )
        };
        let base = run(&store);
        assert_identical(&base, &run(&mmap), "mmap vs in-memory");
        assert_identical(&base, &run(&read), "read vs in-memory");
        assert_identical(&base, &run(&json_store), "json vs in-memory");

        let _ = std::fs::remove_file(&path);
    });
}

#[test]
fn corrupt_binary_traces_are_rejected_never_panicking() {
    let store = TraceStore::generate(&TraceSpec {
        n_requests: 12,
        seed: 3,
        ..Default::default()
    });
    let valid = store.to_binary().unwrap();
    assert!(
        TraceStore::from_binary_bytes(valid.clone())
            .and_then(|s| s.validate_all())
            .is_ok(),
        "pristine bytes must decode and validate"
    );

    // Header field offsets (see the format docs in workload/store.rs).
    let meta0 = TRACE_HEADER_BYTES;
    let instr_table = meta0 + 12 * TRACE_META_BYTES;
    type Mutation = Box<dyn Fn(Vec<u8>) -> Vec<u8>>;
    let put_u64 = |b: &mut [u8], off: usize, v: u64| {
        b[off..off + 8].copy_from_slice(&v.to_le_bytes());
    };
    let put_u32 = |b: &mut [u8], off: usize, v: u32| {
        b[off..off + 4].copy_from_slice(&v.to_le_bytes());
    };
    let cases: Vec<(&str, Mutation)> = vec![
        ("empty file", Box::new(|_| Vec::new())),
        (
            "truncated header",
            Box::new(|b: Vec<u8>| b[..TRACE_HEADER_BYTES - 7].to_vec()),
        ),
        (
            "truncated mid meta table",
            Box::new(move |b: Vec<u8>| b[..meta0 + TRACE_META_BYTES / 2].to_vec()),
        ),
        (
            "one byte chopped off the arena",
            Box::new(|mut b: Vec<u8>| {
                b.pop();
                b
            }),
        ),
        (
            "one trailing garbage byte",
            Box::new(|mut b: Vec<u8>| {
                b.push(0);
                b
            }),
        ),
        (
            "bad magic",
            Box::new(|mut b: Vec<u8>| {
                b[0] ^= 0xFF;
                b
            }),
        ),
        (
            "wrong version",
            Box::new(move |mut b: Vec<u8>| {
                put_u32(&mut b, 8, TRACE_VERSION + 1);
                b
            }),
        ),
        (
            "nonzero reserved header field",
            Box::new(move |mut b: Vec<u8>| {
                put_u32(&mut b, 12, 0xDEAD);
                b
            }),
        ),
        (
            "meta count inflated to overflow",
            Box::new(move |mut b: Vec<u8>| {
                put_u64(&mut b, 16, u64::MAX);
                b
            }),
        ),
        (
            "instruction count inflated",
            Box::new(move |mut b: Vec<u8>| {
                put_u64(&mut b, 24, u64::MAX / 8);
                b
            }),
        ),
        (
            "meta span start past the arena",
            Box::new(move |mut b: Vec<u8>| {
                put_u64(&mut b, meta0 + 16, u64::MAX / 2);
                b
            }),
        ),
        (
            "meta span length overruns the arena",
            Box::new(move |mut b: Vec<u8>| {
                put_u32(&mut b, meta0 + 24, u32::MAX);
                b
            }),
        ),
        (
            "bad task id",
            Box::new(move |mut b: Vec<u8>| {
                put_u32(&mut b, meta0 + 28, 999);
                b
            }),
        ),
        (
            "instruction index out of range",
            Box::new(move |mut b: Vec<u8>| {
                put_u32(&mut b, meta0 + 32, u32::MAX);
                b
            }),
        ),
        (
            "non-UTF-8 instruction text",
            Box::new(move |mut b: Vec<u8>| {
                b[instr_table + 4] = 0xFF; // first byte after the length prefix
                b
            }),
        ),
        (
            "non-UTF-8 arena byte",
            Box::new(|mut b: Vec<u8>| {
                let last = b.len() - 1; // arena is the final section
                b[last] = 0xFF;
                b
            }),
        ),
    ];

    for (name, mutate) in cases {
        let bytes = mutate(valid.clone());
        // In-memory decode + full sweep: an error, not a panic, not a
        // store.  The open alone is O(1)-lazy, so structural damage
        // fails there and per-record damage fails in `validate_all` —
        // either way the pair must reject.
        match catch_unwind(AssertUnwindSafe(|| {
            TraceStore::from_binary_bytes(bytes.clone()).and_then(|s| s.validate_all())
        })) {
            Ok(res) => assert!(res.is_err(), "corrupt case {name:?} was accepted"),
            Err(_) => panic!("corrupt case {name:?} panicked instead of erroring"),
        }
        // And identically through real files on both open routes.
        let path = temp_path("corrupt");
        std::fs::write(&path, &bytes).unwrap();
        let via_mmap = || TraceStore::open_mmap(&path).and_then(|s| s.validate_all());
        let via_read = || TraceStore::open_read(&path).and_then(|s| s.validate_all());
        let routes: [(&str, &dyn Fn() -> anyhow::Result<()>); 2] =
            [("mmap", &via_mmap), ("read", &via_read)];
        for (route, open) in routes {
            match catch_unwind(AssertUnwindSafe(open)) {
                Ok(res) => {
                    assert!(res.is_err(), "corrupt case {name:?} accepted via {route}")
                }
                Err(_) => panic!("corrupt case {name:?} panicked via {route}"),
            }
        }
        let _ = std::fs::remove_file(&path);
    }
}

#[test]
fn span_splitting_a_utf8_sequence_is_rejected() {
    // Craft a store whose arena holds a multi-byte char, then point a
    // span's end into the middle of it: accepting that span would make
    // per-access unchecked slicing unsound, so decode must reject it.
    let mut store = TraceStore::new();
    store.push(0, TaskId::Gc, "fix grammar", "héllo", 5, 8, 4, 0.25);
    let mut bytes = store.to_binary().unwrap();
    let span_len_off = TRACE_HEADER_BYTES + 24;
    bytes[span_len_off..span_len_off + 4].copy_from_slice(&2u32.to_le_bytes());
    // The lazy open defers per-record span checks; the sweep catches it.
    let err = TraceStore::from_binary_bytes(bytes)
        .unwrap()
        .validate_all()
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("UTF-8"),
        "unexpected error: {err:#}"
    );
}

/// FNV-1a over every view the store can resolve — forces full text
/// resolution (arena + instruction table) in a deterministic order.
fn trace_checksum(store: &TraceStore) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    for i in 0..store.len() {
        let v = store.view(i);
        eat(v.user_input.as_bytes());
        eat(v.instruction.as_bytes());
        eat(&v.gen_len.to_le_bytes());
    }
    h
}

#[test]
fn threads_resolving_views_from_shared_mmap_store_match_single_threaded_sim() {
    let spec = TraceSpec {
        rate: 8.0,
        n_requests: 200,
        seed: 31,
        ..Default::default()
    };
    let store = TraceStore::generate(&spec);
    let path = temp_path("concurrent");
    store.write_file(&path).unwrap();
    let shared = Arc::new(TraceStore::open_mmap(&path).unwrap());

    let cfg = ServingConfig::default();
    let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
    let run = |s: &TraceStore| {
        run_magnus_store(
            &cfg,
            &MagnusPolicy::magnus(),
            trained_predictor(&cfg, 100),
            &engine,
            s,
        )
    };
    let single = run(&store);
    let expect = trace_checksum(&store);
    assert_eq!(trace_checksum(&shared), expect, "backings must agree before racing");

    let concurrent = std::thread::scope(|scope| {
        let readers: Vec<_> = (0..4)
            .map(|_| {
                let s = Arc::clone(&shared);
                scope.spawn(move || {
                    (0..8).map(|_| trace_checksum(&s)).collect::<Vec<u64>>()
                })
            })
            .collect();
        // The sim runs over the same shared mapping while readers hammer
        // every span of it.
        let out = run(&shared);
        for r in readers {
            for sum in r.join().expect("reader thread panicked") {
                assert_eq!(sum, expect, "concurrent resolution diverged");
            }
        }
        out
    });
    assert_identical(&single, &concurrent, "mmap-shared concurrent vs single-threaded");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn resolving_a_meta_against_the_wrong_store_panics_loudly() {
    if !cfg!(debug_assertions) {
        // The provenance stamp is a debug_assert on the resolution hot
        // path; release builds trade the check for throughput.
        return;
    }
    let spec = TraceSpec {
        n_requests: 6,
        seed: 1,
        ..Default::default()
    };
    // Two stores with IDENTICAL content: without the stamp, resolving
    // a's meta against b would silently alias b's (byte-equal) arena —
    // exactly the quiet failure the stamp turns into a loud one.
    let a = TraceStore::generate(&spec);
    let b = TraceStore::generate(&spec);
    assert_eq!(a.arena_str(), b.arena_str());
    let m = a.meta(3);
    assert_eq!(a.user_input(&m), a.user_input(&m)); // right store: fine
    for (what, res) in [
        (
            "user_input",
            catch_unwind(AssertUnwindSafe(|| b.user_input(&m).len())),
        ),
        (
            "instruction",
            catch_unwind(AssertUnwindSafe(|| b.instruction(&m).len())),
        ),
        (
            "view_of",
            catch_unwind(AssertUnwindSafe(|| b.view_of(&m).request_len)),
        ),
    ] {
        assert!(
            res.is_err(),
            "{what}: wrong-store resolution must panic, not alias"
        );
    }

    // Reopening a file mints fresh provenance: metas of the original
    // store don't resolve against the reopened one (and vice versa).
    let path = temp_path("provenance");
    a.write_file(&path).unwrap();
    let reopened = TraceStore::open_mmap(&path).unwrap();
    assert_eq!(reopened.user_input(&reopened.meta(3)), a.user_input(&m));
    assert!(catch_unwind(AssertUnwindSafe(|| reopened.user_input(&m).len())).is_err());
    assert!(
        catch_unwind(AssertUnwindSafe(|| a.user_input(&reopened.meta(3)).len())).is_err()
    );
    let _ = std::fs::remove_file(&path);
}

/// Collision-free temp *directory* (sharded traces live in one).
fn temp_dir(tag: &str) -> PathBuf {
    let d = temp_path(tag).with_extension("d");
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn sharded_single_and_json_backings_agree_bitwise() {
    let cfg = ServingConfig::default();
    let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
    let spec = TraceSpec {
        rate: 6.0,
        n_requests: 90,
        seed: 17,
        ..Default::default()
    };
    let store = TraceStore::generate(&spec);

    // Single binary file, reopened lazily.
    let path = temp_path("equiv");
    store.write_file(&path).unwrap();
    let single = TraceStore::open_mmap(&path).unwrap();
    single.validate_all().unwrap();

    // The same requests split into 3 shards, reopened via the manifest.
    let dir = temp_dir("equiv_shards");
    let manifest = shard_store(&store, 3, &dir).unwrap();
    let sharded = open_manifest(&manifest).unwrap();
    sharded.validate_all().unwrap();
    assert_eq!(sharded.len(), store.len());

    // And the pre-binary JSON route.
    let json_store =
        TraceStore::from_json(&Json::parse(&store.to_json().to_string()).unwrap()).unwrap();

    // Every byte the formats carry agrees, request by request.
    for g in 0..store.len() {
        let base = store.view(g);
        for (route, v) in [
            ("single-file", single.view(g)),
            ("sharded", sharded.view(g)),
            ("json", json_store.view(g)),
        ] {
            assert_eq!(v.id, base.id, "{route}: id of {g}");
            assert_eq!(v.task, base.task, "{route}: task of {g}");
            assert_eq!(v.instruction, base.instruction, "{route}: instruction of {g}");
            assert_eq!(v.user_input, base.user_input, "{route}: user_input of {g}");
            assert_eq!(
                v.user_input_len, base.user_input_len,
                "{route}: user_input_len of {g}"
            );
            assert_eq!(v.request_len, base.request_len, "{route}: request_len of {g}");
            assert_eq!(v.gen_len, base.gen_len, "{route}: gen_len of {g}");
            assert_eq!(
                v.arrival.to_bits(),
                base.arrival.to_bits(),
                "{route}: arrival of {g}"
            );
            assert_eq!(v.uih, base.uih, "{route}: uih of {g}");
        }
    }

    // Bit-identical full serving runs over every backing, sharded
    // included — the generic replay loop never concatenates shards.
    let base = run_magnus_store(
        &cfg,
        &MagnusPolicy::magnus(),
        trained_predictor(&cfg, 80),
        &engine,
        &store,
    );
    assert_identical(
        &base,
        &run_magnus_store(
            &cfg,
            &MagnusPolicy::magnus(),
            trained_predictor(&cfg, 80),
            &engine,
            &single,
        ),
        "single-file vs in-memory",
    );
    assert_identical(
        &base,
        &run_magnus_store(
            &cfg,
            &MagnusPolicy::magnus(),
            trained_predictor(&cfg, 80),
            &engine,
            &sharded,
        ),
        "sharded vs in-memory",
    );
    assert_identical(
        &base,
        &run_magnus_store(
            &cfg,
            &MagnusPolicy::magnus(),
            trained_predictor(&cfg, 80),
            &engine,
            &json_store,
        ),
        "json vs in-memory",
    );

    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_manifests_error_never_panic() {
    let store = TraceStore::generate(&TraceSpec {
        n_requests: 10,
        seed: 7,
        ..Default::default()
    });
    // 2 shards of 5 requests each — entry 1 starts at 5.
    let make = |tag: &str| {
        let dir = temp_dir(tag);
        shard_store(&store, 2, &dir).unwrap();
        let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        (dir, text)
    };
    // Positive control: the pristine directory opens and validates.
    {
        let (dir, _) = make("pristine");
        match open_any(&dir).unwrap() {
            LoadedTrace::Sharded(s) => {
                s.validate_all().unwrap();
                assert_eq!(s.len(), 10);
            }
            LoadedTrace::Single(_) => panic!("directory opened as a single store"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    type Mutation = Box<dyn Fn(&std::path::Path, String) -> String>;
    let flip_shard_byte = |dir: &std::path::Path, shard: &str, off: usize| {
        let p = dir.join(shard);
        let mut b = std::fs::read(&p).unwrap();
        b[off] ^= 0xFF;
        std::fs::write(&p, b).unwrap();
    };
    let cases: Vec<(&str, Mutation)> = vec![
        (
            "missing shard file",
            Box::new(|dir, text| {
                std::fs::remove_file(dir.join("shard-0001.mtr")).unwrap();
                text
            }),
        ),
        (
            "shard header checksum mismatch",
            Box::new(move |dir, text| {
                // A byte inside the 48-byte header trips the manifest
                // checksum before the shard is even opened.
                flip_shard_byte(dir, "shard-0000.mtr", 20);
                text
            }),
        ),
        (
            "overlapping meta range",
            Box::new(|_, text: String| text.replace("\"start\":5", "\"start\":3")),
        ),
        (
            "out-of-order meta range",
            Box::new(|_, text: String| text.replace("\"start\":5", "\"start\":0")),
        ),
        (
            "shard byte length drifted",
            Box::new(|dir, text: String| {
                let len = std::fs::metadata(dir.join("shard-0000.mtr")).unwrap().len();
                text.replace(
                    &format!("\"bytes\":{len}"),
                    &format!("\"bytes\":{}", len + 48),
                )
            }),
        ),
        (
            "shard request count drifted",
            Box::new(|_, text: String| text.replace("\"requests\":5", "\"requests\":4")),
        ),
        (
            "total_requests mismatch",
            Box::new(|_, text: String| {
                text.replace("\"total_requests\":10", "\"total_requests\":11")
            }),
        ),
        (
            "unsupported manifest version",
            Box::new(|_, text: String| text.replace("\"version\":1", "\"version\":99")),
        ),
        (
            "wrong format field",
            Box::new(|_, text: String| {
                text.replace("magnus-trace-manifest", "magnus-trace-manifold")
            }),
        ),
        (
            "empty shards array",
            Box::new(|_, _| {
                "{\"format\":\"magnus-trace-manifest\",\"version\":1,\
                 \"total_requests\":0,\"shards\":[]}"
                    .to_string()
            }),
        ),
        (
            "manifest is not JSON",
            Box::new(|_, _| "not json at all".to_string()),
        ),
    ];
    for (name, mutate) in cases {
        let (dir, text) = make("corrupt");
        let mutated = mutate(&dir, text);
        std::fs::write(dir.join("manifest.json"), &mutated).unwrap();
        match catch_unwind(AssertUnwindSafe(|| {
            open_any(&dir).and_then(|t| match t {
                LoadedTrace::Sharded(s) => s.validate_all(),
                LoadedTrace::Single(_) => Ok(()),
            })
        })) {
            Ok(res) => assert!(res.is_err(), "corrupt manifest {name:?} was accepted"),
            Err(_) => panic!("corrupt manifest {name:?} panicked instead of erroring"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn misnamed_trace_files_load_by_content_not_extension() {
    let store = TraceStore::generate(&TraceSpec {
        n_requests: 8,
        seed: 23,
        ..Default::default()
    });

    // A binary trace hiding behind a .json name still loads as binary.
    let bin_as_json = temp_path("misnamed_bin").with_extension("json");
    store.write_file(&bin_as_json).unwrap();
    match open_any(&bin_as_json).unwrap() {
        LoadedTrace::Single(s) => {
            assert_eq!(s.len(), 8);
            assert!(s.is_file_backed(), "magic sniff must take the binary route");
        }
        LoadedTrace::Sharded(_) => panic!("binary file detected as sharded"),
    }

    // A JSON trace hiding behind a .mtr name still loads as JSON.
    let json_as_mtr = temp_path("misnamed_json"); // temp_path names end in .mtr
    std::fs::write(&json_as_mtr, store.to_json().to_string()).unwrap();
    match open_any(&json_as_mtr).unwrap() {
        LoadedTrace::Single(s) => {
            assert_eq!(s.len(), 8);
            assert_eq!(s.arena_str(), store.arena_str());
        }
        LoadedTrace::Sharded(_) => panic!("JSON trace detected as sharded"),
    }

    // JSON that is neither a trace nor a manifest errors naming the
    // detected format instead of panicking or misloading.
    let stray = temp_path("misnamed_stray");
    std::fs::write(&stray, "{\"not\": \"a trace\"}").unwrap();
    let err = open_any(&stray).unwrap_err().to_string();
    assert!(err.contains("detected JSON"), "unexpected error: {err}");

    for p in [&bin_as_json, &json_as_mtr, &stray] {
        let _ = std::fs::remove_file(p);
    }
}
