//! Satellite coverage for the PR-3 scale structures: the batcher's
//! indexed per-policy selection must equal the linear-scan reference
//! under arbitrary churn (inserts, dispatches, OOM re-queues) and
//! mid-stream estimator-generation bumps, and LogDb cursor readers must
//! observe a consistent prefix while writers append concurrently.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use magnus::batch::{AdaptiveBatcher, BatcherConfig};
use magnus::config::SchedPolicy;
use magnus::estimator::BatchShape;
use magnus::logdb::{BatchLog, LogDb};
use magnus::scheduler::{select, BatchView};
use magnus::util::prop::prop_check;
use magnus::util::Rng;
use magnus::workload::{PredictedRequest, RequestMeta, Span, StoreId, TaskId};

fn request(id: u64, len: u32, pred: u32, arrival: f64) -> PredictedRequest {
    PredictedRequest {
        meta: RequestMeta {
            id,
            task: TaskId::Gc,
            store: StoreId::DETACHED,
            instr: u32::MAX,
            user_input_len: len,
            request_len: len,
            gen_len: pred,
            arrival,
            span: Span::DETACHED,
            uih: 0,
        },
        predicted_gen_len: pred,
    }
}

/// The linear-scan reference, built exactly like the Cached dispatch
/// path: aggregates + cached estimates + `scheduler::select`.
fn scan_reference(
    b: &mut AdaptiveBatcher,
    policy: SchedPolicy,
    now: f64,
    gen: u64,
    est: &impl Fn(&BatchShape) -> f64,
) -> Option<(usize, f64)> {
    let mut views = Vec::with_capacity(b.queue_len());
    for i in 0..b.queue_len() {
        let e = b.cached_estimate(i, gen, |s| est(s));
        let (min_arrival, created_at, batch_id) = b.view_meta(i);
        views.push(BatchView {
            queuing_time: (now - min_arrival).max(0.0),
            est_serving_time: e,
            created_at,
            batch_id,
        });
    }
    select(policy, &views).map(|i| (i, views[i].est_serving_time))
}

/// Heap-based select equals the linear scan for all three policies over
/// random traces with mid-stream estimator-generation bumps — the
/// satellite property test, exercising the public API end to end.
#[test]
fn indexed_select_equals_scan_across_policies_and_generations() {
    for policy in [SchedPolicy::Fcfs, SchedPolicy::Sjf, SchedPolicy::Hrrn] {
        prop_check(30, |rng| {
            // Random Φ: sometimes batches coalesce (joins mutate shapes
            // and stale the heaps), sometimes every request is its own
            // batch (deep queues).
            let coalesce = rng.range_u64(0, 2) == 0;
            let mut b = AdaptiveBatcher::new(BatcherConfig {
                wma_threshold: if coalesce { 50_000.0 } else { 0.0 },
                theta: 6_900_000_000,
                delta: 458_752,
                max_batch_size: 0,
            });
            let mut gen = 1u64;
            let mut now = 0.0;
            let est_of = |gen: u64| {
                move |s: &BatchShape| {
                    s.batch_gen_len as f64 * 0.05
                        + s.batch_len as f64 * 1e-4
                        + s.batch_size as f64 * 0.02
                        + gen as f64 * 0.11
                }
            };
            let n = rng.range_usize(3, 80);
            for i in 0..n {
                now += rng.f64();
                let len = rng.range_u64(1, 1024) as u32;
                let pred = rng.range_u64(1, 1024) as u32;
                b.insert(request(i as u64, len, pred, now - rng.f64() * 2.0), now);
                if rng.range_u64(0, 4) == 0 {
                    gen += 1; // estimator refit mid-stream
                }
                let est = est_of(gen);
                let got = b.select_indexed(policy, now, gen, &est);
                let want = scan_reference(&mut b, policy, now, gen, &est);
                assert_eq!(
                    got.map(|x| x.0),
                    want.map(|x| x.0),
                    "{policy:?} case n={n} i={i} gen={gen}"
                );
                let (g, w) = (got.unwrap(), want.unwrap());
                assert_eq!(
                    g.1.to_bits(),
                    w.1.to_bits(),
                    "{policy:?} estimate mismatch at i={i}"
                );
                // Churn: dispatch the winner, occasionally OOM-split it
                // back into the queue.
                if rng.range_u64(0, 3) == 0 {
                    let taken = b.take(g.0);
                    if taken.size() >= 2 && rng.range_u64(0, 2) == 0 {
                        let nid = b.alloc_id();
                        let (l, r) = taken.split(nid);
                        b.requeue(l);
                        b.requeue(r);
                        let est = est_of(gen);
                        let got = b.select_indexed(policy, now, gen, &est);
                        let want = scan_reference(&mut b, policy, now, gen, &est);
                        assert_eq!(
                            got.map(|x| x.0),
                            want.map(|x| x.0),
                            "{policy:?} post-requeue i={i}"
                        );
                    }
                }
            }
            // Drain what remains: the index must stay exact to the end.
            let est = est_of(gen);
            while !b.is_empty() {
                now += 0.25;
                let got = b.select_indexed(policy, now, gen, &est);
                let want = scan_reference(&mut b, policy, now, gen, &est);
                assert_eq!(got.map(|x| x.0), want.map(|x| x.0), "{policy:?} drain");
                b.take(got.unwrap().0);
            }
            assert!(b.select_indexed(policy, now, gen, &est).is_none());
        });
    }
}

/// Degenerate keys: identical creation times, identical shapes, zero
/// waits — every comparison ties and the smaller batch id must win from
/// the heaps exactly as from the scan.
#[test]
fn indexed_select_tie_storm_matches_scan() {
    let mut rng = Rng::new(42);
    for policy in [SchedPolicy::Fcfs, SchedPolicy::Sjf, SchedPolicy::Hrrn] {
        let mut b = AdaptiveBatcher::new(BatcherConfig {
            wma_threshold: 0.0,
            theta: 6_900_000_000,
            delta: 458_752,
            max_batch_size: 0,
        });
        for i in 0..32 {
            b.insert(request(i, 64, 64, 0.0), 0.0);
        }
        let est = |_: &BatchShape| 3.0;
        let mut picked = Vec::new();
        while !b.is_empty() {
            let now = 5.0;
            let got = b.select_indexed(policy, now, 1, est).unwrap();
            let want = scan_reference(&mut b, policy, now, 1, &est).unwrap();
            assert_eq!(got.0, want.0, "{policy:?}");
            picked.push(b.queue()[got.0].id);
            b.take(got.0);
            // interleave fresh ties to keep the heaps churning
            if picked.len() % 5 == 0 {
                let id = 1000 + picked.len() as u64 + rng.range_u64(0, 3);
                b.insert(request(id, 64, 64, 0.0), 0.0);
            }
        }
        // ids strictly increase within the original tie block
        let original: Vec<u64> = picked.iter().copied().filter(|&id| id < 32).collect();
        let mut sorted = original.clone();
        sorted.sort_unstable();
        assert_eq!(original, sorted, "{policy:?} tie order must be id order");
    }
}

/// LogDb concurrency smoke (satellite): a cursor reader sweeping while
/// writers append sees every batch entry exactly once and in order,
/// while `n_batches` never runs ahead of what a subsequent sweep can
/// observe (consistent prefix).
#[test]
fn logdb_readers_observe_consistent_prefix_under_writes() {
    const WRITERS: usize = 3;
    const PER_WRITER: usize = 700; // > 2 segments each
    let db = Arc::new(LogDb::new());
    let written = Arc::new(AtomicUsize::new(0));
    let handles: Vec<_> = (0..WRITERS)
        .map(|w| {
            let db = db.clone();
            let written = written.clone();
            std::thread::spawn(move || {
                for seq in 0..PER_WRITER {
                    db.log_batch(BatchLog {
                        shape: BatchShape {
                            batch_size: w as u32 + 1,
                            batch_len: seq as u32 + 1,
                            batch_gen_len: 1,
                        },
                        estimated_time: w as f64,
                        actual_time: seq as f64,
                        at: (w * 1_000_000 + seq) as f64,
                    });
                    written.fetch_add(1, Ordering::Release);
                }
            })
        })
        .collect();

    let mut cursor = 0usize;
    let mut per_writer_next = [0usize; WRITERS];
    while cursor < WRITERS * PER_WRITER {
        // Whatever the writers have acknowledged must be fully visible
        // to a sweep that starts afterwards (prefix consistency).
        let floor = written.load(Ordering::Acquire);
        let mut seen_this_sweep = 0usize;
        cursor += db.visit_batches_from(cursor, |l| {
            let code = l.at as usize;
            let (w, seq) = (code / 1_000_000, code % 1_000_000);
            assert_eq!(seq, per_writer_next[w], "writer {w} out of order");
            assert_eq!(l.shape.batch_size, w as u32 + 1, "torn entry");
            per_writer_next[w] += 1;
            seen_this_sweep += 1;
        });
        assert!(cursor >= floor, "sweep saw {cursor} < acknowledged {floor}");
        if seen_this_sweep == 0 {
            std::thread::yield_now();
        }
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(cursor, WRITERS * PER_WRITER);
    assert_eq!(db.n_batches(), WRITERS * PER_WRITER);
    assert!(per_writer_next.iter().all(|&n| n == PER_WRITER));
}
