//! Helpers shared by the golden-equivalence integration suites
//! (`dispatch_equivalence`, `store_equivalence`, `trace_io`).  A
//! subdirectory module, not a test target: each suite pulls it in with
//! `mod common;`, so there is exactly one definition of the equivalence
//! gate and it cannot drift between suites.

use magnus::sim::SimOutput;

/// Field-by-field bitwise comparison of two sim outputs: per-request
/// records, OOM counts, log-DB sizes, predictor and estimator telemetry
/// (values AND timestamps), and the derived summary statistics.  This is
/// the union of every suite's needs — e.g. the predictor telemetry is
/// load-bearing where the two sides run different predict call shapes
/// (store vs owned), and harmlessly redundant elsewhere.
pub fn assert_identical(a: &SimOutput, b: &SimOutput, ctx: &str) {
    assert_eq!(a.metrics.records.len(), b.metrics.records.len(), "{ctx}");
    for (x, y) in a.metrics.records.iter().zip(&b.metrics.records) {
        assert_eq!(x.request_id, y.request_id, "{ctx}");
        assert_eq!(x.arrival.to_bits(), y.arrival.to_bits(), "{ctx}");
        assert_eq!(
            x.finish.to_bits(),
            y.finish.to_bits(),
            "{ctx}: request {} finish {} vs {}",
            x.request_id,
            x.finish,
            y.finish
        );
        assert_eq!(x.valid_tokens, y.valid_tokens, "{ctx}");
        assert_eq!(x.invalid_tokens, y.invalid_tokens, "{ctx}");
    }
    assert_eq!(a.metrics.oom_events, b.metrics.oom_events, "{ctx}");
    assert_eq!(a.db.n_requests(), b.db.n_requests(), "{ctx}");
    assert_eq!(a.db.n_batches(), b.db.n_batches(), "{ctx}");
    assert_eq!(a.pred_errors.len(), b.pred_errors.len(), "{ctx}");
    for (x, y) in a.pred_errors.iter().zip(&b.pred_errors) {
        assert_eq!(x.0.to_bits(), y.0.to_bits(), "{ctx} pred_errors t");
        assert_eq!(x.1.to_bits(), y.1.to_bits(), "{ctx} pred_errors err");
    }
    assert_eq!(a.est_errors.len(), b.est_errors.len(), "{ctx}");
    for (x, y) in a.est_errors.iter().zip(&b.est_errors) {
        assert_eq!(x.0.to_bits(), y.0.to_bits(), "{ctx} est_errors t");
        assert_eq!(x.1.to_bits(), y.1.to_bits(), "{ctx} est_errors err");
    }
    let (sa, sb) = (a.metrics.summarise(), b.metrics.summarise());
    for (va, vb, name) in [
        (sa.request_throughput, sb.request_throughput, "thr"),
        (sa.mean_response_time, sb.mean_response_time, "mean_rt"),
        (sa.p95_response_time, sb.p95_response_time, "p95_rt"),
        (sa.p50_response_time, sb.p50_response_time, "p50_rt"),
        (sa.p90_response_time, sb.p90_response_time, "p90_rt"),
        (sa.p99_response_time, sb.p99_response_time, "p99_rt"),
        (sa.token_throughput, sb.token_throughput, "tok"),
        (sa.valid_token_throughput, sb.valid_token_throughput, "vtok"),
    ] {
        assert_eq!(
            va.to_bits(),
            vb.to_bits(),
            "{ctx}: summary {name} {va} vs {vb}"
        );
    }
    // Robustness counters: the golden suites only ever compare
    // fault-free runs, so beyond matching each other these must all be
    // exactly zero — any nonzero value means a fault-injection code
    // path leaked into the legacy pipeline.
    for (va, vb, name) in [
        (a.metrics.shed.len() as u64, b.metrics.shed.len() as u64, "shed"),
        (u64::from(a.metrics.retries), u64::from(b.metrics.retries), "retries"),
        (
            u64::from(a.metrics.worker_restarts),
            u64::from(b.metrics.worker_restarts),
            "worker_restarts",
        ),
        (
            u64::from(a.metrics.fallback_predictions),
            u64::from(b.metrics.fallback_predictions),
            "fallback_predictions",
        ),
        (
            u64::from(a.metrics.rebucketed),
            u64::from(b.metrics.rebucketed),
            "rebucketed",
        ),
        (
            u64::from(a.metrics.injected_faults),
            u64::from(b.metrics.injected_faults),
            "injected_faults",
        ),
        (
            u64::from(a.metrics.low_confidence_admissions),
            u64::from(b.metrics.low_confidence_admissions),
            "low_confidence_admissions",
        ),
        (
            u64::from(a.metrics.drift_demotions),
            u64::from(b.metrics.drift_demotions),
            "drift_demotions",
        ),
        (
            u64::from(a.metrics.speculative_rebuckets),
            u64::from(b.metrics.speculative_rebuckets),
            "speculative_rebuckets",
        ),
    ] {
        assert_eq!(va, vb, "{ctx}: counter {name}");
        assert_eq!(va, 0, "{ctx}: counter {name} must be zero fault-free");
    }
}
