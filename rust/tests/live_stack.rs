//! Integration over the REAL three-layer stack: Rust coordinator →
//! PJRT-compiled JAX model → Pallas kernels.  Requires `make artifacts`;
//! each test degrades to a skip-notice when they are absent so `cargo
//! test` stays green on a fresh checkout.

use magnus::batch::Batch;
use magnus::config::ServingConfig;
use magnus::engine::pjrt::PjrtBatchServer;
use magnus::engine::BatchOutcome;
use magnus::predictor::{GenLenPredictor, Variant};
use magnus::server::{serve_trace, LivePolicy, ServeOptions};
use magnus::sim::MagnusPolicy;
use magnus::workload::dataset::build_predictor_split;
use magnus::workload::{generate_trace, LlmProfile, Request, TaskId, TraceSpec, TraceStore};

fn have_artifacts() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("skipping live-stack test: run `make artifacts`");
    }
    ok
}

fn req(id: u64, input: &str, gen: u32) -> Request {
    Request {
        id,
        task: TaskId::Bf,
        instruction: "Fix bugs in the following code:".into(),
        user_input: input.into(),
        user_input_len: input.len() as u32,
        request_len: input.len() as u32 + 32,
        gen_len: gen,
        arrival: 0.0,
    }
}

/// Intern `reqs` and form one batch (id `bid`) over the whole store.
fn batch_of(bid: u64, reqs: &[Request]) -> (TraceStore, Batch) {
    let store = TraceStore::from_requests(reqs);
    let b = Batch::of_store(bid, &store);
    (store, b)
}

/// The §II-D batch procedure on real compute: iteration count equals the
/// batch generation length; waiting requests accumulate invalid tokens.
#[test]
fn real_batch_semantics_match_paper() {
    if !have_artifacts() {
        return;
    }
    let mut srv = PjrtBatchServer::load("artifacts").unwrap();
    let (store, b) = batch_of(
        0,
        &[
            req(0, "int main() {}", 3),
            req(1, "def f(): pass", 12),
            req(2, "x = 1", 7),
        ],
    );
    let out = srv.serve(&b, &store).unwrap();
    match out.outcome {
        BatchOutcome::Completed { per_request, .. } => {
            // G(B) = 12; every request runs 12 iterations.
            for (sr, want_valid) in per_request.iter().zip([3u32, 12, 7]) {
                assert_eq!(sr.valid_tokens, want_valid);
                assert_eq!(sr.valid_tokens + sr.invalid_tokens, 12);
            }
        }
        _ => panic!("OOM unexpected"),
    }
    // Valid outputs truncated at the injected EOS.
    assert_eq!(out.generated[0].len(), 3);
    assert_eq!(out.generated[1].len(), 12);
}

/// Batch composition must not change a request's generated tokens
/// (pad-masking correctness through the whole stack — the Pallas mask,
/// the JAX model, the runtime padding and the coordinator agree).
#[test]
fn batchmates_do_not_change_generation() {
    if !have_artifacts() {
        return;
    }
    let mut srv = PjrtBatchServer::load("artifacts").unwrap();
    let (solo_store, solo) = batch_of(0, &[req(0, "alpha beta", 8)]);
    let solo_out = srv.serve(&solo, &solo_store).unwrap();

    let (duo_store, duo) = batch_of(
        1,
        &[
            req(0, "alpha beta", 8),
            req(1, "some other much longer input text!", 8),
        ],
    );
    let duo_out = srv.serve(&duo, &duo_store).unwrap();

    assert_eq!(
        solo_out.generated[0], duo_out.generated[0],
        "request 0's tokens must be independent of its batch-mates"
    );
}

/// Live cluster sanity at 2 workers: all served, Magnus RT ≤ VS RT on the
/// same trace (the paper's headline, at demo scale).
#[test]
fn live_cluster_magnus_not_worse_than_vs() {
    if !have_artifacts() {
        return;
    }
    let g_max = 16u32;
    let mut cfg = ServingConfig::default();
    cfg.gpu.g_max = g_max;
    let trace = generate_trace(&TraceSpec {
        rate: 4.0,
        n_requests: 14,
        g_max,
        l_cap: 30,
        seed: 3,
        ..Default::default()
    });
    let split = build_predictor_split(LlmProfile::ChatGlm6B, 100, 5, g_max, 4);
    let mut p = GenLenPredictor::new(Variant::Usin, &cfg);
    p.train(&split.train);

    let opts = ServeOptions {
        n_workers: 2,
        time_scale: 25.0,
        ..Default::default()
    };
    let magnus = serve_trace(
        &cfg,
        &opts,
        LivePolicy::Magnus(MagnusPolicy::magnus()),
        Some(p),
        &trace,
    )
    .unwrap()
    .summarise();
    let vs = serve_trace(
        &cfg,
        &opts,
        LivePolicy::Vanilla { fixed_batch: 4 },
        None,
        &trace,
    )
    .unwrap()
    .summarise();
    assert_eq!(magnus.n_requests, 14);
    assert_eq!(vs.n_requests, 14);
    // At this tiny scale allow slack, but Magnus must not be dramatically
    // worse; over larger traces it wins (see examples/lmaas_cluster.rs).
    assert!(
        magnus.mean_response_time <= vs.mean_response_time * 1.25,
        "magnus {:.1} vs vs {:.1}",
        magnus.mean_response_time,
        vs.mean_response_time
    );
}
