//! Differential chaos suite for the fault-domain cluster (ISSUE 8).
//!
//! Three gates over `cluster::run_cluster_store`:
//!
//! * **M=1 golden equivalence** — a single-instance cluster under a
//!   no-instance-fault plan is BIT-identical to the single-instance
//!   simulator core (`run_magnus_store_faulted`): the router, ledger and
//!   heartbeat machinery must be pure structure, never arithmetic.
//! * **Seeded instance-fault schedules** — kills, slow instances and
//!   partitions (mixed with engine-level crash/OOM axes) hold the
//!   exactly-once cluster ledger (`offered == completed + shed +
//!   expired`, no id resolved twice) and replay bit-identically.
//! * **Work stealing** — under an adversarially imbalanced placement,
//!   stealing fires and still never duplicates a request id.

mod common;

use std::collections::HashSet;

use magnus::cluster::{
    parse_route_policy, run_cluster_store, ClusterOptions, ClusterOutput,
};
use magnus::config::ServingConfig;
use magnus::engine::cost::CostModelEngine;
use magnus::faults::FaultPlan;
use magnus::metrics::RunMetrics;
use magnus::predictor::{GenLenPredictor, Variant};
use magnus::sim::{
    run_magnus_store_faulted, DispatchMode, MagnusPolicy, SimOutput,
};
use magnus::workload::{open_manifest, shard_store, TraceSpec, TraceStore};

fn cluster_store(n: usize, rate: f64, seed: u64) -> TraceStore {
    TraceStore::generate(&TraceSpec {
        rate,
        n_requests: n,
        seed,
        ..Default::default()
    })
}

/// Run the cluster under the untrained input-length predictor (Uilo) —
/// like the chaos suite, these runs exercise fault plumbing, not forest
/// accuracy.
fn run_cluster(
    cfg: &ServingConfig,
    store: &TraceStore,
    plan: &FaultPlan,
    copts: &ClusterOptions,
    route: &str,
) -> ClusterOutput {
    let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
    let mut policy = parse_route_policy(route, copts.route_seed, cfg.gpu.g_max).unwrap();
    run_cluster_store(
        cfg,
        &MagnusPolicy::magnus(),
        GenLenPredictor::new(Variant::Uilo, cfg),
        &engine,
        store,
        plan,
        copts,
        policy.as_mut(),
    )
}

/// Every admitted id resolves to exactly one terminal state across the
/// merged cluster: no id completes twice, is shed twice, or both.
fn assert_exactly_once(merged: &RunMetrics, store: &TraceStore, ctx: &str) {
    let mut seen = HashSet::new();
    for r in &merged.records {
        assert!(
            seen.insert(r.request_id),
            "{ctx}: request {} completed twice",
            r.request_id
        );
    }
    for &id in &merged.shed {
        assert!(
            seen.insert(id),
            "{ctx}: request {id} shed twice or both completed and shed"
        );
    }
    assert_eq!(seen.len(), store.len(), "{ctx}: admitted != completed + shed");
    for m in store.metas() {
        assert!(seen.contains(&m.id), "{ctx}: request {} lost", m.id);
    }
}

/// Bitwise comparison of two cluster runs (faulted runs carry nonzero
/// robustness counters, so the golden-gate `common::assert_identical`
/// does not fit here).
fn assert_bitwise_replay(a: &ClusterOutput, b: &ClusterOutput, ctx: &str) {
    assert_eq!(a.offered, b.offered, "{ctx}");
    assert_eq!(a.completed, b.completed, "{ctx}: completed");
    assert_eq!(a.shed, b.shed, "{ctx}: shed count");
    assert_eq!(a.duplicate_acks, b.duplicate_acks, "{ctx}: dup acks");
    assert_eq!(a.steals, b.steals, "{ctx}: steals");
    assert_eq!(a.reroutes, b.reroutes, "{ctx}: reroutes");
    assert_eq!(a.failovers, b.failovers, "{ctx}: failovers");
    assert_eq!(a.rejoins, b.rejoins, "{ctx}: rejoins");
    assert_eq!(a.shed_ids, b.shed_ids, "{ctx}: shed ids");
    assert_eq!(a.pred_errors.len(), b.pred_errors.len(), "{ctx}");
    for (x, y) in a.pred_errors.iter().zip(&b.pred_errors) {
        assert_eq!(x.0.to_bits(), y.0.to_bits(), "{ctx}: pred_errors t");
        assert_eq!(x.1.to_bits(), y.1.to_bits(), "{ctx}: pred_errors err");
    }
    assert_eq!(a.nodes.len(), b.nodes.len(), "{ctx}");
    for (i, (na, nb)) in a.nodes.iter().zip(&b.nodes).enumerate() {
        assert_eq!(
            na.metrics.records.len(),
            nb.metrics.records.len(),
            "{ctx}: node {i} record count"
        );
        for (x, y) in na.metrics.records.iter().zip(&nb.metrics.records) {
            assert_eq!(x.request_id, y.request_id, "{ctx}: node {i}");
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits(), "{ctx}: node {i}");
            assert_eq!(
                x.finish.to_bits(),
                y.finish.to_bits(),
                "{ctx}: node {i} request {} finish {} vs {}",
                x.request_id,
                x.finish,
                y.finish
            );
            assert_eq!(x.valid_tokens, y.valid_tokens, "{ctx}: node {i}");
            assert_eq!(x.invalid_tokens, y.invalid_tokens, "{ctx}: node {i}");
        }
        assert_eq!(na.metrics.oom_events, nb.metrics.oom_events, "{ctx}: node {i}");
        assert_eq!(na.metrics.retries, nb.metrics.retries, "{ctx}: node {i}");
        assert_eq!(
            na.metrics.worker_restarts,
            nb.metrics.worker_restarts,
            "{ctx}: node {i}"
        );
        assert_eq!(
            na.metrics.injected_faults,
            nb.metrics.injected_faults,
            "{ctx}: node {i}"
        );
        assert_eq!(na.est_errors.len(), nb.est_errors.len(), "{ctx}: node {i}");
        for (x, y) in na.est_errors.iter().zip(&nb.est_errors) {
            assert_eq!(x.0.to_bits(), y.0.to_bits(), "{ctx}: node {i} est t");
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "{ctx}: node {i} est err");
        }
    }
}

/// An M=1 cluster with no instance faults is the single-instance core
/// wearing a router hat: records, telemetry, log-DB sizes and summary
/// statistics must match the direct run bit for bit.
#[test]
fn single_node_cluster_is_bit_identical_to_core() {
    let cfg = ServingConfig::default();
    let store = cluster_store(220, 10.0, 41);
    let plan = FaultPlan::none();
    let copts = ClusterOptions {
        n_nodes: 1,
        ..Default::default()
    };

    let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
    let direct = run_magnus_store_faulted(
        &cfg,
        &MagnusPolicy::magnus(),
        GenLenPredictor::new(Variant::Uilo, &cfg),
        &engine,
        &store,
        DispatchMode::Indexed,
        &plan,
    );

    let out = run_cluster(&cfg, &store, &plan, &copts, "rr");
    assert!(out.accounted(), "M=1 ledger must close");
    assert_eq!(out.shed, 0, "fault-free M=1 cluster sheds nothing");
    let merged = out.merged_metrics();
    let node = out.nodes.into_iter().next().unwrap();
    let as_sim = SimOutput {
        metrics: merged,
        db: node.db,
        pred_errors: out.pred_errors,
        est_errors: node.est_errors,
    };
    common::assert_identical(&direct, &as_sim, "M=1 cluster vs core");
}

/// Exactly-once cluster ledger under three qualitatively different
/// seeded instance-fault schedules (kill, slow+kill, partition+OOM
/// storm), each mixed with engine-level axes — and bit-identical replay
/// of every schedule.
#[test]
fn instance_fault_schedules_hold_ledger_and_replay_bitwise() {
    let cfg = ServingConfig::default();
    let n = 240;
    let rate = 12.0;
    let span = n as f64 / rate;
    let store = cluster_store(n, rate, 99);
    let copts = ClusterOptions {
        n_nodes: 4,
        hb_interval_s: 0.5,
        suspect_after: 2,
        steal_threshold_tokens: 64,
        route_seed: 7,
    };

    let kill = FaultPlan::parse_spec(&format!(
        "seed=11,crash=0.2,ikill=1:{:.1}..{:.1}",
        0.2 * span,
        0.6 * span
    ))
    .unwrap();
    let slow_kill = FaultPlan::parse_spec(&format!(
        "seed=12,err=0.1,islow=2:{:.1}..{:.1}@5,ikill=3:{:.1}..{:.1}",
        0.1 * span,
        0.7 * span,
        0.4 * span,
        0.8 * span
    ))
    .unwrap();
    let part_storm = FaultPlan::parse_spec(&format!(
        "seed=13,ipart=0:{:.1}..{:.1},oom={:.1}..{:.1}@0.3,guard",
        0.2 * span,
        0.5 * span,
        0.3 * span,
        0.6 * span
    ))
    .unwrap();

    for (name, plan, route) in [
        ("kill", &kill, "jspq"),
        ("slow+kill", &slow_kill, "p2c"),
        ("part+storm", &part_storm, "rr"),
    ] {
        let a = run_cluster(&cfg, &store, plan, &copts, route);
        assert!(
            a.accounted(),
            "{name}: offered {} != completed {} + shed {} + expired {}",
            a.offered,
            a.completed,
            a.shed,
            a.expired
        );
        assert_exactly_once(&a.merged_metrics(), &store, name);
        let b = run_cluster(&cfg, &store, plan, &copts, route);
        assert_bitwise_replay(&a, &b, name);
    }

    // The kill schedules must actually have exercised failover.
    let a = run_cluster(&cfg, &store, &kill, &copts, "jspq");
    assert!(a.failovers > 0, "kill window must trigger a declared failover");
    assert!(a.rejoins > 0, "killed instance must rejoin after its window");
}

/// Work stealing under an adversarially imbalanced placement: a band
/// policy scaled far past the real g_max routes EVERY request to node
/// 0, so its peers sit idle with empty queues and must steal.  Ids
/// move, never copy — the exactly-once set must stay clean and no
/// duplicate acks may appear.
#[test]
fn work_stealing_rebalances_without_duplicating_ids() {
    let cfg = ServingConfig::default();
    let store = cluster_store(200, 30.0, 57);
    let copts = ClusterOptions {
        n_nodes: 4,
        steal_threshold_tokens: 8,
        ..Default::default()
    };

    let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
    // g_max = 255 while every prediction is ≤ 64: band 0 swallows all.
    let mut policy = parse_route_policy("band", copts.route_seed, 255).unwrap();
    let out = run_cluster_store(
        &cfg,
        &MagnusPolicy::magnus(),
        GenLenPredictor::new(Variant::Uilo, &cfg),
        &engine,
        &store,
        &FaultPlan::none(),
        &copts,
        policy.as_mut(),
    );

    assert!(out.accounted(), "stealing run must close the ledger");
    assert!(
        out.steals > 0,
        "all-to-one placement with idle peers must trigger stealing"
    );
    assert_eq!(out.duplicate_acks, 0, "fault-free run may never see dup acks");
    assert_eq!(out.shed, 0, "fault-free run sheds nothing");
    let merged = out.merged_metrics();
    assert_exactly_once(&merged, &store, "stealing");
    // Stealing moved real work off node 0: some peer completed requests.
    let off_node0: usize = out.nodes[1..].iter().map(|n| n.metrics.records.len()).sum();
    assert!(off_node0 > 0, "stolen batches must complete on the thief");
}

/// One shard mapped per instance (ISSUE 10): a 3-shard trace replayed
/// over a 3-instance cluster under the shard-affinity router.  Fault
/// free and with stealing disabled, every request must complete on its
/// home instance — and the exactly-once ledger (debug-asserted inside
/// the run) still closes over the sharded source.
#[test]
fn sharded_trace_maps_one_shard_per_instance() {
    let cfg = ServingConfig::default();
    let store = cluster_store(180, 9.0, 73);
    let dir = std::env::temp_dir().join(format!(
        "magnus_cluster_shards_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let manifest = shard_store(&store, 3, &dir).unwrap();
    let sharded = open_manifest(&manifest).unwrap();
    let copts = ClusterOptions {
        n_nodes: 3,
        // Stealing would move work off its home node; this test pins the
        // shard→instance mapping, so disable it.
        steal_threshold_tokens: 0,
        ..Default::default()
    };

    let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
    let mut policy = parse_route_policy("shard", copts.route_seed, cfg.gpu.g_max).unwrap();
    let out = run_cluster_store(
        &cfg,
        &MagnusPolicy::magnus(),
        GenLenPredictor::new(Variant::Uilo, &cfg),
        &engine,
        &sharded,
        &FaultPlan::none(),
        &copts,
        policy.as_mut(),
    );

    assert!(out.accounted(), "sharded ledger must close");
    assert_eq!(out.shed, 0, "fault-free sharded run sheds nothing");
    assert_eq!(out.duplicate_acks, 0, "fault-free run may never see dup acks");
    assert_exactly_once(&out.merged_metrics(), &store, "sharded");

    // Shard affinity held: node i completed exactly the ids of shard i.
    assert_eq!(out.nodes.len(), 3);
    for (i, node) in out.nodes.iter().enumerate() {
        let want: HashSet<u64> = sharded.shard(i).iter_metas().map(|m| m.id).collect();
        let got: HashSet<u64> =
            node.metrics.records.iter().map(|r| r.request_id).collect();
        assert_eq!(got, want, "node {i} must complete exactly its shard");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
