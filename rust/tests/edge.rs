//! Edge admission invariants, end to end (ISSUE 7 satellite 3):
//!
//! * a zero-RPS edge sheds *everything*, explicitly, over real HTTP;
//! * with no overload the admitted request sequence is a pass-through —
//!   the sim replay of what the edge admitted is byte-identical to the
//!   sim replay of the raw trace (golden gate from `tests/common`);
//! * under combined client chaos (connection drops, slow clients) and
//!   core chaos (crashes, transient errors) every offered request is
//!   accounted for exactly once on both sides of the wire.
//!
//! Everything runs on loopback with small request counts: these are
//! correctness gates, not load tests — `benches/bench_edge.rs` owns the
//! overload curve.

mod common;

use std::sync::Arc;
use std::time::Duration;

use magnus::config::ServingConfig;
use magnus::edge::{
    run_loadgen, AdmissionConfig, AdmissionController, EdgeOptions, EdgeServer, LoadGenConfig,
    Offer,
};
use magnus::faults::FaultPlan;
use magnus::http::HttpConfig;
use magnus::server::LivePolicy;
use magnus::sim::{run_policy_store, trained_predictor, MagnusPolicy, Policy};
use magnus::workload::{TraceSpec, TraceStore};

fn small_store(n: usize, seed: u64) -> Arc<TraceStore> {
    Arc::new(TraceStore::generate(&TraceSpec {
        rate: 8.0,
        n_requests: n,
        seed,
        ..Default::default()
    }))
}

fn edge_opts(admission: AdmissionConfig) -> EdgeOptions {
    EdgeOptions {
        http: HttpConfig {
            read_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            ..Default::default()
        },
        admission,
        n_workers: 2,
        time_scale: 400.0,
        fault_plan: FaultPlan::none(),
        drain_grace: Duration::from_secs(30),
    }
}

/// Zero RPS limit, real sockets: every request comes back `429`, nothing
/// reaches the core, and the ledger still closes.
#[test]
fn zero_rps_edge_sheds_every_request_explicitly() {
    let cfg = ServingConfig::default();
    let store = small_store(16, 31);
    let opts = edge_opts(AdmissionConfig {
        rps_limit: 0.0,
        ..AdmissionConfig::default()
    });
    let edge = EdgeServer::start(
        &cfg,
        &opts,
        LivePolicy::Magnus(MagnusPolicy::magnus()),
        None,
        Arc::clone(&store),
    )
    .unwrap();
    let lg = run_loadgen(&LoadGenConfig {
        addr: edge.addr().to_string(),
        rps: 200.0,
        n_requests: 30,
        trace_len: store.len(),
        n_conns: 4,
        seed: 5,
        ..Default::default()
    })
    .unwrap();
    let report = edge.shutdown().unwrap();
    assert_eq!(lg.shed, 30, "every request must be refused: {lg:?}");
    assert_eq!(lg.ok, 0);
    assert_eq!(report.offered, 30);
    assert_eq!(report.shed, 30);
    assert_eq!(report.completed, 0);
    assert!(report.accounted(), "{report:?}");
    assert_eq!(report.core.records.len(), 0, "nothing may reach the core");
    assert_eq!(report.core.shed.len(), 0);
}

/// No overload → the controller is a pure pass-through, and the sim
/// replay of the admitted sequence is *byte-identical* to the replay of
/// the raw trace, under the shared golden gate.  This is the "the edge
/// costs nothing when idle" claim in its strongest falsifiable form.
#[test]
fn no_overload_admission_is_byte_identical_to_bypassing_the_edge() {
    let cfg = ServingConfig::default();
    let store = small_store(40, 77);
    let mut ctl = AdmissionController::new(AdmissionConfig {
        queue_cap: 64,
        token_budget: u64::MAX,
        rps_limit: f64::INFINITY,
        default_deadline_s: 30.0,
        max_deadline_s: 120.0,
    });
    // Offer the trace in arrival order with its own predictions; with
    // generous budgets every offer must forward, in order.
    let mut predictor = trained_predictor(&cfg, 60);
    let mut admitted = Vec::new();
    for i in 0..store.len() {
        let meta = store.meta(i);
        let p = predictor.predict(store.view(i)).max(1);
        let dl = ctl.resolve_deadline(None, meta.arrival);
        match ctl.offer(meta.id, p, dl, meta.arrival) {
            Offer::Forward => admitted.push(store.request_of(&meta)),
            other => panic!("request {i} not forwarded under no overload: {other:?}"),
        }
        ctl.complete(meta.id);
    }
    let rebuilt = TraceStore::from_requests(&admitted);
    let a = run_policy_store(&cfg, Policy::Magnus, &store, 60);
    let b = run_policy_store(&cfg, Policy::Magnus, &rebuilt, 60);
    common::assert_identical(&a, &b, "edge pass-through vs raw trace");
}

/// Chaos on both sides of the socket: clients drop connections and stall
/// mid-request, the core crashes and throws transient errors — and still
/// every offered request resolves exactly once, on the edge's ledger and
/// the generator's, and the core's own exactly-once identity holds.
#[test]
fn chaos_load_accounts_for_every_request_exactly_once() {
    let cfg = ServingConfig::default();
    let store = small_store(24, 99);

    let mut core_plan = FaultPlan::none();
    core_plan.seed = 11;
    core_plan.crash_p = 0.10;
    core_plan.serve_error_p = 0.10;

    let mut opts = edge_opts(AdmissionConfig {
        queue_cap: 8,
        token_budget: 600,
        rps_limit: f64::INFINITY,
        default_deadline_s: 5.0,
        max_deadline_s: 30.0,
    });
    opts.fault_plan = core_plan;

    let edge = EdgeServer::start(
        &cfg,
        &opts,
        LivePolicy::Magnus(MagnusPolicy::magnus()),
        Some(trained_predictor(&cfg, 60)),
        Arc::clone(&store),
    )
    .unwrap();

    let mut client_plan = FaultPlan::none();
    client_plan.seed = 23;
    client_plan.conn_drop_p = 0.2;
    client_plan.slow_client_p = 0.15;
    client_plan.slow_client_delay_s = 0.05;

    let lg = run_loadgen(&LoadGenConfig {
        addr: edge.addr().to_string(),
        rps: 150.0,
        n_requests: 80,
        trace_len: store.len(),
        burst: None,
        n_conns: 8,
        deadline_ms: Some(5_000),
        plan: client_plan,
        seed: 17,
    })
    .unwrap();
    let report = edge.shutdown().unwrap();

    // Generator side: every request it offered has a terminal outcome.
    assert!(lg.accounted(), "loadgen ledger must close: {lg:?}");
    assert!(lg.dropped > 0, "chaos plan must actually drop connections");
    // Edge side: the admission identity, under chaos.
    assert!(report.accounted(), "edge ledger must close: {report:?}");
    // Dropped connections never became offers; everything else did.
    assert_eq!(report.offered, lg.ok + lg.shed + lg.expired + lg.client_errors);
    // Core side: its exactly-once identity, and agreement with the edge.
    assert_eq!(report.core.records.len() as u64, report.completed);
    assert_eq!(lg.ok, report.completed, "every 200 the client saw completed in core");
    // The server reaped each dropped connection instead of hanging.
    assert!(report.http_reaped >= lg.dropped);
    assert_eq!(report.bad_requests, 0);
}
