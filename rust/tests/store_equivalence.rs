//! Golden equivalence of the zero-copy request plumbing.
//!
//! The compact path — `TraceStore` arena + `Copy` `RequestMeta`s through
//! batcher, scheduler, engine, log DB and continuous learning — must
//! replay the **owned-`Request` reference** (`sim::reference`, an
//! independent implementation that clones requests at arrival and into
//! its logs, evaluates Algorithm 1 by raw Eq. 2–5 member scans, and
//! linear-scans fresh scheduler views) bit for bit: same records, same
//! OOM counts, same estimator/predictor telemetry, across the
//! Magnus-family policies and every `DispatchMode`.  The trace layer has
//! its own golden: the streaming arena generator must emit byte-for-byte
//! the trace the owned generator emits.

use magnus::config::ServingConfig;
use magnus::engine::cost::CostModelEngine;
use magnus::sim::{
    run_magnus_owned, run_magnus_store_with, trained_predictor, DispatchMode, MagnusPolicy,
};
use magnus::util::prop::prop_check;
use magnus::workload::{generate_trace, TraceSpec, TraceStore};

mod common;
use common::assert_identical;

/// The tentpole golden: compact store path ≡ owned reference, across all
/// Magnus-family policies × all dispatch modes, on an overload workload
/// that exercises joins, OOM splits and (for full Magnus) the
/// continuous-learning sweeps.
#[test]
fn compact_store_path_replays_owned_reference_across_policies_and_modes() {
    let cfg = ServingConfig::default();
    let spec = TraceSpec {
        rate: 9.0,
        n_requests: 300,
        seed: 101,
        ..Default::default()
    };
    let trace = generate_trace(&spec);
    let store = TraceStore::generate(&spec); // streaming, not interned-from-owned
    let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);

    for policy in [MagnusPolicy::magnus(), MagnusPolicy::glp(7), MagnusPolicy::abp()] {
        let owned = run_magnus_owned(
            &cfg,
            &policy,
            trained_predictor(&cfg, 60),
            &engine,
            &trace,
        );
        for mode in [DispatchMode::Indexed, DispatchMode::Cached, DispatchMode::Fresh] {
            let compact = run_magnus_store_with(
                &cfg,
                &policy,
                trained_predictor(&cfg, 60),
                &engine,
                &store,
                mode,
            );
            assert_identical(
                &compact,
                &owned,
                &format!(
                    "sched={:?} cap={} est={} mode={mode:?}",
                    policy.sched, policy.max_batch_size, policy.use_estimator
                ),
            );
        }
    }
}

/// OOM recovery equivalence under a shrunken memory budget: splits,
/// re-queues and reload timing must replay identically through the
/// compact and owned representations.
#[test]
fn compact_and_owned_agree_under_oom_splits() {
    let mut cfg = ServingConfig::default();
    cfg.gpu.model_resident_bytes = 20_000_000_000;
    cfg.mem_margin = 1.0; // no planner guard: force engine OOMs
    // Same workload shape tests/integration.rs proves produces OOM splits.
    let spec = TraceSpec {
        rate: 20.0,
        n_requests: 300,
        seed: 17,
        ..Default::default()
    };
    let trace = generate_trace(&spec);
    let store = TraceStore::generate(&spec);
    let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
    let owned = run_magnus_owned(
        &cfg,
        &MagnusPolicy::magnus(),
        trained_predictor(&cfg, 50),
        &engine,
        &trace,
    );
    let compact = run_magnus_store_with(
        &cfg,
        &MagnusPolicy::magnus(),
        trained_predictor(&cfg, 50),
        &engine,
        &store,
        DispatchMode::Indexed,
    );
    assert!(owned.metrics.oom_events > 0, "workload must exercise OOM");
    assert_identical(&compact, &owned, "oom-split workload");
}

/// Property test: random traces, loads and policies — the compact path
/// replays the owned reference bit for bit.
#[test]
fn compact_replays_owned_on_random_traces() {
    prop_check(8, |rng| {
        let cfg = ServingConfig::default();
        let spec = TraceSpec {
            rate: rng.range_f64(2.0, 20.0),
            n_requests: rng.range_usize(40, 130),
            seed: rng.next_u64(),
            ..Default::default()
        };
        let policy = match rng.range_u64(0, 3) {
            0 => MagnusPolicy::magnus(),
            1 => MagnusPolicy::glp(7),
            _ => MagnusPolicy::abp(),
        };
        let mode = match rng.range_u64(0, 3) {
            0 => DispatchMode::Indexed,
            1 => DispatchMode::Cached,
            _ => DispatchMode::Fresh,
        };
        let trace = generate_trace(&spec);
        let store = TraceStore::generate(&spec);
        let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
        let owned =
            run_magnus_owned(&cfg, &policy, trained_predictor(&cfg, 40), &engine, &trace);
        let compact = run_magnus_store_with(
            &cfg,
            &policy,
            trained_predictor(&cfg, 40),
            &engine,
            &store,
            mode,
        );
        assert_identical(
            &compact,
            &owned,
            &format!(
                "rate={:.1} n={} seed={:#x} sched={:?} mode={mode:?}",
                spec.rate, spec.n_requests, spec.seed, policy.sched
            ),
        );
    });
}

/// Trace-layer golden: the streaming arena generator emits byte-for-byte
/// the trace the owned generator emits (all fields, all texts), across
/// random specs — including task-weight and input-cap variants.
#[test]
fn streaming_generator_is_bitwise_identical_to_owned_generator() {
    prop_check(10, |rng| {
        let mut task_weights = Vec::new();
        if rng.range_u64(0, 2) == 0 {
            task_weights = (0..8).map(|_| rng.f64() + 0.01).collect();
        }
        let spec = TraceSpec {
            rate: rng.range_f64(0.5, 30.0),
            n_requests: rng.range_usize(1, 200),
            l_cap: if rng.range_u64(0, 2) == 0 {
                0
            } else {
                rng.range_u64(8, 300) as u32
            },
            task_weights,
            seed: rng.next_u64(),
            ..Default::default()
        };
        let owned = generate_trace(&spec);
        let store = TraceStore::generate(&spec);
        assert_eq!(store.len(), owned.len());
        for (i, r) in owned.iter().enumerate() {
            let v = store.view(i);
            assert_eq!(v.id, r.id);
            assert_eq!(v.task, r.task);
            assert_eq!(v.instruction, r.instruction);
            assert_eq!(v.user_input, r.user_input);
            assert_eq!(v.user_input_len, r.user_input_len);
            assert_eq!(v.request_len, r.request_len);
            assert_eq!(v.gen_len, r.gen_len);
            assert_eq!(v.arrival.to_bits(), r.arrival.to_bits());
        }
        // Round trip through owned materialisation too.
        let back = store.to_requests();
        for (x, y) in back.iter().zip(&owned) {
            assert_eq!(x.user_input, y.user_input);
            assert_eq!(x.instruction, y.instruction);
        }
    });
}
