//! Golden equivalence of the optimized dispatch loop.
//!
//! The indexed dispatch path (per-policy lazy heaps owned by the batcher)
//! and the cached-view path (batcher-maintained aggregates, cached
//! serving-time estimates, swap-removal) must pick bit-for-bit the same
//! batches at the same times as the fresh-view reference across policies,
//! loads and random traces — and the event queue the loop runs on must
//! replay deterministically.  The acceptance-scale run doubles as the
//! tier-1 perf recording: wall clocks for the modes land in
//! `BENCH_sim.json` at the repo root.

use std::time::Instant;

use magnus::config::ServingConfig;
use magnus::engine::cost::CostModelEngine;
use magnus::sim::{
    run_magnus_with, trained_predictor, DispatchMode, EventQueue, MagnusPolicy, SimOutput,
};
use magnus::util::bench::record_sim_bench;
use magnus::util::prop::prop_check;
use magnus::util::Json;
use magnus::workload::{generate_trace, TraceSpec};

mod common;
use common::assert_identical;

fn run_mode(
    cfg: &ServingConfig,
    policy: &MagnusPolicy,
    rate: f64,
    n: usize,
    seed: u64,
    train: usize,
    mode: DispatchMode,
) -> SimOutput {
    let trace = generate_trace(&TraceSpec {
        rate,
        n_requests: n,
        seed,
        ..Default::default()
    });
    let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
    let predictor = trained_predictor(cfg, train);
    run_magnus_with(cfg, policy, predictor, &engine, &trace, mode)
}

/// Acceptance-scale golden run (rate 10, n 600, full Magnus) + perf
/// recording: the wall clock of the modes goes to BENCH_sim.json.
#[test]
fn golden_equivalence_and_bench_at_acceptance_scale() {
    let cfg = ServingConfig::default();
    let policy = MagnusPolicy::magnus();

    let t0 = Instant::now();
    let fresh = run_mode(&cfg, &policy, 10.0, 600, 99, 200, DispatchMode::Fresh);
    let fresh_s = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let cached = run_mode(&cfg, &policy, 10.0, 600, 99, 200, DispatchMode::Cached);
    let cached_s = t0.elapsed().as_secs_f64();

    let indexed = run_mode(&cfg, &policy, 10.0, 600, 99, 200, DispatchMode::Indexed);

    assert_identical(&fresh, &cached, "magnus@rate10/n600 cached");
    assert_identical(&fresh, &indexed, "magnus@rate10/n600 indexed");

    // Record the perf point, but only if no record exists yet: this
    // test runs under parallel test load and takes one sample, so it
    // must not clobber a careful multi-sample `bench_sim` measurement.
    // Timings include predictor training (~identical in both), so this
    // is the conservative end-to-end number.
    let path = format!("{}/../BENCH_sim.json", env!("CARGO_MANIFEST_DIR"));
    if !std::path::Path::new(&path).exists() {
        let _ = record_sim_bench(
            &path,
            10.0,
            600,
            1,
            fresh_s,
            cached_s,
            vec![
                ("policy", Json::str("Magnus")),
                ("source", Json::str("tests/dispatch_equivalence.rs")),
            ],
        );
    }
    // No speedup assertion here: test machines are noisy and tier-1 must
    // stay deterministic; benches/bench_sim.rs asserts and measures
    // properly. Sanity only:
    assert!(fresh_s > 0.0 && cached_s > 0.0);
}

/// Indexed and cached dispatch pick batches identical to the fresh-scan
/// reference across random traces, loads and Magnus-family policies
/// (satellite property test).  Runs cross estimator refits mid-trace, so
/// the indexed paths also replay generation bumps bit-for-bit; in debug
/// builds every indexed select additionally self-checks against the scan
/// inside `AdaptiveBatcher::select_indexed`.
#[test]
fn optimized_and_fresh_dispatch_agree_on_random_traces() {
    prop_check(10, |rng| {
        let cfg = ServingConfig::default();
        let rate = rng.range_f64(2.0, 25.0);
        let n = rng.range_usize(40, 140);
        let seed = rng.next_u64();
        let policy = match rng.range_u64(0, 3) {
            0 => MagnusPolicy::magnus(),
            1 => MagnusPolicy::glp(7),
            _ => MagnusPolicy::abp(),
        };
        let mode = if rng.range_u64(0, 2) == 0 {
            DispatchMode::Indexed
        } else {
            DispatchMode::Cached
        };
        let a = run_mode(&cfg, &policy, rate, n, seed, 40, mode);
        let b = run_mode(&cfg, &policy, rate, n, seed, 40, DispatchMode::Fresh);
        assert_identical(
            &a,
            &b,
            &format!("{mode:?} rate={rate:.1} n={n} seed={seed:#x}"),
        );
    });
}

/// EventQueue determinism survives the refactor: identical push/pop
/// programs (with duplicate timestamps) replay identical sequences.
#[test]
fn event_queue_replays_deterministically() {
    prop_check(60, |rng| {
        let mut q1: EventQueue<u32> = EventQueue::new();
        let mut q2: EventQueue<u32> = EventQueue::new();
        let ops = rng.range_usize(1, 300);
        let mut pending = 0usize;
        for i in 0..ops {
            if pending > 0 && rng.range_u64(0, 3) == 0 {
                let a = q1.pop();
                let b = q2.pop();
                match (a, b) {
                    (Some((ta, ea)), Some((tb, eb))) => {
                        assert_eq!(ta.to_bits(), tb.to_bits());
                        assert_eq!(ea, eb);
                    }
                    (None, None) => {}
                    _ => panic!("queues diverged"),
                }
                pending = pending.saturating_sub(1);
            } else {
                // coarse times → many exact duplicates; sequence numbers
                // must break the ties identically
                let t = rng.range_u64(0, 8) as f64;
                q1.push(t, i as u32);
                q2.push(t, i as u32);
                pending += 1;
            }
        }
        let mut last = f64::NEG_INFINITY;
        loop {
            match (q1.pop(), q2.pop()) {
                (Some((ta, ea)), Some((tb, eb))) => {
                    assert_eq!(ta.to_bits(), tb.to_bits());
                    assert_eq!(ea, eb);
                    assert!(ta >= last);
                    last = ta;
                }
                (None, None) => break,
                _ => panic!("queues diverged at drain"),
            }
        }
    });
}
