//! Differential chaos suite (ISSUE 6 tentpole gate).
//!
//! Headline invariant: under ANY fault schedule, every admitted request
//! completes exactly once or is explicitly shed — nothing is silently
//! lost, nothing is double-served.  Checked over the discrete-event
//! simulator core (`run_magnus_store_faulted`) and the supervised live
//! cluster (`serve_trace_store_sim`, cost-model backend: real threads,
//! channels, restarts and wall clock).
//!
//! Secondary gates:
//! * a fault-free plan (even with non-default retry/backoff budgets) is
//!   bit-identical to the legacy goldens for every Magnus-family policy;
//! * same seed + same plan → bit-identical records, shed lists and
//!   robustness counters on replay (fault decisions are stateless
//!   hashes, not RNG state threaded through the loop);
//! * whole-run OOM storms shed explicitly (bounded retries), whole-run
//!   predictor outages route every admission through the fallback chain.

mod common;

use std::collections::HashSet;
use std::sync::Arc;

use magnus::config::ServingConfig;
use magnus::engine::cost::CostModelEngine;
use magnus::faults::{FaultPlan, OomStorm, PredictorNoise, PredictorOutage, Stall, Window};
use magnus::predictor::{FallbackMode, GenLenPredictor, Variant};
use magnus::server::{serve_trace_store_sim, LivePolicy, ServeOptions};
use magnus::sim::{
    run_magnus_store_faulted, run_policy_store, run_policy_store_faulted, DispatchMode,
    MagnusPolicy, Policy, SimOutput,
};
use magnus::workload::{TraceSpec, TraceStore};

fn chaos_store(n: usize, rate: f64, seed: u64) -> TraceStore {
    TraceStore::generate(&TraceSpec {
        rate,
        n_requests: n,
        seed,
        ..Default::default()
    })
}

/// Run the faulted simulator core under the untrained input-length
/// predictor (Uilo) — chaos runs exercise fault plumbing, not forest
/// accuracy, and skipping training keeps the suite fast.
fn run_chaos(cfg: &ServingConfig, store: &TraceStore, plan: &FaultPlan) -> SimOutput {
    let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
    run_magnus_store_faulted(
        cfg,
        &MagnusPolicy::magnus(),
        GenLenPredictor::new(Variant::Uilo, cfg),
        &engine,
        store,
        DispatchMode::Indexed,
        plan,
    )
}

/// The headline invariant: completed ∪ shed covers every admitted id,
/// with no id appearing twice on either side or both sides.
fn assert_exactly_once(
    records: &[magnus::metrics::RequestRecord],
    shed: &[u64],
    store: &TraceStore,
    ctx: &str,
) {
    let mut seen = HashSet::new();
    for r in records {
        assert!(
            seen.insert(r.request_id),
            "{ctx}: request {} completed twice",
            r.request_id
        );
    }
    for &id in shed {
        assert!(
            seen.insert(id),
            "{ctx}: request {id} shed twice or both completed and shed"
        );
    }
    assert_eq!(
        seen.len(),
        store.len(),
        "{ctx}: admitted != completed + shed"
    );
    for m in store.metas() {
        assert!(seen.contains(&m.id), "{ctx}: request {} lost", m.id);
    }
}

/// Bitwise comparison for FAULTED runs (the golden-gate
/// `common::assert_identical` additionally requires every robustness
/// counter to be zero, so it only fits fault-free pairs).
fn assert_bitwise_replay(a: &SimOutput, b: &SimOutput, ctx: &str) {
    assert_eq!(a.metrics.records.len(), b.metrics.records.len(), "{ctx}");
    for (x, y) in a.metrics.records.iter().zip(&b.metrics.records) {
        assert_eq!(x.request_id, y.request_id, "{ctx}");
        assert_eq!(x.finish.to_bits(), y.finish.to_bits(), "{ctx}");
        assert_eq!(x.valid_tokens, y.valid_tokens, "{ctx}");
        assert_eq!(x.invalid_tokens, y.invalid_tokens, "{ctx}");
    }
    assert_eq!(a.metrics.shed, b.metrics.shed, "{ctx}: shed");
    assert_eq!(a.metrics.oom_events, b.metrics.oom_events, "{ctx}");
    assert_eq!(a.metrics.retries, b.metrics.retries, "{ctx}");
    assert_eq!(a.metrics.worker_restarts, b.metrics.worker_restarts, "{ctx}");
    assert_eq!(
        a.metrics.fallback_predictions,
        b.metrics.fallback_predictions,
        "{ctx}"
    );
    assert_eq!(a.metrics.rebucketed, b.metrics.rebucketed, "{ctx}");
    assert_eq!(a.metrics.injected_faults, b.metrics.injected_faults, "{ctx}");
    assert_eq!(
        a.metrics.low_confidence_admissions,
        b.metrics.low_confidence_admissions,
        "{ctx}"
    );
    assert_eq!(a.metrics.drift_demotions, b.metrics.drift_demotions, "{ctx}");
    assert_eq!(
        a.metrics.drift_repromotions,
        b.metrics.drift_repromotions,
        "{ctx}"
    );
    assert_eq!(
        a.metrics.speculative_rebuckets,
        b.metrics.speculative_rebuckets,
        "{ctx}"
    );
}

/// A plan that injects nothing — even with non-default retry/backoff
/// budgets — must be bit-identical to the legacy entry point for every
/// Magnus-family policy (the fault-free golden gate).
#[test]
fn fault_free_plan_is_bit_identical_to_legacy_goldens() {
    let cfg = ServingConfig::default();
    let store = chaos_store(200, 10.0, 31);
    let mut plan = FaultPlan::none();
    plan.max_retries = 9;
    plan.restart_backoff_s = 1.5;
    assert!(plan.is_noop());
    for policy in [Policy::Magnus, Policy::Glp, Policy::Abp] {
        let a = run_policy_store(&cfg, policy, &store, 120);
        let b = run_policy_store_faulted(&cfg, policy, &store, 120, &plan).unwrap();
        common::assert_identical(&a, &b, policy.name());
    }
}

/// Non-predictive baselines have no supervised dispatch loop to inject
/// into: a noop plan falls through to the legacy run, a non-noop plan is
/// an explicit error (never a silently fault-free run).
#[test]
fn baseline_policies_reject_non_noop_plans() {
    let cfg = ServingConfig::default();
    let store = chaos_store(40, 10.0, 32);
    let ok = run_policy_store_faulted(&cfg, Policy::Vs, &store, 0, &FaultPlan::none());
    assert!(ok.is_ok());
    let mut plan = FaultPlan::none();
    plan.crash_p = 0.5;
    let err = run_policy_store_faulted(&cfg, Policy::Vs, &store, 0, &plan);
    assert!(err.is_err());
}

/// Exactly-once under three qualitatively different schedules, and
/// bit-identical replay of each (stateless fault decisions).
#[test]
fn chaos_schedules_hold_exactly_once_and_replay_bitwise() {
    let cfg = ServingConfig::default();
    let n = 240;
    let rate = 12.0;
    let span = n as f64 / rate;
    let store = chaos_store(n, rate, 99);

    let mut crashes = FaultPlan::none();
    crashes.seed = 11;
    crashes.crash_p = 0.3;
    crashes.serve_error_p = 0.2;

    let mut degraded = FaultPlan::none();
    degraded.seed = 12;
    degraded.stalls = vec![Stall {
        window: Window::new(0.0, span),
        factor: 3.0,
    }];
    degraded.predictor_noise = Some(PredictorNoise {
        bias: 4.0,
        jitter: 0.5,
    });

    let mut storm = FaultPlan::none();
    storm.seed = 13;
    storm.crash_p = 0.15;
    storm.oom_storms = vec![OomStorm {
        window: Window::new(0.25 * span, 0.75 * span),
        p: 0.5,
    }];
    storm.predictor_outages = vec![PredictorOutage {
        window: Window::new(0.5 * span, span),
        mode: FallbackMode::Heuristic,
    }];
    storm.overrun_guard = true;

    for (name, plan) in [
        ("crashes", &crashes),
        ("degraded", &degraded),
        ("storm", &storm),
    ] {
        let a = run_chaos(&cfg, &store, plan);
        assert_exactly_once(&a.metrics.records, &a.metrics.shed, &store, name);
        let b = run_chaos(&cfg, &store, plan);
        assert_bitwise_replay(&a, &b, name);
    }
    // The degraded plan injects no failures: everything completes.
    let degraded_out = run_chaos(&cfg, &store, &degraded);
    assert_eq!(degraded_out.metrics.records.len(), n);
    assert!(degraded_out.metrics.shed.is_empty());
    assert_eq!(degraded_out.metrics.retries, 0);
}

/// A whole-run certain OOM storm: no batch can ever complete, so after
/// bounded splits and retries EVERY request is explicitly shed — the
/// worst case degrades to explicit shedding, never to silent loss.
#[test]
fn total_oom_storm_sheds_everything_explicitly() {
    let cfg = ServingConfig::default();
    let store = chaos_store(60, 15.0, 77);
    let mut plan = FaultPlan::none();
    plan.seed = 5;
    plan.oom_storms = vec![OomStorm {
        window: Window::new(0.0, f64::INFINITY),
        p: 1.0,
    }];
    let out = run_chaos(&cfg, &store, &plan);
    assert_exactly_once(&out.metrics.records, &out.metrics.shed, &store, "total storm");
    assert!(out.metrics.records.is_empty(), "nothing can complete under p=1.0");
    assert_eq!(out.metrics.shed.len(), store.len());
    assert!(out.metrics.oom_events > 0);
    assert!(out.metrics.injected_faults > 0);
}

/// Same storm with the overrun guard on: the EOS-partitioned split path
/// runs (when both sides are non-empty) and the invariant still holds.
#[test]
fn total_oom_storm_with_overrun_guard_still_closes_accounting() {
    let cfg = ServingConfig::default();
    let store = chaos_store(60, 15.0, 77);
    let mut plan = FaultPlan::none();
    plan.seed = 5;
    plan.oom_storms = vec![OomStorm {
        window: Window::new(0.0, f64::INFINITY),
        p: 1.0,
    }];
    plan.overrun_guard = true;
    let out = run_chaos(&cfg, &store, &plan);
    assert_exactly_once(&out.metrics.records, &out.metrics.shed, &store, "guarded storm");
    assert!(out.metrics.oom_events > 0);
}

/// A whole-run predictor outage: every admission routes through the
/// fallback chain, and (with no other faults) everything completes.
#[test]
fn total_predictor_outage_falls_back_for_every_admission() {
    let cfg = ServingConfig::default();
    let store = chaos_store(80, 10.0, 55);
    let mut plan = FaultPlan::none();
    plan.predictor_outages = vec![PredictorOutage {
        window: Window::new(0.0, f64::INFINITY),
        mode: FallbackMode::Heuristic,
    }];
    let out = run_chaos(&cfg, &store, &plan);
    assert_eq!(out.metrics.fallback_predictions as usize, store.len());
    assert_eq!(out.metrics.records.len(), store.len());
    assert!(out.metrics.shed.is_empty());

    plan.predictor_outages[0].mode = FallbackMode::MaxBucket;
    let out = run_chaos(&cfg, &store, &plan);
    assert_eq!(out.metrics.fallback_predictions as usize, store.len());
    assert_exactly_once(&out.metrics.records, &out.metrics.shed, &store, "max bucket");
}

/// Seeded drift schedule under uncertainty-aware scheduling: the
/// windowed bias pushes the per-(app, tier) signed-error EWMA past the
/// budget, the detector demotes the predictor down the fallback chain
/// (fallback admissions appear), serves out the probation window, and
/// re-promotes — then the biased windows bite again.  The whole
/// demotion → probation → re-promotion cycle is deterministic: a second
/// run replays bit-identically, counters included.
#[test]
fn seeded_drift_schedule_demotes_and_repromotes_deterministically() {
    let mut cfg = ServingConfig::default();
    cfg.uncertainty.enabled = true;
    cfg.uncertainty.drift_budget_tokens = 10.0;
    cfg.uncertainty.drift_min_samples = 4;
    cfg.uncertainty.drift_probation = 8;
    let n = 240;
    let store = chaos_store(n, 12.0, 101);
    let mut plan = FaultPlan::parse_spec("drift=0..100000@-0.45").unwrap();
    plan.seed = 17;
    assert!(plan.has_predictor_faults());

    let a = run_chaos(&cfg, &store, &plan);
    assert_exactly_once(&a.metrics.records, &a.metrics.shed, &store, "drift");
    assert!(
        a.metrics.drift_demotions >= 1,
        "sustained bias must demote at least once (got {})",
        a.metrics.drift_demotions
    );
    assert!(
        a.metrics.drift_repromotions >= 1,
        "probation must end in re-promotion at least once (got {})",
        a.metrics.drift_repromotions
    );
    assert!(
        a.metrics.fallback_predictions > 0,
        "demoted windows admit through the fallback chain"
    );
    let b = run_chaos(&cfg, &store, &plan);
    assert_bitwise_replay(&a, &b, "drift replay");
}

/// Uncertainty enabled but neutralised (threshold 0, infinite drift
/// budget) over a noop plan is bit-identical to the disabled config:
/// the confidence layer annotates, it never perturbs the point
/// pipeline.
#[test]
fn neutral_uncertainty_config_matches_disabled_bitwise() {
    let store = chaos_store(160, 10.0, 103);
    let off = ServingConfig::default();
    let mut on = ServingConfig::default();
    on.uncertainty.enabled = true;
    on.uncertainty.confidence_threshold = 0.0;
    on.uncertainty.drift_budget_tokens = 1e9;
    let plan = FaultPlan::none();
    let a = run_chaos(&off, &store, &plan);
    let b = run_chaos(&on, &store, &plan);
    common::assert_identical(&a, &b, "neutral uncertainty");
}

/// Live supervised cluster (cost backend) under heavy crash + transient
/// error pressure: workers die and restart on real threads, yet the
/// exactly-once set invariant holds.  (Wall-clock timing is
/// nondeterministic, so only set-level facts are asserted.)
#[test]
fn live_supervised_crash_chaos_loses_no_request() {
    let mut cfg = ServingConfig::default();
    cfg.gpu.g_max = 24;
    let store = Arc::new(TraceStore::generate(&TraceSpec {
        rate: 20.0,
        n_requests: 30,
        g_max: 24,
        l_cap: 40,
        seed: 21,
        ..Default::default()
    }));
    let mut plan = FaultPlan::none();
    plan.seed = 9;
    plan.crash_p = 0.6;
    plan.serve_error_p = 0.3;
    plan.max_retries = 5;
    plan.max_worker_restarts = 6;
    plan.restart_backoff_s = 0.005;
    let opts = ServeOptions {
        n_workers: 2,
        time_scale: 300.0,
        fault_plan: plan,
        ..Default::default()
    };
    let p = GenLenPredictor::new(Variant::Uilo, &cfg);
    let metrics = serve_trace_store_sim(
        &cfg,
        &opts,
        LivePolicy::Magnus(MagnusPolicy::magnus()),
        Some(p),
        Arc::clone(&store),
    )
    .unwrap();
    assert_exactly_once(&metrics.records, &metrics.shed, &store, "live crash chaos");
}

/// Certain crashes with a tiny restart budget: every incarnation dies on
/// its first serve, the supervisor retires the slot after the budget,
/// and the whole queue is shed — records empty, restart count exact.
#[test]
fn live_all_workers_retired_sheds_whole_queue() {
    let mut cfg = ServingConfig::default();
    cfg.gpu.g_max = 24;
    let store = Arc::new(TraceStore::generate(&TraceSpec {
        rate: 50.0,
        n_requests: 10,
        g_max: 24,
        l_cap: 40,
        seed: 23,
        ..Default::default()
    }));
    let mut plan = FaultPlan::none();
    plan.seed = 3;
    plan.crash_p = 1.0;
    plan.max_worker_restarts = 2;
    plan.restart_backoff_s = 0.002;
    let opts = ServeOptions {
        n_workers: 1,
        time_scale: 300.0,
        fault_plan: plan,
        ..Default::default()
    };
    let p = GenLenPredictor::new(Variant::Uilo, &cfg);
    let metrics = serve_trace_store_sim(
        &cfg,
        &opts,
        LivePolicy::Magnus(MagnusPolicy::magnus()),
        Some(p),
        Arc::clone(&store),
    )
    .unwrap();
    assert!(metrics.records.is_empty(), "crash_p = 1.0 completes nothing");
    assert_eq!(metrics.shed.len(), store.len());
    assert_eq!(metrics.worker_restarts, 2);
    assert_exactly_once(&metrics.records, &metrics.shed, &store, "all retired");
}

/// Live fault-free supervised run keeps every robustness counter at
/// zero — the live analogue of the golden-gate counter assertions.
#[test]
fn live_fault_free_run_reports_zero_robustness_counters() {
    let mut cfg = ServingConfig::default();
    cfg.gpu.g_max = 24;
    let store = Arc::new(TraceStore::generate(&TraceSpec {
        rate: 20.0,
        n_requests: 16,
        g_max: 24,
        l_cap: 40,
        seed: 29,
        ..Default::default()
    }));
    let opts = ServeOptions {
        n_workers: 2,
        time_scale: 300.0,
        ..Default::default()
    };
    let p = GenLenPredictor::new(Variant::Uilo, &cfg);
    let metrics = serve_trace_store_sim(
        &cfg,
        &opts,
        LivePolicy::Magnus(MagnusPolicy::magnus()),
        Some(p),
        Arc::clone(&store),
    )
    .unwrap();
    assert_eq!(metrics.records.len(), 16);
    assert!(metrics.shed.is_empty());
    assert_eq!(metrics.retries, 0);
    assert_eq!(metrics.worker_restarts, 0);
    assert_eq!(metrics.fallback_predictions, 0);
    assert_eq!(metrics.rebucketed, 0);
    assert_eq!(metrics.injected_faults, 0);
}
