//! Cross-module integration tests: the full coordinator pipeline over the
//! cost-model engine, conservation invariants, and paper-shape checks that
//! span multiple subsystems.

use magnus::config::ServingConfig;
use magnus::predictor::{GenLenPredictor, Variant};
use magnus::sim::{run_policy, Policy};
use magnus::util::prop::prop_check_sized;
use magnus::util::stats::rmse;
use magnus::workload::dataset::build_predictor_split;
use magnus::workload::{generate_trace, LlmProfile, TraceSpec};

/// Every policy must conserve requests and tokens for arbitrary traces.
#[test]
fn conservation_across_policies() {
    let cfg = ServingConfig::default();
    prop_check_sized(6, |rng, case| {
        let rate = rng.range_f64(1.0, 30.0);
        let n = 50 + case * 30;
        let trace = generate_trace(&TraceSpec {
            rate,
            n_requests: n,
            seed: rng.next_u64(),
            ..Default::default()
        });
        let total_valid: u64 = trace.iter().map(|r| r.gen_len as u64).sum();
        for policy in [Policy::Vs, Policy::Ccb, Policy::Magnus] {
            let out = run_policy(&cfg, policy, &trace, 30);
            assert_eq!(out.metrics.records.len(), n, "{}", policy.name());
            let valid: u64 = out
                .metrics
                .records
                .iter()
                .map(|r| r.valid_tokens as u64)
                .sum();
            assert_eq!(valid, total_valid, "{} token conservation", policy.name());
            // Response times positive, finishes ordered after arrivals.
            for r in &out.metrics.records {
                assert!(r.finish >= r.arrival);
            }
        }
    });
}

/// Magnus ends the run with every request served exactly once (no
/// duplication through OOM splits).
#[test]
fn oom_splits_do_not_duplicate_requests() {
    let mut cfg = ServingConfig::default();
    // Shrink memory so OOM splits actually happen.
    cfg.gpu.model_resident_bytes = 20_000_000_000;
    cfg.mem_margin = 1.0; // no planner guard: force engine OOMs
    let trace = generate_trace(&TraceSpec {
        rate: 20.0,
        n_requests: 300,
        seed: 17,
        ..Default::default()
    });
    let out = run_policy(&cfg, Policy::Magnus, &trace, 50);
    assert_eq!(out.metrics.records.len(), 300);
    let mut ids: Vec<u64> = out.metrics.records.iter().map(|r| r.request_id).collect();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), 300, "every request served exactly once");
    assert!(out.metrics.oom_events > 0, "test should exercise OOM path");
}

/// The predictor-estimator-scheduler loop: continuous learning data from a
/// real run retrains a fresh predictor to better accuracy.
#[test]
fn served_logs_improve_a_cold_predictor() {
    let cfg = ServingConfig::default();
    let trace = generate_trace(&TraceSpec {
        rate: 10.0,
        n_requests: 600,
        seed: 23,
        ..Default::default()
    });
    let out = run_policy(&cfg, Policy::Magnus, &trace, 40);
    let logs = out.db.requests_between(0.0, f64::INFINITY);
    assert_eq!(logs.len(), 600);

    // Fresh predictor trained only on logged requests from the run.  The
    // compact log carries metas; trace ids index the owned trace, which
    // is the same text the run's store interned.
    let mut p = GenLenPredictor::new(Variant::Usin, &cfg);
    let reqs: Vec<_> = logs
        .iter()
        .map(|l| trace[l.meta.id as usize].clone())
        .collect();
    p.train(&reqs);

    let split = build_predictor_split(LlmProfile::ChatGlm6B, 1, 150, 1024, 29);
    let pred: Vec<f64> = split.test.iter().map(|r| p.predict(r) as f64).collect();
    let act: Vec<f64> = split.test.iter().map(|r| r.gen_len as f64).collect();
    let trained_rmse = rmse(&pred, &act);
    let uilo: Vec<f64> = split
        .test
        .iter()
        .map(|r| r.user_input_len as f64)
        .collect();
    let uilo_rmse = rmse(&uilo, &act);
    assert!(
        trained_rmse < uilo_rmse,
        "log-trained {trained_rmse:.1} !< UILO {uilo_rmse:.1}"
    );
}

/// Fig. 14 shape: windowed prediction RMSE decreases from the first to
/// the last third of a run that starts nearly untrained.
#[test]
fn continuous_learning_reduces_error_over_time() {
    let mut cfg = ServingConfig::default();
    // Shorter sweep periods so several retrains fit in the test's span
    // (the paper's 3 min / 2 min periods over a ~30 min run scale to this).
    cfg.learning.predictor_period_s = 30.0;
    cfg.learning.estimator_period_s = 20.0;
    let trace = generate_trace(&TraceSpec {
        rate: 8.0,
        n_requests: 1500,
        seed: 31,
        ..Default::default()
    });
    let out = run_policy(&cfg, Policy::Magnus, &trace, 30);
    let errs = &out.pred_errors;
    assert!(errs.len() == 1500);
    let t_end = errs.iter().map(|e| e.0).fold(0.0, f64::max);
    let third = t_end / 3.0;
    let rmse_of = |lo: f64, hi: f64| {
        let sq: Vec<f64> = errs
            .iter()
            .filter(|(t, _)| *t >= lo && *t < hi)
            .map(|(_, e)| e * e)
            .collect();
        (sq.iter().sum::<f64>() / sq.len().max(1) as f64).sqrt()
    };
    let first = rmse_of(0.0, third);
    let last = rmse_of(2.0 * third, t_end + 1.0);
    assert!(
        last < first * 0.9,
        "continuous learning: first-third RMSE {first:.1}, last-third {last:.1}"
    );
}

/// Headline claim at heavy load: Magnus beats VS on request throughput by
/// a healthy factor and cuts response time.
#[test]
fn headline_magnus_vs_vanilla() {
    let cfg = ServingConfig::default();
    let trace = generate_trace(&TraceSpec {
        rate: 20.0,
        n_requests: 600,
        seed: 37,
        ..Default::default()
    });
    let magnus = run_policy(&cfg, Policy::Magnus, &trace, 200)
        .metrics
        .summarise();
    let vs = run_policy(&cfg, Policy::Vs, &trace, 0).metrics.summarise();
    let speedup = magnus.request_throughput / vs.request_throughput;
    let rt_cut = 1.0 - magnus.mean_response_time / vs.mean_response_time;
    // Paper: +66%..+234% throughput, −60.3%..−89.7% mean RT.
    assert!(speedup > 1.4, "thr speedup {speedup:.2}");
    assert!(rt_cut > 0.35, "RT reduction {:.0}%", rt_cut * 100.0);
}

/// Deterministic replays: the same seed gives identical metrics.
#[test]
fn end_to_end_determinism() {
    let cfg = ServingConfig::default();
    let trace = generate_trace(&TraceSpec {
        rate: 6.0,
        n_requests: 200,
        seed: 41,
        ..Default::default()
    });
    let a = run_policy(&cfg, Policy::Magnus, &trace, 60).metrics.summarise();
    let b = run_policy(&cfg, Policy::Magnus, &trace, 60).metrics.summarise();
    assert_eq!(a.n_requests, b.n_requests);
    assert_eq!(a.request_throughput, b.request_throughput);
    assert_eq!(a.mean_response_time, b.mean_response_time);
    assert_eq!(a.token_throughput, b.token_throughput);
}

/// Config knobs actually steer the system: a tighter WMA threshold makes
/// more, smaller batches (more homogeneous grouping).
#[test]
fn wma_threshold_controls_grouping() {
    let mut tight = ServingConfig::default();
    tight.wma_threshold = 2_000.0;
    let mut loose = ServingConfig::default();
    loose.wma_threshold = 5_000_000.0;
    let trace = generate_trace(&TraceSpec {
        rate: 20.0,
        n_requests: 400,
        seed: 43,
        ..Default::default()
    });
    let bt = run_policy(&tight, Policy::Magnus, &trace, 60);
    let bl = run_policy(&loose, Policy::Magnus, &trace, 60);
    let mean_beta = |out: &magnus::sim::SimOutput| {
        let logs = out.db.batches_between(0.0, f64::INFINITY);
        logs.iter().map(|b| b.shape.batch_size as f64).sum::<f64>() / logs.len() as f64
    };
    assert!(
        mean_beta(&bt) < mean_beta(&bl),
        "tight Φ should mean smaller batches: {:.1} vs {:.1}",
        mean_beta(&bt),
        mean_beta(&bl)
    );
}
