//! Golden equivalence of the predictor hot-path overhaul.
//!
//! The flattened SoA forest must reproduce the node-enum reference
//! bit-for-bit on random datasets, parallel and serial `Forest::fit`
//! must produce identical trees from the same seed, and the zero-alloc
//! feature pipeline must emit exactly the rows the pre-overhaul
//! allocating pipeline did.  The acceptance-scale run doubles as the
//! tier-1 perf recording: naive-vs-flat predict and refit wall clocks
//! land in `BENCH_predictor.json` at the repo root (single sample,
//! written only when no bench-quality record exists).

use std::time::Instant;

use magnus::config::ServingConfig;
use magnus::predictor::{
    ColMatrix, FeatureExtractor, Forest, ForestParams, GenLenPredictor, Tree,
    TreeParams, Variant,
};
use magnus::util::bench::{bb, record_predictor_bench};
use magnus::util::prop::prop_check;
use magnus::util::{Json, Rng};
use magnus::workload::dataset::build_predictor_split;
use magnus::workload::{LlmProfile, Request, RequestView};

/// Random row-major dataset with deliberate duplicate feature values
/// (ties exercise the stable-sort / equal-value split paths).
fn random_dataset(rng: &mut Rng) -> (Vec<Vec<f32>>, Vec<f32>) {
    let n = rng.range_usize(20, 200);
    let d = rng.range_usize(1, 7);
    let x: Vec<Vec<f32>> = (0..n)
        .map(|_| {
            (0..d)
                .map(|_| {
                    if rng.f64() < 0.4 {
                        // quantised → many exact duplicates
                        rng.range_u64(0, 12) as f32 * 0.5
                    } else {
                        rng.range_f64(-50.0, 50.0) as f32
                    }
                })
                .collect()
        })
        .collect();
    let y: Vec<f32> = x
        .iter()
        .map(|r| r.iter().sum::<f32>() * 2.0 + rng.normal_ms(0.0, 3.0) as f32)
        .collect();
    (x, y)
}

fn random_params(rng: &mut Rng, d: usize) -> ForestParams {
    ForestParams {
        n_trees: rng.range_usize(1, 12),
        tree: TreeParams {
            max_depth: rng.range_usize(2, 14),
            min_samples_leaf: rng.range_usize(1, 5),
            mtry: if rng.f64() < 0.5 {
                0
            } else {
                rng.range_usize(1, d + 1)
            },
        },
        bootstrap_frac: if rng.f64() < 0.3 { 0.6 } else { 1.0 },
    }
}

/// The flattened SoA layout replays the node-enum reference bit-for-bit:
/// single-row predict, batched predict_many, training rows and unseen
/// probes alike.
#[test]
fn flat_forest_matches_node_enum_reference() {
    prop_check(25, |rng| {
        let (x, y) = random_dataset(rng);
        let d = x[0].len();
        let params = random_params(rng, d);
        let mut frng = rng.fork(1);
        let f = Forest::fit(&x, &y, &params, &mut frng);

        let mut probes = x.clone();
        for _ in 0..16 {
            probes.push((0..d).map(|_| rng.range_f64(-80.0, 80.0) as f32).collect());
        }
        let rows_flat: Vec<f32> =
            probes.iter().flat_map(|r| r.iter().copied()).collect();
        let mut batched = Vec::new();
        f.predict_many(&rows_flat, d, &mut batched);
        for (i, row) in probes.iter().enumerate() {
            let reference = f.predict_reference(row);
            assert_eq!(
                f.predict(row).to_bits(),
                reference.to_bits(),
                "row {i}: flat vs enum"
            );
            assert_eq!(
                batched[i].to_bits(),
                reference.to_bits(),
                "row {i}: batched vs enum"
            );
        }
    });
}

/// Parallel and serial `Forest::fit` produce identical trees (and hence
/// identical flat layouts) given the same seed.
#[test]
fn parallel_and_serial_fit_produce_identical_forests() {
    prop_check(15, |rng| {
        let (x, y) = random_dataset(rng);
        let d = x[0].len();
        let params = random_params(rng, d);
        let data = ColMatrix::from_rows(&x);
        let idx: Vec<u32> = (0..x.len() as u32).collect();
        let seed = rng.next_u64();
        let mut r1 = Rng::new(seed);
        let mut r2 = Rng::new(seed);
        let serial = Forest::fit_view_mode(&data, &y, &idx, &params, &mut r1, false);
        let parallel = Forest::fit_view_mode(&data, &y, &idx, &params, &mut r2, true);
        assert_eq!(serial, parallel, "seed {seed:#x}");
    });
}

/// A NaN feature value must not panic mid-fit (total_cmp sort), for
/// single trees and whole forests.
#[test]
fn nan_features_never_panic_fit() {
    prop_check(15, |rng| {
        let (mut x, y) = random_dataset(rng);
        let d = x[0].len();
        for _ in 0..rng.range_usize(1, 6) {
            let i = rng.range_usize(0, x.len());
            let f = rng.range_usize(0, d);
            x[i][f] = f32::NAN;
        }
        let params = random_params(rng, d);
        let mut frng = rng.fork(2);
        let f = Forest::fit(&x, &y, &params, &mut frng);
        let probe: Vec<f32> = (0..d).map(|_| 1.0).collect();
        assert!(f.predict(&probe).is_finite());
        let mut trng = rng.fork(3);
        let t = Tree::fit(&x, &y, &params.tree, &mut trng);
        assert!(t.predict(&probe).is_finite());
    });
}

/// The zero-alloc feature pipeline emits exactly the rows of the
/// pre-overhaul allocating pipeline, across variants and tasks.
#[test]
fn zero_alloc_features_match_baseline_on_real_requests() {
    let split = build_predictor_split(LlmProfile::ChatGlm6B, 8, 4, 1024, 21);
    let mut fx = FeatureExtractor::new();
    let mut row = Vec::new();
    for v in [Variant::Raft, Variant::Inst, Variant::Usin] {
        for r in split.train.iter().chain(&split.test) {
            let base = fx.features_baseline(v, r);
            fx.features_into(v, r, &mut row);
            assert_eq!(base.len(), row.len());
            for (a, b) in base.iter().zip(&row) {
                assert_eq!(a.to_bits(), b.to_bits(), "{} req {}", v.name(), r.id);
            }
        }
    }
}

/// The pre-overhaul predict path (baseline features + node-enum
/// traversal), reproduced from the retained reference APIs.
fn predict_naive(
    fx: &mut FeatureExtractor,
    forest: &Forest,
    req: &Request,
    g_max: u32,
) -> u32 {
    let row = fx.features_baseline(Variant::Usin, req);
    let raw = forest.predict_reference(&row);
    (raw.round().max(1.0) as u32).min(g_max)
}

/// Acceptance-scale golden run (USIN, 400 train/task): the full service
/// path — batched flat predict — matches the naive reference on every
/// test request, and the measured wall clocks are recorded to
/// `BENCH_predictor.json` when no record exists yet.
#[test]
fn golden_equivalence_and_bench_at_acceptance_scale() {
    let cfg = ServingConfig::default();
    let split = build_predictor_split(LlmProfile::ChatGlm6B, 400, 100, 1024, 3);
    let n_test = split.test.len();
    let mut p = GenLenPredictor::new(Variant::Usin, &cfg);
    p.train(&split.train);
    let forest = p.global_forest().expect("trained USIN forest").clone();
    let mut fx = FeatureExtractor::new();
    let g_max = cfg.gpu.g_max;

    let refs: Vec<&Request> = split.test.iter().collect();
    let mut batch = Vec::new();
    p.predict_many(&refs, &mut batch);
    for (i, r) in split.test.iter().enumerate() {
        let naive = predict_naive(&mut fx, &forest, r, g_max);
        assert_eq!(naive, p.predict(r), "req {i}: naive vs flat");
        assert_eq!(naive, batch[i], "req {i}: naive vs batched");
    }

    // Single-sample perf point (tier-1 is built with opt-level 3, so the
    // ratio is representative; benches/bench_predictor.rs overwrites
    // with careful multi-sample numbers).
    let reps = 10;
    let t0 = Instant::now();
    for _ in 0..reps {
        for r in &split.test {
            bb(predict_naive(&mut fx, &forest, r, g_max));
        }
    }
    let naive_s = t0.elapsed().as_secs_f64();
    // Timed over prebuilt views (the serving shape); the owned
    // predict_many wrapper allocates a view Vec per call.
    let views: Vec<RequestView> = split.test.iter().map(|r| r.view()).collect();
    let t0 = Instant::now();
    for _ in 0..reps {
        p.predict_many_views(&views, &mut batch);
        bb(&batch);
    }
    let flat_s = t0.elapsed().as_secs_f64();
    let calls = (reps * n_test) as f64;
    let naive_ns = naive_s * 1e9 / calls;
    let flat_ns = flat_s * 1e9 / calls;

    // refit at a continuous-learning train-set size, one sample each way
    let rows: Vec<Vec<f32>> = split
        .train
        .iter()
        .map(|r| fx.features(Variant::Usin, r))
        .collect();
    let y: Vec<f32> = split.train.iter().map(|r| r.gen_len as f32).collect();
    let data = ColMatrix::from_rows(&rows);
    let idx: Vec<u32> = (0..rows.len() as u32).collect();
    let params = ForestParams {
        n_trees: cfg.rf_trees,
        tree: TreeParams {
            max_depth: cfg.rf_max_depth,
            ..Default::default()
        },
        ..Default::default()
    };
    let nreq = rows.len();
    let t0 = Instant::now();
    {
        let mut rng = Rng::new(7);
        let mut trees = Vec::with_capacity(params.n_trees);
        for t in 0..params.n_trees {
            let mut trng = rng.fork(t as u64);
            let picks: Vec<usize> =
                (0..nreq).map(|_| trng.range_usize(0, nreq)).collect();
            let bx: Vec<Vec<f32>> = picks.iter().map(|&i| rows[i].clone()).collect();
            let by: Vec<f32> = picks.iter().map(|&i| y[i]).collect();
            trees.push(Tree::fit(&bx, &by, &params.tree, &mut trng));
        }
        bb(&trees);
    }
    let refit_naive_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    {
        let mut rng = Rng::new(7);
        bb(Forest::fit_view_mode(&data, &y, &idx, &params, &mut rng, true));
    }
    let refit_flat_s = t0.elapsed().as_secs_f64();

    // Only record when nothing is there yet: this runs under parallel
    // test load with one sample and must not clobber a bench-quality
    // measurement.
    let path = format!("{}/../BENCH_predictor.json", env!("CARGO_MANIFEST_DIR"));
    if !std::path::Path::new(&path).exists() {
        let _ = record_predictor_bench(
            &path,
            split.train.len(),
            n_test,
            1,
            naive_ns,
            flat_ns,
            refit_naive_s,
            refit_flat_s,
            vec![
                ("refit_rows", Json::num(nreq as f64)),
                ("source", Json::str("tests/predictor_equivalence.rs")),
            ],
        );
    }
    assert!(naive_s > 0.0 && flat_s > 0.0);
}
