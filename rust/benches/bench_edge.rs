//! Edge overload curve: a live HTTP front door driven past capacity by
//! the open-loop generator → `BENCH_edge.json` (ISSUE 7).
//!
//! Protocol:
//!
//! 1. **Capacity** — saturate the bare ingress channel (no HTTP, no
//!    admission) and measure completions/second; this is the core's
//!    ceiling `C` and the denominator for every overload multiple.
//! 2. **Sweep** — fresh [`EdgeServer`] per point, offered load at
//!    `{1×, 2×, 5×} C` Poisson plus one bursty 2× point; record goodput,
//!    shed rate, and p50/p99 latency.
//! 3. **Comparison** — the channel-only path paced at `1× C`, so the 1×
//!    edge point has an HTTP-free twin to be judged against.
//!
//! Asserted before anything is recorded, at every point:
//!
//! * the edge accounting identity `offered == completed + shed +
//!   expired + core_shed` (nothing lost, nothing hung);
//! * the generator's own ledger closes (`LoadReport::accounted`);
//! * in full mode, 1× goodput within 10% of the channel-only twin —
//!   the front door must be ~free when there is no overload.
//!
//! `MAGNUS_EDGE_SMOKE` (or `MAGNUS_BENCH_QUICK`) shrinks everything for
//! CI; the 10% goodput gate is skipped there (sub-second runs are noise).

use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use magnus::config::ServingConfig;
use magnus::edge::{run_loadgen, AdmissionConfig, EdgeOptions, EdgeServer, LoadGenConfig};
use magnus::faults::FaultPlan;
use magnus::http::HttpConfig;
use magnus::server::{serve_ingress_sim, CoreSignal, EdgeJob, LivePolicy, ServeOptions};
use magnus::sim::{trained_predictor, MagnusPolicy};
use magnus::util::bench::{record_edge_bench, EdgePoint};
use magnus::util::{Json, Rng};
use magnus::workload::{TraceSpec, TraceStore};

const SEED: u64 = 777;
const TIME_SCALE: f64 = 200.0;
const N_WORKERS: usize = 2;
const DEADLINE_MS: u64 = 3_000;

fn serve_opts() -> ServeOptions {
    ServeOptions {
        n_workers: N_WORKERS,
        time_scale: TIME_SCALE,
        fault_plan: FaultPlan::none(),
        ..Default::default()
    }
}

/// Predicted generation length per trace index, from the same trained
/// predictor the edge uses (the channel paths need them precomputed).
fn predictions(cfg: &ServingConfig, store: &TraceStore) -> Vec<u32> {
    let mut p = trained_predictor(cfg, 120);
    (0..store.len()).map(|i| p.predict(store.view(i)).max(1)).collect()
}

/// Saturate the bare ingress channel: every job offered at t=0, no HTTP,
/// no admission.  Completions per wall second is the core's capacity.
fn channel_capacity(
    cfg: &ServingConfig,
    store: &Arc<TraceStore>,
    preds: &[u32],
    n: usize,
) -> f64 {
    let (jobs_tx, jobs_rx) = mpsc::channel();
    let (sig_tx, sig_rx) = mpsc::channel();
    let t0 = Instant::now();
    for serial in 0..n {
        let i = serial % store.len();
        let mut meta = store.meta(i);
        meta.id = serial as u64 + 1;
        jobs_tx.send(EdgeJob { meta, predicted_gen_len: preds[i] }).unwrap();
    }
    drop(jobs_tx);
    let core = {
        let (cfg, opts, store) = (cfg.clone(), serve_opts(), Arc::clone(store));
        std::thread::spawn(move || {
            serve_ingress_sim(
                &cfg,
                &opts,
                LivePolicy::Magnus(MagnusPolicy::magnus()),
                jobs_rx,
                sig_tx,
                store,
            )
        })
    };
    let mut done = 0usize;
    for sig in sig_rx.iter() {
        if matches!(sig, CoreSignal::Completed { .. }) {
            done += 1;
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let metrics = core.join().unwrap().unwrap();
    assert_eq!(
        metrics.records.len() + metrics.shed.len(),
        n,
        "capacity run must account for every job"
    );
    assert_eq!(done, metrics.records.len());
    done as f64 / elapsed.max(1e-9)
}

/// Channel-only path paced at `rate` — the HTTP-free twin of the 1×
/// edge point.  Returns goodput (everything completes; no admission).
fn channel_paced_goodput(
    cfg: &ServingConfig,
    store: &Arc<TraceStore>,
    preds: &[u32],
    n: usize,
    rate: f64,
) -> f64 {
    let (jobs_tx, jobs_rx) = mpsc::channel();
    let (sig_tx, sig_rx) = mpsc::channel();
    let core = {
        let (cfg, opts, store) = (cfg.clone(), serve_opts(), Arc::clone(store));
        std::thread::spawn(move || {
            serve_ingress_sim(
                &cfg,
                &opts,
                LivePolicy::Magnus(MagnusPolicy::magnus()),
                jobs_rx,
                sig_tx,
                store,
            )
        })
    };
    let t0 = Instant::now();
    let mut rng = Rng::new(SEED ^ 0x9ace);
    let mut due = 0.0f64;
    for serial in 0..n {
        due += rng.exponential(rate.max(1e-9));
        let wait = due - t0.elapsed().as_secs_f64();
        if wait > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(wait));
        }
        let i = serial % store.len();
        let mut meta = store.meta(i);
        meta.id = serial as u64 + 1;
        jobs_tx.send(EdgeJob { meta, predicted_gen_len: preds[i] }).unwrap();
    }
    drop(jobs_tx);
    let mut done = 0usize;
    for sig in sig_rx.iter() {
        if matches!(sig, CoreSignal::Completed { .. }) {
            done += 1;
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    core.join().unwrap().unwrap();
    done as f64 / elapsed.max(1e-9)
}

/// One edge sweep point: fresh server, open-loop load at `rate`, drain,
/// assert the ledgers, fold into an [`EdgePoint`].
#[allow(clippy::too_many_arguments)]
fn edge_point(
    cfg: &ServingConfig,
    store: &Arc<TraceStore>,
    preds: &[u32],
    label: &str,
    overload: f64,
    rate: f64,
    n: usize,
    burst: Option<(f64, f64)>,
) -> EdgePoint {
    // Budget ≈ 48 mean predictions in core; binding under overload,
    // invisible below capacity (the core never holds near 48 batches of
    // headroom at 1×).
    let mean_pred = preds.iter().map(|&p| u64::from(p)).sum::<u64>() / preds.len() as u64;
    let opts = EdgeOptions {
        http: HttpConfig {
            max_connections: 128,
            read_timeout: Duration::from_secs(5),
            ..Default::default()
        },
        admission: AdmissionConfig {
            queue_cap: 32,
            token_budget: mean_pred * 48,
            rps_limit: f64::INFINITY,
            default_deadline_s: DEADLINE_MS as f64 / 1e3,
            max_deadline_s: 30.0,
        },
        n_workers: N_WORKERS,
        time_scale: TIME_SCALE,
        fault_plan: FaultPlan::none(),
        drain_grace: Duration::from_secs(20),
    };
    let edge = EdgeServer::start(
        cfg,
        &opts,
        LivePolicy::Magnus(MagnusPolicy::magnus()),
        Some(trained_predictor(cfg, 120)),
        Arc::clone(store),
    )
    .unwrap();
    let lg = run_loadgen(&LoadGenConfig {
        addr: edge.addr().to_string(),
        rps: rate,
        n_requests: n,
        trace_len: store.len(),
        burst,
        n_conns: 24,
        deadline_ms: Some(DEADLINE_MS),
        plan: FaultPlan::none(),
        seed: SEED,
    })
    .unwrap();
    let report = edge.shutdown().unwrap();
    assert!(report.accounted(), "{label}: edge ledger must close: {report:?}");
    assert!(lg.accounted(), "{label}: loadgen ledger must close: {lg:?}");
    assert_eq!(report.bad_requests, 0, "{label}: bench sends only valid bodies");
    println!(
        "  {label}: offered {} @ {:.0} rps | ok {} shed {} expired {} core-shed {} | \
         goodput {:.1} rps | p99 {:.3}s | lag {:.3}s",
        report.offered,
        rate,
        report.completed,
        report.shed,
        report.expired,
        report.core_shed,
        report.goodput(),
        report.latency.quantile(99.0),
        lg.max_lag_s,
    );
    EdgePoint {
        label: label.to_string(),
        overload,
        offered_rps: rate,
        offered: report.offered,
        completed: report.completed,
        shed: report.shed,
        expired: report.expired,
        core_shed: report.core_shed,
        goodput: report.goodput(),
        shed_rate: report.shed_rate(),
        p50_latency_s: report.latency.quantile(50.0),
        p99_latency_s: report.latency.quantile(99.0),
        max_lag_s: lg.max_lag_s,
    }
}

fn main() {
    let smoke = std::env::var("MAGNUS_EDGE_SMOKE").is_ok()
        || std::env::var("MAGNUS_BENCH_QUICK").is_ok();
    let cfg = ServingConfig::default();
    let store = Arc::new(TraceStore::generate(&TraceSpec {
        rate: 8.0,
        n_requests: 128,
        seed: SEED,
        ..Default::default()
    }));
    let preds = predictions(&cfg, &store);

    let n_cap = if smoke { 80 } else { 400 };
    let capacity = channel_capacity(&cfg, &store, &preds, n_cap);
    println!("== edge overload sweep (capacity {capacity:.1} rps, smoke={smoke}) ==");

    // Point duration in seconds of offered load; n is capped so a very
    // fast core cannot explode the request count.
    let dur = if smoke { 1.5 } else { 6.0 };
    let n_cap_point = if smoke { 300 } else { 3_000 };
    let n_at = |mult: f64| ((capacity * mult * dur) as usize).clamp(20, n_cap_point);

    let mut points = Vec::new();
    for (label, mult) in [("overload_1x", 1.0), ("overload_2x", 2.0), ("overload_5x", 5.0)] {
        points.push(edge_point(
            &cfg,
            &store,
            &preds,
            label,
            mult,
            capacity * mult,
            n_at(mult),
            None,
        ));
    }
    points.push(edge_point(
        &cfg,
        &store,
        &preds,
        "burst_2x",
        2.0,
        capacity * 2.0,
        n_at(2.0),
        Some((1.0, 4.0)),
    ));

    let channel_1x = channel_paced_goodput(&cfg, &store, &preds, n_at(1.0), capacity);
    let edge_1x = points[0].goodput;
    println!("  1x goodput: edge {edge_1x:.1} rps vs channel-only {channel_1x:.1} rps");
    if !smoke {
        assert!(
            edge_1x >= 0.9 * channel_1x,
            "HTTP front door costs more than 10% at 1x: edge {edge_1x:.1} vs channel {channel_1x:.1}"
        );
    }

    let path = format!("{}/../BENCH_edge.json", env!("CARGO_MANIFEST_DIR"));
    record_edge_bench(
        &path,
        capacity,
        &points,
        vec![
            ("channel_goodput_1x", Json::num(channel_1x)),
            ("smoke", Json::num(smoke as u32 as f64)),
        ],
    )
    .unwrap();
    println!("wrote {path}");
}
