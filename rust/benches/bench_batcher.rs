//! §IV-D overhead: WMA-directed batch insertion (paper bound: < 0.001 s)
//! across queue depths, plus the raw WMA evaluation.

use std::time::Duration;

use magnus::batch::wma::{wma_with, mem_with};
use magnus::batch::{AdaptiveBatcher, Batch, BatcherConfig};
use magnus::config::ServingConfig;
use magnus::util::bench::BenchSuite;
use magnus::util::Rng;
use magnus::workload::{PredictedRequest, RequestMeta, Span, StoreId, TaskId};

fn req(id: u64, rng: &mut Rng) -> PredictedRequest {
    let len = rng.range_u64(8, 1024) as u32;
    let gen = rng.range_u64(8, 1024) as u32;
    PredictedRequest {
        meta: RequestMeta {
            id,
            task: TaskId::Gc,
            store: StoreId::DETACHED,
            instr: u32::MAX,
            user_input_len: len,
            request_len: len,
            gen_len: gen,
            arrival: 0.0,
            span: Span::DETACHED,
            uih: 0,
        },
        predicted_gen_len: gen,
    }
}

fn batcher(cfg: &ServingConfig) -> AdaptiveBatcher {
    AdaptiveBatcher::new(BatcherConfig {
        wma_threshold: cfg.wma_threshold,
        theta: cfg.gpu.theta(),
        delta: cfg.gpu.delta_bytes_per_token,
        max_batch_size: 0,
    })
}

fn main() {
    let mut suite = BenchSuite::new("WMA-directed adaptive batcher (§IV-D)");
    suite.header();
    let cfg = ServingConfig::default();
    let mut rng = Rng::new(1);

    // Raw Eq. 2-5 evaluation against a 32-request batch.
    let mut big = Batch::new(0, req(0, &mut rng), 0.0);
    for i in 1..32 {
        big.requests.push(req(i, &mut rng));
    }
    let cand = req(99, &mut rng);
    suite.bench_val("wma_with/β=32", || wma_with(&big, &cand));
    suite.bench_val("mem_with/β=32", || mem_with(&big, &cand, 458_752));

    // Algorithm 1 insertion at different standing queue depths.
    for depth in [10usize, 100, 400] {
        // Pre-fill a queue of `depth` single-request batches with spread-out
        // shapes so candidates rarely coalesce (worst case: full scan).
        let mut b = batcher(&cfg);
        let mut r = Rng::new(2);
        for i in 0..depth as u64 {
            let mut q = req(i, &mut r);
            q.predicted_gen_len = (i as u32 % 64) * 16 + 1;
            q.meta.request_len = ((i as u32 * 37) % 1000) + 8;
            b.insert(q, 0.0);
        }
        let mut i = 1000u64;
        suite.bench(&format!("insert/queue~{depth}"), || {
            i += 1;
            let mut q = req(i, &mut r);
            // randomise shape so it sometimes joins, sometimes opens
            q.predicted_gen_len = (i as u32 % 64) * 16 + 1;
            b.insert(q, 0.0);
        });
    }

    // paper §IV-D: batch packaging takes < 0.001 s
    suite.assert_mean_below("insert/queue~10", Duration::from_millis(1));
    println!("\nPASS: insertion below the paper's 1 ms bound at queue=10");
}
