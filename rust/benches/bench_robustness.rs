//! Robustness degradation curve: the full Magnus pipeline replayed under
//! an escalating deterministic fault schedule → `BENCH_robustness.json`.
//!
//! Each point reruns the SAME trace (same workload seed) under a seeded
//! [`FaultPlan`] whose crash / transient-error / forced-OOM probabilities
//! scale with the point's fault rate; the `fault_rate == 0.0` row is the
//! untouched baseline the degradation ratios divide by.  Two invariants
//! are asserted before any number is recorded:
//!
//! * **exactly-once** — every admitted request completes or is shed
//!   (`completed + shed == n`) at every fault rate;
//! * fault-free shape — the baseline row sheds nothing and reports zero
//!   retries / restarts / fallback predictions.
//!
//! `MAGNUS_ROBUSTNESS_SMOKE` (or `MAGNUS_BENCH_QUICK`) shrinks the trace
//! for CI.

use magnus::config::ServingConfig;
use magnus::engine::cost::CostModelEngine;
use magnus::faults::{FaultPlan, OomStorm, PredictorOutage, Window};
use magnus::predictor::FallbackMode;
use magnus::sim::{run_magnus_store_faulted, trained_predictor, DispatchMode, MagnusPolicy};
use magnus::util::bench::{record_robustness_bench, RobustnessPoint};
use magnus::workload::{TraceSpec, TraceStore};

const RATE: f64 = 8.0;
const SEED: u64 = 4242;
const PREDICTOR_TRAIN: usize = 200;

/// Fault schedule for one sweep point: crash and transient-error
/// probabilities split the rate, an OOM storm covers the whole span at
/// half the rate, and a predictor outage blacks out the middle third.
fn plan_at(fault_rate: f64, span_s: f64) -> FaultPlan {
    let mut plan = FaultPlan::none();
    if fault_rate <= 0.0 {
        return plan;
    }
    plan.seed = 7;
    plan.crash_p = fault_rate / 2.0;
    plan.serve_error_p = fault_rate / 2.0;
    plan.oom_storms = vec![OomStorm {
        window: Window::new(0.0, span_s),
        p: fault_rate / 2.0,
    }];
    plan.predictor_outages = vec![PredictorOutage {
        window: Window::new(0.2 * span_s, 0.5 * span_s),
        mode: FallbackMode::Heuristic,
    }];
    plan.overrun_guard = true;
    plan
}

fn main() {
    let quick = std::env::var("MAGNUS_ROBUSTNESS_SMOKE").is_ok()
        || std::env::var("MAGNUS_BENCH_QUICK").is_ok();
    let n: usize = if quick { 250 } else { 800 };
    // The plan windows are in sim seconds; size them off the nominal
    // arrival span (n / rate) so every storm actually overlaps traffic.
    let span_s = n as f64 / RATE;

    let cfg = ServingConfig::default();
    let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
    let store = TraceStore::generate(&TraceSpec {
        rate: RATE,
        n_requests: n,
        seed: SEED,
        ..Default::default()
    });

    println!("== robustness fault sweep (n={n}, rate={RATE}) ==");
    let mut points: Vec<RobustnessPoint> = Vec::new();
    for &fault_rate in &[0.0, 0.05, 0.15, 0.30] {
        let plan = plan_at(fault_rate, span_s);
        let out = run_magnus_store_faulted(
            &cfg,
            &MagnusPolicy::magnus(),
            trained_predictor(&cfg, PREDICTOR_TRAIN),
            &engine,
            &store,
            DispatchMode::Indexed,
            &plan,
        );
        let m = &out.metrics;
        assert_eq!(
            m.records.len() + m.shed.len(),
            n,
            "exactly-once accounting must close at fault_rate {fault_rate}"
        );
        if fault_rate == 0.0 {
            assert!(m.shed.is_empty(), "fault-free baseline must shed nothing");
            assert_eq!((m.retries, m.worker_restarts, m.fallback_predictions), (0, 0, 0));
        }
        let s = m.summarise();
        println!(
            "  rate {:4.2}: {} done, {} shed | thr {:.3} req/s | mean RT {:.1}s | \
             retries {} | restarts {} | fallbacks {} | OOM {}",
            fault_rate,
            s.n_requests,
            s.shed_requests,
            s.request_throughput,
            s.mean_response_time,
            s.retries,
            s.worker_restarts,
            s.fallback_predictions,
            s.oom_events
        );
        points.push(RobustnessPoint {
            label: format!("fault_rate_{fault_rate}"),
            fault_rate,
            n_requests: n,
            completed: s.n_requests,
            shed: s.shed_requests,
            retries: s.retries,
            worker_restarts: s.worker_restarts,
            fallback_predictions: s.fallback_predictions,
            oom_events: s.oom_events,
            request_throughput: s.request_throughput,
            mean_response_time: s.mean_response_time,
            p95_response_time: s.p95_response_time,
        });
    }

    let path = format!("{}/../BENCH_robustness.json", env!("CARGO_MANIFEST_DIR"));
    record_robustness_bench(&path, n, RATE, &points, vec![]).unwrap();
    println!("wrote {path}");
}
