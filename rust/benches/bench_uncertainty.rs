//! Uncertainty-aware scheduling vs the point-estimate baseline under a
//! seeded drift schedule → `BENCH_uncertainty.json` (ISSUE 9 gate).
//!
//! Both rows replay the SAME trace under the SAME fault plan — a
//! whole-run drift window biasing every trained prediction down by 45%
//! (well past the ≥0.3-bias acceptance bar).  The baseline row runs with
//! `uncertainty.enabled = false`: shrunken predictions overpack batches
//! against Θ, the engine OOMs on the true lengths, and every OOM costs a
//! reload.  The confidence row charges low-confidence admissions their
//! upper-quantile tokens, demotes the predictor down the fallback chain
//! when the signed-error EWMA crosses the drift budget, and speculatively
//! re-buckets low-confidence batches before the OOM reload.
//!
//! Asserted before anything is recorded:
//!
//! * **exactly-once** — completed + shed == n in both rows;
//! * the headline `goodput_retention` (confidence goodput over baseline
//!   goodput) is ≥ 1.2 — the ISSUE 9 acceptance threshold.
//!
//! `MAGNUS_PREDICTOR_SMOKE` (or `MAGNUS_BENCH_QUICK`) shrinks the trace
//! for CI.

use magnus::config::ServingConfig;
use magnus::engine::cost::CostModelEngine;
use magnus::faults::FaultPlan;
use magnus::sim::{run_magnus_store_faulted, trained_predictor, DispatchMode, MagnusPolicy};
use magnus::util::bench::{record_uncertainty_bench, UncertaintyPoint};
use magnus::workload::{TraceSpec, TraceStore};

const RATE: f64 = 8.0;
const SEED: u64 = 9191;
const PREDICTOR_TRAIN: usize = 200;
const DRIFT_BIAS: f64 = -0.45;

fn main() {
    let quick = std::env::var("MAGNUS_PREDICTOR_SMOKE").is_ok()
        || std::env::var("MAGNUS_BENCH_QUICK").is_ok();
    let n: usize = if quick { 250 } else { 800 };
    let span_s = n as f64 / RATE;

    let engine = {
        let cfg = ServingConfig::default();
        CostModelEngine::new(cfg.cost.clone(), &cfg.gpu)
    };
    let store = TraceStore::generate(&TraceSpec {
        rate: RATE,
        n_requests: n,
        seed: SEED,
        ..Default::default()
    });
    // Whole-run bias through the compact-spec parser (what an operator
    // would actually type); seed only matters to the (absent) noise axes.
    let mut plan =
        FaultPlan::parse_spec(&format!("drift=0..{:.0}@{DRIFT_BIAS}", span_s * 10.0)).unwrap();
    plan.seed = 7;

    println!("== uncertainty drift retention (n={n}, rate={RATE}, bias={DRIFT_BIAS}) ==");
    let mut points: Vec<UncertaintyPoint> = Vec::new();
    for enabled in [false, true] {
        let mut cfg = ServingConfig::default();
        cfg.uncertainty.enabled = enabled;
        if enabled {
            // Aggressive posture for the drifted regime: charge the
            // upper quantile for anything short of near-certainty, and
            // let per-(app, tier) cells demote on few samples — the
            // smoke trace spreads thin across cells.
            cfg.uncertainty.confidence_threshold = 0.95;
            cfg.uncertainty.drift_budget_tokens = 15.0;
            cfg.uncertainty.drift_min_samples = 8;
            cfg.uncertainty.drift_probation = 40;
        }
        let out = run_magnus_store_faulted(
            &cfg,
            &MagnusPolicy::magnus(),
            trained_predictor(&cfg, PREDICTOR_TRAIN),
            &engine,
            &store,
            DispatchMode::Indexed,
            &plan,
        );
        let m = &out.metrics;
        assert_eq!(
            m.records.len() + m.shed.len(),
            n,
            "exactly-once accounting must close (uncertainty={enabled})"
        );
        let s = m.summarise();
        println!(
            "  uncertainty={:5}: {} done, {} shed | goodput {:.3} req/s | OOM {} | \
             low-conf {} | demotions {} | spec-rebuckets {} | fallbacks {}",
            enabled,
            s.n_requests,
            s.shed_requests,
            s.request_throughput,
            s.oom_events,
            s.low_confidence_admissions,
            s.drift_demotions,
            s.speculative_rebuckets,
            m.fallback_predictions
        );
        points.push(UncertaintyPoint {
            label: if enabled { "confidence_aware" } else { "point_estimate" }.to_string(),
            uncertainty_enabled: enabled,
            completed: s.n_requests,
            shed: s.shed_requests,
            goodput: s.request_throughput,
            oom_events: s.oom_events,
            low_confidence_admissions: s.low_confidence_admissions,
            drift_demotions: s.drift_demotions,
            drift_repromotions: m.drift_repromotions,
            speculative_rebuckets: s.speculative_rebuckets,
            fallback_predictions: m.fallback_predictions,
            mean_response_time: s.mean_response_time,
        });
    }

    let retention = points[1].goodput / points[0].goodput.max(1e-12);
    println!("goodput retention: {retention:.3}x");
    assert!(
        retention >= 1.2,
        "confidence-aware scheduling must retain >=20% more goodput under \
         {DRIFT_BIAS} drift (got {retention:.3}x)"
    );

    let path = format!("{}/../BENCH_uncertainty.json", env!("CARGO_MANIFEST_DIR"));
    record_uncertainty_bench(&path, n, RATE, DRIFT_BIAS, &points, vec![]).unwrap();
    println!("wrote {path}");
}
