//! Engine benchmarks: (a) the real PJRT decode iteration — the serving
//! hot path of the three-layer stack — across batch buckets; (b) the
//! analytic cost-model engine, which must be fast enough for the
//! discrete-event simulator to sweep thousands of batches per second.
//!
//! Requires artifacts for the PJRT half (skipped with a notice if absent).

use magnus::batch::Batch;
use magnus::config::ServingConfig;
use magnus::engine::cost::CostModelEngine;
use magnus::engine::InferenceEngine;
use magnus::runtime::ModelRuntime;
use magnus::util::bench::BenchSuite;
use magnus::workload::{PredictedRequest, RequestMeta, Span, StoreId, TaskId};

fn req(id: u64, len: u32, gen: u32) -> PredictedRequest {
    PredictedRequest {
        meta: RequestMeta {
            id,
            task: TaskId::Gc,
            store: StoreId::DETACHED,
            instr: u32::MAX,
            user_input_len: len,
            request_len: len,
            gen_len: gen,
            arrival: 0.0,
            span: Span::DETACHED,
            uih: 0,
        },
        predicted_gen_len: gen,
    }
}

fn main() {
    let mut suite = BenchSuite::new("inference engines");
    suite.header();

    // ── analytic engine: closed-form batch time (simulator inner loop) ──
    let cfg = ServingConfig::default();
    let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
    let mut big = Batch::new(0, req(0, 500, 400), 0.0);
    for i in 1..32 {
        big.requests.push(req(i, 100 + i as u32 * 20, 50 + i as u32 * 25));
    }
    suite.bench_val("cost-model/serve_batch β=32", || engine.serve_batch(&big));
    suite.bench_val("cost-model/batch_time closed form", || {
        engine.batch_time(32, 500, 800)
    });

    // ── real PJRT decode iteration per batch bucket ──
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("(PJRT half skipped: run `make artifacts`)");
        return;
    }
    let mut rt = ModelRuntime::load("artifacts").expect("load artifacts");
    let buckets: Vec<usize> = rt.manifest.decode.iter().map(|d| d.batch).collect();
    for &b in buckets.iter().filter(|&&b| b <= 16) {
        // Prefill once to get a cache of the right bucket.
        let prompts: Vec<Vec<u32>> = (0..b).map(|i| vec![1, 60 + i as u32, 70]).collect();
        let out = rt.prefill(&prompts).expect("prefill");
        let bl = rt.manifest.prefill_bucket(b, 3).unwrap().len as u32;
        let lens: Vec<u32> = vec![3; b];
        let tokens: Vec<u32> = vec![5; b];
        // Reuse one cache: decode at a fixed position each iteration
        // (numerically nonsense, representative cost-wise).
        let mut cache = Some(out.cache);
        suite.bench(&format!("pjrt/decode_step β={b}"), || {
            let c = cache.take().unwrap();
            let step = rt
                .decode_step(&tokens, bl, bl, &lens, c)
                .expect("decode");
            cache = Some(step.cache);
        });
    }

    // prefill cost per bucket length (β=1)
    for &(bb, bl) in rt
        .manifest
        .prefill
        .iter()
        .filter(|p| p.batch == 1)
        .map(|p| (p.batch, p.len))
        .collect::<Vec<_>>()
        .iter()
    {
        let prompt = vec![vec![1u32; bl.min(bl)]];
        suite.bench(&format!("pjrt/prefill β={bb} L={bl}"), || {
            std::hint::black_box(rt.prefill(&prompt).expect("prefill"));
        });
    }
}
