//! §IV-D overhead: HRRN batch selection (paper bound: < 0.002 s) across
//! queue depths, vs FCFS and SJF.

use std::time::Duration;

use magnus::config::SchedPolicy;
use magnus::scheduler::{select, BatchView};
use magnus::util::bench::BenchSuite;
use magnus::util::Rng;

fn views(n: usize, seed: u64) -> Vec<BatchView> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| BatchView {
            queuing_time: rng.range_f64(0.0, 500.0),
            est_serving_time: rng.range_f64(0.1, 400.0),
            created_at: rng.range_f64(0.0, 500.0),
            batch_id: i as u64,
        })
        .collect()
}

fn main() {
    let mut suite = BenchSuite::new("batch scheduler (§IV-D)");
    suite.header();

    for depth in [10usize, 100, 1000] {
        let vs = views(depth, depth as u64);
        for policy in [SchedPolicy::Hrrn, SchedPolicy::Fcfs, SchedPolicy::Sjf] {
            suite.bench_val(
                &format!("{}/queue={depth}", policy.name()),
                || select(policy, &vs),
            );
        }
    }

    // paper §IV-D: batch scheduling takes < 0.002 s
    suite.assert_mean_below("hrrn/queue=1000", Duration::from_millis(2));
    println!("\nPASS: HRRN select below the paper's 2 ms bound at queue=1000");
}
