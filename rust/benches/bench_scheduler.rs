//! §IV-D overhead + scale: batch selection across queue depths
//! (Q ∈ {16, 256, 4096}) for the O(Q) linear scan vs the batcher's
//! indexed heaps, plus LogDb append/sweep contention.  Records
//! `BENCH_sched.json` at the repo root (uploaded with the other
//! `BENCH_*.json` artifacts in CI).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use magnus::batch::{AdaptiveBatcher, BatcherConfig};
use magnus::config::SchedPolicy;
use magnus::estimator::BatchShape;
use magnus::logdb::{LogDb, RequestLog};
use magnus::scheduler::{select, BatchView};
use magnus::util::bench::{record_sched_bench, BenchSuite};
use magnus::util::{Json, Rng};
use magnus::workload::{PredictedRequest, RequestMeta, Span, StoreId, TaskId};

const DEPTHS: [usize; 3] = [16, 256, 4096];
const NOW: f64 = 1_000.0;

fn views(n: usize, seed: u64) -> Vec<BatchView> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| BatchView {
            queuing_time: rng.range_f64(0.0, 500.0),
            est_serving_time: rng.range_f64(0.1, 400.0),
            created_at: rng.range_f64(0.0, 500.0),
            batch_id: i as u64,
        })
        .collect()
}

/// Deterministic stand-in estimator: a pure function of the shape, like
/// the real KNN is of (shape, generation).
fn est_fn(s: &BatchShape) -> f64 {
    s.batch_gen_len as f64 * 0.05 + s.batch_len as f64 * 1e-4 + s.batch_size as f64 * 0.01
}

/// A batcher holding `n` distinct single-request batches (Φ = 0 so no
/// two requests coalesce), with randomized shapes and arrivals.
fn filled_batcher(n: usize, seed: u64) -> AdaptiveBatcher {
    let mut rng = Rng::new(seed);
    let mut b = AdaptiveBatcher::new(BatcherConfig {
        wma_threshold: 0.0,
        theta: u64::MAX,
        delta: 1,
        max_batch_size: 0,
    });
    for i in 0..n {
        let len = rng.range_u64(1, 1024) as u32;
        let pred = rng.range_u64(1, 1024) as u32;
        let arrival = rng.range_f64(0.0, 500.0);
        b.insert(
            PredictedRequest {
                meta: RequestMeta {
                    id: i as u64,
                    task: TaskId::Gc,
                    store: StoreId::DETACHED,
                    instr: u32::MAX,
                    user_input_len: len,
                    request_len: len,
                    gen_len: pred,
                    arrival,
                    span: Span::DETACHED,
                    uih: 0,
                },
                predicted_gen_len: pred,
            },
            arrival,
        );
    }
    b
}

fn rlog(at: f64) -> RequestLog {
    RequestLog {
        meta: RequestMeta {
            id: 0,
            task: TaskId::Gc,
            store: StoreId::DETACHED,
            instr: u32::MAX,
            user_input_len: 5,
            request_len: 6,
            gen_len: 7,
            arrival: 0.0,
            span: Span::DETACHED,
            uih: 0,
        },
        predicted_gen_len: 9,
        actual_gen_len: 7,
        at,
    }
}

fn main() {
    let mut suite = BenchSuite::new("batch scheduler + log path (§IV-D, scale)");
    suite.header();

    let mut scan_hrrn_ns = Vec::new();
    let mut indexed_hrrn_ns = Vec::new();

    for &depth in &DEPTHS {
        let vs = views(depth, depth as u64);
        for policy in [SchedPolicy::Hrrn, SchedPolicy::Fcfs, SchedPolicy::Sjf] {
            let r = suite.bench_val(&format!("scan/{}/q={depth}", policy.name()), || {
                select(policy, &vs)
            });
            if policy == SchedPolicy::Hrrn {
                scan_hrrn_ns.push(r.mean_ns);
            }
        }
        for policy in [SchedPolicy::Hrrn, SchedPolicy::Fcfs, SchedPolicy::Sjf] {
            let mut b = filled_batcher(depth, depth as u64);
            // Warm once: pays the one-off heap build for this estimator
            // generation, exactly like the first select after a refit.
            let _ = b.select_indexed(policy, NOW, 1, est_fn);
            let r = suite.bench_val(&format!("indexed/{}/q={depth}", policy.name()), || {
                b.select_indexed(policy, NOW, 1, est_fn).map(|(i, _)| i)
            });
            if policy == SchedPolicy::Hrrn {
                indexed_hrrn_ns.push(r.mean_ns);
            }
        }
        // Steady-state churn: select, dispatch the winner, re-queue it —
        // the index pays its maintenance, the scan its full rebuild.
        let mut b = filled_batcher(depth, depth as u64 ^ 0xC0DE);
        let _ = b.select_indexed(SchedPolicy::Hrrn, NOW, 1, est_fn);
        suite.bench_val(&format!("indexed-churn/hrrn/q={depth}"), || {
            let (i, _) = b.select_indexed(SchedPolicy::Hrrn, NOW, 1, est_fn).unwrap();
            let batch = b.take(i);
            b.requeue(batch);
        });
    }

    // paper §IV-D: batch scheduling takes < 0.002 s — now asserted at 4×
    // the old harness's deepest queue, on both paths.
    suite.assert_mean_below("scan/hrrn/q=4096", Duration::from_millis(2));
    suite.assert_mean_below("indexed/hrrn/q=4096", Duration::from_millis(2));

    // LogDb: append latency alone vs under a continuously-sweeping
    // reader (the live server's worker-log vs learner-sweep contention).
    // Fixed append counts — the store is append-only, so a calibrated
    // bench loop would grow it without bound.
    let quick = std::env::var("MAGNUS_BENCH_QUICK").is_ok();
    let n_appends = if quick { 50_000 } else { 200_000 };
    let timed_appends = |db: &LogDb, n: usize| -> f64 {
        let t0 = std::time::Instant::now();
        for i in 0..n {
            db.log_request(rlog(i as f64));
        }
        t0.elapsed().as_nanos() as f64 / n as f64
    };
    let append_ns = timed_appends(&LogDb::new(), n_appends);
    println!("  logdb/append                    mean {append_ns:8.1} ns  (n={n_appends})");

    let db = Arc::new(LogDb::new());
    let stop = Arc::new(AtomicBool::new(false));
    let sweeper = {
        let db = db.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut cursor = 0usize;
            let mut sweeps = 0usize;
            while !stop.load(Ordering::Relaxed) {
                cursor += db.visit_requests_from(cursor, |r| {
                    std::hint::black_box(r.at);
                });
                sweeps += 1;
            }
            (cursor, sweeps)
        })
    };
    let append_contended_ns = timed_appends(&db, n_appends);
    stop.store(true, Ordering::Relaxed);
    let (swept, sweeps) = sweeper.join().unwrap();
    println!(
        "  logdb/append+sweeper            mean {append_contended_ns:8.1} ns  \
         (sweeper saw {swept} entries over {sweeps} sweeps)"
    );

    let deepest = DEPTHS.len() - 1;
    let speedup = scan_hrrn_ns[deepest] / indexed_hrrn_ns[deepest].max(1e-9);
    println!(
        "\n  hrrn @ q=4096: scan {:.0} ns vs indexed {:.0} ns → {speedup:.1}x",
        scan_hrrn_ns[deepest], indexed_hrrn_ns[deepest]
    );
    assert!(
        speedup > 1.0,
        "indexed select must beat the scan at q=4096 ({speedup:.2}x)"
    );
    // Sublinear growth: 256× deeper queue must cost far less than 256×.
    let growth = indexed_hrrn_ns[deepest] / indexed_hrrn_ns[0].max(1e-9);
    println!("  indexed growth 16→4096: {growth:.1}x (scan would be ~256x)");

    let path = format!("{}/../BENCH_sched.json", env!("CARGO_MANIFEST_DIR"));
    record_sched_bench(
        &path,
        &DEPTHS,
        &scan_hrrn_ns,
        &indexed_hrrn_ns,
        append_ns,
        append_contended_ns,
        vec![
            ("policy", Json::str("Hrrn")),
            ("indexed_growth_16_to_4096", Json::num(growth)),
            ("source", Json::str("benches/bench_scheduler.rs")),
        ],
    )
    .expect("write BENCH_sched.json");
    println!("wrote {path}");
    println!("\nPASS: both select paths under the 2 ms bound; indexed beats scan at q=4096");
}
