//! Cluster routing/fault matrix: every route policy under every fault
//! schedule through the deterministic discrete-event cluster
//! (`run_cluster_store`) → `BENCH_cluster.json`.
//!
//! The matrix crosses the four route policies (rr, jspq, p2c, band)
//! with four instance-level fault schedules (fault-free, one slow
//! instance, one kill window, one partition window) on an M=4 cluster.
//! Every cell replays the SAME trace, and before any number is recorded
//! the cluster ledger must close exactly once
//! (`offered == completed + shed + expired`).
//!
//! The DES path is bit-stable across runs — same seed, same plan, same
//! numbers — so the gated headline (`cluster_goodput`, the best
//! policy's goodput under the slow-instance schedule) cannot flap in
//! CI.  The load-aware policies (jspq, p2c) are expected to beat
//! round-robin here because they route around the stalled instance's
//! predicted-token backlog; the bench records the comparison, it does
//! not assert the ordering.
//!
//! `MAGNUS_CLUSTER_SMOKE` (or `MAGNUS_BENCH_QUICK`) shrinks the trace
//! for CI.

use magnus::cluster::{parse_route_policy, run_cluster_store, ClusterOptions, ROUTE_POLICY_NAMES};
use magnus::config::ServingConfig;
use magnus::engine::cost::CostModelEngine;
use magnus::faults::FaultPlan;
use magnus::predictor::{GenLenPredictor, Variant};
use magnus::sim::MagnusPolicy;
use magnus::util::bench::{record_cluster_bench, ClusterPoint};
use magnus::workload::{TraceSpec, TraceStore};

const RATE: f64 = 20.0;
const SEED: u64 = 4242;
const M: usize = 4;
const HEADLINE_SCHEDULE: &str = "slow1";

/// Instance-level fault schedules, windows sized off the nominal
/// arrival span so each fault actually overlaps traffic.
fn schedules(span_s: f64) -> Vec<(&'static str, FaultPlan)> {
    let slow1 = format!(
        "seed=9,islow=1:{:.1}..{:.1}@8",
        0.1 * span_s,
        0.8 * span_s
    );
    let kill1 = format!("seed=9,ikill=1:{:.1}..{:.1}", 0.2 * span_s, 0.6 * span_s);
    let part2 = format!("seed=9,ipart=2:{:.1}..{:.1}", 0.2 * span_s, 0.5 * span_s);
    vec![
        ("nofault", FaultPlan::none()),
        ("slow1", FaultPlan::parse_spec(&slow1).unwrap()),
        ("kill1", FaultPlan::parse_spec(&kill1).unwrap()),
        ("part2", FaultPlan::parse_spec(&part2).unwrap()),
    ]
}

fn main() {
    let quick = std::env::var("MAGNUS_CLUSTER_SMOKE").is_ok()
        || std::env::var("MAGNUS_BENCH_QUICK").is_ok();
    let n: usize = if quick { 240 } else { 640 };
    let span_s = n as f64 / RATE;

    let cfg = ServingConfig::default();
    let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
    let store = TraceStore::generate(&TraceSpec {
        rate: RATE,
        n_requests: n,
        seed: SEED,
        ..Default::default()
    });
    let copts = ClusterOptions {
        n_nodes: M,
        hb_interval_s: 1.0,
        suspect_after: 2,
        steal_threshold_tokens: 64,
        route_seed: 0xC1_0C,
    };

    println!("== cluster routing/fault matrix (n={n}, rate={RATE}, M={M}) ==");
    let mut points: Vec<ClusterPoint> = Vec::new();
    for (schedule, plan) in schedules(span_s) {
        for &policy_name in &ROUTE_POLICY_NAMES {
            // Fresh routing state and predictor per cell: each run is a
            // standalone, bit-replayable simulation.
            let mut route =
                parse_route_policy(policy_name, copts.route_seed, cfg.gpu.g_max).unwrap();
            let out = run_cluster_store(
                &cfg,
                &MagnusPolicy::magnus(),
                GenLenPredictor::new(Variant::Uilo, &cfg),
                &engine,
                &store,
                &plan,
                &copts,
                route.as_mut(),
            );
            assert_eq!(out.offered, n, "{schedule}/{policy_name}: offered != trace");
            assert!(
                out.accounted(),
                "{schedule}/{policy_name}: ledger must close exactly once \
                 (offered {} completed {} shed {} expired {})",
                out.offered,
                out.completed,
                out.shed,
                out.expired
            );
            let s = out.merged_metrics().summarise();
            println!(
                "  {schedule:>7}/{policy_name:<4}: {} done, {} shed | goodput {:.3} req/s | \
                 p99 {:.2}s | imbalance {:.2} | failovers {} (rec {:.2}s) | \
                 reroutes {} | steals {} | dup-acks {}",
                out.completed,
                out.shed,
                s.request_throughput,
                s.p99_response_time,
                out.imbalance_ratio(),
                out.failovers,
                out.mean_recovery_s(),
                out.reroutes,
                out.steals,
                out.duplicate_acks
            );
            points.push(ClusterPoint {
                policy: policy_name.to_string(),
                schedule: schedule.to_string(),
                goodput: s.request_throughput,
                p99_response_time: s.p99_response_time,
                imbalance: out.imbalance_ratio(),
                recovery_s: out.mean_recovery_s(),
                completed: out.completed,
                shed: out.shed,
                steals: out.steals,
                reroutes: out.reroutes,
                duplicate_acks: out.duplicate_acks,
            });
        }
    }

    let path = format!("{}/../BENCH_cluster.json", env!("CARGO_MANIFEST_DIR"));
    record_cluster_bench(&path, n, RATE, M, HEADLINE_SCHEDULE, &points, vec![]).unwrap();
    println!("wrote {path} (headline schedule: {HEADLINE_SCHEDULE})");
}
