//! End-to-end policy sweep — the bench-harness twin of Figs. 10–13.
//!
//! Runs every policy over the same saturated trace on the cost-model
//! engine and reports request/token throughput and response times, then
//! asserts the paper's headline orderings (who wins).  This is the
//! regression gate for the whole coordinator.

use magnus::config::ServingConfig;
use magnus::metrics::Summary;
use magnus::sim::{run_policy, Policy};
use magnus::util::bench::BenchSuite;
use magnus::workload::{generate_trace, TraceSpec};

fn main() {
    let mut suite = BenchSuite::new("end-to-end policy sweep (Figs. 10–13 shape)");
    suite.header();
    let cfg = ServingConfig::default();
    let quick = std::env::var("MAGNUS_BENCH_QUICK").is_ok();
    let n = if quick { 300 } else { 1000 };
    let trace = generate_trace(&TraceSpec {
        rate: 20.0,
        n_requests: n,
        seed: 99,
        ..Default::default()
    });

    let mut results: Vec<(Policy, Summary, f64)> = Vec::new();
    for p in Policy::ALL {
        let t0 = std::time::Instant::now();
        let s = run_policy(&cfg, p, &trace, 300).metrics.summarise();
        let wall = t0.elapsed().as_secs_f64();
        results.push((p, s, wall));
    }

    println!(
        "\n{:8} | {:>9} | {:>8} | {:>8} | {:>8} | {:>8} | {:>9}",
        "policy", "thr req/s", "mean RT", "p95 RT", "tok/s", "valid/s", "sim wall"
    );
    for (p, s, wall) in &results {
        println!(
            "{:8} | {:9.3} | {:7.1}s | {:7.1}s | {:8.1} | {:8.1} | {:8.2}s",
            p.name(),
            s.request_throughput,
            s.mean_response_time,
            s.p95_response_time,
            s.token_throughput,
            s.valid_token_throughput,
            wall
        );
    }

    let get = |p: Policy| &results.iter().find(|(q, _, _)| *q == p).unwrap().1;
    let (vs, vsq, ccb, glp, abp, magnus) = (
        get(Policy::Vs),
        get(Policy::Vsq),
        get(Policy::Ccb),
        get(Policy::Glp),
        get(Policy::Abp),
        get(Policy::Magnus),
    );

    // Fig. 11a ordering
    assert!(magnus.request_throughput > ccb.request_throughput);
    assert!(ccb.request_throughput > vs.request_throughput);
    assert!(vs.request_throughput > vsq.request_throughput);
    // Fig. 11b ordering
    assert!(magnus.mean_response_time < ccb.mean_response_time);
    assert!(vs.mean_response_time < vsq.mean_response_time);
    // Fig. 13 ablation ordering
    assert!(glp.request_throughput > vs.request_throughput);
    assert!(abp.request_throughput > glp.request_throughput);
    println!(
        "\nPASS orderings: Magnus>CCB>VS>VSQ (thr), Magnus<CCB (RT), VS<GLP<ABP (thr)"
    );
    println!(
        "Magnus vs VS: thr ×{:.2}, mean RT −{:.0}%  (paper: ×1.66–3.34, −60–90%)",
        magnus.request_throughput / vs.request_throughput,
        100.0 * (1.0 - magnus.mean_response_time / vs.mean_response_time)
    );

    // Also time the whole-sweep cost so sim perf regressions surface.
    suite.bench("sim/magnus 300req@rate20", || {
        let t = generate_trace(&TraceSpec {
            rate: 20.0,
            n_requests: 300,
            seed: 5,
            ..Default::default()
        });
        std::hint::black_box(run_policy(&cfg, Policy::Magnus, &t, 50));
    });
}
