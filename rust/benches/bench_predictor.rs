//! Predictor hot path (§IV-D): flattened SoA forest + zero-alloc feature
//! pipeline vs the node-enum / per-call-allocation baseline, plus the
//! continuous-learning refit cost (parallel index-based fit vs the
//! pre-overhaul serial row-cloned shape).  Asserts the paper's < 0.03 s
//! prediction bound and records `BENCH_predictor.json` at the repo root
//! (same shape as `BENCH_sim.json`; the acceptance floor for the
//! overhaul is a 5× per-request USIN predict speedup).

use std::time::Duration;

use magnus::config::ServingConfig;
use magnus::predictor::{
    ColMatrix, FeatureExtractor, Forest, ForestParams, GenLenPredictor, Tree,
    TreeParams, Variant,
};
use magnus::util::bench::{bb, record_predictor_bench, BenchSuite};
use magnus::util::{Json, Rng};
use magnus::workload::dataset::build_predictor_split;
use magnus::workload::{LlmProfile, Request, RequestView};

/// The pre-overhaul predict path: fresh feature `Vec` per call (baseline
/// embedder with per-bigram key concatenation, cached-row clone) into
/// the node-enum tree traversal.
fn predict_naive(
    fx: &mut FeatureExtractor,
    forest: &Forest,
    req: &Request,
    g_max: u32,
) -> u32 {
    let row = fx.features_baseline(Variant::Usin, req);
    let raw = forest.predict_reference(&row);
    (raw.round().max(1.0) as u32).min(g_max)
}

fn mean_ns(suite: &BenchSuite, name: &str) -> f64 {
    suite
        .results
        .iter()
        .find(|r| r.name == name)
        .unwrap_or_else(|| panic!("no bench named {name}"))
        .mean_ns
}

fn main() {
    let mut suite = BenchSuite::new("generation-length predictor hot path (§IV-D)");
    suite.header();
    let cfg = ServingConfig::default();
    let split = build_predictor_split(LlmProfile::ChatGlm6B, 400, 100, 1024, 3);
    let n_test = split.test.len();

    // paper-bound check per variant (the seed harness's cases)
    for v in [Variant::Raft, Variant::Inst, Variant::Usin] {
        let mut p = GenLenPredictor::new(v, &cfg);
        p.train(&split.train);
        let mut i = 0;
        suite.bench_val(&format!("predict/{}", v.name()), || {
            i = (i + 1) % n_test;
            p.predict(&split.test[i])
        });
    }

    // === USIN predict: naive baseline vs flattened + zero-alloc ===
    let mut p = GenLenPredictor::new(Variant::Usin, &cfg);
    p.train(&split.train);
    let forest = p.global_forest().expect("trained USIN forest").clone();
    let mut fx = FeatureExtractor::new();
    let g_max = cfg.gpu.g_max;

    // golden check before timing anything: all three paths agree exactly
    let refs: Vec<&Request> = split.test.iter().collect();
    let mut batch = Vec::new();
    p.predict_many(&refs, &mut batch);
    for (i, r) in split.test.iter().enumerate() {
        let naive = predict_naive(&mut fx, &forest, r, g_max);
        assert_eq!(naive, p.predict(r), "req {i}: naive vs flat diverge");
        assert_eq!(naive, batch[i], "req {i}: naive vs batched diverge");
    }

    let mut i = 0;
    suite.bench_val("predict/USIN/naive(enum+alloc)", || {
        i = (i + 1) % n_test;
        predict_naive(&mut fx, &forest, &split.test[i], g_max)
    });
    // one logical op = the whole test set through the batched view path
    // (prebuilt views, as the simulator's arrival drain holds them — the
    // owned predict_many wrapper would add a per-call Vec<RequestView>)
    let views: Vec<RequestView> = split.test.iter().map(|r| r.view()).collect();
    suite.bench(&format!("predict/USIN/flat(batch of {n_test})"), || {
        p.predict_many_views(&views, &mut batch);
        bb(&batch);
    });
    let naive_ns = mean_ns(&suite, "predict/USIN/naive(enum+alloc)");
    let flat_single_ns = mean_ns(&suite, "predict/USIN");
    let flat_batch_ns =
        mean_ns(&suite, &format!("predict/USIN/flat(batch of {n_test})")) / n_test as f64;

    // === continuous-learning refit: pre-overhaul row-cloned serial vs
    // index-based parallel, at augmented train-set sizes ===
    let mut refit_naive_s = 0.0;
    let mut refit_flat_s = 0.0;
    let mut refit_rows = 0usize;
    for n in [100usize, 400] {
        let split = build_predictor_split(LlmProfile::ChatGlm6B, n, 1, 1024, 4);
        let mut fx = FeatureExtractor::new();
        let rows: Vec<Vec<f32>> = split
            .train
            .iter()
            .map(|r| fx.features(Variant::Usin, r))
            .collect();
        let y: Vec<f32> = split.train.iter().map(|r| r.gen_len as f32).collect();
        let data = ColMatrix::from_rows(&rows);
        let idx: Vec<u32> = (0..rows.len() as u32).collect();
        let params = ForestParams {
            n_trees: cfg.rf_trees,
            tree: TreeParams {
                max_depth: cfg.rf_max_depth,
                ..Default::default()
            },
            ..Default::default()
        };
        let nreq = rows.len();
        let naive = suite
            .bench(&format!("refit/naive-rowclone-serial/{nreq}rows"), || {
                // the pre-overhaul shape: clone every bootstrap row,
                // fit trees one after another
                let mut rng = Rng::new(7);
                let mut trees = Vec::with_capacity(params.n_trees);
                for t in 0..params.n_trees {
                    let mut trng = rng.fork(t as u64);
                    let picks: Vec<usize> =
                        (0..nreq).map(|_| trng.range_usize(0, nreq)).collect();
                    let bx: Vec<Vec<f32>> =
                        picks.iter().map(|&i| rows[i].clone()).collect();
                    let by: Vec<f32> = picks.iter().map(|&i| y[i]).collect();
                    trees.push(Tree::fit(&bx, &by, &params.tree, &mut trng));
                }
                bb(&trees);
            })
            .mean_ns;
        let flat = suite
            .bench(&format!("refit/flat-parallel/{nreq}rows"), || {
                let mut rng = Rng::new(7);
                bb(Forest::fit_view_mode(&data, &y, &idx, &params, &mut rng, true));
            })
            .mean_ns;
        // record the largest (closest to continuous-learning reality)
        refit_naive_s = naive / 1e9;
        refit_flat_s = flat / 1e9;
        refit_rows = nreq;
    }

    // paper §IV-D: prediction takes < 0.03 s
    suite.assert_mean_below("predict/USIN", Duration::from_millis(30));

    let speedup = naive_ns / flat_batch_ns.max(1e-9);
    let refit_speedup = refit_naive_s / refit_flat_s.max(1e-12);
    println!(
        "\n  USIN predict: naive {naive_ns:.0} ns vs flat batched {flat_batch_ns:.0} ns/req \
         → {speedup:.2}x (acceptance floor: 5.00x; single-row flat {flat_single_ns:.0} ns)"
    );
    println!(
        "  refit @ {refit_rows} rows: naive {refit_naive_s:.4} s vs parallel \
         {refit_flat_s:.4} s → {refit_speedup:.2}x"
    );

    let path = format!("{}/../BENCH_predictor.json", env!("CARGO_MANIFEST_DIR"));
    record_predictor_bench(
        &path,
        split.train.len(),
        n_test,
        suite.samples(),
        naive_ns,
        flat_batch_ns,
        refit_naive_s,
        refit_flat_s,
        vec![
            ("refit_rows", Json::num(refit_rows as f64)),
            ("flat_single_ns", Json::num(flat_single_ns)),
            ("source", Json::str("benches/bench_predictor.rs")),
        ],
    )
    .expect("write BENCH_predictor.json");
    println!("wrote {path}");
    println!("\nPASS: USIN predict below the paper's 30 ms bound; all paths bit-identical");
}
