//! §IV-D overhead: generation-length prediction latency (paper bound:
//! < 0.03 s per request), plus training-time scaling.

use std::time::Duration;

use magnus::config::ServingConfig;
use magnus::predictor::{GenLenPredictor, Variant};
use magnus::util::bench::BenchSuite;
use magnus::workload::dataset::build_predictor_split;
use magnus::workload::LlmProfile;

fn main() {
    let mut suite = BenchSuite::new("generation-length predictor (§IV-D)");
    suite.header();
    let cfg = ServingConfig::default();
    let split = build_predictor_split(LlmProfile::ChatGlm6B, 400, 100, 1024, 3);

    for v in [Variant::Raft, Variant::Inst, Variant::Usin] {
        let mut p = GenLenPredictor::new(v, &cfg);
        p.train(&split.train);
        let mut i = 0;
        suite.bench_val(&format!("predict/{}", v.name()), || {
            i = (i + 1) % split.test.len();
            p.predict(&split.test[i])
        });
    }

    // training cost at increasing train-set sizes (continuous-learning
    // refits run every 3 minutes and must stay cheap)
    for n in [100usize, 400] {
        let split = build_predictor_split(LlmProfile::ChatGlm6B, n, 1, 1024, 4);
        suite.bench(&format!("train/USIN/{}req", n * 8), || {
            let mut p = GenLenPredictor::new(Variant::Usin, &cfg);
            p.train(&split.train);
        });
    }

    // paper §IV-D: prediction takes < 0.03 s
    suite.assert_mean_below("predict/USIN", Duration::from_millis(30));
    println!("\nPASS: USIN predict below the paper's 30 ms bound");
}
