//! §IV-D overhead: serving-time estimation (paper bound: < 0.001 s per
//! batch) at several logged-history sizes, plus refit cost.

use std::time::Duration;

use magnus::estimator::{BatchShape, ServingTimeEstimator};
use magnus::util::bench::BenchSuite;
use magnus::util::Rng;

fn shapes(n: usize, seed: u64) -> (Vec<BatchShape>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let shapes: Vec<BatchShape> = (0..n)
        .map(|_| BatchShape {
            batch_size: rng.range_u64(1, 33) as u32,
            batch_len: rng.range_u64(8, 1025) as u32,
            batch_gen_len: rng.range_u64(4, 1025) as u32,
        })
        .collect();
    let times = shapes
        .iter()
        .map(|s| s.batch_gen_len as f64 * (0.045 + 2.4e-6 * s.batch_size as f64 * s.batch_len as f64))
        .collect();
    (shapes, times)
}

fn main() {
    let mut suite = BenchSuite::new("KNN serving-time estimator (§IV-D)");
    suite.header();

    for n in [500usize, 2000, 8000] {
        let (xs, ys) = shapes(n, 1);
        let mut est = ServingTimeEstimator::new(5);
        est.train(&xs, &ys);
        let (probes, _) = shapes(256, 2);
        let mut i = 0;
        suite.bench_val(&format!("estimate/history={n}"), || {
            i = (i + 1) % probes.len();
            est.estimate(&probes[i])
        });
    }

    // continuous-learning refit (every 2 minutes per §III-D)
    let (xs, ys) = shapes(2000, 3);
    let (ex, ey) = shapes(100, 4);
    suite.bench("refit/2000+100", || {
        let mut est = ServingTimeEstimator::new(5);
        est.train(&xs, &ys);
        est.augment_and_refit(&ex, &ey);
    });

    // paper §IV-D: estimation takes < 0.001 s (per batch; the estimator
    // is called once per queued batch per idle instance)
    suite.assert_mean_below("estimate/history=2000", Duration::from_millis(1));
    println!("\nPASS: estimate below the paper's 1 ms bound at history=2000");
}
