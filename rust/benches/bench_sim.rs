//! End-to-end simulator speedup: the cached dispatch loop vs the
//! fresh-view (pre-refactor) reference, on the acceptance workload
//! (rate = 10 req/s, 600 requests, full Magnus policy).
//!
//! Both paths produce bit-for-bit identical `Summary` metrics (asserted
//! here and property-tested in tests/dispatch_equivalence.rs); this
//! harness measures what the equivalence buys and records it as
//! machine-readable `BENCH_sim.json` at the repo root, starting the perf
//! trajectory EXPERIMENTS.md §Perf tracks.

use std::time::Instant;

use magnus::config::ServingConfig;
use magnus::engine::cost::CostModelEngine;
use magnus::sim::{run_magnus_with, trained_predictor, DispatchMode, MagnusPolicy};
use magnus::util::bench::record_sim_bench;
use magnus::util::Json;
use magnus::workload::{generate_trace, TraceSpec};

const RATE: f64 = 10.0;
const N_REQUESTS: usize = 600;
const PREDICTOR_TRAIN: usize = 200;

fn main() {
    let quick = std::env::var("MAGNUS_BENCH_QUICK").is_ok();
    let samples = if quick { 2 } else { 5 };

    let cfg = ServingConfig::default();
    let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
    let trace = generate_trace(&TraceSpec {
        rate: RATE,
        n_requests: N_REQUESTS,
        seed: 99,
        ..Default::default()
    });

    println!(
        "== sim dispatch: cached vs fresh (rate {RATE}, n {N_REQUESTS}, {samples} samples) =="
    );
    let mut time_mode = |mode: DispatchMode| -> (f64, magnus::metrics::Summary) {
        let mut total = 0.0;
        let mut summary = None;
        for _ in 0..samples {
            let predictor = trained_predictor(&cfg, PREDICTOR_TRAIN);
            let t0 = Instant::now();
            let out = run_magnus_with(
                &cfg,
                &MagnusPolicy::magnus(),
                predictor,
                &engine,
                &trace,
                mode,
            );
            total += t0.elapsed().as_secs_f64();
            summary = Some(out.metrics.summarise());
        }
        (total / samples as f64, summary.unwrap())
    };

    let (fresh_s, fresh_sum) = time_mode(DispatchMode::Fresh);
    let (cached_s, cached_sum) = time_mode(DispatchMode::Cached);
    let (indexed_s, indexed_sum) = time_mode(DispatchMode::Indexed);

    // The speedup only counts if behaviour is untouched.
    assert_eq!(
        fresh_sum.request_throughput.to_bits(),
        cached_sum.request_throughput.to_bits(),
        "golden equivalence violated: fresh {} vs cached {}",
        fresh_sum.request_throughput,
        cached_sum.request_throughput
    );
    assert_eq!(
        fresh_sum.mean_response_time.to_bits(),
        cached_sum.mean_response_time.to_bits()
    );
    assert_eq!(
        fresh_sum.request_throughput.to_bits(),
        indexed_sum.request_throughput.to_bits(),
        "golden equivalence violated: fresh {} vs indexed {}",
        fresh_sum.request_throughput,
        indexed_sum.request_throughput
    );
    assert_eq!(
        fresh_sum.mean_response_time.to_bits(),
        indexed_sum.mean_response_time.to_bits()
    );

    let speedup = fresh_s / cached_s.max(1e-12);
    println!("  fresh   dispatch: {fresh_s:8.3} s / run");
    println!("  cached  dispatch: {cached_s:8.3} s / run");
    println!("  indexed dispatch: {indexed_s:8.3} s / run");
    println!("  speedup:          {speedup:8.2}x  (acceptance floor: 2.00x)");

    let path = format!("{}/../BENCH_sim.json", env!("CARGO_MANIFEST_DIR"));
    record_sim_bench(
        &path,
        RATE,
        N_REQUESTS,
        samples,
        fresh_s,
        cached_s,
        vec![
            ("policy", Json::str("Magnus")),
            ("indexed_s", Json::num(indexed_s)),
            ("predictor_train", Json::num(PREDICTOR_TRAIN as f64)),
            ("source", Json::str("benches/bench_sim.rs")),
            (
                "request_throughput",
                Json::num(cached_sum.request_throughput),
            ),
            ("mean_response_time", Json::num(cached_sum.mean_response_time)),
        ],
    )
    .expect("write BENCH_sim.json");
    println!("wrote {path}");

    // No wall-clock assertion: shared runners are noisy and a spurious
    // red would gate merges on scheduler jitter.  The hard gate is the
    // bitwise equivalence asserted above; the speedup is reported and
    // recorded for the perf trajectory.
    println!("\nPASS: modes bit-for-bit equivalent; speedup {speedup:.2}x recorded");
}
