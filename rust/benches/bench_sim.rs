//! End-to-end simulator speedups, two sections:
//!
//! 1. **Dispatch** — the cached/indexed dispatch loops vs the fresh-view
//!    (pre-refactor) reference on the acceptance workload (rate =
//!    10 req/s, 600 requests, full Magnus) → `BENCH_sim.json`.
//! 2. **Scale (zero-copy request plumbing)** — the interned `TraceStore`
//!    path (streaming generation + compact `RequestMeta` pipeline) vs
//!    the owned-`Request` reference (`sim::reference`: clone per
//!    arrival, clone per log entry, member rescans) at N ∈ {10⁴, 10⁵,
//!    10⁶} requests → `BENCH_scale.json`, with wall time AND peak heap
//!    bytes from the counting global allocator.  The reference is the
//!    owned representation in its pre-overhaul algorithmic shape, so the
//!    wall-time ratio is the whole PR 1–4 trajectory gap (see
//!    `sim::reference` docs); the peak-byte column and the 10⁶ row —
//!    which the owned shape cannot reach — are the zero-copy-specific
//!    evidence.  The owned reference is capped at 10⁵.
//!
//! 3. **Trace I/O (binary format + mmap arena)** — loading the same
//!    replayed trace via the JSON route (read + parse + re-intern: the
//!    whole text arena is materialised before the first request can
//!    dispatch) vs `TraceStore::open_mmap` (O(1)-lazy binary decode, the
//!    kernel pages text on demand) vs the read-into-memory fallback, at
//!    N ∈ {10⁴, 10⁵, 10⁶} → `BENCH_trace.json`, wall time + peak heap.
//!
//! 4. **Big sharded trace (zero-parse at scale, ISSUE 10)** — generate a
//!    10⁷-request (10⁸ under `MAGNUS_TRACE_FULL=1`) 8-shard trace
//!    streaming, reopen it through the manifest, and sweep the exact
//!    fields the event loop reads — recording open latency, replay
//!    time and peak heap next to what an eager meta table would hold
//!    resident, appended to `BENCH_trace.json`.
//!
//! Section 1 asserts bit-for-bit behavioural equivalence before timing
//! anything; section 2 asserts it for every row the owned reference
//! runs at (N ≤ 10⁵ — rows above the cap are completion-checked only;
//! representation equivalence at those sizes rests on the golden suite
//! in tests/store_equivalence.rs and tests/dispatch_equivalence.rs);
//! section 3 asserts every loaded store is bit-identical (metas, arena,
//! instruction table) to the generated one before its numbers count
//! (run-level equivalence of the loaded stores is tests/trace_io.rs's
//! job).  `MAGNUS_BENCH_QUICK` or `MAGNUS_SCALE_SMOKE` limit both
//! sweeps to N = 10⁴ (CI smoke).

use std::time::Instant;

use magnus::config::ServingConfig;
use magnus::engine::cost::CostModelEngine;
use magnus::predictor::{GenLenPredictor, Variant};
use magnus::sim::{
    run_magnus_owned, run_magnus_store, run_magnus_with, trained_predictor, DispatchMode,
    MagnusPolicy,
};
use magnus::util::alloc::{peak_bytes, reset_peak, CountingAllocator};
use magnus::util::bench::{
    record_scale_bench, record_sim_bench, record_trace_bench, BigTracePoint, ScalePoint,
    TracePoint,
};
use magnus::util::Json;
use magnus::workload::{
    generate_trace, open_manifest, write_sharded, RequestMeta, TraceSource, TraceSpec,
    TraceStore,
};

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

const RATE: f64 = 10.0;
const N_REQUESTS: usize = 600;
const PREDICTOR_TRAIN: usize = 200;

/// Scale-sweep arrival rate: comfortably below the 7-instance capacity,
/// so queues stay bounded and the sweep measures per-request plumbing
/// rather than overload dynamics (the overload regime is section 1's and
/// bench_scheduler's job).
const SCALE_RATE: f64 = 4.0;
/// Largest N the owned reference runs at (see module docs).
const OWNED_CAP: usize = 100_000;

fn main() {
    let quick = std::env::var("MAGNUS_BENCH_QUICK").is_ok();
    let samples = if quick { 2 } else { 5 };

    let cfg = ServingConfig::default();
    let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
    let trace = generate_trace(&TraceSpec {
        rate: RATE,
        n_requests: N_REQUESTS,
        seed: 99,
        ..Default::default()
    });

    println!(
        "== sim dispatch: cached vs fresh (rate {RATE}, n {N_REQUESTS}, {samples} samples) =="
    );
    let mut time_mode = |mode: DispatchMode| -> (f64, magnus::metrics::Summary) {
        let mut total = 0.0;
        let mut summary = None;
        for _ in 0..samples {
            let predictor = trained_predictor(&cfg, PREDICTOR_TRAIN);
            let t0 = Instant::now();
            let out = run_magnus_with(
                &cfg,
                &MagnusPolicy::magnus(),
                predictor,
                &engine,
                &trace,
                mode,
            );
            total += t0.elapsed().as_secs_f64();
            summary = Some(out.metrics.summarise());
        }
        (total / samples as f64, summary.unwrap())
    };

    let (fresh_s, fresh_sum) = time_mode(DispatchMode::Fresh);
    let (cached_s, cached_sum) = time_mode(DispatchMode::Cached);
    let (indexed_s, indexed_sum) = time_mode(DispatchMode::Indexed);

    // The speedup only counts if behaviour is untouched.
    assert_eq!(
        fresh_sum.request_throughput.to_bits(),
        cached_sum.request_throughput.to_bits(),
        "golden equivalence violated: fresh {} vs cached {}",
        fresh_sum.request_throughput,
        cached_sum.request_throughput
    );
    assert_eq!(
        fresh_sum.mean_response_time.to_bits(),
        cached_sum.mean_response_time.to_bits()
    );
    assert_eq!(
        fresh_sum.request_throughput.to_bits(),
        indexed_sum.request_throughput.to_bits(),
        "golden equivalence violated: fresh {} vs indexed {}",
        fresh_sum.request_throughput,
        indexed_sum.request_throughput
    );
    assert_eq!(
        fresh_sum.mean_response_time.to_bits(),
        indexed_sum.mean_response_time.to_bits()
    );

    let speedup = fresh_s / cached_s.max(1e-12);
    println!("  fresh   dispatch: {fresh_s:8.3} s / run");
    println!("  cached  dispatch: {cached_s:8.3} s / run");
    println!("  indexed dispatch: {indexed_s:8.3} s / run");
    println!("  speedup:          {speedup:8.2}x  (acceptance floor: 2.00x)");

    let path = format!("{}/../BENCH_sim.json", env!("CARGO_MANIFEST_DIR"));
    record_sim_bench(
        &path,
        RATE,
        N_REQUESTS,
        samples,
        fresh_s,
        cached_s,
        vec![
            ("policy", Json::str("Magnus")),
            ("indexed_s", Json::num(indexed_s)),
            ("predictor_train", Json::num(PREDICTOR_TRAIN as f64)),
            ("source", Json::str("benches/bench_sim.rs")),
            (
                "request_throughput",
                Json::num(cached_sum.request_throughput),
            ),
            ("mean_response_time", Json::num(cached_sum.mean_response_time)),
        ],
    )
    .expect("write BENCH_sim.json");
    println!("wrote {path}");

    // ── section 2: zero-copy scale sweep ──────────────────────────────
    let smoke = quick || std::env::var("MAGNUS_SCALE_SMOKE").is_ok();
    let ns: &[usize] = if smoke {
        &[10_000]
    } else {
        &[10_000, 100_000, 1_000_000]
    };
    println!(
        "\n== scale: TraceStore (zero-copy) vs owned-Request reference \
         (rate {SCALE_RATE}, N {ns:?}) =="
    );

    // Isolate the plumbing: UILO predictor (prediction cost ~0, so the
    // per-request clone/alloc tax is what the clock sees) and learning
    // sweeps disabled (periodic full refits would otherwise dominate a
    // 10⁶-request run for BOTH paths identically; equivalence with
    // learning ON is covered by tests/store_equivalence.rs).  The policy
    // is still full Magnus: WMA batching, estimator estimates, HRRN.
    let mut scfg = ServingConfig::default();
    scfg.learning.predictor_period_s = f64::INFINITY;
    scfg.learning.estimator_period_s = f64::INFINITY;
    let sengine = CostModelEngine::new(scfg.cost.clone(), &scfg.gpu);

    let mut points: Vec<ScalePoint> = Vec::new();
    for &n in ns {
        let spec = TraceSpec {
            rate: SCALE_RATE,
            n_requests: n,
            seed: 7,
            ..Default::default()
        };

        // Zero-copy path: stream the trace into the arena, run compact.
        reset_peak();
        let base = peak_bytes();
        let t0 = Instant::now();
        let store = TraceStore::generate(&spec);
        let store_out = run_magnus_store(
            &scfg,
            &MagnusPolicy::magnus(),
            GenLenPredictor::new(Variant::Uilo, &scfg),
            &sengine,
            &store,
        );
        let store_s = t0.elapsed().as_secs_f64();
        let store_peak = peak_bytes() - base;
        let arena = store.arena_bytes();
        assert_eq!(store_out.metrics.records.len(), n, "scale run must complete");
        // Keep only what the equivalence check needs, then free the
        // store-phase state so the owned phase runs on a symmetric heap
        // (and the process high-water mark is one run, not the sum).
        let store_records: Vec<(u64, u64)> = store_out
            .metrics
            .records
            .iter()
            .map(|r| (r.request_id, r.finish.to_bits()))
            .collect();
        drop(store_out);
        drop(store);

        // Owned reference, up to the cap.
        let (owned_s, owned_peak) = if n <= OWNED_CAP {
            reset_peak();
            let base = peak_bytes();
            let t0 = Instant::now();
            let owned_trace = generate_trace(&spec);
            let owned_out = run_magnus_owned(
                &scfg,
                &MagnusPolicy::magnus(),
                GenLenPredictor::new(Variant::Uilo, &scfg),
                &sengine,
                &owned_trace,
            );
            let owned_s = t0.elapsed().as_secs_f64();
            let owned_peak = peak_bytes() - base;
            // Equivalence before the numbers count.
            assert_eq!(owned_out.metrics.records.len(), n);
            for (x, &(id, finish_bits)) in
                owned_out.metrics.records.iter().zip(&store_records)
            {
                assert_eq!(x.request_id, id, "owned vs store diverged");
                assert_eq!(x.finish.to_bits(), finish_bits, "owned vs store diverged");
            }
            (Some(owned_s), Some(owned_peak))
        } else {
            (None, None)
        };

        let fmt_mb = |b: usize| b as f64 / 1e6;
        match (owned_s, owned_peak) {
            (Some(os), Some(op)) => println!(
                "  n={n:>9}: store {store_s:8.3} s / {:8.1} MB peak (arena {:6.1} MB) | \
                 owned {os:8.3} s / {:8.1} MB peak → {:.2}x time, {:.2}x peak",
                fmt_mb(store_peak),
                fmt_mb(arena),
                fmt_mb(op),
                os / store_s.max(1e-12),
                op as f64 / store_peak.max(1) as f64,
            ),
            _ => println!(
                "  n={n:>9}: store {store_s:8.3} s / {:8.1} MB peak (arena {:6.1} MB) | \
                 owned — (above reference cap)",
                fmt_mb(store_peak),
                fmt_mb(arena),
            ),
        }
        points.push(ScalePoint {
            n,
            store_s,
            store_peak_bytes: store_peak,
            arena_bytes: arena,
            owned_s,
            owned_peak_bytes: owned_peak,
        });
    }

    let scale_path = format!("{}/../BENCH_scale.json", env!("CARGO_MANIFEST_DIR"));
    record_scale_bench(
        &scale_path,
        SCALE_RATE,
        &points,
        vec![
            ("policy", Json::str("Magnus")),
            ("predictor", Json::str("UILO")),
            ("learning", Json::str("disabled")),
            (
                "baseline",
                Json::str("owned Requests, pre-overhaul shape (naive WMA rescans + fresh select)"),
            ),
            ("owned_cap", Json::num(OWNED_CAP as f64)),
            ("smoke", Json::Bool(smoke)),
            ("source", Json::str("benches/bench_sim.rs")),
        ],
    )
    .expect("write BENCH_scale.json");
    println!("wrote {scale_path}");

    // ── section 3: trace I/O — JSON parse vs binary open ──────────────
    println!("\n== trace I/O: JSON parse vs binary mmap open (N {ns:?}) ==");
    let tmp = |n: usize, ext: &str| {
        std::env::temp_dir().join(format!(
            "magnus_bench_trace_{}_{n}.{ext}",
            std::process::id()
        ))
    };
    let mut tpoints: Vec<TracePoint> = Vec::new();
    for &n in ns {
        let spec = TraceSpec {
            rate: SCALE_RATE,
            n_requests: n,
            seed: 7,
            ..Default::default()
        };
        let store = TraceStore::generate(&spec);
        let bin_path = tmp(n, "mtr");
        let json_path = tmp(n, "json");
        store.write_file(&bin_path).expect("write binary trace");
        std::fs::write(&json_path, store.to_json().to_string()).expect("write JSON trace");
        let file_bytes = std::fs::metadata(&bin_path).unwrap().len() as usize;

        // JSON route: read + parse + re-intern — the pre-PR-5 load path.
        reset_peak();
        let base = peak_bytes();
        let t0 = Instant::now();
        let text = std::fs::read_to_string(&json_path).unwrap();
        let j = Json::parse(&text).unwrap();
        let json_store = TraceStore::from_json(&j).unwrap();
        let json_parse_s = t0.elapsed().as_secs_f64();
        let json_peak = peak_bytes() - base;
        drop(j);
        drop(text);

        // Binary route, mapped: O(metas) decode, arena paged on demand.
        reset_peak();
        let base = peak_bytes();
        let t0 = Instant::now();
        let mstore = TraceStore::open_mmap(&bin_path).unwrap();
        let mmap_open_s = t0.elapsed().as_secs_f64();
        let mmap_peak = peak_bytes() - base;

        // Binary route, read fallback: same decode over owned bytes.
        reset_peak();
        let base = peak_bytes();
        let t0 = Instant::now();
        let rstore = TraceStore::open_read(&bin_path).unwrap();
        let read_open_s = t0.elapsed().as_secs_f64();
        let read_peak = peak_bytes() - base;

        // Every loaded store must be bit-identical before numbers count.
        for (loaded, route) in [(&json_store, "json"), (&mstore, "mmap"), (&rstore, "read")]
        {
            assert_eq!(loaded.metas(), store.metas(), "{route} metas diverged");
            assert_eq!(
                loaded.arena_str(),
                store.arena_str(),
                "{route} arena diverged"
            );
            assert_eq!(
                loaded.instruction_table(),
                store.instruction_table(),
                "{route} instruction table diverged"
            );
        }

        let fmt_mb = |b: usize| b as f64 / 1e6;
        println!(
            "  n={n:>9}: json {json_parse_s:8.3} s / {:8.1} MB peak | mmap open \
             {mmap_open_s:8.4} s / {:6.1} MB peak{} | read open {read_open_s:8.4} s / \
             {:6.1} MB peak → {:.1}x faster open, {:.1}x lower peak",
            fmt_mb(json_peak),
            fmt_mb(mmap_peak),
            if mstore.is_mmap_backed() { "" } else { " (fallback!)" },
            fmt_mb(read_peak),
            json_parse_s / mmap_open_s.max(1e-12),
            json_peak as f64 / mmap_peak.max(1) as f64,
        );
        tpoints.push(TracePoint {
            n,
            file_bytes,
            arena_bytes: store.arena_bytes(),
            json_parse_s,
            json_peak_bytes: json_peak,
            mmap_open_s,
            mmap_open_peak_bytes: mmap_peak,
            read_open_s,
            read_open_peak_bytes: read_peak,
            mmap_backed: mstore.is_mmap_backed(),
        });
        let _ = std::fs::remove_file(&bin_path);
        let _ = std::fs::remove_file(&json_path);
    }
    // ── section 4: big sharded trace — zero-parse open + replay ───────
    let big_n: usize = if smoke {
        20_000
    } else if std::env::var("MAGNUS_TRACE_FULL").is_ok() {
        100_000_000
    } else {
        10_000_000
    };
    let shards = 8;
    println!(
        "\n== big trace: sharded zero-parse open + replay (n {big_n}, {shards} shards) =="
    );
    let big_dir = std::env::temp_dir().join(format!(
        "magnus_bench_bigtrace_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&big_dir);
    let big_spec = TraceSpec {
        rate: SCALE_RATE,
        n_requests: big_n,
        seed: 7,
        ..Default::default()
    };
    // Streaming generation: one shard resident at a time, so the write
    // side never holds the whole trace either.
    let t0 = Instant::now();
    let manifest = write_sharded(&big_spec, shards, &big_dir).expect("write sharded trace");
    let gen_write_s = t0.elapsed().as_secs_f64();
    let file_bytes: usize = (0..shards)
        .map(|k| {
            std::fs::metadata(big_dir.join(format!("shard-{k:04}.mtr")))
                .map(|m| m.len() as usize)
                .unwrap_or(0)
        })
        .sum();

    // Open: O(shards) manifest verification over O(1)-lazy decodes — the
    // peak-heap number is the tentpole's evidence that no per-meta state
    // materialises at open.
    reset_peak();
    let base = peak_bytes();
    let t0 = Instant::now();
    let sharded = open_manifest(&manifest).expect("open sharded trace");
    let open_s = t0.elapsed().as_secs_f64();
    let open_peak = peak_bytes() - base;
    assert_eq!(sharded.len(), big_n, "sharded open must cover every request");

    // Replay sweep: exactly the fields the event loop reads — arrival to
    // seed, then the meta record at dispatch — folded into a checksum so
    // the reads cannot be optimised away.
    reset_peak();
    let base = peak_bytes();
    let t0 = Instant::now();
    let mut fold = 0xcbf29ce484222325u64;
    for i in 0..sharded.len() {
        fold ^= sharded.arrival(i).to_bits() ^ u64::from(sharded.meta(i).gen_len);
        fold = fold.wrapping_mul(0x100000001b3);
    }
    let replay_s = t0.elapsed().as_secs_f64();
    let replay_peak = peak_bytes() - base;

    let eager_meta_bytes = big_n * std::mem::size_of::<RequestMeta>();
    println!(
        "  gen+write {gen_write_s:8.2} s ({:.1} MB on disk) | open {open_s:8.4} s / \
         {:.2} MB peak | replay {replay_s:8.2} s / {:.2} MB peak | eager meta table \
         would hold {:.1} MB (sweep checksum {fold:016x})",
        file_bytes as f64 / 1e6,
        open_peak as f64 / 1e6,
        replay_peak as f64 / 1e6,
        eager_meta_bytes as f64 / 1e6,
    );
    let big = BigTracePoint {
        n: big_n,
        shards,
        file_bytes,
        gen_write_s,
        open_s,
        open_peak_bytes: open_peak,
        replay_s,
        replay_peak_bytes: replay_peak,
        eager_meta_bytes,
    };
    drop(sharded);
    let _ = std::fs::remove_dir_all(&big_dir);

    let trace_path = format!("{}/../BENCH_trace.json", env!("CARGO_MANIFEST_DIR"));
    record_trace_bench(
        &trace_path,
        &tpoints,
        Some(&big),
        vec![
            ("smoke", Json::Bool(smoke)),
            ("source", Json::str("benches/bench_sim.rs")),
        ],
    )
    .expect("write BENCH_trace.json");
    println!("wrote {trace_path}");

    // No wall-clock assertion: shared runners are noisy and a spurious
    // red would gate merges on scheduler jitter.  The hard gates are the
    // bitwise equivalences asserted above; speedups and peak bytes are
    // reported and recorded for the perf trajectory.
    println!(
        "\nPASS: dispatch modes bit-for-bit equivalent; store ≡ owned \
         asserted up to N = {OWNED_CAP} (larger rows completion-checked; \
         equivalence there rests on the golden suite); loaded stores \
         (json/mmap/read) bit-identical at every N; dispatch speedup \
         {speedup:.2}x recorded"
    );
}
