//! Minimal, offline, API-compatible stand-in for the `anyhow` crate.
//!
//! The build environment vendors only the `xla` crate's dependency
//! closure, so the real `anyhow` is not available. This shim implements
//! exactly the surface the Magnus crate uses — [`Error`], [`Result`],
//! [`Context`], `Error::msg`, and the `anyhow!` / `bail!` / `ensure!`
//! macros — over a plain message string with the source chain flattened
//! at conversion time.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`; that is what makes the blanket
//! `From<E: std::error::Error>` impl coherent and lets `?` convert any
//! standard error into an [`Error`].

use std::fmt;

/// A flattened error: message plus any context prefixes.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }

    /// Prefix additional context (mirrors `anyhow::Error::context`).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{e}` and `{e:#}` both render the flattened chain.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Flatten the source chain eagerly: "outer: mid: inner".
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// Drop-in for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Drop-in for `anyhow::Context`: attach context to results and options.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error {
            msg: format!("{context}: {e}"),
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error {
            msg: format!("{}: {e}", f()),
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string (mirrors `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Early-return an [`Error`] (mirrors `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Assert-or-return (mirrors `anyhow::ensure!`).
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros_and_context_compose() {
        let base: Result<()> = Err(anyhow!("inner {}", 7));
        let wrapped = base.context("outer");
        assert_eq!(wrapped.unwrap_err().to_string(), "outer: inner 7");

        fn guarded(x: u32) -> Result<u32> {
            ensure!(x > 2, "x too small: {x}");
            if x > 100 {
                bail!("x too big");
            }
            Ok(x)
        }
        assert_eq!(guarded(1).unwrap_err().to_string(), "x too small: 1");
        assert_eq!(guarded(200).unwrap_err().to_string(), "x too big");
        assert_eq!(guarded(10).unwrap(), 10);
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.with_context(|| "missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
    }
}
