//! Supervised live serving cluster: the Fig. 7 workflow over real
//! threads, channels and a wall clock.
//!
//! A leader thread owns the coordinator state (predictor → WMA batcher →
//! estimator → scheduler, §III-A) and replays a trace in (scaled) wall
//! time; N worker threads each own an engine built by a [`WorkerFactory`]
//! (one "LLM instance" per §III-F worker process) and serve dispatched
//! batches, reporting completions back over channels.  Two factories are
//! provided: the PJRT backend executes real compute from compiled
//! artifacts, and the cost-model backend drives the same machinery from
//! the analytic engine, which is what the chaos suite exercises.
//!
//! The leader is a *supervisor*, not a bail-on-first-error coordinator:
//! a worker that dies is restarted with capped exponential backoff (up
//! to the fault plan's budget), its in-flight batch is re-queued from
//! the leader-side copy with bounded retries, and a batch that exhausts
//! its retries is recorded as shed — never silently lost.  The headline
//! invariant, asserted at shutdown and by the chaos tests, is that every
//! admitted request completes exactly once or is explicitly shed.
//!
//! Two ingress modes share the same core loop ([`serve_core`]):
//! * **Replay** ([`serve_supervised`]) — arrivals come from the trace
//!   store by replayed time, exactly the pre-edge behaviour;
//! * **Live** ([`serve_ingress_supervised`]) — arrivals come as
//!   [`EdgeJob`]s over a channel from the HTTP admission layer
//!   ([`crate::edge`]), with per-request completion/shed notifications
//!   flowing back as [`CoreSignal`]s so the edge can answer its
//!   still-connected clients.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::batch::{AdaptiveBatcher, Batch, BatcherConfig};
use crate::config::ServingConfig;
use crate::engine::cost::CostModelEngine;
use crate::engine::faulty::{FaultyEngine, InjectedOutcome};
use crate::engine::BatchOutcome;
use crate::estimator::{BatchShape, ServingTimeEstimator};
use crate::faults::FaultPlan;
use crate::logdb::{BatchLog, LogDb, RequestLog};
use crate::metrics::{RequestRecord, RunMetrics};
use crate::predictor::{
    fallback_prediction, predict_degraded, DriftDetector, DriftEvent, GenLenPredictor,
};
use crate::sim::MagnusPolicy;
use crate::util::clamped_duration;
use crate::workload::{PredictedRequest, RequestMeta, TraceStore};

#[cfg(feature = "pjrt")]
use crate::engine::pjrt::PjrtBatchServer;
#[cfg(feature = "pjrt")]
use crate::workload::Request;

/// What a worker receives per dispatch: the batch, the serving-time
/// estimate captured at dispatch (rides the round-trip so the leader
/// keeps no batch-id → estimate map) and the replayed-time dispatch
/// stamp (fault plans locate their windows in trace time).
type Dispatch = (Batch, f64, f64);

/// One admitted live request handed to the core by the edge.  The
/// prediction already happened at admission (the edge owns the
/// predictor — admission *is* the prediction's first consumer), so the
/// core only batches and serves.
#[derive(Debug, Clone, Copy)]
pub struct EdgeJob {
    pub meta: RequestMeta,
    pub predicted_gen_len: u32,
}

/// Per-request outcome notification the core sends back to the edge in
/// live-ingress mode (the edge resolves its waiting HTTP handlers and
/// closes its accounting with these).
#[derive(Debug, Clone, Copy)]
pub enum CoreSignal {
    Completed {
        request_id: u64,
        valid_tokens: u32,
        invalid_tokens: u32,
    },
    /// The core gave up on the request (retry budget exhausted, or all
    /// workers retired) — never silently lost.
    Shed { request_id: u64 },
}

/// Where the core's requests come from.
enum Ingress {
    /// Arrivals replayed from the trace store by (scaled) wall time.
    Replay,
    /// Arrivals pushed by the edge; the channel closing means "no more
    /// traffic, finish what you have and return".
    Live { jobs: mpsc::Receiver<EdgeJob> },
}

/// Metrics plus the optional live-mode signal channel: every completion
/// and every shed flows through here, so the edge hears about each
/// outcome exactly once no matter which code path produced it.
struct Ledger {
    metrics: RunMetrics,
    signals: Option<mpsc::Sender<CoreSignal>>,
}

impl Ledger {
    fn done(&mut self, rec: RequestRecord) {
        if let Some(tx) = &self.signals {
            let _ = tx.send(CoreSignal::Completed {
                request_id: rec.request_id,
                valid_tokens: rec.valid_tokens,
                invalid_tokens: rec.invalid_tokens,
            });
        }
        self.metrics.record(rec);
    }

    fn shed(&mut self, request_id: u64) {
        if let Some(tx) = &self.signals {
            let _ = tx.send(CoreSignal::Shed { request_id });
        }
        self.metrics.record_shed(request_id);
    }
}

/// Live-serving policy.
pub enum LivePolicy {
    /// The full pipeline (or a GLP/ABP ablation via `MagnusPolicy`).
    Magnus(MagnusPolicy),
    /// Vanilla scheduling with a fixed batch size.
    Vanilla { fixed_batch: u32 },
}

/// Options for a live run.
pub struct ServeOptions {
    pub artifacts_dir: String,
    pub n_workers: usize,
    /// Trace arrival times are divided by this (replay speed-up).
    pub time_scale: f64,
    /// Compile all buckets before accepting traffic.
    pub warm_up: bool,
    /// Deterministic fault schedule (noop by default).
    pub fault_plan: FaultPlan,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            artifacts_dir: "artifacts".to_string(),
            n_workers: 2,
            time_scale: 10.0,
            warm_up: false,
            fault_plan: FaultPlan::none(),
        }
    }
}

/// Leader-side capacity probe: what the planner may assume about every
/// worker without constructing an engine on the leader thread.
#[derive(Debug, Clone, Copy)]
pub struct WorkerProbe {
    pub max_batch: usize,
    /// Θ — KV-cache byte budget the batcher plans against.
    pub theta: u64,
    /// δ — KV bytes per token.
    pub delta: u64,
}

/// Worker-side serve failure classification.
#[derive(Debug)]
pub enum ServeError {
    /// The engine survives; the worker stays up and the batch can be
    /// re-dispatched immediately.
    Transient(String),
    /// Engine state is unknown or gone; the worker must be rebuilt.
    Fatal(String),
}

impl ServeError {
    fn message(self) -> String {
        match self {
            ServeError::Transient(m) | ServeError::Fatal(m) => m,
        }
    }
}

/// One worker's compute substrate, owned by its thread.
pub trait WorkerEngine {
    /// Serve a dispatched batch.  `dispatched_at` is the replayed-time
    /// dispatch stamp (trace seconds).
    fn serve_batch(
        &mut self,
        batch: &Batch,
        store: &TraceStore,
        dispatched_at: f64,
    ) -> std::result::Result<BatchOutcome, ServeError>;

    /// Optional pre-traffic warm-up (e.g. compile all buckets).
    fn prewarm(&mut self) -> std::result::Result<(), ServeError> {
        Ok(())
    }
}

/// Builds worker engines on their own threads (PJRT clients are
/// `!Send`) and answers the leader's capacity probe.
pub trait WorkerFactory: Send + Sync + 'static {
    type Engine: WorkerEngine;

    /// Leader-side capacity probe (no engine construction).
    fn probe(&self) -> Result<WorkerProbe>;

    /// Build one worker engine; called on the worker's own thread, and
    /// again on every supervised restart of that slot.
    fn build(&self, worker: usize) -> std::result::Result<Self::Engine, ServeError>;
}

/// Cost-model worker factory: real threads, channels and wall clock, but
/// the analytic engine computes outcomes (scaled down into wall seconds
/// by `time_scale`).  Exercises the full supervision machinery without
/// PJRT artifacts — the substrate the chaos suite drives.
pub struct CostWorkerFactory {
    engine: CostModelEngine,
    probe: WorkerProbe,
    time_scale: f64,
    plan: FaultPlan,
    /// Worker incarnations built so far.  Each incarnation gets its own
    /// fault-salt namespace so a re-dispatched batch redraws its
    /// crash/error decisions instead of deterministically dying on every
    /// worker that picks it up.
    serial: AtomicU64,
}

impl CostWorkerFactory {
    pub fn from_config(cfg: &ServingConfig, time_scale: f64, plan: FaultPlan) -> Self {
        CostWorkerFactory {
            engine: CostModelEngine::new(cfg.cost.clone(), &cfg.gpu),
            probe: WorkerProbe {
                max_batch: usize::MAX,
                theta: (cfg.gpu.theta() as f64 * cfg.mem_margin) as u64,
                delta: cfg.gpu.delta_bytes_per_token,
            },
            time_scale: time_scale.max(1e-9),
            plan,
            serial: AtomicU64::new(0),
        }
    }
}

impl WorkerFactory for CostWorkerFactory {
    type Engine = CostWorker;

    fn probe(&self) -> Result<WorkerProbe> {
        Ok(self.probe)
    }

    fn build(&self, _worker: usize) -> std::result::Result<CostWorker, ServeError> {
        Ok(CostWorker {
            engine: self.engine.clone(),
            plan: self.plan.clone(),
            time_scale: self.time_scale,
            salt_base: self.serial.fetch_add(1, Ordering::Relaxed) << 20,
            serves: 0,
        })
    }
}

/// Cap on how long a cost-model worker actually sleeps per batch, so
/// chaos tests stay fast even when a stall multiplier inflates the
/// modelled time.
const COST_SLEEP_CAP_S: f64 = 0.25;

/// One cost-model worker incarnation.
pub struct CostWorker {
    engine: CostModelEngine,
    plan: FaultPlan,
    time_scale: f64,
    salt_base: u64,
    serves: u64,
}

impl WorkerEngine for CostWorker {
    fn serve_batch(
        &mut self,
        batch: &Batch,
        _store: &TraceStore,
        dispatched_at: f64,
    ) -> std::result::Result<BatchOutcome, ServeError> {
        self.serves += 1;
        let salt = self.salt_base | (self.serves & 0xF_FFFF);
        let faulty = FaultyEngine::new(&self.engine, &self.plan);
        match faulty.serve_batch_at(dispatched_at, batch, salt) {
            InjectedOutcome::Crash { .. } => Err(ServeError::Fatal(format!(
                "injected crash (serve #{} of this incarnation)",
                self.serves
            ))),
            InjectedOutcome::TransientError { .. } => Err(ServeError::Transient(format!(
                "injected transient serve error (serve #{})",
                self.serves
            ))),
            InjectedOutcome::Outcome { outcome, .. } => {
                let model_s = match &outcome {
                    BatchOutcome::Completed { serving_time, .. } => *serving_time,
                    BatchOutcome::Oom { wasted_time, .. } => *wasted_time,
                };
                let busy = (model_s / self.time_scale).clamp(0.0, COST_SLEEP_CAP_S);
                if busy > 0.0 {
                    std::thread::sleep(Duration::from_secs_f64(busy));
                }
                Ok(scale_to_wall(outcome, self.time_scale))
            }
        }
    }
}

/// Map a model-time outcome into wall seconds so the leader's uniform
/// `serving_time * time_scale` logging round-trips back to model time.
fn scale_to_wall(outcome: BatchOutcome, time_scale: f64) -> BatchOutcome {
    match outcome {
        BatchOutcome::Completed {
            serving_time,
            per_request,
        } => BatchOutcome::Completed {
            serving_time: serving_time / time_scale,
            per_request,
        },
        BatchOutcome::Oom {
            at_iteration,
            wasted_time,
        } => BatchOutcome::Oom {
            at_iteration,
            wasted_time: wasted_time / time_scale,
        },
    }
}

/// PJRT worker factory: each worker loads the compiled artifacts and
/// serves real compute.
#[cfg(feature = "pjrt")]
pub struct PjrtWorkerFactory {
    pub artifacts_dir: String,
}

#[cfg(feature = "pjrt")]
impl WorkerFactory for PjrtWorkerFactory {
    type Engine = PjrtBatchServer;

    /// Lightweight manifest probe (no PJRT client on the leader).
    /// Artifacts bound the real memory: Θ is the max bucket's KV bytes,
    /// so the planner can never exceed a compiled shape.
    fn probe(&self) -> Result<WorkerProbe> {
        let m = crate::runtime::Manifest::load(&self.artifacts_dir)?;
        let max_batch = m.max_batch();
        Ok(WorkerProbe {
            max_batch,
            theta: (max_batch as u64) * (m.model.l_max as u64) * m.model.kv_bytes_per_token,
            delta: m.model.kv_bytes_per_token,
        })
    }

    fn build(&self, worker: usize) -> std::result::Result<PjrtBatchServer, ServeError> {
        PjrtBatchServer::load(&self.artifacts_dir)
            .map_err(|e| ServeError::Fatal(format!("worker {worker} load: {e:#}")))
    }
}

#[cfg(feature = "pjrt")]
impl WorkerEngine for PjrtBatchServer {
    fn serve_batch(
        &mut self,
        batch: &Batch,
        store: &TraceStore,
        _dispatched_at: f64,
    ) -> std::result::Result<BatchOutcome, ServeError> {
        match PjrtBatchServer::serve(self, batch, store) {
            Ok(out) => Ok(out.outcome),
            // A PJRT error leaves client state unknown: rebuild the worker.
            Err(e) => Err(ServeError::Fatal(format!("{e:#}"))),
        }
    }

    fn prewarm(&mut self) -> std::result::Result<(), ServeError> {
        self.warm_up().map_err(|e| ServeError::Fatal(format!("{e:#}")))
    }
}

enum WorkerMsg {
    Done {
        worker: usize,
        batch: Batch,
        /// Serving-time estimate captured at dispatch; riding the
        /// round-trip kills the leader-side batch-id → estimate map (as
        /// the simulator's in-flight events do).
        est: f64,
        outcome: BatchOutcome,
    },
    Failed {
        worker: usize,
        error: String,
        /// True when the worker thread exited (engine state unknown);
        /// false for a transient serve error the worker survived.
        fatal: bool,
    },
    Ready {
        worker: usize,
    },
}

/// Supervisor's view of one worker slot's lifecycle.
enum SlotState {
    /// Thread spawned, engine still building / warming.
    Starting,
    /// Ready and serving.
    Up,
    /// Crashed; eligible for respawn once the backoff deadline passes.
    Down(Instant),
    /// Restart budget exhausted — never respawned again.
    Retired,
}

struct WorkerSlot {
    tx: Option<mpsc::Sender<Dispatch>>,
    state: SlotState,
    /// Restarts consumed (for the backoff exponent and the budget).
    restarts: u32,
    /// Leader-side copy of the dispatched batch: crash recovery re-queues
    /// from here, so a dead worker can never take requests with it.
    in_flight: Option<(Batch, f64)>,
}

/// Spawn one worker incarnation for `slot` and return its dispatch
/// channel.  The thread builds its engine via the factory (on-thread —
/// PJRT clients are `!Send`), reports `Ready`, then serves until its
/// dispatch channel closes or a fatal error kills it.
fn spawn_worker<F: WorkerFactory>(
    factory: &Arc<F>,
    worker: usize,
    warm: bool,
    done: &mpsc::Sender<WorkerMsg>,
    store: &Arc<TraceStore>,
    handles: &mut Vec<std::thread::JoinHandle<()>>,
) -> mpsc::Sender<Dispatch> {
    let (tx, rx) = mpsc::channel::<Dispatch>();
    let done = done.clone();
    let factory = Arc::clone(factory);
    let store = Arc::clone(store);
    handles.push(std::thread::spawn(move || {
        let mut engine = match factory.build(worker) {
            Ok(e) => e,
            Err(e) => {
                let _ = done.send(WorkerMsg::Failed {
                    worker,
                    error: e.message(),
                    fatal: true,
                });
                return;
            }
        };
        if warm {
            if let Err(e) = engine.prewarm() {
                let _ = done.send(WorkerMsg::Failed {
                    worker,
                    error: e.message(),
                    fatal: true,
                });
                return;
            }
        }
        let _ = done.send(WorkerMsg::Ready { worker });
        while let Ok((batch, est, at)) = rx.recv() {
            match engine.serve_batch(&batch, &store, at) {
                Ok(outcome) => {
                    let _ = done.send(WorkerMsg::Done {
                        worker,
                        batch,
                        est,
                        outcome,
                    });
                }
                Err(ServeError::Transient(error)) => {
                    let _ = done.send(WorkerMsg::Failed {
                        worker,
                        error,
                        fatal: false,
                    });
                }
                Err(ServeError::Fatal(error)) => {
                    let _ = done.send(WorkerMsg::Failed {
                        worker,
                        error,
                        fatal: true,
                    });
                    return;
                }
            }
        }
    }));
    tx
}

/// Re-queue (bounded) or shed a crashed worker's in-flight batch from
/// the leader-side copy.
fn recover_in_flight(
    slot: &mut WorkerSlot,
    plan: &FaultPlan,
    magnus: bool,
    attempts: &mut HashMap<u64, u32>,
    batcher: &mut AdaptiveBatcher,
    pending: &mut VecDeque<Batch>,
    ledger: &mut Ledger,
) {
    let (batch, _est) = match slot.in_flight.take() {
        Some(x) => x,
        None => return,
    };
    let attempt = attempts.entry(batch.id).or_insert(0);
    *attempt += 1;
    if *attempt > plan.max_retries {
        for pr in &batch.requests {
            ledger.shed(pr.meta.id);
        }
        return;
    }
    ledger.metrics.retries += 1;
    if magnus {
        batcher.requeue(batch);
    } else {
        pending.push_back(batch);
    }
}

/// Re-queue the two halves of an OOM'd batch (§III-C), preferring the
/// overrun-guard EOS partition when the plan enables it.  Singleton
/// batches cannot split and ride the bounded retry path instead.
#[allow(clippy::too_many_arguments)]
fn requeue_oom_live(
    plan: &FaultPlan,
    magnus: bool,
    attempts: &mut HashMap<u64, u32>,
    batcher: &mut AdaptiveBatcher,
    pending: &mut VecDeque<Batch>,
    ledger: &mut Ledger,
    mut batch: Batch,
    at_iteration: u32,
    g_max: u32,
    next_batch_id_vanilla: &mut u64,
) {
    if batch.size() < 2 {
        batch.insertable = false;
        let attempt = attempts.entry(batch.id).or_insert(0);
        *attempt += 1;
        if *attempt > plan.max_retries {
            for pr in &batch.requests {
                ledger.shed(pr.meta.id);
            }
            return;
        }
        ledger.metrics.retries += 1;
        if magnus {
            batcher.requeue(batch);
        } else {
            pending.push_back(batch);
        }
        return;
    }
    let nid = if magnus {
        batcher.alloc_id()
    } else {
        let id = *next_batch_id_vanilla;
        *next_batch_id_vanilla += 1;
        id
    };
    let batch = if plan.overrun_guard {
        match batch.split_overrun(nid, at_iteration, g_max) {
            Ok((l, r)) => {
                ledger.metrics.rebucketed += r.size();
                if magnus {
                    batcher.requeue(l);
                    batcher.requeue(r);
                } else {
                    pending.push_back(l);
                    pending.push_back(r);
                }
                return;
            }
            Err(b) => b,
        }
    } else {
        batch
    };
    let (l, r) = batch.split(nid);
    if magnus {
        batcher.requeue(l);
        batcher.requeue(r);
    } else {
        pending.push_back(l);
        pending.push_back(r);
    }
}

/// Clamp the leader's arrival-poll timeout: a `next_arrival` already in
/// the past (or a NaN delta) yields `ZERO` via
/// [`crate::util::clamped_duration`], and the 50 ms cap keeps
/// completions and worker restarts responsive while idling toward a
/// distant arrival.  The cap is applied on the `Duration` side so NaN
/// can never reach it (`f64::min` would propagate the cap on NaN).
pub fn arrival_timeout(due_s: f64, elapsed_s: f64) -> Duration {
    clamped_duration(due_s - elapsed_s).min(Duration::from_millis(50))
}

/// Replay an interned trace through the supervised cluster; returns run
/// metrics (times are in replayed seconds, i.e. wall seconds ×
/// time_scale, so they are comparable with trace arrival timestamps).
///
/// Zero-copy: the leader admits compact metas, the workers resolve
/// prompt text from the shared read-only arena, and the dispatch
/// channels carry `Copy` records plus one batch.
///
/// Exactly-once: the loop runs until `completed + shed == admitted`.
/// Worker crashes re-queue the leader-side in-flight copy with bounded
/// retries; exhausted retries shed explicitly; if every slot retires
/// (restart budgets spent) the remaining queue is shed so accounting
/// still closes instead of spinning forever.
pub fn serve_supervised<F: WorkerFactory>(
    cfg: &ServingConfig,
    opts: &ServeOptions,
    policy: LivePolicy,
    predictor: Option<GenLenPredictor>,
    store: Arc<TraceStore>,
    factory: Arc<F>,
) -> Result<RunMetrics> {
    serve_core(cfg, opts, policy, predictor, store, factory, Ingress::Replay, None)
}

/// Live-ingress variant: requests arrive as [`EdgeJob`]s over `jobs`
/// (predicted at the edge; `meta.arrival` is rewritten to the admission
/// instant in replayed seconds), per-request outcomes flow back over
/// `signals`, and the run ends when `jobs` closes and every admitted
/// request has completed or been shed.  This is what the HTTP front door
/// ([`crate::edge::EdgeServer`]) runs underneath.
pub fn serve_ingress_supervised<F: WorkerFactory>(
    cfg: &ServingConfig,
    opts: &ServeOptions,
    policy: LivePolicy,
    jobs: mpsc::Receiver<EdgeJob>,
    signals: mpsc::Sender<CoreSignal>,
    store: Arc<TraceStore>,
    factory: Arc<F>,
) -> Result<RunMetrics> {
    serve_core(
        cfg,
        opts,
        policy,
        None,
        store,
        factory,
        Ingress::Live { jobs },
        Some(signals),
    )
}

#[allow(clippy::too_many_arguments)]
fn serve_core<F: WorkerFactory>(
    cfg: &ServingConfig,
    opts: &ServeOptions,
    policy: LivePolicy,
    mut predictor: Option<GenLenPredictor>,
    store: Arc<TraceStore>,
    factory: Arc<F>,
    ingress: Ingress,
    signals: Option<mpsc::Sender<CoreSignal>>,
) -> Result<RunMetrics> {
    let plan = &opts.fault_plan;
    let probe = factory.probe()?;

    // done_tx stays alive on the leader: restarts need fresh clones, and
    // "all workers dead" must surface as slot state, not a Disconnected
    // error racing the supervisor.
    let (done_tx, done_rx) = mpsc::channel::<WorkerMsg>();
    let mut handles = Vec::new();
    let mut slots: Vec<WorkerSlot> = Vec::with_capacity(opts.n_workers);
    for w in 0..opts.n_workers {
        let tx = spawn_worker(&factory, w, opts.warm_up, &done_tx, &store, &mut handles);
        slots.push(WorkerSlot {
            tx: Some(tx),
            state: SlotState::Starting,
            restarts: 0,
            in_flight: None,
        });
    }

    // Coordinator state.
    let (magnus_policy, fixed_batch) = match &policy {
        LivePolicy::Magnus(p) => (Some(p.clone()), 0),
        LivePolicy::Vanilla { fixed_batch } => (None, *fixed_batch),
    };
    let magnus = matches!(&policy, LivePolicy::Magnus(_));
    let max_batch = probe.max_batch.min(if let Some(p) = &magnus_policy {
        if p.max_batch_size > 0 {
            p.max_batch_size as usize
        } else {
            usize::MAX
        }
    } else {
        fixed_batch as usize
    });
    let mut batcher = AdaptiveBatcher::new(BatcherConfig {
        wma_threshold: cfg.wma_threshold,
        theta: probe.theta,
        delta: probe.delta,
        // usize::MAX (cost backend, uncapped policy) → 0 = uncapped.
        max_batch_size: u32::try_from(max_batch).unwrap_or(0),
    });
    let g_max = cfg.gpu.g_max;
    // Uncertainty-aware scheduling state (ISSUE 9) — inert (and
    // behaviour-neutral) unless `cfg.uncertainty.enabled`.
    let unc = &cfg.uncertainty;
    let mut drift = DriftDetector::new(unc.drift_config());
    let mut low_conf: HashSet<u64> = HashSet::new();
    let mut point_of: HashMap<u64, u32> = HashMap::new();
    // Vanilla-path admission queue (Copy metas; replay pushes from the
    // store, live ingress pushes from the jobs channel).
    let mut fifo: VecDeque<RequestMeta> = VecDeque::new();
    // Vanilla-path re-dispatch queue (crash recovery, OOM splits).
    let mut pending: VecDeque<Batch> = VecDeque::new();
    let mut attempts: HashMap<u64, u32> = HashMap::new();
    let mut estimator = ServingTimeEstimator::new(cfg.knn_k);
    // Estimator refresh state: a segment cursor into the log DB plus the
    // rows already absorbed, so each completion trains on O(new) entries
    // instead of re-cloning the whole batch log (O(n²) over a run).
    let mut est_cursor = 0usize;
    let mut est_new_shapes: Vec<BatchShape> = Vec::new();
    let mut est_new_times: Vec<f64> = Vec::new();
    let db = LogDb::new();
    let mut ledger = Ledger {
        metrics: RunMetrics::new(),
        signals,
    };
    let mut idle: Vec<usize> = Vec::new();
    let mut next_batch_id_vanilla = 1_000_000u64;

    let start = Instant::now();
    let scale = opts.time_scale.max(1e-9);
    let now_replayed = |start: Instant| start.elapsed().as_secs_f64() * scale;

    let replay = matches!(ingress, Ingress::Replay);
    // Replay: the whole trace is admitted up front.  Live: `admitted`
    // counts jobs received so far and `jobs_open` tracks the channel.
    let mut admitted = if replay { store.len() } else { 0 };
    let mut jobs_open = !replay;
    let mut next_arrival = 0usize;
    let mut completed = 0usize;

    while jobs_open || completed + ledger.metrics.shed.len() < admitted {
        // 0. Respawn crashed workers whose backoff deadline has passed.
        let wall = Instant::now();
        for w in 0..slots.len() {
            let due = match slots[w].state {
                SlotState::Down(due) => due,
                _ => continue,
            };
            if due <= wall {
                let tx = spawn_worker(&factory, w, opts.warm_up, &done_tx, &store, &mut handles);
                slots[w].tx = Some(tx);
                slots[w].state = SlotState::Starting;
            }
        }

        // 1. Admit arrivals.  Replay: every request whose (scaled)
        //    arrival time has passed — zero-copy, the meta is a few
        //    machine words and the predictor borrows the prompt text
        //    straight from the shared arena; the fallback chain (trained
        //    predictor → input-length heuristic → max-bucket default)
        //    keeps admission alive through predictor outages.  Live:
        //    drain the edge's jobs channel; the prediction already
        //    happened at admission, and `meta.arrival` is rewritten to
        //    the receipt instant so response times measure real
        //    queueing + service.
        let now = now_replayed(start);
        match &ingress {
            Ingress::Replay => {
                while next_arrival < admitted && store.meta(next_arrival).arrival <= now {
                    let meta = store.meta(next_arrival);
                    next_arrival += 1;
                    match (&policy, &mut predictor) {
                        (LivePolicy::Magnus(_), Some(p)) => {
                            let view = store.view_of(&meta);
                            // Merged outage chain: global window, then the
                            // per-app window; drift demotion joins in only
                            // under uncertainty-aware scheduling.
                            let outage = plan
                                .predictor_outage(now)
                                .or_else(|| plan.app_outage(meta.task.app().index(), now));
                            let predicted = if unc.enabled {
                                let outage = outage.or_else(|| drift.active_fallback());
                                if let Some(mode) = outage {
                                    ledger.metrics.fallback_predictions += 1;
                                    let pf =
                                        fallback_prediction(mode, meta.user_input_len, g_max);
                                    point_of.insert(meta.id, pf);
                                    pf
                                } else {
                                    let pwc = p.predict_with_confidence(
                                        view,
                                        unc.upper_quantile as f32,
                                    );
                                    let point = plan.noisy_prediction(
                                        plan.drifted_prediction(pwc.point, now, g_max),
                                        meta.id,
                                        g_max,
                                    );
                                    point_of.insert(meta.id, point);
                                    if f64::from(pwc.confidence) < unc.confidence_threshold {
                                        ledger.metrics.low_confidence_admissions += 1;
                                        low_conf.insert(meta.id);
                                        let upper = plan.noisy_prediction(
                                            plan.drifted_prediction(
                                                pwc.upper_quantile,
                                                now,
                                                g_max,
                                            ),
                                            meta.id,
                                            g_max,
                                        );
                                        point.max(upper)
                                    } else {
                                        point
                                    }
                                }
                            } else {
                                let (predicted, fell_back) =
                                    predict_degraded(p, outage, &view, g_max);
                                if fell_back {
                                    ledger.metrics.fallback_predictions += 1;
                                    predicted
                                } else {
                                    plan.noisy_prediction(
                                        plan.drifted_prediction(predicted, now, g_max),
                                        meta.id,
                                        g_max,
                                    )
                                }
                            };
                            batcher.insert(
                                PredictedRequest {
                                    meta,
                                    predicted_gen_len: predicted,
                                },
                                now,
                            );
                        }
                        _ => fifo.push_back(meta),
                    }
                }
            }
            Ingress::Live { jobs } => {
                while jobs_open {
                    match jobs.try_recv() {
                        Ok(job) => {
                            admitted += 1;
                            let mut meta = job.meta;
                            meta.arrival = now;
                            if magnus {
                                batcher.insert(
                                    PredictedRequest {
                                        meta,
                                        predicted_gen_len: job.predicted_gen_len,
                                    },
                                    now,
                                );
                            } else {
                                fifo.push_back(meta);
                            }
                        }
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => {
                            jobs_open = false;
                        }
                    }
                }
            }
        }

        // 2. Dispatch to idle workers.  The leader keeps a copy of every
        //    in-flight batch so a crash can re-queue it.
        while !idle.is_empty() {
            let now = now_replayed(start);
            let (batch, est) = match &policy {
                LivePolicy::Magnus(p) => {
                    if batcher.is_empty() {
                        break;
                    }
                    // Indexed selection — same incremental structures as
                    // the simulator's dispatch loop (O(log Q) steady
                    // state instead of a per-round view rebuild).
                    let (pick, est) = batcher
                        .select_indexed(p.sched, now, estimator.generation(), |shape| {
                            estimator.estimate(shape)
                        })
                        .unwrap();
                    (batcher.take(pick), est)
                }
                LivePolicy::Vanilla { fixed_batch } => {
                    if let Some(b) = pending.pop_front() {
                        (b, 0.0)
                    } else if fifo.is_empty() {
                        break;
                    } else {
                        let take = (*fixed_batch as usize).min(fifo.len());
                        let mut reqs = Vec::with_capacity(take);
                        for _ in 0..take {
                            let meta = fifo.pop_front().unwrap();
                            reqs.push(PredictedRequest {
                                meta,
                                predicted_gen_len: 0,
                            });
                        }
                        let mut it = reqs.into_iter();
                        let mut b = Batch::new(next_batch_id_vanilla, it.next().unwrap(), now);
                        next_batch_id_vanilla += 1;
                        b.requests.extend(it);
                        (b, 0.0)
                    }
                }
            };
            let w = idle.pop().unwrap();
            slots[w].in_flight = Some((batch.clone(), est));
            let delivered = match &slots[w].tx {
                Some(tx) => tx.send((batch, est, now)).is_ok(),
                None => false,
            };
            if !delivered {
                // Defensive: a channel closed without a Failed message
                // (unreachable by protocol).  Recover the copy so the
                // requests are not lost with the dead channel.
                slots[w].tx = None;
                slots[w].state = SlotState::Retired;
                recover_in_flight(
                    &mut slots[w],
                    plan,
                    magnus,
                    &mut attempts,
                    &mut batcher,
                    &mut pending,
                    &mut ledger,
                );
            }
        }

        // 3. Wait for the next completion, the next arrival deadline, or
        //    the next restart deadline — whichever is soonest.  Live
        //    ingress has no arrival schedule to sleep toward, but new
        //    jobs cannot wake `done_rx` either, so it polls on a short
        //    leash instead.
        let timeout = if !replay {
            Duration::from_millis(5)
        } else if next_arrival < admitted {
            let due = store.meta(next_arrival).arrival / scale;
            arrival_timeout(due, start.elapsed().as_secs_f64())
        } else {
            Duration::from_millis(50)
        };
        let wall = Instant::now();
        let timeout = slots.iter().fold(timeout, |t, s| match s.state {
            SlotState::Down(due) => t.min(due.saturating_duration_since(wall)),
            _ => t,
        });
        match done_rx.recv_timeout(timeout) {
            Ok(WorkerMsg::Done {
                worker,
                batch,
                est,
                outcome,
            }) => {
                slots[worker].in_flight = None;
                let now = now_replayed(start);
                match outcome {
                    BatchOutcome::Completed {
                        serving_time,
                        per_request,
                    } => {
                        attempts.remove(&batch.id);
                        completed += per_request.len();
                        for (pr, sr) in batch.requests.iter().zip(&per_request) {
                            ledger.metrics.record_prediction(pr.predicted_gen_len, pr.meta.gen_len);
                            ledger.done(RequestRecord {
                                request_id: sr.request_id,
                                arrival: pr.meta.arrival,
                                finish: now,
                                valid_tokens: sr.valid_tokens,
                                invalid_tokens: sr.invalid_tokens,
                            });
                            db.log_request(RequestLog {
                                meta: pr.meta,
                                predicted_gen_len: pr.predicted_gen_len,
                                actual_gen_len: pr.meta.gen_len,
                                at: now,
                            });
                        }
                        if unc.enabled {
                            // Drift detection observes the *point*
                            // estimate's signed error — charged values
                            // would hide exactly the bias the charge is
                            // compensating for.
                            for pr in &batch.requests {
                                let point = point_of
                                    .remove(&pr.meta.id)
                                    .unwrap_or(pr.predicted_gen_len);
                                low_conf.remove(&pr.meta.id);
                                match drift.observe(
                                    pr.meta.task.app(),
                                    pr.meta.user_input_len,
                                    f64::from(point) - f64::from(pr.meta.gen_len),
                                ) {
                                    DriftEvent::Demoted => {
                                        ledger.metrics.drift_demotions += 1
                                    }
                                    DriftEvent::Repromoted => {
                                        ledger.metrics.drift_repromotions += 1
                                    }
                                    DriftEvent::None => {}
                                }
                            }
                        }
                        db.log_batch(BatchLog {
                            shape: batch.true_shape(),
                            estimated_time: est,
                            // serving_time is wall seconds; scale into
                            // replayed seconds so HRRN compares like with
                            // like.
                            actual_time: serving_time * scale,
                            at: now,
                        });
                        // Online estimator refresh from real executions:
                        // absorb only the log tail since the last refresh
                        // (KNN appends are equivalent to a fresh fit on
                        // the union — property-tested in estimator::knn).
                        // Rows accumulate until the 3-row cold-start
                        // threshold.
                        est_cursor += db.visit_batches_from(est_cursor, |l| {
                            est_new_shapes.push(l.shape);
                            est_new_times.push(l.actual_time);
                        });
                        if estimator.is_trained() || est_new_shapes.len() >= 3 {
                            estimator.augment_and_refit(&est_new_shapes, &est_new_times);
                            est_new_shapes.clear();
                            est_new_times.clear();
                        }
                    }
                    BatchOutcome::Oom { at_iteration, .. } => {
                        // Speculative overrun guard: a batch the admission
                        // already charged conservatively (low confidence)
                        // gets the EOS-partitioned re-bucket without OOM
                        // accounting — mirrors the simulator's path.
                        let mut batch = batch;
                        let mut handled = false;
                        if unc.enabled
                            && magnus
                            && batch.size() >= 2
                            && batch
                                .requests
                                .iter()
                                .any(|pr| low_conf.contains(&pr.meta.id))
                        {
                            let nid = batcher.alloc_id();
                            match batch.split_overrun(nid, at_iteration, g_max) {
                                Ok((l, r)) => {
                                    ledger.metrics.speculative_rebuckets += 1;
                                    ledger.metrics.rebucketed += r.size();
                                    batcher.requeue(l);
                                    batcher.requeue(r);
                                    handled = true;
                                }
                                Err(b) => batch = b,
                            }
                        }
                        if !handled {
                            ledger.metrics.record_oom();
                            requeue_oom_live(
                                plan,
                                magnus,
                                &mut attempts,
                                &mut batcher,
                                &mut pending,
                                &mut ledger,
                                batch,
                                at_iteration,
                                g_max,
                                &mut next_batch_id_vanilla,
                            );
                        }
                    }
                }
                idle.push(worker);
            }
            Ok(WorkerMsg::Failed {
                worker,
                error,
                fatal,
            }) => {
                recover_in_flight(
                    &mut slots[worker],
                    plan,
                    magnus,
                    &mut attempts,
                    &mut batcher,
                    &mut pending,
                    &mut ledger,
                );
                if fatal {
                    slots[worker].tx = None;
                    if slots[worker].restarts >= plan.max_worker_restarts {
                        slots[worker].state = SlotState::Retired;
                        eprintln!("server: worker {worker} retired: {error}");
                    } else {
                        slots[worker].restarts += 1;
                        ledger.metrics.worker_restarts += 1;
                        let backoff = plan.restart_backoff(slots[worker].restarts - 1);
                        // Bound the Duration before Instant arithmetic: a
                        // degenerate plan (inf backoff) saturates
                        // `clamped_duration` to MAX, which would overflow
                        // `Instant + Duration`.
                        slots[worker].state = SlotState::Down(
                            Instant::now()
                                + clamped_duration(backoff).min(Duration::from_secs(3600)),
                        );
                        eprintln!(
                            "server: worker {worker} down ({error}); restart in {backoff:.3}s"
                        );
                    }
                } else {
                    // Transient: the worker thread survived and loops on.
                    idle.push(worker);
                }
            }
            Ok(WorkerMsg::Ready { worker }) => {
                slots[worker].state = SlotState::Up;
                idle.push(worker);
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // Unreachable: the leader holds done_tx for restarts.
                anyhow::bail!("supervisor channel closed unexpectedly");
            }
        }

        // 4. If every slot has exhausted its restart budget there is no
        //    worker left (and none coming back): shed everything still
        //    queued so accounting closes instead of spinning forever.
        if slots.iter().all(|s| matches!(s.state, SlotState::Retired)) {
            while !batcher.is_empty() {
                let b = batcher.take(0);
                for pr in &b.requests {
                    ledger.shed(pr.meta.id);
                }
            }
            while let Some(b) = pending.pop_front() {
                for pr in &b.requests {
                    ledger.shed(pr.meta.id);
                }
            }
            while let Some(m) = fifo.pop_front() {
                ledger.shed(m.id);
            }
            match &ingress {
                Ingress::Replay => {
                    for i in next_arrival..admitted {
                        ledger.shed(store.meta(i).id);
                    }
                }
                Ingress::Live { jobs } => {
                    // Shed whatever the edge already pushed; the edge
                    // notices the signal channel die after we return and
                    // fails anything it still holds, so accounting closes
                    // on both sides.
                    while let Ok(job) = jobs.try_recv() {
                        admitted += 1;
                        ledger.shed(job.meta.id);
                    }
                    jobs_open = false;
                }
            }
            break;
        }
    }

    // Shutdown: close the dispatch channels, join every incarnation, then
    // drain completions that raced the shutdown edge so no Done message
    // is silently dropped (they finished serving; record them).
    for s in &mut slots {
        s.tx = None;
    }
    for h in handles {
        let _ = h.join();
    }
    drop(done_tx);
    let now = now_replayed(start);
    while let Ok(msg) = done_rx.try_recv() {
        if let WorkerMsg::Done {
            batch,
            outcome: BatchOutcome::Completed { per_request, .. },
            ..
        } = msg
        {
            completed += per_request.len();
            for (pr, sr) in batch.requests.iter().zip(&per_request) {
                ledger.metrics.record_prediction(pr.predicted_gen_len, pr.meta.gen_len);
                ledger.done(RequestRecord {
                    request_id: sr.request_id,
                    arrival: pr.meta.arrival,
                    finish: now,
                    valid_tokens: sr.valid_tokens,
                    invalid_tokens: sr.invalid_tokens,
                });
                db.log_request(RequestLog {
                    meta: pr.meta,
                    predicted_gen_len: pr.predicted_gen_len,
                    actual_gen_len: pr.meta.gen_len,
                    at: now,
                });
            }
        }
    }
    debug_assert_eq!(
        completed + ledger.metrics.shed.len(),
        admitted,
        "exactly-once accounting must close: every admitted request \
         completes or is explicitly shed"
    );
    Ok(ledger.metrics)
}

/// Replay an owned `trace` through the live cluster; interns it once and
/// delegates to [`serve_trace_store`].  Callers that can produce a
/// [`TraceStore`] directly (JSON load via `TraceStore::from_json`,
/// streaming generation) should use the store entry point and skip the
/// owned `Vec<Request>` entirely — this wrapper holds both copies of the
/// text alive for the run.
#[cfg(feature = "pjrt")]
pub fn serve_trace(
    cfg: &ServingConfig,
    opts: &ServeOptions,
    policy: LivePolicy,
    predictor: Option<GenLenPredictor>,
    trace: &[Request],
) -> Result<RunMetrics> {
    serve_trace_store(
        cfg,
        opts,
        policy,
        predictor,
        Arc::new(TraceStore::from_requests(trace)),
    )
}

/// Replay an interned trace over real PJRT compute.
#[cfg(feature = "pjrt")]
pub fn serve_trace_store(
    cfg: &ServingConfig,
    opts: &ServeOptions,
    policy: LivePolicy,
    predictor: Option<GenLenPredictor>,
    store: Arc<TraceStore>,
) -> Result<RunMetrics> {
    let factory = Arc::new(PjrtWorkerFactory {
        artifacts_dir: opts.artifacts_dir.clone(),
    });
    serve_supervised(cfg, opts, policy, predictor, store, factory)
}

/// Replay an interned trace over the cost-model backend: the same
/// supervised cluster (threads, channels, wall clock, restarts) with
/// analytic serving times, honouring `opts.fault_plan`.  No artifacts
/// required — this is the chaos suite's substrate.
pub fn serve_trace_store_sim(
    cfg: &ServingConfig,
    opts: &ServeOptions,
    policy: LivePolicy,
    predictor: Option<GenLenPredictor>,
    store: Arc<TraceStore>,
) -> Result<RunMetrics> {
    let factory = Arc::new(CostWorkerFactory::from_config(
        cfg,
        opts.time_scale,
        opts.fault_plan.clone(),
    ));
    serve_supervised(cfg, opts, policy, predictor, store, factory)
}

/// Live-ingress serving over the cost-model backend: what the HTTP edge
/// runs underneath when no PJRT artifacts are present (and what the edge
/// tests/benches drive).
pub fn serve_ingress_sim(
    cfg: &ServingConfig,
    opts: &ServeOptions,
    policy: LivePolicy,
    jobs: mpsc::Receiver<EdgeJob>,
    signals: mpsc::Sender<CoreSignal>,
    store: Arc<TraceStore>,
) -> Result<RunMetrics> {
    let factory = Arc::new(CostWorkerFactory::from_config(
        cfg,
        opts.time_scale,
        opts.fault_plan.clone(),
    ));
    serve_ingress_supervised(cfg, opts, policy, jobs, signals, store, factory)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::Variant;
    use crate::workload::dataset::build_predictor_split;
    use crate::workload::{LlmProfile, TraceSpec};

    #[test]
    fn arrival_timeout_clamps_past_nan_and_far_future() {
        assert_eq!(arrival_timeout(1.0, 5.0), Duration::ZERO); // already past
        assert_eq!(arrival_timeout(3.0, 3.0), Duration::ZERO); // due now
        assert_eq!(arrival_timeout(f64::NAN, 1.0), Duration::ZERO);
        let near = arrival_timeout(1.010, 1.0);
        assert!(near > Duration::ZERO && near <= Duration::from_millis(50));
        let far = arrival_timeout(100.0, 0.0);
        assert!(far >= Duration::from_millis(49) && far <= Duration::from_millis(50));
        let inf = arrival_timeout(f64::INFINITY, 0.0);
        assert!(inf >= Duration::from_millis(49) && inf <= Duration::from_millis(50));
    }

    /// Property coverage for the timeout clamp itself (ISSUE 7 satellite:
    /// previously only exercised implicitly through `serve_supervised`):
    /// for ANY pair of inputs — past-due, NaN, ±∞, huge deltas — the
    /// result is a valid Duration in `[0, 50ms]`, never a panic, and it
    /// equals the true clamped delta whenever that delta is finite.
    #[test]
    fn arrival_timeout_is_total_and_clamped() {
        crate::util::prop::prop_check(400, |rng| {
            // Mix tame magnitudes with pathological ones.
            let wild = |rng: &mut crate::util::Rng| match rng.range_usize(0, 8) {
                0 => f64::NAN,
                1 => f64::INFINITY,
                2 => f64::NEG_INFINITY,
                3 => rng.range_f64(-1e300, 1e300),
                4 => -rng.f64() * 1e-9,
                _ => rng.range_f64(-100.0, 100.0),
            };
            let due = wild(rng);
            let elapsed = wild(rng);
            let t = arrival_timeout(due, elapsed);
            assert!(t <= Duration::from_millis(50), "due={due} elapsed={elapsed} t={t:?}");
            let dt = due - elapsed;
            if dt.is_nan() || dt <= 0.0 {
                assert_eq!(t, Duration::ZERO, "due={due} elapsed={elapsed}");
            } else if dt >= 0.050 {
                assert_eq!(t, Duration::from_millis(50), "due={due} elapsed={elapsed}");
            } else {
                // from_secs_f64 rounds to the nearest nanosecond
                assert!(
                    (t.as_secs_f64() - dt).abs() <= 1e-9,
                    "due={due} elapsed={elapsed} t={t:?}"
                );
            }
        });
    }

    /// Fault-free supervised run over the cost backend: everything
    /// completes, nothing sheds, every robustness counter stays zero.
    #[test]
    fn supervised_cost_backend_serves_all_fault_free() {
        let mut cfg = ServingConfig::default();
        cfg.gpu.g_max = 24;
        let store = Arc::new(TraceStore::generate(&TraceSpec {
            rate: 20.0,
            n_requests: 12,
            g_max: 24,
            l_cap: 40,
            seed: 11,
            ..Default::default()
        }));
        let split = build_predictor_split(LlmProfile::ChatGlm6B, 40, 5, 24, 6);
        let mut p = GenLenPredictor::new(Variant::Usin, &cfg);
        p.train(&split.train);
        let opts = ServeOptions {
            n_workers: 2,
            time_scale: 400.0,
            ..Default::default()
        };
        let metrics = serve_trace_store_sim(
            &cfg,
            &opts,
            LivePolicy::Magnus(MagnusPolicy::magnus()),
            Some(p),
            store,
        )
        .unwrap();
        assert_eq!(metrics.records.len(), 12);
        assert!(metrics.shed.is_empty());
        assert_eq!(metrics.retries, 0);
        assert_eq!(metrics.worker_restarts, 0);
        assert_eq!(metrics.fallback_predictions, 0);
        assert!(metrics.records.iter().all(|r| r.finish >= r.arrival));
    }

    #[test]
    fn supervised_cost_backend_vanilla_smoke() {
        let cfg = ServingConfig::default();
        let store = Arc::new(TraceStore::generate(&TraceSpec {
            rate: 20.0,
            n_requests: 8,
            g_max: 16,
            l_cap: 30,
            seed: 7,
            ..Default::default()
        }));
        let opts = ServeOptions {
            n_workers: 1,
            time_scale: 400.0,
            ..Default::default()
        };
        let metrics = serve_trace_store_sim(
            &cfg,
            &opts,
            LivePolicy::Vanilla { fixed_batch: 4 },
            None,
            store,
        )
        .unwrap();
        assert_eq!(metrics.records.len(), 8);
        assert!(metrics.shed.is_empty());
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod pjrt_tests {
    use super::*;
    use crate::predictor::Variant;
    use crate::workload::dataset::build_predictor_split;
    use crate::workload::{generate_trace, LlmProfile, TraceSpec};

    fn have_artifacts() -> bool {
        std::path::Path::new("artifacts/manifest.json").exists()
    }

    /// End-to-end: real PJRT compute under the full Magnus pipeline.
    #[test]
    fn live_magnus_serves_small_trace() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts/ not built");
            return;
        }
        let mut cfg = ServingConfig::default();
        cfg.gpu.g_max = 24;
        let trace = generate_trace(&TraceSpec {
            rate: 2.0,
            n_requests: 10,
            g_max: 24,
            l_cap: 40,
            seed: 5,
            ..Default::default()
        });
        let split = build_predictor_split(LlmProfile::ChatGlm6B, 40, 5, 24, 6);
        let mut p = GenLenPredictor::new(Variant::Usin, &cfg);
        p.train(&split.train);
        let metrics = serve_trace(
            &cfg,
            &ServeOptions {
                n_workers: 1,
                time_scale: 20.0,
                ..Default::default()
            },
            LivePolicy::Magnus(MagnusPolicy::magnus()),
            Some(p),
            &trace,
        )
        .unwrap();
        assert_eq!(metrics.records.len(), 10);
        assert!(metrics.records.iter().all(|r| r.finish >= r.arrival));
    }

    #[test]
    fn live_vanilla_serves_small_trace() {
        if !have_artifacts() {
            return;
        }
        let cfg = ServingConfig::default();
        let trace = generate_trace(&TraceSpec {
            rate: 3.0,
            n_requests: 8,
            g_max: 16,
            l_cap: 30,
            seed: 7,
            ..Default::default()
        });
        let metrics = serve_trace(
            &cfg,
            &ServeOptions {
                n_workers: 1,
                time_scale: 20.0,
                ..Default::default()
            },
            LivePolicy::Vanilla { fixed_batch: 4 },
            None,
            &trace,
        )
        .unwrap();
        assert_eq!(metrics.records.len(), 8);
    }
}
