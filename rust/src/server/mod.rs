//! Live serving cluster: the Fig. 7 workflow over REAL compute.
//!
//! A leader thread owns the coordinator state (predictor → WMA batcher →
//! estimator → scheduler, §III-A) and replays a trace in (scaled) wall
//! time; N worker threads each own a [`PjrtBatchServer`] (one "LLM
//! instance" per §III-F worker process — PJRT clients are `!Send`, so each
//! worker constructs its engine on its own thread) and serve dispatched
//! batches, reporting completions back over channels.  This mirrors the
//! discrete-event simulator exactly — same policy objects, different clock
//! and engine — which is what makes the simulator's figures trustworthy.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::batch::{AdaptiveBatcher, Batch, BatcherConfig};
use crate::config::ServingConfig;
use crate::engine::pjrt::PjrtBatchServer;
use crate::engine::BatchOutcome;
use crate::estimator::{BatchShape, ServingTimeEstimator};
use crate::logdb::{BatchLog, LogDb, RequestLog};
use crate::metrics::{RequestRecord, RunMetrics};
use crate::predictor::GenLenPredictor;
use crate::sim::MagnusPolicy;
use crate::workload::{PredictedRequest, Request, TraceStore};

/// Live-serving policy.
pub enum LivePolicy {
    /// The full pipeline (or a GLP/ABP ablation via `MagnusPolicy`).
    Magnus(MagnusPolicy),
    /// Vanilla scheduling with a fixed batch size.
    Vanilla { fixed_batch: u32 },
}

/// Options for a live run.
pub struct ServeOptions {
    pub artifacts_dir: String,
    pub n_workers: usize,
    /// Trace arrival times are divided by this (replay speed-up).
    pub time_scale: f64,
    /// Compile all buckets before accepting traffic.
    pub warm_up: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            artifacts_dir: "artifacts".to_string(),
            n_workers: 2,
            time_scale: 10.0,
            warm_up: false,
        }
    }
}

enum WorkerMsg {
    Done {
        worker: usize,
        batch: Batch,
        /// Serving-time estimate captured at dispatch; riding the
        /// round-trip kills the leader-side batch-id → estimate map (as
        /// the simulator's in-flight events do).
        est: f64,
        outcome: BatchOutcome,
    },
    Failed {
        worker: usize,
        error: String,
    },
    Ready {
        #[allow(dead_code)] // diagnostic payload, read in error paths only
        worker: usize,
    },
}

/// Replay an owned `trace` through the live cluster; interns it once and
/// delegates to [`serve_trace_store`].  Callers that can produce a
/// [`TraceStore`] directly (JSON load via `TraceStore::from_json`,
/// streaming generation) should use the store entry point and skip the
/// owned `Vec<Request>` entirely — this wrapper holds both copies of the
/// text alive for the run.
pub fn serve_trace(
    cfg: &ServingConfig,
    opts: &ServeOptions,
    policy: LivePolicy,
    predictor: Option<GenLenPredictor>,
    trace: &[Request],
) -> Result<RunMetrics> {
    serve_trace_store(
        cfg,
        opts,
        policy,
        predictor,
        Arc::new(TraceStore::from_requests(trace)),
    )
}

/// Replay an interned trace through the live cluster; returns run
/// metrics (times are in replayed seconds, i.e. wall seconds ×
/// time_scale, so they are comparable with trace arrival timestamps).
///
/// Zero-copy: the leader admits compact metas, the workers resolve
/// prompt text from the shared read-only arena, and the dispatch
/// channels carry `Copy` records instead of cloned strings.
pub fn serve_trace_store(
    cfg: &ServingConfig,
    opts: &ServeOptions,
    policy: LivePolicy,
    mut predictor: Option<GenLenPredictor>,
    store: Arc<TraceStore>,
) -> Result<RunMetrics> {
    let (done_tx, done_rx) = mpsc::channel::<WorkerMsg>();
    let mut batch_txs: Vec<mpsc::Sender<(Batch, f64)>> = Vec::new();
    let mut handles = Vec::new();

    for w in 0..opts.n_workers {
        let (tx, rx) = mpsc::channel::<(Batch, f64)>();
        batch_txs.push(tx);
        let done = done_tx.clone();
        let dir = opts.artifacts_dir.clone();
        let warm = opts.warm_up;
        let store = Arc::clone(&store);
        handles.push(std::thread::spawn(move || {
            // Engine constructed on the worker thread (PJRT is !Send).
            let mut srv = match PjrtBatchServer::load(&dir) {
                Ok(s) => s,
                Err(e) => {
                    let _ = done.send(WorkerMsg::Failed {
                        worker: w,
                        error: format!("{e:#}"),
                    });
                    return;
                }
            };
            if warm {
                if let Err(e) = srv.warm_up() {
                    let _ = done.send(WorkerMsg::Failed {
                        worker: w,
                        error: format!("{e:#}"),
                    });
                    return;
                }
            }
            let _ = done.send(WorkerMsg::Ready { worker: w });
            while let Ok((batch, est)) = rx.recv() {
                match srv.serve(&batch, &store) {
                    Ok(out) => {
                        let _ = done.send(WorkerMsg::Done {
                            worker: w,
                            batch,
                            est,
                            outcome: out.outcome,
                        });
                    }
                    Err(e) => {
                        let _ = done.send(WorkerMsg::Failed {
                            worker: w,
                            error: format!("{e:#}"),
                        });
                        return;
                    }
                }
            }
        }));
    }
    drop(done_tx);

    // Wait for all workers to come up (artifact load + optional warm-up).
    let mut ready = 0;
    while ready < opts.n_workers {
        match done_rx.recv()? {
            WorkerMsg::Ready { .. } => ready += 1,
            WorkerMsg::Failed { worker, error } => {
                anyhow::bail!("worker {worker} failed to start: {error}")
            }
            _ => {}
        }
    }

    // Coordinator state.  Artifacts bound the real memory: Θ is the max
    // bucket's KV bytes, so the planner can never exceed a compiled shape.
    let probe = PjrtBatchServerProbe::load(&opts.artifacts_dir)?;
    let (magnus_policy, fixed_batch) = match &policy {
        LivePolicy::Magnus(p) => (Some(p.clone()), 0),
        LivePolicy::Vanilla { fixed_batch } => (None, *fixed_batch),
    };
    let max_batch = probe.max_batch.min(if let Some(p) = &magnus_policy {
        if p.max_batch_size > 0 {
            p.max_batch_size as usize
        } else {
            usize::MAX
        }
    } else {
        fixed_batch as usize
    });
    let mut batcher = AdaptiveBatcher::new(BatcherConfig {
        wma_threshold: cfg.wma_threshold,
        theta: (probe.max_batch as u64) * (probe.l_max as u64) * probe.delta,
        delta: probe.delta,
        max_batch_size: max_batch as u32,
    });
    let mut fifo: std::collections::VecDeque<usize> = Default::default();
    let mut estimator = ServingTimeEstimator::new(cfg.knn_k);
    // Estimator refresh state: a segment cursor into the log DB plus the
    // rows already absorbed, so each completion trains on O(new) entries
    // instead of re-cloning the whole batch log (O(n²) over a run).
    let mut est_cursor = 0usize;
    let mut est_new_shapes: Vec<BatchShape> = Vec::new();
    let mut est_new_times: Vec<f64> = Vec::new();
    let db = LogDb::new();
    let mut metrics = RunMetrics::new();
    let mut idle: Vec<usize> = (0..opts.n_workers).collect();
    let mut next_batch_id_vanilla = 1_000_000u64;

    let start = Instant::now();
    let scale = opts.time_scale.max(1e-9);
    let now_replayed = |start: Instant| start.elapsed().as_secs_f64() * scale;

    let mut next_arrival = 0usize;
    let mut completed = 0usize;

    while completed < store.len() {
        // 1. Admit every request whose (scaled) arrival time has passed.
        //    Zero-copy: the meta is a few machine words and the predictor
        //    borrows the prompt text straight from the shared arena.
        let now = now_replayed(start);
        while next_arrival < store.len() && store.meta(next_arrival).arrival <= now {
            let meta = store.meta(next_arrival);
            next_arrival += 1;
            match (&policy, &mut predictor) {
                (LivePolicy::Magnus(_), Some(p)) => {
                    let predicted = p.predict(store.view_of(&meta));
                    batcher.insert(
                        PredictedRequest {
                            meta,
                            predicted_gen_len: predicted,
                        },
                        now,
                    );
                }
                _ => fifo.push_back(next_arrival - 1),
            }
        }

        // 2. Dispatch to idle workers (the captured estimate rides the
        //    worker round-trip; no leader-side map).
        while !idle.is_empty() {
            let now = now_replayed(start);
            let (batch, est) = match &policy {
                LivePolicy::Magnus(p) => {
                    if batcher.is_empty() {
                        break;
                    }
                    // Indexed selection — same incremental structures as
                    // the simulator's dispatch loop (O(log Q) steady
                    // state instead of a per-round view rebuild).
                    let (pick, est) = batcher
                        .select_indexed(p.sched, now, estimator.generation(), |shape| {
                            estimator.estimate(shape)
                        })
                        .unwrap();
                    (batcher.take(pick), est)
                }
                LivePolicy::Vanilla { fixed_batch } => {
                    if fifo.is_empty() {
                        break;
                    }
                    let take = (*fixed_batch as usize).min(fifo.len());
                    let mut reqs = Vec::with_capacity(take);
                    for _ in 0..take {
                        let i = fifo.pop_front().unwrap();
                        reqs.push(PredictedRequest {
                            meta: store.meta(i),
                            predicted_gen_len: 0,
                        });
                    }
                    let mut it = reqs.into_iter();
                    let mut b =
                        Batch::new(next_batch_id_vanilla, it.next().unwrap(), now);
                    next_batch_id_vanilla += 1;
                    b.requests.extend(it);
                    (b, 0.0)
                }
            };
            let w = idle.pop().unwrap();
            batch_txs[w].send((batch, est)).expect("worker channel closed");
        }

        // 3. Wait for the next completion or the next arrival deadline.
        let timeout = if next_arrival < store.len() {
            let due = store.meta(next_arrival).arrival / scale;
            let elapsed = start.elapsed().as_secs_f64();
            Duration::from_secs_f64((due - elapsed).max(0.0).min(0.050))
        } else {
            Duration::from_millis(50)
        };
        match done_rx.recv_timeout(timeout) {
            Ok(WorkerMsg::Done {
                worker,
                batch,
                est,
                outcome,
            }) => {
                let now = now_replayed(start);
                if let BatchOutcome::Completed {
                    serving_time,
                    per_request,
                } = outcome
                {
                    completed += per_request.len();
                    for (pr, sr) in batch.requests.iter().zip(&per_request) {
                        metrics.record(RequestRecord {
                            request_id: sr.request_id,
                            arrival: pr.meta.arrival,
                            finish: now,
                            valid_tokens: sr.valid_tokens,
                            invalid_tokens: sr.invalid_tokens,
                        });
                        db.log_request(RequestLog {
                            meta: pr.meta,
                            predicted_gen_len: pr.predicted_gen_len,
                            actual_gen_len: pr.meta.gen_len,
                            at: now,
                        });
                    }
                    db.log_batch(BatchLog {
                        shape: batch.true_shape(),
                        estimated_time: est,
                        // serving_time is wall seconds; scale into replayed
                        // seconds so HRRN compares like with like.
                        actual_time: serving_time * scale,
                        at: now,
                    });
                    // Online estimator refresh from real executions:
                    // absorb only the log tail since the last refresh
                    // (KNN appends are equivalent to a fresh fit on the
                    // union — property-tested in estimator::knn).  Rows
                    // accumulate until the 3-row cold-start threshold.
                    est_cursor += db.visit_batches_from(est_cursor, |l| {
                        est_new_shapes.push(l.shape);
                        est_new_times.push(l.actual_time);
                    });
                    if estimator.is_trained() || est_new_shapes.len() >= 3 {
                        estimator.augment_and_refit(&est_new_shapes, &est_new_times);
                        est_new_shapes.clear();
                        est_new_times.clear();
                    }
                }
                idle.push(worker);
            }
            Ok(WorkerMsg::Failed { worker, error }) => {
                anyhow::bail!("worker {worker} failed: {error}");
            }
            Ok(WorkerMsg::Ready { .. }) => {}
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                anyhow::bail!("all workers exited early");
            }
        }
    }

    drop(batch_txs);
    for h in handles {
        let _ = h.join();
    }
    Ok(metrics)
}

/// Lightweight manifest probe (avoids holding a PJRT client on the leader).
struct PjrtBatchServerProbe {
    max_batch: usize,
    l_max: usize,
    delta: u64,
}

impl PjrtBatchServerProbe {
    fn load(dir: &str) -> Result<Self> {
        let m = crate::runtime::Manifest::load(dir)?;
        Ok(PjrtBatchServerProbe {
            max_batch: m.max_batch(),
            l_max: m.model.l_max,
            delta: m.model.kv_bytes_per_token,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::Variant;
    use crate::workload::dataset::build_predictor_split;
    use crate::workload::{generate_trace, LlmProfile, TraceSpec};

    fn have_artifacts() -> bool {
        std::path::Path::new("artifacts/manifest.json").exists()
    }

    /// End-to-end: real PJRT compute under the full Magnus pipeline.
    #[test]
    fn live_magnus_serves_small_trace() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts/ not built");
            return;
        }
        let mut cfg = ServingConfig::default();
        cfg.gpu.g_max = 24;
        let trace = generate_trace(&TraceSpec {
            rate: 2.0,
            n_requests: 10,
            g_max: 24,
            l_cap: 40,
            seed: 5,
            ..Default::default()
        });
        let split = build_predictor_split(LlmProfile::ChatGlm6B, 40, 5, 24, 6);
        let mut p = GenLenPredictor::new(Variant::Usin, &cfg);
        p.train(&split.train);
        let metrics = serve_trace(
            &cfg,
            &ServeOptions {
                n_workers: 1,
                time_scale: 20.0,
                ..Default::default()
            },
            LivePolicy::Magnus(MagnusPolicy::magnus()),
            Some(p),
            &trace,
        )
        .unwrap();
        assert_eq!(metrics.records.len(), 10);
        assert!(metrics.records.iter().all(|r| r.finish >= r.arrival));
    }

    #[test]
    fn live_vanilla_serves_small_trace() {
        if !have_artifacts() {
            return;
        }
        let cfg = ServingConfig::default();
        let trace = generate_trace(&TraceSpec {
            rate: 3.0,
            n_requests: 8,
            g_max: 16,
            l_cap: 30,
            seed: 7,
            ..Default::default()
        });
        let metrics = serve_trace(
            &cfg,
            &ServeOptions {
                n_workers: 1,
                time_scale: 20.0,
                ..Default::default()
            },
            LivePolicy::Vanilla { fixed_batch: 4 },
            None,
            &trace,
        )
        .unwrap();
        assert_eq!(metrics.records.len(), 8);
    }
}
