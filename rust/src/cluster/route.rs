//! Prediction-aware request routing for the M-instance cluster (ISSUE 8).
//!
//! The router places each admitted request on one logical engine instance
//! using its *predicted* generation length — the same signal Magnus uses
//! for batching (PAPER §III-B) pushed one layer up, in the spirit of
//! length-aware slice scheduling (arXiv:2406.13511).  All policies are
//! deterministic functions of `(policy state, request id, node loads)` so
//! cluster runs replay bit-identically under a fixed seed.
//!
//! Policies only ever see [`NodeLoad`] snapshots — queued work plus
//! in-flight predicted tokens — never engine internals, so the same trait
//! object drives both the discrete-event sim and the live threaded path.

/// The routing-visible identity of one admitted request.
#[derive(Debug, Clone, Copy)]
pub struct RouteRequest {
    /// Stable request id (ties fault hashes and the cluster ledger).
    pub id: u64,
    /// Predicted generation length (tokens) from the shared predictor.
    pub predicted: u32,
    /// Prediction confidence in `[0, 1]` (ISSUE 9): the predictor's
    /// modal-bucket vote share, or `1.0` when the pipeline runs
    /// point-estimate-only — every policy that ignores it behaves
    /// exactly as before.
    pub confidence: f32,
    /// Home instance for sharded traces (ISSUE 10): the index of the
    /// shard — and therefore the node whose arena already holds this
    /// request's bytes — when the trace is sharded one-per-node, `None`
    /// otherwise.  Only [`ShardAffinity`] consults it; every other
    /// policy ignores it and behaves exactly as before.
    pub home: Option<usize>,
}

/// Router-visible load snapshot for one logical instance.
#[derive(Debug, Clone, Copy, Default)]
pub struct NodeLoad {
    /// False once the health checker has declared the instance Dead
    /// (Suspect instances still receive traffic until declared).
    pub alive: bool,
    /// Requests sitting in the instance's adaptive-batcher queue.
    pub queued_requests: usize,
    /// Sum of predicted generation lengths over queued + in-flight
    /// requests — the "predicted-token load" the paper's length signal
    /// makes visible to placement.
    pub backlog_tokens: u64,
}

/// One placement policy behind the cluster router.  `route` returns the
/// chosen instance index, or `None` when no listed instance is alive
/// (the router then sheds the request explicitly).
pub trait RoutePolicy: Send {
    fn name(&self) -> &'static str;
    fn route(&mut self, req: &RouteRequest, loads: &[NodeLoad]) -> Option<usize>;
}

/// Baseline: rotate over instances, skipping dead ones.  Ignores the
/// prediction entirely — the control every prediction-aware policy must
/// beat on goodput or p99 (ISSUE 8 acceptance).
#[derive(Debug, Default)]
pub struct RoundRobin {
    cursor: usize,
}

impl RoutePolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(&mut self, _req: &RouteRequest, loads: &[NodeLoad]) -> Option<usize> {
        let m = loads.len();
        for _ in 0..m {
            let i = self.cursor % m;
            self.cursor = (self.cursor + 1) % m;
            if loads[i].alive {
                return Some(i);
            }
        }
        None
    }
}

/// Join-shortest-predicted-queue: argmin over alive instances of
/// predicted backlog tokens (ties → fewer queued requests → lowest
/// index).  The predicted-token metric is what distinguishes this from
/// classic JSQ: a queue of 3 long-generation requests loses to a queue
/// of 5 short ones.
#[derive(Debug, Default)]
pub struct JoinShortestPredictedQueue;

impl RoutePolicy for JoinShortestPredictedQueue {
    fn name(&self) -> &'static str {
        "jspq"
    }

    fn route(&mut self, _req: &RouteRequest, loads: &[NodeLoad]) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, l) in loads.iter().enumerate() {
            if !l.alive {
                continue;
            }
            best = Some(match best {
                None => i,
                Some(b) => {
                    let cur = (loads[b].backlog_tokens, loads[b].queued_requests);
                    let cand = (l.backlog_tokens, l.queued_requests);
                    if cand < cur {
                        i
                    } else {
                        b
                    }
                }
            });
        }
        best
    }
}

/// splitmix64 finalizer — same stateless-hash construction the fault
/// plan uses, kept local so routing draws never perturb fault draws.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Power-of-two-choices over predicted-token load: two stateless draws
/// keyed on `(seed, request id)` pick candidate instances; the lighter
/// predicted backlog wins (ties → lower index).  Stateless draws keep
/// replay bit-identical regardless of arrival interleaving.
#[derive(Debug)]
pub struct PowerOfTwoChoices {
    pub seed: u64,
}

impl RoutePolicy for PowerOfTwoChoices {
    fn name(&self) -> &'static str {
        "p2c"
    }

    fn route(&mut self, req: &RouteRequest, loads: &[NodeLoad]) -> Option<usize> {
        let alive: Vec<usize> = (0..loads.len()).filter(|&i| loads[i].alive).collect();
        match alive.len() {
            0 => None,
            1 => Some(alive[0]),
            n => {
                let a = alive[(mix64(self.seed ^ req.id.wrapping_mul(0xa24b_aed4_963e_e407)) % n as u64) as usize];
                let b = alive[(mix64(self.seed ^ req.id.wrapping_mul(0x9fb2_1c65_1e98_df25).wrapping_add(1)) % n as u64) as usize];
                let (la, lb) = (loads[a].backlog_tokens, loads[b].backlog_tokens);
                if lb < la || (lb == la && b < a) {
                    Some(b)
                } else {
                    Some(a)
                }
            }
        }
    }
}

/// Length-partitioned placement (slice scheduling, arXiv:2406.13511):
/// the predicted-length range `[0, g_max]` is split into equal bands,
/// one per alive instance, so short requests never queue behind long
/// ones on the same node.  Band index maps onto alive instances in
/// index order; dead instances shrink the band set.
#[derive(Debug)]
pub struct LengthPartitioned {
    pub g_max: u32,
    /// Confidence spillover threshold (ISSUE 9): a request whose
    /// prediction confidence is *below* this is banded by length only
    /// nominally — its true length is anyone's guess, so it routes to
    /// the spillover band (the last alive instance, which also hosts the
    /// longest nominal band and therefore already absorbs overruns).
    /// `0.0` (the default) never spills — confidence lives in `[0, 1]`
    /// — keeping the pre-ISSUE-9 banding bit-identical.
    pub spill_threshold: f32,
}

impl RoutePolicy for LengthPartitioned {
    fn name(&self) -> &'static str {
        "length-partitioned"
    }

    fn route(&mut self, req: &RouteRequest, loads: &[NodeLoad]) -> Option<usize> {
        let alive: Vec<usize> = (0..loads.len()).filter(|&i| loads[i].alive).collect();
        if alive.is_empty() {
            return None;
        }
        if req.confidence < self.spill_threshold {
            return Some(alive[alive.len() - 1]);
        }
        let span = u64::from(self.g_max) + 1;
        let band = (u64::from(req.predicted.min(self.g_max)) * alive.len() as u64) / span;
        Some(alive[(band as usize).min(alive.len() - 1)])
    }
}

/// Shard-affinity placement (ISSUE 10): send each request to the node
/// that maps its trace shard — the only node whose arena can resolve
/// the request's text without cross-node traffic — falling back to
/// join-shortest-predicted-queue when the home node is dead or the
/// request carries no home (unsharded traces, failover re-routes).
/// With every node alive and a one-shard-per-node trace this is a pure
/// static map, so placement is trivially deterministic.
#[derive(Debug, Default)]
pub struct ShardAffinity;

impl RoutePolicy for ShardAffinity {
    fn name(&self) -> &'static str {
        "shard-affinity"
    }

    fn route(&mut self, req: &RouteRequest, loads: &[NodeLoad]) -> Option<usize> {
        if let Some(h) = req.home {
            if loads.get(h).is_some_and(|l| l.alive) {
                return Some(h);
            }
        }
        JoinShortestPredictedQueue.route(req, loads)
    }
}

/// Canonical policy names, in bench/CLI order.
pub const ROUTE_POLICY_NAMES: [&str; 5] = ["rr", "jspq", "p2c", "band", "shard"];

/// Parse a CLI/bench policy name into a boxed policy.  `seed` salts the
/// p2c draws; `g_max` bounds the length-partitioned bands.
pub fn parse_route_policy(name: &str, seed: u64, g_max: u32) -> Option<Box<dyn RoutePolicy>> {
    match name {
        "rr" | "round-robin" => Some(Box::new(RoundRobin::default())),
        "jspq" | "jsq" | "shortest" => Some(Box::new(JoinShortestPredictedQueue)),
        "p2c" | "power2" => Some(Box::new(PowerOfTwoChoices { seed })),
        "band" | "length" | "slice" => Some(Box::new(LengthPartitioned {
            g_max,
            spill_threshold: 0.0,
        })),
        // Uncertainty-aware banding: low-confidence requests spill to the
        // last (longest) band instead of trusting their point estimate.
        "bandu" | "band-spill" => Some(Box::new(LengthPartitioned {
            g_max,
            spill_threshold: 0.55,
        })),
        "shard" | "shard-affinity" | "affinity" => Some(Box::new(ShardAffinity)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loads(spec: &[(bool, u64)]) -> Vec<NodeLoad> {
        spec.iter()
            .map(|&(alive, backlog_tokens)| NodeLoad {
                alive,
                queued_requests: backlog_tokens as usize,
                backlog_tokens,
            })
            .collect()
    }

    fn req(id: u64, predicted: u32) -> RouteRequest {
        RouteRequest {
            id,
            predicted,
            confidence: 1.0,
            home: None,
        }
    }

    #[test]
    fn round_robin_rotates_and_skips_dead() {
        let mut rr = RoundRobin::default();
        let l = loads(&[(true, 0), (false, 0), (true, 0)]);
        assert_eq!(rr.route(&req(1, 10), &l), Some(0));
        assert_eq!(rr.route(&req(2, 10), &l), Some(2));
        assert_eq!(rr.route(&req(3, 10), &l), Some(0));
        let dead = loads(&[(false, 0), (false, 0)]);
        assert_eq!(rr.route(&req(4, 10), &dead), None);
    }

    #[test]
    fn jspq_prefers_lightest_predicted_backlog() {
        let mut p = JoinShortestPredictedQueue;
        let l = loads(&[(true, 90), (true, 40), (true, 40), (false, 0)]);
        // 1 and 2 tie on backlog and queued — lowest index wins.
        assert_eq!(p.route(&req(1, 10), &l), Some(1));
        assert_eq!(p.route(&req(2, 10), &loads(&[(false, 0), (true, 7)])), Some(1));
        assert_eq!(p.route(&req(3, 10), &loads(&[(false, 0)])), None);
    }

    #[test]
    fn p2c_is_deterministic_and_respects_liveness() {
        let mut p = PowerOfTwoChoices { seed: 42 };
        let l = loads(&[(true, 10), (true, 20), (true, 30), (true, 5)]);
        let first = p.route(&req(7, 10), &l);
        for _ in 0..5 {
            assert_eq!(p.route(&req(7, 10), &l), first, "stateless draws replay");
        }
        // Single alive instance short-circuits.
        assert_eq!(p.route(&req(7, 10), &loads(&[(false, 0), (true, 9)])), Some(1));
        assert_eq!(p.route(&req(7, 10), &loads(&[(false, 0)])), None);
        // The chosen node is never the heavier of the two candidates:
        // with every node dead except the lightest two, it picks one of them.
        let skew = loads(&[(true, 0), (true, 1_000_000)]);
        for id in 0..64 {
            let got = p.route(&req(id, 10), &skew).unwrap();
            assert!(got < 2);
        }
    }

    #[test]
    fn length_partitioned_bands_split_short_from_long() {
        let mut p = LengthPartitioned {
            g_max: 64,
            spill_threshold: 0.0,
        };
        let l = loads(&[(true, 0), (true, 0), (true, 0), (true, 0)]);
        assert_eq!(p.route(&req(1, 0), &l), Some(0));
        assert_eq!(p.route(&req(2, 16), &l), Some(0));
        assert_eq!(p.route(&req(3, 17), &l), Some(1));
        assert_eq!(p.route(&req(4, 64), &l), Some(3));
        // predictions above g_max clamp into the top band
        assert_eq!(p.route(&req(5, 10_000), &l), Some(3));
        // dead nodes shrink the band set: two alive → two bands
        let l2 = loads(&[(true, 0), (false, 0), (true, 0), (false, 0)]);
        assert_eq!(p.route(&req(6, 10), &l2), Some(0));
        assert_eq!(p.route(&req(7, 60), &l2), Some(2));
    }

    #[test]
    fn low_confidence_spills_to_the_last_band() {
        let mut p = LengthPartitioned {
            g_max: 64,
            spill_threshold: 0.5,
        };
        let l = loads(&[(true, 0), (true, 0), (true, 0), (true, 0)]);
        // Confident short request: banded normally.
        assert_eq!(p.route(&req(1, 10), &l), Some(0));
        // Uncertain short request: spills to the last alive instance.
        let uncertain = RouteRequest {
            id: 2,
            predicted: 10,
            confidence: 0.2,
            home: None,
        };
        assert_eq!(p.route(&uncertain, &l), Some(3));
        // Dead tail: the spillover band tracks aliveness.
        let l2 = loads(&[(true, 0), (true, 0), (false, 0), (false, 0)]);
        assert_eq!(p.route(&uncertain, &l2), Some(1));
        // Threshold 0.0 never spills (confidence is non-negative), so the
        // default construction replays pre-confidence banding exactly.
        let mut off = LengthPartitioned {
            g_max: 64,
            spill_threshold: 0.0,
        };
        let zero_conf = RouteRequest {
            id: 3,
            predicted: 10,
            confidence: 0.0,
            home: None,
        };
        assert_eq!(off.route(&zero_conf, &l), Some(0));
    }

    #[test]
    fn shard_affinity_honors_home_and_falls_back_when_dead() {
        let mut p = ShardAffinity;
        let l = loads(&[(true, 90), (true, 40), (true, 10)]);
        // Home node alive → routed there regardless of load.
        let homed = RouteRequest {
            home: Some(0),
            ..req(1, 10)
        };
        assert_eq!(p.route(&homed, &l), Some(0));
        // Home node dead → least predicted backlog among the alive.
        let l2 = loads(&[(false, 0), (true, 40), (true, 10)]);
        assert_eq!(p.route(&homed, &l2), Some(2));
        // No home (unsharded trace, failover re-route) → pure jspq.
        assert_eq!(p.route(&req(2, 10), &l), Some(2));
        // Out-of-range home never panics; it falls back.
        let stray = RouteRequest {
            home: Some(9),
            ..req(3, 10)
        };
        assert_eq!(p.route(&stray, &l), Some(2));
        assert_eq!(p.route(&homed, &loads(&[(false, 0)])), None);
    }

    #[test]
    fn parse_covers_every_policy_name() {
        for name in ROUTE_POLICY_NAMES {
            let p = parse_route_policy(name, 1, 64).unwrap();
            assert!(!p.name().is_empty());
        }
        for name in ["bandu", "band-spill"] {
            assert!(parse_route_policy(name, 1, 64).is_some(), "{name}");
        }
        assert!(parse_route_policy("nope", 1, 64).is_none());
    }
}
