//! Live threaded cluster path (ISSUE 8): M supervised serving cores
//! (`server::serve_ingress_sim` — real threads, channels, wall clock,
//! worker supervision) behind the same prediction-aware router the
//! discrete-event sim uses, with heartbeat health checks against the
//! fault plan's instance windows and failover of in-flight request
//! copies.
//!
//! Semantics vs the sim path:
//! - A kill window cuts the instance's ingress (its job sender is
//!   dropped) once declared Dead; the core drains what it already
//!   admitted and exits.  Requests the router still holds copies of are
//!   re-routed under the failover retry budget — late completions from
//!   the draining core race the re-runs, and the router's terminal set
//!   resolves them first-signal-wins (later ones count as
//!   `duplicate_signals`).
//! - A partition window is handled identically at this layer (ingress
//!   cut + reroute + dedup): the in-process core cannot actually lose
//!   its ack channel, so deferred-ack realism lives in the sim path.
//! - Work stealing is a sim-layer mechanism (it requires reaching into
//!   peer queues, which the supervised cores own); the live router
//!   rebalances only through placement and failover.
//!
//! Exactly-once: `offered == completed + shed + expired` over the
//! router's terminal set, debug-asserted at shutdown (`expired` is 0 —
//! deadline expiry is the edge's axis, not the router's).

use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::{mpsc, Arc};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::cluster::route::{NodeLoad, RoutePolicy, RouteRequest};
use crate::cluster::ClusterOptions;
use crate::config::ServingConfig;
use crate::metrics::RunMetrics;
use crate::server::{serve_ingress_sim, CoreSignal, EdgeJob, LivePolicy, ServeOptions};
use crate::util::clamped_duration;
use crate::workload::TraceStore;

/// Router-side outcome of a live cluster run.
#[derive(Debug)]
pub struct ClusterReport {
    pub offered: u64,
    pub completed: u64,
    pub shed: u64,
    /// Always 0 here (deadline expiry is the edge's axis); kept so the
    /// ledger identity reads the same everywhere.
    pub expired: u64,
    /// Terminal signals for already-resolved ids (zombie-core drains
    /// racing failover re-runs).
    pub duplicate_signals: u64,
    /// Request copies re-routed by failover.
    pub reroutes: u64,
    /// Dead declarations.
    pub failovers: u32,
    /// Fresh cores spawned after a fault window closed.
    pub respawns: u32,
    /// Core incarnations that returned an error instead of metrics.
    pub core_failures: u32,
    /// Final metrics of every core incarnation, in spawn order.
    pub per_core: Vec<RunMetrics>,
}

impl ClusterReport {
    /// Does the exactly-once ledger close?
    pub fn accounted(&self) -> bool {
        self.offered == self.completed + self.shed + self.expired
    }
}

/// One live instance slot as the router sees it.
struct Instance {
    /// `None` once the instance is Dead (ingress cut) — also how
    /// liveness is surfaced to the routing policies.
    sender: Option<mpsc::Sender<EdgeJob>>,
    /// Router-side copies of requests admitted to this incarnation.
    in_flight: BTreeMap<u64, EdgeJob>,
    misses: u32,
    declared_dead: bool,
}

fn clone_opts(o: &ServeOptions) -> ServeOptions {
    ServeOptions {
        artifacts_dir: o.artifacts_dir.clone(),
        n_workers: o.n_workers,
        time_scale: o.time_scale,
        warm_up: o.warm_up,
        fault_plan: o.fault_plan.clone(),
    }
}

/// Spawn one serving core plus its signal forwarder; returns the job
/// sender and both join handles.
#[allow(clippy::type_complexity)]
fn spawn_core(
    i: usize,
    cfg: &ServingConfig,
    opts: &ServeOptions,
    make_policy: &dyn Fn() -> LivePolicy,
    merged_tx: &mpsc::Sender<(usize, CoreSignal)>,
    store: &Arc<TraceStore>,
) -> (
    mpsc::Sender<EdgeJob>,
    JoinHandle<Result<RunMetrics>>,
    JoinHandle<()>,
) {
    let (jtx, jrx) = mpsc::channel::<EdgeJob>();
    let (stx, srx) = mpsc::channel::<CoreSignal>();
    let (cfg_c, opts_c, store_c) = (cfg.clone(), clone_opts(opts), Arc::clone(store));
    let policy = make_policy();
    let core = thread::spawn(move || serve_ingress_sim(&cfg_c, &opts_c, policy, jrx, stx, store_c));
    let fwd_tx = merged_tx.clone();
    let fwd = thread::spawn(move || {
        for sig in srx.iter() {
            if fwd_tx.send((i, sig)).is_err() {
                break;
            }
        }
    });
    (jtx, core, fwd)
}

/// Serve live-ingress jobs over an M-core cluster.  `jobs` closing means
/// "no more traffic"; every offered job resolves to exactly one
/// `CoreSignal` on `signals`.
///
/// `stores` maps trace storage onto cores: one entry shares a single
/// store across every core (the pre-sharding behaviour); exactly M
/// entries give each core its own shard (ISSUE 10), and the router
/// exposes each job's home shard to the policy via
/// [`RouteRequest::home`].  Sharded mapping assumes an engine that
/// never resolves request text from a foreign core's arena — the cost
/// engine ignores the store entirely, so failover and re-routing stay
/// safe; text-resolving engines must use the single-store mapping.
#[allow(clippy::too_many_arguments)]
pub fn serve_cluster_ingress_sim(
    cfg: &ServingConfig,
    opts: &ServeOptions,
    copts: &ClusterOptions,
    make_policy: &dyn Fn() -> LivePolicy,
    route_policy: &mut dyn RoutePolicy,
    jobs: mpsc::Receiver<EdgeJob>,
    signals: mpsc::Sender<CoreSignal>,
    stores: Vec<Arc<TraceStore>>,
) -> Result<ClusterReport> {
    let m = copts.n_nodes.max(1);
    assert!(
        stores.len() == 1 || stores.len() == m,
        "stores must be one shared store or exactly one per core \
         ({} stores for {m} cores)",
        stores.len()
    );
    let sharded = stores.len() == m && m > 1;
    let store_for = |i: usize| -> &Arc<TraceStore> {
        if stores.len() == m {
            &stores[i]
        } else {
            &stores[0]
        }
    };
    let plan = opts.fault_plan.clone();
    let time_scale = opts.time_scale.max(1e-9);

    let (merged_tx, merged_rx) = mpsc::channel::<(usize, CoreSignal)>();
    let mut merged_master = Some(merged_tx);

    let mut instances: Vec<Instance> = Vec::with_capacity(m);
    let mut cores: Vec<JoinHandle<Result<RunMetrics>>> = Vec::new();
    let mut forwarders: Vec<JoinHandle<()>> = Vec::new();
    for i in 0..m {
        let (jtx, core, fwd) = spawn_core(
            i,
            cfg,
            opts,
            make_policy,
            merged_master.as_ref().unwrap(),
            store_for(i),
        );
        instances.push(Instance {
            sender: Some(jtx),
            in_flight: BTreeMap::new(),
            misses: 0,
            declared_dead: false,
        });
        cores.push(core);
        forwarders.push(fwd);
    }

    let mut terminal: HashSet<u64> = HashSet::new();
    let mut failover_attempts: HashMap<u64, u32> = HashMap::new();
    // Request copies orphaned by an instance death (heartbeat Dead
    // declaration, or a send failure discovering the core exited) that
    // still need a failover decision: reroute under the retry budget or
    // explicit shed.
    let mut pending_failover: std::collections::VecDeque<EdgeJob> = Default::default();
    let (mut offered, mut completed, mut shed) = (0u64, 0u64, 0u64);
    let (mut duplicate_signals, mut reroutes) = (0u64, 0u64);
    let (mut failovers, mut respawns, mut core_failures) = (0u32, 0u32, 0u32);

    let start = Instant::now();
    // Heartbeat period in wall seconds: the plan's windows live in
    // replayed (trace) time, which runs `time_scale`× wall time.  The
    // shared clamp helper keeps a degenerate interval from panicking
    // (ISSUE 8 satellite: `util::clamped_duration` in the cluster loop);
    // the upper bound keeps `Instant + wall_hb` from overflowing when
    // the helper saturates a huge/inf interval to `Duration::MAX`.
    let wall_hb = clamped_duration(copts.hb_interval_s / time_scale)
        .clamp(Duration::from_millis(5), Duration::from_secs(3600));
    let poll = Duration::from_millis(2).min(wall_hb);
    let mut next_hb = start + wall_hb;
    let mut jobs_open = true;

    macro_rules! resolve {
        ($id:expr, $sig:expr, $ctr:ident) => {
            if terminal.insert($id) {
                $ctr += 1;
                let _ = signals.send($sig);
            } else {
                duplicate_signals += 1;
            }
        };
    }

    // Route one job copy; on send failure the target is marked dead and
    // routing retries over the survivors.
    macro_rules! place {
        ($job:expr) => {{
            let job: EdgeJob = $job;
            let id = job.meta.id;
            loop {
                let loads: Vec<NodeLoad> = instances
                    .iter()
                    .map(|inst| NodeLoad {
                        alive: inst.sender.is_some(),
                        queued_requests: inst.in_flight.len(),
                        backlog_tokens: inst
                            .in_flight
                            .values()
                            .map(|j| u64::from(j.predicted_gen_len))
                            .sum(),
                    })
                    .collect();
                // One-shard-per-core mapping: the job's minting store
                // identifies its home core.  Guarded on `sharded` so a
                // single shared store never reports a constant home.
                let home = if sharded {
                    stores.iter().position(|s| s.id() == job.meta.store)
                } else {
                    None
                };
                let req = RouteRequest {
                    id,
                    predicted: job.predicted_gen_len,
                    confidence: 1.0,
                    home,
                };
                match route_policy.route(&req, &loads) {
                    Some(j) => {
                        let ok = instances[j]
                            .sender
                            .as_ref()
                            .map_or(false, |tx| tx.send(job).is_ok());
                        if ok {
                            instances[j].in_flight.insert(id, job);
                            break true;
                        }
                        // The core exited under us: cut its ingress,
                        // queue its in-flight copies for failover (the
                        // caller drains them under the retry budget),
                        // and let routing retry over the survivors.
                        instances[j].sender = None;
                        let stranded = std::mem::take(&mut instances[j].in_flight);
                        pending_failover.extend(stranded.into_values());
                    }
                    None => {
                        resolve!(id, CoreSignal::Shed { request_id: id }, shed);
                        break false;
                    }
                }
            }
        }};
    }

    // Failover every orphaned copy: reroute under the retry budget,
    // then explicit shed.  Placement can discover further dead cores
    // and push more orphans, so loop until the queue is dry.
    macro_rules! drain_failover {
        () => {
            while let Some(job) = pending_failover.pop_front() {
                let id = job.meta.id;
                if terminal.contains(&id) {
                    continue;
                }
                let fa = failover_attempts.entry(id).or_insert(0);
                *fa += 1;
                if *fa > plan.max_retries {
                    resolve!(id, CoreSignal::Shed { request_id: id }, shed);
                    continue;
                }
                if place!(job) {
                    reroutes += 1;
                }
            }
        };
    }

    loop {
        if jobs_open {
            match jobs.recv_timeout(poll) {
                Ok(job) => {
                    offered += 1;
                    place!(job);
                    drain_failover!();
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    jobs_open = false;
                    // Close every ingress so the cores drain and exit;
                    // drop our master signal sender so the merged
                    // channel disconnects once the forwarders finish.
                    for inst in instances.iter_mut() {
                        inst.sender = None;
                    }
                    merged_master = None;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
            }
        } else {
            match merged_rx.recv_timeout(poll) {
                Ok((i, sig)) => handle_signal(
                    i,
                    sig,
                    &mut instances,
                    &mut terminal,
                    &mut completed,
                    &mut shed,
                    &mut duplicate_signals,
                    &signals,
                ),
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
                Err(mpsc::RecvTimeoutError::Timeout) => {}
            }
        }
        while let Ok((i, sig)) = merged_rx.try_recv() {
            handle_signal(
                i,
                sig,
                &mut instances,
                &mut terminal,
                &mut completed,
                &mut shed,
                &mut duplicate_signals,
                &signals,
            );
        }

        // Heartbeat health checks, in replayed time, while admitting.
        let now = Instant::now();
        if jobs_open && now >= next_hb {
            // Catch up past `now` in one step: after a stall (long
            // placement, scheduler hiccup) firing the backlog of probes
            // back-to-back would accumulate misses faster than one per
            // `hb_interval_s` and declare Dead earlier than
            // `suspect_after * hb_interval_s` implies.
            while next_hb <= now {
                next_hb += wall_hb;
            }
            let t = start.elapsed().as_secs_f64() * time_scale;
            for i in 0..m {
                let miss = plan.instance_dead(i, t) || plan.instance_partitioned(i, t);
                if miss {
                    instances[i].misses += 1;
                    if !instances[i].declared_dead && instances[i].misses >= copts.suspect_after {
                        instances[i].declared_dead = true;
                        failovers += 1;
                        instances[i].sender = None;
                        let inflight = std::mem::take(&mut instances[i].in_flight);
                        pending_failover.extend(inflight.into_values());
                        drain_failover!();
                    }
                } else {
                    if instances[i].declared_dead {
                        // Window over: bring a fresh incarnation up.
                        instances[i].declared_dead = false;
                        respawns += 1;
                        let (jtx, core, fwd) = spawn_core(
                            i,
                            cfg,
                            opts,
                            make_policy,
                            merged_master.as_ref().expect("admitting implies master"),
                            store_for(i),
                        );
                        instances[i].sender = Some(jtx);
                        cores.push(core);
                        forwarders.push(fwd);
                    }
                    instances[i].misses = 0;
                }
            }
        }
    }

    // The merged channel is closed: every core exited and every signal
    // was delivered.  Anything still untracked resolves as shed so the
    // ledger closes even if a core died without signalling.
    let leftover: Vec<u64> = instances
        .iter()
        .flat_map(|inst| inst.in_flight.keys().copied())
        .chain(pending_failover.iter().map(|j| j.meta.id))
        .collect();
    for id in leftover {
        resolve!(id, CoreSignal::Shed { request_id: id }, shed);
    }

    let mut per_core = Vec::new();
    for core in cores {
        match core.join() {
            Ok(Ok(metrics)) => per_core.push(metrics),
            _ => core_failures += 1,
        }
    }
    for fwd in forwarders {
        let _ = fwd.join();
    }

    debug_assert_eq!(
        offered,
        completed + shed,
        "live cluster exactly-once ledger must close: every offered job \
         resolves to exactly one terminal signal"
    );
    Ok(ClusterReport {
        offered,
        completed,
        shed,
        expired: 0,
        duplicate_signals,
        reroutes,
        failovers,
        respawns,
        core_failures,
        per_core,
    })
}

/// Resolve one core signal against the router's terminal set: the first
/// terminal wins and is forwarded to the edge; later ones are counted
/// and swallowed.
#[allow(clippy::too_many_arguments)]
fn handle_signal(
    i: usize,
    sig: CoreSignal,
    instances: &mut [Instance],
    terminal: &mut HashSet<u64>,
    completed: &mut u64,
    shed: &mut u64,
    duplicate_signals: &mut u64,
    signals: &mpsc::Sender<CoreSignal>,
) {
    let id = match sig {
        CoreSignal::Completed { request_id, .. } | CoreSignal::Shed { request_id } => request_id,
    };
    instances[i].in_flight.remove(&id);
    if terminal.insert(id) {
        match sig {
            CoreSignal::Completed { .. } => *completed += 1,
            CoreSignal::Shed { .. } => *shed += 1,
        }
        let _ = signals.send(sig);
    } else {
        *duplicate_signals += 1;
    }
}
