//! Discrete-event cluster simulator (ISSUE 8): M replicas of the
//! single-instance Magnus event loop (`sim::run_magnus_store_faulted`)
//! behind a prediction-aware router, with heartbeat health checks,
//! kill/partition failover, slow-instance stall scaling and mispredict-
//! imbalance work stealing.
//!
//! Determinism contract: every run is a pure function of `(cfg, policy,
//! predictor, store, plan, options, routing policy)` — fault draws are
//! stateless hashes, routing draws are stateless hashes, leader-side
//! in-flight copies live in a `BTreeMap` so failover drains in slot
//! order, and the event queue breaks time ties by insertion sequence.
//! Replays are bit-identical.
//!
//! M=1 reduction: with one node and a plan carrying no instance-level
//! axes, the router degenerates to a constant, no heartbeats are
//! scheduled, work stealing has no peers, and the per-node loop executes
//! the exact event sequence of the single-instance core — outputs are
//! bit-for-bit identical (asserted by `tests/cluster.rs`).

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};

use crate::batch::{AdaptiveBatcher, Batch, BatcherConfig};
use crate::cluster::route::{NodeLoad, RoutePolicy, RouteRequest};
use crate::cluster::{merge_metrics, ClusterLedger, ClusterOptions, DeadCause, Health};
use crate::config::ServingConfig;
use crate::engine::faulty::{FaultyEngine, InjectedOutcome};
use crate::engine::{BatchOutcome, InferenceEngine};
use crate::estimator::ServingTimeEstimator;
use crate::faults::FaultPlan;
use crate::learning::ContinuousLearner;
use crate::logdb::{BatchLog, LogDb, RequestLog};
use crate::metrics::{RequestRecord, RunMetrics};
use crate::predictor::{
    fallback_prediction, predict_degraded, DriftDetector, DriftEvent, GenLenPredictor,
};
use crate::sim::events::EventQueue;
use crate::sim::{MagnusPolicy, OOM_RELOAD_S};
use crate::workload::{PredictedRequest, RequestView, TraceSource};

enum Event {
    Arrival(usize),
    /// A node's engine slot finished serving a batch.  `epoch` is the
    /// node incarnation at dispatch: completions from before a kill
    /// declaration are dropped as stale (their requests were failed
    /// over).
    BatchDone {
        node: usize,
        slot: usize,
        epoch: u32,
        batch: Batch,
        est: f64,
        outcome: BatchOutcome,
    },
    /// An engine slot came back (OOM reload, crash backoff, kill-window
    /// reboot).
    SlotReady { node: usize, slot: usize, epoch: u32 },
    /// Router heartbeat tick: probe every node, walk the Up → Suspect →
    /// Dead machine, fail over / rejoin.  Only scheduled when the plan
    /// carries instance-level axes.
    Heartbeat,
}

/// One logical engine instance: a full replica of the single-instance
/// serving state.
struct Node {
    batcher: AdaptiveBatcher,
    estimator: ServingTimeEstimator,
    learner: ContinuousLearner,
    db: LogDb,
    metrics: RunMetrics,
    est_errors: Vec<(f64, f64)>,
    /// Engine-retry attempt counters (fault-hash salts), per batch id.
    attempts: HashMap<u64, u32>,
    /// Per-slot restart counts (crash backoff exponents).
    slot_restarts: Vec<u32>,
    idle: VecDeque<usize>,
    /// Leader-side copies of batches currently being served, by slot —
    /// what failover re-runs when the node dies mid-serve.  BTreeMap so
    /// draining is slot-ordered (deterministic replay).
    in_flight: BTreeMap<usize, Batch>,
    /// Incarnation counter: bumped when a kill is declared, so stale
    /// completions/slot-returns from the dead incarnation are dropped.
    epoch: u32,
    health: Health,
    misses: u32,
}

impl Node {
    fn new(cfg: &ServingConfig, policy: &MagnusPolicy) -> Node {
        Node {
            batcher: AdaptiveBatcher::new(BatcherConfig {
                wma_threshold: cfg.wma_threshold,
                theta: (cfg.gpu.theta() as f64 * cfg.mem_margin) as u64,
                delta: cfg.gpu.delta_bytes_per_token,
                max_batch_size: policy.max_batch_size,
            }),
            estimator: ServingTimeEstimator::new(cfg.knn_k),
            learner: ContinuousLearner::new(cfg.learning.clone()),
            db: LogDb::new(),
            metrics: RunMetrics::new(),
            est_errors: Vec::new(),
            attempts: HashMap::new(),
            slot_restarts: vec![0; cfg.n_instances],
            idle: (0..cfg.n_instances).collect(),
            in_flight: BTreeMap::new(),
            epoch: 0,
            health: Health::Up,
            misses: 0,
        }
    }

    fn is_declared_dead(&self) -> bool {
        matches!(self.health, Health::Dead(_))
    }
}

/// Per-instance slice of a cluster run's output.
pub struct NodeOutput {
    pub metrics: RunMetrics,
    pub db: LogDb,
    /// (time, |estimated − actual|) per batch served on this instance.
    pub est_errors: Vec<(f64, f64)>,
}

/// Result of a cluster run.  The exactly-once identity
/// `offered == completed + shed + expired` holds under any fault
/// schedule (debug-asserted before returning).
pub struct ClusterOutput {
    pub nodes: Vec<NodeOutput>,
    /// (time, |predicted − actual|) per admitted request, router-side.
    pub pred_errors: Vec<(f64, f64)>,
    /// Requests offered to the router (the whole trace).
    pub offered: usize,
    /// Unique completions across instances.
    pub completed: usize,
    /// Unique explicit sheds (retry budget exhausted, or no instance
    /// alive to take the request).
    pub shed: usize,
    /// Deadline expiries — always 0 in the sim (no deadline axis here);
    /// kept so the ledger identity reads the same as the live path's.
    pub expired: usize,
    /// Terminal signals for already-resolved ids (partition replays).
    pub duplicate_acks: u64,
    /// Work-stealing transfers (batches moved between instances).
    pub steals: u64,
    /// Requests re-routed by failover.
    pub reroutes: u64,
    /// Dead declarations.
    pub failovers: u32,
    /// Dead instances that later rejoined.
    pub rejoins: u32,
    /// Detection latency per failover: heartbeat declaration time minus
    /// fault-window start.
    pub recovery_samples: Vec<f64>,
    /// Admissions predicted by the fallback chain (router-side).
    pub fallback_predictions: u32,
    /// Router-side admissions charged at the upper quantile (ISSUE 9) —
    /// 0 with uncertainty off.
    pub low_confidence_admissions: u32,
    /// Router-side drift-detector demotions — 0 with uncertainty off.
    pub drift_demotions: u32,
    /// Router-side drift-detector re-promotions after probation.
    pub drift_repromotions: u32,
    /// Unique shed request ids, in shed order.
    pub shed_ids: Vec<u64>,
}

impl ClusterOutput {
    /// Cluster-wide collector: per-instance records and counters merged
    /// in instance order plus router-side sheds/fallbacks.  For M=1
    /// this is bit-identical to the single-instance collector.
    pub fn merged_metrics(&self) -> RunMetrics {
        let ms: Vec<RunMetrics> = self.nodes.iter().map(|n| n.metrics.clone()).collect();
        let mut m = merge_metrics(&ms, &self.shed_ids, self.fallback_predictions);
        // Router-side uncertainty counters sit above the per-node
        // collectors (admission and drift live at the router).
        m.low_confidence_admissions += self.low_confidence_admissions;
        m.drift_demotions += self.drift_demotions;
        m.drift_repromotions += self.drift_repromotions;
        m
    }

    /// Does the exactly-once ledger close?
    pub fn accounted(&self) -> bool {
        self.offered == self.completed + self.shed + self.expired
    }

    /// Max per-instance completions over the per-instance mean (1.0 =
    /// perfectly balanced; 0 completions → 1.0).
    pub fn imbalance_ratio(&self) -> f64 {
        if self.completed == 0 || self.nodes.is_empty() {
            return 1.0;
        }
        let max = self
            .nodes
            .iter()
            .map(|n| n.metrics.records.len())
            .max()
            .unwrap_or(0) as f64;
        let mean = self.completed as f64 / self.nodes.len() as f64;
        max / mean
    }

    /// Mean failover detection latency (0.0 when no failover fired).
    pub fn mean_recovery_s(&self) -> f64 {
        if self.recovery_samples.is_empty() {
            0.0
        } else {
            self.recovery_samples.iter().sum::<f64>() / self.recovery_samples.len() as f64
        }
    }
}

/// Instance-stall scaling that stays bit-exact when no window is open
/// (`f == 1.0` must not touch the value).
#[inline]
fn scale(t: f64, f: f64) -> f64 {
    if f == 1.0 {
        t
    } else {
        t * f
    }
}

/// Router-visible load snapshot (queued + in-flight predicted tokens).
fn node_loads(nodes: &[Node]) -> Vec<NodeLoad> {
    nodes
        .iter()
        .map(|nd| {
            let mut tokens = 0u64;
            for b in nd.batcher.queue() {
                for pr in &b.requests {
                    tokens += u64::from(pr.predicted_gen_len);
                }
            }
            for b in nd.in_flight.values() {
                for pr in &b.requests {
                    tokens += u64::from(pr.predicted_gen_len);
                }
            }
            NodeLoad {
                alive: !nd.is_declared_dead(),
                queued_requests: nd.batcher.queued_requests(),
                backlog_tokens: tokens,
            }
        })
        .collect()
}

/// Run the cluster over an interned trace — a single [`TraceStore`] or
/// a sharded one (any [`TraceSource`]).  `route_policy` is consulted
/// once per admitted request (and again per failed-over request copy);
/// sharded traces additionally expose each request's home shard to the
/// policy via [`RouteRequest::home`].
///
/// [`TraceStore`]: crate::workload::TraceStore
#[allow(clippy::too_many_arguments)]
pub fn run_cluster_store<S: TraceSource>(
    cfg: &ServingConfig,
    policy: &MagnusPolicy,
    mut predictor: GenLenPredictor,
    engine: &dyn InferenceEngine,
    store: &S,
    plan: &FaultPlan,
    copts: &ClusterOptions,
    route_policy: &mut dyn RoutePolicy,
) -> ClusterOutput {
    let m = copts.n_nodes.max(1);
    let mut nodes: Vec<Node> = (0..m).map(|_| Node::new(cfg, policy)).collect();
    let faulty = FaultyEngine::new(engine, plan);
    let g_max = cfg.gpu.g_max;
    let ifaults = plan.has_instance_faults();
    let slots_per_node = cfg.n_instances;

    let mut events: EventQueue<Event> = EventQueue::new();
    // Seed arrivals via `arrival(i)` — one 8-byte field per request —
    // so a lazily-opened sharded trace never resolves a record just to
    // schedule it.
    for i in 0..store.len() {
        events.push(store.arrival(i), Event::Arrival(i));
    }
    if ifaults && store.len() > 0 {
        events.push(copts.hb_interval_s, Event::Heartbeat);
    }

    let mut ledger = ClusterLedger::default();
    let mut shed_ids: Vec<u64> = Vec::new();
    let mut failover_attempts: HashMap<u64, u32> = HashMap::new();
    let mut pred_errors: Vec<(f64, f64)> = Vec::new();
    let mut recovery_samples: Vec<f64> = Vec::new();
    let mut fallback_predictions = 0u32;
    let (mut steals, mut reroutes) = (0u64, 0u64);
    let (mut failovers, mut rejoins) = (0u32, 0u32);

    // Uncertainty-aware admission state (ISSUE 9).  All router-side:
    // the drift detector watches signed error on unique completions and
    // demotes the predictor down the fallback chain past its budget.
    let unc = &cfg.uncertainty;
    let mut drift = DriftDetector::new(unc.drift_config());
    let mut low_conf: HashSet<u64> = HashSet::new();
    let mut point_of: HashMap<u64, u32> = HashMap::new();
    let mut low_confidence_admissions = 0u32;
    let (mut drift_demotions, mut drift_repromotions) = (0u32, 0u32);

    // Scratch buffers reused across events.
    let mut arrivals: Vec<usize> = Vec::new();
    let mut arrival_views: Vec<RequestView> = Vec::new();
    let mut preds: Vec<u32> = Vec::new();
    let mut confs: Vec<f32> = Vec::new();

    while let Some((now, ev)) = events.pop() {
        match ev {
            Event::Arrival(i) => {
                // Same-timestamp arrival draining + batched prediction,
                // exactly as the single-instance core does it.
                arrivals.clear();
                arrivals.push(i);
                loop {
                    match events.peek() {
                        Some((t, Event::Arrival(j))) if t == now => {
                            arrivals.push(*j);
                            events.pop();
                        }
                        _ => break,
                    }
                }
                arrival_views.clear();
                arrival_views.extend(arrivals.iter().map(|&k| store.view(k)));
                if unc.enabled {
                    // Uncertainty-aware admission: charge low-confidence
                    // requests their upper-quantile tokens and remember
                    // them so routing can spill and drift can observe.
                    preds.clear();
                    confs.clear();
                    for v in &arrival_views {
                        let outage = plan
                            .predictor_outage(now)
                            .or_else(|| plan.app_outage(v.task.app().index(), now))
                            .or_else(|| drift.active_fallback());
                        if let Some(mode) = outage {
                            let p = fallback_prediction(mode, v.user_input_len, g_max);
                            fallback_predictions += 1;
                            point_of.insert(v.id, p);
                            preds.push(p);
                            confs.push(1.0);
                        } else {
                            let pwc =
                                predictor.predict_with_confidence(*v, unc.upper_quantile as f32);
                            let point = plan.noisy_prediction(
                                plan.drifted_prediction(pwc.point, now, g_max),
                                v.id,
                                g_max,
                            );
                            let low = f64::from(pwc.confidence) < unc.confidence_threshold;
                            let admitted = if low {
                                low_confidence_admissions += 1;
                                low_conf.insert(v.id);
                                point.max(plan.noisy_prediction(
                                    plan.drifted_prediction(pwc.upper_quantile, now, g_max),
                                    v.id,
                                    g_max,
                                ))
                            } else {
                                point
                            };
                            point_of.insert(v.id, point);
                            preds.push(admitted);
                            confs.push(pwc.confidence);
                        }
                    }
                } else if plan.has_predictor_faults() {
                    preds.clear();
                    confs.clear();
                    for v in &arrival_views {
                        let outage = plan
                            .predictor_outage(now)
                            .or_else(|| plan.app_outage(v.task.app().index(), now));
                        let (p, fell_back) = predict_degraded(&mut predictor, outage, v, g_max);
                        if fell_back {
                            fallback_predictions += 1;
                            preds.push(p);
                        } else {
                            preds.push(plan.noisy_prediction(
                                plan.drifted_prediction(p, now, g_max),
                                v.id,
                                g_max,
                            ));
                        }
                        confs.push(1.0);
                    }
                } else {
                    predictor.predict_many_views(&arrival_views, &mut preds);
                    confs.clear();
                    confs.resize(preds.len(), 1.0);
                }
                for (k, &ti) in arrivals.iter().enumerate() {
                    let meta = store.meta(ti);
                    let predicted = preds[k];
                    pred_errors.push((now, (predicted as f64 - meta.gen_len as f64).abs()));
                    let loads = node_loads(&nodes);
                    let req = RouteRequest {
                        id: meta.id,
                        predicted,
                        confidence: confs[k],
                        home: store.home_of(ti),
                    };
                    match route_policy.route(&req, &loads) {
                        Some(j) => {
                            nodes[j].batcher.insert(
                                PredictedRequest {
                                    meta,
                                    predicted_gen_len: predicted,
                                },
                                now,
                            );
                            dispatch_node(
                                now,
                                j,
                                &mut nodes[j],
                                policy,
                                &faulty,
                                plan,
                                ifaults,
                                g_max,
                                &mut events,
                                &mut ledger,
                                &mut shed_ids,
                            );
                        }
                        None => {
                            // No instance alive: shed explicitly at the
                            // router, never silently dropped.
                            if ledger.shed(meta.id) {
                                shed_ids.push(meta.id);
                            }
                        }
                    }
                }
            }
            Event::BatchDone {
                node: n,
                slot,
                epoch,
                batch,
                est,
                outcome,
            } => {
                if ifaults && plan.instance_dead(n, now) {
                    // The instance died mid-serve: the completion is
                    // lost.  Retry/shed locally (short kill windows that
                    // dodge every heartbeat must still resolve); the
                    // slot reboots at window end.  If the death was
                    // already declared (stale epoch), the requests were
                    // failed over and the slots reset at rejoin — drop.
                    if epoch == nodes[n].epoch {
                        nodes[n].in_flight.remove(&slot);
                        retry_or_shed_node(plan, &mut nodes[n], &mut ledger, &mut shed_ids, batch);
                        let end = plan.kill_end(n, now).unwrap_or(now);
                        events.push(end, Event::SlotReady { node: n, slot, epoch });
                    }
                } else if ifaults && plan.instance_partitioned(n, now) {
                    // Partitioned: served but cannot ack — defer the
                    // completion to the partition-window end.  Failover
                    // may re-run these requests elsewhere meanwhile; the
                    // ledger resolves duplicates first-terminal-wins.
                    let end = plan.partition_end(n, now).unwrap_or(now);
                    events.push(
                        end,
                        Event::BatchDone {
                            node: n,
                            slot,
                            epoch,
                            batch,
                            est,
                            outcome,
                        },
                    );
                } else if epoch != nodes[n].epoch {
                    // Stale completion from a killed incarnation: its
                    // requests were failed over at declaration.
                } else {
                    nodes[n].in_flight.remove(&slot);
                    match outcome {
                        BatchOutcome::Completed {
                            serving_time,
                            per_request,
                        } => {
                            for (pr, sr) in batch.requests.iter().zip(&per_request) {
                                if ledger.complete(pr.meta.id) {
                                    if unc.enabled {
                                        let point = point_of
                                            .remove(&pr.meta.id)
                                            .unwrap_or(pr.predicted_gen_len);
                                        low_conf.remove(&pr.meta.id);
                                        match drift.observe(
                                            pr.meta.task.app(),
                                            pr.meta.user_input_len,
                                            f64::from(point) - f64::from(pr.meta.gen_len),
                                        ) {
                                            DriftEvent::Demoted => drift_demotions += 1,
                                            DriftEvent::Repromoted => drift_repromotions += 1,
                                            DriftEvent::None => {}
                                        }
                                    }
                                    nodes[n]
                                        .metrics
                                        .record_prediction(pr.predicted_gen_len, pr.meta.gen_len);
                                    nodes[n].metrics.record(RequestRecord {
                                        request_id: sr.request_id,
                                        arrival: pr.meta.arrival,
                                        finish: now,
                                        valid_tokens: sr.valid_tokens,
                                        invalid_tokens: sr.invalid_tokens,
                                    });
                                    nodes[n].db.log_request(RequestLog {
                                        meta: pr.meta,
                                        predicted_gen_len: pr.predicted_gen_len,
                                        actual_gen_len: pr.meta.gen_len,
                                        at: now,
                                    });
                                }
                            }
                            nodes[n].est_errors.push((now, (est - serving_time).abs()));
                            nodes[n].db.log_batch(BatchLog {
                                shape: batch.true_shape(),
                                estimated_time: est,
                                actual_time: serving_time,
                                at: now,
                            });
                            if policy.use_estimator {
                                let node = &mut nodes[n];
                                node.learner.tick(
                                    now,
                                    &node.db,
                                    &mut predictor,
                                    &mut node.estimator,
                                    store,
                                );
                            }
                        }
                        BatchOutcome::Oom { .. } => {
                            unreachable!("OOM resolved at dispatch")
                        }
                    }
                    nodes[n].idle.push_back(slot);
                }
            }
            Event::SlotReady { node: n, slot, epoch } => {
                if ifaults && plan.instance_dead(n, now) {
                    // Slot return lands inside a kill window: defer to
                    // the reboot at window end.
                    let end = plan.kill_end(n, now).unwrap_or(now);
                    events.push(end, Event::SlotReady { node: n, slot, epoch });
                } else if epoch == nodes[n].epoch {
                    nodes[n].idle.push_back(slot);
                }
            }
            Event::Heartbeat => {
                for n in 0..m {
                    let dead_now = plan.instance_dead(n, now);
                    let miss = dead_now || plan.instance_partitioned(n, now);
                    if miss {
                        nodes[n].misses += 1;
                        if nodes[n].is_declared_dead() {
                            continue;
                        }
                        if nodes[n].misses < copts.suspect_after {
                            nodes[n].health = Health::Suspect;
                            continue;
                        }
                        // Declare Dead and fail over.
                        let cause = if dead_now {
                            DeadCause::Kill
                        } else {
                            DeadCause::Partition
                        };
                        nodes[n].health = Health::Dead(cause);
                        failovers += 1;
                        let win_start = match cause {
                            DeadCause::Kill => plan
                                .inst_kills
                                .iter()
                                .filter(|k| k.instance == n && k.window.contains(now))
                                .map(|k| k.window.start)
                                .fold(f64::INFINITY, f64::min),
                            DeadCause::Partition => plan
                                .inst_partitions
                                .iter()
                                .filter(|p| p.instance == n && p.window.contains(now))
                                .map(|p| p.window.start)
                                .fold(f64::INFINITY, f64::min),
                        };
                        if win_start.is_finite() {
                            recovery_samples.push(now - win_start);
                        }
                        // Drain queued batches; a kill also forfeits the
                        // in-flight incarnation (epoch bump), a
                        // partition re-runs copies and dedups later.
                        let mut drained: Vec<Batch> = Vec::new();
                        while !nodes[n].batcher.is_empty() {
                            drained.push(nodes[n].batcher.take(0));
                        }
                        match cause {
                            DeadCause::Kill => {
                                nodes[n].epoch += 1;
                                let inflight = std::mem::take(&mut nodes[n].in_flight);
                                drained.extend(inflight.into_values());
                            }
                            DeadCause::Partition => {
                                drained.extend(nodes[n].in_flight.values().cloned());
                            }
                        }
                        for b in drained {
                            for pr in b.requests {
                                if ledger.is_terminal(pr.meta.id) {
                                    continue;
                                }
                                let fa = failover_attempts.entry(pr.meta.id).or_insert(0);
                                *fa += 1;
                                if *fa > plan.max_retries {
                                    if ledger.shed(pr.meta.id) {
                                        shed_ids.push(pr.meta.id);
                                    }
                                    continue;
                                }
                                let loads = node_loads(&nodes);
                                // Failed-over copies carry no home: the
                                // home node is the one being declared
                                // dead, so affinity would just bounce.
                                let req = RouteRequest {
                                    id: pr.meta.id,
                                    predicted: pr.predicted_gen_len,
                                    confidence: 1.0,
                                    home: None,
                                };
                                match route_policy.route(&req, &loads) {
                                    Some(j) => {
                                        nodes[j].batcher.insert(pr, now);
                                        reroutes += 1;
                                    }
                                    None => {
                                        if ledger.shed(pr.meta.id) {
                                            shed_ids.push(pr.meta.id);
                                        }
                                    }
                                }
                            }
                        }
                    } else {
                        if let Health::Dead(cause) = nodes[n].health {
                            rejoins += 1;
                            if cause == DeadCause::Kill {
                                // Reboot: fresh slots, empty engine.
                                nodes[n].idle = (0..slots_per_node).collect();
                                nodes[n].in_flight.clear();
                            }
                        }
                        nodes[n].health = Health::Up;
                        nodes[n].misses = 0;
                    }
                }
                // The heartbeat chain is the cluster's liveness driver:
                // keep ticking while any request is unresolved.
                if ledger.resolved() < store.len() {
                    events.push(now + copts.hb_interval_s, Event::Heartbeat);
                }
            }
        }

        // Dispatch every node while slots are idle and batches queued.
        for n in 0..m {
            dispatch_node(
                now,
                n,
                &mut nodes[n],
                policy,
                &faulty,
                plan,
                ifaults,
                g_max,
                &mut events,
                &mut ledger,
                &mut shed_ids,
            );
        }
        // Mispredict-imbalance work stealing: idle instances pull the
        // heaviest queued batch from the most backlogged peer.
        if copts.steal_threshold_tokens > 0 && m > 1 {
            while let Some(thief) =
                steal_once(now, &mut nodes, plan, ifaults, copts.steal_threshold_tokens)
            {
                steals += 1;
                dispatch_node(
                    now,
                    thief,
                    &mut nodes[thief],
                    policy,
                    &faulty,
                    plan,
                    ifaults,
                    g_max,
                    &mut events,
                    &mut ledger,
                    &mut shed_ids,
                );
            }
        }
    }

    debug_assert_eq!(
        ledger.completed + ledger.shed,
        store.len(),
        "cluster exactly-once ledger must close under any fault schedule: \
         offered == completed + shed (+ expired, always 0 in the sim)"
    );
    debug_assert_eq!(
        nodes.iter().map(|nd| nd.metrics.records.len()).sum::<usize>(),
        ledger.completed,
        "per-instance records must sum to the ledger's unique completions"
    );

    ClusterOutput {
        nodes: nodes
            .into_iter()
            .map(|nd| NodeOutput {
                metrics: nd.metrics,
                db: nd.db,
                est_errors: nd.est_errors,
            })
            .collect(),
        pred_errors,
        offered: store.len(),
        completed: ledger.completed,
        shed: ledger.shed,
        expired: 0,
        duplicate_acks: ledger.duplicate_acks,
        steals,
        reroutes,
        failovers,
        rejoins,
        recovery_samples,
        fallback_predictions,
        low_confidence_admissions,
        drift_demotions,
        drift_repromotions,
        shed_ids,
    }
}

/// Per-node dispatch loop — the cluster counterpart of the
/// single-instance `dispatch_idle` (Indexed mode), plus the kill-window
/// guard, leader-side in-flight copies and instance-stall scaling.
#[allow(clippy::too_many_arguments)]
fn dispatch_node(
    now: f64,
    n: usize,
    node: &mut Node,
    policy: &MagnusPolicy,
    faulty: &FaultyEngine<'_>,
    plan: &FaultPlan,
    ifaults: bool,
    g_max: u32,
    events: &mut EventQueue<Event>,
    ledger: &mut ClusterLedger,
    shed_ids: &mut Vec<u64>,
) {
    if ifaults && (plan.instance_dead(n, now) || node.is_declared_dead()) {
        return;
    }
    while !node.idle.is_empty() && !node.batcher.is_empty() {
        let (pick, est) = {
            let estimator = &node.estimator;
            node.batcher
                .select_indexed(policy.sched, now, estimator.generation(), |shape| {
                    estimator.estimate(shape)
                })
                .unwrap()
        };
        let batch = node.batcher.take(pick);
        let slot = node.idle.pop_front().unwrap();
        let epoch = node.epoch;

        if plan.is_noop() {
            // Legacy path, byte-for-byte: the M=1 equivalence suite
            // replays fault-free runs through here.
            match faulty.inner().serve_batch(&batch) {
                BatchOutcome::Oom {
                    at_iteration: _,
                    wasted_time,
                } => {
                    node.metrics.record_oom();
                    let nid = node.batcher.alloc_id();
                    let (l, r) = batch.split(nid);
                    node.batcher.requeue(l);
                    node.batcher.requeue(r);
                    events.push(
                        now + wasted_time + OOM_RELOAD_S,
                        Event::SlotReady { node: n, slot, epoch },
                    );
                }
                done @ BatchOutcome::Completed { .. } => {
                    let serving_time = match &done {
                        BatchOutcome::Completed { serving_time, .. } => *serving_time,
                        _ => unreachable!(),
                    };
                    node.in_flight.insert(slot, batch.clone());
                    events.push(
                        now + serving_time,
                        Event::BatchDone {
                            node: n,
                            slot,
                            epoch,
                            batch,
                            est,
                            outcome: done,
                        },
                    );
                }
            }
            continue;
        }

        let attempt = node.attempts.get(&batch.id).copied().unwrap_or(0);
        let slow = if ifaults {
            plan.instance_stall(n, now)
        } else {
            1.0
        };
        match faulty.serve_batch_at(now, &batch, u64::from(attempt)) {
            InjectedOutcome::Crash { wasted_time } => {
                node.metrics.injected_faults += 1;
                let backoff = plan.restart_backoff(node.slot_restarts[slot]);
                node.slot_restarts[slot] += 1;
                node.metrics.worker_restarts += 1;
                retry_or_shed_node(plan, node, ledger, shed_ids, batch);
                events.push(
                    now + scale(wasted_time, slow) + backoff,
                    Event::SlotReady { node: n, slot, epoch },
                );
            }
            InjectedOutcome::TransientError { wasted_time } => {
                node.metrics.injected_faults += 1;
                retry_or_shed_node(plan, node, ledger, shed_ids, batch);
                events.push(
                    now + scale(wasted_time, slow),
                    Event::SlotReady { node: n, slot, epoch },
                );
            }
            InjectedOutcome::Outcome {
                outcome:
                    BatchOutcome::Oom {
                        at_iteration,
                        wasted_time,
                    },
                forced,
            } => {
                node.metrics.record_oom();
                if forced {
                    node.metrics.injected_faults += 1;
                }
                requeue_oom_node(plan, node, ledger, shed_ids, batch, at_iteration, g_max);
                events.push(
                    now + scale(wasted_time, slow) + OOM_RELOAD_S,
                    Event::SlotReady { node: n, slot, epoch },
                );
            }
            InjectedOutcome::Outcome {
                outcome:
                    BatchOutcome::Completed {
                        serving_time,
                        per_request,
                    },
                ..
            } => {
                // Slow-instance windows stretch the wall-clock serve
                // (factor 1.0 leaves the float untouched).
                let serving_time = scale(serving_time, slow);
                node.in_flight.insert(slot, batch.clone());
                events.push(
                    now + serving_time,
                    Event::BatchDone {
                        node: n,
                        slot,
                        epoch,
                        batch,
                        est,
                        outcome: BatchOutcome::Completed {
                            serving_time,
                            per_request,
                        },
                    },
                );
            }
        }
    }
}

/// Bounded-retry policy for a batch lost to a crash/error/kill on one
/// node — like the single-instance `retry_or_shed`, but sheds go
/// through the cluster ledger (an id completed elsewhere must not be
/// double-counted).
fn retry_or_shed_node(
    plan: &FaultPlan,
    node: &mut Node,
    ledger: &mut ClusterLedger,
    shed_ids: &mut Vec<u64>,
    batch: Batch,
) {
    let attempt = node.attempts.entry(batch.id).or_insert(0);
    *attempt += 1;
    if *attempt > plan.max_retries {
        for pr in &batch.requests {
            if ledger.shed(pr.meta.id) {
                shed_ids.push(pr.meta.id);
            }
        }
    } else {
        node.metrics.retries += 1;
        node.batcher.requeue(batch);
    }
}

/// OOM re-queue on one node — the single-instance `requeue_oom` against
/// the node's own batcher and the cluster ledger.
fn requeue_oom_node(
    plan: &FaultPlan,
    node: &mut Node,
    ledger: &mut ClusterLedger,
    shed_ids: &mut Vec<u64>,
    mut batch: Batch,
    at_iteration: u32,
    g_max: u32,
) {
    if batch.size() < 2 {
        batch.insertable = false;
        retry_or_shed_node(plan, node, ledger, shed_ids, batch);
        return;
    }
    let nid = node.batcher.alloc_id();
    let batch = if plan.overrun_guard {
        match batch.split_overrun(nid, at_iteration, g_max) {
            Ok((l, r)) => {
                node.metrics.rebucketed += r.size();
                node.batcher.requeue(l);
                node.batcher.requeue(r);
                return;
            }
            Err(b) => b,
        }
    } else {
        batch
    };
    let (l, r) = batch.split(nid);
    node.batcher.requeue(l);
    node.batcher.requeue(r);
}

/// One work-stealing transfer: the first alive instance with an idle
/// slot and an empty queue pulls the heaviest (predicted tokens)
/// insertable batch from the most backlogged alive peer, provided that
/// peer's queued predicted tokens reach `threshold`.  Requests *move*
/// (`take` then re-insert), so stealing can never duplicate an id.
/// Returns the thief's index so the caller can run its dispatch loop.
fn steal_once(
    now: f64,
    nodes: &mut [Node],
    plan: &FaultPlan,
    ifaults: bool,
    threshold: u64,
) -> Option<usize> {
    let alive =
        |i: usize, nd: &Node| !nd.is_declared_dead() && !(ifaults && plan.instance_dead(i, now));
    let thief = nodes
        .iter()
        .enumerate()
        .position(|(i, nd)| alive(i, nd) && !nd.idle.is_empty() && nd.batcher.is_empty())?;
    let mut victim: Option<(usize, u64)> = None;
    for (i, nd) in nodes.iter().enumerate() {
        if i == thief || !alive(i, nd) {
            continue;
        }
        let mut tokens = 0u64;
        let mut has_insertable = false;
        for b in nd.batcher.queue() {
            if b.insertable {
                has_insertable = true;
            }
            for pr in &b.requests {
                tokens += u64::from(pr.predicted_gen_len);
            }
        }
        if has_insertable && tokens >= threshold && victim.map_or(true, |(_, best)| tokens > best) {
            victim = Some((i, tokens));
        }
    }
    let (v, _) = victim?;
    let mut pick: Option<(usize, u64)> = None;
    for (i, b) in nodes[v].batcher.queue().iter().enumerate() {
        if !b.insertable {
            continue;
        }
        let t: u64 = b
            .requests
            .iter()
            .map(|pr| u64::from(pr.predicted_gen_len))
            .sum();
        if pick.map_or(true, |(_, best)| t > best) {
            pick = Some((i, t));
        }
    }
    let (bi, _) = pick?;
    let batch = nodes[v].batcher.take(bi);
    for pr in batch.requests {
        nodes[thief].batcher.insert(pr, now);
    }
    Some(thief)
}
