//! Fault-domain cluster serving (ISSUE 8): M logical engine instances,
//! each a full replica of the supervised Magnus core (own adaptive
//! batcher, serving-time estimator, continuous learner, memory budget,
//! engine slots), fronted by a router that places every admitted request
//! by *predicted* generation length ([`route::RoutePolicy`]).
//!
//! Robustness machinery on top of placement:
//!
//! - **Heartbeat health checks** — every `hb_interval_s` the router
//!   probes each instance; consecutive misses walk Up → Suspect → Dead
//!   (`suspect_after` misses).  Kill windows and partition windows
//!   (`FaultPlan::{instance_dead, instance_partitioned}`) both fail the
//!   probe.
//! - **Failover** — declaring an instance Dead drains its queued batches
//!   plus leader-side copies of its in-flight batches back through the
//!   router under a per-request retry budget (`FaultPlan::max_retries`);
//!   exhausted requests are shed *explicitly*.
//! - **Partition semantics** — a partitioned instance keeps serving but
//!   cannot ack: its completions are deferred to the partition-window
//!   end.  Because failover may have re-run those requests elsewhere,
//!   the cluster ledger resolves duplicates first-terminal-wins.
//! - **Work stealing** — an idle instance with an empty queue pulls the
//!   heaviest queued batch (predicted tokens) from the most backlogged
//!   peer, re-bucketing its requests locally; ids move, never copy, so
//!   stealing can never duplicate a request.
//!
//! Exactly-once ledger, the cluster-level invariant (debug-asserted on
//! every run): `offered == completed + shed + expired` summed across
//! instances, under any fault schedule.  Both entry points hold it: the
//! discrete-event sim ([`sim::run_cluster_store`], deterministic and
//! seed-replayable — an M=1 cluster under a no-instance-fault plan is
//! bit-identical to the single-instance core) and the live threaded path
//! ([`live::serve_cluster_ingress_sim`]).

pub mod live;
pub mod route;
pub mod sim;

pub use live::{serve_cluster_ingress_sim, ClusterReport};
pub use route::{
    parse_route_policy, JoinShortestPredictedQueue, LengthPartitioned, NodeLoad,
    PowerOfTwoChoices, RoundRobin, RoutePolicy, RouteRequest, ShardAffinity,
    ROUTE_POLICY_NAMES,
};
pub use sim::{run_cluster_store, ClusterOutput, NodeOutput};

use std::collections::HashSet;

use crate::metrics::RunMetrics;

/// Cluster-level knobs shared by the sim and live paths.
#[derive(Debug, Clone)]
pub struct ClusterOptions {
    /// Logical engine instances behind the router (M ≥ 1).
    pub n_nodes: usize,
    /// Heartbeat probe period (simulated seconds in the DES path,
    /// replayed seconds in the live path).
    pub hb_interval_s: f64,
    /// Consecutive missed heartbeats before an instance is declared
    /// Dead (1 = first miss kills it; 2 = one Suspect beat first).
    pub suspect_after: u32,
    /// Work stealing fires when the most backlogged peer's queued
    /// predicted tokens reach this threshold (0 disables stealing).
    pub steal_threshold_tokens: u64,
    /// Salt for stateless routing draws (power-of-two-choices).
    pub route_seed: u64,
}

impl Default for ClusterOptions {
    fn default() -> Self {
        ClusterOptions {
            n_nodes: 4,
            hb_interval_s: 1.0,
            suspect_after: 2,
            steal_threshold_tokens: 64,
            route_seed: 0x524f_5554,
        }
    }
}

/// First-terminal-wins exactly-once ledger: every offered request id
/// resolves to exactly one terminal state (completed or shed); later
/// terminals for the same id — e.g. a partitioned instance's deferred
/// completion racing its failover re-run — count as duplicate acks and
/// mutate nothing.
#[derive(Debug, Default)]
pub struct ClusterLedger {
    terminal: HashSet<u64>,
    /// Unique completions.
    pub completed: usize,
    /// Unique explicit sheds.
    pub shed: usize,
    /// Terminal signals for already-resolved ids (duplicate-delivery
    /// pressure under partitions; 0 under kill-only schedules).
    pub duplicate_acks: u64,
}

impl ClusterLedger {
    /// Record a completion; true iff this id was not yet terminal.
    pub fn complete(&mut self, id: u64) -> bool {
        if self.terminal.insert(id) {
            self.completed += 1;
            true
        } else {
            self.duplicate_acks += 1;
            false
        }
    }

    /// Record an explicit shed; true iff this id was not yet terminal.
    pub fn shed(&mut self, id: u64) -> bool {
        if self.terminal.insert(id) {
            self.shed += 1;
            true
        } else {
            self.duplicate_acks += 1;
            false
        }
    }

    pub fn is_terminal(&self, id: u64) -> bool {
        self.terminal.contains(&id)
    }

    /// Requests resolved to a terminal state so far.
    pub fn resolved(&self) -> usize {
        self.completed + self.shed
    }
}

/// Instance health as seen by the router's heartbeat checker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    Up,
    /// Missed at least one heartbeat but not yet declared.
    Suspect,
    /// Declared dead; carries the failure mode so rejoin knows whether
    /// the instance rebooted (kill → slots reset) or merely re-connected
    /// (partition → in-flight work drains via deferred acks).
    Dead(DeadCause),
}

/// Why an instance was declared Dead.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeadCause {
    /// Kill window: the instance lost all state and reboots at window
    /// end.
    Kill,
    /// Partition window: the instance kept serving but could not ack.
    Partition,
}

/// Merge per-instance collectors plus cluster-level counters into one
/// [`RunMetrics`] (instance order, record order within an instance).
/// For an M=1 cluster this reproduces the single-instance collector
/// bit-for-bit.
pub(crate) fn merge_metrics(
    nodes: &[RunMetrics],
    shed_ids: &[u64],
    fallback_predictions: u32,
) -> RunMetrics {
    let mut m = RunMetrics::new();
    for nm in nodes {
        for r in &nm.records {
            m.record(r.clone());
        }
        m.oom_events += nm.oom_events;
        m.retries += nm.retries;
        m.worker_restarts += nm.worker_restarts;
        m.rebucketed += nm.rebucketed;
        m.injected_faults += nm.injected_faults;
        m.low_confidence_admissions += nm.low_confidence_admissions;
        m.drift_demotions += nm.drift_demotions;
        m.drift_repromotions += nm.drift_repromotions;
        m.speculative_rebuckets += nm.speculative_rebuckets;
        m.mispredict.merge(&nm.mispredict);
    }
    m.fallback_predictions = fallback_predictions;
    for &id in shed_ids {
        m.record_shed(id);
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_is_first_terminal_wins() {
        let mut l = ClusterLedger::default();
        assert!(l.complete(1));
        assert!(!l.complete(1), "second completion is a duplicate ack");
        assert!(!l.shed(1), "shed after completion is a duplicate ack");
        assert!(l.shed(2));
        assert!(!l.complete(2), "completion after shed is a duplicate ack");
        assert_eq!(l.completed, 1);
        assert_eq!(l.shed, 2 - 1);
        assert_eq!(l.duplicate_acks, 3);
        assert_eq!(l.resolved(), 2);
        assert!(l.is_terminal(1) && l.is_terminal(2) && !l.is_terminal(3));
    }

    #[test]
    fn merge_metrics_folds_counters_and_sheds() {
        use crate::metrics::RequestRecord;
        let mut a = RunMetrics::new();
        a.record_prediction(10, 10);
        a.record(RequestRecord {
            request_id: 1,
            arrival: 0.0,
            finish: 1.0,
            valid_tokens: 4,
            invalid_tokens: 0,
        });
        a.retries = 2;
        let mut b = RunMetrics::new();
        b.record_prediction(10, 90);
        b.record(RequestRecord {
            request_id: 2,
            arrival: 0.5,
            finish: 3.0,
            valid_tokens: 7,
            invalid_tokens: 1,
        });
        b.oom_events = 1;
        let m = merge_metrics(&[a, b], &[9], 3);
        assert_eq!(m.records.len(), 2);
        assert_eq!(m.retries, 2);
        assert_eq!(m.oom_events, 1);
        assert_eq!(m.fallback_predictions, 3);
        assert_eq!(m.shed, vec![9]);
        assert_eq!(m.mispredict.predictions, 2);
        assert_eq!(m.mispredict.mispredicted, 1);
        assert_eq!(m.first_arrival, 0.0);
        assert_eq!(m.last_finish, 3.0);
    }
}
