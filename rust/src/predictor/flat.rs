//! Flattened struct-of-arrays forest layout for the predict hot path.
//!
//! A fitted [`Tree`] stores a `Vec` of enum nodes — every traversal
//! step branches on the discriminant and chases a ~24-byte variant.
//! [`FlatForest`] compiles all trees of a forest into four contiguous
//! arrays over *internal* nodes only (`feature` / `threshold` / `left` /
//! `right`), with leaves encoded directly in the child index: the high
//! bit marks a leaf, the low bits index a separate `leaf_value` array.
//! Traversal is a tight loop over the arrays, and
//! [`FlatForest::predict_many`] iterates trees-outer / rows-inner so one
//! tree's arrays stay cache-hot across a whole batch of rows.
//!
//! Predictions are bit-for-bit those of the node-enum reference
//! ([`crate::predictor::Forest::predict_reference`]): same traversal
//! comparisons, same tree-order summation, same final division —
//! `tests/predictor_equivalence.rs` proves it on random datasets.

use crate::predictor::tree::{Node, Tree};

/// Child code: high bit set ⇒ leaf (low bits index `leaf_value`);
/// otherwise an internal-node index.
const LEAF_BIT: u32 = 1 << 31;

/// A forest compiled into the flattened SoA layout.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FlatForest {
    /// Per-tree root code (a single-leaf tree's root is a leaf code).
    roots: Vec<u32>,
    feature: Vec<u32>,
    threshold: Vec<f32>,
    left: Vec<u32>,
    right: Vec<u32>,
    leaf_value: Vec<f32>,
}

impl FlatForest {
    /// Compile fitted trees into the flattened layout.
    pub fn compile(trees: &[Tree]) -> FlatForest {
        let mut f = FlatForest::default();
        for t in trees {
            let root = f.compile_node(t.nodes(), 0);
            f.roots.push(root);
        }
        f
    }

    fn compile_node(&mut self, nodes: &[Node], i: usize) -> u32 {
        match &nodes[i] {
            Node::Leaf { value } => {
                self.leaf_value.push(*value);
                LEAF_BIT | (self.leaf_value.len() - 1) as u32
            }
            Node::Split {
                feature,
                threshold,
                left,
                right,
            } => {
                let me = self.feature.len();
                self.feature.push(*feature as u32);
                self.threshold.push(*threshold);
                self.left.push(0);
                self.right.push(0);
                let l = self.compile_node(nodes, *left);
                let r = self.compile_node(nodes, *right);
                self.left[me] = l;
                self.right[me] = r;
                me as u32
            }
        }
    }

    #[inline]
    fn descend(&self, mut code: u32, row: &[f32]) -> f32 {
        while code & LEAF_BIT == 0 {
            let i = code as usize;
            code = if row[self.feature[i] as usize] <= self.threshold[i] {
                self.left[i]
            } else {
                self.right[i]
            };
        }
        self.leaf_value[(code & !LEAF_BIT) as usize]
    }

    /// Mean prediction across trees (summed in tree order — bit-identical
    /// to the node-enum reference).
    pub fn predict(&self, row: &[f32]) -> f32 {
        let s: f32 = self.roots.iter().map(|&r| self.descend(r, row)).sum();
        s / self.roots.len() as f32
    }

    /// Batch predict: `rows` is row-major n × `d`; `out` is overwritten
    /// with one prediction per row.  Trees-outer iteration keeps each
    /// tree's arrays cache-resident across the batch while per-row
    /// accumulation stays in tree order, so every output is bit-identical
    /// to [`FlatForest::predict`] on that row.
    pub fn predict_many(&self, rows: &[f32], d: usize, out: &mut Vec<f32>) {
        assert!(d > 0 && rows.len() % d == 0, "rows must be row-major n × d");
        let n = rows.len() / d;
        out.clear();
        out.resize(n, 0.0);
        for &root in &self.roots {
            for (r, acc) in out.iter_mut().enumerate() {
                *acc += self.descend(root, &rows[r * d..(r + 1) * d]);
            }
        }
        let k = self.roots.len() as f32;
        for acc in out.iter_mut() {
            *acc /= k;
        }
    }

    pub fn n_trees(&self) -> usize {
        self.roots.len()
    }

    /// Total compiled nodes (internal + leaves) across all trees.
    pub fn n_nodes(&self) -> usize {
        self.feature.len() + self.leaf_value.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::tree::TreeParams;
    use crate::util::Rng;

    fn step_tree() -> Tree {
        let x: Vec<Vec<f32>> = (0..100).map(|i| vec![i as f32]).collect();
        let y: Vec<f32> = (0..100)
            .map(|i| if i < 50 { 1.0 } else { 9.0 })
            .collect();
        let mut rng = Rng::new(1);
        Tree::fit(&x, &y, &TreeParams::default(), &mut rng)
    }

    #[test]
    fn single_leaf_tree_compiles_to_leaf_root() {
        let x = vec![vec![0.0f32]; 8];
        let y = vec![3.5f32; 8];
        let mut rng = Rng::new(2);
        let t = Tree::fit(&x, &y, &TreeParams::default(), &mut rng);
        let f = FlatForest::compile(&[t]);
        assert_eq!(f.n_trees(), 1);
        assert_eq!(f.predict(&[123.0]), 3.5);
    }

    #[test]
    fn matches_enum_traversal_on_probes() {
        let t = step_tree();
        let f = FlatForest::compile(&[t.clone()]);
        for probe in 0..100 {
            let row = [probe as f32];
            assert_eq!(f.predict(&row).to_bits(), t.predict(&row).to_bits());
        }
    }

    #[test]
    fn predict_many_matches_predict() {
        let t = step_tree();
        let f = FlatForest::compile(&[t.clone(), t]);
        let rows: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let mut out = Vec::new();
        f.predict_many(&rows, 1, &mut out);
        assert_eq!(out.len(), 100);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v.to_bits(), f.predict(&[i as f32]).to_bits());
        }
    }
}
