//! Column-major (struct-of-arrays) training dataset for the forest.
//!
//! Tree growing sorts a node's rows per candidate feature, so the hot
//! read pattern is "all values of one feature" — column-major storage
//! makes that a contiguous scan instead of a strided walk over per-row
//! `Vec`s.  Appending a row (continuous learning) is one push per
//! column, so the retained train set is never re-laid-out or cloned
//! across refits.

/// Column-major f32 matrix: `col(f)[i]` is feature `f` of row `i`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ColMatrix {
    n_rows: usize,
    cols: Vec<Vec<f32>>,
}

impl ColMatrix {
    /// Empty matrix with `n_cols` feature columns.
    pub fn new(n_cols: usize) -> Self {
        ColMatrix {
            n_rows: 0,
            cols: vec![Vec::new(); n_cols],
        }
    }

    /// Transpose row-major rows (n × d) into a column-major matrix.
    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        let d = rows.first().map(|r| r.len()).unwrap_or(0);
        let mut m = ColMatrix {
            n_rows: 0,
            cols: vec![Vec::with_capacity(rows.len()); d],
        };
        for r in rows {
            m.push_row(r);
        }
        m
    }

    /// Append one row (one push per column).
    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.cols.len(), "row width mismatch");
        for (c, &v) in self.cols.iter_mut().zip(row) {
            c.push(v);
        }
        self.n_rows += 1;
    }

    #[inline]
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    #[inline]
    pub fn n_cols(&self) -> usize {
        self.cols.len()
    }

    pub fn is_empty(&self) -> bool {
        self.n_rows == 0
    }

    /// All values of feature `f`, contiguous.
    #[inline]
    pub fn col(&self, f: usize) -> &[f32] {
        &self.cols[f]
    }

    #[inline]
    pub fn at(&self, row: usize, col: usize) -> f32 {
        self.cols[col][row]
    }

    /// Drop all rows, keeping the column layout (and capacity).
    pub fn clear(&mut self) {
        for c in &mut self.cols {
            c.clear();
        }
        self.n_rows = 0;
    }

    /// Copy row `i` into `out` (cleared first).
    pub fn row_into(&self, i: usize, out: &mut Vec<f32>) {
        out.clear();
        out.extend(self.cols.iter().map(|c| c[i]));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips_rows() {
        let rows = vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]];
        let m = ColMatrix::from_rows(&rows);
        assert_eq!(m.n_rows(), 2);
        assert_eq!(m.n_cols(), 3);
        assert_eq!(m.col(1), &[2.0, 5.0]);
        assert_eq!(m.at(1, 2), 6.0);
        let mut r = Vec::new();
        m.row_into(0, &mut r);
        assert_eq!(r, rows[0]);
    }

    #[test]
    fn push_and_clear() {
        let mut m = ColMatrix::new(2);
        assert!(m.is_empty());
        m.push_row(&[1.0, 2.0]);
        m.push_row(&[3.0, 4.0]);
        assert_eq!(m.col(0), &[1.0, 3.0]);
        m.clear();
        assert!(m.is_empty());
        assert_eq!(m.n_cols(), 2);
    }

    #[test]
    #[should_panic]
    fn rejects_width_mismatch() {
        let mut m = ColMatrix::new(2);
        m.push_row(&[1.0]);
    }
}
