//! Degraded-mode prediction: what the serving tier does when the trained
//! predictor is unavailable (ISSUE 6, ROADMAP items 3–4).
//!
//! The fallback chain is: trained forest → input-length heuristic →
//! conservative max-bucket default.  The middle rung follows the paper's
//! own observation (§III-B, Table II) that user-input length is the
//! single strongest cheap signal for generation length; the last rung
//! trades batcher efficiency for safety by assuming every request runs to
//! `G_max`, which can never trigger an overrun-driven OOM.
//!
//! Which rung is active is decided by the caller (normally a
//! [`FaultPlan`](crate::faults::FaultPlan) predictor-outage window, or a
//! load error for live artifacts) — this module only computes the
//! degraded value, so it stays dependency-free and trivially testable.

use crate::predictor::GenLenPredictor;
use crate::workload::RequestView;

/// Which degraded rung of the prediction fallback chain to use while the
/// trained predictor is offline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FallbackMode {
    /// Predict the user-input length, clamped to `[1, G_max]` — the
    /// paper's strongest single-feature signal (UIL, Table II).
    Heuristic,
    /// Predict `G_max` for everything: maximally conservative, immune to
    /// overrun OOMs, worst for batching efficiency.
    MaxBucket,
}

/// The prediction an offline-predictor rung produces for one request.
/// Clamped to `[1, max(G_max, 1)]` exactly like the trained path's
/// output, so downstream bucketing invariants hold unchanged.
pub fn fallback_prediction(mode: FallbackMode, user_input_len: u32, g_max: u32) -> u32 {
    let cap = g_max.max(1);
    match mode {
        FallbackMode::Heuristic => user_input_len.clamp(1, cap),
        FallbackMode::MaxBucket => cap,
    }
}

/// One admission-time prediction under a possibly-degraded predictor:
/// `outage == None` runs the trained predictor exactly as the fault-free
/// path does; `Some(mode)` short-circuits to the fallback chain without
/// touching the forest (it is "offline").  Returns the prediction and
/// whether a fallback rung produced it (so callers can count
/// `fallback_predictions`).
pub fn predict_degraded(
    predictor: &mut GenLenPredictor,
    outage: Option<FallbackMode>,
    view: &RequestView<'_>,
    g_max: u32,
) -> (u32, bool) {
    match outage {
        Some(mode) => (fallback_prediction(mode, view.user_input_len, g_max), true),
        None => (predictor.predict(*view), false),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServingConfig;
    use crate::predictor::Variant;
    use crate::workload::{generate_trace, TraceSpec};

    #[test]
    fn fallback_rungs_clamp_like_the_trained_path() {
        assert_eq!(fallback_prediction(FallbackMode::Heuristic, 17, 64), 17);
        assert_eq!(fallback_prediction(FallbackMode::Heuristic, 0, 64), 1);
        assert_eq!(fallback_prediction(FallbackMode::Heuristic, 900, 64), 64);
        assert_eq!(fallback_prediction(FallbackMode::MaxBucket, 17, 64), 64);
        // degenerate g_max never yields 0 (bucket index math divides by it)
        assert_eq!(fallback_prediction(FallbackMode::Heuristic, 5, 0), 1);
        assert_eq!(fallback_prediction(FallbackMode::MaxBucket, 5, 0), 1);
    }

    #[test]
    fn degraded_path_bypasses_predictor_and_flags_fallback() {
        let cfg = ServingConfig::default();
        let mut p = GenLenPredictor::new(Variant::Uilo, &cfg);
        let trace = generate_trace(&TraceSpec {
            n_requests: 4,
            seed: 99,
            ..TraceSpec::default()
        });
        let v = trace[0].view();
        let g_max = cfg.gpu.g_max;
        let (pred, fell_back) =
            predict_degraded(&mut p, Some(FallbackMode::MaxBucket), &v, g_max);
        assert_eq!((pred, fell_back), (g_max, true));
        let (pred, fell_back) =
            predict_degraded(&mut p, Some(FallbackMode::Heuristic), &v, g_max);
        assert_eq!(pred, v.user_input_len.clamp(1, g_max));
        assert!(fell_back);
        let (pred, fell_back) = predict_degraded(&mut p, None, &v, g_max);
        assert!(!fell_back);
        assert!(pred >= 1 && pred <= g_max);
    }
}
