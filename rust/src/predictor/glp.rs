//! The generation-length predictor service (paper §III-B, Fig. 8).
//!
//! Wraps a feature pipeline + random forest(s) behind a simple
//! `predict(&Request) -> u32` interface, supports the four Table-II
//! variants, and implements the continuous-learning augmentation loop
//! (collect badly-predicted requests, extend the train set, refit).

use crate::config::ServingConfig;
use crate::predictor::features::{FeatureExtractor, Variant};
use crate::predictor::forest::{Forest, ForestParams};
use crate::predictor::tree::TreeParams;
use crate::util::Rng;
use crate::workload::{Request, TaskId};

/// A trained generation-length predictor.
pub struct GenLenPredictor {
    variant: Variant,
    fx: FeatureExtractor,
    /// INST/USIN: single forest. RAFT: indexed by task.
    global: Option<Forest>,
    per_task: Vec<Option<Forest>>,
    params: ForestParams,
    g_max: u32,
    /// Retained training data for continuous learning.
    train_x: Vec<Vec<f32>>,
    train_y: Vec<f32>,
    train_task: Vec<TaskId>,
    seed: u64,
}

impl GenLenPredictor {
    /// Build (untrained) with hyperparameters from the serving config.
    pub fn new(variant: Variant, cfg: &ServingConfig) -> Self {
        GenLenPredictor {
            variant,
            fx: FeatureExtractor::new(),
            global: None,
            per_task: (0..TaskId::ALL.len()).map(|_| None).collect(),
            params: ForestParams {
                n_trees: cfg.rf_trees,
                tree: TreeParams {
                    max_depth: cfg.rf_max_depth,
                    ..Default::default()
                },
                ..Default::default()
            },
            g_max: cfg.gpu.g_max,
            train_x: Vec::new(),
            train_y: Vec::new(),
            train_task: Vec::new(),
            seed: cfg.seed,
        }
    }

    pub fn variant(&self) -> Variant {
        self.variant
    }

    /// Fit on labelled requests (UILO needs no fit and ignores the data).
    pub fn train(&mut self, data: &[Request]) {
        if self.variant == Variant::Uilo {
            return;
        }
        self.train_x.clear();
        self.train_y.clear();
        self.train_task.clear();
        for r in data {
            self.train_x.push(self.fx.features(self.variant, r));
            self.train_y.push(r.gen_len as f32);
            self.train_task.push(r.task);
        }
        self.refit();
    }

    /// Continuous learning (§III-B): augment the train set with logged
    /// requests whose prediction error exceeded the thresholds, refit.
    pub fn augment_and_refit(&mut self, extra: &[Request]) {
        if self.variant == Variant::Uilo || extra.is_empty() {
            return;
        }
        for r in extra {
            self.train_x.push(self.fx.features(self.variant, r));
            self.train_y.push(r.gen_len as f32);
            self.train_task.push(r.task);
        }
        self.refit();
    }

    fn refit(&mut self) {
        let mut rng = Rng::new(self.seed ^ 0x474c_50);
        match self.variant {
            Variant::Uilo => {}
            Variant::Raft => {
                for (ti, task) in TaskId::ALL.iter().enumerate() {
                    let idx: Vec<usize> = (0..self.train_x.len())
                        .filter(|&i| self.train_task[i] == *task)
                        .collect();
                    if idx.is_empty() {
                        self.per_task[ti] = None;
                        continue;
                    }
                    let x: Vec<Vec<f32>> =
                        idx.iter().map(|&i| self.train_x[i].clone()).collect();
                    let y: Vec<f32> = idx.iter().map(|&i| self.train_y[i]).collect();
                    self.per_task[ti] =
                        Some(Forest::fit(&x, &y, &self.params, &mut rng));
                }
            }
            Variant::Inst | Variant::Usin => {
                self.global = Some(Forest::fit(
                    &self.train_x,
                    &self.train_y,
                    &self.params,
                    &mut rng,
                ));
            }
        }
    }

    /// Predict G'(p), clamped to [1, G_max].
    pub fn predict(&mut self, req: &Request) -> u32 {
        let raw = match self.variant {
            Variant::Uilo => req.user_input_len as f32,
            Variant::Raft => {
                let row = self.fx.features(self.variant, req);
                match &self.per_task[req.task.index()] {
                    Some(f) => f.predict(&row),
                    None => req.user_input_len as f32, // cold start
                }
            }
            Variant::Inst | Variant::Usin => {
                let row = self.fx.features(self.variant, req);
                match &self.global {
                    Some(f) => f.predict(&row),
                    None => req.user_input_len as f32,
                }
            }
        };
        (raw.round().max(1.0) as u32).min(self.g_max)
    }

    /// Current training-set size (for continuous-learning telemetry).
    pub fn train_size(&self) -> usize {
        self.train_y.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::rmse;
    use crate::workload::dataset::build_predictor_split;
    use crate::workload::LlmProfile;

    fn eval_rmse(variant: Variant, n_train: usize, n_test: usize) -> f64 {
        let cfg = ServingConfig::default();
        let split =
            build_predictor_split(LlmProfile::ChatGlm6B, n_train, n_test, 1024, 11);
        let mut p = GenLenPredictor::new(variant, &cfg);
        p.train(&split.train);
        let pred: Vec<f64> = split
            .test
            .iter()
            .map(|r| p.predict(r) as f64)
            .collect();
        let actual: Vec<f64> =
            split.test.iter().map(|r| r.gen_len as f64).collect();
        rmse(&pred, &actual)
    }

    #[test]
    fn table2_ordering_uilo_worst_usin_best() {
        // Table II: UILO >> RAFT ≈ INST > USIN.
        let uilo = eval_rmse(Variant::Uilo, 300, 80);
        let raft = eval_rmse(Variant::Raft, 300, 80);
        let usin = eval_rmse(Variant::Usin, 300, 80);
        assert!(uilo > raft * 1.2, "uilo={uilo} raft={raft}");
        assert!(usin <= raft * 1.05, "usin={usin} raft={raft}");
    }

    #[test]
    fn predictions_clamped() {
        let cfg = ServingConfig::default();
        let split = build_predictor_split(LlmProfile::ChatGlm6B, 50, 10, 1024, 12);
        let mut p = GenLenPredictor::new(Variant::Usin, &cfg);
        p.train(&split.train);
        for r in &split.test {
            let g = p.predict(r);
            assert!(g >= 1 && g <= cfg.gpu.g_max);
        }
    }

    #[test]
    fn cold_start_falls_back_to_uil() {
        let cfg = ServingConfig::default();
        let split = build_predictor_split(LlmProfile::ChatGlm6B, 10, 5, 1024, 13);
        let mut p = GenLenPredictor::new(Variant::Usin, &cfg);
        let r = &split.test[0];
        assert_eq!(p.predict(r), r.user_input_len.clamp(1, cfg.gpu.g_max));
    }

    #[test]
    fn augmentation_grows_train_set_and_helps() {
        let cfg = ServingConfig::default();
        let split = build_predictor_split(LlmProfile::ChatGlm6B, 40, 100, 1024, 14);
        let mut p = GenLenPredictor::new(Variant::Usin, &cfg);
        p.train(&split.train);
        let before_n = p.train_size();
        let extra = build_predictor_split(LlmProfile::ChatGlm6B, 150, 1, 1024, 15).train;
        p.augment_and_refit(&extra);
        assert!(p.train_size() > before_n);
    }
}
