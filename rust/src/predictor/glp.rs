//! The generation-length predictor service (paper §III-B, Fig. 8).
//!
//! Wraps a feature pipeline + random forest(s) behind a simple
//! `predict(view) -> u32` interface, supports the four Table-II
//! variants, and implements the continuous-learning augmentation loop
//! (collect badly-predicted requests, extend the train set, refit).
//!
//! Hot-path layout: the retained train set is a column-major
//! [`ColMatrix`] (continuous learning appends rows, refits pass index
//! views — no row is ever cloned), prediction reuses one feature-row
//! scratch buffer, and [`GenLenPredictor::predict_many_views`] batches
//! same-tick arrivals through the flattened forest trees-outer.  Every
//! entry point takes a [`RequestView`] (or anything converting to one,
//! e.g. `&Request`), so the serving path feeds the predictor borrowed
//! arena slices and never clones request text.

use crate::config::ServingConfig;
use crate::predictor::data::ColMatrix;
use crate::predictor::features::{FeatureExtractor, Variant};
use crate::predictor::forest::{Forest, ForestParams};
use crate::predictor::traits::{self, PredictionWithConfidence};
use crate::predictor::tree::TreeParams;
use crate::util::Rng;
use crate::workload::{Request, RequestView, TaskId};

/// A trained generation-length predictor.
pub struct GenLenPredictor {
    variant: Variant,
    fx: FeatureExtractor,
    /// INST/USIN: single forest. RAFT: indexed by task.
    global: Option<Forest>,
    per_task: Vec<Option<Forest>>,
    params: ForestParams,
    g_max: u32,
    /// Retained training data (column-major; continuous learning appends).
    train_data: ColMatrix,
    train_y: Vec<f32>,
    train_task: Vec<TaskId>,
    seed: u64,
    /// Scratch: one feature row, reused across predicts/absorbs.
    row_buf: Vec<f32>,
    /// Scratch: row-major batch rows + raw outputs for `predict_many`.
    batch_rows: Vec<f32>,
    batch_out: Vec<f32>,
    /// Scratch: per-tree raw predictions for the confidence path.
    vote_buf: Vec<f32>,
}

impl GenLenPredictor {
    /// Build (untrained) with hyperparameters from the serving config.
    pub fn new(variant: Variant, cfg: &ServingConfig) -> Self {
        GenLenPredictor {
            variant,
            fx: FeatureExtractor::new(),
            global: None,
            per_task: (0..TaskId::ALL.len()).map(|_| None).collect(),
            params: ForestParams {
                n_trees: cfg.rf_trees,
                tree: TreeParams {
                    max_depth: cfg.rf_max_depth,
                    ..Default::default()
                },
                ..Default::default()
            },
            g_max: cfg.gpu.g_max,
            train_data: ColMatrix::new(variant.dim()),
            train_y: Vec::new(),
            train_task: Vec::new(),
            seed: cfg.seed,
            row_buf: Vec::new(),
            batch_rows: Vec::new(),
            batch_out: Vec::new(),
            vote_buf: Vec::new(),
        }
    }

    pub fn variant(&self) -> Variant {
        self.variant
    }

    /// Fit on labelled requests (UILO needs no fit and ignores the data).
    pub fn train(&mut self, data: &[Request]) {
        if self.variant == Variant::Uilo {
            return;
        }
        self.train_data.clear();
        self.train_y.clear();
        self.train_task.clear();
        for r in data {
            self.absorb(r);
        }
        self.refit();
    }

    /// Append one labelled request to the retained train set WITHOUT
    /// refitting — continuous-learning sweeps absorb a batch of rows,
    /// then call [`GenLenPredictor::refit`] once.  No-op for UILO.
    pub fn absorb<'a>(&mut self, r: impl Into<RequestView<'a>>) {
        if self.variant == Variant::Uilo {
            return;
        }
        let r: RequestView<'a> = r.into();
        self.fx.features_into(self.variant, r, &mut self.row_buf);
        self.train_data.push_row(&self.row_buf);
        self.train_y.push(r.gen_len as f32);
        self.train_task.push(r.task);
    }

    /// Continuous learning (§III-B): augment the train set with logged
    /// requests whose prediction error exceeded the thresholds, refit.
    pub fn augment_and_refit(&mut self, extra: &[Request]) {
        if self.variant == Variant::Uilo || extra.is_empty() {
            return;
        }
        for r in extra {
            self.absorb(r);
        }
        self.refit();
    }

    /// Refit every forest from the retained train set (index views into
    /// the column-major matrix — no rows are copied out).
    pub fn refit(&mut self) {
        let mut rng = Rng::new(self.seed ^ 0x474c_50);
        match self.variant {
            Variant::Uilo => {}
            Variant::Raft => {
                for (ti, task) in TaskId::ALL.iter().enumerate() {
                    let idx: Vec<u32> = (0..self.train_task.len() as u32)
                        .filter(|&i| self.train_task[i as usize] == *task)
                        .collect();
                    if idx.is_empty() {
                        self.per_task[ti] = None;
                        continue;
                    }
                    self.per_task[ti] = Some(Forest::fit_view(
                        &self.train_data,
                        &self.train_y,
                        &idx,
                        &self.params,
                        &mut rng,
                    ));
                }
            }
            Variant::Inst | Variant::Usin => {
                let idx: Vec<u32> = (0..self.train_y.len() as u32).collect();
                self.global = Some(Forest::fit_view(
                    &self.train_data,
                    &self.train_y,
                    &idx,
                    &self.params,
                    &mut rng,
                ));
            }
        }
    }

    #[inline]
    fn clamp_raw(raw: f32, g_max: u32) -> u32 {
        (raw.round().max(1.0) as u32).min(g_max)
    }

    /// Predict G'(p), clamped to [1, G_max].  Takes any request view
    /// (`&Request`, or a zero-copy `TraceStore` view on the serving path).
    pub fn predict<'a>(&mut self, req: impl Into<RequestView<'a>>) -> u32 {
        let req: RequestView<'a> = req.into();
        let raw = match self.variant {
            Variant::Uilo => req.user_input_len as f32,
            Variant::Raft => {
                if self.per_task[req.task.index()].is_some() {
                    self.fx.features_into(self.variant, req, &mut self.row_buf);
                    self.per_task[req.task.index()]
                        .as_ref()
                        .unwrap()
                        .predict(&self.row_buf)
                } else {
                    req.user_input_len as f32 // cold start
                }
            }
            Variant::Inst | Variant::Usin => {
                if self.global.is_some() {
                    self.fx.features_into(self.variant, req, &mut self.row_buf);
                    self.global.as_ref().unwrap().predict(&self.row_buf)
                } else {
                    req.user_input_len as f32
                }
            }
        };
        Self::clamp_raw(raw, self.g_max)
    }

    /// Batch predict over borrowed views: same values, in order, as
    /// calling [`GenLenPredictor::predict`] per request.  INST/USIN rows
    /// go through the flattened forest trees-outer (one pass over the
    /// batch per tree, arrays cache-hot); other variants fall back per
    /// row.  This is the simulator's arrival path — the views borrow the
    /// trace arena, so nothing is cloned.
    pub fn predict_many_views(&mut self, views: &[RequestView<'_>], out: &mut Vec<u32>) {
        out.clear();
        let batched = matches!(self.variant, Variant::Inst | Variant::Usin)
            && self.global.is_some()
            && views.len() > 1;
        if !batched {
            for v in views {
                out.push(self.predict(*v));
            }
            return;
        }
        self.batch_rows.clear();
        for v in views {
            self.fx.features_into(self.variant, *v, &mut self.row_buf);
            self.batch_rows.extend_from_slice(&self.row_buf);
        }
        let forest = self.global.as_ref().unwrap();
        forest.predict_many(&self.batch_rows, self.variant.dim(), &mut self.batch_out);
        out.extend(
            self.batch_out
                .iter()
                .map(|&raw| Self::clamp_raw(raw, self.g_max)),
        );
    }

    /// [`GenLenPredictor::predict_many_views`] over owned requests
    /// (goldens/benches).
    pub fn predict_many(&mut self, reqs: &[&Request], out: &mut Vec<u32>) {
        let views: Vec<RequestView> = reqs.iter().map(|r| r.view()).collect();
        self.predict_many_views(&views, out);
    }

    /// Per-tree raw predictions of the forest that would serve `req` —
    /// the vote distribution behind the bucket-classifier confidence.
    /// Returns `false` (and leaves `out` empty) when no trained forest
    /// covers the request (UILO, or cold start), i.e. when the point
    /// prediction is the UIL heuristic and carries no vote spread.
    pub fn tree_predictions<'a>(
        &mut self,
        req: impl Into<RequestView<'a>>,
        out: &mut Vec<f32>,
    ) -> bool {
        let req: RequestView<'a> = req.into();
        out.clear();
        let trained = match self.variant {
            Variant::Uilo => false,
            Variant::Raft => self.per_task[req.task.index()].is_some(),
            Variant::Inst | Variant::Usin => self.global.is_some(),
        };
        if !trained {
            return false;
        }
        self.fx.features_into(self.variant, req, &mut self.row_buf);
        let forest = match self.variant {
            Variant::Raft => self.per_task[req.task.index()].as_ref().unwrap(),
            _ => self.global.as_ref().unwrap(),
        };
        for t in forest.trees() {
            out.push(t.predict(&self.row_buf));
        }
        true
    }

    /// Point prediction plus bucketed confidence: the per-tree votes of
    /// the serving forest, histogrammed into the [`traits::N_BUCKETS`]
    /// generation-length buckets.  The `point` field is **exactly**
    /// [`GenLenPredictor::predict`] (same flat-forest path, same clamp) —
    /// the confidence layer annotates it and never perturbs it.  Cold
    /// start / UILO return a fully-confident one-hot (there is no vote
    /// spread to measure), so untrained predictors behave like the point
    /// pipeline.
    pub fn predict_with_confidence<'a>(
        &mut self,
        req: impl Into<RequestView<'a>>,
        quantile: f32,
    ) -> PredictionWithConfidence {
        let req: RequestView<'a> = req.into();
        let point = self.predict(req);
        let mut votes = std::mem::take(&mut self.vote_buf);
        let trained = self.tree_predictions(req, &mut votes);
        let pwc = if trained {
            traits::prediction_from_votes(point, &votes, self.g_max, quantile)
        } else {
            PredictionWithConfidence::certain(point, self.g_max)
        };
        self.vote_buf = votes;
        pwc
    }

    /// The generation-length cap every prediction is clamped to.
    pub fn g_max(&self) -> u32 {
        self.g_max
    }

    /// The trained INST/USIN forest, if any (benches and golden tests
    /// drive the reference traversal through it).
    pub fn global_forest(&self) -> Option<&Forest> {
        self.global.as_ref()
    }

    /// Current training-set size (for continuous-learning telemetry).
    pub fn train_size(&self) -> usize {
        self.train_y.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::rmse;
    use crate::workload::dataset::build_predictor_split;
    use crate::workload::LlmProfile;

    fn eval_rmse(variant: Variant, n_train: usize, n_test: usize) -> f64 {
        let cfg = ServingConfig::default();
        let split =
            build_predictor_split(LlmProfile::ChatGlm6B, n_train, n_test, 1024, 11);
        let mut p = GenLenPredictor::new(variant, &cfg);
        p.train(&split.train);
        let pred: Vec<f64> = split
            .test
            .iter()
            .map(|r| p.predict(r) as f64)
            .collect();
        let actual: Vec<f64> =
            split.test.iter().map(|r| r.gen_len as f64).collect();
        rmse(&pred, &actual)
    }

    #[test]
    fn table2_ordering_uilo_worst_usin_best() {
        // Table II: UILO >> RAFT ≈ INST > USIN.
        let uilo = eval_rmse(Variant::Uilo, 300, 80);
        let raft = eval_rmse(Variant::Raft, 300, 80);
        let usin = eval_rmse(Variant::Usin, 300, 80);
        assert!(uilo > raft * 1.2, "uilo={uilo} raft={raft}");
        assert!(usin <= raft * 1.05, "usin={usin} raft={raft}");
    }

    #[test]
    fn predictions_clamped() {
        let cfg = ServingConfig::default();
        let split = build_predictor_split(LlmProfile::ChatGlm6B, 50, 10, 1024, 12);
        let mut p = GenLenPredictor::new(Variant::Usin, &cfg);
        p.train(&split.train);
        for r in &split.test {
            let g = p.predict(r);
            assert!(g >= 1 && g <= cfg.gpu.g_max);
        }
    }

    #[test]
    fn cold_start_falls_back_to_uil() {
        let cfg = ServingConfig::default();
        let split = build_predictor_split(LlmProfile::ChatGlm6B, 10, 5, 1024, 13);
        let mut p = GenLenPredictor::new(Variant::Usin, &cfg);
        let r = &split.test[0];
        assert_eq!(p.predict(r), r.user_input_len.clamp(1, cfg.gpu.g_max));
    }

    #[test]
    fn augmentation_grows_train_set_and_helps() {
        let cfg = ServingConfig::default();
        let split = build_predictor_split(LlmProfile::ChatGlm6B, 40, 100, 1024, 14);
        let mut p = GenLenPredictor::new(Variant::Usin, &cfg);
        p.train(&split.train);
        let before_n = p.train_size();
        let extra = build_predictor_split(LlmProfile::ChatGlm6B, 150, 1, 1024, 15).train;
        p.augment_and_refit(&extra);
        assert!(p.train_size() > before_n);
    }

    #[test]
    fn predict_many_matches_predict() {
        let cfg = ServingConfig::default();
        let split = build_predictor_split(LlmProfile::ChatGlm6B, 60, 30, 1024, 16);
        for v in [Variant::Uilo, Variant::Raft, Variant::Inst, Variant::Usin] {
            let mut p = GenLenPredictor::new(v, &cfg);
            p.train(&split.train);
            let refs: Vec<&Request> = split.test.iter().collect();
            let mut out = Vec::new();
            p.predict_many(&refs, &mut out);
            assert_eq!(out.len(), split.test.len());
            for (r, &got) in split.test.iter().zip(&out) {
                assert_eq!(got, p.predict(r), "{}", v.name());
            }
        }
    }

    #[test]
    fn confidence_annotates_without_perturbing_the_point() {
        let cfg = ServingConfig::default();
        let split = build_predictor_split(LlmProfile::ChatGlm6B, 80, 30, 1024, 18);
        let mut p = GenLenPredictor::new(Variant::Usin, &cfg);
        p.train(&split.train);
        for r in &split.test {
            let pwc = p.predict_with_confidence(r, 0.9);
            assert_eq!(pwc.point, p.predict(r));
            let sum: f32 = pwc.per_bucket_probs.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "probs sum {sum}");
            assert!(pwc.confidence > 0.0 && pwc.confidence <= 1.0);
            assert!(pwc.upper_quantile >= pwc.point);
            assert!(pwc.upper_quantile <= cfg.gpu.g_max);
        }
    }

    #[test]
    fn cold_start_confidence_is_a_one_hot() {
        let cfg = ServingConfig::default();
        let split = build_predictor_split(LlmProfile::ChatGlm6B, 10, 4, 1024, 19);
        let mut p = GenLenPredictor::new(Variant::Usin, &cfg);
        for r in &split.test {
            let pwc = p.predict_with_confidence(r, 0.9);
            assert_eq!(pwc.point, p.predict(r));
            assert_eq!(pwc.confidence, 1.0);
            assert_eq!(pwc.upper_quantile, pwc.point);
            assert_eq!(pwc.per_bucket_probs[pwc.bucket], 1.0);
        }
    }

    #[test]
    fn predict_many_cold_start_falls_back() {
        let cfg = ServingConfig::default();
        let split = build_predictor_split(LlmProfile::ChatGlm6B, 10, 6, 1024, 17);
        let mut p = GenLenPredictor::new(Variant::Usin, &cfg);
        let refs: Vec<&Request> = split.test.iter().collect();
        let mut out = Vec::new();
        p.predict_many(&refs, &mut out);
        for (r, &got) in split.test.iter().zip(&out) {
            assert_eq!(got, r.user_input_len.clamp(1, cfg.gpu.g_max));
        }
    }
}
