//! Generation-length prediction (paper §III-B): from-scratch CART +
//! random forest over a column-major dataset view, a flattened SoA
//! inference layout, the four Table-II feature variants, and the
//! predictor service with continuous learning.

pub mod data;
pub mod drift;
pub mod fallback;
pub mod features;
pub mod flat;
pub mod forest;
pub mod glp;
pub mod traits;
pub mod tree;

pub use data::ColMatrix;
pub use drift::{uil_tier, DriftConfig, DriftDetector, DriftEvent, N_UIL_TIERS};
pub use fallback::{fallback_prediction, predict_degraded, FallbackMode};
pub use features::{FeatureExtractor, Variant};
pub use flat::FlatForest;
pub use forest::{Forest, ForestParams};
pub use glp::GenLenPredictor;
pub use traits::{
    bucket_of, bucket_upper, bucket_width, make_length_predictor, prediction_from_votes,
    BucketClassifierPredictor, LengthPredictor, PredictionWithConfidence,
    LENGTH_PREDICTOR_NAMES, N_BUCKETS,
};
pub use tree::{Tree, TreeParams};
