//! Generation-length prediction (paper §III-B): from-scratch CART +
//! random forest over a column-major dataset view, a flattened SoA
//! inference layout, the four Table-II feature variants, and the
//! predictor service with continuous learning.

pub mod data;
pub mod fallback;
pub mod features;
pub mod flat;
pub mod forest;
pub mod glp;
pub mod tree;

pub use data::ColMatrix;
pub use fallback::{fallback_prediction, predict_degraded, FallbackMode};
pub use features::{FeatureExtractor, Variant};
pub use flat::FlatForest;
pub use forest::{Forest, ForestParams};
pub use glp::GenLenPredictor;
pub use tree::{Tree, TreeParams};
