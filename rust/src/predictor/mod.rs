//! Generation-length prediction (paper §III-B): from-scratch CART +
//! random forest, the four Table-II feature variants, and the predictor
//! service with continuous learning.

pub mod features;
pub mod forest;
pub mod glp;
pub mod tree;

pub use features::Variant;
pub use forest::{Forest, ForestParams};
pub use glp::GenLenPredictor;
pub use tree::{Tree, TreeParams};
