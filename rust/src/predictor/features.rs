//! Feature pipelines for the four predictor variants of Table II.
//!
//! * **UILO** — no features: the user-input length *is* the prediction.
//! * **RAFT** — one forest per task, feature = [UIL].
//! * **INST** — single forest, features = [UIL] ++ compress(E(instruction), 4).
//! * **USIN** — INST features ++ compress(E(user input), 16) — the full
//!   Magnus predictor (Fig. 8).

use std::collections::HashMap;

use crate::embedding::{compress, Embedder, D_APP, D_USER};
use crate::workload::Request;

/// Which predictor variant (Table II row).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    Uilo,
    Raft,
    Inst,
    Usin,
}

impl Variant {
    pub const ALL: [Variant; 4] =
        [Variant::Uilo, Variant::Raft, Variant::Inst, Variant::Usin];

    pub fn name(&self) -> &'static str {
        match self {
            Variant::Uilo => "UILO",
            Variant::Raft => "RAFT",
            Variant::Inst => "INST",
            Variant::Usin => "USIN",
        }
    }

    /// Feature dimensionality (0 for UILO which has no regressor).
    pub fn dim(&self) -> usize {
        match self {
            Variant::Uilo => 0,
            Variant::Raft => 1,
            Variant::Inst => 1 + D_APP,
            Variant::Usin => 1 + D_APP + D_USER,
        }
    }
}

/// Feature extractor with an instruction-embedding cache (there are only a
/// handful of distinct instructions — embedding them once mirrors how the
/// paper batches LaBSE calls).
pub struct FeatureExtractor {
    embedder: Embedder,
    instr_cache: HashMap<String, Vec<f32>>,
}

impl Default for FeatureExtractor {
    fn default() -> Self {
        Self::new()
    }
}

impl FeatureExtractor {
    pub fn new() -> Self {
        FeatureExtractor {
            embedder: Embedder::new(),
            instr_cache: HashMap::new(),
        }
    }

    fn instr_features(&mut self, instruction: &str) -> Vec<f32> {
        if let Some(v) = self.instr_cache.get(instruction) {
            return v.clone();
        }
        let emb = self.embedder.embed(instruction);
        let c = compress(&emb, D_APP);
        self.instr_cache.insert(instruction.to_string(), c.clone());
        c
    }

    /// Build the feature row for `variant` (panics for UILO, which has no
    /// regressor input).
    pub fn features(&mut self, variant: Variant, req: &Request) -> Vec<f32> {
        match variant {
            Variant::Uilo => panic!("UILO has no feature pipeline"),
            Variant::Raft => vec![req.user_input_len as f32],
            Variant::Inst => {
                let mut row = Vec::with_capacity(1 + D_APP);
                row.push(req.user_input_len as f32);
                row.extend(self.instr_features(&req.instruction));
                row
            }
            Variant::Usin => {
                let mut row = Vec::with_capacity(1 + D_APP + D_USER);
                row.push(req.user_input_len as f32);
                row.extend(self.instr_features(&req.instruction));
                let ue = self.embedder.embed(&req.user_input);
                row.extend(compress(&ue, D_USER));
                row
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::dataset::build_task_dataset;
    use crate::workload::{LlmProfile, TaskId};

    fn sample() -> Request {
        build_task_dataset(TaskId::Bf, LlmProfile::ChatGlm6B, 1, 1024, 1, 0)
            .pop()
            .unwrap()
    }

    #[test]
    fn dims_match_variant() {
        let mut fx = FeatureExtractor::new();
        let r = sample();
        for v in [Variant::Raft, Variant::Inst, Variant::Usin] {
            assert_eq!(fx.features(v, &r).len(), v.dim());
        }
    }

    #[test]
    fn first_feature_is_uil() {
        let mut fx = FeatureExtractor::new();
        let r = sample();
        for v in [Variant::Raft, Variant::Inst, Variant::Usin] {
            assert_eq!(fx.features(v, &r)[0], r.user_input_len as f32);
        }
    }

    #[test]
    fn same_task_shares_instruction_features() {
        let mut fx = FeatureExtractor::new();
        let rs = build_task_dataset(TaskId::Gc, LlmProfile::ChatGlm6B, 2, 1024, 2, 0);
        let a = fx.features(Variant::Inst, &rs[0]);
        let b = fx.features(Variant::Inst, &rs[1]);
        assert_eq!(a[1..], b[1..]);
    }

    #[test]
    fn different_tasks_differ_in_instruction_features() {
        let mut fx = FeatureExtractor::new();
        let a_req = build_task_dataset(TaskId::Gc, LlmProfile::ChatGlm6B, 1, 1024, 3, 0)
            .pop()
            .unwrap();
        let b_req = build_task_dataset(TaskId::Cc, LlmProfile::ChatGlm6B, 1, 1024, 3, 0)
            .pop()
            .unwrap();
        let a = fx.features(Variant::Inst, &a_req);
        let b = fx.features(Variant::Inst, &b_req);
        assert_ne!(a[1..], b[1..]);
    }

    #[test]
    #[should_panic]
    fn uilo_has_no_features() {
        let mut fx = FeatureExtractor::new();
        fx.features(Variant::Uilo, &sample());
    }
}
