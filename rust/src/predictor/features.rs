//! Feature pipelines for the four predictor variants of Table II.
//!
//! * **UILO** — no features: the user-input length *is* the prediction.
//! * **RAFT** — one forest per task, feature = [UIL].
//! * **INST** — single forest, features = [UIL] ++ compress(E(instruction), 4).
//! * **USIN** — INST features ++ compress(E(user input), 16) — the full
//!   Magnus predictor (Fig. 8).
//!
//! The hot path is [`FeatureExtractor::features_into`]: it writes into a
//! caller-provided row, copies the cached instruction features from a
//! borrowed row (no clone), and runs the user-input embedding through
//! the fused zero-alloc [`Embedder::embed_compress_into`] with a reused
//! scratch buffer.  Inputs arrive as [`RequestView`]s — borrowed `&str`
//! slices, on the serving path straight out of the `TraceStore` arena —
//! so the whole pipeline touches no owned request text; `&Request`
//! converts implicitly for dataset/golden callers.  The pre-overhaul
//! allocating pipeline is kept as
//! [`FeatureExtractor::features_baseline`] — the measured baseline for
//! `benches/bench_predictor.rs`, bit-identical by construction (tested).

use std::collections::HashMap;

use crate::embedding::{compress, Embedder, D_APP, D_USER};
use crate::workload::RequestView;

/// Entry cap of the user-embedding cache; at ~16 floats per entry the
/// cache tops out around half a megabyte, then drops wholesale (the
/// trace workloads repeat texts via retries/requeues and the continuous-
/// learning absorb path, so recency is a fine eviction proxy).
const USER_CACHE_CAP: usize = 8192;

/// Which predictor variant (Table II row).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    Uilo,
    Raft,
    Inst,
    Usin,
}

impl Variant {
    pub const ALL: [Variant; 4] =
        [Variant::Uilo, Variant::Raft, Variant::Inst, Variant::Usin];

    pub fn name(&self) -> &'static str {
        match self {
            Variant::Uilo => "UILO",
            Variant::Raft => "RAFT",
            Variant::Inst => "INST",
            Variant::Usin => "USIN",
        }
    }

    /// Feature dimensionality (0 for UILO which has no regressor).
    pub fn dim(&self) -> usize {
        match self {
            Variant::Uilo => 0,
            Variant::Raft => 1,
            Variant::Inst => 1 + D_APP,
            Variant::Usin => 1 + D_APP + D_USER,
        }
    }
}

/// Feature extractor with an instruction-embedding cache (there are only
/// a handful of distinct instructions — embedding them once mirrors how
/// the paper batches LaBSE calls; a short linear-probed list beats
/// hashing the whole instruction string per lookup).
pub struct FeatureExtractor {
    embedder: Embedder,
    instr_cache: Vec<(String, Vec<f32>)>,
    /// Scratch: raw 768-bucket buffer reused across embeds.
    embed_buf: Vec<f32>,
    /// Compressed user-input embeddings keyed by the interned content
    /// hash (`RequestView::uih`) plus byte length (belt-and-braces
    /// against hash collisions aliasing different texts of equal hash
    /// but different length).  The hash is computed once at trace
    /// intern time, so a repeat predict/absorb/refit of the same text
    /// skips the per-predict rehash *and* the 768-bucket embed.
    /// Keyless views (`uih == 0`) bypass the cache entirely.
    user_cache: HashMap<(u64, u32), Vec<f32>>,
    user_cache_hits: u64,
}

impl Default for FeatureExtractor {
    fn default() -> Self {
        Self::new()
    }
}

impl FeatureExtractor {
    pub fn new() -> Self {
        FeatureExtractor {
            embedder: Embedder::new(),
            instr_cache: Vec::new(),
            embed_buf: Vec::new(),
            user_cache: HashMap::new(),
            user_cache_hits: 0,
        }
    }

    /// Hits served out of the user-embedding cache (telemetry/tests).
    pub fn user_cache_hits(&self) -> u64 {
        self.user_cache_hits
    }

    /// Distinct user texts currently cached.
    pub fn user_cache_len(&self) -> usize {
        self.user_cache.len()
    }

    /// Cache `instruction`'s compressed embedding if new; returns its
    /// index in the cache (one scan per call).
    fn ensure_instr(&mut self, instruction: &str) -> usize {
        if let Some(i) = self.instr_cache.iter().position(|(k, _)| k == instruction) {
            return i;
        }
        let mut c = Vec::with_capacity(D_APP);
        self.embedder
            .embed_compress_into(instruction, D_APP, &mut self.embed_buf, &mut c);
        self.instr_cache.push((instruction.to_string(), c));
        self.instr_cache.len() - 1
    }

    /// Build the feature row for `variant` into `row` (cleared first) —
    /// the zero-alloc hot path.  Accepts anything that converts to a
    /// [`RequestView`] (`&Request`, or a store view borrowing the arena).
    /// Panics for UILO, which has no regressor input.
    pub fn features_into<'a>(
        &mut self,
        variant: Variant,
        req: impl Into<RequestView<'a>>,
        row: &mut Vec<f32>,
    ) {
        let req: RequestView<'a> = req.into();
        row.clear();
        match variant {
            Variant::Uilo => panic!("UILO has no feature pipeline"),
            Variant::Raft => row.push(req.user_input_len as f32),
            Variant::Inst => {
                row.push(req.user_input_len as f32);
                let ci = self.ensure_instr(req.instruction);
                row.extend_from_slice(&self.instr_cache[ci].1);
            }
            Variant::Usin => {
                row.push(req.user_input_len as f32);
                let ci = self.ensure_instr(req.instruction);
                row.extend_from_slice(&self.instr_cache[ci].1);
                let key = (req.uih, req.user_input.len() as u32);
                if req.uih != 0 {
                    if let Some(cached) = self.user_cache.get(&key) {
                        // The embedder is a pure function of the text,
                        // so the cached floats are bit-identical to a
                        // fresh embed (asserted by the golden tests).
                        row.extend_from_slice(cached);
                        self.user_cache_hits += 1;
                        return;
                    }
                }
                let tail = row.len();
                self.embedder.embed_compress_into(
                    req.user_input,
                    D_USER,
                    &mut self.embed_buf,
                    row,
                );
                if req.uih != 0 {
                    if self.user_cache.len() >= USER_CACHE_CAP {
                        self.user_cache.clear();
                    }
                    self.user_cache.insert(key, row[tail..].to_vec());
                }
            }
        }
    }

    /// Allocating wrapper over [`FeatureExtractor::features_into`].
    pub fn features<'a>(
        &mut self,
        variant: Variant,
        req: impl Into<RequestView<'a>>,
    ) -> Vec<f32> {
        let mut row = Vec::with_capacity(variant.dim());
        self.features_into(variant, req, &mut row);
        row
    }

    /// The pre-overhaul pipeline (fresh `Vec` per call, cached-row clone,
    /// baseline embedder with per-bigram key concatenation), kept as the
    /// measured baseline for `benches/bench_predictor.rs`.  Bit-identical
    /// to [`FeatureExtractor::features_into`] — asserted by the golden
    /// tests.
    pub fn features_baseline<'a>(
        &mut self,
        variant: Variant,
        req: impl Into<RequestView<'a>>,
    ) -> Vec<f32> {
        let req: RequestView<'a> = req.into();
        match variant {
            Variant::Uilo => panic!("UILO has no feature pipeline"),
            Variant::Raft => vec![req.user_input_len as f32],
            Variant::Inst => {
                let mut row = Vec::with_capacity(1 + D_APP);
                row.push(req.user_input_len as f32);
                row.extend(self.instr_features_cloned(req.instruction));
                row
            }
            Variant::Usin => {
                let mut row = Vec::with_capacity(1 + D_APP + D_USER);
                row.push(req.user_input_len as f32);
                row.extend(self.instr_features_cloned(req.instruction));
                let ue = self.embedder.embed_baseline(req.user_input);
                row.extend(compress(&ue, D_USER));
                row
            }
        }
    }

    fn instr_features_cloned(&mut self, instruction: &str) -> Vec<f32> {
        let ci = self.ensure_instr(instruction);
        self.instr_cache[ci].1.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::dataset::build_task_dataset;
    use crate::workload::{LlmProfile, TaskId};

    fn sample() -> Request {
        build_task_dataset(TaskId::Bf, LlmProfile::ChatGlm6B, 1, 1024, 1, 0)
            .pop()
            .unwrap()
    }

    #[test]
    fn dims_match_variant() {
        let mut fx = FeatureExtractor::new();
        let r = sample();
        for v in [Variant::Raft, Variant::Inst, Variant::Usin] {
            assert_eq!(fx.features(v, &r).len(), v.dim());
        }
    }

    #[test]
    fn first_feature_is_uil() {
        let mut fx = FeatureExtractor::new();
        let r = sample();
        for v in [Variant::Raft, Variant::Inst, Variant::Usin] {
            assert_eq!(fx.features(v, &r)[0], r.user_input_len as f32);
        }
    }

    #[test]
    fn same_task_shares_instruction_features() {
        let mut fx = FeatureExtractor::new();
        let rs = build_task_dataset(TaskId::Gc, LlmProfile::ChatGlm6B, 2, 1024, 2, 0);
        let a = fx.features(Variant::Inst, &rs[0]);
        let b = fx.features(Variant::Inst, &rs[1]);
        assert_eq!(a[1..], b[1..]);
    }

    #[test]
    fn different_tasks_differ_in_instruction_features() {
        let mut fx = FeatureExtractor::new();
        let a_req = build_task_dataset(TaskId::Gc, LlmProfile::ChatGlm6B, 1, 1024, 3, 0)
            .pop()
            .unwrap();
        let b_req = build_task_dataset(TaskId::Cc, LlmProfile::ChatGlm6B, 1, 1024, 3, 0)
            .pop()
            .unwrap();
        let a = fx.features(Variant::Inst, &a_req);
        let b = fx.features(Variant::Inst, &b_req);
        assert_ne!(a[1..], b[1..]);
    }

    #[test]
    #[should_panic]
    fn uilo_has_no_features() {
        let mut fx = FeatureExtractor::new();
        fx.features(Variant::Uilo, &sample());
    }

    #[test]
    fn features_into_matches_baseline_bitwise() {
        let mut fx = FeatureExtractor::new();
        let rs = build_task_dataset(TaskId::Gc, LlmProfile::ChatGlm6B, 6, 1024, 5, 0);
        let mut row = Vec::new();
        for v in [Variant::Raft, Variant::Inst, Variant::Usin] {
            for r in &rs {
                let base = fx.features_baseline(v, r);
                fx.features_into(v, r, &mut row);
                assert_eq!(base.len(), row.len());
                for (a, b) in base.iter().zip(&row) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{}", v.name());
                }
            }
        }
    }

    #[test]
    fn user_embedding_cache_hits_on_repeat_and_stays_bitwise() {
        let mut fx = FeatureExtractor::new();
        let r = sample();
        let first = fx.features(Variant::Usin, &r);
        assert_eq!(fx.user_cache_hits(), 0);
        assert_eq!(fx.user_cache_len(), 1);
        let second = fx.features(Variant::Usin, &r);
        assert_eq!(fx.user_cache_hits(), 1);
        assert_eq!(first.len(), second.len());
        for (a, b) in first.iter().zip(&second) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Keyless views (uih == 0, synthetic metas) bypass the cache but
        // still produce the identical row through the live embed.
        let mut v = r.view();
        v.uih = 0;
        let mut row = Vec::new();
        fx.features_into(Variant::Usin, v, &mut row);
        assert_eq!(fx.user_cache_hits(), 1, "no hit without a key");
        assert_eq!(fx.user_cache_len(), 1, "nothing inserted without a key");
        assert_eq!(row, second);
    }

    #[test]
    fn features_into_reuses_buffer_cleanly() {
        let mut fx = FeatureExtractor::new();
        let r = sample();
        let mut row = vec![1.0; 64]; // stale content must be discarded
        fx.features_into(Variant::Usin, &r, &mut row);
        assert_eq!(row.len(), Variant::Usin.dim());
        let fresh = fx.features(Variant::Usin, &r);
        assert_eq!(row, fresh);
    }
}
