//! Per-app / per-user-tier prediction-drift detection (ISSUE 9c).
//!
//! The continuous-learning sweep already *repairs* the forest after the
//! workload shifts, but repair lags by a refit interval — between the
//! shift and the refit, every admission runs on systematically wrong
//! predictions (PR 6's chaos runs surface this as OOM storms).  This
//! module watches the **signed** prediction error of completed
//! generations, bucketed per (application, user-input-length tier), and
//! drives a small deterministic state machine:
//!
//! * **Healthy** — trained predictions serve admissions.  When any
//!   cell's signed-error EWMA exceeds the drift budget (after a minimum
//!   sample count, so cold cells can't trigger), the detector demotes.
//! * **Demoted** — admissions run the PR 6 fallback chain
//!   ([`FallbackMode::Heuristic`]: the UIL rung, which is immune to
//!   forest drift) for a fixed probation window of completions, giving
//!   the learner time to absorb + refit.  When the window drains, the
//!   detector re-promotes and resets every cell.
//!
//! Everything is integer/EWMA arithmetic off completion events — no
//! clocks, no randomness — so a seeded fault schedule replays the exact
//! demotion/re-promotion sequence bit-for-bit in sim, live server, edge
//! and cluster.

use crate::predictor::fallback::FallbackMode;
use crate::workload::App;

/// Number of user-input-length tiers each app's errors are bucketed
/// into (short / medium / long / very long prompts behave differently
/// under drift, so one shared EWMA would wash real shifts out).
pub const N_UIL_TIERS: usize = 4;

/// Tier of a user-input length (tokens).
#[inline]
pub fn uil_tier(uil: u32) -> usize {
    match uil {
        0..=63 => 0,
        64..=191 => 1,
        192..=511 => 2,
        _ => 3,
    }
}

/// Detector knobs — normally sourced from
/// [`UncertaintyConfig`](crate::config::UncertaintyConfig).
#[derive(Debug, Clone, Copy)]
pub struct DriftConfig {
    /// EWMA smoothing factor in (0, 1]; higher reacts faster.
    pub alpha: f64,
    /// Demote when a cell's |signed-error EWMA| exceeds this many tokens.
    pub budget_tokens: f64,
    /// Minimum completions in a cell before it may demote (cold-start
    /// noise guard).
    pub min_samples: u32,
    /// Completions to stay demoted before re-promoting.
    pub probation: u32,
}

impl Default for DriftConfig {
    fn default() -> DriftConfig {
        DriftConfig {
            alpha: 0.2,
            budget_tokens: 25.0,
            min_samples: 25,
            probation: 64,
        }
    }
}

/// What one completion observation did to the detector state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DriftEvent {
    None,
    /// A cell blew its budget: the predictor is demoted to the fallback
    /// chain for the probation window.
    Demoted,
    /// The probation window drained: trained predictions resume.
    Repromoted,
}

#[derive(Debug, Clone, Copy, Default)]
struct Cell {
    ewma: f64,
    n: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    Healthy,
    Demoted { remaining: u32 },
}

/// Windowed signed-error drift detector over `App::ALL × N_UIL_TIERS`
/// cells.
pub struct DriftDetector {
    cfg: DriftConfig,
    cells: Vec<Cell>,
    state: State,
    demotions: u32,
    repromotions: u32,
}

impl DriftDetector {
    pub fn new(cfg: DriftConfig) -> DriftDetector {
        DriftDetector {
            cfg,
            cells: vec![Cell::default(); App::ALL.len() * N_UIL_TIERS],
            state: State::Healthy,
            demotions: 0,
            repromotions: 0,
        }
    }

    /// Feed one completed generation: `signed_err = predicted − actual`
    /// (point estimate, not the conservatively charged value).  Returns
    /// what, if anything, the observation did to the detector state.
    pub fn observe(&mut self, app: App, uil: u32, signed_err: f64) -> DriftEvent {
        let cell = &mut self.cells[app.index() * N_UIL_TIERS + uil_tier(uil)];
        cell.n += 1;
        cell.ewma = if cell.n == 1 {
            signed_err
        } else {
            self.cfg.alpha * signed_err + (1.0 - self.cfg.alpha) * cell.ewma
        };
        match self.state {
            State::Healthy => {
                if cell.n >= u64::from(self.cfg.min_samples)
                    && cell.ewma.abs() > self.cfg.budget_tokens
                {
                    self.state = State::Demoted {
                        remaining: self.cfg.probation.max(1),
                    };
                    self.demotions += 1;
                    self.reset_cells();
                    DriftEvent::Demoted
                } else {
                    DriftEvent::None
                }
            }
            State::Demoted { remaining } => {
                let remaining = remaining - 1;
                if remaining == 0 {
                    self.state = State::Healthy;
                    self.repromotions += 1;
                    // Fresh cells: probation completions were served by
                    // the fallback rung, so their errors say nothing
                    // about the (possibly refitted) forest.
                    self.reset_cells();
                    DriftEvent::Repromoted
                } else {
                    self.state = State::Demoted { remaining };
                    DriftEvent::None
                }
            }
        }
    }

    fn reset_cells(&mut self) {
        for c in &mut self.cells {
            *c = Cell::default();
        }
    }

    /// The fallback rung admissions must use right now (`None` while
    /// healthy).  The UIL heuristic rung: cheap, forest-free, immune to
    /// the drift that tripped the budget.
    pub fn active_fallback(&self) -> Option<FallbackMode> {
        match self.state {
            State::Healthy => None,
            State::Demoted { .. } => Some(FallbackMode::Heuristic),
        }
    }

    pub fn is_demoted(&self) -> bool {
        matches!(self.state, State::Demoted { .. })
    }

    /// Total demotion events so far.
    pub fn demotions(&self) -> u32 {
        self.demotions
    }

    /// Total re-promotion events so far.
    pub fn repromotions(&self) -> u32 {
        self.repromotions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> DriftConfig {
        DriftConfig {
            alpha: 0.5,
            budget_tokens: 10.0,
            min_samples: 4,
            probation: 6,
        }
    }

    #[test]
    fn unbiased_errors_never_demote() {
        let mut d = DriftDetector::new(cfg());
        for i in 0..500 {
            let e = if i % 2 == 0 { 8.0 } else { -8.0 };
            assert_eq!(d.observe(App::MT, 30, e), DriftEvent::None);
        }
        assert!(!d.is_demoted());
        assert_eq!(d.demotions(), 0);
    }

    #[test]
    fn sustained_bias_demotes_then_probation_repromotes() {
        let mut d = DriftDetector::new(cfg());
        // Below min_samples nothing can fire, however large the bias.
        for _ in 0..3 {
            assert_eq!(d.observe(App::GC, 30, 100.0), DriftEvent::None);
        }
        assert_eq!(d.observe(App::GC, 30, 100.0), DriftEvent::Demoted);
        assert!(d.is_demoted());
        assert_eq!(d.active_fallback(), Some(FallbackMode::Heuristic));
        // Probation: exactly `probation` completions, then re-promote —
        // even if the observed errors are still large (they come from
        // the fallback rung, not the forest).
        for _ in 0..5 {
            assert_eq!(d.observe(App::GC, 30, 100.0), DriftEvent::None);
        }
        assert_eq!(d.observe(App::GC, 30, 100.0), DriftEvent::Repromoted);
        assert!(!d.is_demoted());
        assert_eq!(d.active_fallback(), None);
        assert_eq!((d.demotions(), d.repromotions()), (1, 1));
        // Cells were reset: the next demotion needs min_samples again.
        for _ in 0..3 {
            assert_eq!(d.observe(App::GC, 30, 100.0), DriftEvent::None);
        }
        assert_eq!(d.observe(App::GC, 30, 100.0), DriftEvent::Demoted);
        assert_eq!(d.demotions(), 2);
    }

    #[test]
    fn cells_are_keyed_per_app_and_tier() {
        let mut d = DriftDetector::new(cfg());
        // Alternate apps: each cell accumulates its own count, so the
        // budget trips at min_samples of the *biased* cell only.
        for _ in 0..3 {
            assert_eq!(d.observe(App::MT, 30, 50.0), DriftEvent::None);
            assert_eq!(d.observe(App::CC, 30, 0.0), DriftEvent::None);
        }
        assert_eq!(d.observe(App::MT, 30, 50.0), DriftEvent::Demoted);

        // Different UIL tiers of one app are independent cells too:
        // three short-prompt samples plus three long-prompt samples
        // leave both cells below min_samples.
        let mut d = DriftDetector::new(cfg());
        for _ in 0..3 {
            assert_eq!(d.observe(App::MT, 10, 50.0), DriftEvent::None);
            assert_eq!(d.observe(App::MT, 600, 50.0), DriftEvent::None);
        }
        assert!(!d.is_demoted());
        assert_eq!(d.observe(App::MT, 10, 50.0), DriftEvent::Demoted);
    }

    #[test]
    fn uil_tiers_partition_the_length_axis() {
        assert_eq!(uil_tier(0), 0);
        assert_eq!(uil_tier(63), 0);
        assert_eq!(uil_tier(64), 1);
        assert_eq!(uil_tier(191), 1);
        assert_eq!(uil_tier(192), 2);
        assert_eq!(uil_tier(511), 2);
        assert_eq!(uil_tier(512), 3);
        assert_eq!(uil_tier(u32::MAX), 3);
    }

    #[test]
    fn replay_is_deterministic() {
        let run = || {
            let mut d = DriftDetector::new(cfg());
            let mut events = Vec::new();
            for i in 0u64..200 {
                let app = App::ALL[(i % 6) as usize];
                let uil = (i * 37 % 700) as u32;
                let err = if i < 100 { 40.0 } else { -3.0 };
                events.push(d.observe(app, uil, err));
            }
            (events, d.demotions(), d.repromotions())
        };
        assert_eq!(run(), run());
    }
}
