//! Bagged random-forest regressor over [`Tree`] (sklearn stand-in).

use crate::predictor::tree::{Tree, TreeParams};
use crate::util::Rng;

/// Random-forest hyperparameters.
#[derive(Debug, Clone)]
pub struct ForestParams {
    pub n_trees: usize,
    pub tree: TreeParams,
    /// Bootstrap sample fraction (1.0 = n samples with replacement).
    pub bootstrap_frac: f64,
}

impl Default for ForestParams {
    fn default() -> Self {
        ForestParams {
            n_trees: 20,
            tree: TreeParams::default(),
            bootstrap_frac: 1.0,
        }
    }
}

/// A fitted random forest.
#[derive(Debug, Clone)]
pub struct Forest {
    trees: Vec<Tree>,
}

impl Forest {
    /// Fit on rows `x` (n × d), targets `y`.  `mtry = 0` considers ALL
    /// features at every split — the sklearn convention for regression
    /// forests (`max_features=1.0`), which matters here because the UIL
    /// feature dominates and must be splittable at every depth.
    pub fn fit(x: &[Vec<f32>], y: &[f32], params: &ForestParams, rng: &mut Rng) -> Forest {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty());
        let tree_params = params.tree.clone();
        let n_boot = ((x.len() as f64) * params.bootstrap_frac).round() as usize;
        let n_boot = n_boot.max(1);

        let trees = (0..params.n_trees)
            .map(|t| {
                let mut trng = rng.fork(t as u64);
                let bx: Vec<Vec<f32>>;
                let by: Vec<f32>;
                if params.n_trees == 1 {
                    // Single tree = plain CART on the full data.
                    bx = x.to_vec();
                    by = y.to_vec();
                } else {
                    let picks: Vec<usize> = (0..n_boot)
                        .map(|_| trng.range_usize(0, x.len()))
                        .collect();
                    bx = picks.iter().map(|&i| x[i].clone()).collect();
                    by = picks.iter().map(|&i| y[i]).collect();
                }
                Tree::fit(&bx, &by, &tree_params, &mut trng)
            })
            .collect();
        Forest { trees }
    }

    /// Mean prediction across trees.
    pub fn predict(&self, row: &[f32]) -> f32 {
        let s: f32 = self.trees.iter().map(|t| t.predict(row)).sum();
        s / self.trees.len() as f32
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::rmse;

    fn noisy_linear(n: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let x: Vec<Vec<f32>> = (0..n)
            .map(|_| vec![rng.range_f64(0.0, 100.0) as f32])
            .collect();
        let y: Vec<f32> = x
            .iter()
            .map(|r| 3.0 * r[0] + 10.0 + rng.normal_ms(0.0, 5.0) as f32)
            .collect();
        (x, y)
    }

    #[test]
    fn forest_beats_or_matches_noise_floor() {
        let (x, y) = noisy_linear(1000, 1);
        let (tx, ty) = noisy_linear(200, 2);
        let mut rng = Rng::new(3);
        let f = Forest::fit(&x, &y, &ForestParams::default(), &mut rng);
        let pred: Vec<f64> = tx.iter().map(|r| f.predict(r) as f64).collect();
        let actual: Vec<f64> = ty.iter().map(|&v| v as f64).collect();
        let e = rmse(&pred, &actual);
        // noise sigma is 5; a good fit should be within ~2x of it
        assert!(e < 12.0, "rmse={e}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = noisy_linear(200, 4);
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let f1 = Forest::fit(&x, &y, &ForestParams::default(), &mut r1);
        let f2 = Forest::fit(&x, &y, &ForestParams::default(), &mut r2);
        for probe in [0.0f32, 33.3, 99.0] {
            assert_eq!(f1.predict(&[probe]), f2.predict(&[probe]));
        }
    }

    #[test]
    fn single_tree_mode_uses_full_data() {
        let (x, y) = noisy_linear(100, 5);
        let mut rng = Rng::new(6);
        let f = Forest::fit(
            &x,
            &y,
            &ForestParams {
                n_trees: 1,
                ..Default::default()
            },
            &mut rng,
        );
        assert_eq!(f.n_trees(), 1);
    }

    #[test]
    fn multifeature_input_works() {
        let mut rng = Rng::new(7);
        let n = 400;
        let x: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..21).map(|_| rng.f64() as f32).collect())
            .collect();
        let y: Vec<f32> = x.iter().map(|r| r[0] * 50.0 + r[20] * 10.0).collect();
        let f = Forest::fit(&x, &y, &ForestParams::default(), &mut rng);
        let lo = f.predict(&vec![0.1; 21]);
        let hi = f.predict(&vec![0.9; 21]);
        assert!(hi > lo, "hi={hi} lo={lo}");
    }
}
