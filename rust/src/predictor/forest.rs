//! Bagged random-forest regressor over [`Tree`] (sklearn stand-in).
//!
//! Fitting runs over a column-major [`ColMatrix`] view with index-based
//! bootstrap (no sample row is ever cloned) and fits trees in parallel
//! via [`par_map`] when the job is big enough.  Determinism is preserved
//! by pre-forking one RNG per tree in tree order — exactly the stream
//! the serial loop draws — so parallel and serial fits produce identical
//! trees (property-tested in `tests/predictor_equivalence.rs`).  Fitted
//! trees are compiled once into a [`FlatForest`] for the predict hot
//! path; the node-enum trees are retained as the golden reference.

use crate::predictor::data::ColMatrix;
use crate::predictor::flat::FlatForest;
use crate::predictor::tree::{Tree, TreeParams};
use crate::util::par::par_map;
use crate::util::Rng;

/// Below this much work (selected rows × trees), thread-spawn overhead
/// beats the parallel win and the fit stays serial.  Results are
/// bit-identical either way; this only picks the cheaper schedule.
const PAR_FIT_MIN_WORK: usize = 20_000;

/// Random-forest hyperparameters.
#[derive(Debug, Clone)]
pub struct ForestParams {
    pub n_trees: usize,
    pub tree: TreeParams,
    /// Bootstrap sample fraction (1.0 = n samples with replacement).
    pub bootstrap_frac: f64,
}

impl Default for ForestParams {
    fn default() -> Self {
        ForestParams {
            n_trees: 20,
            tree: TreeParams::default(),
            bootstrap_frac: 1.0,
        }
    }
}

/// A fitted random forest: node-enum trees (reference) plus their
/// compiled flattened layout (hot path).
#[derive(Debug, Clone, PartialEq)]
pub struct Forest {
    trees: Vec<Tree>,
    flat: FlatForest,
}

impl Forest {
    /// Fit on row-major rows `x` (n × d), targets `y` — convenience
    /// wrapper over [`Forest::fit_view`].  `mtry = 0` considers ALL
    /// features at every split — the sklearn convention for regression
    /// forests (`max_features=1.0`), which matters here because the UIL
    /// feature dominates and must be splittable at every depth.
    pub fn fit(x: &[Vec<f32>], y: &[f32], params: &ForestParams, rng: &mut Rng) -> Forest {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty());
        let data = ColMatrix::from_rows(x);
        let idx: Vec<u32> = (0..x.len() as u32).collect();
        Forest::fit_view(&data, y, &idx, params, rng)
    }

    /// Fit on the rows of `data` selected by `idx`; `y` is indexed by
    /// dataset row id.  Bootstrap samples are index lists into `data` —
    /// no row is cloned — and trees fit in parallel when the job is big
    /// enough.
    pub fn fit_view(
        data: &ColMatrix,
        y: &[f32],
        idx: &[u32],
        params: &ForestParams,
        rng: &mut Rng,
    ) -> Forest {
        let parallel = idx.len().saturating_mul(params.n_trees) >= PAR_FIT_MIN_WORK;
        Forest::fit_view_mode(data, y, idx, params, rng, parallel)
    }

    /// [`Forest::fit_view`] with the serial/parallel choice made
    /// explicit (the equivalence property test runs both and asserts
    /// identical trees).
    pub fn fit_view_mode(
        data: &ColMatrix,
        y: &[f32],
        idx: &[u32],
        params: &ForestParams,
        rng: &mut Rng,
        parallel: bool,
    ) -> Forest {
        assert_eq!(data.n_rows(), y.len());
        assert!(!idx.is_empty());
        let tree_params = params.tree.clone();
        let n_boot = ((idx.len() as f64) * params.bootstrap_frac).round() as usize;
        let n_boot = n_boot.max(1);

        // One forked RNG per tree, in tree order — the same stream the
        // serial loop would draw, so scheduling cannot change the fit.
        let mut tree_rngs: Vec<Rng> =
            (0..params.n_trees).map(|t| rng.fork(t as u64)).collect();

        let fit_one = |trng: &mut Rng| -> Tree {
            let mut picks: Vec<u32>;
            if params.n_trees == 1 {
                // Single tree = plain CART on the full selection.
                picks = idx.to_vec();
            } else {
                picks = (0..n_boot)
                    .map(|_| idx[trng.range_usize(0, idx.len())])
                    .collect();
            }
            Tree::fit_view(data, y, &mut picks, &tree_params, trng)
        };

        let trees: Vec<Tree> = if parallel && params.n_trees > 1 {
            par_map(params.n_trees, |t| {
                let mut trng = tree_rngs[t].clone();
                fit_one(&mut trng)
            })
        } else {
            tree_rngs.iter_mut().map(fit_one).collect()
        };
        let flat = FlatForest::compile(&trees);
        Forest { trees, flat }
    }

    /// Mean prediction across trees (flattened SoA hot path).
    pub fn predict(&self, row: &[f32]) -> f32 {
        self.flat.predict(row)
    }

    /// Node-enum reference traversal — the golden baseline the flat
    /// layout is tested (and benched) against.
    pub fn predict_reference(&self, row: &[f32]) -> f32 {
        let s: f32 = self.trees.iter().map(|t| t.predict(row)).sum();
        s / self.trees.len() as f32
    }

    /// Batch predict over row-major `rows` (n × d) into `out` — see
    /// [`FlatForest::predict_many`].
    pub fn predict_many(&self, rows: &[f32], d: usize, out: &mut Vec<f32>) {
        self.flat.predict_many(rows, d, out)
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }

    /// The fitted node-enum trees (reference layout).
    pub fn trees(&self) -> &[Tree] {
        &self.trees
    }

    /// The compiled hot-path layout.
    pub fn flat(&self) -> &FlatForest {
        &self.flat
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::rmse;

    fn noisy_linear(n: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let x: Vec<Vec<f32>> = (0..n)
            .map(|_| vec![rng.range_f64(0.0, 100.0) as f32])
            .collect();
        let y: Vec<f32> = x
            .iter()
            .map(|r| 3.0 * r[0] + 10.0 + rng.normal_ms(0.0, 5.0) as f32)
            .collect();
        (x, y)
    }

    #[test]
    fn forest_beats_or_matches_noise_floor() {
        let (x, y) = noisy_linear(1000, 1);
        let (tx, ty) = noisy_linear(200, 2);
        let mut rng = Rng::new(3);
        let f = Forest::fit(&x, &y, &ForestParams::default(), &mut rng);
        let pred: Vec<f64> = tx.iter().map(|r| f.predict(r) as f64).collect();
        let actual: Vec<f64> = ty.iter().map(|&v| v as f64).collect();
        let e = rmse(&pred, &actual);
        // noise sigma is 5; a good fit should be within ~2x of it
        assert!(e < 12.0, "rmse={e}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = noisy_linear(200, 4);
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let f1 = Forest::fit(&x, &y, &ForestParams::default(), &mut r1);
        let f2 = Forest::fit(&x, &y, &ForestParams::default(), &mut r2);
        for probe in [0.0f32, 33.3, 99.0] {
            assert_eq!(f1.predict(&[probe]), f2.predict(&[probe]));
        }
    }

    #[test]
    fn single_tree_mode_uses_full_data() {
        let (x, y) = noisy_linear(100, 5);
        let mut rng = Rng::new(6);
        let f = Forest::fit(
            &x,
            &y,
            &ForestParams {
                n_trees: 1,
                ..Default::default()
            },
            &mut rng,
        );
        assert_eq!(f.n_trees(), 1);
    }

    #[test]
    fn multifeature_input_works() {
        let mut rng = Rng::new(7);
        let n = 400;
        let x: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..21).map(|_| rng.f64() as f32).collect())
            .collect();
        let y: Vec<f32> = x.iter().map(|r| r[0] * 50.0 + r[20] * 10.0).collect();
        let f = Forest::fit(&x, &y, &ForestParams::default(), &mut rng);
        let lo = f.predict(&vec![0.1; 21]);
        let hi = f.predict(&vec![0.9; 21]);
        assert!(hi > lo, "hi={hi} lo={lo}");
    }

    #[test]
    fn flat_predictions_match_reference_bitwise() {
        let (x, y) = noisy_linear(600, 8);
        let mut rng = Rng::new(10);
        let f = Forest::fit(&x, &y, &ForestParams::default(), &mut rng);
        let rows_flat: Vec<f32> = x.iter().flat_map(|r| r.iter().copied()).collect();
        let mut out = Vec::new();
        f.predict_many(&rows_flat, 1, &mut out);
        for (i, r) in x.iter().enumerate() {
            let reference = f.predict_reference(r);
            assert_eq!(f.predict(r).to_bits(), reference.to_bits());
            assert_eq!(out[i].to_bits(), reference.to_bits());
        }
    }

    #[test]
    fn parallel_and_serial_fit_identical() {
        let (x, y) = noisy_linear(400, 9);
        let data = ColMatrix::from_rows(&x);
        let idx: Vec<u32> = (0..x.len() as u32).collect();
        let p = ForestParams::default();
        let mut r1 = Rng::new(12);
        let mut r2 = Rng::new(12);
        let a = Forest::fit_view_mode(&data, &y, &idx, &p, &mut r1, false);
        let b = Forest::fit_view_mode(&data, &y, &idx, &p, &mut r2, true);
        assert_eq!(a, b);
    }
}
