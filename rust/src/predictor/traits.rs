//! Pluggable length prediction with uncertainty (ISSUE 9, ROADMAP item 3).
//!
//! The paper's predictor emits a single point estimate; every downstream
//! consumer (batcher packing, edge admission, cluster routing) silently
//! trusts it.  Proxy-model serving (arXiv:2404.08509) and entropy-guided
//! prediction reframe the problem as **bucketed classification with
//! confidence**: predict which of a few generation-length buckets a
//! request lands in, and how sure the model is.  This module is the
//! seam: a [`LengthPredictor`] trait whose output,
//! [`PredictionWithConfidence`], carries the point estimate *plus* a
//! per-bucket probability vector, a calibrated confidence, and an
//! upper-quantile token bound the schedulers can charge conservatively.
//!
//! Two registered implementations:
//!
//! * [`GenLenPredictor`] itself — the paper's point pipeline, adapted
//!   behind the trait with a fully-confident one-hot (bit-identical
//!   behaviour when the confidence layer is disabled).
//! * [`BucketClassifierPredictor`] — per-bucket vote shares from the
//!   forest's individual trees (each tree votes for the bucket its raw
//!   prediction falls in); confidence is the modal vote share and the
//!   upper quantile is the first bucket edge whose cumulative share
//!   reaches the configured quantile.
//!
//! The point estimate is **never** perturbed: both implementations
//! return exactly `GenLenPredictor::predict` as `point`, so enabling
//! confidence changes what schedulers *charge*, not what the predictor
//! *predicts*.

use crate::predictor::GenLenPredictor;
use crate::workload::RequestView;

/// Number of generation-length buckets the classifier view quantises
/// `[1, G_max]` into.  Eight keeps the per-bucket vote counts meaningful
/// for the default 24-tree forest while still separating short from
/// runaway generations.
pub const N_BUCKETS: usize = 8;

/// Width of one bucket in tokens (ceil division so the buckets cover
/// `[1, G_max]` exactly; never 0 even for degenerate `g_max`).
#[inline]
pub fn bucket_width(g_max: u32) -> u32 {
    (g_max.max(1) + N_BUCKETS as u32 - 1) / N_BUCKETS as u32
}

/// Bucket index of a generation length (`tokens` clamped to ≥ 1).
#[inline]
pub fn bucket_of(tokens: u32, g_max: u32) -> usize {
    ((tokens.max(1) - 1) / bucket_width(g_max)).min(N_BUCKETS as u32 - 1) as usize
}

/// Inclusive upper token edge of bucket `b` (capped at `G_max`).
#[inline]
pub fn bucket_upper(b: usize, g_max: u32) -> u32 {
    ((b as u32 + 1) * bucket_width(g_max)).min(g_max.max(1))
}

/// One uncertainty-annotated length prediction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PredictionWithConfidence {
    /// G'(p): the point estimate — identical to the plain predictor's
    /// output, clamped to `[1, G_max]`.
    pub point: u32,
    /// Bucket index of `point` (`bucket_of(point, g_max)`).
    pub bucket: usize,
    /// Per-bucket probability mass (sums to 1).
    pub per_bucket_probs: [f32; N_BUCKETS],
    /// Conservative token bound: the upper edge of the first bucket
    /// whose cumulative probability reaches the configured quantile
    /// (never below `point`).  Schedulers charge this instead of
    /// `point` for low-confidence requests.
    pub upper_quantile: u32,
    /// Modal bucket probability in `[0, 1]` — the calibration signal
    /// admission compares against its confidence threshold.
    pub confidence: f32,
}

impl PredictionWithConfidence {
    /// A fully-confident one-hot at `point` — what a point-only
    /// predictor (or a cold-start forest) reports.  `upper_quantile ==
    /// point`, so conservative charging is a no-op.
    pub fn certain(point: u32, g_max: u32) -> PredictionWithConfidence {
        let bucket = bucket_of(point, g_max);
        let mut probs = [0.0; N_BUCKETS];
        probs[bucket] = 1.0;
        PredictionWithConfidence {
            point,
            bucket,
            per_bucket_probs: probs,
            upper_quantile: point,
            confidence: 1.0,
        }
    }
}

/// Histogram per-tree raw votes into bucket shares and derive the
/// confidence annotation for `point`.  `votes` must be non-empty (the
/// caller checks trainedness first); raw votes are clamped exactly like
/// the point path before bucketing.
pub fn prediction_from_votes(
    point: u32,
    votes: &[f32],
    g_max: u32,
    quantile: f32,
) -> PredictionWithConfidence {
    debug_assert!(!votes.is_empty());
    let mut probs = [0.0f32; N_BUCKETS];
    let w = 1.0 / votes.len() as f32;
    for &raw in votes {
        let g = (raw.round().max(1.0) as u32).min(g_max.max(1));
        probs[bucket_of(g, g_max)] += w;
    }
    let confidence = probs.iter().copied().fold(0.0f32, f32::max);
    let mut cum = 0.0f32;
    let mut qb = N_BUCKETS - 1;
    for (b, &p) in probs.iter().enumerate() {
        cum += p;
        // Tiny epsilon so e.g. quantile 1.0 is reachable despite
        // accumulated float error in the shares.
        if cum + 1e-6 >= quantile {
            qb = b;
            break;
        }
    }
    PredictionWithConfidence {
        point,
        bucket: bucket_of(point, g_max),
        per_bucket_probs: probs,
        upper_quantile: bucket_upper(qb, g_max).max(point),
        confidence,
    }
}

/// The pluggable prediction interface the confidence-aware schedulers
/// consume.  Implementations must keep `point` identical to the plain
/// point pipeline — uncertainty annotates, it never re-predicts.
pub trait LengthPredictor {
    fn name(&self) -> &'static str;

    /// Predict one request with its uncertainty annotation.
    fn predict_with_confidence(&mut self, view: &RequestView<'_>) -> PredictionWithConfidence;

    /// Batched path over same-tick arrivals; the default loops, the
    /// point adapter overrides it with the flattened-forest batch
    /// kernel (`predict_many_views`).
    fn predict_many_with_confidence(
        &mut self,
        views: &[RequestView<'_>],
        out: &mut Vec<PredictionWithConfidence>,
    ) {
        out.clear();
        for v in views {
            out.push(self.predict_with_confidence(v));
        }
    }
}

/// The paper's point pipeline behind the trait: fully-confident one-hot
/// annotations, batched through `predict_many_views`.
impl LengthPredictor for GenLenPredictor {
    fn name(&self) -> &'static str {
        "point"
    }

    fn predict_with_confidence(&mut self, view: &RequestView<'_>) -> PredictionWithConfidence {
        let g_max = self.g_max();
        PredictionWithConfidence::certain(self.predict(*view), g_max)
    }

    fn predict_many_with_confidence(
        &mut self,
        views: &[RequestView<'_>],
        out: &mut Vec<PredictionWithConfidence>,
    ) {
        let mut points = Vec::with_capacity(views.len());
        self.predict_many_views(views, &mut points);
        let g_max = self.g_max();
        out.clear();
        out.extend(points.iter().map(|&p| PredictionWithConfidence::certain(p, g_max)));
    }
}

/// Bucket-classifier view of the forest: per-tree votes → bucket shares
/// → calibrated confidence and an upper-quantile token bound.
pub struct BucketClassifierPredictor {
    inner: GenLenPredictor,
    /// Cumulative vote share at which the upper bound stops (e.g. 0.9).
    quantile: f32,
}

impl BucketClassifierPredictor {
    pub fn new(inner: GenLenPredictor, quantile: f32) -> BucketClassifierPredictor {
        BucketClassifierPredictor { inner, quantile }
    }

    /// The wrapped point predictor (continuous learning still talks to
    /// the forest directly).
    pub fn inner_mut(&mut self) -> &mut GenLenPredictor {
        &mut self.inner
    }
}

impl LengthPredictor for BucketClassifierPredictor {
    fn name(&self) -> &'static str {
        "bucket-classifier"
    }

    fn predict_with_confidence(&mut self, view: &RequestView<'_>) -> PredictionWithConfidence {
        self.inner.predict_with_confidence(*view, self.quantile)
    }
}

/// Registered predictor kinds (`--predictor` style selection).
pub const LENGTH_PREDICTOR_NAMES: [&str; 2] = ["point", "bucket-classifier"];

/// Wrap a trained forest behind the named trait implementation.
pub fn make_length_predictor(
    kind: &str,
    inner: GenLenPredictor,
    quantile: f32,
) -> anyhow::Result<Box<dyn LengthPredictor>> {
    match kind {
        "point" => Ok(Box::new(inner)),
        "bucket-classifier" => Ok(Box::new(BucketClassifierPredictor::new(inner, quantile))),
        other => anyhow::bail!(
            "unknown length predictor `{other}` (want one of {})",
            LENGTH_PREDICTOR_NAMES.join(", ")
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServingConfig;
    use crate::predictor::Variant;
    use crate::workload::dataset::build_predictor_split;
    use crate::workload::LlmProfile;

    #[test]
    fn buckets_tile_the_generation_range() {
        for g_max in [1u32, 7, 8, 64, 1000, 1024] {
            assert!(bucket_width(g_max) >= 1);
            assert_eq!(bucket_of(1, g_max), 0);
            assert_eq!(bucket_upper(N_BUCKETS - 1, g_max), g_max.max(1));
            // An upper edge never maps past its own bucket (it may map
            // earlier when `g_max` caps several edges to the same
            // value), and edges are monotone non-decreasing.
            for b in 0..N_BUCKETS {
                assert!(bucket_of(bucket_upper(b, g_max), g_max) <= b);
                if b > 0 {
                    assert!(bucket_upper(b, g_max) >= bucket_upper(b - 1, g_max));
                }
            }
        }
        // Concrete case: g_max 1024 → width 128, token 128 in bucket 0,
        // token 129 in bucket 1, token 1024 in bucket 7.
        assert_eq!(bucket_width(1024), 128);
        assert_eq!(bucket_of(128, 1024), 0);
        assert_eq!(bucket_of(129, 1024), 1);
        assert_eq!(bucket_of(1024, 1024), 7);
        assert_eq!(bucket_upper(0, 1024), 128);
    }

    #[test]
    fn vote_histogram_calibrates_confidence_and_quantile() {
        // 24 votes, 18 in bucket 0 (≤128) and 6 in bucket 2 — the modal
        // share is 0.75 and the 0.9-quantile edge is bucket 2's.
        let votes: Vec<f32> = (0..18)
            .map(|_| 100.0)
            .chain((0..6).map(|_| 300.0))
            .collect();
        let pwc = prediction_from_votes(120, &votes, 1024, 0.9);
        assert_eq!(pwc.point, 120);
        assert_eq!(pwc.bucket, 0);
        assert!((pwc.confidence - 0.75).abs() < 1e-5);
        assert_eq!(pwc.upper_quantile, bucket_upper(2, 1024));
        // A lower quantile stops at the modal bucket.
        let pwc = prediction_from_votes(120, &votes, 1024, 0.5);
        assert_eq!(pwc.upper_quantile, bucket_upper(0, 1024));
        // The bound never undershoots the point.
        let pwc = prediction_from_votes(900, &votes, 1024, 0.5);
        assert_eq!(pwc.upper_quantile, 900);
    }

    #[test]
    fn unanimous_votes_are_fully_confident() {
        let votes = vec![64.0f32; 24];
        let pwc = prediction_from_votes(64, &votes, 1024, 0.9);
        assert!((pwc.confidence - 1.0).abs() < 1e-5);
        assert_eq!(pwc.upper_quantile, bucket_upper(0, 1024));
    }

    #[test]
    fn point_adapter_is_a_confident_one_hot_and_batches() {
        let cfg = ServingConfig::default();
        let split = build_predictor_split(LlmProfile::ChatGlm6B, 60, 12, 1024, 21);
        let mut p = GenLenPredictor::new(Variant::Usin, &cfg);
        p.train(&split.train);
        let views: Vec<_> = split.test.iter().map(|r| r.view()).collect();
        let mut batched = Vec::new();
        LengthPredictor::predict_many_with_confidence(&mut p, &views, &mut batched);
        assert_eq!(batched.len(), views.len());
        for (v, b) in views.iter().zip(&batched) {
            let one = LengthPredictor::predict_with_confidence(&mut p, v);
            assert_eq!(one.point, b.point);
            assert_eq!(one.confidence, 1.0);
            assert_eq!(one.upper_quantile, one.point);
        }
    }

    #[test]
    fn bucket_classifier_keeps_the_point_estimate() {
        let cfg = ServingConfig::default();
        let split = build_predictor_split(LlmProfile::ChatGlm6B, 80, 20, 1024, 22);
        let mut point = GenLenPredictor::new(Variant::Usin, &cfg);
        point.train(&split.train);
        let mut trained = GenLenPredictor::new(Variant::Usin, &cfg);
        trained.train(&split.train);
        let mut bc = BucketClassifierPredictor::new(trained, 0.9);
        for r in &split.test {
            let v = r.view();
            let pwc = LengthPredictor::predict_with_confidence(&mut bc, &v);
            assert_eq!(pwc.point, point.predict(r), "bucket classifier moved the point");
            assert!(pwc.upper_quantile >= pwc.point);
            let sum: f32 = pwc.per_bucket_probs.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn registry_resolves_both_kinds_and_rejects_unknown() {
        let cfg = ServingConfig::default();
        for kind in LENGTH_PREDICTOR_NAMES {
            let p = GenLenPredictor::new(Variant::Uilo, &cfg);
            let boxed = make_length_predictor(kind, p, 0.9).unwrap();
            assert_eq!(boxed.name(), kind);
        }
        let p = GenLenPredictor::new(Variant::Uilo, &cfg);
        let err = make_length_predictor("oracle", p, 0.9).unwrap_err();
        assert!(err.to_string().contains("oracle"));
    }
}
