//! CART regression tree — the building block of the random-forest
//! generation-length predictor (sklearn stand-in, from scratch).
//!
//! Standard variance-reduction splitting over a column-major
//! [`ColMatrix`] view: at each node, a random subset of features is
//! scanned; for each candidate feature the node's rows are sorted by
//! value and the split that minimises the weighted sum of child
//! variances is found with prefix sums in O(n log n).  A node's sample
//! set is an index list partitioned in place over shared scratch
//! buffers — growing a tree never clones a sample row, and bootstrap
//! samples are index lists with repetition rather than copied rows.

use crate::predictor::data::ColMatrix;
use crate::util::Rng;

/// A fitted regression tree (node-enum array — the reference layout;
/// [`crate::predictor::FlatForest`] compiles it for the predict hot
/// path).
#[derive(Debug, Clone, PartialEq)]
pub struct Tree {
    nodes: Vec<Node>,
}

#[derive(Debug, Clone, PartialEq)]
pub(crate) enum Node {
    Leaf {
        value: f32,
    },
    Split {
        feature: usize,
        threshold: f32,
        /// child indices in `nodes`
        left: usize,
        right: usize,
    },
}

/// Tree-growing hyperparameters.
#[derive(Debug, Clone)]
pub struct TreeParams {
    pub max_depth: usize,
    pub min_samples_leaf: usize,
    /// Number of features considered per split (0 = all).
    pub mtry: usize,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams {
            max_depth: 12,
            min_samples_leaf: 3,
            mtry: 0,
        }
    }
}

struct Builder<'a> {
    data: &'a ColMatrix,
    y: &'a [f32],
    params: &'a TreeParams,
    nodes: Vec<Node>,
    rng: Rng,
    /// Scratch: candidate features per node (shuffled, truncated to mtry).
    feats: Vec<usize>,
    /// Scratch: per-feature sort buffer.
    order: Vec<u32>,
    /// Scratch: spill side of the stable in-place index partition.
    spill: Vec<u32>,
}

impl<'a> Builder<'a> {
    fn leaf(&mut self, idx: &[u32]) -> usize {
        let mean = idx.iter().map(|&i| self.y[i as usize]).sum::<f32>()
            / idx.len().max(1) as f32;
        self.nodes.push(Node::Leaf { value: mean });
        self.nodes.len() - 1
    }

    fn grow(&mut self, idx: &mut [u32], depth: usize) -> usize {
        let n = idx.len();
        if depth >= self.params.max_depth || n < 2 * self.params.min_samples_leaf {
            return self.leaf(idx);
        }
        // Early exit on pure nodes.
        let first = self.y[idx[0] as usize];
        if idx.iter().all(|&i| (self.y[i as usize] - first).abs() < 1e-9) {
            return self.leaf(idx);
        }

        let d = self.data.n_cols();
        let mtry = if self.params.mtry == 0 || self.params.mtry > d {
            d
        } else {
            self.params.mtry
        };
        // Sample candidate features without replacement.
        self.feats.clear();
        self.feats.extend(0..d);
        self.rng.shuffle(&mut self.feats);
        self.feats.truncate(mtry);

        let total_sum: f64 = idx.iter().map(|&i| self.y[i as usize] as f64).sum();
        let total_sq: f64 = idx
            .iter()
            .map(|&i| (self.y[i as usize] as f64).powi(2))
            .sum();
        let parent_score = total_sq - total_sum * total_sum / n as f64;

        let data = self.data;
        let mut best: Option<(f64, usize, f32)> = None; // (score, feature, thr)
        for fi in 0..self.feats.len() {
            let f = self.feats[fi];
            let col = data.col(f);
            self.order.clear();
            self.order.extend_from_slice(idx);
            // total_cmp: a NaN feature value must sort (to the end)
            // rather than panic mid-fit.
            self.order
                .sort_by(|&a, &b| col[a as usize].total_cmp(&col[b as usize]));
            let order = &self.order;
            let mut lsum = 0f64;
            let mut lsq = 0f64;
            for split_at in 1..n {
                let yi = self.y[order[split_at - 1] as usize] as f64;
                lsum += yi;
                lsq += yi * yi;
                let xv = col[order[split_at - 1] as usize];
                let xn = col[order[split_at] as usize];
                if xv == xn {
                    continue; // can't split between equal values
                }
                if split_at < self.params.min_samples_leaf
                    || n - split_at < self.params.min_samples_leaf
                {
                    continue;
                }
                let rsum = total_sum - lsum;
                let rsq = total_sq - lsq;
                let lscore = lsq - lsum * lsum / split_at as f64;
                let rscore = rsq - rsum * rsum / (n - split_at) as f64;
                let score = lscore + rscore;
                if best.map(|(s, _, _)| score < s).unwrap_or(true) {
                    best = Some((score, f, (xv + xn) * 0.5));
                }
            }
        }

        match best {
            Some((score, feature, threshold)) if score < parent_score - 1e-12 => {
                // Stable in-place partition: keeps the appearance order
                // on both sides (the order the old Vec partition
                // produced), spilling the right side through scratch.
                let col = data.col(feature);
                self.spill.clear();
                let mut n_left = 0usize;
                for k in 0..n {
                    let i = idx[k];
                    if col[i as usize] <= threshold {
                        idx[n_left] = i;
                        n_left += 1;
                    } else {
                        self.spill.push(i);
                    }
                }
                idx[n_left..].copy_from_slice(&self.spill);
                if n_left == 0 || n_left == n {
                    return self.leaf(idx);
                }
                let me = self.nodes.len();
                self.nodes.push(Node::Leaf { value: 0.0 }); // placeholder
                let (li, ri) = idx.split_at_mut(n_left);
                let left = self.grow(li, depth + 1);
                let right = self.grow(ri, depth + 1);
                self.nodes[me] = Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                };
                me
            }
            _ => self.leaf(idx),
        }
    }
}

impl Tree {
    /// Fit on row-major rows `x` (n × d) with targets `y` (n) —
    /// convenience wrapper that builds a column-major view first.
    pub fn fit(x: &[Vec<f32>], y: &[f32], params: &TreeParams, rng: &mut Rng) -> Tree {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty(), "cannot fit an empty tree");
        let data = ColMatrix::from_rows(x);
        let mut idx: Vec<u32> = (0..x.len() as u32).collect();
        Tree::fit_view(&data, y, &mut idx, params, rng)
    }

    /// Fit on the rows of `data` selected by `idx` (dataset row ids,
    /// with repetition for bootstrap samples; permuted in place while
    /// growing).  `y` is indexed by dataset row.  No row is ever cloned.
    pub fn fit_view(
        data: &ColMatrix,
        y: &[f32],
        idx: &mut [u32],
        params: &TreeParams,
        rng: &mut Rng,
    ) -> Tree {
        assert_eq!(data.n_rows(), y.len());
        assert!(!idx.is_empty(), "cannot fit an empty tree");
        let mut b = Builder {
            data,
            y,
            params,
            nodes: Vec::new(),
            rng: rng.fork(0x7265_6772),
            feats: Vec::with_capacity(data.n_cols()),
            order: Vec::with_capacity(idx.len()),
            spill: Vec::with_capacity(idx.len()),
        };
        let root = b.grow(idx, 0);
        debug_assert_eq!(root, 0);
        Tree { nodes: b.nodes }
    }

    /// Predict one row — the node-enum reference traversal (the hot path
    /// runs over [`crate::predictor::FlatForest`]'s compiled layout).
    pub fn predict(&self, row: &[f32]) -> f32 {
        let mut i = 0;
        loop {
            match &self.nodes[i] {
                Node::Leaf { value } => return *value,
                Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => {
                    i = if row[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub(crate) fn nodes(&self) -> &[Node] {
        &self.nodes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid_xy(f: impl Fn(f32) -> f32, n: usize) -> (Vec<Vec<f32>>, Vec<f32>) {
        let x: Vec<Vec<f32>> = (0..n).map(|i| vec![i as f32]).collect();
        let y: Vec<f32> = (0..n).map(|i| f(i as f32)).collect();
        (x, y)
    }

    #[test]
    fn fits_step_function_exactly() {
        let (x, y) = grid_xy(|v| if v < 50.0 { 1.0 } else { 9.0 }, 100);
        let mut rng = Rng::new(1);
        let t = Tree::fit(&x, &y, &TreeParams::default(), &mut rng);
        assert_eq!(t.predict(&[10.0]), 1.0);
        assert_eq!(t.predict(&[80.0]), 9.0);
    }

    #[test]
    fn approximates_linear_function() {
        let (x, y) = grid_xy(|v| 2.0 * v + 5.0, 200);
        let mut rng = Rng::new(2);
        let t = Tree::fit(&x, &y, &TreeParams::default(), &mut rng);
        for &probe in &[10.0f32, 100.0, 190.0] {
            let got = t.predict(&[probe]);
            let want = 2.0 * probe + 5.0;
            assert!((got - want).abs() < 20.0, "probe={probe} got={got}");
        }
    }

    #[test]
    fn respects_max_depth() {
        let (x, y) = grid_xy(|v| v, 512);
        let mut rng = Rng::new(3);
        let t = Tree::fit(
            &x,
            &y,
            &TreeParams {
                max_depth: 3,
                min_samples_leaf: 1,
                mtry: 0,
            },
            &mut rng,
        );
        // depth-3 binary tree has at most 15 nodes
        assert!(t.n_nodes() <= 15, "n_nodes={}", t.n_nodes());
    }

    #[test]
    fn constant_target_yields_single_leaf() {
        let (x, y) = grid_xy(|_| 4.25, 64);
        let mut rng = Rng::new(4);
        let t = Tree::fit(&x, &y, &TreeParams::default(), &mut rng);
        assert_eq!(t.n_nodes(), 1);
        assert_eq!(t.predict(&[7.0]), 4.25);
    }

    #[test]
    fn uses_informative_feature_among_noise() {
        // feature 1 is informative, features 0 and 2 are constant/noise
        let mut rng = Rng::new(5);
        let n = 300;
        let x: Vec<Vec<f32>> = (0..n)
            .map(|i| {
                vec![
                    0.5,
                    i as f32,
                    (rng.f64() as f32) * 0.001,
                ]
            })
            .collect();
        let y: Vec<f32> = (0..n).map(|i| if i < 150 { 0.0 } else { 10.0 }).collect();
        let t = Tree::fit(&x, &y, &TreeParams::default(), &mut rng);
        assert!((t.predict(&[0.5, 10.0, 0.0]) - 0.0).abs() < 1.0);
        assert!((t.predict(&[0.5, 290.0, 0.0]) - 10.0).abs() < 1.0);
    }

    #[test]
    fn bootstrap_view_uses_only_selected_rows() {
        // rows 0..50 map to 1.0, rows 50..100 to 9.0; fit on the low
        // half only — the tree must never see the high half.
        let (x, y) = grid_xy(|v| if v < 50.0 { 1.0 } else { 9.0 }, 100);
        let data = ColMatrix::from_rows(&x);
        let mut idx: Vec<u32> = (0..50).collect();
        let mut rng = Rng::new(6);
        let t = Tree::fit_view(&data, &y, &mut idx, &TreeParams::default(), &mut rng);
        assert_eq!(t.predict(&[80.0]), 1.0);
    }

    #[test]
    fn nan_feature_values_do_not_panic() {
        // total_cmp sort: a NaN feature value sorts instead of panicking
        // mid-fit, and the grown tree stays finite.
        let mut x: Vec<Vec<f32>> = (0..64).map(|i| vec![i as f32]).collect();
        x[10][0] = f32::NAN;
        x[40][0] = f32::NAN;
        let y: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let mut rng = Rng::new(11);
        let t = Tree::fit(&x, &y, &TreeParams::default(), &mut rng);
        assert!(t.n_nodes() >= 1);
        assert!(t.predict(&[5.0]).is_finite());
    }
}
