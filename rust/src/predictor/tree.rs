//! CART regression tree — the building block of the random-forest
//! generation-length predictor (sklearn stand-in, from scratch).
//!
//! Standard variance-reduction splitting: at each node, a random subset of
//! features is scanned; for each candidate feature the samples are sorted
//! by value and the split that minimises the weighted sum of child
//! variances is found with prefix sums in O(n log n).

use crate::util::Rng;

/// A fitted regression tree (flattened node array).
#[derive(Debug, Clone)]
pub struct Tree {
    nodes: Vec<Node>,
}

#[derive(Debug, Clone)]
enum Node {
    Leaf {
        value: f32,
    },
    Split {
        feature: usize,
        threshold: f32,
        /// child indices in `nodes`
        left: usize,
        right: usize,
    },
}

/// Tree-growing hyperparameters.
#[derive(Debug, Clone)]
pub struct TreeParams {
    pub max_depth: usize,
    pub min_samples_leaf: usize,
    /// Number of features considered per split (0 = all).
    pub mtry: usize,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams {
            max_depth: 12,
            min_samples_leaf: 3,
            mtry: 0,
        }
    }
}

struct Builder<'a> {
    x: &'a [Vec<f32>],
    y: &'a [f32],
    params: &'a TreeParams,
    nodes: Vec<Node>,
    rng: Rng,
}

impl<'a> Builder<'a> {
    fn leaf(&mut self, idx: &[usize]) -> usize {
        let mean = idx.iter().map(|&i| self.y[i]).sum::<f32>() / idx.len().max(1) as f32;
        self.nodes.push(Node::Leaf { value: mean });
        self.nodes.len() - 1
    }

    fn grow(&mut self, idx: &mut Vec<usize>, depth: usize) -> usize {
        let n = idx.len();
        if depth >= self.params.max_depth || n < 2 * self.params.min_samples_leaf {
            return self.leaf(idx);
        }
        // Early exit on pure nodes.
        let first = self.y[idx[0]];
        if idx.iter().all(|&i| (self.y[i] - first).abs() < 1e-9) {
            return self.leaf(idx);
        }

        let d = self.x[0].len();
        let mtry = if self.params.mtry == 0 || self.params.mtry > d {
            d
        } else {
            self.params.mtry
        };
        // Sample candidate features without replacement.
        let mut feats: Vec<usize> = (0..d).collect();
        self.rng.shuffle(&mut feats);
        feats.truncate(mtry);

        let total_sum: f64 = idx.iter().map(|&i| self.y[i] as f64).sum();
        let total_sq: f64 = idx.iter().map(|&i| (self.y[i] as f64).powi(2)).sum();
        let parent_score = total_sq - total_sum * total_sum / n as f64;

        let mut best: Option<(f64, usize, f32)> = None; // (score, feature, thr)
        let mut order: Vec<usize> = Vec::with_capacity(n);
        for &f in &feats {
            order.clear();
            order.extend_from_slice(idx);
            order.sort_by(|&a, &b| {
                self.x[a][f].partial_cmp(&self.x[b][f]).unwrap()
            });
            let mut lsum = 0f64;
            let mut lsq = 0f64;
            for split_at in 1..n {
                let yi = self.y[order[split_at - 1]] as f64;
                lsum += yi;
                lsq += yi * yi;
                let xv = self.x[order[split_at - 1]][f];
                let xn = self.x[order[split_at]][f];
                if xv == xn {
                    continue; // can't split between equal values
                }
                if split_at < self.params.min_samples_leaf
                    || n - split_at < self.params.min_samples_leaf
                {
                    continue;
                }
                let rsum = total_sum - lsum;
                let rsq = total_sq - lsq;
                let lscore = lsq - lsum * lsum / split_at as f64;
                let rscore = rsq - rsum * rsum / (n - split_at) as f64;
                let score = lscore + rscore;
                if best.map(|(s, _, _)| score < s).unwrap_or(true) {
                    best = Some((score, f, (xv + xn) * 0.5));
                }
            }
        }

        match best {
            Some((score, feature, threshold)) if score < parent_score - 1e-12 => {
                let (mut left_idx, mut right_idx): (Vec<usize>, Vec<usize>) =
                    idx.iter().partition(|&&i| self.x[i][feature] <= threshold);
                if left_idx.is_empty() || right_idx.is_empty() {
                    return self.leaf(idx);
                }
                let me = self.nodes.len();
                self.nodes.push(Node::Leaf { value: 0.0 }); // placeholder
                let left = self.grow(&mut left_idx, depth + 1);
                let right = self.grow(&mut right_idx, depth + 1);
                self.nodes[me] = Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                };
                me
            }
            _ => self.leaf(idx),
        }
    }
}

impl Tree {
    /// Fit a tree on rows `x` (n × d) with targets `y` (n).
    pub fn fit(x: &[Vec<f32>], y: &[f32], params: &TreeParams, rng: &mut Rng) -> Tree {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty(), "cannot fit an empty tree");
        let mut b = Builder {
            x,
            y,
            params,
            nodes: Vec::new(),
            rng: rng.fork(0x7265_6772),
        };
        let mut idx: Vec<usize> = (0..x.len()).collect();
        let root = b.grow(&mut idx, 0);
        debug_assert_eq!(root, 0);
        Tree { nodes: b.nodes }
    }

    /// Predict one row.
    pub fn predict(&self, row: &[f32]) -> f32 {
        let mut i = 0;
        loop {
            match &self.nodes[i] {
                Node::Leaf { value } => return *value,
                Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => {
                    i = if row[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid_xy(f: impl Fn(f32) -> f32, n: usize) -> (Vec<Vec<f32>>, Vec<f32>) {
        let x: Vec<Vec<f32>> = (0..n).map(|i| vec![i as f32]).collect();
        let y: Vec<f32> = (0..n).map(|i| f(i as f32)).collect();
        (x, y)
    }

    #[test]
    fn fits_step_function_exactly() {
        let (x, y) = grid_xy(|v| if v < 50.0 { 1.0 } else { 9.0 }, 100);
        let mut rng = Rng::new(1);
        let t = Tree::fit(&x, &y, &TreeParams::default(), &mut rng);
        assert_eq!(t.predict(&[10.0]), 1.0);
        assert_eq!(t.predict(&[80.0]), 9.0);
    }

    #[test]
    fn approximates_linear_function() {
        let (x, y) = grid_xy(|v| 2.0 * v + 5.0, 200);
        let mut rng = Rng::new(2);
        let t = Tree::fit(&x, &y, &TreeParams::default(), &mut rng);
        for &probe in &[10.0f32, 100.0, 190.0] {
            let got = t.predict(&[probe]);
            let want = 2.0 * probe + 5.0;
            assert!((got - want).abs() < 20.0, "probe={probe} got={got}");
        }
    }

    #[test]
    fn respects_max_depth() {
        let (x, y) = grid_xy(|v| v, 512);
        let mut rng = Rng::new(3);
        let t = Tree::fit(
            &x,
            &y,
            &TreeParams {
                max_depth: 3,
                min_samples_leaf: 1,
                mtry: 0,
            },
            &mut rng,
        );
        // depth-3 binary tree has at most 15 nodes
        assert!(t.n_nodes() <= 15, "n_nodes={}", t.n_nodes());
    }

    #[test]
    fn constant_target_yields_single_leaf() {
        let (x, y) = grid_xy(|_| 4.25, 64);
        let mut rng = Rng::new(4);
        let t = Tree::fit(&x, &y, &TreeParams::default(), &mut rng);
        assert_eq!(t.n_nodes(), 1);
        assert_eq!(t.predict(&[7.0]), 4.25);
    }

    #[test]
    fn uses_informative_feature_among_noise() {
        // feature 1 is informative, features 0 and 2 are constant/noise
        let mut rng = Rng::new(5);
        let n = 300;
        let x: Vec<Vec<f32>> = (0..n)
            .map(|i| {
                vec![
                    0.5,
                    i as f32,
                    (rng.f64() as f32) * 0.001,
                ]
            })
            .collect();
        let y: Vec<f32> = (0..n).map(|i| if i < 150 { 0.0 } else { 10.0 }).collect();
        let t = Tree::fit(&x, &y, &TreeParams::default(), &mut rng);
        assert!((t.predict(&[0.5, 10.0, 0.0]) - 0.0).abs() < 1.0);
        assert!((t.predict(&[0.5, 290.0, 0.0]) - 10.0).abs() < 1.0);
    }
}
