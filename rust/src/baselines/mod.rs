//! The paper's baselines (§IV-A), collected in one place.
//!
//! The actual serving loops live next to the simulator (`sim::vanilla`,
//! `sim::ccb`) because they share its event machinery; this module owns
//! the baseline *definitions* — batch sizes, engine wrappers — and
//! re-exports the runners so callers can write `baselines::vs(...)`.

use crate::config::ServingConfig;
use crate::engine::cost::CostModelEngine;
use crate::engine::quantized::QuantizedEngine;
use crate::metrics::RunMetrics;
use crate::sim::{ccb::run_ccb, vanilla::run_vanilla};
use crate::workload::Request;

/// Vanilla Scheduling: FCFS, fixed β from Eq. (1) (paper: 7).
pub fn vs(cfg: &ServingConfig, trace: &[Request]) -> RunMetrics {
    let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
    run_vanilla(cfg, cfg.gpu.vanilla_batch_size(), &engine, trace)
}

/// Vanilla Scheduling with 4-bit Quantization: fixed β = 10, slower
/// iterations, inflated generation lengths.
pub fn vsq(cfg: &ServingConfig, trace: &[Request]) -> RunMetrics {
    let engine = QuantizedEngine::new(
        CostModelEngine::new(cfg.cost.clone(), &cfg.gpu),
        cfg.quant.clone(),
    );
    run_vanilla(cfg, cfg.quant.batch_size, &engine, trace)
}

/// Conservative Continuous Batching: iteration-level scheduling with the
/// parallel-processing limit of Eq. (1)'s β (paper: 7).
pub fn ccb(cfg: &ServingConfig, trace: &[Request]) -> RunMetrics {
    let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
    run_ccb(cfg, cfg.gpu.vanilla_batch_size(), &engine, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate_trace, TraceSpec};

    #[test]
    fn all_baselines_complete_the_trace() {
        let cfg = ServingConfig::default();
        let trace = generate_trace(&TraceSpec {
            rate: 2.0,
            n_requests: 60,
            ..Default::default()
        });
        assert_eq!(vs(&cfg, &trace).records.len(), 60);
        assert_eq!(vsq(&cfg, &trace).records.len(), 60);
        assert_eq!(ccb(&cfg, &trace).records.len(), 60);
    }
}
