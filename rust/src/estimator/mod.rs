//! Serving-time estimation (paper §III-D): KNN regression on
//! (batch size, batch length, batch generation length) with continuous
//! learning, plus the generic KNN regressor it is built on.

pub mod knn;
pub mod serving_time;

pub use knn::Knn;
pub use serving_time::{BatchShape, ServingTimeEstimator};
