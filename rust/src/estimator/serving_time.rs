//! The serving-time estimator (paper §III-D).
//!
//! KNN regression from (batch size, batch length, batch generation length)
//! to batch serving time, trained on logged batch executions and refined by
//! continuous learning.  At estimation time the *predicted* batch
//! generation length (max of the batched requests' predicted G') is used —
//! the ground truth is only available after serving.

use crate::estimator::knn::Knn;

/// The feature triple of §III-D.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchShape {
    /// β — number of requests in the batch.
    pub batch_size: u32,
    /// L(B) — padded prompt length.
    pub batch_len: u32,
    /// G(B) — (predicted) batch generation length.
    pub batch_gen_len: u32,
}

impl BatchShape {
    /// Stack feature row — `estimate` sits on the dispatch hot path, so
    /// no per-call heap allocation.
    fn row(&self) -> [f32; 3] {
        [
            self.batch_size as f32,
            self.batch_len as f32,
            self.batch_gen_len as f32,
        ]
    }
}

/// Serving-time estimator service.
///
/// The model is a single incrementally-extended [`Knn`]: continuous
/// learning appends rows and renormalises via running moments instead of
/// refitting from scratch (which was O(n) per sweep, O(n²) cumulative
/// over a run).  Every model change bumps `generation`, which the
/// batcher's per-batch estimate cache uses as its invalidation key.
pub struct ServingTimeEstimator {
    knn: Option<Knn>,
    k: usize,
    generation: u64,
}

impl ServingTimeEstimator {
    pub fn new(k: usize) -> Self {
        ServingTimeEstimator {
            knn: None,
            k,
            generation: 0,
        }
    }

    /// Fit on logged (shape, serving time seconds) pairs.
    pub fn train(&mut self, shapes: &[BatchShape], times_s: &[f64]) {
        assert_eq!(shapes.len(), times_s.len());
        self.generation += 1;
        if shapes.is_empty() {
            self.knn = None;
            return;
        }
        let x: Vec<Vec<f32>> = shapes.iter().map(|s| s.row().to_vec()).collect();
        let y: Vec<f32> = times_s.iter().map(|&t| t as f32).collect();
        self.knn = Some(Knn::fit(&x, &y, self.k));
    }

    /// Continuous learning (§III-D): extend with badly-estimated batches.
    /// Incremental — O(new rows), not O(history).
    pub fn augment_and_refit(&mut self, shapes: &[BatchShape], times_s: &[f64]) {
        assert_eq!(shapes.len(), times_s.len());
        if shapes.is_empty() {
            return;
        }
        self.generation += 1;
        let x: Vec<Vec<f32>> = shapes.iter().map(|s| s.row().to_vec()).collect();
        let y: Vec<f32> = times_s.iter().map(|&t| t as f32).collect();
        match &mut self.knn {
            Some(m) => m.append(&x, &y),
            None => self.knn = Some(Knn::fit(&x, &y, self.k)),
        }
    }

    /// Model-change counter: bumped by every train/augment.  Cached
    /// estimates tagged with an older generation are stale.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Estimate the serving time of a queued batch in seconds.
    ///
    /// Cold start (no logged batches yet) falls back to a coarse
    /// G(B)-proportional guess — one decode iteration per generated token
    /// at a conservative 60 ms — so HRRN degrades gracefully instead of
    /// dividing by garbage.
    pub fn estimate(&self, shape: &BatchShape) -> f64 {
        match &self.knn {
            Some(m) => m.predict(&shape.row()).max(1e-3) as f64,
            None => 0.060 * shape.batch_gen_len.max(1) as f64,
        }
    }

    pub fn train_size(&self) -> usize {
        self.knn.as_ref().map_or(0, |m| m.len())
    }

    pub fn is_trained(&self) -> bool {
        self.knn.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Synthetic ground truth in the cost-model family:
    /// t = G·(0.05 + 0.002·β + 2e-6·β·(L+G/2)).
    fn synth_time(s: &BatchShape) -> f64 {
        let ctx = s.batch_len as f64 + s.batch_gen_len as f64 / 2.0;
        s.batch_gen_len as f64
            * (0.05 + 0.002 * s.batch_size as f64 + 2e-6 * s.batch_size as f64 * ctx)
    }

    fn synth_data(n: usize, seed: u64) -> (Vec<BatchShape>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let shapes: Vec<BatchShape> = (0..n)
            .map(|_| BatchShape {
                batch_size: rng.range_u64(1, 33) as u32,
                batch_len: rng.range_u64(8, 1025) as u32,
                batch_gen_len: rng.range_u64(4, 1025) as u32,
            })
            .collect();
        let times = shapes.iter().map(synth_time).collect();
        (shapes, times)
    }

    #[test]
    fn knn_estimates_within_20pct_on_dense_region() {
        let (shapes, times) = synth_data(4000, 1);
        let mut est = ServingTimeEstimator::new(5);
        est.train(&shapes, &times);
        let (probe, truth) = synth_data(200, 2);
        let mut ok = 0;
        for (s, t) in probe.iter().zip(&truth) {
            let e = est.estimate(s);
            if (e - t).abs() / t < 0.2 {
                ok += 1;
            }
        }
        // similar shapes → similar serving time (the paper's premise)
        assert!(ok >= 160, "only {ok}/200 within 20%");
    }

    #[test]
    fn cold_start_is_proportional_to_gen_len() {
        let est = ServingTimeEstimator::new(5);
        let a = est.estimate(&BatchShape {
            batch_size: 4,
            batch_len: 100,
            batch_gen_len: 10,
        });
        let b = est.estimate(&BatchShape {
            batch_size: 4,
            batch_len: 100,
            batch_gen_len: 100,
        });
        assert!(b > a * 5.0);
    }

    #[test]
    fn augmentation_improves_new_region() {
        // Train only on small batches, then augment with large ones.
        let (shapes, times) = synth_data(500, 3);
        let small: Vec<(BatchShape, f64)> = shapes
            .iter()
            .zip(&times)
            .filter(|(s, _)| s.batch_size <= 8)
            .map(|(s, t)| (*s, *t))
            .collect();
        let mut est = ServingTimeEstimator::new(5);
        est.train(
            &small.iter().map(|x| x.0).collect::<Vec<_>>(),
            &small.iter().map(|x| x.1).collect::<Vec<_>>(),
        );
        let big = BatchShape {
            batch_size: 30,
            batch_len: 900,
            batch_gen_len: 900,
        };
        let truth = synth_time(&big);
        let err_before = (est.estimate(&big) - truth).abs() / truth;
        let (ex, et) = synth_data(2000, 4);
        est.augment_and_refit(&ex, &et);
        let err_after = (est.estimate(&big) - truth).abs() / truth;
        assert!(err_after < err_before, "{err_after} !< {err_before}");
    }

    #[test]
    fn generation_tracks_model_changes() {
        let (shapes, times) = synth_data(50, 6);
        let mut est = ServingTimeEstimator::new(3);
        assert_eq!(est.generation(), 0);
        est.train(&shapes, &times);
        assert_eq!(est.generation(), 1);
        // empty augment is a no-op: cached estimates stay valid
        est.augment_and_refit(&[], &[]);
        assert_eq!(est.generation(), 1);
        est.augment_and_refit(&shapes[..5], &times[..5]);
        assert_eq!(est.generation(), 2);
        assert_eq!(est.train_size(), 55);
    }

    #[test]
    fn estimate_is_positive() {
        let (shapes, times) = synth_data(100, 5);
        let mut est = ServingTimeEstimator::new(3);
        est.train(&shapes, &times);
        assert!(est.estimate(&shapes[0]) > 0.0);
    }
}
