//! K-nearest-neighbour regressor (sklearn stand-in, from scratch).
//!
//! Distance-weighted KNN over z-score-normalised features, engineered for
//! the serving-time estimator's hot path:
//!
//! * rows live in one contiguous row-major buffer (no per-row `Vec`, no
//!   pointer chasing during scans);
//! * k-selection uses a bounded max-heap — O(n log k) worst case instead
//!   of a `sort_by` per candidate;
//! * normalisation is *virtual*: raw rows are stored once and distances
//!   are scaled by `1/σ` at query time, so refits never rewrite the
//!   buffer (the mean cancels inside the distance);
//! * continuous learning appends rows and updates running moments in
//!   O(d) — no denormalise-and-refit-from-scratch;
//! * a 3-d grid (bucket) index over raw space answers most queries by
//!   expanding Chebyshev rings of cells, with an exact stopping bound, and
//!   falls back to the brute-force scan for other dimensionalities or tiny
//!   train sets.  Grid answers are *identical* to brute force (property-
//!   tested): ties at the k boundary break by (distance, index) in both.

use std::collections::BinaryHeap;

/// Grid index kicks in at this many stored rows (below it, the flat scan
/// wins on constant factors — see benches/bench_estimator).
const GRID_MIN_POINTS: usize = 256;

/// A candidate neighbour; the heap keeps the k lexicographically smallest
/// (d2, idx) pairs with the largest on top.
#[derive(Debug, Clone, Copy)]
struct Cand {
    d2: f32,
    idx: u32,
}

impl PartialEq for Cand {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for Cand {}

impl Ord for Cand {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.d2.total_cmp(&other.d2).then(self.idx.cmp(&other.idx))
    }
}

impl PartialOrd for Cand {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Uniform 3-d bucket index over *raw* feature space.
///
/// Cell geometry is fixed at build time; the query-time metric (the
/// current `1/σ` scaling) only enters through the ring lower bound, so
/// the index survives normalisation drift from continuous learning.
/// Points outside the original bounding box clamp to edge cells, which
/// can only move them to *earlier* rings — the stopping bound stays a
/// true lower bound (see `ring_query`).
#[derive(Debug, Clone)]
struct Grid {
    dims: [usize; 3],
    lo: [f32; 3],
    /// Raw-space cell widths (sentinel 1.0 on degenerate dims).
    w: [f32; 3],
    cells: Vec<Vec<u32>>,
    /// Row count when the grid was (re)built; doubling triggers a rebuild
    /// so occupancy stays balanced (amortised O(log n) rebuilds).
    built_at_n: usize,
}

impl Grid {
    fn build(xs: &[f32], n: usize) -> Grid {
        let mut lo = [f32::INFINITY; 3];
        let mut hi = [f32::NEG_INFINITY; 3];
        for i in 0..n {
            for j in 0..3 {
                let v = xs[i * 3 + j];
                lo[j] = lo[j].min(v);
                hi[j] = hi[j].max(v);
            }
        }
        // ~8 points per cell on average, capped so the cell table stays
        // small even at large n.
        let r = (((n as f64) / 8.0).cbrt().ceil() as usize).clamp(1, 32);
        let mut dims = [1usize; 3];
        let mut w = [1.0f32; 3];
        for j in 0..3 {
            let extent = hi[j] - lo[j];
            if extent.is_finite() && extent > 0.0 {
                let wj = extent / r as f32;
                if wj > 0.0 && wj.is_finite() {
                    dims[j] = r;
                    w[j] = wj;
                }
            }
        }
        let mut grid = Grid {
            dims,
            lo,
            w,
            cells: vec![Vec::new(); dims[0] * dims[1] * dims[2]],
            built_at_n: n,
        };
        for i in 0..n {
            let p = [xs[i * 3], xs[i * 3 + 1], xs[i * 3 + 2]];
            grid.insert(p, i as u32);
        }
        grid
    }

    #[inline]
    fn coords(&self, p: [f32; 3]) -> [usize; 3] {
        let mut c = [0usize; 3];
        for j in 0..3 {
            let raw = ((p[j] - self.lo[j]) / self.w[j]).floor();
            // clamp handles out-of-box points AND the hi[j] boundary
            c[j] = if raw.is_finite() && raw > 0.0 {
                (raw as usize).min(self.dims[j] - 1)
            } else {
                0
            };
        }
        c
    }

    #[inline]
    fn cell_index(&self, c: [usize; 3]) -> usize {
        (c[0] * self.dims[1] + c[1]) * self.dims[2] + c[2]
    }

    fn insert(&mut self, p: [f32; 3], idx: u32) {
        let c = self.coords(p);
        let ci = self.cell_index(c);
        self.cells[ci].push(idx);
    }
}

/// KNN regression model.
#[derive(Debug, Clone)]
pub struct Knn {
    k: usize,
    d: usize,
    /// RAW rows, row-major, n × d.
    xs: Vec<f32>,
    y: Vec<f32>,
    /// Running per-feature moments (f64: no drift over many appends).
    sum: Vec<f64>,
    sumsq: Vec<f64>,
    /// Derived normalisation: per-feature mean and 1/std.
    mean: Vec<f32>,
    inv_std: Vec<f32>,
    grid: Option<Grid>,
}

impl Knn {
    /// Fit with `k` neighbours.
    pub fn fit(x: &[Vec<f32>], y: &[f32], k: usize) -> Knn {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty());
        assert!(k >= 1);
        let d = x[0].len();
        let mut m = Knn {
            k,
            d,
            xs: Vec::with_capacity(x.len() * d),
            y: Vec::new(),
            sum: vec![0.0; d],
            sumsq: vec![0.0; d],
            mean: vec![0.0; d],
            inv_std: vec![0.0; d],
            grid: None,
        };
        m.append(x, y);
        m
    }

    /// Append new samples and refresh normalisation in O(extra·d + d):
    /// running moments give the new (mean, std) directly, and since rows
    /// are stored raw nothing is rewritten.  The grid index absorbs the
    /// new points incrementally and rebuilds only when the model has
    /// doubled since the last build.
    pub fn append(&mut self, extra_x: &[Vec<f32>], extra_y: &[f32]) {
        assert_eq!(extra_x.len(), extra_y.len());
        if extra_x.is_empty() {
            return;
        }
        let start = self.len();
        for row in extra_x {
            assert_eq!(row.len(), self.d);
            for (j, v) in row.iter().enumerate() {
                self.sum[j] += *v as f64;
                self.sumsq[j] += (*v as f64) * (*v as f64);
            }
            self.xs.extend_from_slice(row);
        }
        self.y.extend_from_slice(extra_y);
        let n = self.len() as f64;
        for j in 0..self.d {
            let mean = self.sum[j] / n;
            let var = (self.sumsq[j] / n - mean * mean).max(0.0);
            let std = (var.sqrt() as f32).max(1e-6);
            self.mean[j] = mean as f32;
            self.inv_std[j] = 1.0 / std;
        }
        if self.d == 3 {
            let n = self.len();
            let rebuild = match &self.grid {
                None => n >= GRID_MIN_POINTS,
                Some(g) => n >= 2 * g.built_at_n,
            };
            if rebuild {
                self.grid = Some(Grid::build(&self.xs, n));
            } else if let Some(mut grid) = self.grid.take() {
                for i in start..n {
                    let p = [self.xs[i * 3], self.xs[i * 3 + 1], self.xs[i * 3 + 2]];
                    grid.insert(p, i as u32);
                }
                self.grid = Some(grid);
            }
        }
    }

    /// Append new samples into a copy (continuous-learning refit).  Kept
    /// for API compatibility; [`Knn::append`] is the in-place fast path.
    pub fn refit_with(&self, extra_x: &[Vec<f32>], extra_y: &[f32]) -> Knn {
        let mut m = self.clone();
        m.append(extra_x, extra_y);
        m
    }

    /// Squared z-scored distance between stored row `i` and query `row`
    /// (the mean cancels, so only the 1/σ scaling is applied).
    #[inline]
    fn d2(&self, i: usize, row: &[f32]) -> f32 {
        let base = i * self.d;
        let mut s = 0f32;
        for j in 0..self.d {
            let t = (self.xs[base + j] - row[j]) * self.inv_std[j];
            s += t * t;
        }
        s
    }

    /// Offer candidate `i` to a heap holding the k smallest (d2, idx).
    #[inline]
    fn consider(heap: &mut BinaryHeap<Cand>, k: usize, cand: Cand) {
        if heap.len() < k {
            heap.push(cand);
        } else if let Some(&top) = heap.peek() {
            if cand < top {
                heap.pop();
                heap.push(cand);
            }
        }
    }

    /// The k nearest stored rows, sorted ascending by (d2, idx).
    fn nearest(&self, row: &[f32]) -> Vec<Cand> {
        let k = self.k.min(self.len());
        let mut heap: BinaryHeap<Cand> = BinaryHeap::with_capacity(k + 1);
        match &self.grid {
            Some(grid) => self.ring_query(grid, row, k, &mut heap),
            None => {
                for i in 0..self.len() {
                    Self::consider(
                        &mut heap,
                        k,
                        Cand {
                            d2: self.d2(i, row),
                            idx: i as u32,
                        },
                    );
                }
            }
        }
        let mut out: Vec<Cand> = heap.into_vec();
        out.sort_unstable();
        out
    }

    /// Exact grid-accelerated k-selection: expand Chebyshev rings of
    /// cells around the query's (clamped) cell; points in any ring ≥ m
    /// are at least (m−1)·min_j(w_j/σ_j) away, so once the heap is full
    /// and its worst distance is under that bound the remaining rings
    /// cannot improve the answer.
    fn ring_query(&self, grid: &Grid, row: &[f32], k: usize, heap: &mut BinaryHeap<Cand>) {
        let q = [row[0], row[1], row[2]];
        let c = grid.coords(q);
        let mut max_r = 0usize;
        for j in 0..3 {
            max_r = max_r.max(c[j]).max(grid.dims[j] - 1 - c[j]);
        }
        // Lower-bound cell width in scaled space over the non-degenerate
        // dims (size-1 dims never separate rings, so they are excluded).
        let mut min_w_scaled = f32::INFINITY;
        for j in 0..3 {
            if grid.dims[j] > 1 {
                min_w_scaled = min_w_scaled.min(grid.w[j] * self.inv_std[j]);
            }
        }
        for r in 0..=max_r as isize {
            for dx in -r..=r {
                let x = c[0] as isize + dx;
                if x < 0 || x >= grid.dims[0] as isize {
                    continue;
                }
                for dy in -r..=r {
                    let y = c[1] as isize + dy;
                    if y < 0 || y >= grid.dims[1] as isize {
                        continue;
                    }
                    let on_shell = dx.abs() == r || dy.abs() == r;
                    let mut visit = |dz: isize| {
                        let z = c[2] as isize + dz;
                        if z < 0 || z >= grid.dims[2] as isize {
                            return;
                        }
                        let ci =
                            grid.cell_index([x as usize, y as usize, z as usize]);
                        for &idx in &grid.cells[ci] {
                            Self::consider(
                                heap,
                                k,
                                Cand {
                                    d2: self.d2(idx as usize, row),
                                    idx,
                                },
                            );
                        }
                    };
                    if on_shell {
                        for dz in -r..=r {
                            visit(dz);
                        }
                    } else if r > 0 {
                        visit(-r);
                        visit(r);
                    }
                }
            }
            if heap.len() == k && min_w_scaled.is_finite() {
                // Strict: an unvisited point at exactly the bound could
                // still tie-break its way into the k set.
                let bound = r as f32 * min_w_scaled;
                if let Some(top) = heap.peek() {
                    if top.d2 < bound * bound {
                        return;
                    }
                }
            }
        }
    }

    /// Distance-weighted mean of the k nearest targets.
    ///
    /// When every neighbour is so far that the inverse-distance weights
    /// underflow (or the distances overflow to ∞ — e.g. all-identical
    /// training points queried from far away), the weighted mean is
    /// 0/0 = NaN; this falls back to the unweighted neighbour mean.
    pub fn predict(&self, row: &[f32]) -> f32 {
        assert_eq!(row.len(), self.d);
        let best = self.nearest(row);
        self.weighted_mean(&best)
    }

    fn weighted_mean(&self, best: &[Cand]) -> f32 {
        let mut wsum = 0f32;
        let mut vsum = 0f32;
        for c in best {
            let w = 1.0 / (c.d2.sqrt() + 1e-6);
            wsum += w;
            vsum += w * self.y[c.idx as usize];
        }
        if wsum.is_finite() && wsum > f32::MIN_POSITIVE && vsum.is_finite() {
            vsum / wsum
        } else {
            let s: f32 = best.iter().map(|c| self.y[c.idx as usize]).sum();
            s / best.len() as f32
        }
    }

    /// Number of stored samples.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Whether the bucket index is active (diagnostics/benches).
    pub fn has_index(&self) -> bool {
        self.grid.is_some()
    }

    /// Brute-force reference prediction (ignores the grid index); used by
    /// the equivalence property tests and benches.
    pub fn predict_bruteforce(&self, row: &[f32]) -> f32 {
        assert_eq!(row.len(), self.d);
        let k = self.k.min(self.len());
        let mut heap: BinaryHeap<Cand> = BinaryHeap::with_capacity(k + 1);
        for i in 0..self.len() {
            Self::consider(
                &mut heap,
                k,
                Cand {
                    d2: self.d2(i, row),
                    idx: i as u32,
                },
            );
        }
        let mut best = heap.into_vec();
        best.sort_unstable();
        self.weighted_mean(&best)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use crate::util::Rng;

    #[test]
    fn exact_on_training_points() {
        let x = vec![vec![0.0], vec![10.0], vec![20.0]];
        let y = vec![1.0, 2.0, 3.0];
        let m = Knn::fit(&x, &y, 1);
        assert!((m.predict(&[10.0]) - 2.0).abs() < 1e-4);
    }

    #[test]
    fn interpolates_between_neighbours() {
        let x = vec![vec![0.0], vec![10.0]];
        let y = vec![0.0, 10.0];
        let m = Knn::fit(&x, &y, 2);
        let p = m.predict(&[5.0]);
        assert!((p - 5.0).abs() < 0.5, "p={p}");
    }

    #[test]
    fn scales_features() {
        // feature 1 has huge scale but no signal; normalisation must keep
        // feature 0 informative.
        let mut rng = Rng::new(1);
        let x: Vec<Vec<f32>> = (0..200)
            .map(|i| vec![i as f32, rng.range_f64(0.0, 1e6) as f32])
            .collect();
        let y: Vec<f32> = (0..200).map(|i| i as f32).collect();
        let m = Knn::fit(&x, &y, 3);
        let p = m.predict(&[100.0, 5e5]);
        assert!((p - 100.0).abs() < 20.0, "p={p}");
    }

    #[test]
    fn refit_with_extends_model() {
        let x = vec![vec![0.0], vec![1.0]];
        let y = vec![0.0, 1.0];
        let m = Knn::fit(&x, &y, 1);
        let m2 = m.refit_with(&[vec![100.0]], &[50.0]);
        assert_eq!(m2.len(), 3);
        assert!((m2.predict(&[100.0]) - 50.0).abs() < 1e-3);
    }

    #[test]
    fn k_larger_than_n_is_safe() {
        let x = vec![vec![0.0], vec![1.0]];
        let y = vec![2.0, 4.0];
        let m = Knn::fit(&x, &y, 10);
        let p = m.predict(&[0.5]);
        assert!(p > 2.0 && p < 4.0);
    }

    /// Regression (wsum underflow): all-identical training points have
    /// σ = ε, so a far query's scaled distances overflow to ∞, every
    /// weight collapses to 0 and the weighted mean used to be 0/0 = NaN.
    /// The guard must return the unweighted neighbour mean instead.
    #[test]
    fn far_query_on_identical_points_falls_back_to_mean() {
        let x = vec![vec![5.0], vec![5.0], vec![5.0]];
        let y = vec![1.0, 2.0, 3.0];
        let m = Knn::fit(&x, &y, 3);
        let p = m.predict(&[1e20]);
        assert!(p.is_finite(), "p={p}");
        assert!((p - 2.0).abs() < 1e-5, "p={p}");
    }

    #[test]
    fn incremental_append_matches_fresh_fit() {
        // Appending must yield the same predictions as one fresh fit on
        // the union (running moments ≡ full-pass moments).
        let mut rng = Rng::new(9);
        let gen_rows = |rng: &mut Rng, n: usize| -> (Vec<Vec<f32>>, Vec<f32>) {
            let x: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    vec![
                        rng.range_f64(1.0, 33.0) as f32,
                        rng.range_f64(8.0, 1025.0) as f32,
                        rng.range_f64(4.0, 1025.0) as f32,
                    ]
                })
                .collect();
            let y: Vec<f32> = x.iter().map(|r| r[0] + 0.01 * r[1] * r[2]).collect();
            (x, y)
        };
        let (x1, y1) = gen_rows(&mut rng, 400);
        let (x2, y2) = gen_rows(&mut rng, 150);
        let mut incremental = Knn::fit(&x1, &y1, 5);
        incremental.append(&x2, &y2);
        let union_x: Vec<Vec<f32>> = x1.iter().chain(&x2).cloned().collect();
        let union_y: Vec<f32> = y1.iter().chain(&y2).copied().collect();
        let fresh = Knn::fit(&union_x, &union_y, 5);
        let (probes, _) = gen_rows(&mut rng, 50);
        for p in &probes {
            let a = incremental.predict(p);
            let b = fresh.predict(p);
            assert!(
                (a - b).abs() <= 1e-4 * b.abs().max(1.0),
                "incremental {a} vs fresh {b}"
            );
        }
    }

    /// The grid index must be invisible: identical predictions to the
    /// brute-force scan on random 3-d data, including duplicated rows
    /// (distance ties) and out-of-box queries.
    #[test]
    fn grid_index_matches_bruteforce() {
        prop_check(20, |rng| {
            let n = rng.range_usize(GRID_MIN_POINTS, 1200);
            let x: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    // coarse rounding → plenty of exact duplicates
                    vec![
                        rng.range_u64(1, 33) as f32,
                        (rng.range_u64(1, 65) * 16) as f32,
                        (rng.range_u64(1, 65) * 16) as f32,
                    ]
                })
                .collect();
            let y: Vec<f32> = (0..n).map(|i| (i % 97) as f32).collect();
            let m = Knn::fit(&x, &y, 5);
            assert!(m.has_index(), "grid must be active at n={n}");
            for _ in 0..30 {
                let probe = vec![
                    rng.range_f64(-10.0, 50.0) as f32,
                    rng.range_f64(-100.0, 1500.0) as f32,
                    rng.range_f64(-100.0, 1500.0) as f32,
                ];
                let a = m.predict(&probe);
                let b = m.predict_bruteforce(&probe);
                assert!(
                    a.to_bits() == b.to_bits(),
                    "grid {a} != brute {b} at {probe:?}"
                );
            }
        });
    }

    #[test]
    fn grid_survives_incremental_appends() {
        let mut rng = Rng::new(17);
        let row = |rng: &mut Rng| {
            vec![
                rng.range_u64(1, 33) as f32,
                rng.range_u64(8, 1025) as f32,
                rng.range_u64(4, 1025) as f32,
            ]
        };
        let x: Vec<Vec<f32>> = (0..GRID_MIN_POINTS).map(|_| row(&mut rng)).collect();
        let y: Vec<f32> = (0..GRID_MIN_POINTS).map(|i| i as f32).collect();
        let mut m = Knn::fit(&x, &y, 5);
        // many small appends: insertions + at least one doubling rebuild
        for round in 0..20 {
            let ex: Vec<Vec<f32>> = (0..40).map(|_| row(&mut rng)).collect();
            let ey: Vec<f32> = (0..40).map(|i| (round * 40 + i) as f32).collect();
            m.append(&ex, &ey);
            let probe = row(&mut rng);
            let a = m.predict(&probe);
            let b = m.predict_bruteforce(&probe);
            assert!(a.to_bits() == b.to_bits(), "round {round}: {a} != {b}");
        }
        assert_eq!(m.len(), GRID_MIN_POINTS + 20 * 40);
    }
}
