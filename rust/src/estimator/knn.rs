//! K-nearest-neighbour regressor (sklearn stand-in, from scratch).
//!
//! Distance-weighted KNN over z-score-normalised features, engineered for
//! the serving-time estimator's hot path:
//!
//! * rows live in one contiguous row-major buffer (no per-row `Vec`, no
//!   pointer chasing during scans);
//! * k-selection uses a bounded max-heap — O(n log k) worst case instead
//!   of a `sort_by` per candidate;
//! * normalisation is *virtual*: raw rows are stored once and distances
//!   are scaled by `1/σ` at query time, so refits never rewrite the
//!   buffer (the mean cancels inside the distance);
//! * continuous learning appends rows and updates running moments in
//!   O(d) — no denormalise-and-refit-from-scratch;
//! * a d ∈ {2, 3, 4} grid (bucket) index over raw space answers most
//!   queries by expanding Chebyshev rings of cells, with an exact stopping
//!   bound, and falls back to the brute-force scan for other
//!   dimensionalities or tiny train sets.  Cells store each point's
//!   coordinates *and* target value inline, so a ring visit never chases
//!   back into the row/target buffers (≈half the cache misses per
//!   candidate).  Grid answers are *identical* to brute force (property-
//!   tested): ties at the k boundary break by (distance, index) in both.

use std::collections::BinaryHeap;

/// Grid index kicks in at this many stored rows (below it, the flat scan
/// wins on constant factors — see benches/bench_estimator).
const GRID_MIN_POINTS: usize = 256;

/// A candidate neighbour; the heap keeps the k lexicographically smallest
/// (d2, idx) pairs with the largest on top.  The target value rides along
/// as a payload (never compared) so the weighted mean reads no buffer the
/// candidate scan did not already touch.
#[derive(Debug, Clone, Copy)]
struct Cand {
    d2: f32,
    idx: u32,
    y: f32,
}

impl PartialEq for Cand {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for Cand {}

impl Ord for Cand {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.d2.total_cmp(&other.d2).then(self.idx.cmp(&other.idx))
    }
}

impl PartialOrd for Cand {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// One indexed point, stored inline in its cell: raw coordinates and
/// target value duplicated from the row/target buffers so candidate
/// scans are fully cell-local.
#[derive(Debug, Clone, Copy)]
struct CellPoint<const D: usize> {
    p: [f32; D],
    y: f32,
    idx: u32,
}

/// Uniform d-dimensional bucket index over *raw* feature space.
///
/// Cell geometry is fixed at build time; the query-time metric (the
/// current `1/σ` scaling) only enters through the ring lower bound, so
/// the index survives normalisation drift from continuous learning.
/// Points outside the original bounding box clamp to edge cells, which
/// can only move them to *earlier* rings — the stopping bound stays a
/// true lower bound (see `ring_query`).
#[derive(Debug, Clone)]
struct Grid<const D: usize> {
    dims: [usize; D],
    lo: [f32; D],
    /// Raw-space cell widths (sentinel 1.0 on degenerate dims).
    w: [f32; D],
    cells: Vec<Vec<CellPoint<D>>>,
    /// Row count when the grid was (re)built; doubling triggers a rebuild
    /// so occupancy stays balanced (amortised O(log n) rebuilds).
    built_at_n: usize,
}

impl<const D: usize> Grid<D> {
    fn build(xs: &[f32], y: &[f32], n: usize) -> Grid<D> {
        let mut lo = [f32::INFINITY; D];
        let mut hi = [f32::NEG_INFINITY; D];
        for i in 0..n {
            for j in 0..D {
                let v = xs[i * D + j];
                lo[j] = lo[j].min(v);
                hi[j] = hi[j].max(v);
            }
        }
        // ~8 points per cell on average, capped so the cell table stays
        // small even at large n (the d-th root generalises the 3-d cbrt).
        let r = (((n as f64) / 8.0).powf(1.0 / D as f64).ceil() as usize).clamp(1, 32);
        let mut dims = [1usize; D];
        let mut w = [1.0f32; D];
        for j in 0..D {
            let extent = hi[j] - lo[j];
            if extent.is_finite() && extent > 0.0 {
                let wj = extent / r as f32;
                if wj > 0.0 && wj.is_finite() {
                    dims[j] = r;
                    w[j] = wj;
                }
            }
        }
        let mut grid = Grid {
            dims,
            lo,
            w,
            cells: vec![Vec::new(); dims.iter().product()],
            built_at_n: n,
        };
        for i in 0..n {
            grid.insert(xs, y, i);
        }
        grid
    }

    #[inline]
    fn coords(&self, p: &[f32; D]) -> [usize; D] {
        let mut c = [0usize; D];
        for j in 0..D {
            let raw = ((p[j] - self.lo[j]) / self.w[j]).floor();
            // clamp handles out-of-box points AND the hi[j] boundary
            c[j] = if raw.is_finite() && raw > 0.0 {
                (raw as usize).min(self.dims[j] - 1)
            } else {
                0
            };
        }
        c
    }

    #[inline]
    fn cell_index(&self, c: &[usize; D]) -> usize {
        let mut ci = c[0];
        for j in 1..D {
            ci = ci * self.dims[j] + c[j];
        }
        ci
    }

    fn insert(&mut self, xs: &[f32], y: &[f32], i: usize) {
        let mut p = [0f32; D];
        p.copy_from_slice(&xs[i * D..(i + 1) * D]);
        let c = self.coords(&p);
        let ci = self.cell_index(&c);
        self.cells[ci].push(CellPoint {
            p,
            y: y[i],
            idx: i as u32,
        });
    }
}

/// The dimension-erased handle the model stores: one concrete grid per
/// supported dimensionality, behind the same fast path.
#[derive(Debug, Clone)]
enum GridIndex {
    D2(Grid<2>),
    D3(Grid<3>),
    D4(Grid<4>),
}

impl GridIndex {
    /// Whether dimensionality `d` has a grid specialisation.
    fn supports(d: usize) -> bool {
        (2..=4).contains(&d)
    }

    fn build(d: usize, xs: &[f32], y: &[f32], n: usize) -> Option<GridIndex> {
        match d {
            2 => Some(GridIndex::D2(Grid::build(xs, y, n))),
            3 => Some(GridIndex::D3(Grid::build(xs, y, n))),
            4 => Some(GridIndex::D4(Grid::build(xs, y, n))),
            _ => None,
        }
    }

    fn built_at_n(&self) -> usize {
        match self {
            GridIndex::D2(g) => g.built_at_n,
            GridIndex::D3(g) => g.built_at_n,
            GridIndex::D4(g) => g.built_at_n,
        }
    }

    /// Absorb rows [start, end) incrementally.
    fn insert_range(&mut self, xs: &[f32], y: &[f32], start: usize, end: usize) {
        for i in start..end {
            match self {
                GridIndex::D2(g) => g.insert(xs, y, i),
                GridIndex::D3(g) => g.insert(xs, y, i),
                GridIndex::D4(g) => g.insert(xs, y, i),
            }
        }
    }
}

/// KNN regression model.
#[derive(Debug, Clone)]
pub struct Knn {
    k: usize,
    d: usize,
    /// RAW rows, row-major, n × d.
    xs: Vec<f32>,
    y: Vec<f32>,
    /// Running per-feature moments (f64: no drift over many appends).
    sum: Vec<f64>,
    sumsq: Vec<f64>,
    /// Derived normalisation: per-feature mean and 1/std.
    mean: Vec<f32>,
    inv_std: Vec<f32>,
    grid: Option<GridIndex>,
}

/// Squared z-scored distance between a stored point and a query row (the
/// mean cancels, so only the 1/σ scaling is applied).  Shared by the flat
/// scan and the grid path so both produce bit-identical floats.
#[inline]
fn dist2(a: &[f32], b: &[f32], inv_std: &[f32]) -> f32 {
    let mut s = 0f32;
    for j in 0..a.len() {
        let t = (a[j] - b[j]) * inv_std[j];
        s += t * t;
    }
    s
}

impl Knn {
    /// Fit with `k` neighbours.
    pub fn fit(x: &[Vec<f32>], y: &[f32], k: usize) -> Knn {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty());
        assert!(k >= 1);
        let d = x[0].len();
        let mut m = Knn {
            k,
            d,
            xs: Vec::with_capacity(x.len() * d),
            y: Vec::new(),
            sum: vec![0.0; d],
            sumsq: vec![0.0; d],
            mean: vec![0.0; d],
            inv_std: vec![0.0; d],
            grid: None,
        };
        m.append(x, y);
        m
    }

    /// Append new samples and refresh normalisation in O(extra·d + d):
    /// running moments give the new (mean, std) directly, and since rows
    /// are stored raw nothing is rewritten.  The grid index absorbs the
    /// new points incrementally and rebuilds only when the model has
    /// doubled since the last build.
    pub fn append(&mut self, extra_x: &[Vec<f32>], extra_y: &[f32]) {
        assert_eq!(extra_x.len(), extra_y.len());
        if extra_x.is_empty() {
            return;
        }
        let start = self.len();
        for row in extra_x {
            assert_eq!(row.len(), self.d);
            for (j, v) in row.iter().enumerate() {
                self.sum[j] += *v as f64;
                self.sumsq[j] += (*v as f64) * (*v as f64);
            }
            self.xs.extend_from_slice(row);
        }
        self.y.extend_from_slice(extra_y);
        let n = self.len() as f64;
        for j in 0..self.d {
            let mean = self.sum[j] / n;
            let var = (self.sumsq[j] / n - mean * mean).max(0.0);
            let std = (var.sqrt() as f32).max(1e-6);
            self.mean[j] = mean as f32;
            self.inv_std[j] = 1.0 / std;
        }
        if GridIndex::supports(self.d) {
            let n = self.len();
            let rebuild = match &self.grid {
                None => n >= GRID_MIN_POINTS,
                Some(g) => n >= 2 * g.built_at_n(),
            };
            if rebuild {
                self.grid = GridIndex::build(self.d, &self.xs, &self.y, n);
            } else if let Some(grid) = &mut self.grid {
                grid.insert_range(&self.xs, &self.y, start, n);
            }
        }
    }

    /// Append new samples into a copy (continuous-learning refit).  Kept
    /// for API compatibility; [`Knn::append`] is the in-place fast path.
    pub fn refit_with(&self, extra_x: &[Vec<f32>], extra_y: &[f32]) -> Knn {
        let mut m = self.clone();
        m.append(extra_x, extra_y);
        m
    }

    /// Squared z-scored distance between stored row `i` and query `row`.
    #[inline]
    fn d2(&self, i: usize, row: &[f32]) -> f32 {
        let base = i * self.d;
        dist2(&self.xs[base..base + self.d], row, &self.inv_std)
    }

    /// Offer candidate `i` to a heap holding the k smallest (d2, idx).
    #[inline]
    fn consider(heap: &mut BinaryHeap<Cand>, k: usize, cand: Cand) {
        if heap.len() < k {
            heap.push(cand);
        } else if let Some(&top) = heap.peek() {
            if cand < top {
                heap.pop();
                heap.push(cand);
            }
        }
    }

    /// The k nearest stored rows, sorted ascending by (d2, idx).
    fn nearest(&self, row: &[f32]) -> Vec<Cand> {
        let k = self.k.min(self.len());
        let mut heap: BinaryHeap<Cand> = BinaryHeap::with_capacity(k + 1);
        match &self.grid {
            Some(GridIndex::D2(g)) => self.ring_query(g, row, k, &mut heap),
            Some(GridIndex::D3(g)) => self.ring_query(g, row, k, &mut heap),
            Some(GridIndex::D4(g)) => self.ring_query(g, row, k, &mut heap),
            None => {
                for i in 0..self.len() {
                    Self::consider(
                        &mut heap,
                        k,
                        Cand {
                            d2: self.d2(i, row),
                            idx: i as u32,
                            y: self.y[i],
                        },
                    );
                }
            }
        }
        let mut out: Vec<Cand> = heap.into_vec();
        out.sort_unstable();
        out
    }

    /// Exact grid-accelerated k-selection: expand Chebyshev rings of
    /// cells around the query's (clamped) cell; points in any ring ≥ m
    /// are at least (m−1)·min_j(w_j/σ_j) away, so once the heap is full
    /// and its worst distance is under that bound the remaining rings
    /// cannot improve the answer.
    fn ring_query<const D: usize>(
        &self,
        grid: &Grid<D>,
        row: &[f32],
        k: usize,
        heap: &mut BinaryHeap<Cand>,
    ) {
        let mut q = [0f32; D];
        q.copy_from_slice(&row[..D]);
        let c = grid.coords(&q);
        let mut max_r = 0usize;
        for j in 0..D {
            max_r = max_r.max(c[j]).max(grid.dims[j] - 1 - c[j]);
        }
        // Lower-bound cell width in scaled space over the non-degenerate
        // dims (size-1 dims never separate rings, so they are excluded).
        let mut min_w_scaled = f32::INFINITY;
        for j in 0..D {
            if grid.dims[j] > 1 {
                min_w_scaled = min_w_scaled.min(grid.w[j] * self.inv_std[j]);
            }
        }
        let mut coord = [0usize; D];
        for r in 0..=max_r as isize {
            self.ring_shell(grid, row, k, heap, r, 0, false, &c, &mut coord);
            if heap.len() == k && min_w_scaled.is_finite() {
                // Strict: an unvisited point at exactly the bound could
                // still tie-break its way into the k set.
                let bound = r as f32 * min_w_scaled;
                if let Some(top) = heap.peek() {
                    if top.d2 < bound * bound {
                        return;
                    }
                }
            }
        }
    }

    /// Enumerate exactly the cells of the Chebyshev shell at radius `r`
    /// (all offsets with max-norm == r), recursing over dimensions: dims
    /// 0..D−1 sweep their full [-r, r] range, and the last dim sweeps
    /// fully only when an earlier dim is already pinned to ±r, otherwise
    /// just its two ±r faces — the D-dimensional generalisation of the
    /// hand-rolled 3-d loop nest this replaces.
    #[allow(clippy::too_many_arguments)]
    fn ring_shell<const D: usize>(
        &self,
        grid: &Grid<D>,
        row: &[f32],
        k: usize,
        heap: &mut BinaryHeap<Cand>,
        r: isize,
        j: usize,
        on_shell: bool,
        c: &[usize; D],
        coord: &mut [usize; D],
    ) {
        if j == D - 1 {
            if on_shell {
                for dz in -r..=r {
                    self.visit_cell(grid, row, k, heap, dz, c, coord);
                }
            } else if r > 0 {
                self.visit_cell(grid, row, k, heap, -r, c, coord);
                self.visit_cell(grid, row, k, heap, r, c, coord);
            }
            return;
        }
        for dj in -r..=r {
            let x = c[j] as isize + dj;
            if x < 0 || x >= grid.dims[j] as isize {
                continue;
            }
            coord[j] = x as usize;
            self.ring_shell(grid, row, k, heap, r, j + 1, on_shell || dj.abs() == r, c, coord);
        }
    }

    /// Offer every point of one last-dimension cell to the heap; the
    /// distance reads the cell-local coordinates, never the row buffer.
    fn visit_cell<const D: usize>(
        &self,
        grid: &Grid<D>,
        row: &[f32],
        k: usize,
        heap: &mut BinaryHeap<Cand>,
        dz: isize,
        c: &[usize; D],
        coord: &mut [usize; D],
    ) {
        let j = D - 1;
        let z = c[j] as isize + dz;
        if z < 0 || z >= grid.dims[j] as isize {
            return;
        }
        coord[j] = z as usize;
        let ci = grid.cell_index(coord);
        for pt in &grid.cells[ci] {
            Self::consider(
                heap,
                k,
                Cand {
                    d2: dist2(&pt.p, row, &self.inv_std),
                    idx: pt.idx,
                    y: pt.y,
                },
            );
        }
    }

    /// Distance-weighted mean of the k nearest targets.
    ///
    /// When every neighbour is so far that the inverse-distance weights
    /// underflow (or the distances overflow to ∞ — e.g. all-identical
    /// training points queried from far away), the weighted mean is
    /// 0/0 = NaN; this falls back to the unweighted neighbour mean.
    pub fn predict(&self, row: &[f32]) -> f32 {
        assert_eq!(row.len(), self.d);
        let best = self.nearest(row);
        self.weighted_mean(&best)
    }

    fn weighted_mean(&self, best: &[Cand]) -> f32 {
        let mut wsum = 0f32;
        let mut vsum = 0f32;
        for c in best {
            let w = 1.0 / (c.d2.sqrt() + 1e-6);
            wsum += w;
            vsum += w * c.y;
        }
        if wsum.is_finite() && wsum > f32::MIN_POSITIVE && vsum.is_finite() {
            vsum / wsum
        } else {
            let s: f32 = best.iter().map(|c| c.y).sum();
            s / best.len() as f32
        }
    }

    /// Number of stored samples.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Whether the bucket index is active (diagnostics/benches).
    pub fn has_index(&self) -> bool {
        self.grid.is_some()
    }

    /// Brute-force reference prediction (ignores the grid index); used by
    /// the equivalence property tests and benches.
    pub fn predict_bruteforce(&self, row: &[f32]) -> f32 {
        assert_eq!(row.len(), self.d);
        let k = self.k.min(self.len());
        let mut heap: BinaryHeap<Cand> = BinaryHeap::with_capacity(k + 1);
        for i in 0..self.len() {
            Self::consider(
                &mut heap,
                k,
                Cand {
                    d2: self.d2(i, row),
                    idx: i as u32,
                    y: self.y[i],
                },
            );
        }
        let mut best = heap.into_vec();
        best.sort_unstable();
        self.weighted_mean(&best)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use crate::util::Rng;

    #[test]
    fn exact_on_training_points() {
        let x = vec![vec![0.0], vec![10.0], vec![20.0]];
        let y = vec![1.0, 2.0, 3.0];
        let m = Knn::fit(&x, &y, 1);
        assert!((m.predict(&[10.0]) - 2.0).abs() < 1e-4);
    }

    #[test]
    fn interpolates_between_neighbours() {
        let x = vec![vec![0.0], vec![10.0]];
        let y = vec![0.0, 10.0];
        let m = Knn::fit(&x, &y, 2);
        let p = m.predict(&[5.0]);
        assert!((p - 5.0).abs() < 0.5, "p={p}");
    }

    #[test]
    fn scales_features() {
        // feature 1 has huge scale but no signal; normalisation must keep
        // feature 0 informative.
        let mut rng = Rng::new(1);
        let x: Vec<Vec<f32>> = (0..200)
            .map(|i| vec![i as f32, rng.range_f64(0.0, 1e6) as f32])
            .collect();
        let y: Vec<f32> = (0..200).map(|i| i as f32).collect();
        let m = Knn::fit(&x, &y, 3);
        let p = m.predict(&[100.0, 5e5]);
        assert!((p - 100.0).abs() < 20.0, "p={p}");
    }

    #[test]
    fn refit_with_extends_model() {
        let x = vec![vec![0.0], vec![1.0]];
        let y = vec![0.0, 1.0];
        let m = Knn::fit(&x, &y, 1);
        let m2 = m.refit_with(&[vec![100.0]], &[50.0]);
        assert_eq!(m2.len(), 3);
        assert!((m2.predict(&[100.0]) - 50.0).abs() < 1e-3);
    }

    #[test]
    fn k_larger_than_n_is_safe() {
        let x = vec![vec![0.0], vec![1.0]];
        let y = vec![2.0, 4.0];
        let m = Knn::fit(&x, &y, 10);
        let p = m.predict(&[0.5]);
        assert!(p > 2.0 && p < 4.0);
    }

    /// Regression (wsum underflow): all-identical training points have
    /// σ = ε, so a far query's scaled distances overflow to ∞, every
    /// weight collapses to 0 and the weighted mean used to be 0/0 = NaN.
    /// The guard must return the unweighted neighbour mean instead.
    #[test]
    fn far_query_on_identical_points_falls_back_to_mean() {
        let x = vec![vec![5.0], vec![5.0], vec![5.0]];
        let y = vec![1.0, 2.0, 3.0];
        let m = Knn::fit(&x, &y, 3);
        let p = m.predict(&[1e20]);
        assert!(p.is_finite(), "p={p}");
        assert!((p - 2.0).abs() < 1e-5, "p={p}");
    }

    #[test]
    fn incremental_append_matches_fresh_fit() {
        // Appending must yield the same predictions as one fresh fit on
        // the union (running moments ≡ full-pass moments).
        let mut rng = Rng::new(9);
        let gen_rows = |rng: &mut Rng, n: usize| -> (Vec<Vec<f32>>, Vec<f32>) {
            let x: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    vec![
                        rng.range_f64(1.0, 33.0) as f32,
                        rng.range_f64(8.0, 1025.0) as f32,
                        rng.range_f64(4.0, 1025.0) as f32,
                    ]
                })
                .collect();
            let y: Vec<f32> = x.iter().map(|r| r[0] + 0.01 * r[1] * r[2]).collect();
            (x, y)
        };
        let (x1, y1) = gen_rows(&mut rng, 400);
        let (x2, y2) = gen_rows(&mut rng, 150);
        let mut incremental = Knn::fit(&x1, &y1, 5);
        incremental.append(&x2, &y2);
        let union_x: Vec<Vec<f32>> = x1.iter().chain(&x2).cloned().collect();
        let union_y: Vec<f32> = y1.iter().chain(&y2).copied().collect();
        let fresh = Knn::fit(&union_x, &union_y, 5);
        let (probes, _) = gen_rows(&mut rng, 50);
        for p in &probes {
            let a = incremental.predict(p);
            let b = fresh.predict(p);
            assert!(
                (a - b).abs() <= 1e-4 * b.abs().max(1.0),
                "incremental {a} vs fresh {b}"
            );
        }
    }

    /// The grid index must be invisible: identical predictions to the
    /// brute-force scan on random 3-d data, including duplicated rows
    /// (distance ties) and out-of-box queries.
    #[test]
    fn grid_index_matches_bruteforce() {
        prop_check(20, |rng| {
            let n = rng.range_usize(GRID_MIN_POINTS, 1200);
            let x: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    // coarse rounding → plenty of exact duplicates
                    vec![
                        rng.range_u64(1, 33) as f32,
                        (rng.range_u64(1, 65) * 16) as f32,
                        (rng.range_u64(1, 65) * 16) as f32,
                    ]
                })
                .collect();
            let y: Vec<f32> = (0..n).map(|i| (i % 97) as f32).collect();
            let m = Knn::fit(&x, &y, 5);
            assert!(m.has_index(), "grid must be active at n={n}");
            for _ in 0..30 {
                let probe = vec![
                    rng.range_f64(-10.0, 50.0) as f32,
                    rng.range_f64(-100.0, 1500.0) as f32,
                    rng.range_f64(-100.0, 1500.0) as f32,
                ];
                let a = m.predict(&probe);
                let b = m.predict_bruteforce(&probe);
                assert!(
                    a.to_bits() == b.to_bits(),
                    "grid {a} != brute {b} at {probe:?}"
                );
            }
        });
    }

    /// The generalised index must stay invisible in every supported
    /// dimensionality (2-d and 4-d ride the same fast path as 3-d).
    #[test]
    fn grid_index_matches_bruteforce_in_2d_and_4d() {
        prop_check(16, |rng| {
            let d = if rng.range_u64(0, 2) == 0 { 2 } else { 4 };
            let n = rng.range_usize(GRID_MIN_POINTS, 900);
            let x: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    (0..d)
                        .map(|_| (rng.range_u64(1, 65) * 8) as f32) // duplicates
                        .collect()
                })
                .collect();
            let y: Vec<f32> = (0..n).map(|i| (i % 89) as f32).collect();
            let m = Knn::fit(&x, &y, 5);
            assert!(m.has_index(), "grid must be active at n={n} d={d}");
            for _ in 0..20 {
                let probe: Vec<f32> = (0..d)
                    .map(|_| rng.range_f64(-50.0, 600.0) as f32)
                    .collect();
                let a = m.predict(&probe);
                let b = m.predict_bruteforce(&probe);
                assert!(
                    a.to_bits() == b.to_bits(),
                    "d={d}: grid {a} != brute {b} at {probe:?}"
                );
            }
        });
    }

    #[test]
    fn high_dimensions_skip_the_grid() {
        // d = 5 has no specialisation: the flat scan must silently serve.
        let x: Vec<Vec<f32>> = (0..GRID_MIN_POINTS + 50)
            .map(|i| (0..5).map(|j| ((i * 7 + j * 3) % 101) as f32).collect())
            .collect();
        let y: Vec<f32> = (0..x.len()).map(|i| i as f32).collect();
        let m = Knn::fit(&x, &y, 3);
        assert!(!m.has_index());
        assert!(m.predict(&[1.0, 2.0, 3.0, 4.0, 5.0]).is_finite());
    }

    #[test]
    fn grid_survives_incremental_appends() {
        let mut rng = Rng::new(17);
        let row = |rng: &mut Rng| {
            vec![
                rng.range_u64(1, 33) as f32,
                rng.range_u64(8, 1025) as f32,
                rng.range_u64(4, 1025) as f32,
            ]
        };
        let x: Vec<Vec<f32>> = (0..GRID_MIN_POINTS).map(|_| row(&mut rng)).collect();
        let y: Vec<f32> = (0..GRID_MIN_POINTS).map(|i| i as f32).collect();
        let mut m = Knn::fit(&x, &y, 5);
        // many small appends: insertions + at least one doubling rebuild
        for round in 0..20 {
            let ex: Vec<Vec<f32>> = (0..40).map(|_| row(&mut rng)).collect();
            let ey: Vec<f32> = (0..40).map(|i| (round * 40 + i) as f32).collect();
            m.append(&ex, &ey);
            let probe = row(&mut rng);
            let a = m.predict(&probe);
            let b = m.predict_bruteforce(&probe);
            assert!(a.to_bits() == b.to_bits(), "round {round}: {a} != {b}");
        }
        assert_eq!(m.len(), GRID_MIN_POINTS + 20 * 40);
    }
}
