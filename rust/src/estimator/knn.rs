//! K-nearest-neighbour regressor (sklearn stand-in, from scratch).
//!
//! Distance-weighted KNN over z-score-normalised features.  The serving
//! time estimator's feature space is tiny (3-d) and its train set is a few
//! thousand logged batches, so brute-force scan is both simple and faster
//! than tree indices at this scale (verified in benches/bench_estimator).

/// KNN regression model.
#[derive(Debug, Clone)]
pub struct Knn {
    k: usize,
    /// Normalised rows.
    x: Vec<Vec<f32>>,
    y: Vec<f32>,
    /// Per-feature (mean, std) used for normalisation.
    norm: Vec<(f32, f32)>,
}

impl Knn {
    /// Fit with `k` neighbours.
    pub fn fit(x: &[Vec<f32>], y: &[f32], k: usize) -> Knn {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty());
        assert!(k >= 1);
        let d = x[0].len();
        let n = x.len() as f32;
        let mut norm = Vec::with_capacity(d);
        for j in 0..d {
            let mean = x.iter().map(|r| r[j]).sum::<f32>() / n;
            let var = x.iter().map(|r| (r[j] - mean).powi(2)).sum::<f32>() / n;
            let std = var.sqrt().max(1e-6);
            norm.push((mean, std));
        }
        let xn: Vec<Vec<f32>> = x
            .iter()
            .map(|r| {
                r.iter()
                    .zip(&norm)
                    .map(|(v, (m, s))| (v - m) / s)
                    .collect()
            })
            .collect();
        Knn {
            k,
            x: xn,
            y: y.to_vec(),
            norm,
        }
    }

    fn normalise(&self, row: &[f32]) -> Vec<f32> {
        row.iter()
            .zip(&self.norm)
            .map(|(v, (m, s))| (v - m) / s)
            .collect()
    }

    /// Distance-weighted mean of the k nearest targets.
    pub fn predict(&self, row: &[f32]) -> f32 {
        let q = self.normalise(row);
        // Partial selection of k smallest distances.
        let mut best: Vec<(f32, usize)> = Vec::with_capacity(self.k + 1);
        for (i, xr) in self.x.iter().enumerate() {
            let d2: f32 = xr.iter().zip(&q).map(|(a, b)| (a - b) * (a - b)).sum();
            if best.len() < self.k {
                best.push((d2, i));
                best.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            } else if d2 < best[self.k - 1].0 {
                best[self.k - 1] = (d2, i);
                best.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            }
        }
        let mut wsum = 0f32;
        let mut vsum = 0f32;
        for (d2, i) in &best {
            let w = 1.0 / (d2.sqrt() + 1e-6);
            wsum += w;
            vsum += w * self.y[*i];
        }
        vsum / wsum
    }

    /// Number of stored samples.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Append new samples and renormalise (continuous learning refit).
    pub fn refit_with(&self, extra_x: &[Vec<f32>], extra_y: &[f32]) -> Knn {
        // Denormalise stored rows back to raw space, then refit fresh.
        let raw: Vec<Vec<f32>> = self
            .x
            .iter()
            .map(|r| {
                r.iter()
                    .zip(&self.norm)
                    .map(|(v, (m, s))| v * s + m)
                    .collect()
            })
            .collect();
        let mut all_x = raw;
        all_x.extend_from_slice(extra_x);
        let mut all_y = self.y.clone();
        all_y.extend_from_slice(extra_y);
        Knn::fit(&all_x, &all_y, self.k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn exact_on_training_points() {
        let x = vec![vec![0.0], vec![10.0], vec![20.0]];
        let y = vec![1.0, 2.0, 3.0];
        let m = Knn::fit(&x, &y, 1);
        assert!((m.predict(&[10.0]) - 2.0).abs() < 1e-4);
    }

    #[test]
    fn interpolates_between_neighbours() {
        let x = vec![vec![0.0], vec![10.0]];
        let y = vec![0.0, 10.0];
        let m = Knn::fit(&x, &y, 2);
        let p = m.predict(&[5.0]);
        assert!((p - 5.0).abs() < 0.5, "p={p}");
    }

    #[test]
    fn scales_features() {
        // feature 1 has huge scale but no signal; normalisation must keep
        // feature 0 informative.
        let mut rng = Rng::new(1);
        let x: Vec<Vec<f32>> = (0..200)
            .map(|i| vec![i as f32, rng.range_f64(0.0, 1e6) as f32])
            .collect();
        let y: Vec<f32> = (0..200).map(|i| i as f32).collect();
        let m = Knn::fit(&x, &y, 3);
        let p = m.predict(&[100.0, 5e5]);
        assert!((p - 100.0).abs() < 20.0, "p={p}");
    }

    #[test]
    fn refit_with_extends_model() {
        let x = vec![vec![0.0], vec![1.0]];
        let y = vec![0.0, 1.0];
        let m = Knn::fit(&x, &y, 1);
        let m2 = m.refit_with(&[vec![100.0]], &[50.0]);
        assert_eq!(m2.len(), 3);
        assert!((m2.predict(&[100.0]) - 50.0).abs() < 1e-3);
    }

    #[test]
    fn k_larger_than_n_is_safe() {
        let x = vec![vec![0.0], vec![1.0]];
        let y = vec![2.0, 4.0];
        let m = Knn::fit(&x, &y, 10);
        let p = m.predict(&[0.5]);
        assert!(p > 2.0 && p < 4.0);
    }
}
