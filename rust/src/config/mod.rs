//! Configuration system: every knob of the serving stack in one place,
//! loadable from JSON with CLI overrides, with the paper's §IV settings as
//! defaults.
//!
//! Three layers of config compose a run:
//! * [`GpuProfile`]   — the accelerator + LLM the cost model emulates
//!   (defaults describe a 32 GB V100 running ChatGLM-6B, the paper's
//!   testbed; calibration constants documented inline).
//! * [`CostModelParams`] — the analytic batch-serving-time model used by
//!   the simulator engine (calibrated against the paper's Fig. 6 case
//!   study; see `engine::cost` tests).
//! * [`ServingConfig`] — Magnus policy knobs (Φ, scheduler, predictor…)
//!   plus cluster shape.

use crate::util::Json;

/// Scheduling policy for picking the next queued batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    /// First-come-first-served over batches (creation order).
    Fcfs,
    /// Highest response ratio next — the paper's §III-E policy.
    Hrrn,
    /// Shortest (estimated) job first — ablation extra.
    Sjf,
}

impl SchedPolicy {
    pub fn parse(s: &str) -> Option<SchedPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "fcfs" => Some(SchedPolicy::Fcfs),
            "hrrn" => Some(SchedPolicy::Hrrn),
            "sjf" => Some(SchedPolicy::Sjf),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchedPolicy::Fcfs => "fcfs",
            SchedPolicy::Hrrn => "hrrn",
            SchedPolicy::Sjf => "sjf",
        }
    }
}

/// The accelerator/LLM pair the memory model reasons about (Eq. 1 / Eq. 5).
///
/// Defaults describe the paper's testbed: NVIDIA V100 32 GB + ChatGLM-6B
/// (28 layers, hidden 4096, fp16 KV). `model_resident_bytes` bundles the
/// fp16 weights (~12.4 GB) with the inference-engine workspace so that
/// Eq. (1) reproduces the paper's vanilla batch size β = 7 — the paper
/// states β = 7 but not its workspace accounting, so that constant is the
/// one calibrated value here.
#[derive(Debug, Clone)]
pub struct GpuProfile {
    /// Total device memory in bytes (V100: 32 GB).
    pub total_mem: u64,
    /// Fraction of total memory usable after fragmentation (paper: 0.7).
    pub mem_fraction: f64,
    /// Bytes resident for model weights + engine workspace.
    pub model_resident_bytes: u64,
    /// Δ of Eq. (5): KV-cache bytes per token
    /// (2 · n_layers · hidden · bytes_per_el = 2·28·4096·2 for ChatGLM-6B).
    pub delta_bytes_per_token: u64,
    /// Preset maximal request length L_max (paper: 1024).
    pub l_max: u32,
    /// Preset maximal generation length G_max (paper: 1024).
    pub g_max: u32,
}

impl Default for GpuProfile {
    fn default() -> Self {
        GpuProfile {
            total_mem: 32_000_000_000,
            mem_fraction: 0.7,
            model_resident_bytes: 15_500_000_000,
            delta_bytes_per_token: 2 * 28 * 4096 * 2,
            l_max: 1024,
            g_max: 1024,
        }
    }
}

impl GpuProfile {
    /// Θ: bytes available for the KV cache (text above Eq. 1).
    pub fn theta(&self) -> u64 {
        let avail = self.mem_fraction * self.total_mem as f64
            - self.model_resident_bytes as f64;
        avail.max(0.0) as u64
    }

    /// Eq. (1): the vanilla fixed batch size β.
    pub fn vanilla_batch_size(&self) -> u32 {
        let denom =
            (self.l_max + self.g_max) as u64 * self.delta_bytes_per_token;
        if denom == 0 {
            0
        } else {
            (self.theta() / denom) as u32
        }
    }

    fn from_json(j: &Json, base: GpuProfile) -> GpuProfile {
        GpuProfile {
            total_mem: j.get("total_mem").as_u64().unwrap_or(base.total_mem),
            mem_fraction: j
                .get("mem_fraction")
                .as_f64()
                .unwrap_or(base.mem_fraction),
            model_resident_bytes: j
                .get("model_resident_bytes")
                .as_u64()
                .unwrap_or(base.model_resident_bytes),
            delta_bytes_per_token: j
                .get("delta_bytes_per_token")
                .as_u64()
                .unwrap_or(base.delta_bytes_per_token),
            l_max: j.get("l_max").as_u64().unwrap_or(base.l_max as u64) as u32,
            g_max: j.get("g_max").as_u64().unwrap_or(base.g_max as u64) as u32,
        }
    }
}

/// Analytic batch-serving-time model (see `engine::cost`).
///
/// One decoding iteration of a batch with β requests and per-request
/// context `ctx` (padded length + tokens generated so far) costs
///
///   t_iter = c0 + c1·β + c2·β·ctx        seconds,
///
/// where c0 captures the weight-streaming floor of a 6B model on a V100
/// under huggingface-transformers (the paper's engine) — decode time is
/// nearly flat in β until the KV term dominates, which is exactly the
/// under-utilisation Magnus exploits — c1 a small per-request overhead, and c2 the KV-cache read bandwidth term.
/// The prefill (initialisation phase) costs c0 + c3·β·L² + c4·β·L.
/// Constants are calibrated so the Fig. 6 case study reproduces (VS ≈ 242 s,
/// Magnus ≈ 60 s); see `engine::cost::tests::fig6_calibration`.
#[derive(Debug, Clone)]
pub struct CostModelParams {
    pub c0: f64,
    pub c1: f64,
    pub c2: f64,
    pub c3: f64,
    pub c4: f64,
}

impl Default for CostModelParams {
    fn default() -> Self {
        CostModelParams {
            c0: 0.045,
            c1: 0.0002,
            c2: 2.4e-6,
            c3: 1.2e-6,
            c4: 2.0e-5,
        }
    }
}

impl CostModelParams {
    fn from_json(j: &Json, base: CostModelParams) -> CostModelParams {
        CostModelParams {
            c0: j.get("c0").as_f64().unwrap_or(base.c0),
            c1: j.get("c1").as_f64().unwrap_or(base.c1),
            c2: j.get("c2").as_f64().unwrap_or(base.c2),
            c3: j.get("c3").as_f64().unwrap_or(base.c3),
            c4: j.get("c4").as_f64().unwrap_or(base.c4),
        }
    }
}

/// VSQ (4-bit quantization) baseline knobs, §IV-A and §IV-B.
#[derive(Debug, Clone)]
pub struct QuantConfig {
    /// Fixed batch size the paper reports for VSQ.
    pub batch_size: u32,
    /// Multiplicative slowdown of each iteration (dequant overhead).
    pub iter_slowdown: f64,
    /// Multiplicative inflation of generation lengths (quality degradation
    /// producing redundant content, §IV-B).
    pub genlen_inflation: f64,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig {
            batch_size: 10,
            iter_slowdown: 1.6,
            genlen_inflation: 1.25,
        }
    }
}

/// Continuous-learning knobs (§III-B, §III-D).
#[derive(Debug, Clone)]
pub struct LearningConfig {
    /// Predictor retrain period (paper: every 3 minutes).
    pub predictor_period_s: f64,
    /// Collect a request when |err| > this many tokens…
    pub predictor_err_tokens: f64,
    /// …AND > this fraction of the actual generation length.
    pub predictor_err_frac: f64,
    /// Estimator retrain period (paper: every 2 minutes).
    pub estimator_period_s: f64,
    /// Collect a batch when |err| > this many seconds…
    pub estimator_err_s: f64,
    /// …AND > this fraction of the actual serving time.
    pub estimator_err_frac: f64,
}

impl Default for LearningConfig {
    fn default() -> Self {
        LearningConfig {
            predictor_period_s: 180.0,
            predictor_err_tokens: 10.0,
            predictor_err_frac: 0.10,
            estimator_period_s: 120.0,
            estimator_err_s: 2.0,
            estimator_err_frac: 0.20,
        }
    }
}

/// Uncertainty-aware scheduling knobs (ISSUE 9): how admission uses the
/// predictor's confidence annotation, and when sustained prediction
/// drift demotes the predictor down the fallback chain.
///
/// `enabled: false` (the default) keeps every serving path bit-identical
/// to the point-estimate pipeline — the confidence layer is never even
/// computed.
#[derive(Debug, Clone)]
pub struct UncertaintyConfig {
    /// Master switch for confidence-aware admission + drift detection.
    pub enabled: bool,
    /// Admissions whose modal-bucket confidence falls below this are
    /// charged their upper-quantile tokens instead of the point.
    pub confidence_threshold: f64,
    /// Cumulative vote-share quantile defining the conservative token
    /// bound (see `predictor::traits`).
    pub upper_quantile: f64,
    /// Cluster banding: route requests below this confidence to the
    /// spillover band (0.0 = spillover disabled, banding unchanged).
    pub spill_confidence: f64,
    /// Drift detector: demotion budget in tokens of signed-error EWMA.
    pub drift_budget_tokens: f64,
    /// Drift detector: EWMA smoothing factor.
    pub drift_alpha: f64,
    /// Drift detector: minimum per-cell completions before demotion.
    pub drift_min_samples: u32,
    /// Drift detector: completions to stay demoted before re-promotion.
    pub drift_probation: u32,
}

impl Default for UncertaintyConfig {
    fn default() -> Self {
        UncertaintyConfig {
            enabled: false,
            confidence_threshold: 0.55,
            upper_quantile: 0.9,
            spill_confidence: 0.0,
            drift_budget_tokens: 25.0,
            drift_alpha: 0.2,
            drift_min_samples: 25,
            drift_probation: 64,
        }
    }
}

impl UncertaintyConfig {
    /// The drift-detector view of these knobs.
    pub fn drift_config(&self) -> crate::predictor::DriftConfig {
        crate::predictor::DriftConfig {
            alpha: self.drift_alpha,
            budget_tokens: self.drift_budget_tokens,
            min_samples: self.drift_min_samples,
            probation: self.drift_probation,
        }
    }
}

/// Top-level serving configuration.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Number of LLM instances (paper: 7 V100s serving + 1 for LaBSE).
    pub n_instances: usize,
    /// WMA threshold Φ of Algorithm 1 (paper: 50 000).
    pub wma_threshold: f64,
    /// Batch-scheduling policy (paper: HRRN).
    pub sched: SchedPolicy,
    /// Number of parallel generation-length predictors (paper: 3).
    pub n_predictors: usize,
    /// Random-forest size for the generation-length predictor.
    pub rf_trees: usize,
    /// Max depth of each tree.
    pub rf_max_depth: usize,
    /// K for the serving-time KNN estimator.
    pub knn_k: usize,
    /// Cap on requests per batch (0 = unlimited / memory-bound only).
    /// GLP ablation sets this to the vanilla batch size.
    pub max_batch_size: u32,
    /// Fraction of Θ the batcher may plan up to (engineering guard: the
    /// planner works with *predicted* generation lengths, so filling to
    /// exactly Θ makes every under-prediction an OOM; the engine still
    /// enforces the full Θ at run time).
    pub mem_margin: f64,
    /// Device + model profile.
    pub gpu: GpuProfile,
    /// Analytic engine constants.
    pub cost: CostModelParams,
    /// VSQ baseline knobs.
    pub quant: QuantConfig,
    /// Continuous-learning knobs.
    pub learning: LearningConfig,
    /// Uncertainty-aware scheduling + drift degradation knobs.
    pub uncertainty: UncertaintyConfig,
    /// CCB baseline: extra stall per admitted request on top of its
    /// initialisation phase (calibrated so CCB's token throughput lands at
    /// the paper's Fig. 10a ratio vs VS; their implementation pauses every
    /// running request while a joiner prefills).
    pub ccb_overhead_s: f64,
    /// Master seed for all derived RNG streams.
    pub seed: u64,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            n_instances: 7,
            wma_threshold: 50_000.0,
            sched: SchedPolicy::Hrrn,
            n_predictors: 3,
            rf_trees: 24,
            rf_max_depth: 20,
            knn_k: 5,
            max_batch_size: 0,
            mem_margin: 0.85,
            gpu: GpuProfile::default(),
            cost: CostModelParams::default(),
            quant: QuantConfig::default(),
            learning: LearningConfig::default(),
            uncertainty: UncertaintyConfig::default(),
            ccb_overhead_s: 0.70,
            seed: 42,
        }
    }
}

impl ServingConfig {
    /// Merge a JSON object over the defaults.
    pub fn from_json(j: &Json) -> ServingConfig {
        let base = ServingConfig::default();
        ServingConfig {
            n_instances: j
                .get("n_instances")
                .as_usize()
                .unwrap_or(base.n_instances),
            wma_threshold: j
                .get("wma_threshold")
                .as_f64()
                .unwrap_or(base.wma_threshold),
            sched: j
                .get("sched")
                .as_str()
                .and_then(SchedPolicy::parse)
                .unwrap_or(base.sched),
            n_predictors: j
                .get("n_predictors")
                .as_usize()
                .unwrap_or(base.n_predictors),
            rf_trees: j.get("rf_trees").as_usize().unwrap_or(base.rf_trees),
            rf_max_depth: j
                .get("rf_max_depth")
                .as_usize()
                .unwrap_or(base.rf_max_depth),
            knn_k: j.get("knn_k").as_usize().unwrap_or(base.knn_k),
            max_batch_size: j
                .get("max_batch_size")
                .as_u64()
                .unwrap_or(base.max_batch_size as u64) as u32,
            mem_margin: j.get("mem_margin").as_f64().unwrap_or(base.mem_margin),
            gpu: GpuProfile::from_json(j.get("gpu"), base.gpu),
            cost: CostModelParams::from_json(j.get("cost"), base.cost),
            quant: QuantConfig {
                batch_size: j
                    .path("quant.batch_size")
                    .as_u64()
                    .unwrap_or(base.quant.batch_size as u64)
                    as u32,
                iter_slowdown: j
                    .path("quant.iter_slowdown")
                    .as_f64()
                    .unwrap_or(base.quant.iter_slowdown),
                genlen_inflation: j
                    .path("quant.genlen_inflation")
                    .as_f64()
                    .unwrap_or(base.quant.genlen_inflation),
            },
            learning: LearningConfig {
                predictor_period_s: j
                    .path("learning.predictor_period_s")
                    .as_f64()
                    .unwrap_or(base.learning.predictor_period_s),
                predictor_err_tokens: j
                    .path("learning.predictor_err_tokens")
                    .as_f64()
                    .unwrap_or(base.learning.predictor_err_tokens),
                predictor_err_frac: j
                    .path("learning.predictor_err_frac")
                    .as_f64()
                    .unwrap_or(base.learning.predictor_err_frac),
                estimator_period_s: j
                    .path("learning.estimator_period_s")
                    .as_f64()
                    .unwrap_or(base.learning.estimator_period_s),
                estimator_err_s: j
                    .path("learning.estimator_err_s")
                    .as_f64()
                    .unwrap_or(base.learning.estimator_err_s),
                estimator_err_frac: j
                    .path("learning.estimator_err_frac")
                    .as_f64()
                    .unwrap_or(base.learning.estimator_err_frac),
            },
            uncertainty: UncertaintyConfig {
                enabled: j
                    .path("uncertainty.enabled")
                    .as_bool()
                    .unwrap_or(base.uncertainty.enabled),
                confidence_threshold: j
                    .path("uncertainty.confidence_threshold")
                    .as_f64()
                    .unwrap_or(base.uncertainty.confidence_threshold),
                upper_quantile: j
                    .path("uncertainty.upper_quantile")
                    .as_f64()
                    .unwrap_or(base.uncertainty.upper_quantile),
                spill_confidence: j
                    .path("uncertainty.spill_confidence")
                    .as_f64()
                    .unwrap_or(base.uncertainty.spill_confidence),
                drift_budget_tokens: j
                    .path("uncertainty.drift_budget_tokens")
                    .as_f64()
                    .unwrap_or(base.uncertainty.drift_budget_tokens),
                drift_alpha: j
                    .path("uncertainty.drift_alpha")
                    .as_f64()
                    .unwrap_or(base.uncertainty.drift_alpha),
                drift_min_samples: j
                    .path("uncertainty.drift_min_samples")
                    .as_u64()
                    .unwrap_or(u64::from(base.uncertainty.drift_min_samples))
                    as u32,
                drift_probation: j
                    .path("uncertainty.drift_probation")
                    .as_u64()
                    .unwrap_or(u64::from(base.uncertainty.drift_probation))
                    as u32,
            },
            ccb_overhead_s: j
                .get("ccb_overhead_s")
                .as_f64()
                .unwrap_or(base.ccb_overhead_s),
            seed: j.get("seed").as_u64().unwrap_or(base.seed),
        }
    }

    /// Load from a JSON file, or defaults when `path` is None.
    pub fn load(path: Option<&str>) -> anyhow::Result<ServingConfig> {
        match path {
            None => Ok(ServingConfig::default()),
            Some(p) => {
                let text = std::fs::read_to_string(p)?;
                let j = Json::parse(&text)
                    .map_err(|e| anyhow::anyhow!("{p}: {e}"))?;
                Ok(ServingConfig::from_json(&j))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_profile_reproduces_paper_beta() {
        // Eq. (1) with the default V100/ChatGLM-6B profile must yield the
        // paper's vanilla batch size of 7.
        let gpu = GpuProfile::default();
        assert_eq!(gpu.vanilla_batch_size(), 7);
    }

    #[test]
    fn theta_positive_and_sane() {
        let gpu = GpuProfile::default();
        let theta = gpu.theta();
        assert!(theta > 5_000_000_000 && theta < 10_000_000_000);
    }

    #[test]
    fn vanilla_beta_monotone_in_memory() {
        let mut gpu = GpuProfile::default();
        let b0 = gpu.vanilla_batch_size();
        gpu.total_mem *= 2;
        assert!(gpu.vanilla_batch_size() > b0);
    }

    #[test]
    fn json_overrides_apply() {
        let j = Json::parse(
            r#"{"n_instances": 3, "sched": "fcfs",
                "gpu": {"l_max": 512}, "quant": {"batch_size": 12},
                "learning": {"predictor_period_s": 60}}"#,
        )
        .unwrap();
        let c = ServingConfig::from_json(&j);
        assert_eq!(c.n_instances, 3);
        assert_eq!(c.sched, SchedPolicy::Fcfs);
        assert_eq!(c.gpu.l_max, 512);
        assert_eq!(c.quant.batch_size, 12);
        assert_eq!(c.learning.predictor_period_s, 60.0);
        // untouched fields keep defaults
        assert_eq!(c.wma_threshold, 50_000.0);
    }

    #[test]
    fn uncertainty_defaults_off_and_overrides_apply() {
        let base = ServingConfig::default();
        assert!(!base.uncertainty.enabled, "confidence layer must default off");
        let j = Json::parse(
            r#"{"uncertainty": {"enabled": true, "confidence_threshold": 0.8,
                "drift_budget_tokens": 5.5, "drift_probation": 16}}"#,
        )
        .unwrap();
        let c = ServingConfig::from_json(&j);
        assert!(c.uncertainty.enabled);
        assert_eq!(c.uncertainty.confidence_threshold, 0.8);
        assert_eq!(c.uncertainty.drift_budget_tokens, 5.5);
        assert_eq!(c.uncertainty.drift_probation, 16);
        // untouched knobs keep defaults
        assert_eq!(c.uncertainty.upper_quantile, 0.9);
        let dc = c.uncertainty.drift_config();
        assert_eq!(dc.budget_tokens, 5.5);
        assert_eq!(dc.probation, 16);
    }

    #[test]
    fn sched_policy_parse() {
        assert_eq!(SchedPolicy::parse("HRRN"), Some(SchedPolicy::Hrrn));
        assert_eq!(SchedPolicy::parse("nope"), None);
        assert_eq!(SchedPolicy::Hrrn.name(), "hrrn");
    }

    #[test]
    fn default_wma_threshold_matches_paper() {
        assert_eq!(ServingConfig::default().wma_threshold, 50_000.0);
        assert_eq!(ServingConfig::default().n_instances, 7);
    }
}
