//! Micro-benchmark harness (criterion is not vendored in this environment).
//!
//! `cargo bench` runs the `[[bench]]` targets with `harness = false`; each
//! target builds a [`BenchSuite`], registers closures, and gets warmup,
//! calibrated iteration counts, and mean / p50 / p95 / stddev reporting.

use std::hint::black_box;
use std::time::{Duration, Instant};

use crate::util::Json;

pub use std::hint::black_box as bb;

/// One measured benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub stddev_ns: f64,
}

impl BenchResult {
    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:8.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:8.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:8.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:8.3} s ", ns / 1_000_000_000.0)
    }
}

/// Benchmark registry with a shared time budget per case.
pub struct BenchSuite {
    title: String,
    warmup: Duration,
    measure: Duration,
    samples: usize,
    pub results: Vec<BenchResult>,
}

impl BenchSuite {
    pub fn new(title: &str) -> Self {
        // Honor a quick mode for CI-ish runs: MAGNUS_BENCH_QUICK=1.
        let quick = std::env::var("MAGNUS_BENCH_QUICK").is_ok();
        BenchSuite {
            title: title.to_string(),
            warmup: if quick {
                Duration::from_millis(20)
            } else {
                Duration::from_millis(200)
            },
            measure: if quick {
                Duration::from_millis(100)
            } else {
                Duration::from_millis(800)
            },
            samples: if quick { 10 } else { 30 },
            results: Vec::new(),
        }
    }

    /// Measure `f`, which performs ONE logical operation per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + calibration: how many calls fit in ~1/samples budget?
        let w0 = Instant::now();
        let mut calls: u64 = 0;
        while w0.elapsed() < self.warmup {
            f();
            calls += 1;
        }
        let per_call = self.warmup.as_nanos() as f64 / calls.max(1) as f64;
        let budget_ns = self.measure.as_nanos() as f64 / self.samples as f64;
        let batch = ((budget_ns / per_call).ceil() as u64).max(1);

        let mut samples_ns: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples_ns.push(t0.elapsed().as_nanos() as f64 / batch as f64);
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
        let var = samples_ns
            .iter()
            .map(|x| (x - mean) * (x - mean))
            .sum::<f64>()
            / samples_ns.len() as f64;
        let p = |q: f64| {
            let idx = (q * (samples_ns.len() - 1) as f64).round() as usize;
            samples_ns[idx]
        };
        let res = BenchResult {
            name: name.to_string(),
            iters: batch * self.samples as u64,
            mean_ns: mean,
            p50_ns: p(0.50),
            p95_ns: p(0.95),
            stddev_ns: var.sqrt(),
        };
        println!(
            "  {:44} mean {}  p50 {}  p95 {}  (n={})",
            res.name,
            fmt_ns(res.mean_ns),
            fmt_ns(res.p50_ns),
            fmt_ns(res.p95_ns),
            res.iters
        );
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Measure with a value-producing closure (prevents dead-code elision).
    pub fn bench_val<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.bench(name, move || {
            black_box(f());
        })
    }

    pub fn header(&self) {
        println!("\n== {} ==", self.title);
    }

    /// Timing samples taken per case (smaller under MAGNUS_BENCH_QUICK).
    pub fn samples(&self) -> usize {
        self.samples
    }

    /// Machine-readable export of every measured result.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::str(self.title.clone())),
            (
                "results",
                Json::Arr(
                    self.results
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("name", Json::str(r.name.clone())),
                                ("iters", Json::num(r.iters as f64)),
                                ("mean_ns", Json::num(r.mean_ns)),
                                ("p50_ns", Json::num(r.p50_ns)),
                                ("p95_ns", Json::num(r.p95_ns)),
                                ("stddev_ns", Json::num(r.stddev_ns)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Write [`BenchSuite::to_json`] to `path`.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
    }

    /// Assert an upper bound on a named result's mean (used to check the
    /// paper's §IV-D overhead numbers).
    pub fn assert_mean_below(&self, name: &str, limit: Duration) {
        let r = self
            .results
            .iter()
            .find(|r| r.name == name)
            .unwrap_or_else(|| panic!("no bench named {name}"));
        assert!(
            r.mean_ns <= limit.as_nanos() as f64,
            "{name}: mean {} exceeds limit {:?}",
            fmt_ns(r.mean_ns),
            limit
        );
    }
}

/// Record the end-to-end simulator speedup measurement as
/// `BENCH_sim.json` at the repo root — the machine-readable start of the
/// perf trajectory (EXPERIMENTS.md §Perf reads these fields).
///
/// `naive_s` / `cached_s` are wall-clock seconds for one full
/// `run_magnus_with` pass in `DispatchMode::Fresh` / `DispatchMode::Cached`
/// over the same trace and predictor.  Written by the `bench_sim`
/// harness (multi-sample, authoritative — always overwrites) and by the
/// `dispatch_equivalence` tier-1 test (single sample, only when no
/// record exists yet, so it never clobbers a bench-quality one).
pub fn record_sim_bench(
    path: &str,
    rate: f64,
    n_requests: usize,
    samples: usize,
    naive_s: f64,
    cached_s: f64,
    extra: Vec<(&str, Json)>,
) -> std::io::Result<()> {
    let unix_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let mut fields = vec![
        ("bench", Json::str("sim_e2e_dispatch")),
        ("rate", Json::num(rate)),
        ("requests", Json::num(n_requests as f64)),
        ("samples", Json::num(samples as f64)),
        ("naive_s", Json::num(naive_s)),
        ("cached_s", Json::num(cached_s)),
        ("speedup", Json::num(naive_s / cached_s.max(1e-12))),
        ("unix_time", Json::num(unix_s as f64)),
    ];
    fields.extend(extra);
    std::fs::write(path, Json::obj(fields).to_string_pretty())
}

/// Record the predictor hot-path comparison as `BENCH_predictor.json` at
/// the repo root (same shape as [`record_sim_bench`]'s `BENCH_sim.json`).
/// `naive_predict_ns` is the node-enum / per-call-allocation baseline,
/// `flat_predict_ns` the flattened SoA + zero-alloc pipeline (per-row,
/// batched); the refit pair compares the pre-overhaul row-cloned serial
/// forest fit against the index-based parallel one at a
/// continuous-learning train-set size.  Written by
/// `benches/bench_predictor.rs` (multi-sample, authoritative — always
/// overwrites) and by the tier-1 `predictor_equivalence` test (single
/// sample, only when no record exists yet).
#[allow(clippy::too_many_arguments)]
pub fn record_predictor_bench(
    path: &str,
    train_rows: usize,
    test_rows: usize,
    samples: usize,
    naive_predict_ns: f64,
    flat_predict_ns: f64,
    refit_naive_s: f64,
    refit_flat_s: f64,
    extra: Vec<(&str, Json)>,
) -> std::io::Result<()> {
    let unix_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let mut fields = vec![
        ("bench", Json::str("predictor_hot_path")),
        ("train_rows", Json::num(train_rows as f64)),
        ("test_rows", Json::num(test_rows as f64)),
        ("samples", Json::num(samples as f64)),
        ("naive_predict_ns", Json::num(naive_predict_ns)),
        ("flat_predict_ns", Json::num(flat_predict_ns)),
        (
            "speedup",
            Json::num(naive_predict_ns / flat_predict_ns.max(1e-9)),
        ),
        ("refit_naive_s", Json::num(refit_naive_s)),
        ("refit_flat_s", Json::num(refit_flat_s)),
        (
            "refit_speedup",
            Json::num(refit_naive_s / refit_flat_s.max(1e-12)),
        ),
        ("unix_time", Json::num(unix_s as f64)),
    ];
    fields.extend(extra);
    std::fs::write(path, Json::obj(fields).to_string_pretty())
}

/// Record the scheduler/log-path scale measurements as `BENCH_sched.json`
/// at the repo root (same family as `BENCH_sim.json` /
/// `BENCH_predictor.json`).  `depths` pairs with `scan_ns` /
/// `indexed_ns`: mean HRRN select cost at each queue depth for the O(Q)
/// linear scan vs the batcher's indexed heaps.  `append_ns` /
/// `append_contended_ns` measure one LogDb append alone vs under a
/// continuously-sweeping concurrent reader.  Written by
/// `benches/bench_scheduler.rs`.
pub fn record_sched_bench(
    path: &str,
    depths: &[usize],
    scan_ns: &[f64],
    indexed_ns: &[f64],
    append_ns: f64,
    append_contended_ns: f64,
    extra: Vec<(&str, Json)>,
) -> std::io::Result<()> {
    assert_eq!(depths.len(), scan_ns.len());
    assert_eq!(depths.len(), indexed_ns.len());
    let unix_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let deepest = depths.len() - 1;
    let mut fields = vec![
        ("bench", Json::str("sched_select_logdb")),
        (
            "depths",
            Json::Arr(depths.iter().map(|&d| Json::num(d as f64)).collect()),
        ),
        (
            "scan_select_ns",
            Json::Arr(scan_ns.iter().map(|&v| Json::num(v)).collect()),
        ),
        (
            "indexed_select_ns",
            Json::Arr(indexed_ns.iter().map(|&v| Json::num(v)).collect()),
        ),
        (
            "speedup_deepest",
            Json::num(scan_ns[deepest] / indexed_ns[deepest].max(1e-9)),
        ),
        ("logdb_append_ns", Json::num(append_ns)),
        ("logdb_append_contended_ns", Json::num(append_contended_ns)),
        (
            "logdb_contention_overhead",
            Json::num(append_contended_ns / append_ns.max(1e-9)),
        ),
        ("unix_time", Json::num(unix_s as f64)),
    ];
    fields.extend(extra);
    std::fs::write(path, Json::obj(fields).to_string_pretty())
}

/// One measured point of the zero-copy scale sweep (`BENCH_scale.json`).
///
/// `n` requests at the sweep's arrival rate; `store_*` fields measure the
/// interned `TraceStore` path (streaming generation + compact pipeline),
/// `owned_*` the owned-`Request` reference (`sim::reference`) — `None`
/// above the owned cap, where the reference is wall-clock prohibitive.
/// Times are end-to-end seconds including trace generation; peaks are
/// [`crate::util::alloc`] high-water bytes over the same window.
#[derive(Debug, Clone, Copy)]
pub struct ScalePoint {
    pub n: usize,
    pub store_s: f64,
    pub store_peak_bytes: usize,
    pub arena_bytes: usize,
    pub owned_s: Option<f64>,
    pub owned_peak_bytes: Option<usize>,
}

/// Record the zero-copy scale sweep as `BENCH_scale.json` at the repo
/// root (same family as the other `BENCH_*.json` records).  Derives the
/// headline ratios — wall-time speedup and peak-byte reduction — at the
/// largest N both paths ran.
pub fn record_scale_bench(
    path: &str,
    rate: f64,
    points: &[ScalePoint],
    extra: Vec<(&str, Json)>,
) -> std::io::Result<()> {
    let unix_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let arr = |f: &dyn Fn(&ScalePoint) -> Json| {
        Json::Arr(points.iter().map(f).collect())
    };
    let mut fields = vec![
        ("bench", Json::str("sim_scale_zero_copy")),
        ("rate", Json::num(rate)),
        ("n", arr(&|p| Json::num(p.n as f64))),
        ("store_s", arr(&|p| Json::num(p.store_s))),
        (
            "store_peak_bytes",
            arr(&|p| Json::num(p.store_peak_bytes as f64)),
        ),
        ("arena_bytes", arr(&|p| Json::num(p.arena_bytes as f64))),
        (
            "owned_s",
            arr(&|p| p.owned_s.map_or(Json::Null, Json::num)),
        ),
        (
            "owned_peak_bytes",
            arr(&|p| p.owned_peak_bytes.map_or(Json::Null, |b| Json::num(b as f64))),
        ),
        ("unix_time", Json::num(unix_s as f64)),
    ];
    if let Some(p) = points
        .iter()
        .rev()
        .find(|p| p.owned_s.is_some() && p.owned_peak_bytes.is_some())
    {
        fields.push(("compared_n", Json::num(p.n as f64)));
        fields.push((
            "speedup",
            Json::num(p.owned_s.unwrap() / p.store_s.max(1e-12)),
        ));
        fields.push((
            "peak_bytes_ratio",
            Json::num(p.owned_peak_bytes.unwrap() as f64 / p.store_peak_bytes.max(1) as f64),
        ));
    }
    fields.extend(extra);
    std::fs::write(path, Json::obj(fields).to_string_pretty())
}

/// One measured point of the trace-I/O sweep (`BENCH_trace.json`).
///
/// The same generated trace serialised both ways, then loaded back: the
/// `json_*` fields time the JSON route (read + parse + re-intern, which
/// materialises the whole text arena before the first request can
/// dispatch), the `mmap_*` fields time `TraceStore::open_mmap` (O(metas)
/// decode; the kernel pages the arena on demand), and the `read_*`
/// fields the explicit read-into-memory fallback over the same decode.
/// Peaks are [`crate::util::alloc`] high-water bytes over each load.
#[derive(Debug, Clone, Copy)]
pub struct TracePoint {
    pub n: usize,
    /// Binary trace file size (the mapped footprint).
    pub file_bytes: usize,
    pub arena_bytes: usize,
    pub json_parse_s: f64,
    pub json_peak_bytes: usize,
    pub mmap_open_s: f64,
    pub mmap_open_peak_bytes: usize,
    pub read_open_s: f64,
    pub read_open_peak_bytes: usize,
    /// Whether `open_mmap` actually mapped (false = platform fell back).
    pub mmap_backed: bool,
}

/// One measured point of the big-trace open+replay gate (ISSUE 10): a
/// sharded 10⁷–10⁸-request trace generated streaming, reopened through
/// the manifest (O(shards) verification over O(1)-lazy per-shard
/// decodes), then swept end to end.
#[derive(Debug, Clone, Copy)]
pub struct BigTracePoint {
    pub n: usize,
    pub shards: usize,
    /// Total bytes across all shard files.
    pub file_bytes: usize,
    /// Streaming generation + shard-file write wall time.
    pub gen_write_s: f64,
    /// Manifest open: checksum walk + per-shard lazy decode.
    pub open_s: f64,
    /// Alloc high-water over the open — the O(1)-in-metas evidence.
    pub open_peak_bytes: usize,
    /// Full arrival + meta sweep over every request.
    pub replay_s: f64,
    pub replay_peak_bytes: usize,
    /// What an eager per-meta table would hold resident
    /// (`n × sizeof(RequestMeta)`) — the peak-reduction denominator.
    pub eager_meta_bytes: usize,
}

/// Record the trace-I/O sweep as `BENCH_trace.json` at the repo root
/// (same family as the other `BENCH_*.json` records).  Derives the
/// headline ratios — binary-open speedup over JSON parse and the peak-
/// heap reduction — at the largest measured N, plus the big-trace
/// open/replay throughputs and peak-heap reduction when that gate ran.
pub fn record_trace_bench(
    path: &str,
    points: &[TracePoint],
    big: Option<&BigTracePoint>,
    extra: Vec<(&str, Json)>,
) -> std::io::Result<()> {
    let unix_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let arr = |f: &dyn Fn(&TracePoint) -> Json| Json::Arr(points.iter().map(f).collect());
    let mut fields = vec![
        ("bench", Json::str("trace_io_load")),
        ("n", arr(&|p| Json::num(p.n as f64))),
        ("file_bytes", arr(&|p| Json::num(p.file_bytes as f64))),
        ("arena_bytes", arr(&|p| Json::num(p.arena_bytes as f64))),
        ("json_parse_s", arr(&|p| Json::num(p.json_parse_s))),
        (
            "json_peak_bytes",
            arr(&|p| Json::num(p.json_peak_bytes as f64)),
        ),
        ("mmap_open_s", arr(&|p| Json::num(p.mmap_open_s))),
        (
            "mmap_open_peak_bytes",
            arr(&|p| Json::num(p.mmap_open_peak_bytes as f64)),
        ),
        ("read_open_s", arr(&|p| Json::num(p.read_open_s))),
        (
            "read_open_peak_bytes",
            arr(&|p| Json::num(p.read_open_peak_bytes as f64)),
        ),
        ("mmap_backed", arr(&|p| Json::Bool(p.mmap_backed))),
        ("unix_time", Json::num(unix_s as f64)),
    ];
    if let Some(p) = points.last() {
        fields.push(("compared_n", Json::num(p.n as f64)));
        fields.push((
            "open_speedup",
            Json::num(p.json_parse_s / p.mmap_open_s.max(1e-12)),
        ));
        fields.push((
            "peak_bytes_ratio",
            Json::num(p.json_peak_bytes as f64 / p.mmap_open_peak_bytes.max(1) as f64),
        ));
    }
    if let Some(b) = big {
        fields.push(("bigtrace_n", Json::num(b.n as f64)));
        fields.push(("bigtrace_shards", Json::num(b.shards as f64)));
        fields.push(("bigtrace_file_bytes", Json::num(b.file_bytes as f64)));
        fields.push(("bigtrace_gen_write_s", Json::num(b.gen_write_s)));
        fields.push(("bigtrace_open_s", Json::num(b.open_s)));
        fields.push((
            "bigtrace_open_peak_bytes",
            Json::num(b.open_peak_bytes as f64),
        ));
        fields.push(("bigtrace_replay_s", Json::num(b.replay_s)));
        fields.push((
            "bigtrace_replay_peak_bytes",
            Json::num(b.replay_peak_bytes as f64),
        ));
        // Headline fields (the `bench_diff` gate watches *throughput /
        // *speedup names): requests opened and replayed per second, and
        // the open peak-heap reduction versus an eager meta table.
        fields.push((
            "bigtrace_open_throughput",
            Json::num(b.n as f64 / b.open_s.max(1e-12)),
        ));
        fields.push((
            "bigtrace_replay_throughput",
            Json::num(b.n as f64 / b.replay_s.max(1e-12)),
        ));
        fields.push((
            "bigtrace_open_peak_speedup",
            Json::num(b.eager_meta_bytes as f64 / b.open_peak_bytes.max(1) as f64),
        ));
    }
    fields.extend(extra);
    std::fs::write(path, Json::obj(fields).to_string_pretty())
}

/// One measured point of the robustness fault sweep
/// (`BENCH_robustness.json`).
///
/// Each point replays the same trace under a seeded [`crate::faults`]
/// plan whose crash / transient-error / forced-OOM probabilities scale
/// with `fault_rate` (0.0 = fault-free baseline).  Counters come from
/// [`crate::metrics::RunMetrics`]; every admitted request is either in
/// `completed` or `shed` — the exactly-once invariant the chaos suite
/// asserts.
#[derive(Debug, Clone)]
pub struct RobustnessPoint {
    pub label: String,
    pub fault_rate: f64,
    pub n_requests: usize,
    pub completed: usize,
    pub shed: usize,
    pub retries: u32,
    pub worker_restarts: u32,
    pub fallback_predictions: u32,
    pub oom_events: u32,
    pub request_throughput: f64,
    pub mean_response_time: f64,
    pub p95_response_time: f64,
}

/// Record the robustness degradation curve as `BENCH_robustness.json` at
/// the repo root (same family as the other `BENCH_*.json` records).
/// Derives the headline ratios — throughput and mean-RT degradation plus
/// the completion fraction — at the highest fault rate relative to the
/// `fault_rate == 0.0` baseline when both are present.
pub fn record_robustness_bench(
    path: &str,
    n_requests: usize,
    rate: f64,
    points: &[RobustnessPoint],
    extra: Vec<(&str, Json)>,
) -> std::io::Result<()> {
    let unix_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let arr = |f: &dyn Fn(&RobustnessPoint) -> Json| {
        Json::Arr(points.iter().map(f).collect())
    };
    let mut fields = vec![
        ("bench", Json::str("robustness_fault_sweep")),
        ("requests", Json::num(n_requests as f64)),
        ("rate", Json::num(rate)),
        ("label", arr(&|p| Json::str(p.label.clone()))),
        ("fault_rate", arr(&|p| Json::num(p.fault_rate))),
        ("completed", arr(&|p| Json::num(p.completed as f64))),
        ("shed", arr(&|p| Json::num(p.shed as f64))),
        ("retries", arr(&|p| Json::num(p.retries))),
        ("worker_restarts", arr(&|p| Json::num(p.worker_restarts))),
        (
            "fallback_predictions",
            arr(&|p| Json::num(p.fallback_predictions)),
        ),
        ("oom_events", arr(&|p| Json::num(p.oom_events))),
        (
            "request_throughput",
            arr(&|p| Json::num(p.request_throughput)),
        ),
        (
            "mean_response_time",
            arr(&|p| Json::num(p.mean_response_time)),
        ),
        (
            "p95_response_time",
            arr(&|p| Json::num(p.p95_response_time)),
        ),
        ("unix_time", Json::num(unix_s as f64)),
    ];
    let base = points.iter().find(|p| p.fault_rate == 0.0);
    let worst = points
        .iter()
        .filter(|p| p.fault_rate > 0.0)
        .max_by(|a, b| a.fault_rate.partial_cmp(&b.fault_rate).unwrap());
    if let (Some(base), Some(worst)) = (base, worst) {
        fields.push(("worst_fault_rate", Json::num(worst.fault_rate)));
        fields.push((
            "throughput_degradation",
            Json::num(base.request_throughput / worst.request_throughput.max(1e-12)),
        ));
        fields.push((
            "mean_rt_inflation",
            Json::num(worst.mean_response_time / base.mean_response_time.max(1e-12)),
        ));
        fields.push((
            "worst_completion_fraction",
            Json::num(worst.completed as f64 / (worst.completed + worst.shed).max(1) as f64),
        ));
    }
    fields.extend(extra);
    std::fs::write(path, Json::obj(fields).to_string_pretty())
}

/// One measured point of the uncertainty/drift comparison
/// (`BENCH_uncertainty.json`).
///
/// Both points replay the same trace under the same seeded drift
/// schedule; they differ only in `uncertainty_enabled` — the
/// point-estimate baseline versus confidence-aware scheduling with
/// upper-quantile admission, drift-triggered degradation and
/// speculative re-bucketing.  Counters come from
/// [`crate::metrics::RunMetrics`].
#[derive(Debug, Clone)]
pub struct UncertaintyPoint {
    pub label: String,
    pub uncertainty_enabled: bool,
    pub completed: usize,
    pub shed: usize,
    /// Completed requests per simulated second over the run's makespan —
    /// the number the confidence layer must defend under drift.
    pub goodput: f64,
    pub oom_events: u32,
    pub low_confidence_admissions: u32,
    pub drift_demotions: u32,
    pub drift_repromotions: u32,
    pub speculative_rebuckets: u32,
    pub fallback_predictions: u32,
    pub mean_response_time: f64,
}

/// Record the uncertainty-aware-vs-point-estimate comparison as
/// `BENCH_uncertainty.json` at the repo root.  The headline
/// `goodput_retention` is the confidence-aware goodput over the
/// point-estimate baseline's under the identical drift schedule —
/// ISSUE 9's acceptance gate requires ≥ 1.2 under a ≥ 0.3 bias.
pub fn record_uncertainty_bench(
    path: &str,
    n_requests: usize,
    rate: f64,
    drift_bias: f64,
    points: &[UncertaintyPoint],
    extra: Vec<(&str, Json)>,
) -> std::io::Result<()> {
    let unix_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let arr = |f: &dyn Fn(&UncertaintyPoint) -> Json| {
        Json::Arr(points.iter().map(f).collect())
    };
    let mut fields = vec![
        ("bench", Json::str("uncertainty_drift_retention")),
        ("requests", Json::num(n_requests as f64)),
        ("rate", Json::num(rate)),
        ("drift_bias", Json::num(drift_bias)),
        ("label", arr(&|p| Json::str(p.label.clone()))),
        (
            "uncertainty_enabled",
            arr(&|p| Json::Bool(p.uncertainty_enabled)),
        ),
        ("completed", arr(&|p| Json::num(p.completed as f64))),
        ("shed", arr(&|p| Json::num(p.shed as f64))),
        ("goodput", arr(&|p| Json::num(p.goodput))),
        ("oom_events", arr(&|p| Json::num(p.oom_events))),
        (
            "low_confidence_admissions",
            arr(&|p| Json::num(p.low_confidence_admissions)),
        ),
        ("drift_demotions", arr(&|p| Json::num(p.drift_demotions))),
        (
            "drift_repromotions",
            arr(&|p| Json::num(p.drift_repromotions)),
        ),
        (
            "speculative_rebuckets",
            arr(&|p| Json::num(p.speculative_rebuckets)),
        ),
        (
            "fallback_predictions",
            arr(&|p| Json::num(p.fallback_predictions)),
        ),
        (
            "mean_response_time",
            arr(&|p| Json::num(p.mean_response_time)),
        ),
        ("unix_time", Json::num(unix_s as f64)),
    ];
    let base = points.iter().find(|p| !p.uncertainty_enabled);
    let conf = points.iter().find(|p| p.uncertainty_enabled);
    if let (Some(base), Some(conf)) = (base, conf) {
        fields.push((
            "goodput_retention",
            Json::num(conf.goodput / base.goodput.max(1e-12)),
        ));
        fields.push((
            "oom_reduction",
            Json::num(f64::from(base.oom_events) / f64::from(conf.oom_events).max(1.0)),
        ));
    }
    fields.extend(extra);
    std::fs::write(path, Json::obj(fields).to_string_pretty())
}

/// One measured point of the edge overload sweep (`BENCH_edge.json`).
///
/// Each point drives a live [`crate::edge::EdgeServer`] with the
/// open-loop generator at a multiple of measured capacity; the counters
/// come from [`crate::edge::EdgeReport`], whose accounting identity
/// (`offered == completed + shed + expired + core_shed`) the bench
/// asserts before recording anything.
#[derive(Debug, Clone)]
pub struct EdgePoint {
    pub label: String,
    /// Offered load as a multiple of measured capacity (1.0 = at
    /// capacity, 5.0 = 5× overload).
    pub overload: f64,
    pub offered_rps: f64,
    pub offered: u64,
    pub completed: u64,
    pub shed: u64,
    pub expired: u64,
    pub core_shed: u64,
    /// Completions per wall second — the number that must *hold* as the
    /// offered load grows past capacity.
    pub goodput: f64,
    pub shed_rate: f64,
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    /// Peak admission-queue lag seen by the generator (open-loop check).
    pub max_lag_s: f64,
}

/// Record the edge overload curve as `BENCH_edge.json` at the repo root.
/// Derives the headline numbers: goodput retention and shed rate at the
/// worst overload relative to the ~1× point — graceful degradation means
/// retention stays near 1 while shed rate absorbs the excess.
pub fn record_edge_bench(
    path: &str,
    capacity_rps: f64,
    points: &[EdgePoint],
    extra: Vec<(&str, Json)>,
) -> std::io::Result<()> {
    let unix_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let arr = |f: &dyn Fn(&EdgePoint) -> Json| Json::Arr(points.iter().map(f).collect());
    let mut fields = vec![
        ("bench", Json::str("edge_overload_sweep")),
        ("capacity_rps", Json::num(capacity_rps)),
        ("label", arr(&|p| Json::str(p.label.clone()))),
        ("overload", arr(&|p| Json::num(p.overload))),
        ("offered_rps", arr(&|p| Json::num(p.offered_rps))),
        ("offered", arr(&|p| Json::num(p.offered as f64))),
        ("completed", arr(&|p| Json::num(p.completed as f64))),
        ("shed", arr(&|p| Json::num(p.shed as f64))),
        ("expired", arr(&|p| Json::num(p.expired as f64))),
        ("core_shed", arr(&|p| Json::num(p.core_shed as f64))),
        ("goodput_rps", arr(&|p| Json::num(p.goodput))),
        ("shed_rate", arr(&|p| Json::num(p.shed_rate))),
        ("p50_latency_s", arr(&|p| Json::num(p.p50_latency_s))),
        ("p99_latency_s", arr(&|p| Json::num(p.p99_latency_s))),
        ("max_lag_s", arr(&|p| Json::num(p.max_lag_s))),
        ("unix_time", Json::num(unix_s as f64)),
    ];
    let base = points
        .iter()
        .filter(|p| p.overload > 0.0)
        .min_by(|a, b| a.overload.partial_cmp(&b.overload).unwrap());
    let worst = points
        .iter()
        .max_by(|a, b| a.overload.partial_cmp(&b.overload).unwrap());
    if let (Some(base), Some(worst)) = (base, worst) {
        if worst.overload > base.overload {
            fields.push(("worst_overload", Json::num(worst.overload)));
            fields.push((
                "goodput_retention",
                Json::num(worst.goodput / base.goodput.max(1e-12)),
            ));
            fields.push(("worst_shed_rate", Json::num(worst.shed_rate)));
            fields.push((
                "p99_inflation",
                Json::num(worst.p99_latency_s / base.p99_latency_s.max(1e-12)),
            ));
        }
    }
    fields.extend(extra);
    std::fs::write(path, Json::obj(fields).to_string_pretty())
}

/// One measured point of the cluster routing/fault matrix
/// (`BENCH_cluster.json`): one route policy under one fault schedule,
/// through the deterministic discrete-event cluster
/// ([`crate::cluster::run_cluster_store`]) — numbers are bit-stable
/// across runs, so the CI gate never flaps on them.
#[derive(Debug, Clone)]
pub struct ClusterPoint {
    /// Route policy name (`rr`, `jspq`, `p2c`, `band`).
    pub policy: String,
    /// Fault schedule label (`nofault`, `slow1`, `kill1`, ...).
    pub schedule: String,
    /// Completions per simulated second under this schedule.
    pub goodput: f64,
    pub p99_response_time: f64,
    /// Max-over-mean completions per instance (1.0 = perfectly even).
    pub imbalance: f64,
    /// Mean heartbeat detection latency over failovers (0 if none).
    pub recovery_s: f64,
    pub completed: usize,
    pub shed: usize,
    pub steals: u64,
    pub reroutes: u64,
    pub duplicate_acks: u64,
}

/// Record the routing-policy × fault-schedule matrix as
/// `BENCH_cluster.json` at the repo root.  The gated headline
/// (`cluster_goodput`) is the best policy's goodput on
/// `headline_schedule`; the round-robin comparison fields are named
/// without the gate substrings on purpose — they may be negative and
/// must not trip the higher-is-better check.
pub fn record_cluster_bench(
    path: &str,
    n_requests: usize,
    rate: f64,
    n_nodes: usize,
    headline_schedule: &str,
    points: &[ClusterPoint],
    extra: Vec<(&str, Json)>,
) -> std::io::Result<()> {
    let unix_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let arr = |f: &dyn Fn(&ClusterPoint) -> Json| Json::Arr(points.iter().map(f).collect());
    let mut fields = vec![
        ("bench", Json::str("cluster_routing_fault_matrix")),
        ("requests", Json::num(n_requests as f64)),
        ("rate", Json::num(rate)),
        ("instances", Json::num(n_nodes as f64)),
        ("headline_schedule", Json::str(headline_schedule.to_string())),
        ("policy", arr(&|p| Json::str(p.policy.clone()))),
        ("schedule", arr(&|p| Json::str(p.schedule.clone()))),
        ("goodput", arr(&|p| Json::num(p.goodput))),
        ("p99_response_time", arr(&|p| Json::num(p.p99_response_time))),
        ("imbalance", arr(&|p| Json::num(p.imbalance))),
        ("recovery_s", arr(&|p| Json::num(p.recovery_s))),
        ("completed", arr(&|p| Json::num(p.completed as f64))),
        ("shed", arr(&|p| Json::num(p.shed as f64))),
        ("steals", arr(&|p| Json::num(p.steals as f64))),
        ("reroutes", arr(&|p| Json::num(p.reroutes as f64))),
        ("duplicate_acks", arr(&|p| Json::num(p.duplicate_acks as f64))),
        ("unix_time", Json::num(unix_s as f64)),
    ];
    let on_headline: Vec<&ClusterPoint> = points
        .iter()
        .filter(|p| p.schedule == headline_schedule)
        .collect();
    let rr = on_headline.iter().find(|p| p.policy == "rr");
    let best = on_headline
        .iter()
        .max_by(|a, b| a.goodput.partial_cmp(&b.goodput).unwrap());
    if let Some(best) = best {
        fields.push(("cluster_goodput", Json::num(best.goodput)));
        fields.push(("best_policy", Json::str(best.policy.clone())));
        if let Some(rr) = rr {
            fields.push((
                "gain_vs_round_robin_pct",
                Json::num((best.goodput / rr.goodput.max(1e-12) - 1.0) * 100.0),
            ));
            let best_p99 = on_headline
                .iter()
                .filter(|p| p.policy != "rr")
                .map(|p| p.p99_response_time)
                .fold(f64::INFINITY, f64::min);
            if best_p99.is_finite() {
                fields.push((
                    "p99_gain_vs_round_robin_pct",
                    Json::num((rr.p99_response_time / best_p99.max(1e-12) - 1.0) * 100.0),
                ));
            }
        }
    }
    let recoveries: Vec<f64> = points
        .iter()
        .filter(|p| p.recovery_s > 0.0)
        .map(|p| p.recovery_s)
        .collect();
    if !recoveries.is_empty() {
        fields.push((
            "mean_recovery_s",
            Json::num(recoveries.iter().sum::<f64>() / recoveries.len() as f64),
        ));
    }
    fields.extend(extra);
    std::fs::write(path, Json::obj(fields).to_string_pretty())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        std::env::set_var("MAGNUS_BENCH_QUICK", "1");
        let mut s = BenchSuite::new("t");
        let r = s.bench_val("noop-ish", || 1u64 + black_box(2u64));
        assert!(r.mean_ns > 0.0);
        assert!(r.iters > 0);
    }

    #[test]
    fn json_export_roundtrips() {
        std::env::set_var("MAGNUS_BENCH_QUICK", "1");
        let mut s = BenchSuite::new("t");
        s.bench_val("case", || black_box(1u64) + 1);
        let j = s.to_json();
        let results = j.get("results").as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("name").as_str(), Some("case"));
        assert!(results[0].get("mean_ns").as_f64().unwrap() > 0.0);
        // parse back through the JSON layer
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("title").as_str(), Some("t"));
    }

    #[test]
    fn record_sim_bench_writes_speedup() {
        let path = std::env::temp_dir().join("magnus_bench_sim_test.json");
        let path = path.to_string_lossy().into_owned();
        record_sim_bench(&path, 10.0, 600, 3, 4.0, 1.0, vec![]).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("speedup").as_f64(), Some(4.0));
        assert_eq!(j.get("requests").as_u64(), Some(600));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn record_predictor_bench_writes_speedups() {
        let path = std::env::temp_dir().join("magnus_bench_predictor_test.json");
        let path = path.to_string_lossy().into_owned();
        record_predictor_bench(&path, 3200, 800, 1, 6000.0, 1000.0, 0.4, 0.1, vec![])
            .unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("speedup").as_f64(), Some(6.0));
        assert_eq!(j.get("refit_speedup").as_f64(), Some(4.0));
        assert_eq!(j.get("train_rows").as_u64(), Some(3200));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn record_sched_bench_writes_ratios() {
        let path = std::env::temp_dir().join("magnus_bench_sched_test.json");
        let path = path.to_string_lossy().into_owned();
        record_sched_bench(
            &path,
            &[16, 256, 4096],
            &[100.0, 1600.0, 25600.0],
            &[50.0, 60.0, 80.0],
            200.0,
            260.0,
            vec![],
        )
        .unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("speedup_deepest").as_f64(), Some(320.0));
        assert_eq!(j.get("logdb_contention_overhead").as_f64(), Some(1.3));
        assert_eq!(j.get("depths").as_arr().unwrap().len(), 3);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn record_cluster_bench_derives_headline_and_rr_gains() {
        let path = std::env::temp_dir().join("magnus_bench_cluster_test.json");
        let path = path.to_string_lossy().into_owned();
        let mk = |policy: &str, schedule: &str, goodput: f64, p99: f64| ClusterPoint {
            policy: policy.into(),
            schedule: schedule.into(),
            goodput,
            p99_response_time: p99,
            imbalance: 1.2,
            recovery_s: if schedule == "kill1" { 2.0 } else { 0.0 },
            completed: 100,
            shed: 3,
            steals: 1,
            reroutes: 4,
            duplicate_acks: 0,
        };
        let points = [
            mk("rr", "kill1", 4.0, 10.0),
            mk("jspq", "kill1", 5.0, 8.0),
            mk("rr", "nofault", 6.0, 5.0),
        ];
        record_cluster_bench(&path, 400, 8.0, 4, "kill1", &points, vec![]).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("cluster_goodput").as_f64(), Some(5.0));
        assert_eq!(j.get("best_policy").as_str(), Some("jspq"));
        assert_eq!(j.get("gain_vs_round_robin_pct").as_f64(), Some(25.0));
        assert_eq!(j.get("p99_gain_vs_round_robin_pct").as_f64(), Some(25.0));
        assert_eq!(j.get("mean_recovery_s").as_f64(), Some(2.0));
        assert_eq!(j.get("policy").as_arr().unwrap().len(), 3);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn record_scale_bench_derives_ratios_at_largest_common_n() {
        let path = std::env::temp_dir().join("magnus_bench_scale_test.json");
        let path = path.to_string_lossy().into_owned();
        let points = [
            ScalePoint {
                n: 10_000,
                store_s: 0.5,
                store_peak_bytes: 10_000_000,
                arena_bytes: 1_500_000,
                owned_s: Some(1.0),
                owned_peak_bytes: Some(40_000_000),
            },
            ScalePoint {
                n: 100_000,
                store_s: 5.0,
                store_peak_bytes: 100_000_000,
                arena_bytes: 15_000_000,
                owned_s: Some(10.0),
                owned_peak_bytes: Some(400_000_000),
            },
            ScalePoint {
                n: 1_000_000,
                store_s: 50.0,
                store_peak_bytes: 1_000_000_000,
                arena_bytes: 150_000_000,
                owned_s: None,
                owned_peak_bytes: None,
            },
        ];
        record_scale_bench(&path, 4.0, &points, vec![]).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        // ratios derive from the largest N with an owned measurement
        assert_eq!(j.get("compared_n").as_u64(), Some(100_000));
        assert_eq!(j.get("speedup").as_f64(), Some(2.0));
        assert_eq!(j.get("peak_bytes_ratio").as_f64(), Some(4.0));
        assert_eq!(j.get("n").as_arr().unwrap().len(), 3);
        // the owned column is null past the cap
        assert!(matches!(j.get("owned_s").as_arr().unwrap()[2], Json::Null));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn record_trace_bench_derives_ratios_at_largest_n() {
        let path = std::env::temp_dir().join("magnus_bench_trace_test.json");
        let path = path.to_string_lossy().into_owned();
        let points = [
            TracePoint {
                n: 10_000,
                file_bytes: 2_000_000,
                arena_bytes: 1_500_000,
                json_parse_s: 0.2,
                json_peak_bytes: 12_000_000,
                mmap_open_s: 0.01,
                mmap_open_peak_bytes: 600_000,
                read_open_s: 0.02,
                read_open_peak_bytes: 2_600_000,
                mmap_backed: true,
            },
            TracePoint {
                n: 1_000_000,
                file_bytes: 200_000_000,
                arena_bytes: 150_000_000,
                json_parse_s: 20.0,
                json_peak_bytes: 1_200_000_000,
                mmap_open_s: 0.5,
                mmap_open_peak_bytes: 60_000_000,
                read_open_s: 1.0,
                read_open_peak_bytes: 260_000_000,
                mmap_backed: true,
            },
        ];
        record_trace_bench(&path, &points, None, vec![]).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("compared_n").as_u64(), Some(1_000_000));
        assert_eq!(j.get("open_speedup").as_f64(), Some(40.0));
        assert_eq!(j.get("peak_bytes_ratio").as_f64(), Some(20.0));
        assert_eq!(j.get("n").as_arr().unwrap().len(), 2);
        assert!(
            matches!(j.get("bigtrace_open_throughput"), Json::Null),
            "no big-trace gate ran, so no big-trace fields"
        );

        // With the big-trace gate: throughput and peak headlines derive.
        let big = BigTracePoint {
            n: 10_000_000,
            shards: 8,
            file_bytes: 2_000_000_000,
            gen_write_s: 100.0,
            open_s: 0.5,
            open_peak_bytes: 1_000_000,
            replay_s: 20.0,
            replay_peak_bytes: 2_000_000,
            eager_meta_bytes: 480_000_000,
        };
        record_trace_bench(&path, &points, Some(&big), vec![]).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("bigtrace_n").as_u64(), Some(10_000_000));
        assert_eq!(
            j.get("bigtrace_open_throughput").as_f64(),
            Some(20_000_000.0)
        );
        assert_eq!(j.get("bigtrace_replay_throughput").as_f64(), Some(500_000.0));
        assert_eq!(j.get("bigtrace_open_peak_speedup").as_f64(), Some(480.0));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn record_robustness_bench_derives_degradation_vs_baseline() {
        let path = std::env::temp_dir().join("magnus_bench_robustness_test.json");
        let path = path.to_string_lossy().into_owned();
        let mk = |label: &str, rate: f64, completed: usize, shed: usize, thr: f64, rt: f64| {
            RobustnessPoint {
                label: label.to_string(),
                fault_rate: rate,
                n_requests: 100,
                completed,
                shed,
                retries: if rate > 0.0 { 9 } else { 0 },
                worker_restarts: 0,
                fallback_predictions: 0,
                oom_events: 2,
                request_throughput: thr,
                mean_response_time: rt,
                p95_response_time: rt * 2.0,
            }
        };
        let points = [
            mk("baseline", 0.0, 100, 0, 4.0, 10.0),
            mk("mid", 0.15, 98, 2, 2.0, 15.0),
            mk("storm", 0.30, 80, 20, 1.0, 30.0),
        ];
        record_robustness_bench(&path, 100, 8.0, &points, vec![]).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("worst_fault_rate").as_f64(), Some(0.30));
        assert_eq!(j.get("throughput_degradation").as_f64(), Some(4.0));
        assert_eq!(j.get("mean_rt_inflation").as_f64(), Some(3.0));
        assert_eq!(j.get("worst_completion_fraction").as_f64(), Some(0.8));
        assert_eq!(j.get("fault_rate").as_arr().unwrap().len(), 3);
        // exactly-once accounting is visible per point
        let c = j.get("completed").as_arr().unwrap();
        let s = j.get("shed").as_arr().unwrap();
        for i in 0..3 {
            let total = c[i].as_f64().unwrap() + s[i].as_f64().unwrap();
            assert_eq!(total, 100.0);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    #[should_panic]
    fn assert_mean_below_fires() {
        std::env::set_var("MAGNUS_BENCH_QUICK", "1");
        let mut s = BenchSuite::new("t");
        s.bench("sleepy", || std::thread::sleep(Duration::from_micros(200)));
        s.assert_mean_below("sleepy", Duration::from_nanos(1));
    }
}
