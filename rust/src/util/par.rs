//! Minimal data parallelism over `std::thread::scope` (rayon is not
//! vendored in this environment).
//!
//! [`par_map`] fans a pure index-to-value function out over the available
//! cores with work stealing via a shared atomic counter, then reassembles
//! results in index order — so output is deterministic regardless of
//! thread scheduling.  Used by the figure driver to run independent
//! (policy × load-point) simulator cells concurrently.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Evaluate `f(0..n)` on up to `available_parallelism` worker threads and
/// return results in index order.  `f` must be pure per index (cells must
/// not share mutable state); panics in workers propagate.
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1)
        .min(n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if workers <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let fref = &f;
    let nref = &next;
    let mut pairs: Vec<(usize, T)> = Vec::with_capacity(n);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(move || {
                    let mut local: Vec<(usize, T)> = Vec::new();
                    loop {
                        let i = nref.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, fref(i)));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            pairs.extend(h.join().expect("par_map worker panicked"));
        }
    });
    pairs.sort_by_key(|p| p.0);
    pairs.into_iter().map(|p| p.1).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_index_order() {
        let out = par_map(64, |i| i * i);
        assert_eq!(out.len(), 64);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
        assert_eq!(par_map(1, |i| i + 10), vec![10]);
    }

    #[test]
    fn captures_shared_read_only_state() {
        let table: Vec<u64> = (0..100).map(|i| i * 3).collect();
        let out = par_map(table.len(), |i| table[i] + 1);
        assert_eq!(out[99], 298);
    }

    #[test]
    fn heavy_cells_all_complete() {
        // more cells than cores; each does real work
        let out = par_map(37, |i| {
            let mut acc = 0u64;
            for j in 0..10_000u64 {
                acc = acc.wrapping_add(j ^ i as u64);
            }
            acc
        });
        assert_eq!(out.len(), 37);
    }
}
