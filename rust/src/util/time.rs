//! Duration construction from *computed* float deltas.
//!
//! `Duration::from_secs_f64` panics on negative or NaN input, and a
//! subtraction of two floats in an event loop can produce either (clock
//! skew, NaN-poisoned estimates, deadlines in the past).  Every such
//! call site must clamp first — this helper is the one shared clamp so
//! the audit is "grep for `from_secs_f64`" instead of "re-derive the
//! edge cases at each site".

use std::time::Duration;

/// Convert a computed delta (seconds) into a [`Duration`], clamping
/// NaN and non-positive values to [`Duration::ZERO`].
///
/// The NaN check is load-bearing and must come first: `f64::min`/`max`
/// propagate the *other* operand on NaN (`f64::NAN.max(0.0) == 0.0`
/// but `f64::NAN.min(cap) == cap`), so a naive `clamp` chain can turn
/// NaN into the cap instead of zero.
#[inline]
pub fn clamped_duration(secs: f64) -> Duration {
    if secs.is_nan() || secs <= 0.0 {
        return Duration::ZERO;
    }
    Duration::from_secs_f64(secs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clamps_nan_and_non_positive_to_zero() {
        assert_eq!(clamped_duration(f64::NAN), Duration::ZERO);
        assert_eq!(clamped_duration(-1.0), Duration::ZERO);
        assert_eq!(clamped_duration(-0.0), Duration::ZERO);
        assert_eq!(clamped_duration(0.0), Duration::ZERO);
        assert_eq!(clamped_duration(f64::NEG_INFINITY), Duration::ZERO);
    }

    #[test]
    fn passes_positive_values_through_exactly() {
        for secs in [1e-9, 0.05, 1.0, 3600.0] {
            assert_eq!(clamped_duration(secs), Duration::from_secs_f64(secs));
        }
    }
}
