//! Duration construction from *computed* float deltas.
//!
//! `Duration::from_secs_f64` panics on negative, NaN, infinite, or
//! `Duration`-overflowing input (> u64::MAX s ≈ 1.84e19), and a subtraction or
//! division of floats in an event loop can produce any of these (clock
//! skew, NaN-poisoned estimates, deadlines in the past, degenerate
//! user-supplied intervals).  Every such call site must clamp first —
//! this helper is the one shared clamp so the audit is "grep for
//! `from_secs_f64`" instead of "re-derive the edge cases at each site".
//!
//! Note for callers feeding the result into `Instant` arithmetic: the
//! saturated `Duration::MAX` overflows `Instant + Duration` — bound the
//! result (e.g. `.min(...)`) before adding it to a clock reading.

use std::time::Duration;

/// Convert a computed delta (seconds) into a [`Duration`], clamping
/// NaN and non-positive values to [`Duration::ZERO`] and `+inf` or
/// anything overflowing `Duration` to [`Duration::MAX`].  Total: never
/// panics for any `f64` input.
///
/// The NaN check is load-bearing and must come first: `f64::min`/`max`
/// propagate the *other* operand on NaN (`f64::NAN.max(0.0) == 0.0`
/// but `f64::NAN.min(cap) == cap`), so a naive `clamp` chain can turn
/// NaN into the cap instead of zero.
#[inline]
pub fn clamped_duration(secs: f64) -> Duration {
    if secs.is_nan() || secs <= 0.0 {
        return Duration::ZERO;
    }
    Duration::try_from_secs_f64(secs).unwrap_or(Duration::MAX)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clamps_nan_and_non_positive_to_zero() {
        assert_eq!(clamped_duration(f64::NAN), Duration::ZERO);
        assert_eq!(clamped_duration(-1.0), Duration::ZERO);
        assert_eq!(clamped_duration(-0.0), Duration::ZERO);
        assert_eq!(clamped_duration(0.0), Duration::ZERO);
        assert_eq!(clamped_duration(f64::NEG_INFINITY), Duration::ZERO);
    }

    #[test]
    fn passes_positive_values_through_exactly() {
        for secs in [1e-9, 0.05, 1.0, 3600.0] {
            assert_eq!(clamped_duration(secs), Duration::from_secs_f64(secs));
        }
    }

    #[test]
    fn saturates_infinity_and_overflow_to_max() {
        assert_eq!(clamped_duration(f64::INFINITY), Duration::MAX);
        assert_eq!(clamped_duration(1e300), Duration::MAX);
        // Past the largest representable Duration (u64::MAX s ≈ 1.84e19).
        assert_eq!(clamped_duration(2e19), Duration::MAX);
        // ...while huge-but-representable values still convert exactly.
        assert_eq!(clamped_duration(1e18), Duration::from_secs_f64(1e18));
    }
}
