//! Read-only memory-mapped files behind a small safe wrapper.
//!
//! The repo vendors no crates (no `libc`, no `memmap2`), so the two
//! syscalls the trace loader needs — `mmap` / `munmap` — are declared
//! directly against the C library every unix target already links.  The
//! wrapper keeps all the unsafety in one place:
//!
//! * [`Mmap`] owns a `PROT_READ`/`MAP_PRIVATE` mapping of a whole file
//!   and derefs to `&[u8]`.  This process never writes through the
//!   mapping and never remaps, so sharing `&Mmap` across threads is
//!   data-race free (`Send + Sync`); read-only private mappings still
//!   share page-cache pages between processes mapping the same file.
//! * [`FileBytes`] is the enum the trace loader actually consumes: the
//!   same bytes either mapped ([`FileBytes::Mapped`]) or read into an
//!   owned `Vec` ([`FileBytes::Owned`]).  [`map_file`] prefers the
//!   mapping and silently falls back to a read when mapping is
//!   unavailable; [`read_file`] always takes the owned route.  Callers
//!   decode through `&[u8]` either way, so the two backings share one
//!   code path and one test suite.
//!
//! The mapped route is compiled only for **64-bit unix** targets: the
//! `extern` declaration below passes the file offset as `i64`, which
//! matches `off_t` exactly where `off_t` is 64-bit.  On 32-bit unix
//! (where the plain `mmap` symbol takes a 32-bit `off_t`, so the call
//! would be a wrong-ABI foreign call) and on non-unix targets,
//! [`map_file`] is simply [`read_file`] — same decode, no mapping.
//!
//! Caveat (inherent to file mappings, not this wrapper): the mapped
//! bytes are only as immutable as the underlying file.  If another
//! process truncates it while mapped, touching the vanished pages
//! raises `SIGBUS`; if another process rewrites it **in place** (same
//! size, `dd conv=notrunc`-style), the mapped bytes change underneath
//! us — and callers that cached validation results about the content
//! (e.g. the trace loader's one-time UTF-8 check backing later
//! `from_utf8_unchecked` resolution) would be left holding a violated
//! invariant, which is undefined behavior, not a crash.  `MAP_PRIVATE`
//! narrows but does not close that window (untouched pages still track
//! the file).  Trace files are written once and then replayed
//! read-only; `map_file` MUST NOT be pointed at files that concurrent
//! writers may modify — use [`read_file`] for anything mutable.

use std::io;
use std::ops::Deref;
use std::path::Path;

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;
    pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;

    // The C library is always linked on unix targets; these two are in
    // POSIX and off_t is 64-bit on every 64-bit unix target rust ships
    // for (the module is cfg-gated to exactly those, keeping the i64
    // offset ABI-correct).
    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

/// A read-only, private mapping of an entire file.
///
/// Dereferences to the file's bytes.  Read-only and fixed-size for its
/// whole lifetime; unmapped on drop.  See the module docs for the
/// file-immutability precondition.
#[cfg(all(unix, target_pointer_width = "64"))]
pub struct Mmap {
    ptr: *const u8,
    len: usize,
}

// SAFETY: the mapping is PROT_READ and never remapped or unmapped until
// Drop, so concurrent shared reads from any thread are data-race free.
// (The module-level caveat about external file modification applies to
// single-threaded use equally; it is a file-immutability precondition,
// not a threading one.)
#[cfg(all(unix, target_pointer_width = "64"))]
unsafe impl Send for Mmap {}
#[cfg(all(unix, target_pointer_width = "64"))]
unsafe impl Sync for Mmap {}

#[cfg(all(unix, target_pointer_width = "64"))]
impl Mmap {
    /// Map `file` read-only in its entirety.  Fails with the OS error if
    /// the file cannot be mapped (callers typically fall back to a
    /// plain read); a zero-length file is an error here too (`mmap(2)`
    /// rejects len 0) and is handled by [`map_file`].
    pub fn of_file(file: &std::fs::File) -> io::Result<Mmap> {
        use std::os::unix::io::AsRawFd;

        let len = file.metadata()?.len();
        if len == 0 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "mmap: zero-length file",
            ));
        }
        let len = usize::try_from(len).map_err(|_| {
            io::Error::new(io::ErrorKind::InvalidInput, "mmap: file exceeds address space")
        })?;
        // SAFETY: fd is a valid open file for the duration of the call;
        // we request a fresh PROT_READ/MAP_PRIVATE mapping at a
        // kernel-chosen address and check for MAP_FAILED.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == sys::MAP_FAILED {
            return Err(io::Error::last_os_error());
        }
        Ok(Mmap {
            ptr: ptr as *const u8,
            len,
        })
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

#[cfg(all(unix, target_pointer_width = "64"))]
impl Deref for Mmap {
    type Target = [u8];

    #[inline]
    fn deref(&self) -> &[u8] {
        // SAFETY: ptr/len describe a live PROT_READ mapping owned by
        // self; the kernel keeps it valid until munmap in Drop.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

#[cfg(all(unix, target_pointer_width = "64"))]
impl Drop for Mmap {
    fn drop(&mut self) {
        // SAFETY: ptr/len are exactly what mmap returned; after this the
        // struct is gone, so no dangling deref can observe the unmap.
        unsafe {
            sys::munmap(self.ptr as *mut _, self.len);
        }
    }
}

#[cfg(all(unix, target_pointer_width = "64"))]
impl std::fmt::Debug for Mmap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mmap").field("len", &self.len).finish()
    }
}

/// File contents, either mapped or owned — one decode path for both.
#[derive(Debug)]
pub enum FileBytes {
    /// Kernel-paged, read-only mapping ([`map_file`]'s preferred route).
    #[cfg(all(unix, target_pointer_width = "64"))]
    Mapped(Mmap),
    /// Bytes read into memory (the fallback route, and [`read_file`]).
    Owned(Vec<u8>),
}

impl FileBytes {
    /// Whether these bytes are backed by a live mapping (telemetry /
    /// bench labelling; decoding never branches on it).
    pub fn is_mapped(&self) -> bool {
        match self {
            #[cfg(all(unix, target_pointer_width = "64"))]
            FileBytes::Mapped(_) => true,
            FileBytes::Owned(_) => false,
        }
    }
}

impl Deref for FileBytes {
    type Target = [u8];

    #[inline]
    fn deref(&self) -> &[u8] {
        match self {
            #[cfg(all(unix, target_pointer_width = "64"))]
            FileBytes::Mapped(m) => m,
            FileBytes::Owned(v) => v,
        }
    }
}

/// Map `path` read-only, falling back to an in-memory read when mapping
/// is unavailable (non-unix or 32-bit target, zero-length file, or an
/// mmap error such as a filesystem that forbids mappings).  A missing
/// file is an error on both routes.  The file must not be modified
/// while the returned bytes are alive (module docs).
pub fn map_file(path: &Path) -> io::Result<FileBytes> {
    #[cfg(all(unix, target_pointer_width = "64"))]
    {
        let file = std::fs::File::open(path)?;
        match Mmap::of_file(&file) {
            Ok(m) => Ok(FileBytes::Mapped(m)),
            Err(_) => read_file(path),
        }
    }
    #[cfg(not(all(unix, target_pointer_width = "64")))]
    {
        read_file(path)
    }
}

/// Read `path` fully into owned bytes — the explicit fallback route
/// (tests exercise it on every platform so the two backings cannot
/// drift).
pub fn read_file(path: &Path) -> io::Result<FileBytes> {
    Ok(FileBytes::Owned(std::fs::read(path)?))
}

/// Read at most the first `n` bytes of `path` (shorter files yield what
/// they have).  Format sniffing reads a magic-sized prefix instead of
/// mapping or slurping a multi-gigabyte trace just to find out what it
/// is; shard-manifest verification reads fixed-size headers the same
/// way.
pub fn read_prefix(path: &Path, n: usize) -> io::Result<Vec<u8>> {
    use std::io::Read;

    let file = std::fs::File::open(path)?;
    let mut buf = vec![0u8; n];
    let mut got = 0usize;
    let mut take = file.take(n as u64);
    loop {
        match take.read(&mut buf[got..]) {
            Ok(0) => break,
            Ok(k) => got += k,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    buf.truncate(got);
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("magnus_mmap_{}_{name}", std::process::id()))
    }

    #[test]
    fn mapped_and_read_bytes_are_identical() {
        let path = temp("roundtrip");
        let payload: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        std::fs::write(&path, &payload).unwrap();
        let mapped = map_file(&path).unwrap();
        let owned = read_file(&path).unwrap();
        assert_eq!(&*mapped, payload.as_slice());
        assert_eq!(&*owned, payload.as_slice());
        assert!(!owned.is_mapped());
        #[cfg(all(unix, target_pointer_width = "64"))]
        assert!(mapped.is_mapped());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn empty_file_falls_back_to_owned() {
        let path = temp("empty");
        std::fs::write(&path, b"").unwrap();
        let bytes = map_file(&path).unwrap();
        assert_eq!(bytes.len(), 0);
        assert!(!bytes.is_mapped());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_file_errors_on_both_routes() {
        let path = temp("missing_never_written");
        assert!(map_file(&path).is_err());
        assert!(read_file(&path).is_err());
    }

    #[test]
    fn read_prefix_caps_at_file_length() {
        let path = temp("prefix");
        std::fs::write(&path, b"MAGNUSTRtail").unwrap();
        assert_eq!(read_prefix(&path, 8).unwrap(), b"MAGNUSTR");
        assert_eq!(read_prefix(&path, 64).unwrap(), b"MAGNUSTRtail");
        assert!(read_prefix(&temp("prefix_missing"), 8).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn mapping_outlives_the_file_handle_and_shares_across_threads() {
        let path = temp("threads");
        let payload = b"shared read-only mapping".repeat(500);
        std::fs::write(&path, &payload).unwrap();
        let bytes = std::sync::Arc::new(map_file(&path).unwrap());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let b = std::sync::Arc::clone(&bytes);
                s.spawn(move || {
                    assert_eq!(&b.as_ref()[..], payload.as_slice());
                });
            }
        });
        let _ = std::fs::remove_file(&path);
    }
}
