//! Minimal JSON parser / writer.
//!
//! `serde`/`serde_json` are not vendored in this environment, so the config
//! system, artifact manifest loader, trace files and metrics emitters use
//! this small self-contained implementation.  It supports the full JSON
//! grammar (objects, arrays, strings with escapes, numbers, bools, null);
//! numbers are held as f64 (adequate: manifests and traces stay well below
//! 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Error with byte offset for diagnostics.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------------------------------- accessors ---

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// `get` chained over a dotted path ("model.d_model").
    pub fn path(&self, dotted: &str) -> &Json {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part);
        }
        cur
    }

    // ----------------------------------------------------- construction ---

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn num<T: Into<f64>>(x: T) -> Json {
        Json::Num(x.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ----------------------------------------------------------- parse ---

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ----------------------------------------------------------- write ---

    /// Compact serialisation.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialisation with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = fmt::Write::write_fmt(
                        out,
                        format_args!("{}", *x as i64),
                    );
                } else {
                    let _ = fmt::Write::write_fmt(out, format_args!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(
                    out,
                    format_args!("\\u{:04x}", c as u32),
                );
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.i,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self
                        .peek()
                        .ok_or_else(|| self.err("unterminated escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    s.push(
                                        char::from_u32(c).ok_or_else(|| {
                                            self.err("bad surrogate pair")
                                        })?,
                                    );
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                s.push(char::from_u32(cp).ok_or_else(|| {
                                    self.err("bad \\u escape")
                                })?);
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk =
                            std::str::from_utf8(&self.b[start..end])
                                .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hex, 16)
            .map_err(|_| self.err("bad hex digits"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.path("c.d").as_f64(), Some(-2500.0));
        assert_eq!(v.get("b").as_arr().unwrap().len(), 3);
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parses_nested_arrays() {
        let v = Json::parse("[[1,2],[3,[4]]]").unwrap();
        assert_eq!(
            v.as_arr().unwrap()[1].as_arr().unwrap()[1].as_arr().unwrap()[0]
                .as_f64(),
            Some(4.0)
        );
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn unicode_and_surrogates() {
        let v = Json::parse(r#""é café 😀 日本""#).unwrap();
        assert_eq!(v.as_str(), Some("é café 😀 日本"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::num(3.0).to_string(), "3");
        assert_eq!(Json::num(3.25).to_string(), "3.25");
    }

    #[test]
    fn missing_path_is_null() {
        let v = Json::parse(r#"{"a": {"b": 1}}"#).unwrap();
        assert_eq!(*v.path("a.c.d"), Json::Null);
    }

    #[test]
    fn pretty_output_parses_back() {
        let v = Json::obj(vec![
            ("x", Json::Arr(vec![Json::num(1.0), Json::Bool(false)])),
            ("y", Json::str("hello")),
        ]);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }
}
