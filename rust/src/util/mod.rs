//! Substrate utilities: deterministic RNG + samplers, JSON, statistics,
//! CLI parsing, micro-bench harness, property-testing harness, a
//! scoped-thread parallel map and a read-only mmap wrapper.
//!
//! These exist because the build environment vendors only the `xla` crate's
//! dependency closure — `rand`, `serde`, `clap`, `criterion` and `proptest`
//! are unavailable, and the reproduction needs deterministic equivalents
//! anyway (every figure must regenerate bit-for-bit from a seed).

pub mod alloc;
pub mod bench;
pub mod cli;
pub mod json;
pub mod mmap;
pub mod par;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod time;

pub use json::Json;
pub use rng::Rng;
pub use time::clamped_duration;
