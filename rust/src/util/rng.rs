//! Deterministic pseudo-random number generation and samplers.
//!
//! The crates.io `rand` family is not vendored in this environment, and the
//! reproduction needs *deterministic* workloads anyway (every figure must be
//! regenerable bit-for-bit), so we implement a small, well-known generator
//! (SplitMix64 seeding a xoshiro256**) plus the distributions the paper's
//! workload model needs: uniform, normal (Box–Muller), log-normal,
//! exponential and Poisson (inversion / PTRS for large λ).

/// xoshiro256** seeded via SplitMix64 — fast, high-quality, deterministic.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-component determinism).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [lo, hi) (hi > lo).
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        // Lemire-style bounded generation with rejection.
        let span = hi - lo;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(span as u128);
        let mut l = m as u64;
        if l < span {
            let t = span.wrapping_neg() % span;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(span as u128);
                l = m as u64;
            }
        }
        lo + (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with given mean / std-dev.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal parameterised by the underlying normal's (mu, sigma).
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Poisson-distributed count with mean `lambda`.
    ///
    /// Knuth multiplication for small λ, normal approximation with
    /// continuity correction above 30 (adequate for workload generation).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = self.normal_ms(lambda, lambda.sqrt()) + 0.5;
            if x < 0.0 {
                0
            } else {
                x as u64
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index proportionally to `weights` (all >= 0, sum > 0).
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forks_are_independent() {
        let mut a = Rng::new(7);
        let mut f1 = a.fork(1);
        let mut f2 = a.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_u64_bounds() {
        let mut r = Rng::new(2);
        for _ in 0..10_000 {
            let x = r.range_u64(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let mean =
            (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn poisson_small_lambda_mean() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let mean =
            (0..n).map(|_| r.poisson(3.5) as f64).sum::<f64>() / n as f64;
        assert!((mean - 3.5).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn poisson_large_lambda_mean() {
        let mut r = Rng::new(6);
        let n = 50_000;
        let mean =
            (0..n).map(|_| r.poisson(120.0) as f64).sum::<f64>() / n as f64;
        assert!((mean - 120.0).abs() < 0.5, "mean={mean}");
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng::new(8);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }
}
