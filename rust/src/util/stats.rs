//! Statistics helpers used across the evaluation harness: means, RMSE,
//! Pearson correlation (Table I), percentiles (tail response time, Fig 11c)
//! and simple online accumulators.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Root mean square error between predictions and targets (Table II metric).
pub fn rmse(pred: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(pred.len(), actual.len());
    if pred.is_empty() {
        return 0.0;
    }
    let se: f64 = pred
        .iter()
        .zip(actual)
        .map(|(p, a)| (p - a) * (p - a))
        .sum();
    (se / pred.len() as f64).sqrt()
}

/// Mean absolute error.
pub fn mae(pred: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(pred.len(), actual.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter().zip(actual).map(|(p, a)| (p - a).abs()).sum::<f64>()
        / pred.len() as f64
}

/// Pearson correlation coefficient (Table I metric). NaN-free: returns 0.0
/// when either variable is constant.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        cov += dx * dy;
        vx += dx * dx;
        vy += dy * dy;
    }
    if vx <= 0.0 || vy <= 0.0 {
        0.0
    } else {
        cov / (vx.sqrt() * vy.sqrt())
    }
}

/// p-th percentile (0..=100) with linear interpolation; 0.0 if empty.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Online accumulator for streaming metrics (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Self {
        Online {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Ordinary least squares fit y = a*x + b; returns (a, b).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return (0.0, mean(ys));
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut den = 0.0;
    for i in 0..xs.len() {
        num += (xs[i] - mx) * (ys[i] - my);
        den += (xs[i] - mx) * (xs[i] - mx);
    }
    if den <= 0.0 {
        (0.0, my)
    } else {
        let a = num / den;
        (a, my - a * mx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_variance() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn rmse_basic() {
        assert_eq!(rmse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_correlation() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let neg: Vec<f64> = ys.iter().map(|y| -y).collect();
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_constant_input_is_zero() {
        assert_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert!((percentile(&xs, 95.0) - 4.8).abs() < 1e-12);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-12);
        assert!((o.variance() - variance(&xs)).abs() < 1e-9);
        assert_eq!(o.min(), 1.0);
        assert_eq!(o.max(), 9.0);
    }

    #[test]
    fn linear_fit_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 7.0).collect();
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 7.0).abs() < 1e-9);
    }
}
