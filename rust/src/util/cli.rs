//! Tiny CLI argument parser (clap is not vendored in this environment).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments,
//! with typed getters and a generated usage string.

use std::collections::BTreeMap;

/// Declarative option spec for usage output.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (no program name).
    pub fn parse_from<I: IntoIterator<Item = String>>(
        iter: I,
        flag_names: &[&str],
    ) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&body) {
                    args.flags.push(body.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        return Err(format!("option --{body} expects a value"));
                    }
                    let v = it.next().unwrap();
                    args.opts.insert(body.to_string(), v);
                } else {
                    return Err(format!("option --{body} expects a value"));
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// Parse from `std::env::args()` (skipping the program name).
    pub fn parse_env(flag_names: &[&str]) -> Result<Args, String> {
        Self::parse_from(std::env::args().skip(1), flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    /// Comma-separated f64 list ("1,2.5,4").
    pub fn get_f64_list(&self, name: &str, default: &[f64]) -> Vec<f64> {
        match self.get(name) {
            Some(s) => s
                .split(',')
                .filter_map(|x| x.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }
}

/// Render a usage block from option specs.
pub fn usage(program: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut s = format!("{program} — {about}\n\nOptions:\n");
    for spec in specs {
        let d = spec
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        let kind = if spec.is_flag { "" } else { " <value>" };
        s.push_str(&format!("  --{}{kind}\n      {}{d}\n", spec.name, spec.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str], flags: &[&str]) -> Args {
        Args::parse_from(v.iter().map(|s| s.to_string()), flags).unwrap()
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse(&["--rate", "3.5", "--mode=sim"], &[]);
        assert_eq!(a.get_f64("rate", 0.0), 3.5);
        assert_eq!(a.get("mode"), Some("sim"));
    }

    #[test]
    fn flags_and_positional() {
        let a = parse(&["run", "--verbose", "--n", "7", "trace.json"], &["verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get_usize("n", 0), 7);
        assert_eq!(a.positional, vec!["run", "trace.json"]);
    }

    #[test]
    fn list_parsing() {
        let a = parse(&["--rates", "1,2,4.5"], &[]);
        assert_eq!(a.get_f64_list("rates", &[]), vec![1.0, 2.0, 4.5]);
    }

    #[test]
    fn missing_value_is_error() {
        let r = Args::parse_from(
            ["--n".to_string()].into_iter(),
            &[],
        );
        assert!(r.is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[], &[]);
        assert_eq!(a.get_or("mode", "sim"), "sim");
        assert_eq!(a.get_f64("rate", 2.5), 2.5);
    }
}
