//! Minimal property-based testing harness (proptest is not vendored).
//!
//! A [`Prop`] runs a closure over many seeded random cases; on failure it
//! re-runs with a simple shrinking strategy (halving integer parameters via
//! the [`Shrinkable`] trait is left to call sites — the harness reports the
//! failing seed so every failure is reproducible deterministically).
//!
//! Usage:
//! ```ignore
//! prop_check(200, |rng| {
//!     let n = rng.range_usize(1, 50);
//!     ...
//!     assert!(invariant_holds);
//! });
//! ```

use super::rng::Rng;

/// Number of cases scaled down when MAGNUS_PROP_QUICK is set.
fn scaled(cases: usize) -> usize {
    if std::env::var("MAGNUS_PROP_QUICK").is_ok() {
        (cases / 10).max(5)
    } else {
        cases
    }
}

/// Run `f` over `cases` deterministic random cases.  Panics (propagating the
/// inner assertion) with the failing seed in the message.
pub fn prop_check<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(cases: usize, f: F) {
    let base = 0xC0FFEE_u64;
    for case in 0..scaled(cases) {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            f(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!("property failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Like `prop_check` but the closure receives the case index too (useful
/// for size-graduated generation: small cases first).
pub fn prop_check_sized<F>(cases: usize, f: F)
where
    F: Fn(&mut Rng, usize) + std::panic::RefUnwindSafe,
{
    let total = scaled(cases);
    let base = 0xBADC0DE_u64;
    for case in 0..total {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            f(&mut rng, case);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!("property failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        prop_check(50, |rng| {
            let a = rng.range_u64(0, 100);
            let b = rng.range_u64(0, 100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failing_seed() {
        prop_check(50, |rng| {
            let x = rng.range_u64(0, 10);
            assert!(x < 9, "x={x}");
        });
    }

    #[test]
    fn sized_cases_grow() {
        prop_check_sized(20, |rng, case| {
            let n = rng.range_usize(0, case + 2);
            assert!(n <= case + 1);
        });
    }
}
