//! A counting global allocator for the scale benches.
//!
//! Wraps the system allocator with relaxed atomic live/peak byte
//! counters, so `benches/bench_sim`'s scale mode can report **peak heap
//! bytes** for the owned-`Request` path vs the interned `TraceStore`
//! path without external tooling.  Register it in a binary with
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: magnus::util::alloc::CountingAllocator = CountingAllocator;
//! ```
//!
//! Counting costs two relaxed atomic ops per alloc/free — negligible
//! against the allocations being measured, and identical for every
//! measured variant, so ratios are unaffected.  Peak tracking is a
//! `fetch_max` **upper bound**: `realloc` is counted as
//! alloc-new-then-free-old, so the transient old+new double-residency
//! of a moving grow is included (an in-place grow is over-counted by
//! the old size for that instant — conservative, never an
//! understatement).  The benches run the measured phases
//! single-threaded.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

/// System allocator + live/peak byte accounting.
pub struct CountingAllocator;

#[inline]
fn on_alloc(bytes: usize) {
    let live = LIVE.fetch_add(bytes, Ordering::Relaxed) + bytes;
    PEAK.fetch_max(live, Ordering::Relaxed);
}

#[inline]
fn on_dealloc(bytes: usize) {
    LIVE.fetch_sub(bytes, Ordering::Relaxed);
}

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            on_alloc(layout.size());
        }
        p
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            on_alloc(layout.size());
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        on_dealloc(layout.size());
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            // Counted as alloc(new) then free(old): a moving realloc
            // briefly holds both buffers, and the peak must see it.
            on_alloc(new_size);
            on_dealloc(layout.size());
        }
        p
    }
}

/// Bytes currently live (allocated − freed) under the counting allocator.
/// Zero when [`CountingAllocator`] is not the registered global allocator.
pub fn live_bytes() -> usize {
    LIVE.load(Ordering::Relaxed)
}

/// High-water mark of [`live_bytes`] since the last [`reset_peak`].
pub fn peak_bytes() -> usize {
    PEAK.load(Ordering::Relaxed)
}

/// Restart peak tracking from the current live level — call between
/// measured phases.
pub fn reset_peak() {
    PEAK.store(LIVE.load(Ordering::Relaxed), Ordering::Relaxed);
}
