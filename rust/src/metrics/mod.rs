//! Evaluation metrics (paper §IV-A): request throughput, average and tail
//! (95%) response time, token throughput and valid-token throughput, plus
//! a fixed-bucket log-scale latency [`Histogram`] (p50/p90/p99 without
//! retaining per-request samples) and CSV/markdown emitters for the
//! figure harness.

use crate::util::stats::{mean, percentile};

/// Buckets per decade of the log-scale latency histogram.
const HIST_BPD: usize = 8;
/// Decades covered: `[1e-6 s, 1e6 s)` — sub-microsecond to ~11 days.
const HIST_DECADES: usize = 12;
/// Lowest bucket boundary (seconds).
const HIST_LO: f64 = 1e-6;
/// Bucket count: underflow + HIST_BPD * HIST_DECADES log buckets +
/// overflow.
const HIST_N: usize = 2 + HIST_BPD * HIST_DECADES;

/// Width (tokens) of the generation-length buckets used by the
/// mispredict gauge: predicted and actual lengths are compared at
/// bucket granularity (the batcher groups by predicted length, so a
/// same-bucket miss is harmless while a cross-bucket miss wastes pad
/// tokens or splits batches).
pub const MISPREDICT_BUCKET_TOKENS: u32 = 32;
/// Bins of the per-bucket-error histogram: bin `i` counts completions
/// whose |predicted − actual| bucket distance is `i`; the last bin
/// absorbs everything farther.
pub const MISPREDICT_BINS: usize = 8;

/// Prediction-quality gauge shared by the core collectors
/// ([`RunMetrics`]) and the HTTP edge (`/metrics`): counts completed
/// requests whose predicted generation length missed the actual one's
/// [`MISPREDICT_BUCKET_TOKENS`]-wide bucket, with a per-bucket-distance
/// error histogram.  Deterministic (pure counts), so golden runs agree
/// bitwise.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MispredictGauge {
    /// (predicted, actual) pairs observed — the rate denominator.
    pub predictions: u64,
    /// Observations landing in a different bucket than predicted.
    pub mispredicted: u64,
    /// `bins[d]` counts observations at bucket distance `d`; the last
    /// bin absorbs the tail.
    pub bins: [u64; MISPREDICT_BINS],
}

impl MispredictGauge {
    /// Observe one completed request's (predicted, actual) generation
    /// lengths, compared at [`MISPREDICT_BUCKET_TOKENS`] granularity.
    pub fn record(&mut self, predicted: u32, actual: u32) {
        let d = (predicted / MISPREDICT_BUCKET_TOKENS)
            .abs_diff(actual / MISPREDICT_BUCKET_TOKENS) as usize;
        self.predictions += 1;
        if d > 0 {
            self.mispredicted += 1;
        }
        self.bins[d.min(MISPREDICT_BINS - 1)] += 1;
    }

    /// Fold another gauge's counts into this one (cluster-wide
    /// aggregation over per-instance gauges).
    pub fn merge(&mut self, other: &MispredictGauge) {
        self.predictions += other.predictions;
        self.mispredicted += other.mispredicted;
        for (a, b) in self.bins.iter_mut().zip(&other.bins) {
            *a += b;
        }
    }

    /// Fraction of observations that missed their predicted bucket
    /// (0.0 when nothing was observed).
    pub fn rate(&self) -> f64 {
        if self.predictions == 0 {
            0.0
        } else {
            self.mispredicted as f64 / self.predictions as f64
        }
    }
}

/// Fixed-bucket log-scale histogram for response-time quantiles.
///
/// Buckets are geometric with ratio `10^(1/8)` (~33% relative width, so
/// a reported quantile is within ~±15% of the true sample quantile —
/// plenty for p50/p90/p99 dashboards) spanning `[1e-6 s, 1e6 s)`, plus
/// explicit underflow/overflow buckets so every observation lands
/// somewhere and totals always close.  Quantiles are a deterministic
/// function of the counts (geometric bucket midpoints), so two runs that
/// observe the same values report bit-identical quantiles — the golden
/// gates rely on this.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            counts: vec![0; HIST_N],
            total: 0,
        }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Bucket index for one observation.  NaN and values `<= HIST_LO`
    /// land in the underflow bucket (a response time is never negative,
    /// and counting pathological inputs keeps accounting closed).
    #[inline]
    fn bucket_of(v: f64) -> usize {
        if !(v > HIST_LO) {
            return 0;
        }
        let idx = ((v / HIST_LO).log10() * HIST_BPD as f64).floor();
        if idx < 0.0 {
            0
        } else {
            ((idx as usize) + 1).min(HIST_N - 1)
        }
    }

    /// Representative value (seconds) reported for bucket `i`: the
    /// geometric midpoint, clamped to the histogram range at the ends.
    #[inline]
    fn midpoint(i: usize) -> f64 {
        if i == 0 {
            HIST_LO
        } else if i >= HIST_N - 1 {
            HIST_LO * 10f64.powi(HIST_DECADES as i32)
        } else {
            HIST_LO * 10f64.powf((i as f64 - 0.5) / HIST_BPD as f64)
        }
    }

    /// Upper bound (seconds) of bucket `i` (`f64::INFINITY` for the
    /// overflow bucket) — the `/metrics` cumulative-bucket boundary.
    #[inline]
    pub fn upper_bound(i: usize) -> f64 {
        if i >= HIST_N - 1 {
            f64::INFINITY
        } else {
            HIST_LO * 10f64.powf(i as f64 / HIST_BPD as f64)
        }
    }

    pub fn observe(&mut self, v: f64) {
        self.counts[Self::bucket_of(v)] += 1;
        self.total += 1;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
    }

    /// Quantile `q` in `[0, 100]`: the representative value of the
    /// bucket holding the `ceil(q/100 * total)`-th smallest observation.
    /// Returns 0.0 when empty (matches `stats::percentile`).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((q / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let rank = rank.min(self.total);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::midpoint(i);
            }
        }
        Self::midpoint(HIST_N - 1)
    }

    /// Non-empty buckets as `(upper_bound_s, cumulative_count)` rows in
    /// ascending order — the Prometheus-style `le` export shape.
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                cum += c;
                out.push((Self::upper_bound(i), cum));
            }
        }
        out
    }
}

/// One completed request's record.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub request_id: u64,
    pub arrival: f64,
    pub finish: f64,
    pub valid_tokens: u32,
    pub invalid_tokens: u32,
}

impl RequestRecord {
    pub fn response_time(&self) -> f64 {
        self.finish - self.arrival
    }
}

/// Collector for one serving run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub records: Vec<RequestRecord>,
    /// Number of OOM events observed.
    pub oom_events: u32,
    /// Time of the last completion (run makespan endpoint).
    pub last_finish: f64,
    /// Earliest arrival (run start).
    pub first_arrival: f64,
    /// Request ids explicitly given up on after bounded retries (ISSUE 6
    /// exactly-once accounting: admitted = completed + shed, always).
    pub shed: Vec<u64>,
    /// Batches re-queued after an injected crash/transient serve error.
    pub retries: u32,
    /// Worker restarts performed by the supervisor.
    pub worker_restarts: u32,
    /// Admissions predicted by the fallback chain (predictor offline).
    pub fallback_predictions: u32,
    /// Requests re-bucketed by the overrun guard after an OOM split.
    pub rebucketed: u32,
    /// Faults the plan injected (crashes + transient errors + forced
    /// OOMs) — 0 in any fault-free run, asserted by the golden gates.
    pub injected_faults: u32,
    /// Admissions charged at the predictor's upper-quantile length
    /// because confidence fell below the configured threshold — 0 with
    /// uncertainty-aware scheduling off (golden-gated).
    pub low_confidence_admissions: u32,
    /// Drift-detector demotions down the fallback chain — 0 with
    /// uncertainty-aware scheduling off (golden-gated).
    pub drift_demotions: u32,
    /// Drift-detector re-promotions after probation drained.
    pub drift_repromotions: u32,
    /// Low-confidence batches split pre-emptively by the speculative
    /// overrun guard at an injected OOM, avoiding the full OOM reload —
    /// 0 with uncertainty-aware scheduling off (golden-gated).
    pub speculative_rebuckets: u32,
    /// Log-scale response-time histogram fed by [`RunMetrics::record`]
    /// (p50/p90/p99 in [`Summary`], bucket export on `/metrics`).
    pub response_hist: Histogram,
    /// Prediction-quality gauge fed by [`RunMetrics::record_prediction`]
    /// at every completion.  NOT zero fault-free: mispredicts are a
    /// property of the predictor, not of injected faults.
    pub mispredict: MispredictGauge,
}

/// Summary row for one (policy, arrival-rate) cell of the figures.
#[derive(Debug, Clone)]
pub struct Summary {
    pub n_requests: usize,
    /// Requests per second over the active span.
    pub request_throughput: f64,
    /// Mean response time (s) — Fig. 11b.
    pub mean_response_time: f64,
    /// 95th-percentile response time (s) — Fig. 11c.
    pub p95_response_time: f64,
    /// Median response time (s) from the log-scale histogram.
    pub p50_response_time: f64,
    /// 90th-percentile response time (s) from the log-scale histogram.
    pub p90_response_time: f64,
    /// 99th-percentile response time (s) from the log-scale histogram.
    pub p99_response_time: f64,
    /// All generated tokens per second (valid + invalid) — Fig. 10a.
    pub token_throughput: f64,
    /// Valid tokens per second — Fig. 10b.
    pub valid_token_throughput: f64,
    pub oom_events: u32,
    /// Requests explicitly shed (never silently lost) — 0 fault-free.
    pub shed_requests: usize,
    /// Batch re-dispatches after injected failures — 0 fault-free.
    pub retries: u32,
    /// Supervisor worker restarts — 0 fault-free.
    pub worker_restarts: u32,
    /// Fallback-chain predictions — 0 fault-free.
    pub fallback_predictions: u32,
    /// Upper-quantile-charged admissions — 0 with uncertainty off.
    pub low_confidence_admissions: u32,
    /// Drift-detector demotions — 0 with uncertainty off.
    pub drift_demotions: u32,
    /// Speculative low-confidence batch splits — 0 with uncertainty off.
    pub speculative_rebuckets: u32,
    /// Fraction of completed requests whose predicted generation length
    /// missed the actual one's [`MISPREDICT_BUCKET_TOKENS`]-wide bucket
    /// (0.0 when no predictions were observed).
    pub mispredict_rate: f64,
}

impl RunMetrics {
    pub fn new() -> Self {
        RunMetrics {
            records: Vec::new(),
            oom_events: 0,
            last_finish: 0.0,
            first_arrival: f64::INFINITY,
            shed: Vec::new(),
            retries: 0,
            worker_restarts: 0,
            fallback_predictions: 0,
            rebucketed: 0,
            injected_faults: 0,
            low_confidence_admissions: 0,
            drift_demotions: 0,
            drift_repromotions: 0,
            speculative_rebuckets: 0,
            response_hist: Histogram::new(),
            mispredict: MispredictGauge::default(),
        }
    }

    /// Feed the mispredict gauge with one completed request's
    /// (predicted, actual) generation lengths.
    pub fn record_prediction(&mut self, predicted: u32, actual: u32) {
        self.mispredict.record(predicted, actual);
    }

    /// Fraction of observed completions that missed their predicted
    /// bucket (0.0 when nothing was observed).
    pub fn mispredict_rate(&self) -> f64 {
        self.mispredict.rate()
    }

    pub fn record(&mut self, r: RequestRecord) {
        self.first_arrival = self.first_arrival.min(r.arrival);
        self.last_finish = self.last_finish.max(r.finish);
        self.response_hist.observe(r.response_time());
        self.records.push(r);
    }

    pub fn record_oom(&mut self) {
        self.oom_events += 1;
    }

    /// Give up on a request after bounded retries: the id is recorded so
    /// accounting still closes (admitted = completed + shed).
    pub fn record_shed(&mut self, request_id: u64) {
        self.shed.push(request_id);
    }

    /// Aggregate over the run.  The throughput denominator is the span
    /// from first arrival to last completion (the paper's request
    /// throughput under a finite workload).
    pub fn summarise(&self) -> Summary {
        let span = (self.last_finish - self.first_arrival).max(1e-9);
        let rts: Vec<f64> = self.records.iter().map(|r| r.response_time()).collect();
        let valid: u64 = self.records.iter().map(|r| r.valid_tokens as u64).sum();
        let total: u64 = self
            .records
            .iter()
            .map(|r| (r.valid_tokens + r.invalid_tokens) as u64)
            .sum();
        Summary {
            n_requests: self.records.len(),
            request_throughput: self.records.len() as f64 / span,
            mean_response_time: mean(&rts),
            p95_response_time: percentile(&rts, 95.0),
            p50_response_time: self.response_hist.quantile(50.0),
            p90_response_time: self.response_hist.quantile(90.0),
            p99_response_time: self.response_hist.quantile(99.0),
            token_throughput: total as f64 / span,
            valid_token_throughput: valid as f64 / span,
            oom_events: self.oom_events,
            shed_requests: self.shed.len(),
            retries: self.retries,
            worker_restarts: self.worker_restarts,
            fallback_predictions: self.fallback_predictions,
            low_confidence_admissions: self.low_confidence_admissions,
            drift_demotions: self.drift_demotions,
            speculative_rebuckets: self.speculative_rebuckets,
            mispredict_rate: self.mispredict_rate(),
        }
    }
}

/// Emit rows as CSV with a header.
pub fn to_csv(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = header.join(",");
    s.push('\n');
    for row in rows {
        s.push_str(&row.join(","));
        s.push('\n');
    }
    s
}

/// Emit rows as a GitHub-flavoured markdown table.
pub fn to_markdown(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = format!("| {} |\n", header.join(" | "));
    s.push_str(&format!(
        "|{}\n",
        header.iter().map(|_| "---|").collect::<String>()
    ));
    for row in rows {
        s.push_str(&format!("| {} |\n", row.join(" | ")));
    }
    s
}

/// Write a result file under `results/` (created if needed).
pub fn write_results_file(name: &str, contents: &str) -> anyhow::Result<String> {
    let dir = std::path::Path::new("results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    std::fs::write(&path, contents)?;
    Ok(path.to_string_lossy().into_owned())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, arrival: f64, finish: f64, valid: u32, invalid: u32) -> RequestRecord {
        RequestRecord {
            request_id: id,
            arrival,
            finish,
            valid_tokens: valid,
            invalid_tokens: invalid,
        }
    }

    #[test]
    fn summary_computes_throughputs() {
        let mut m = RunMetrics::new();
        m.record(rec(0, 0.0, 5.0, 50, 10));
        m.record(rec(1, 1.0, 10.0, 30, 0));
        let s = m.summarise();
        assert_eq!(s.n_requests, 2);
        assert!((s.request_throughput - 0.2).abs() < 1e-9);
        assert!((s.token_throughput - 9.0).abs() < 1e-9);
        assert!((s.valid_token_throughput - 8.0).abs() < 1e-9);
    }

    #[test]
    fn response_times() {
        let mut m = RunMetrics::new();
        for i in 0..100 {
            m.record(rec(i, 0.0, 1.0 + i as f64 * 0.01, 1, 0));
        }
        let s = m.summarise();
        assert!((s.mean_response_time - 1.495).abs() < 1e-6);
        assert!(s.p95_response_time > 1.9 && s.p95_response_time < 2.0);
    }

    #[test]
    fn csv_and_markdown_shapes() {
        let rows = vec![vec!["1".into(), "2".into()]];
        let csv = to_csv(&["a", "b"], &rows);
        assert_eq!(csv, "a,b\n1,2\n");
        let md = to_markdown(&["a", "b"], &rows);
        assert!(md.contains("| a | b |") && md.contains("| 1 | 2 |"));
    }

    #[test]
    fn oom_counted() {
        let mut m = RunMetrics::new();
        m.record_oom();
        m.record_oom();
        assert_eq!(m.summarise().oom_events, 2);
    }

    #[test]
    fn histogram_quantiles_track_sample_percentiles() {
        let mut h = Histogram::new();
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64 * 0.01).collect(); // 0.01..10.0 s
        for &x in &xs {
            h.observe(x);
        }
        assert_eq!(h.total(), 1000);
        for q in [50.0, 90.0, 99.0] {
            let exact = percentile(&xs, q);
            let approx = h.quantile(q);
            // geometric buckets at 8/decade: within ~±16% of the sample
            assert!(
                (approx / exact - 1.0).abs() < 0.16,
                "q{q}: hist {approx} vs exact {exact}"
            );
        }
        // monotone in q
        assert!(h.quantile(50.0) <= h.quantile(90.0));
        assert!(h.quantile(90.0) <= h.quantile(99.0));
    }

    #[test]
    fn histogram_edge_inputs_and_merge() {
        let mut h = Histogram::new();
        assert_eq!(h.quantile(99.0), 0.0, "empty histogram reports 0");
        // pathological inputs land in the underflow bucket, never panic
        h.observe(f64::NAN);
        h.observe(-1.0);
        h.observe(0.0);
        h.observe(1e-12);
        assert_eq!(h.total(), 4);
        assert_eq!(h.quantile(99.0), 1e-6);
        // overflow clamps to the top of the range
        h.observe(1e300);
        assert_eq!(h.quantile(100.0), 1e6);
        let mut other = Histogram::new();
        other.observe(1.0);
        other.observe(2.0);
        h.merge(&other);
        assert_eq!(h.total(), 7);
        // cumulative export: monotone bounds, final count == total
        let rows = h.cumulative_buckets();
        assert!(rows.windows(2).all(|w| w[0].0 < w[1].0 && w[0].1 < w[1].1));
        assert_eq!(rows.last().unwrap().1, h.total());
    }

    #[test]
    fn histogram_determinism_matches_summary_quantiles() {
        let mut a = RunMetrics::new();
        let mut b = RunMetrics::new();
        for i in 0..500 {
            let r = rec(i, 0.0, 0.001 * (i + 1) as f64, 1, 0);
            a.record(r.clone());
            b.record(r);
        }
        let (sa, sb) = (a.summarise(), b.summarise());
        assert_eq!(sa.p50_response_time.to_bits(), sb.p50_response_time.to_bits());
        assert_eq!(sa.p90_response_time.to_bits(), sb.p90_response_time.to_bits());
        assert_eq!(sa.p99_response_time.to_bits(), sb.p99_response_time.to_bits());
        assert!(sa.p50_response_time > 0.0 && sa.p50_response_time <= sa.p99_response_time);
    }

    #[test]
    fn mispredict_gauge_buckets_and_rate() {
        let mut m = RunMetrics::new();
        m.record_prediction(10, 20); // same 32-token bucket: a hit
        m.record_prediction(10, 40); // bucket 0 vs bucket 1
        m.record_prediction(1, MISPREDICT_BUCKET_TOKENS * 20); // far miss → tail bin
        assert_eq!(m.mispredict.predictions, 3);
        assert_eq!(m.mispredict.mispredicted, 2);
        assert_eq!(m.mispredict.bins[0], 1);
        assert_eq!(m.mispredict.bins[1], 1);
        assert_eq!(m.mispredict.bins[MISPREDICT_BINS - 1], 1);
        assert!((m.mispredict_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(m.summarise().mispredict_rate, m.mispredict_rate());
        assert_eq!(RunMetrics::new().mispredict_rate(), 0.0, "empty gauge");
    }

    #[test]
    fn robustness_counters_flow_into_summary() {
        let mut m = RunMetrics::new();
        m.record(rec(0, 0.0, 5.0, 50, 10));
        m.record_shed(7);
        m.record_shed(9);
        m.retries = 3;
        m.worker_restarts = 1;
        m.fallback_predictions = 4;
        m.low_confidence_admissions = 6;
        m.drift_demotions = 2;
        m.speculative_rebuckets = 5;
        let s = m.summarise();
        assert_eq!(s.shed_requests, 2);
        assert_eq!(m.shed, vec![7, 9]);
        assert_eq!(s.retries, 3);
        assert_eq!(s.worker_restarts, 1);
        assert_eq!(s.fallback_predictions, 4);
        assert_eq!(s.low_confidence_admissions, 6);
        assert_eq!(s.drift_demotions, 2);
        assert_eq!(s.speculative_rebuckets, 5);
        // a fresh collector reports everything zero (golden-gate shape)
        let z = RunMetrics::new().summarise();
        assert_eq!(
            (z.shed_requests, z.retries, z.worker_restarts, z.fallback_predictions),
            (0, 0, 0, 0)
        );
        assert_eq!(
            (z.low_confidence_admissions, z.drift_demotions, z.speculative_rebuckets),
            (0, 0, 0)
        );
    }
}
