//! Evaluation metrics (paper §IV-A): request throughput, average and tail
//! (95%) response time, token throughput and valid-token throughput, plus
//! CSV/markdown emitters for the figure harness.

use crate::util::stats::{mean, percentile};

/// One completed request's record.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub request_id: u64,
    pub arrival: f64,
    pub finish: f64,
    pub valid_tokens: u32,
    pub invalid_tokens: u32,
}

impl RequestRecord {
    pub fn response_time(&self) -> f64 {
        self.finish - self.arrival
    }
}

/// Collector for one serving run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub records: Vec<RequestRecord>,
    /// Number of OOM events observed.
    pub oom_events: u32,
    /// Time of the last completion (run makespan endpoint).
    pub last_finish: f64,
    /// Earliest arrival (run start).
    pub first_arrival: f64,
    /// Request ids explicitly given up on after bounded retries (ISSUE 6
    /// exactly-once accounting: admitted = completed + shed, always).
    pub shed: Vec<u64>,
    /// Batches re-queued after an injected crash/transient serve error.
    pub retries: u32,
    /// Worker restarts performed by the supervisor.
    pub worker_restarts: u32,
    /// Admissions predicted by the fallback chain (predictor offline).
    pub fallback_predictions: u32,
    /// Requests re-bucketed by the overrun guard after an OOM split.
    pub rebucketed: u32,
    /// Faults the plan injected (crashes + transient errors + forced
    /// OOMs) — 0 in any fault-free run, asserted by the golden gates.
    pub injected_faults: u32,
}

/// Summary row for one (policy, arrival-rate) cell of the figures.
#[derive(Debug, Clone)]
pub struct Summary {
    pub n_requests: usize,
    /// Requests per second over the active span.
    pub request_throughput: f64,
    /// Mean response time (s) — Fig. 11b.
    pub mean_response_time: f64,
    /// 95th-percentile response time (s) — Fig. 11c.
    pub p95_response_time: f64,
    /// All generated tokens per second (valid + invalid) — Fig. 10a.
    pub token_throughput: f64,
    /// Valid tokens per second — Fig. 10b.
    pub valid_token_throughput: f64,
    pub oom_events: u32,
    /// Requests explicitly shed (never silently lost) — 0 fault-free.
    pub shed_requests: usize,
    /// Batch re-dispatches after injected failures — 0 fault-free.
    pub retries: u32,
    /// Supervisor worker restarts — 0 fault-free.
    pub worker_restarts: u32,
    /// Fallback-chain predictions — 0 fault-free.
    pub fallback_predictions: u32,
}

impl RunMetrics {
    pub fn new() -> Self {
        RunMetrics {
            records: Vec::new(),
            oom_events: 0,
            last_finish: 0.0,
            first_arrival: f64::INFINITY,
            shed: Vec::new(),
            retries: 0,
            worker_restarts: 0,
            fallback_predictions: 0,
            rebucketed: 0,
            injected_faults: 0,
        }
    }

    pub fn record(&mut self, r: RequestRecord) {
        self.first_arrival = self.first_arrival.min(r.arrival);
        self.last_finish = self.last_finish.max(r.finish);
        self.records.push(r);
    }

    pub fn record_oom(&mut self) {
        self.oom_events += 1;
    }

    /// Give up on a request after bounded retries: the id is recorded so
    /// accounting still closes (admitted = completed + shed).
    pub fn record_shed(&mut self, request_id: u64) {
        self.shed.push(request_id);
    }

    /// Aggregate over the run.  The throughput denominator is the span
    /// from first arrival to last completion (the paper's request
    /// throughput under a finite workload).
    pub fn summarise(&self) -> Summary {
        let span = (self.last_finish - self.first_arrival).max(1e-9);
        let rts: Vec<f64> = self.records.iter().map(|r| r.response_time()).collect();
        let valid: u64 = self.records.iter().map(|r| r.valid_tokens as u64).sum();
        let total: u64 = self
            .records
            .iter()
            .map(|r| (r.valid_tokens + r.invalid_tokens) as u64)
            .sum();
        Summary {
            n_requests: self.records.len(),
            request_throughput: self.records.len() as f64 / span,
            mean_response_time: mean(&rts),
            p95_response_time: percentile(&rts, 95.0),
            token_throughput: total as f64 / span,
            valid_token_throughput: valid as f64 / span,
            oom_events: self.oom_events,
            shed_requests: self.shed.len(),
            retries: self.retries,
            worker_restarts: self.worker_restarts,
            fallback_predictions: self.fallback_predictions,
        }
    }
}

/// Emit rows as CSV with a header.
pub fn to_csv(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = header.join(",");
    s.push('\n');
    for row in rows {
        s.push_str(&row.join(","));
        s.push('\n');
    }
    s
}

/// Emit rows as a GitHub-flavoured markdown table.
pub fn to_markdown(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = format!("| {} |\n", header.join(" | "));
    s.push_str(&format!(
        "|{}\n",
        header.iter().map(|_| "---|").collect::<String>()
    ));
    for row in rows {
        s.push_str(&format!("| {} |\n", row.join(" | ")));
    }
    s
}

/// Write a result file under `results/` (created if needed).
pub fn write_results_file(name: &str, contents: &str) -> anyhow::Result<String> {
    let dir = std::path::Path::new("results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    std::fs::write(&path, contents)?;
    Ok(path.to_string_lossy().into_owned())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, arrival: f64, finish: f64, valid: u32, invalid: u32) -> RequestRecord {
        RequestRecord {
            request_id: id,
            arrival,
            finish,
            valid_tokens: valid,
            invalid_tokens: invalid,
        }
    }

    #[test]
    fn summary_computes_throughputs() {
        let mut m = RunMetrics::new();
        m.record(rec(0, 0.0, 5.0, 50, 10));
        m.record(rec(1, 1.0, 10.0, 30, 0));
        let s = m.summarise();
        assert_eq!(s.n_requests, 2);
        assert!((s.request_throughput - 0.2).abs() < 1e-9);
        assert!((s.token_throughput - 9.0).abs() < 1e-9);
        assert!((s.valid_token_throughput - 8.0).abs() < 1e-9);
    }

    #[test]
    fn response_times() {
        let mut m = RunMetrics::new();
        for i in 0..100 {
            m.record(rec(i, 0.0, 1.0 + i as f64 * 0.01, 1, 0));
        }
        let s = m.summarise();
        assert!((s.mean_response_time - 1.495).abs() < 1e-6);
        assert!(s.p95_response_time > 1.9 && s.p95_response_time < 2.0);
    }

    #[test]
    fn csv_and_markdown_shapes() {
        let rows = vec![vec!["1".into(), "2".into()]];
        let csv = to_csv(&["a", "b"], &rows);
        assert_eq!(csv, "a,b\n1,2\n");
        let md = to_markdown(&["a", "b"], &rows);
        assert!(md.contains("| a | b |") && md.contains("| 1 | 2 |"));
    }

    #[test]
    fn oom_counted() {
        let mut m = RunMetrics::new();
        m.record_oom();
        m.record_oom();
        assert_eq!(m.summarise().oom_events, 2);
    }

    #[test]
    fn robustness_counters_flow_into_summary() {
        let mut m = RunMetrics::new();
        m.record(rec(0, 0.0, 5.0, 50, 10));
        m.record_shed(7);
        m.record_shed(9);
        m.retries = 3;
        m.worker_restarts = 1;
        m.fallback_predictions = 4;
        let s = m.summarise();
        assert_eq!(s.shed_requests, 2);
        assert_eq!(m.shed, vec![7, 9]);
        assert_eq!(s.retries, 3);
        assert_eq!(s.worker_restarts, 1);
        assert_eq!(s.fallback_predictions, 4);
        // a fresh collector reports everything zero (golden-gate shape)
        let z = RunMetrics::new().summarise();
        assert_eq!(
            (z.shed_requests, z.retries, z.worker_restarts, z.fallback_predictions),
            (0, 0, 0, 0)
        );
    }
}
