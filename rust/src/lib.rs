//! # Magnus — efficient batch serving for LMaaS via generation length prediction
//!
//! Reproduction of Cheng et al., *"Enabling Efficient Batch Serving for
//! LMaaS via Generation Length Prediction"* (CS.DC 2024) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: a generation-
//!   length predictor (random forest over hashed semantic embeddings), the
//!   WMA-directed adaptive batcher (Algorithm 1), a KNN serving-time
//!   estimator, and the HRRN batch scheduler, wired into a multi-instance
//!   serving cluster with the paper's baselines (VS, VSQ, CCB) and
//!   ablations (GLP, ABP).
//! * **Layer 2** — a JAX transformer LM with explicit KV cache
//!   (`python/compile/model.py`), AOT-lowered to HLO text artifacts.
//! * **Layer 1** — Pallas attention kernels (`python/compile/kernels/`)
//!   called by Layer 2; flash-style decode attention is the serving
//!   hot spot.
//!
//! Python runs once at build time (`make artifacts`); the serving binary is
//! pure Rust and loads the artifacts through the PJRT C API (`runtime`).
//!
//! See DESIGN.md for the system inventory and experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod baselines;
pub mod batch;
pub mod cluster;
pub mod config;
pub mod edge;
pub mod embedding;
pub mod engine;
pub mod estimator;
pub mod faults;
pub mod http;
pub mod learning;
pub mod logdb;
pub mod memory;
pub mod metrics;
pub mod predictor;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod sim;
pub mod tokenizer;
pub mod util;
pub mod workload;
