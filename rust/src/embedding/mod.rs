//! Sentence embedding + the paper's compression module (§III-B).
//!
//! **Substitution note.**  The paper uses LaBSE (a 471 M-parameter BERT) to
//! embed the instruction (application-level semantics) and the user input
//! (user-level semantics) into ℝ^768.  Shipping LaBSE is impossible here,
//! and nothing downstream needs *linguistic* meaning — the random-forest
//! regressor only needs embeddings that are (a) deterministic, (b)
//! identical for identical instructions, and (c) close for texts that share
//! vocabulary (GPTCache-style similarity, which the workload generator's
//! topic markers realise).  A hashed character-n-gram embedder has exactly
//! those properties, so it stands in for LaBSE with the same output
//! dimension d = 768.
//!
//! The **compression module** is implemented exactly as the paper
//! describes: the d-dimensional vector is split evenly into `groups`
//! groups, each group is summed and divided by √(group size) for numerical
//! stability — yielding d_app = 4 values for the instruction embedding and
//! d_user = 16 for the user-input embedding.

/// Embedding dimension (matches LaBSE's 768).
pub const D: usize = 768;
/// Paper §III-B: compressed instruction-embedding width.
pub const D_APP: usize = 4;
/// Paper §III-B: compressed user-embedding width.
pub const D_USER: usize = 16;

/// FNV-1a 64-bit — stable, fast string hashing for feature indices.
#[inline]
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Deterministic hashed n-gram sentence embedder (LaBSE stand-in).
///
/// Tokenises on whitespace, hashes unigrams and bigrams of words plus
/// character trigrams into `D` buckets with ±1 signs (feature hashing),
/// then L2-normalises.  Similar texts share buckets ⇒ nearby vectors.
#[derive(Debug, Clone, Default)]
pub struct Embedder;

impl Embedder {
    pub fn new() -> Self {
        Embedder
    }

    /// Embed a text into the unit sphere of ℝ^768.
    pub fn embed(&self, text: &str) -> Vec<f32> {
        let mut v = vec![0f32; D];
        let mut add = |key: &[u8], weight: f32| {
            let h = fnv1a(key);
            let idx = (h % D as u64) as usize;
            let sign = if (h >> 32) & 1 == 0 { 1.0 } else { -1.0 };
            v[idx] += sign * weight;
        };

        let words: Vec<&str> = text.split_whitespace().collect();
        for w in &words {
            add(w.as_bytes(), 1.0);
        }
        for pair in words.windows(2) {
            let key = [pair[0].as_bytes(), b"\x01", pair[1].as_bytes()].concat();
            add(&key, 0.7);
        }
        let bytes = text.as_bytes();
        for tri in bytes.windows(3) {
            add(tri, 0.25);
        }

        let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm > 0.0 {
            for x in &mut v {
                *x /= norm;
            }
        }
        v
    }
}

/// The paper's compression module: split `v` evenly into `groups` groups,
/// sum each group, divide by √(group size).
pub fn compress(v: &[f32], groups: usize) -> Vec<f32> {
    assert!(groups > 0 && v.len() % groups == 0, "d must divide evenly");
    let gsize = v.len() / groups;
    let scale = 1.0 / (gsize as f32).sqrt();
    (0..groups)
        .map(|g| v[g * gsize..(g + 1) * gsize].iter().sum::<f32>() * scale)
        .collect()
}

/// Cosine similarity of two embeddings.
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
    let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na * nb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TaskId;

    #[test]
    fn deterministic_and_unit_norm() {
        let e = Embedder::new();
        let a = e.embed("Fix bugs in the following code");
        let b = e.embed("Fix bugs in the following code");
        assert_eq!(a, b);
        let norm: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-5);
    }

    #[test]
    fn distinct_instructions_are_separable() {
        let e = Embedder::new();
        for t1 in TaskId::ALL {
            for t2 in TaskId::ALL {
                let s = cosine(
                    &e.embed(t1.instruction()),
                    &e.embed(t2.instruction()),
                );
                if t1 == t2 {
                    assert!(s > 0.999);
                } else {
                    // near-duplicate instructions (the two CT directions)
                    // stay below 0.95; all others well below 0.9
                    assert!(s < 0.95, "{} vs {}: {s}", t1.name(), t2.name());
                }
            }
        }
    }

    #[test]
    fn similar_texts_are_closer_than_dissimilar() {
        let e = Embedder::new();
        let a = e.embed("finance the market report finance evening news");
        let b = e.embed("finance market news finance the report");
        let c = e.embed("int vec push_back return for while auto");
        assert!(cosine(&a, &b) > cosine(&a, &c));
    }

    #[test]
    fn compress_shapes_and_scaling() {
        let v = vec![1.0f32; D];
        let c4 = compress(&v, D_APP);
        let c16 = compress(&v, D_USER);
        assert_eq!(c4.len(), 4);
        assert_eq!(c16.len(), 16);
        // group of 192 ones summed / sqrt(192) = sqrt(192)
        assert!((c4[0] - (192f32).sqrt()).abs() < 1e-4);
        assert!((c16[0] - (48f32).sqrt()).abs() < 1e-4);
    }

    #[test]
    #[should_panic]
    fn compress_rejects_uneven_split() {
        compress(&[1.0; 10], 3);
    }

    #[test]
    fn empty_text_embeds_to_zero() {
        let e = Embedder::new();
        let v = e.embed("");
        assert!(v.iter().all(|&x| x == 0.0));
    }
}
