//! Sentence embedding + the paper's compression module (§III-B).
//!
//! **Substitution note.**  The paper uses LaBSE (a 471 M-parameter BERT) to
//! embed the instruction (application-level semantics) and the user input
//! (user-level semantics) into ℝ^768.  Shipping LaBSE is impossible here,
//! and nothing downstream needs *linguistic* meaning — the random-forest
//! regressor only needs embeddings that are (a) deterministic, (b)
//! identical for identical instructions, and (c) close for texts that share
//! vocabulary (GPTCache-style similarity, which the workload generator's
//! topic markers realise).  A hashed character-n-gram embedder has exactly
//! those properties, so it stands in for LaBSE with the same output
//! dimension d = 768.
//!
//! The **compression module** is implemented exactly as the paper
//! describes: the d-dimensional vector is split evenly into `groups`
//! groups, each group is summed and divided by √(group size) for numerical
//! stability — yielding d_app = 4 values for the instruction embedding and
//! d_user = 16 for the user-input embedding.
//!
//! **Hot-path note.**  The predictor embeds every request's user input,
//! so this module exposes zero-alloc entry points: [`Embedder::embed_into`]
//! writes into a caller scratch buffer, and [`Embedder::embed_compress_into`]
//! fuses normalisation into the compression pass (skipping exact-zero
//! buckets, which is bit-identical because `0.0 / norm == +0.0` and
//! `x + 0.0 == x` for every non-`-0.0` f32 this pipeline can produce —
//! bucket sums are never `-0.0`: IEEE addition only returns `-0.0` from
//! all-`-0.0` inputs, and weights are non-zero).  Bigram keys hash through
//! the streaming FNV state instead of materialising the concatenated key —
//! bit-identical to hashing the concatenation because FNV-1a is a
//! byte-sequential fold.  The original allocating implementation is kept
//! verbatim as [`Embedder::embed_baseline`]: it is the measured baseline
//! for `benches/bench_predictor.rs` and the golden reference
//! `tests/predictor_equivalence.rs` checks bit-for-bit.

/// Embedding dimension (matches LaBSE's 768).
pub const D: usize = 768;
/// Paper §III-B: compressed instruction-embedding width.
pub const D_APP: usize = 4;
/// Paper §III-B: compressed user-embedding width.
pub const D_USER: usize = 16;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x1000_0000_01b3;

/// Fold more bytes into an FNV-1a state.
#[inline]
fn fnv1a_update(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a 64-bit — stable, fast string hashing for feature indices.
#[inline]
fn fnv1a(bytes: &[u8]) -> u64 {
    fnv1a_update(FNV_OFFSET, bytes)
}

/// Signed bucket update shared by every n-gram class.
#[inline]
fn bucket_add(v: &mut [f32], h: u64, weight: f32) {
    let idx = (h % D as u64) as usize;
    let sign = if (h >> 32) & 1 == 0 { 1.0 } else { -1.0 };
    v[idx] += sign * weight;
}

/// Deterministic hashed n-gram sentence embedder (LaBSE stand-in).
///
/// Tokenises on whitespace, hashes unigrams and bigrams of words plus
/// character trigrams into `D` buckets with ±1 signs (feature hashing),
/// then L2-normalises.  Similar texts share buckets ⇒ nearby vectors.
#[derive(Debug, Clone, Default)]
pub struct Embedder;

impl Embedder {
    pub fn new() -> Self {
        Embedder
    }

    /// Accumulate the raw (unnormalised) hashed n-gram buckets into
    /// `buf`, resized/zeroed to `D`.  Accumulation order — all unigrams,
    /// then all bigrams, then character trigrams — matches the baseline
    /// exactly (f32 addition order is part of the bit-for-bit contract).
    fn accumulate(&self, text: &str, buf: &mut Vec<f32>) {
        buf.clear();
        buf.resize(D, 0.0);
        for w in text.split_whitespace() {
            bucket_add(buf, fnv1a(w.as_bytes()), 1.0);
        }
        // Bigrams: continue the FNV fold from the previous word's
        // unigram state (== hashing "prev \x01 word" concatenated,
        // without building the key).
        let mut prev_h: Option<u64> = None;
        for w in text.split_whitespace() {
            let hw = fnv1a(w.as_bytes());
            if let Some(ph) = prev_h {
                let h = fnv1a_update(fnv1a_update(ph, b"\x01"), w.as_bytes());
                bucket_add(buf, h, 0.7);
            }
            prev_h = Some(hw);
        }
        for tri in text.as_bytes().windows(3) {
            // manual 3-step unroll of fnv1a(tri)
            let h = ((((FNV_OFFSET ^ tri[0] as u64).wrapping_mul(FNV_PRIME)
                ^ tri[1] as u64)
                .wrapping_mul(FNV_PRIME)
                ^ tri[2] as u64)
                .wrapping_mul(FNV_PRIME)) as u64;
            bucket_add(buf, h, 0.25);
        }
    }

    /// Embed into a caller-provided buffer (resized to `D`) — the
    /// zero-alloc path.  Bit-identical to [`Embedder::embed_baseline`].
    pub fn embed_into(&self, text: &str, out: &mut Vec<f32>) {
        self.accumulate(text, out);
        let norm: f32 = out.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm > 0.0 {
            for x in out.iter_mut() {
                *x /= norm;
            }
        }
    }

    /// Embed a text into the unit sphere of ℝ^768 (allocating wrapper
    /// over [`Embedder::embed_into`]).
    pub fn embed(&self, text: &str) -> Vec<f32> {
        let mut v = Vec::with_capacity(D);
        self.embed_into(text, &mut v);
        v
    }

    /// Fused embed + compress: appends the `groups` compressed values of
    /// the normalised embedding to `out`, using `buf` as the raw-bucket
    /// scratch.  Bit-identical to `compress(&embed(text), groups)` — the
    /// per-element division by the norm happens inside the group fold in
    /// the same index order, and exact-zero buckets are skipped (an
    /// exact no-op, see the module note) so untouched buckets cost no
    /// divisions.
    pub fn embed_compress_into(
        &self,
        text: &str,
        groups: usize,
        buf: &mut Vec<f32>,
        out: &mut Vec<f32>,
    ) {
        assert!(groups > 0 && D % groups == 0, "d must divide evenly");
        self.accumulate(text, buf);
        let gsize = D / groups;
        let scale = 1.0 / (gsize as f32).sqrt();
        let norm: f32 = buf.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm > 0.0 {
            for g in 0..groups {
                let mut acc = 0f32;
                for &x in &buf[g * gsize..(g + 1) * gsize] {
                    if x != 0.0 {
                        acc += x / norm;
                    }
                }
                out.push(acc * scale);
            }
        } else {
            // all-zero embedding (empty text): compress of zeros
            for _ in 0..groups {
                out.push(0.0);
            }
        }
    }

    /// The pre-overhaul implementation (per-call word `Vec`, per-bigram
    /// key concatenation, fresh output buffer), kept verbatim: the
    /// measured baseline for `benches/bench_predictor.rs` and the golden
    /// reference for the zero-alloc path's bit-for-bit tests.
    pub fn embed_baseline(&self, text: &str) -> Vec<f32> {
        let mut v = vec![0f32; D];
        let mut add = |key: &[u8], weight: f32| {
            let h = fnv1a(key);
            let idx = (h % D as u64) as usize;
            let sign = if (h >> 32) & 1 == 0 { 1.0 } else { -1.0 };
            v[idx] += sign * weight;
        };

        let words: Vec<&str> = text.split_whitespace().collect();
        for w in &words {
            add(w.as_bytes(), 1.0);
        }
        for pair in words.windows(2) {
            let key = [pair[0].as_bytes(), b"\x01", pair[1].as_bytes()].concat();
            add(&key, 0.7);
        }
        let bytes = text.as_bytes();
        for tri in bytes.windows(3) {
            add(tri, 0.25);
        }

        let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm > 0.0 {
            for x in &mut v {
                *x /= norm;
            }
        }
        v
    }
}

/// The paper's compression module, appending into a caller buffer: split
/// `v` evenly into `groups` groups, sum each group, divide by
/// √(group size).
pub fn compress_into(v: &[f32], groups: usize, out: &mut Vec<f32>) {
    assert!(groups > 0 && v.len() % groups == 0, "d must divide evenly");
    let gsize = v.len() / groups;
    let scale = 1.0 / (gsize as f32).sqrt();
    out.extend(
        (0..groups).map(|g| v[g * gsize..(g + 1) * gsize].iter().sum::<f32>() * scale),
    );
}

/// Allocating wrapper over [`compress_into`].
pub fn compress(v: &[f32], groups: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(groups);
    compress_into(v, groups, &mut out);
    out
}

/// Cosine similarity of two embeddings.
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
    let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na * nb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TaskId;

    #[test]
    fn deterministic_and_unit_norm() {
        let e = Embedder::new();
        let a = e.embed("Fix bugs in the following code");
        let b = e.embed("Fix bugs in the following code");
        assert_eq!(a, b);
        let norm: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-5);
    }

    #[test]
    fn distinct_instructions_are_separable() {
        let e = Embedder::new();
        for t1 in TaskId::ALL {
            for t2 in TaskId::ALL {
                let s = cosine(
                    &e.embed(t1.instruction()),
                    &e.embed(t2.instruction()),
                );
                if t1 == t2 {
                    assert!(s > 0.999);
                } else {
                    // near-duplicate instructions (the two CT directions)
                    // stay below 0.95; all others well below 0.9
                    assert!(s < 0.95, "{} vs {}: {s}", t1.name(), t2.name());
                }
            }
        }
    }

    #[test]
    fn similar_texts_are_closer_than_dissimilar() {
        let e = Embedder::new();
        let a = e.embed("finance the market report finance evening news");
        let b = e.embed("finance market news finance the report");
        let c = e.embed("int vec push_back return for while auto");
        assert!(cosine(&a, &b) > cosine(&a, &c));
    }

    #[test]
    fn compress_shapes_and_scaling() {
        let v = vec![1.0f32; D];
        let c4 = compress(&v, D_APP);
        let c16 = compress(&v, D_USER);
        assert_eq!(c4.len(), 4);
        assert_eq!(c16.len(), 16);
        // group of 192 ones summed / sqrt(192) = sqrt(192)
        assert!((c4[0] - (192f32).sqrt()).abs() < 1e-4);
        assert!((c16[0] - (48f32).sqrt()).abs() < 1e-4);
    }

    #[test]
    #[should_panic]
    fn compress_rejects_uneven_split() {
        compress(&[1.0; 10], 3);
    }

    #[test]
    fn empty_text_embeds_to_zero() {
        let e = Embedder::new();
        let v = e.embed("");
        assert!(v.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn zero_alloc_paths_match_baseline_bitwise() {
        let e = Embedder::new();
        let mut buf = Vec::new();
        let texts = [
            "",
            "xy",
            "finance",
            "finance the market report finance evening news",
            "int vec push_back return for while auto",
            "a b a b a b a",
            "the the the the",
        ];
        for text in texts {
            let base = e.embed_baseline(text);
            e.embed_into(text, &mut buf);
            assert_eq!(base.len(), buf.len());
            for (a, b) in base.iter().zip(&buf) {
                assert_eq!(a.to_bits(), b.to_bits(), "text={text:?}");
            }
            for groups in [D_APP, D_USER] {
                let reference = compress(&base, groups);
                let mut scratch = Vec::new();
                let mut fused = Vec::new();
                e.embed_compress_into(text, groups, &mut scratch, &mut fused);
                assert_eq!(reference.len(), fused.len());
                for (a, b) in reference.iter().zip(&fused) {
                    assert_eq!(a.to_bits(), b.to_bits(), "text={text:?} g={groups}");
                }
            }
        }
    }

    #[test]
    fn compress_into_appends() {
        let v = vec![2.0f32; D];
        let mut out = vec![9.0f32];
        compress_into(&v, D_APP, &mut out);
        assert_eq!(out.len(), 1 + D_APP);
        assert_eq!(out[0], 9.0);
    }
}
