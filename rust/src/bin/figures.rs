//! Figure/table harness: regenerates every table and figure of the
//! paper's evaluation (§IV) into `results/` as CSV + markdown, printing
//! the same rows/series the paper reports.
//!
//! Usage:
//!   figures all                       # everything (several minutes)
//!   figures table1|table2|fig2|fig6|fig10|fig11|fig12|fig13|fig14|overhead|eq1
//!   figures fig10 --rates 1,2,4,8 --requests 600 --train 300

use magnus::config::ServingConfig;
use magnus::metrics::{to_csv, to_markdown, write_results_file, Summary};
use magnus::predictor::{GenLenPredictor, Variant};
use magnus::sim::{run_policy, Policy};
use magnus::util::cli::Args;
use magnus::util::par::par_map;
use magnus::util::stats::{linear_fit, pearson, rmse};
use magnus::workload::dataset::{build_predictor_split, build_task_dataset};
use magnus::workload::{generate_trace, LlmProfile, TaskId, TraceSpec};

fn main() {
    let args = Args::parse_env(&["help"]).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    let what = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all")
        .to_string();
    let t0 = std::time::Instant::now();
    match what.as_str() {
        "table1" => table1(&args),
        "table2" => table2(&args),
        "fig2" => fig2(&args),
        "fig6" => fig6(&args),
        "fig10" | "fig11" => fig10_11(&args),
        "fig12" | "fig13" => fig12_13(&args),
        "fig14" => fig14(&args),
        "overhead" => overhead(&args),
        "eq1" => eq1(&args),
        "all" => {
            table1(&args);
            table2(&args);
            fig2(&args);
            fig6(&args);
            eq1(&args);
            fig10_11(&args);
            fig12_13(&args);
            fig14(&args);
            overhead(&args);
        }
        other => {
            eprintln!(
                "unknown target '{other}'; expected one of: all table1 \
                 table2 fig2 fig6 fig10 fig11 fig12 fig13 fig14 overhead eq1"
            );
            std::process::exit(2);
        }
    }
    eprintln!("done in {:.1}s", t0.elapsed().as_secs_f64());
}

/// Table I: Pearson coefficient between UIL and G per application per LLM.
fn table1(args: &Args) {
    let n = args.get_usize("requests", 2000);
    println!("\n== Table I: Pearson(UIL, G) per application per LLM ==");
    let apps: Vec<(&str, Vec<TaskId>)> = vec![
        ("MT", vec![TaskId::MtEnDe, TaskId::MtDeEn]),
        ("GC", vec![TaskId::Gc]),
        ("TD", vec![TaskId::Td]),
        ("CT", vec![TaskId::CtCppPy, TaskId::CtPyCpp]),
        ("BF", vec![TaskId::Bf]),
        ("CC", vec![TaskId::Cc]),
    ];
    let header: Vec<&str> = std::iter::once("LLM")
        .chain(apps.iter().map(|(n, _)| *n))
        .collect();
    // Every (LLM × app) cell is independent — same par_map shape as the
    // fig10–13 sweeps; cells come back in index order, so the table is
    // bit-for-bit the serial one's.
    let cells: Vec<String> = par_map(LlmProfile::ALL.len() * apps.len(), |cell| {
        let llm = LlmProfile::ALL[cell / apps.len()];
        let (_, tasks) = &apps[cell % apps.len()];
        // Per-task correlation averaged over the app's tasks (the
        // paper reports one number per app).
        let mut rs = Vec::new();
        for (i, t) in tasks.iter().enumerate() {
            let data = build_task_dataset(*t, llm, n / tasks.len(), 1024,
                                          42 + i as u64, 0);
            let uil: Vec<f64> =
                data.iter().map(|r| r.user_input_len as f64).collect();
            let g: Vec<f64> = data.iter().map(|r| r.gen_len as f64).collect();
            rs.push(pearson(&uil, &g));
        }
        format!("{:.3}", rs.iter().sum::<f64>() / rs.len() as f64)
    });
    let rows: Vec<Vec<String>> = LlmProfile::ALL
        .iter()
        .enumerate()
        .map(|(li, llm)| {
            let mut row = vec![llm.name().to_string()];
            row.extend(cells[li * apps.len()..(li + 1) * apps.len()].iter().cloned());
            row
        })
        .collect();
    emit("table1", &header, &rows);
}

/// Table II: RMSE of UILO / RAFT / INST / USIN per LLM profile.
fn table2(args: &Args) {
    let n_train = args.get_usize("train", 600);
    let n_test = args.get_usize("test", 200);
    println!("\n== Table II: predictor RMSE (train {n_train}/task, test {n_test}/task) ==");
    let cfg = ServingConfig::default();
    let header = vec!["LLM", "UILO", "RAFT", "INST", "USIN"];
    // (LLM × variant) cells are independent — each rebuilds its LLM's
    // deterministic split, so the parallel sweep emits exactly the
    // serial loop's numbers.
    let nv = Variant::ALL.len();
    let cells: Vec<String> = par_map(LlmProfile::ALL.len() * nv, |cell| {
        let llm = LlmProfile::ALL[cell / nv];
        let v = Variant::ALL[cell % nv];
        let split = build_predictor_split(llm, n_train, n_test, 1024, 11);
        let mut p = GenLenPredictor::new(v, &cfg);
        p.train(&split.train);
        let pred: Vec<f64> =
            split.test.iter().map(|r| p.predict(r) as f64).collect();
        let act: Vec<f64> =
            split.test.iter().map(|r| r.gen_len as f64).collect();
        format!("{:.3}", rmse(&pred, &act))
    });
    let rows: Vec<Vec<String>> = LlmProfile::ALL
        .iter()
        .enumerate()
        .map(|(li, llm)| {
            let mut row = vec![llm.name().to_string()];
            row.extend(cells[li * nv..(li + 1) * nv].iter().cloned());
            row
        })
        .collect();
    emit("table2", &header, &rows);
}

/// Fig. 2: UIL-vs-G scatter data + fitted line per application.
fn fig2(args: &Args) {
    let n = args.get_usize("requests", 2000);
    println!("\n== Fig 2: UIL vs G per application (scatter + fit) ==");
    // Per-task cells (dataset + fit + CSV body) run in parallel; the
    // files are written serially afterwards in task order.
    let cells: Vec<(Vec<String>, String, String)> =
        par_map(TaskId::ALL.len(), |ti| {
            let task = TaskId::ALL[ti];
            let data =
                build_task_dataset(task, LlmProfile::ChatGlm6B, n, 1024, 7, 0);
            let uil: Vec<f64> =
                data.iter().map(|r| r.user_input_len as f64).collect();
            let g: Vec<f64> = data.iter().map(|r| r.gen_len as f64).collect();
            let (a, b) = linear_fit(&uil, &g);
            let r = pearson(&uil, &g);
            let fit_row = vec![
                task.name().to_string(),
                format!("{a:.3}"),
                format!("{b:.1}"),
                format!("{r:.3}"),
            ];
            let rows: Vec<Vec<String>> = data
                .iter()
                .map(|d| vec![d.user_input_len.to_string(), d.gen_len.to_string()])
                .collect();
            let csv = to_csv(&["uil", "gen_len"], &rows);
            (fit_row, csv, format!("fig2_{}.csv", task.name()))
        });
    let mut fit_rows = Vec::new();
    for (fit_row, csv, name) in cells {
        fit_rows.push(fit_row);
        let path = write_results_file(&name, &csv).unwrap();
        eprintln!("wrote {path}");
    }
    emit("fig2_fits", &["task", "slope", "intercept", "pearson"], &fit_rows);
}

/// Fig. 6: the 21-request case study.
fn fig6(_args: &Args) {
    use magnus::batch::{AdaptiveBatcher, Batch, BatcherConfig};
    use magnus::engine::cost::CostModelEngine;
    use magnus::engine::InferenceEngine;
    use magnus::workload::{PredictedRequest, RequestMeta, Span, StoreId};

    println!("\n== Fig 6: case study — 18 small + 3 large requests ==");
    let cfg = ServingConfig::default();
    let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);

    let mk = |id: u64, l: u32, g: u32| PredictedRequest {
        meta: RequestMeta {
            id,
            task: TaskId::Gc,
            store: StoreId::DETACHED,
            instr: u32::MAX,
            user_input_len: l,
            request_len: l,
            gen_len: g,
            arrival: 0.0,
            span: Span::DETACHED,
            uih: 0,
        },
        predicted_gen_len: g,
    };
    // Arrival order of Fig. 6a: 6 small, 1 large, repeated.
    let mut arrivals = Vec::new();
    let mut id = 0u64;
    for _ in 0..3 {
        for _ in 0..6 {
            arrivals.push(mk(id, 10, 10));
            id += 1;
        }
        arrivals.push(mk(id, 1000, 1000));
        id += 1;
    }

    // Vanilla: 3 FCFS batches of 7.
    let mut vs_total = 0.0;
    for chunk in arrivals.chunks(7) {
        let mut it = chunk.iter().cloned();
        let mut b = Batch::new(0, it.next().unwrap(), 0.0);
        b.requests.extend(it);
        vs_total += match engine.serve_batch(&b) {
            magnus::engine::BatchOutcome::Completed { serving_time, .. } => serving_time,
            _ => f64::NAN,
        };
    }

    // Magnus: WMA-directed batching (Algorithm 1).
    let mut batcher = AdaptiveBatcher::new(BatcherConfig {
        wma_threshold: cfg.wma_threshold,
        theta: cfg.gpu.theta(),
        delta: cfg.gpu.delta_bytes_per_token,
        max_batch_size: 0,
    });
    for r in arrivals {
        batcher.insert(r, 0.0);
    }
    let mut magnus_total = 0.0;
    let mut shapes = Vec::new();
    while !batcher.is_empty() {
        let b = batcher.take(0);
        shapes.push(format!("β={} L={} G={}", b.size(), b.len(), b.true_gen_len()));
        magnus_total += match engine.serve_batch(&b) {
            magnus::engine::BatchOutcome::Completed { serving_time, .. } => serving_time,
            _ => f64::NAN,
        };
    }

    let reduction = 100.0 * (1.0 - magnus_total / vs_total);
    let rows = vec![
        vec!["VS (3 batches of 7)".into(), format!("{vs_total:.1}"), "242".into()],
        vec![
            format!("Magnus ({})", shapes.join(" + ")),
            format!("{magnus_total:.1}"),
            "60".into(),
        ],
        vec!["reduction %".into(), format!("{reduction:.1}"), "75.2".into()],
    ];
    emit("fig6", &["schedule", "total serving time (s)", "paper"], &rows);
}

/// Eq. 1 sanity table: vanilla β for the default profile.
fn eq1(_args: &Args) {
    println!("\n== Eq. (1): vanilla batch size ==");
    let cfg = ServingConfig::default();
    let rows = vec![vec![
        "V100-32GB / ChatGLM-6B".into(),
        format!("{}", cfg.gpu.theta()),
        format!("{}", cfg.gpu.vanilla_batch_size()),
        "7".into(),
    ]];
    emit("eq1", &["profile", "theta (bytes)", "beta", "paper beta"], &rows);
}

fn sweep(
    args: &Args,
    policies: &[Policy],
    name: &str,
) -> (Vec<&'static str>, Vec<(f64, Vec<Summary>)>) {
    let rates = args.get_f64_list("rates", &[2.0, 5.0, 10.0, 20.0, 40.0]);
    let n = args.get_usize("requests", 800);
    let train = args.get_usize("train", 300);
    let cfg = ServingConfig::default();
    // Every (policy × load-point) cell is an independent simulator run
    // (its own trace copy, predictor, engine, logs), so the whole sweep
    // is embarrassingly parallel; par_map returns cells in index order,
    // so the emitted tables are bit-for-bit those of the serial sweep.
    let n_cells = rates.len() * policies.len();
    let cells: Vec<Summary> = par_map(n_cells, |cell| {
        let rate = rates[cell / policies.len()];
        let policy = policies[cell % policies.len()];
        let trace = generate_trace(&TraceSpec {
            rate,
            n_requests: n,
            seed: 99,
            ..Default::default()
        });
        let s = run_policy(&cfg, policy, &trace, train).metrics.summarise();
        eprintln!("{name}: rate {rate} {} done", policy.name());
        s
    });
    let out: Vec<(f64, Vec<Summary>)> = rates
        .iter()
        .enumerate()
        .map(|(ri, &rate)| {
            let row = cells[ri * policies.len()..(ri + 1) * policies.len()].to_vec();
            (rate, row)
        })
        .collect();
    (policies.iter().map(|p| p.name()).collect(), out)
}

/// Figs. 10 & 11: token/request-level performance vs arrival rate,
/// Magnus vs VS / VSQ / CCB.
fn fig10_11(args: &Args) {
    println!("\n== Fig 10 & 11: Magnus vs baselines across arrival rates ==");
    let (names, data) = sweep(args, &Policy::BASELINES, "fig10_11");
    emit_sweep("fig10a_token_tp", &names, &data, |s| s.token_throughput);
    emit_sweep("fig10b_valid_token_tp", &names, &data, |s| {
        s.valid_token_throughput
    });
    emit_sweep("fig11a_request_tp", &names, &data, |s| s.request_throughput);
    emit_sweep("fig11b_mean_rt", &names, &data, |s| s.mean_response_time);
    emit_sweep("fig11c_p95_rt", &names, &data, |s| s.p95_response_time);
    // Tail views beyond the paper's p95 (histogram-backed: see metrics).
    emit_sweep("fig11d_p50_rt", &names, &data, |s| s.p50_response_time);
    emit_sweep("fig11e_p99_rt", &names, &data, |s| s.p99_response_time);
}

/// Figs. 12 & 13: ablation — VS / GLP / ABP / Magnus.
fn fig12_13(args: &Args) {
    println!("\n== Fig 12 & 13: ablation (VS / GLP / ABP / Magnus) ==");
    let (names, data) = sweep(args, &Policy::ABLATION, "fig12_13");
    emit_sweep("fig12a_token_tp", &names, &data, |s| s.token_throughput);
    emit_sweep("fig12b_valid_token_tp", &names, &data, |s| {
        s.valid_token_throughput
    });
    emit_sweep("fig13a_request_tp", &names, &data, |s| s.request_throughput);
    emit_sweep("fig13b_mean_rt", &names, &data, |s| s.mean_response_time);
    emit_sweep("fig13c_p95_rt", &names, &data, |s| s.p95_response_time);
    // Tail views beyond the paper's p95 (histogram-backed: see metrics).
    emit_sweep("fig13d_p50_rt", &names, &data, |s| s.p50_response_time);
    emit_sweep("fig13e_p99_rt", &names, &data, |s| s.p99_response_time);
}

/// Fig. 14: time-varying RMSE of the two predictors under continuous
/// learning.
fn fig14(args: &Args) {
    println!("\n== Fig 14: prediction error over time (continuous learning) ==");
    let n = args.get_usize("requests", 6000);
    let rate = args.get_f64("rate", 8.0);
    // Deliberately small initial train set so learning has room to help.
    let train = args.get_usize("train", 40);
    let cfg = ServingConfig::default();
    let trace = generate_trace(&TraceSpec {
        rate,
        n_requests: n,
        seed: 7,
        ..Default::default()
    });
    let out = run_policy(&cfg, Policy::Magnus, &trace, train);

    let window = args.get_f64("window", 60.0);
    let bucketise = |errors: &[(f64, f64)]| -> Vec<(f64, f64, usize)> {
        let mut rows = Vec::new();
        if errors.is_empty() {
            return rows;
        }
        let t_end = errors.iter().map(|e| e.0).fold(0.0, f64::max);
        let mut t = window;
        while t <= t_end + window {
            let in_win: Vec<f64> = errors
                .iter()
                .filter(|(at, _)| *at > t - window && *at <= t)
                .map(|(_, e)| e * e)
                .collect();
            if !in_win.is_empty() {
                let rmse_w =
                    (in_win.iter().sum::<f64>() / in_win.len() as f64).sqrt();
                rows.push((t, rmse_w, in_win.len()));
            }
            t += window;
        }
        rows
    };

    for (name, errors) in [
        ("fig14a_genlen_rmse", &out.pred_errors),
        ("fig14b_servtime_rmse", &out.est_errors),
    ] {
        let rows: Vec<Vec<String>> = bucketise(errors)
            .iter()
            .map(|(t, e, n)| {
                vec![format!("{t:.0}"), format!("{e:.3}"), n.to_string()]
            })
            .collect();
        emit(name, &["time_s", "rmse", "n"], &rows);
    }
}

/// §IV-D: component overhead (latency per operation) — the bench harnesses
/// measure these precisely; this target reruns a quick version inline.
fn overhead(_args: &Args) {
    use magnus::batch::{AdaptiveBatcher, BatcherConfig};
    use magnus::estimator::{BatchShape, ServingTimeEstimator};
    use magnus::scheduler::{select, BatchView};
    use magnus::workload::{PredictedRequest, RequestMeta};
    use std::time::Instant;

    println!("\n== §IV-D: component overhead ==");
    let cfg = ServingConfig::default();

    // predictor
    let split = build_predictor_split(LlmProfile::ChatGlm6B, 300, 50, 1024, 3);
    let mut p = GenLenPredictor::new(Variant::Usin, &cfg);
    p.train(&split.train);
    let t = Instant::now();
    let reps = 200;
    for r in split.test.iter().cycle().take(reps) {
        std::hint::black_box(p.predict(r));
    }
    let predict_s = t.elapsed().as_secs_f64() / reps as f64;

    // batcher insert
    let mut b = AdaptiveBatcher::new(BatcherConfig {
        wma_threshold: cfg.wma_threshold,
        theta: cfg.gpu.theta(),
        delta: cfg.gpu.delta_bytes_per_token,
        max_batch_size: 0,
    });
    let trace = generate_trace(&TraceSpec {
        rate: 100.0,
        n_requests: 2000,
        ..Default::default()
    });
    let t = Instant::now();
    for (i, r) in trace.iter().enumerate() {
        b.insert(
            PredictedRequest {
                meta: RequestMeta::detached(r),
                predicted_gen_len: r.gen_len,
            },
            i as f64,
        );
    }
    let batch_s = t.elapsed().as_secs_f64() / trace.len() as f64;

    // estimator
    let shapes: Vec<BatchShape> = (0..2000)
        .map(|i| BatchShape {
            batch_size: 1 + (i % 30) as u32,
            batch_len: 16 + (i % 900) as u32,
            batch_gen_len: 8 + (i % 800) as u32,
        })
        .collect();
    let times: Vec<f64> =
        shapes.iter().map(|s| s.batch_gen_len as f64 * 0.06).collect();
    let mut est = ServingTimeEstimator::new(cfg.knn_k);
    est.train(&shapes, &times);
    let t = Instant::now();
    for s in shapes.iter().take(500) {
        std::hint::black_box(est.estimate(s));
    }
    let est_s = t.elapsed().as_secs_f64() / 500.0;

    // scheduler select over a 100-batch queue
    let views: Vec<BatchView> = (0..100)
        .map(|i| BatchView {
            queuing_time: i as f64,
            est_serving_time: 1.0 + i as f64,
            created_at: i as f64,
            batch_id: i as u64,
        })
        .collect();
    let t = Instant::now();
    for _ in 0..10_000 {
        std::hint::black_box(select(cfg.sched, &views));
    }
    let sched_s = t.elapsed().as_secs_f64() / 10_000.0;

    let rows = vec![
        vec!["generation length prediction".into(), fmt_s(predict_s), "<0.03".into()],
        vec!["batch packaging (insert)".into(), fmt_s(batch_s), "<0.001".into()],
        vec!["serving time estimation".into(), fmt_s(est_s), "<0.001".into()],
        vec!["batch scheduling (select)".into(), fmt_s(sched_s), "<0.002".into()],
    ];
    emit("overhead", &["component", "measured (s)", "paper bound (s)"], &rows);
}

fn fmt_s(s: f64) -> String {
    format!("{s:.6}")
}

fn emit(name: &str, header: &[&str], rows: &[Vec<String>]) {
    print!("{}", to_markdown(header, rows));
    let path =
        write_results_file(&format!("{name}.csv"), &to_csv(header, rows)).unwrap();
    eprintln!("wrote {path}");
}

fn emit_sweep(
    name: &str,
    policies: &[&str],
    data: &[(f64, Vec<Summary>)],
    metric: impl Fn(&Summary) -> f64,
) {
    let mut header = vec!["rate"];
    header.extend(policies);
    let rows: Vec<Vec<String>> = data
        .iter()
        .map(|(rate, summaries)| {
            let mut row = vec![format!("{rate}")];
            row.extend(summaries.iter().map(|s| format!("{:.3}", metric(s))));
            row
        })
        .collect();
    emit(name, &header, &rows);
}
