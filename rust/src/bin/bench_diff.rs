//! `bench_diff` — CI regression gate over `BENCH_*.json` artifacts
//! (ISSUE 7 satellite).
//!
//! Compares every `BENCH_*.json` present in `--new DIR` against the same
//! file in `--old DIR` (the previous run's uploaded artifact) and fails
//! — exit code 1 — when any higher-is-better headline number regressed
//! by more than `--threshold` (default 0.25, i.e. 25%).
//!
//! Headline fields are the *top-level numeric* keys whose name contains
//! `throughput` or `goodput`, or ends in `speedup` or `retention` —
//! the derived ratios every recorder in `util::bench` writes exactly so
//! they can be gated here.  Array-valued series and lower-is-better
//! numbers (latencies, shed rates) are deliberately not gated: they are
//! noisy and direction-ambiguous; the headline ratios already summarise
//! them.
//!
//! Missing baseline (first run, renamed bench, expired artifact) is a
//! pass with a notice, never a failure — the gate must not brick CI on
//! its own bootstrap.

use magnus::util::cli::Args;
use magnus::util::Json;

fn is_headline(key: &str) -> bool {
    key.contains("throughput")
        || key.contains("goodput")
        || key.ends_with("speedup")
        || key.ends_with("retention")
}

/// Top-level numeric headline fields of one bench record.
fn headlines(j: &Json) -> Vec<(String, f64)> {
    let Some(obj) = j.as_obj() else { return Vec::new() };
    let mut out: Vec<(String, f64)> = obj
        .iter()
        .filter(|(k, _)| is_headline(k))
        .filter_map(|(k, v)| v.as_f64().map(|x| (k.clone(), x)))
        .collect();
    out.sort();
    out
}

fn main() {
    let args = match Args::parse_env(&[]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("bench_diff: {e}");
            std::process::exit(2);
        }
    };
    let old_dir = args.get_or("old", "bench-baseline").to_string();
    let new_dir = args.get_or("new", ".").to_string();
    let threshold = args.get_f64("threshold", 0.25);

    let mut checked = 0usize;
    let mut regressions = Vec::new();

    let entries = match std::fs::read_dir(&new_dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("bench_diff: cannot read --new {new_dir}: {e}");
            std::process::exit(2);
        }
    };
    let mut names: Vec<String> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
        .collect();
    names.sort();

    if names.is_empty() {
        println!("bench_diff: no BENCH_*.json in {new_dir}; nothing to gate");
        return;
    }

    for name in &names {
        let new_path = format!("{new_dir}/{name}");
        let old_path = format!("{old_dir}/{name}");
        let new_j = match std::fs::read_to_string(&new_path).map(|s| Json::parse(&s)) {
            Ok(Ok(j)) => j,
            _ => {
                eprintln!("bench_diff: {new_path} unreadable/unparsable; skipping");
                continue;
            }
        };
        let old_j = match std::fs::read_to_string(&old_path).map(|s| Json::parse(&s)) {
            Ok(Ok(j)) => j,
            _ => {
                println!("  {name}: no baseline in {old_dir} — pass (bootstrap)");
                continue;
            }
        };
        let old_fields: std::collections::BTreeMap<String, f64> =
            headlines(&old_j).into_iter().collect();
        for (key, new_v) in headlines(&new_j) {
            let Some(&old_v) = old_fields.get(&key) else { continue };
            if old_v <= 0.0 || !old_v.is_finite() || !new_v.is_finite() {
                continue;
            }
            checked += 1;
            let ratio = new_v / old_v;
            let verdict = if ratio < 1.0 - threshold { "REGRESSED" } else { "ok" };
            println!("  {name}: {key} {old_v:.4} -> {new_v:.4} ({ratio:.3}x) {verdict}");
            if ratio < 1.0 - threshold {
                regressions.push(format!("{name}:{key} {old_v:.4} -> {new_v:.4}"));
            }
        }
    }

    println!("bench_diff: {checked} headline fields checked, {} regressions", regressions.len());
    if !regressions.is_empty() {
        for r in &regressions {
            eprintln!("bench_diff: regression past {:.0}%: {r}", threshold * 100.0);
        }
        std::process::exit(1);
    }
}
