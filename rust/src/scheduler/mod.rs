//! Batch scheduling policies (paper §III-E).
//!
//! When an LLM instance becomes idle the scheduler picks which queued
//! batch it serves next.  Magnus uses HRRN — highest response ratio
//! next, ratio = T_q(B) / T_s(B) with T_s estimated by the serving-time
//! estimator — which trades off queueing time against serving time.
//! FCFS and SJF are provided for baselines/ablations.
//!
//! Ties are broken by batch id, NOT by queue position: the batcher
//! swap-removes dispatched batches (O(1) `take`), so queue order is not
//! stable across a run, and a position-dependent tie-break would make
//! the cached and fresh dispatch paths diverge.  With the id tie-break,
//! `select` is a pure function of the view *set*.

pub mod index;

use crate::batch::Batch;
use crate::config::SchedPolicy;

pub use index::{Entry, LazyHeap};

/// Context the policy needs about one queued batch.
#[derive(Debug, Clone, Copy)]
pub struct BatchView {
    /// T_q(B): longest queuing time among the batch's requests (seconds).
    pub queuing_time: f64,
    /// T_s(B): estimated serving time (seconds).
    pub est_serving_time: f64,
    /// Batch creation order (FCFS key).
    pub created_at: f64,
    /// Stable identity used to break ties order-independently.
    pub batch_id: u64,
}

impl BatchView {
    /// HRRN response ratio with a zero-estimate guard.
    #[inline]
    fn ratio(&self) -> f64 {
        self.queuing_time / self.est_serving_time.max(1e-9)
    }
}

/// Pick the index of the batch to serve next; None if `views` is empty.
pub fn select(policy: SchedPolicy, views: &[BatchView]) -> Option<usize> {
    if views.is_empty() {
        return None;
    }
    // `beats(a, b)` — strict "a should be served before b"; equal keys
    // fall through to the smaller batch id, so the winner is unique and
    // independent of the order batches appear in `views`.  Keys compare
    // via `total_cmp`: a NaN key (a poisoned estimate, say) sorts after
    // every real number instead of panicking mid-dispatch, matching the
    // NaN handling the predictor's split sort adopted.
    let beats = |a: &BatchView, b: &BatchView| -> bool {
        match policy {
            SchedPolicy::Fcfs => match a.created_at.total_cmp(&b.created_at) {
                std::cmp::Ordering::Less => true,
                std::cmp::Ordering::Greater => false,
                std::cmp::Ordering::Equal => a.batch_id < b.batch_id,
            },
            SchedPolicy::Hrrn => match a.ratio().total_cmp(&b.ratio()) {
                std::cmp::Ordering::Greater => true,
                std::cmp::Ordering::Less => false,
                std::cmp::Ordering::Equal => a.batch_id < b.batch_id,
            },
            SchedPolicy::Sjf => match a.est_serving_time.total_cmp(&b.est_serving_time) {
                std::cmp::Ordering::Less => true,
                std::cmp::Ordering::Greater => false,
                std::cmp::Ordering::Equal => a.batch_id < b.batch_id,
            },
        }
    };
    let mut best = 0;
    for i in 1..views.len() {
        if beats(&views[i], &views[best]) {
            best = i;
        }
    }
    Some(best)
}

/// Build a `BatchView` for a queued batch at time `now` given an estimate.
pub fn view_of(batch: &Batch, now: f64, est_serving_time: f64) -> BatchView {
    BatchView {
        queuing_time: (now - batch.earliest_arrival()).max(0.0),
        est_serving_time,
        created_at: batch.created_at,
        batch_id: batch.id,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(q: f64, s: f64, c: f64, id: u64) -> BatchView {
        BatchView {
            queuing_time: q,
            est_serving_time: s,
            created_at: c,
            batch_id: id,
        }
    }

    #[test]
    fn empty_queue_yields_none() {
        assert_eq!(select(SchedPolicy::Hrrn, &[]), None);
    }

    #[test]
    fn fcfs_picks_earliest_created() {
        let views = [v(5.0, 1.0, 3.0, 0), v(1.0, 1.0, 1.0, 1), v(9.0, 1.0, 2.0, 2)];
        assert_eq!(select(SchedPolicy::Fcfs, &views), Some(1));
    }

    #[test]
    fn hrrn_picks_highest_ratio() {
        // ratios: 5/10=0.5, 4/1=4, 100/1000=0.1
        let views = [
            v(5.0, 10.0, 0.0, 0),
            v(4.0, 1.0, 0.0, 1),
            v(100.0, 1000.0, 0.0, 2),
        ];
        assert_eq!(select(SchedPolicy::Hrrn, &views), Some(1));
    }

    #[test]
    fn hrrn_prefers_short_jobs_at_equal_wait() {
        let views = [v(10.0, 100.0, 0.0, 0), v(10.0, 1.0, 0.0, 1)];
        assert_eq!(select(SchedPolicy::Hrrn, &views), Some(1));
    }

    #[test]
    fn hrrn_eventually_favours_long_waiters() {
        // long job has waited 1000x longer → ratio wins despite long Ts
        let views = [v(2.0, 1.0, 0.0, 0), v(5000.0, 1000.0, 0.0, 1)];
        assert_eq!(select(SchedPolicy::Hrrn, &views), Some(1));
    }

    #[test]
    fn sjf_picks_min_serving_time() {
        let views = [
            v(1.0, 5.0, 0.0, 0),
            v(1.0, 2.0, 0.0, 1),
            v(1.0, 9.0, 0.0, 2),
        ];
        assert_eq!(select(SchedPolicy::Sjf, &views), Some(1));
    }

    #[test]
    fn hrrn_handles_zero_estimate() {
        let views = [v(1.0, 0.0, 0.0, 0), v(1.0, 1.0, 0.0, 1)];
        // no panic; zero estimate treated as epsilon → huge ratio
        assert_eq!(select(SchedPolicy::Hrrn, &views), Some(0));
    }

    #[test]
    fn nan_keys_are_total_ordered_instead_of_panicking() {
        // Pre-total_cmp these unwrap-panicked.  Now NaN sorts after every
        // finite key: it loses under the min-policies (FCFS, SJF) and —
        // as the greatest element of the total order — wins under the
        // max-policy (HRRN).  Either way selection stays deterministic
        // and order-independent.
        let nan = f64::NAN;
        let sane = v(1.0, 2.0, 1.0, 7);
        for (policy, bad, nan_wins) in [
            (SchedPolicy::Fcfs, v(1.0, 2.0, nan, 3), false),
            (SchedPolicy::Sjf, v(1.0, nan, 1.0, 3), false),
            (SchedPolicy::Hrrn, v(nan, 2.0, 1.0, 3), true),
        ] {
            let expect_bad_first = if nan_wins { Some(0) } else { Some(1) };
            let expect_sane_first = if nan_wins { Some(1) } else { Some(0) };
            assert_eq!(select(policy, &[bad, sane]), expect_bad_first, "{policy:?}");
            assert_eq!(select(policy, &[sane, bad]), expect_sane_first, "{policy:?}");
            // all-NaN queues still pick deterministically (smaller id)
            let bad2 = BatchView { batch_id: 9, ..bad };
            assert_eq!(select(policy, &[bad2, bad]), Some(1), "{policy:?}");
        }
    }

    #[test]
    fn ties_break_by_batch_id_not_position() {
        // identical keys in every policy: the smaller id must win from
        // either ordering.
        for policy in [SchedPolicy::Fcfs, SchedPolicy::Hrrn, SchedPolicy::Sjf] {
            let a = v(3.0, 2.0, 1.0, 4);
            let b = v(3.0, 2.0, 1.0, 9);
            assert_eq!(select(policy, &[a, b]), Some(0), "{policy:?}");
            assert_eq!(select(policy, &[b, a]), Some(1), "{policy:?}");
        }
    }
}
