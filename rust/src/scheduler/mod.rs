//! Batch scheduling policies (paper §III-E).
//!
//! When an LLM instance becomes idle the scheduler picks which queued
//! batch it serves next.  Magnus uses HRRN — highest response ratio
//! next, ratio = T_q(B) / T_s(B) with T_s estimated by the serving-time
//! estimator — which trades off queueing time against serving time.
//! FCFS and SJF are provided for baselines/ablations.

use crate::batch::Batch;
use crate::config::SchedPolicy;

/// Context the policy needs about one queued batch.
#[derive(Debug, Clone, Copy)]
pub struct BatchView {
    /// T_q(B): longest queuing time among the batch's requests (seconds).
    pub queuing_time: f64,
    /// T_s(B): estimated serving time (seconds).
    pub est_serving_time: f64,
    /// Batch creation order (FCFS key).
    pub created_at: f64,
}

/// Pick the index of the batch to serve next; None if `views` is empty.
pub fn select(policy: SchedPolicy, views: &[BatchView]) -> Option<usize> {
    if views.is_empty() {
        return None;
    }
    let idx = match policy {
        SchedPolicy::Fcfs => {
            // earliest created batch
            (0..views.len())
                .min_by(|&a, &b| {
                    views[a]
                        .created_at
                        .partial_cmp(&views[b].created_at)
                        .unwrap()
                })
                .unwrap()
        }
        SchedPolicy::Hrrn => {
            // max T_q / T_s  (§III-E)
            (0..views.len())
                .max_by(|&a, &b| {
                    let ra = views[a].queuing_time / views[a].est_serving_time.max(1e-9);
                    let rb = views[b].queuing_time / views[b].est_serving_time.max(1e-9);
                    ra.partial_cmp(&rb).unwrap()
                })
                .unwrap()
        }
        SchedPolicy::Sjf => (0..views.len())
            .min_by(|&a, &b| {
                views[a]
                    .est_serving_time
                    .partial_cmp(&views[b].est_serving_time)
                    .unwrap()
            })
            .unwrap(),
    };
    Some(idx)
}

/// Build a `BatchView` for a queued batch at time `now` given an estimate.
pub fn view_of(batch: &Batch, now: f64, est_serving_time: f64) -> BatchView {
    BatchView {
        queuing_time: (now - batch.earliest_arrival()).max(0.0),
        est_serving_time,
        created_at: batch.created_at,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(q: f64, s: f64, c: f64) -> BatchView {
        BatchView {
            queuing_time: q,
            est_serving_time: s,
            created_at: c,
        }
    }

    #[test]
    fn empty_queue_yields_none() {
        assert_eq!(select(SchedPolicy::Hrrn, &[]), None);
    }

    #[test]
    fn fcfs_picks_earliest_created() {
        let views = [v(5.0, 1.0, 3.0), v(1.0, 1.0, 1.0), v(9.0, 1.0, 2.0)];
        assert_eq!(select(SchedPolicy::Fcfs, &views), Some(1));
    }

    #[test]
    fn hrrn_picks_highest_ratio() {
        // ratios: 5/10=0.5, 4/1=4, 100/1000=0.1
        let views = [v(5.0, 10.0, 0.0), v(4.0, 1.0, 0.0), v(100.0, 1000.0, 0.0)];
        assert_eq!(select(SchedPolicy::Hrrn, &views), Some(1));
    }

    #[test]
    fn hrrn_prefers_short_jobs_at_equal_wait() {
        let views = [v(10.0, 100.0, 0.0), v(10.0, 1.0, 0.0)];
        assert_eq!(select(SchedPolicy::Hrrn, &views), Some(1));
    }

    #[test]
    fn hrrn_eventually_favours_long_waiters() {
        // long job has waited 1000x longer → ratio wins despite long Ts
        let views = [v(2.0, 1.0, 0.0), v(5000.0, 1000.0, 0.0)];
        assert_eq!(select(SchedPolicy::Hrrn, &views), Some(1));
    }

    #[test]
    fn sjf_picks_min_serving_time() {
        let views = [v(1.0, 5.0, 0.0), v(1.0, 2.0, 0.0), v(1.0, 9.0, 0.0)];
        assert_eq!(select(SchedPolicy::Sjf, &views), Some(1));
    }

    #[test]
    fn hrrn_handles_zero_estimate() {
        let views = [v(1.0, 0.0, 0.0), v(1.0, 1.0, 0.0)];
        // no panic; zero estimate treated as epsilon → huge ratio
        assert_eq!(select(SchedPolicy::Hrrn, &views), Some(0));
    }
}
