//! Lazily-invalidated priority heaps for indexed batch selection.
//!
//! The dispatch loop used to pick the next batch with an O(Q) scan over
//! every queued batch (`scheduler::select`).  The batcher now maintains
//! per-policy [`LazyHeap`]s over `(key, batch id, stamp)` entries so a
//! steady-state select touches O(log Q) entries instead:
//!
//! * entries are never removed eagerly — a batch leaving the queue
//!   (dispatch) or mutating (a request joins it, an OOM half re-queues)
//!   simply makes its old entries *stale*;
//! * staleness is detected at pop time by a caller-supplied validity
//!   check (is the id still queued, does the stamp still match?), and
//!   stale entries are discarded as they surface — the "popped and
//!   revalidated" discipline;
//! * keys are compared with `total_cmp` and ties break on the smaller
//!   batch id, exactly like the linear-scan reference, so the surfaced
//!   winner is bit-identical to the scan's.
//!
//! The heap itself is policy-agnostic: the batcher keys one instance on
//! `created_at` (FCFS), one on the cached serving-time estimate
//! (SJF, and the HRRN pruning order), and one on the earliest arrival
//! (the HRRN queuing-time bound).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// One heap entry: a selection key for batch `id`, valid while the
/// batch's mutation stamp still equals `stamp` (stamps are globally
/// monotone, so entries from a batch's earlier life — before a dispatch
/// and re-queue, say — can never be mistaken for fresh ones).
#[derive(Debug, Clone, Copy)]
pub struct Entry {
    pub key: f64,
    pub id: u64,
    pub stamp: u64,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for Entry {}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key
            .total_cmp(&other.key)
            .then(self.id.cmp(&other.id))
            .then(self.stamp.cmp(&other.stamp))
    }
}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap over [`Entry`] with lazy deletion.
///
/// Duplicate entries per batch are allowed (each mutation pushes a fresh
/// entry); only the one carrying the batch's current stamp validates, and
/// duplicates with identical `(key, id, stamp)` are harmless because
/// selection is a pure function of the surfaced minimum.
#[derive(Debug, Default)]
pub struct LazyHeap {
    heap: BinaryHeap<Reverse<Entry>>,
}

impl LazyHeap {
    pub fn new() -> Self {
        LazyHeap::default()
    }

    pub fn push(&mut self, key: f64, id: u64, stamp: u64) {
        self.heap.push(Reverse(Entry { key, id, stamp }));
    }

    pub fn clear(&mut self) {
        self.heap.clear();
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Discard stale tops until the minimum valid entry surfaces; return
    /// its `(key, id)` without removing it.
    pub fn peek_valid<F: Fn(u64, u64) -> bool>(&mut self, valid: F) -> Option<(f64, u64)> {
        while let Some(Reverse(e)) = self.heap.peek() {
            if valid(e.id, e.stamp) {
                return Some((e.key, e.id));
            }
            self.heap.pop();
        }
        None
    }

    /// Discard stale tops, then remove and return the minimum valid entry.
    pub fn pop_valid<F: Fn(u64, u64) -> bool>(&mut self, valid: F) -> Option<Entry> {
        while let Some(Reverse(e)) = self.heap.pop() {
            if valid(e.id, e.stamp) {
                return Some(e);
            }
        }
        None
    }

    /// Push back entries temporarily popped by a pruning scan (HRRN pops
    /// candidates in ascending-estimate order, then restores them).
    pub fn reinsert(&mut self, entries: &mut Vec<Entry>) {
        for e in entries.drain(..) {
            self.heap.push(Reverse(e));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn min_order_with_id_tie_break() {
        let mut h = LazyHeap::new();
        h.push(2.0, 7, 0);
        h.push(1.0, 9, 0);
        h.push(1.0, 3, 0);
        assert_eq!(h.peek_valid(|_, _| true), Some((1.0, 3)));
        let e = h.pop_valid(|_, _| true).unwrap();
        assert_eq!((e.key, e.id), (1.0, 3));
        assert_eq!(h.peek_valid(|_, _| true), Some((1.0, 9)));
    }

    #[test]
    fn stale_entries_are_discarded_lazily() {
        let mut h = LazyHeap::new();
        h.push(1.0, 1, 0); // stale: stamp advanced to 1
        h.push(2.0, 1, 1); // fresh replacement, worse key
        h.push(3.0, 2, 0);
        let valid = |id: u64, stamp: u64| match id {
            1 => stamp == 1,
            _ => true,
        };
        assert_eq!(h.peek_valid(valid), Some((2.0, 1)));
        assert_eq!(h.len(), 2, "stale top physically removed");
    }

    #[test]
    fn dead_ids_never_surface() {
        let mut h = LazyHeap::new();
        h.push(1.0, 1, 0);
        h.push(2.0, 2, 0);
        assert_eq!(h.peek_valid(|id, _| id != 1), Some((2.0, 2)));
        assert_eq!(h.pop_valid(|id, _| id != 1).map(|e| e.id), Some(2));
        assert_eq!(h.pop_valid(|_, _| true), None);
    }

    #[test]
    fn reinsert_restores_pruned_entries() {
        let mut h = LazyHeap::new();
        for id in 0..5u64 {
            h.push(id as f64, id, 0);
        }
        let mut scratch = Vec::new();
        for _ in 0..3 {
            scratch.push(h.pop_valid(|_, _| true).unwrap());
        }
        h.reinsert(&mut scratch);
        assert!(scratch.is_empty());
        assert_eq!(h.peek_valid(|_, _| true), Some((0.0, 0)));
        assert_eq!(h.len(), 5);
    }

    #[test]
    fn nan_keys_sort_last_not_panic() {
        let mut h = LazyHeap::new();
        h.push(f64::NAN, 1, 0);
        h.push(5.0, 2, 0);
        assert_eq!(h.peek_valid(|_, _| true), Some((5.0, 2)));
    }
}
