//! Byte-level tokenizer shared with the AOT compile path.
//!
//! The served LM has a 512-entry vocabulary: ids 0..NUM_SPECIALS are the
//! specials (PAD, BOS, EOS — the same ids `python/compile/aot.py` writes to
//! the manifest), 3..259 are raw bytes, and the rest are reserved (they give
//! the model a little headroom and keep the vocab a power of two).
//!
//! This is deliberately NOT a learned BPE: the reproduction's serving
//! results depend on *token counts*, not linguistic segmentation, and a
//! byte tokenizer makes request length == byte length + specials, which the
//! synthetic workload generators control exactly.

/// Padding token id (masked out of attention).
pub const PAD: u32 = 0;
/// Beginning-of-sequence token id.
pub const BOS: u32 = 1;
/// End-of-sequence token id — generation stops when the model emits it.
pub const EOS: u32 = 2;
/// First byte token id.
pub const BYTE_BASE: u32 = 3;
/// Vocabulary size (kept in sync with `ModelConfig.vocab` on the JAX side).
pub const VOCAB: u32 = 512;

/// Byte-level tokenizer.
#[derive(Debug, Clone, Default)]
pub struct Tokenizer;

impl Tokenizer {
    pub fn new() -> Self {
        Tokenizer
    }

    /// Encode text to token ids, prefixed with BOS.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::with_capacity(text.len() + 1);
        out.push(BOS);
        out.extend(text.bytes().map(|b| BYTE_BASE + b as u32));
        out
    }

    /// Encode without the BOS prefix (for concatenating segments).
    pub fn encode_raw(&self, text: &str) -> Vec<u32> {
        text.bytes().map(|b| BYTE_BASE + b as u32).collect()
    }

    /// Decode ids back to text.  Specials and reserved ids are skipped;
    /// invalid UTF-8 is replaced (the tiny random-weight model emits
    /// arbitrary bytes).
    pub fn decode(&self, ids: &[u32]) -> String {
        let bytes: Vec<u8> = ids
            .iter()
            .filter_map(|&id| {
                if (BYTE_BASE..BYTE_BASE + 256).contains(&id) {
                    Some((id - BYTE_BASE) as u8)
                } else {
                    None
                }
            })
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Token count of a text including the BOS prefix.
    pub fn token_len(&self, text: &str) -> usize {
        text.len() + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn encode_prefixes_bos() {
        let t = Tokenizer::new();
        let ids = t.encode("ab");
        assert_eq!(ids, vec![BOS, BYTE_BASE + 97, BYTE_BASE + 98]);
    }

    #[test]
    fn roundtrip_ascii() {
        let t = Tokenizer::new();
        let s = "Fix bugs in the following code:";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn roundtrip_utf8() {
        let t = Tokenizer::new();
        let s = "héllo 世界";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn decode_skips_specials_and_reserved() {
        let t = Tokenizer::new();
        let mut ids = t.encode("x");
        ids.push(EOS);
        ids.push(PAD);
        ids.push(VOCAB - 1); // reserved
        assert_eq!(t.decode(&ids), "x");
    }

    #[test]
    fn token_len_matches_encode() {
        let t = Tokenizer::new();
        prop_check(100, |rng| {
            let n = rng.range_usize(0, 200);
            let s: String = (0..n)
                .map(|_| (rng.range_u64(32, 127) as u8) as char)
                .collect();
            let t2 = Tokenizer::new();
            assert_eq!(t2.encode(&s).len(), t2.token_len(&s));
        });
        let _ = t;
    }

    #[test]
    fn all_ids_below_vocab() {
        let t = Tokenizer::new();
        let ids = t.encode("\u{ff}\u{0}");
        assert!(ids.iter().all(|&id| id < VOCAB));
    }
}
