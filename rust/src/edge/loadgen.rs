//! Open-loop load generator (ISSUE 7): replay trace entries against a
//! live edge socket at a configured arrival rate, independent of how
//! fast the server answers.
//!
//! Open-loop is the property that makes overload benchmarks honest: a
//! closed-loop client slows down when the server does, hiding the very
//! collapse we are measuring.  Here a generator thread emits arrivals on
//! the configured Poisson (or bursty) schedule into a channel; a pool of
//! connection workers sends each one as soon as a connection is free.
//! Under extreme overload the pool itself can lag the schedule — the
//! report carries `max_lag_s` so a run that stopped being open-loop says
//! so instead of lying.
//!
//! Client-side chaos comes from the same [`FaultPlan`](crate::faults)
//! machinery the server uses, keyed per request serial so runs are
//! reproducible: `conndrop=P` closes the socket mid-request (the server
//! must reap the partial read, not hang); `slowclient=P@D` stalls
//! `D` seconds between head and body (the server's read timeout bounds
//! the damage).

use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::faults::FaultPlan;
use crate::http::{read_response, ParseError};
use crate::metrics::Histogram;
use crate::util::{Json, Rng};

use anyhow::{anyhow, Result};

/// One load-generation run against a live edge.
#[derive(Debug, Clone)]
pub struct LoadGenConfig {
    /// Edge address, e.g. `127.0.0.1:8080`.
    pub addr: String,
    /// Mean offered arrival rate (requests per second).
    pub rps: f64,
    /// Total requests to offer.
    pub n_requests: usize,
    /// Trace entries are addressed round-robin modulo this length.
    pub trace_len: usize,
    /// `Some((period_s, factor))` switches Poisson arrivals to a square
    /// wave: `rps × factor` for the first half of each period, `rps ÷
    /// factor` for the second (same mean rate; stresses the queue).
    pub burst: Option<(f64, f64)>,
    /// Concurrent client connections.
    pub n_conns: usize,
    /// Deadline sent with every request (`None` = server default).
    pub deadline_ms: Option<u64>,
    /// Client-side fault axes (`conndrop`, `slowclient`); server axes in
    /// the plan are ignored here.
    pub plan: FaultPlan,
    pub seed: u64,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig {
            addr: "127.0.0.1:8080".to_string(),
            rps: 50.0,
            n_requests: 500,
            trace_len: 1,
            burst: None,
            n_conns: 8,
            deadline_ms: None,
            plan: FaultPlan::none(),
            seed: 1,
        }
    }
}

/// What happened to the offered load, by terminal status.
#[derive(Debug, Default)]
pub struct LoadReport {
    /// Requests the generator attempted (== `n_requests`).
    pub offered: u64,
    /// `200` — served within deadline.
    pub ok: u64,
    /// `429`/`503` — explicitly refused (admission, rate, drain, core).
    pub shed: u64,
    /// `504` — deadline expired in the edge queue.
    pub expired: u64,
    /// Connections this client dropped on purpose (conndrop axis).
    pub dropped: u64,
    /// Transport/parse failures that were *not* injected.
    pub client_errors: u64,
    /// End-to-end wall latency of `ok` responses.
    pub latency: Histogram,
    pub elapsed_s: f64,
    /// Worst (send instant − scheduled instant): how open-loop the run
    /// actually was.
    pub max_lag_s: f64,
}

impl LoadReport {
    pub fn goodput(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.ok as f64 / self.elapsed_s
        } else {
            0.0
        }
    }

    /// Every offered request has a terminal classification.
    pub fn accounted(&self) -> bool {
        self.offered == self.ok + self.shed + self.expired + self.dropped + self.client_errors
    }
}

/// Instantaneous rate at schedule time `t` for the configured shape.
fn rate_at(cfg: &LoadGenConfig, t: f64) -> f64 {
    match cfg.burst {
        Some((period, factor)) if period > 0.0 && factor > 1.0 => {
            let phase = (t / period).fract();
            if phase < 0.5 {
                cfg.rps * factor
            } else {
                cfg.rps / factor
            }
        }
        _ => cfg.rps,
    }
}

/// Precompute the arrival schedule: exponential inter-arrival gaps at
/// the (possibly modulated) instantaneous rate — a Poisson process, or a
/// piecewise-Poisson square wave.
fn build_schedule(cfg: &LoadGenConfig, rng: &mut Rng) -> Vec<f64> {
    let mut at = Vec::with_capacity(cfg.n_requests);
    let mut t = 0.0;
    for _ in 0..cfg.n_requests {
        let r = rate_at(cfg, t).max(1e-9);
        t += rng.exponential(r);
        at.push(t);
    }
    at
}

enum Outcome {
    Status(u16, f64),
    Dropped,
    ClientError,
}

/// Offer the full schedule to `cfg.addr`; blocks until every request
/// has a terminal outcome.
pub fn run_loadgen(cfg: &LoadGenConfig) -> Result<LoadReport> {
    if cfg.trace_len == 0 || cfg.n_requests == 0 {
        return Err(anyhow!("loadgen needs trace_len > 0 and n_requests > 0"));
    }
    let mut rng = Rng::new(cfg.seed ^ 0x10ad_9e4e);
    let schedule = build_schedule(cfg, &mut rng);

    let (work_tx, work_rx) = mpsc::channel::<(u64, usize)>();
    let work_rx = Arc::new(Mutex::new(work_rx));
    let (out_tx, out_rx) = mpsc::channel::<Outcome>();
    let start = Instant::now();
    let max_lag_ns = Arc::new(AtomicU64::new(0));

    let mut workers = Vec::new();
    for _ in 0..cfg.n_conns.max(1) {
        let work_rx = Arc::clone(&work_rx);
        let out_tx = out_tx.clone();
        let cfg = cfg.clone();
        workers.push(std::thread::spawn(move || {
            let mut conn: Option<TcpStream> = None;
            loop {
                let item = work_rx.lock().unwrap().recv();
                let Ok((serial, index)) = item else { return };
                let outcome = send_one(&cfg, &mut conn, serial, index);
                if out_tx.send(outcome).is_err() {
                    return;
                }
            }
        }));
    }
    drop(out_tx);

    // Generator: pace the schedule on this thread (open-loop — nothing
    // here depends on responses).
    {
        let max_lag_ns = Arc::clone(&max_lag_ns);
        for (serial, due) in schedule.iter().enumerate() {
            let due = Duration::from_secs_f64(*due);
            let now = start.elapsed();
            if now < due {
                std::thread::sleep(due - now);
            } else {
                let lag = (now - due).as_nanos().min(u128::from(u64::MAX)) as u64;
                max_lag_ns.fetch_max(lag, Ordering::Relaxed);
            }
            let index = serial % cfg.trace_len;
            if work_tx.send((serial as u64, index)).is_err() {
                break;
            }
        }
        drop(work_tx); // workers drain and exit
    }

    let mut report = LoadReport { offered: cfg.n_requests as u64, ..Default::default() };
    for outcome in out_rx.iter() {
        match outcome {
            Outcome::Status(200, lat) => {
                report.ok += 1;
                report.latency.observe(lat);
            }
            Outcome::Status(504, _) => report.expired += 1,
            Outcome::Status(_, _) => report.shed += 1,
            Outcome::Dropped => report.dropped += 1,
            Outcome::ClientError => report.client_errors += 1,
        }
    }
    for w in workers {
        let _ = w.join();
    }
    report.elapsed_s = start.elapsed().as_secs_f64();
    report.max_lag_s = max_lag_ns.load(Ordering::Relaxed) as f64 / 1e9;
    Ok(report)
}

/// Send request `serial` over the worker's (reconnecting) connection,
/// injecting this serial's client faults.
fn send_one(
    cfg: &LoadGenConfig,
    conn: &mut Option<TcpStream>,
    serial: u64,
    index: usize,
) -> Outcome {
    let mut fields = vec![("index", Json::num(index as f64))];
    if let Some(ms) = cfg.deadline_ms {
        fields.push(("deadline_ms", Json::num(ms as f64)));
    }
    let body = Json::obj(fields).to_string();
    let raw = format!(
        "POST /v1/generate HTTP/1.1\r\nHost: edge\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    );
    let raw = raw.as_bytes();

    if conn.is_none() {
        match TcpStream::connect(&cfg.addr) {
            Ok(s) => {
                let _ = s.set_read_timeout(Some(Duration::from_secs(150)));
                let _ = s.set_nodelay(true);
                *conn = Some(s);
            }
            Err(_) => return Outcome::ClientError,
        }
    }
    let stream = conn.as_mut().expect("connection just ensured");

    if cfg.plan.injects_conn_drop(serial) {
        // Write half the request, then vanish: the server must reap the
        // partial read without wedging a thread.
        let _ = stream.write_all(&raw[..raw.len() / 2]);
        *conn = None; // dropped; next request reconnects
        return Outcome::Dropped;
    }

    let sent = if cfg.plan.injects_slow_client(serial) {
        // Stall between head and body: exercises the read timeout
        // without (normally) tripping it.
        let split = raw.len() - body.len();
        stream.write_all(&raw[..split]).is_ok() && {
            std::thread::sleep(Duration::from_secs_f64(cfg.plan.slow_client_delay_s.max(0.0)));
            stream.write_all(&raw[split..]).is_ok()
        }
    } else {
        stream.write_all(raw).is_ok()
    };
    if !sent {
        *conn = None;
        return Outcome::ClientError;
    }

    let t0 = Instant::now();
    match read_response(stream) {
        Ok((status, _body)) => Outcome::Status(status, t0.elapsed().as_secs_f64()),
        Err(ParseError::Io(_)) | Err(ParseError::Incomplete) => {
            *conn = None;
            Outcome::ClientError
        }
        Err(_) => {
            *conn = None;
            Outcome::ClientError
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base(n: usize, rps: f64) -> LoadGenConfig {
        LoadGenConfig { rps, n_requests: n, trace_len: 7, ..Default::default() }
    }

    #[test]
    fn schedule_is_monotone_and_tracks_mean_rate() {
        let cfg = base(4_000, 80.0);
        let mut rng = Rng::new(9);
        let s = build_schedule(&cfg, &mut rng);
        assert!(s.windows(2).all(|w| w[1] >= w[0]));
        let mean_rate = s.len() as f64 / s.last().unwrap();
        assert!(
            (mean_rate - 80.0).abs() < 8.0,
            "poisson mean rate {mean_rate} vs 80"
        );
    }

    #[test]
    fn bursty_schedule_alternates_fast_and_slow_halves() {
        let cfg = LoadGenConfig { burst: Some((2.0, 4.0)), ..base(6_000, 50.0) };
        let mut rng = Rng::new(5);
        let s = build_schedule(&cfg, &mut rng);
        let (mut fast, mut slow) = (0u64, 0u64);
        for t in &s {
            if (t / 2.0).fract() < 0.5 {
                fast += 1;
            } else {
                slow += 1;
            }
        }
        // 4× vs ¼× rate halves: the fast half should dominate heavily.
        assert!(
            fast > slow * 4,
            "burst imbalance missing: fast={fast} slow={slow}"
        );
    }

    #[test]
    fn schedule_is_deterministic_in_the_seed() {
        let cfg = base(200, 30.0);
        let a = build_schedule(&cfg, &mut Rng::new(42));
        let b = build_schedule(&cfg, &mut Rng::new(42));
        let c = build_schedule(&cfg, &mut Rng::new(43));
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn loadgen_against_dead_address_accounts_every_request() {
        // Nothing listens on this port: every request must come back as
        // a client error — counted, not hung, not panicked.
        let cfg = LoadGenConfig {
            addr: "127.0.0.1:1".to_string(),
            rps: 500.0,
            n_requests: 40,
            n_conns: 4,
            ..base(40, 500.0)
        };
        let r = run_loadgen(&cfg).unwrap();
        assert_eq!(r.offered, 40);
        assert_eq!(r.client_errors, 40);
        assert!(r.accounted(), "{r:?}");
    }
}
