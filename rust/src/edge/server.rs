//! The HTTP front door: admission-controlled live serving over the
//! supervised core (ISSUE 7 tentpole).
//!
//! ```text
//!   clients ── http::HttpServer ── handler ──┐
//!                                            │ offer(id, predicted, deadline)
//!                              AdmissionController (edge clock, wall secs)
//!                                            │ Forward / Queued / Shed
//!                    EdgeJob ────────────────┤
//!                       │                    └─ 429/503 immediately
//!             server::serve_ingress_sim  (leader + workers, exactly-once)
//!                       │ CoreSignal::{Completed, Shed}
//!                    router thread ── resolves waiting handlers,
//!                                     expires deadlines, pumps the queue
//! ```
//!
//! Every offered request resolves to exactly one of four terminal
//! counters — `completed`, `shed` (admission refused it), `expired`
//! (deadline passed while queued), `core_shed` (the core gave up) — so
//!
//! ```text
//!     offered == completed + shed + expired + core_shed
//! ```
//!
//! holds at shutdown no matter the overload or the fault plan; the
//! tests and `bench_edge` assert it ([`EdgeReport::accounted`]).
//! `bad_requests` (malformed bodies, out-of-range indices) are counted
//! separately and never enter the identity — nothing was offered to
//! admission.
//!
//! The edge runs on *wall* seconds (client deadlines are real time); the
//! core keeps its replayed clock (`time_scale`) and rewrites each job's
//! arrival on receipt, so the two clocks never need reconciling.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::ServingConfig;
use crate::faults::FaultPlan;
use crate::http::{HttpConfig, HttpRequest, HttpResponse, HttpServer};
use crate::metrics::{Histogram, MispredictGauge, RunMetrics};
use crate::predictor::GenLenPredictor;
use crate::server::{serve_ingress_sim, CoreSignal, EdgeJob, LivePolicy, ServeOptions};
use crate::util::Json;
use crate::workload::{RequestMeta, TraceStore};

use crate::config::UncertaintyConfig;

use super::admission::{admission_charge, AdmissionConfig, AdmissionController, Offer, ShedReason};

use anyhow::{anyhow, Result};

/// Everything the edge needs beyond the core's `ServingConfig`.
#[derive(Debug, Clone)]
pub struct EdgeOptions {
    pub http: HttpConfig,
    pub admission: AdmissionConfig,
    pub n_workers: usize,
    /// Core replay speed-up (the edge itself runs on wall time).
    pub time_scale: f64,
    /// Core-side fault schedule (crashes, OOMs, predictor outages).
    pub fault_plan: FaultPlan,
    /// Shutdown: how long to wait for queued + in-core work to finish
    /// before expiring the leftovers.
    pub drain_grace: Duration,
}

impl Default for EdgeOptions {
    fn default() -> Self {
        EdgeOptions {
            http: HttpConfig::default(),
            admission: AdmissionConfig::default(),
            n_workers: 2,
            time_scale: 200.0,
            fault_plan: FaultPlan::none(),
            drain_grace: Duration::from_secs(10),
        }
    }
}

/// Terminal outcome sent to the handler thread waiting on a request.
enum Reply {
    Done { valid_tokens: u32, invalid_tokens: u32 },
    /// The core shed it (retry budget gone / workers retired / core gone).
    CoreShed,
    /// Deadline passed while queued at the edge.
    Expired,
    /// Displaced from a full queue by a shorter-predicted arrival.
    Evicted,
}

struct Waiter {
    tx: mpsc::Sender<Reply>,
    start: Instant,
    /// Predicted generation length at admission — compared against the
    /// completion's valid tokens by the socket-level mispredict gauge.
    predicted: u32,
}

/// Mutable edge state, one lock: admission math is microseconds per
/// request, far below the HTTP round-trip it sits inside.
struct Ctl {
    admission: AdmissionController,
    predictor: Option<GenLenPredictor>,
    /// `None` once shutdown closes the ingress — core sees Disconnected.
    jobs: Option<mpsc::Sender<EdgeJob>>,
    waiters: HashMap<u64, Waiter>,
    /// Queued-at-edge requests (id → what to forward when budget frees).
    queued: HashMap<u64, (RequestMeta, u32)>,
    next_id: u64,
}

struct Shared {
    ctl: Mutex<Ctl>,
    store: Arc<TraceStore>,
    g_max: u32,
    /// Confidence-aware admission knobs (ISSUE 9); inert when disabled.
    unc: UncertaintyConfig,
    started: Instant,
    offered: AtomicU64,
    completed: AtomicU64,
    shed: AtomicU64,
    expired: AtomicU64,
    core_shed: AtomicU64,
    bad_requests: AtomicU64,
    /// Admissions whose prediction confidence fell below the threshold
    /// (charged at the upper quantile) — 0 with uncertainty off.
    low_confidence_admissions: AtomicU64,
    /// Wall-clock latency of *completed* requests.
    latency: Mutex<Histogram>,
    /// |predicted − actual| bucket error of completed requests.
    mispredict: Mutex<MispredictGauge>,
}

impl Shared {
    fn now_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }
}

/// Final accounting for one edge run; built by [`EdgeServer::shutdown`].
#[derive(Debug)]
pub struct EdgeReport {
    pub offered: u64,
    pub completed: u64,
    pub shed: u64,
    pub expired: u64,
    pub core_shed: u64,
    pub bad_requests: u64,
    /// Upper-quantile-charged admissions — 0 with uncertainty off.
    pub low_confidence_admissions: u64,
    /// Wall latency of completed requests (edge clock).
    pub latency: Histogram,
    /// Socket-level mispredict gauge over completed requests.
    pub mispredict: MispredictGauge,
    /// The core's own run metrics (replayed clock).
    pub core: RunMetrics,
    pub http_accepted: u64,
    pub http_over_cap: u64,
    pub http_reaped: u64,
    pub elapsed_s: f64,
}

impl EdgeReport {
    /// The exactly-once identity the whole design exists to uphold.
    pub fn accounted(&self) -> bool {
        self.offered == self.completed + self.shed + self.expired + self.core_shed
    }

    /// Completions per wall second.
    pub fn goodput(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.completed as f64 / self.elapsed_s
        } else {
            0.0
        }
    }

    /// Fraction of offered requests refused (shed + expired + core-shed).
    pub fn shed_rate(&self) -> f64 {
        if self.offered > 0 {
            (self.offered - self.completed) as f64 / self.offered as f64
        } else {
            0.0
        }
    }
}

/// A running front door; [`EdgeServer::shutdown`] drains and reports.
pub struct EdgeServer {
    shared: Arc<Shared>,
    http: Option<HttpServer>,
    core: Option<std::thread::JoinHandle<Result<RunMetrics>>>,
    router: Option<std::thread::JoinHandle<()>>,
    drain_grace: Duration,
    addr: std::net::SocketAddr,
}

impl EdgeServer {
    /// Start core workers, the signal router, and the HTTP listener.
    /// Requests address trace entries by index (`POST /v1/generate`
    /// `{"index": i, "deadline_ms": d?}`), so the store is the shared
    /// corpus between load generator and server — no prompt bytes cross
    /// the admission path twice.
    pub fn start(
        cfg: &ServingConfig,
        opts: &EdgeOptions,
        policy: LivePolicy,
        predictor: Option<GenLenPredictor>,
        store: Arc<TraceStore>,
    ) -> Result<EdgeServer> {
        let (jobs_tx, jobs_rx) = mpsc::channel::<EdgeJob>();
        let (sig_tx, sig_rx) = mpsc::channel::<CoreSignal>();

        let shared = Arc::new(Shared {
            ctl: Mutex::new(Ctl {
                admission: AdmissionController::new(opts.admission.clone()),
                predictor,
                jobs: Some(jobs_tx),
                waiters: HashMap::new(),
                queued: HashMap::new(),
                next_id: 1,
            }),
            store: Arc::clone(&store),
            g_max: cfg.gpu.g_max,
            unc: cfg.uncertainty.clone(),
            started: Instant::now(),
            offered: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            expired: AtomicU64::new(0),
            core_shed: AtomicU64::new(0),
            bad_requests: AtomicU64::new(0),
            low_confidence_admissions: AtomicU64::new(0),
            latency: Mutex::new(Histogram::default()),
            mispredict: Mutex::new(MispredictGauge::default()),
        });

        let core = {
            let cfg = cfg.clone();
            let serve_opts = ServeOptions {
                n_workers: opts.n_workers,
                time_scale: opts.time_scale,
                fault_plan: opts.fault_plan.clone(),
                ..Default::default()
            };
            let store = Arc::clone(&store);
            std::thread::spawn(move || {
                serve_ingress_sim(&cfg, &serve_opts, policy, jobs_rx, sig_tx, store)
            })
        };

        let router = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || route_signals(sig_rx, &shared))
        };

        let handler = {
            let shared = Arc::clone(&shared);
            Arc::new(move |req: HttpRequest| handle(&shared, req))
        };
        let http = HttpServer::start(opts.http.clone(), handler)
            .map_err(|e| anyhow!("edge bind {}: {e}", opts.http.addr))?;
        let addr = http.addr();

        Ok(EdgeServer {
            shared,
            http: Some(http),
            core: Some(core),
            router: Some(router),
            drain_grace: opts.drain_grace,
            addr,
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Graceful drain: stop admitting (new offers shed `503`), let
    /// queued + in-core work finish within the grace window, expire the
    /// stragglers, close the ingress so the core returns, and collect
    /// both sides' accounting.
    pub fn shutdown(mut self) -> Result<EdgeReport> {
        self.shared.ctl.lock().unwrap().admission.begin_drain();

        let deadline = Instant::now() + self.drain_grace;
        loop {
            if self.shared.ctl.lock().unwrap().admission.is_idle() || Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }

        {
            // Past grace: whatever is still queued at the edge expires
            // now; in-core work is left for the core's own drain.
            let mut ctl = self.shared.ctl.lock().unwrap();
            let leftover: Vec<u64> = ctl.queued.keys().copied().collect();
            for id in leftover {
                ctl.queued.remove(&id);
                ctl.admission.complete(id); // no-op for queued ids; defensive
                if let Some(w) = ctl.waiters.remove(&id) {
                    self.shared.expired.fetch_add(1, Ordering::Relaxed);
                    let _ = w.tx.send(Reply::Expired);
                }
            }
            ctl.jobs = None; // core's ingress disconnects
        }

        let core = self
            .core
            .take()
            .expect("shutdown called once")
            .join()
            .map_err(|_| anyhow!("core serving thread panicked"))??;
        if let Some(r) = self.router.take() {
            let _ = r.join(); // exits on signal-channel disconnect
        }
        let http = self.http.take().expect("shutdown called once");
        let (http_accepted, http_over_cap, http_reaped) = {
            let s = http.stats();
            (
                s.accepted.load(Ordering::Relaxed),
                s.over_cap.load(Ordering::Relaxed),
                s.reaped.load(Ordering::Relaxed),
            )
        };
        http.shutdown();

        let sh = &self.shared;
        Ok(EdgeReport {
            offered: sh.offered.load(Ordering::Relaxed),
            completed: sh.completed.load(Ordering::Relaxed),
            shed: sh.shed.load(Ordering::Relaxed),
            expired: sh.expired.load(Ordering::Relaxed),
            core_shed: sh.core_shed.load(Ordering::Relaxed),
            bad_requests: sh.bad_requests.load(Ordering::Relaxed),
            low_confidence_admissions: sh.low_confidence_admissions.load(Ordering::Relaxed),
            latency: sh.latency.lock().unwrap().clone(),
            mispredict: sh.mispredict.lock().unwrap().clone(),
            core,
            http_accepted,
            http_over_cap,
            http_reaped,
            elapsed_s: sh.now_s(),
        })
    }
}

/// Resolve deadline-expired queued work and forward whatever now fits.
/// Runs under the ctl lock; called from the router on every signal and
/// on every idle tick.
fn pump_and_expire(ctl: &mut Ctl, shared: &Shared) {
    let now = shared.now_s();
    for id in ctl.admission.expire_due(now) {
        ctl.queued.remove(&id);
        if let Some(w) = ctl.waiters.remove(&id) {
            shared.expired.fetch_add(1, Ordering::Relaxed);
            let _ = w.tx.send(Reply::Expired);
        }
    }
    for id in ctl.admission.pump(now) {
        let Some((meta, predicted)) = ctl.queued.remove(&id) else { continue };
        let sent = match &ctl.jobs {
            Some(tx) => tx.send(EdgeJob { meta, predicted_gen_len: predicted }).is_ok(),
            None => false,
        };
        if !sent {
            ctl.admission.complete(id);
            if let Some(w) = ctl.waiters.remove(&id) {
                shared.core_shed.fetch_add(1, Ordering::Relaxed);
                let _ = w.tx.send(Reply::CoreShed);
            }
        }
    }
}

/// Router thread: every per-request outcome the core emits lands here
/// exactly once; the 25ms timeout doubles as the deadline/pump sweep.
/// Exits when the core returns (its signal sender drops).
fn route_signals(signals: mpsc::Receiver<CoreSignal>, shared: &Shared) {
    loop {
        match signals.recv_timeout(Duration::from_millis(25)) {
            Ok(CoreSignal::Completed { request_id, valid_tokens, invalid_tokens }) => {
                let mut ctl = shared.ctl.lock().unwrap();
                ctl.admission.complete(request_id);
                if let Some(w) = ctl.waiters.remove(&request_id) {
                    shared.completed.fetch_add(1, Ordering::Relaxed);
                    shared
                        .latency
                        .lock()
                        .unwrap()
                        .observe(w.start.elapsed().as_secs_f64());
                    // valid_tokens IS the actual generation length the
                    // core produced — the socket-level mispredict signal.
                    shared.mispredict.lock().unwrap().record(w.predicted, valid_tokens);
                    let _ = w.tx.send(Reply::Done { valid_tokens, invalid_tokens });
                }
                pump_and_expire(&mut ctl, shared);
            }
            Ok(CoreSignal::Shed { request_id }) => {
                let mut ctl = shared.ctl.lock().unwrap();
                ctl.admission.complete(request_id);
                if let Some(w) = ctl.waiters.remove(&request_id) {
                    shared.core_shed.fetch_add(1, Ordering::Relaxed);
                    let _ = w.tx.send(Reply::CoreShed);
                }
                pump_and_expire(&mut ctl, shared);
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                let mut ctl = shared.ctl.lock().unwrap();
                pump_and_expire(&mut ctl, shared);
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // Core returned (or died): nothing else will resolve the
                // outstanding waiters — fail them all, close ingress.
                let mut ctl = shared.ctl.lock().unwrap();
                ctl.jobs = None;
                ctl.queued.clear();
                for (_, w) in ctl.waiters.drain() {
                    shared.core_shed.fetch_add(1, Ordering::Relaxed);
                    let _ = w.tx.send(Reply::CoreShed);
                }
                return;
            }
        }
    }
}

/// How long a handler thread waits for its terminal [`Reply`].  Far
/// above any legitimate service time; the router's drain-on-disconnect
/// means this only fires if the router itself is gone.
const REPLY_CAP: Duration = Duration::from_secs(120);

fn handle(shared: &Shared, req: HttpRequest) -> HttpResponse {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            if shared.ctl.lock().unwrap().admission.is_draining() {
                HttpResponse::text(503, "draining")
            } else {
                HttpResponse::text(200, "ok")
            }
        }
        ("GET", "/metrics") => HttpResponse::text(200, &render_metrics(shared)),
        ("POST", "/v1/generate") => handle_generate(shared, &req),
        (_, "/v1/generate") | (_, "/metrics") | (_, "/healthz") => {
            HttpResponse::text(405, "method not allowed")
        }
        _ => HttpResponse::text(404, "unknown path"),
    }
}

fn handle_generate(shared: &Shared, req: &HttpRequest) -> HttpResponse {
    let bad = |msg: &str| {
        shared.bad_requests.fetch_add(1, Ordering::Relaxed);
        HttpResponse::text(400, msg)
    };
    let body = match std::str::from_utf8(&req.body) {
        Ok(s) => s,
        Err(_) => return bad("body not UTF-8"),
    };
    let j = match Json::parse(body) {
        Ok(j) => j,
        Err(_) => return bad("body not JSON"),
    };
    let Some(index) = j.get("index").as_usize() else {
        return bad("missing numeric 'index'");
    };
    if index >= shared.store.len() {
        return bad("'index' out of range for the loaded trace");
    }
    let deadline_s = j.get("deadline_ms").as_f64().map(|ms| ms / 1_000.0);

    let (rx, id) = {
        let mut ctl = shared.ctl.lock().unwrap();
        if ctl.jobs.is_none() {
            shared.offered.fetch_add(1, Ordering::Relaxed);
            shared.shed.fetch_add(1, Ordering::Relaxed);
            return shed_response(ShedReason::Draining);
        }
        let id = ctl.next_id;
        ctl.next_id += 1;
        // The meta is re-minted with an edge-unique id: many live
        // requests may replay the same trace entry, and core accounting
        // keys on id.
        let mut meta = shared.store.meta(index);
        meta.id = id;
        let predicted = match &mut ctl.predictor {
            Some(p) if shared.unc.enabled => {
                // Confidence-aware admission: charge uncertain requests
                // their upper-quantile predicted length so the memory
                // budget reserves room for the plausible worst case.
                let pwc = p.predict_with_confidence(
                    shared.store.view(index),
                    shared.unc.upper_quantile as f32,
                );
                if f64::from(pwc.confidence) < shared.unc.confidence_threshold {
                    shared.low_confidence_admissions.fetch_add(1, Ordering::Relaxed);
                }
                admission_charge(
                    pwc.point,
                    pwc.upper_quantile,
                    f64::from(pwc.confidence),
                    shared.unc.confidence_threshold,
                )
                .max(1)
            }
            Some(p) => p.predict(shared.store.view(index)).max(1),
            None => shared.g_max.max(1),
        };
        shared.offered.fetch_add(1, Ordering::Relaxed);
        let now = shared.now_s();
        let deadline = ctl.admission.resolve_deadline(deadline_s, now);
        match ctl.admission.offer(id, predicted, deadline, now) {
            Offer::Forward => {
                let (tx, rx) = mpsc::channel();
                ctl.waiters.insert(id, Waiter { tx, start: Instant::now(), predicted });
                let sent = match &ctl.jobs {
                    Some(jtx) => jtx.send(EdgeJob { meta, predicted_gen_len: predicted }).is_ok(),
                    None => false,
                };
                if !sent {
                    ctl.admission.complete(id);
                    ctl.waiters.remove(&id);
                    shared.core_shed.fetch_add(1, Ordering::Relaxed);
                    return HttpResponse::text(503, "serving core unavailable");
                }
                (rx, id)
            }
            Offer::Queued { evicted } => {
                if let Some(v) = evicted {
                    ctl.queued.remove(&v);
                    if let Some(w) = ctl.waiters.remove(&v) {
                        shared.shed.fetch_add(1, Ordering::Relaxed);
                        let _ = w.tx.send(Reply::Evicted);
                    }
                }
                let (tx, rx) = mpsc::channel();
                ctl.waiters.insert(id, Waiter { tx, start: Instant::now(), predicted });
                ctl.queued.insert(id, (meta, predicted));
                (rx, id)
            }
            Offer::Shed(reason) => {
                shared.shed.fetch_add(1, Ordering::Relaxed);
                return shed_response(reason);
            }
        }
    };

    match rx.recv_timeout(REPLY_CAP) {
        Ok(Reply::Done { valid_tokens, invalid_tokens }) => HttpResponse::json(
            200,
            Json::obj(vec![
                ("id", Json::num(id as f64)),
                ("index", Json::num(index as f64)),
                ("valid_tokens", Json::num(valid_tokens)),
                ("invalid_tokens", Json::num(invalid_tokens)),
            ])
            .to_string(),
        ),
        Ok(Reply::CoreShed) => HttpResponse::text(503, "overloaded: core shed request"),
        Ok(Reply::Expired) => HttpResponse::text(504, "deadline expired in admission queue"),
        Ok(Reply::Evicted) => {
            HttpResponse::text(429, "evicted from queue by shorter-predicted request")
        }
        Err(_) => {
            // Router gone or wedged — resolve ourselves, once.
            let mut ctl = shared.ctl.lock().unwrap();
            ctl.queued.remove(&id);
            ctl.admission.complete(id);
            if ctl.waiters.remove(&id).is_some() {
                shared.core_shed.fetch_add(1, Ordering::Relaxed);
            }
            HttpResponse::text(503, "edge reply timeout")
        }
    }
}

fn shed_response(reason: ShedReason) -> HttpResponse {
    match reason {
        ShedReason::QueueFull => HttpResponse::text(429, "admission queue full"),
        ShedReason::RateLimited => HttpResponse::text(429, "rate limited"),
        ShedReason::Evicted => HttpResponse::text(429, "evicted"),
        ShedReason::Draining => HttpResponse::text(503, "draining"),
    }
}

/// Prometheus-style exposition (gauges + counters + latency quantiles).
fn render_metrics(shared: &Shared) -> String {
    let (depth, in_core, in_core_tokens, draining) = {
        let ctl = shared.ctl.lock().unwrap();
        (
            ctl.admission.queue_depth(),
            ctl.admission.in_core_count(),
            ctl.admission.in_core_tokens(),
            ctl.admission.is_draining() as u32,
        )
    };
    let (p50, p99, n_lat) = {
        let h = shared.latency.lock().unwrap();
        (h.quantile(50.0), h.quantile(99.0), h.total())
    };
    let elapsed = shared.now_s();
    let completed = shared.completed.load(Ordering::Relaxed);
    let goodput = if elapsed > 0.0 { completed as f64 / elapsed } else { 0.0 };
    let mut out = String::with_capacity(640);
    let mut line = |k: &str, v: String| {
        out.push_str("magnus_edge_");
        out.push_str(k);
        out.push(' ');
        out.push_str(&v);
        out.push('\n');
    };
    line("offered_total", shared.offered.load(Ordering::Relaxed).to_string());
    line("completed_total", completed.to_string());
    line("shed_total", shared.shed.load(Ordering::Relaxed).to_string());
    line("expired_total", shared.expired.load(Ordering::Relaxed).to_string());
    line("core_shed_total", shared.core_shed.load(Ordering::Relaxed).to_string());
    line("bad_requests_total", shared.bad_requests.load(Ordering::Relaxed).to_string());
    line(
        "low_confidence_admissions_total",
        shared.low_confidence_admissions.load(Ordering::Relaxed).to_string(),
    );
    line("queue_depth", depth.to_string());
    line("in_core_requests", in_core.to_string());
    line("in_core_predicted_tokens", in_core_tokens.to_string());
    line("draining", draining.to_string());
    line("latency_observations", n_lat.to_string());
    line("latency_p50_seconds", format!("{p50:.6}"));
    line("latency_p99_seconds", format!("{p99:.6}"));
    line("goodput_rps", format!("{goodput:.3}"));
    line("uptime_seconds", format!("{elapsed:.3}"));
    let gauge = shared.mispredict.lock().unwrap().clone();
    line("predictions_total", gauge.predictions.to_string());
    line("mispredict_total", gauge.mispredicted.to_string());
    line("mispredict_rate", format!("{:.6}", gauge.rate()));
    for (d, count) in gauge.bins.iter().enumerate() {
        line(&format!("mispredict_bucket_error_{d}_total"), count.to_string());
    }
    out
}
