//! Overload-tolerant network edge (ISSUE 7 tentpole): an HTTP front
//! door that uses the paper's generation-length *prediction* — available
//! before a request has cost anything — for admission control, not just
//! batching.
//!
//! Three pieces:
//!
//! * [`admission`] — pure, clock-free [`AdmissionController`]: a memory
//!   budget over the sum of predicted lengths in core, a bounded queue
//!   with per-request deadlines, a rate token bucket, and full-queue
//!   eviction that sacrifices the longest-predicted request first.
//! * [`server`] — [`EdgeServer`]: HTTP handlers over
//!   [`crate::http::HttpServer`], wired to the supervised core through
//!   [`crate::server::serve_ingress_sim`]; a router thread resolves each
//!   waiting handler from the core's per-request signals and sweeps
//!   deadlines.  `/v1/generate`, `/metrics`, `/healthz`.
//! * [`loadgen`] — open-loop Poisson/bursty load generator with
//!   client-side fault injection, for driving a live edge well past
//!   capacity.
//!
//! The robustness contract, asserted end to end by `tests/edge.rs` and
//! `benches/bench_edge.rs`: under any overload the edge degrades by
//! *explicit* refusal (`429`/`503`/`504`), memory stays bounded by the
//! admission budget, and `offered == completed + shed + expired +
//! core_shed` — nothing hangs, nothing is silently lost.

pub mod admission;
pub mod loadgen;
pub mod server;

pub use admission::{AdmissionConfig, AdmissionController, Offer, ShedReason};
pub use loadgen::{run_loadgen, LoadGenConfig, LoadReport};
pub use server::{EdgeOptions, EdgeReport, EdgeServer};
