//! Predicted-length admission control (ISSUE 7 tentpole).
//!
//! The controller is the paper's thesis applied one layer earlier than
//! batching: the generation-length prediction is available *before* a
//! request costs anything, so the front door can ration memory and queue
//! space by predicted cost instead of request count.  It is pure and
//! clock-free — every method takes `now` (seconds on the caller's clock)
//! — so the unit tests and the golden gates drive it deterministically,
//! and the same code runs under the HTTP edge's wall clock.
//!
//! Decisions, in order, for each offered request:
//!
//! 1. **drain** — a draining edge sheds everything new (`503`);
//! 2. **rate** — a token bucket at `rps_limit` (∞ disables, `0.0` sheds
//!    every request, explicitly — the degenerate case the tests pin);
//! 3. **memory** — admit to core while the sum of *predicted* lengths of
//!    in-core requests stays within `token_budget` (one oversize request
//!    is always admitted when the core is empty, so a request predicted
//!    longer than the whole budget degrades to serial service instead of
//!    deadlocking);
//! 4. **queue** — otherwise a bounded queue holds the request until
//!    budget frees; a full queue prefers short work: the incoming
//!    request *evicts* the longest-predicted queued request if it is
//!    strictly shorter, else it is shed (`429`).  Shedding the long job
//!    forfeits the fewest completions per unit of memory — the same
//!    greedy argument as the batcher's WMA ordering.
//!
//! Queued requests carry a deadline; [`AdmissionController::expire_due`]
//! removes past-due *queued* work (in-core work is never revoked — the
//! tokens are already spent, finishing is strictly better than wasting
//! them).  [`AdmissionController::pump`] scans the whole queue, not just
//! the head, so short requests slip past a long head that does not fit
//! yet.

use std::collections::HashMap;
use std::collections::VecDeque;

/// Edge admission tunables.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Bounded admission-queue capacity (requests).
    pub queue_cap: usize,
    /// Memory budget: max sum of predicted generation lengths in core.
    pub token_budget: u64,
    /// Arrival-rate cap (token bucket). `f64::INFINITY` disables;
    /// `0.0` sheds every request.
    pub rps_limit: f64,
    /// Deadline applied when the client does not send one (seconds).
    pub default_deadline_s: f64,
    /// Ceiling on client-requested deadlines (seconds).
    pub max_deadline_s: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            queue_cap: 64,
            token_budget: 4096,
            rps_limit: f64::INFINITY,
            default_deadline_s: 30.0,
            max_deadline_s: 120.0,
        }
    }
}

/// Why a request was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// Queue full and the incoming request was not shorter than every
    /// queued one.
    QueueFull,
    /// Token bucket empty (or `rps_limit == 0`).
    RateLimited,
    /// Edge is draining for shutdown.
    Draining,
    /// Was queued, then displaced by a shorter-predicted arrival.
    Evicted,
}

/// Admission decision for one offered request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Offer {
    /// Admit to core now.
    Forward,
    /// Held in the bounded queue; `evicted` names a previously queued
    /// request displaced to make room (resolve it as shed).
    Queued { evicted: Option<u64> },
    Shed(ShedReason),
}

#[derive(Debug, Clone, Copy)]
struct QueuedReq {
    id: u64,
    predicted: u64,
    deadline: f64,
}

/// See the module docs for the decision procedure.
#[derive(Debug)]
pub struct AdmissionController {
    cfg: AdmissionConfig,
    /// id → predicted tokens, for everything admitted and not complete.
    in_core: HashMap<u64, u64>,
    in_core_tokens: u64,
    queue: VecDeque<QueuedReq>,
    /// Token bucket for the rate limit.
    bucket: f64,
    bucket_at: f64,
    draining: bool,
}

impl AdmissionController {
    pub fn new(cfg: AdmissionConfig) -> AdmissionController {
        let burst = if cfg.rps_limit.is_finite() { cfg.rps_limit.max(1.0) } else { 0.0 };
        AdmissionController {
            cfg,
            in_core: HashMap::new(),
            in_core_tokens: 0,
            queue: VecDeque::new(),
            bucket: burst, // start full: the first second of traffic is not penalised
            bucket_at: 0.0,
            draining: false,
        }
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    pub fn in_core_count(&self) -> usize {
        self.in_core.len()
    }

    pub fn in_core_tokens(&self) -> u64 {
        self.in_core_tokens
    }

    /// Nothing queued and nothing in core.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.in_core.is_empty()
    }

    pub fn is_draining(&self) -> bool {
        self.draining
    }

    /// Stop admitting: every subsequent offer sheds with
    /// [`ShedReason::Draining`]; queued and in-core work is unaffected.
    pub fn begin_drain(&mut self) {
        self.draining = true;
    }

    /// Would admitting `predicted` more tokens stay within budget?  An
    /// empty core always fits (anti-deadlock: see module docs).
    fn fits(&self, predicted: u64) -> bool {
        self.in_core.is_empty() || self.in_core_tokens.saturating_add(predicted) <= self.cfg.token_budget
    }

    /// Refill-then-take on the rate bucket. Returns false when the
    /// request must be rate-shed.
    fn take_rate_token(&mut self, now: f64) -> bool {
        if self.cfg.rps_limit.is_infinite() {
            return true;
        }
        if self.cfg.rps_limit <= 0.0 {
            return false;
        }
        let burst = self.cfg.rps_limit.max(1.0);
        let dt = (now - self.bucket_at).max(0.0);
        self.bucket = (self.bucket + dt * self.cfg.rps_limit).min(burst);
        self.bucket_at = now;
        if self.bucket >= 1.0 {
            self.bucket -= 1.0;
            true
        } else {
            false
        }
    }

    /// Clamp a client deadline request into `(0, max_deadline_s]`,
    /// falling back to the default for absent/NaN/non-positive input.
    pub fn resolve_deadline(&self, requested_s: Option<f64>, now: f64) -> f64 {
        let d = match requested_s {
            Some(d) if d.is_finite() && d > 0.0 => d.min(self.cfg.max_deadline_s),
            _ => self.cfg.default_deadline_s,
        };
        now + d
    }

    /// Admission decision for request `id` with predicted generation
    /// length `predicted` and absolute deadline `deadline` (same clock
    /// as `now`).  On `Offer::Forward` the controller has already moved
    /// the request in-core; the caller must actually dispatch it.
    pub fn offer(&mut self, id: u64, predicted: u32, deadline: f64, now: f64) -> Offer {
        if self.draining {
            return Offer::Shed(ShedReason::Draining);
        }
        if !self.take_rate_token(now) {
            return Offer::Shed(ShedReason::RateLimited);
        }
        let p = u64::from(predicted.max(1));
        // Budget admission only when nothing is queued ahead — otherwise
        // a short arrival would jump every queued request, starving them.
        if self.queue.is_empty() && self.fits(p) {
            self.in_core.insert(id, p);
            self.in_core_tokens += p;
            return Offer::Forward;
        }
        if self.queue.len() < self.cfg.queue_cap {
            self.queue.push_back(QueuedReq { id, predicted: p, deadline });
            return Offer::Queued { evicted: None };
        }
        // Full queue: drop the most expensive queued prediction if the
        // newcomer is strictly cheaper, else refuse the newcomer.
        let victim = self
            .queue
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.predicted.cmp(&b.1.predicted).then(a.0.cmp(&b.0)))
            .map(|(i, q)| (i, q.predicted));
        match victim {
            Some((i, vp)) if p < vp => {
                let evicted = self.queue.remove(i).map(|q| q.id);
                self.queue.push_back(QueuedReq { id, predicted: p, deadline });
                Offer::Queued { evicted }
            }
            _ => Offer::Shed(ShedReason::QueueFull),
        }
    }

    /// Admit queued work that now fits, scanning the whole queue so a
    /// short request bypasses a long head that is still blocked.
    /// Returns the ids admitted, in admission order; the caller
    /// dispatches them.
    pub fn pump(&mut self, _now: f64) -> Vec<u64> {
        let mut admitted = Vec::new();
        let mut i = 0;
        while i < self.queue.len() {
            let q = self.queue[i];
            if self.fits(q.predicted) {
                self.queue.remove(i);
                self.in_core.insert(q.id, q.predicted);
                self.in_core_tokens += q.predicted;
                admitted.push(q.id);
            } else {
                i += 1;
            }
        }
        admitted
    }

    /// Remove queued requests whose deadline has passed; in-core work is
    /// never expired.  Returns the expired ids.
    pub fn expire_due(&mut self, now: f64) -> Vec<u64> {
        let mut expired = Vec::new();
        self.queue.retain(|q| {
            if q.deadline <= now {
                expired.push(q.id);
                false
            } else {
                true
            }
        });
        expired
    }

    /// The core finished (or shed) request `id`: release its tokens.
    pub fn complete(&mut self, id: u64) {
        if let Some(p) = self.in_core.remove(&id) {
            self.in_core_tokens -= p;
        }
    }
}

/// Confidence-aware memory charge for one admission (ISSUE 9): below the
/// confidence `threshold` the request is charged its `upper`-quantile
/// predicted length instead of the `point` estimate, so an uncertain
/// prediction reserves budget for its plausible worst case.  Pure —
/// charging is a property of the prediction, not of controller state —
/// and monotone: the charge is never below the point estimate.
pub fn admission_charge(point: u32, upper: u32, confidence: f64, threshold: f64) -> u32 {
    if confidence < threshold {
        point.max(upper)
    } else {
        point
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl(queue_cap: usize, token_budget: u64, rps_limit: f64) -> AdmissionController {
        AdmissionController::new(AdmissionConfig {
            queue_cap,
            token_budget,
            rps_limit,
            default_deadline_s: 10.0,
            max_deadline_s: 60.0,
        })
    }

    /// ISSUE 7 satellite: a zero RPS limit must shed every request,
    /// explicitly and with the rate reason — never hang, never admit.
    #[test]
    fn zero_rps_limit_sheds_everything() {
        let mut c = ctl(8, 1_000, 0.0);
        for i in 0..100u64 {
            let dl = c.resolve_deadline(None, i as f64);
            assert_eq!(
                c.offer(i, 10, dl, i as f64),
                Offer::Shed(ShedReason::RateLimited),
                "request {i}"
            );
        }
        assert!(c.is_idle());
        assert_eq!(c.in_core_tokens(), 0);
    }

    /// No overload → pure pass-through: with generous budgets every
    /// offer forwards immediately, in order, whatever the workload.
    #[test]
    fn no_overload_is_pass_through() {
        crate::util::prop::prop_check(60, |rng| {
            let n = rng.range_usize(1, 40);
            let mut c = ctl(n, u64::MAX, f64::INFINITY);
            let mut now = 0.0;
            for i in 0..n as u64 {
                now += rng.f64();
                let p = rng.range_u64(1, 5_000) as u32;
                let dl = c.resolve_deadline(Some(rng.f64() * 100.0), now);
                assert_eq!(c.offer(i, p, dl, now), Offer::Forward);
            }
            assert_eq!(c.in_core_count(), n);
            assert_eq!(c.queue_depth(), 0);
        });
    }

    #[test]
    fn budget_queues_then_pump_admits_after_complete() {
        let mut c = ctl(8, 100, f64::INFINITY);
        assert_eq!(c.offer(1, 60, 10.0, 0.0), Offer::Forward);
        assert_eq!(c.offer(2, 60, 10.0, 0.0), Offer::Queued { evicted: None });
        // A short request also queues — no jumping ahead of request 2...
        assert_eq!(c.offer(3, 10, 10.0, 0.0), Offer::Queued { evicted: None });
        assert_eq!(c.pump(0.0), vec![3u64], "...but pump admits what fits");
        c.complete(1);
        c.complete(3);
        assert_eq!(c.pump(0.1), vec![2u64]);
        assert_eq!(c.in_core_tokens(), 60);
        c.complete(2);
        assert!(c.is_idle());
    }

    #[test]
    fn full_queue_evicts_longest_prediction_for_shorter_arrival() {
        let mut c = ctl(2, 10, f64::INFINITY);
        assert_eq!(c.offer(1, 10, 9.0, 0.0), Offer::Forward); // fills the budget
        assert_eq!(c.offer(2, 500, 9.0, 0.0), Offer::Queued { evicted: None });
        assert_eq!(c.offer(3, 80, 9.0, 0.0), Offer::Queued { evicted: None });
        // Queue full; the longest-predicted (id 2) is displaced.
        assert_eq!(c.offer(4, 40, 9.0, 0.0), Offer::Queued { evicted: Some(2) });
        // A longer-than-everyone arrival is the one shed instead.
        assert_eq!(c.offer(5, 900, 9.0, 0.0), Offer::Shed(ShedReason::QueueFull));
        assert_eq!(c.queue_depth(), 2);
    }

    #[test]
    fn oversize_request_admits_on_empty_core_not_deadlock() {
        let mut c = ctl(4, 100, f64::INFINITY);
        // Predicted longer than the entire budget: admitted anyway when
        // the core is empty (serial degradation, not a wedge).
        assert_eq!(c.offer(1, 10_000, 5.0, 0.0), Offer::Forward);
        assert_eq!(c.offer(2, 1, 5.0, 0.0), Offer::Queued { evicted: None });
        assert_eq!(c.pump(0.0), Vec::<u64>::new());
        c.complete(1);
        assert_eq!(c.pump(0.1), vec![2u64]);
    }

    #[test]
    fn deadlines_expire_queued_but_never_in_core() {
        let mut c = ctl(8, 50, f64::INFINITY);
        assert_eq!(c.offer(1, 50, 100.0, 0.0), Offer::Forward);
        assert_eq!(c.offer(2, 50, 1.0, 0.0), Offer::Queued { evicted: None });
        assert_eq!(c.offer(3, 50, 3.0, 0.0), Offer::Queued { evicted: None });
        assert_eq!(c.expire_due(2.0), vec![2u64]);
        assert_eq!(c.expire_due(2.0), Vec::<u64>::new(), "expiry is idempotent");
        // In-core id 1 is past any deadline but is never revoked.
        assert_eq!(c.expire_due(1_000.0), vec![3u64]);
        assert_eq!(c.in_core_count(), 1);
    }

    #[test]
    fn rate_bucket_enforces_rps_and_refills() {
        let mut c = ctl(0, u64::MAX, 2.0);
        // Burst capacity is max(rps, 1) = 2: two immediate admits, then shed.
        assert_eq!(c.offer(1, 1, 9.0, 0.0), Offer::Forward);
        assert_eq!(c.offer(2, 1, 9.0, 0.0), Offer::Forward);
        assert_eq!(c.offer(3, 1, 9.0, 0.0), Offer::Shed(ShedReason::RateLimited));
        // Half a second refills one token at 2 rps.
        assert_eq!(c.offer(4, 1, 9.0, 0.5), Offer::Forward);
        assert_eq!(c.offer(5, 1, 9.0, 0.5), Offer::Shed(ShedReason::RateLimited));
    }

    #[test]
    fn drain_sheds_new_work_only() {
        let mut c = ctl(8, 10, f64::INFINITY);
        assert_eq!(c.offer(1, 10, 9.0, 0.0), Offer::Forward);
        assert_eq!(c.offer(2, 10, 9.0, 0.0), Offer::Queued { evicted: None });
        c.begin_drain();
        assert_eq!(c.offer(3, 1, 9.0, 0.0), Offer::Shed(ShedReason::Draining));
        c.complete(1);
        assert_eq!(c.pump(0.0), vec![2u64], "queued work still drains to core");
    }

    #[test]
    fn admission_charge_is_confidence_gated_and_monotone() {
        // Confident: the point estimate is the charge.
        assert_eq!(admission_charge(100, 400, 0.9, 0.55), 100);
        // Uncertain: charged the upper quantile.
        assert_eq!(admission_charge(100, 400, 0.3, 0.55), 400);
        // Equality is "confident enough" (strict less-than gates).
        assert_eq!(admission_charge(100, 400, 0.55, 0.55), 100);
        // Never below the point, even if the bound is degenerate.
        assert_eq!(admission_charge(100, 50, 0.0, 0.55), 100);
        // Threshold 0.0 disables the mechanism entirely.
        assert_eq!(admission_charge(100, 400, 0.0, 0.0), 100);
    }

    #[test]
    fn resolve_deadline_clamps_and_defaults() {
        let c = ctl(1, 1, f64::INFINITY);
        assert_eq!(c.resolve_deadline(None, 5.0), 15.0);
        assert_eq!(c.resolve_deadline(Some(f64::NAN), 5.0), 15.0);
        assert_eq!(c.resolve_deadline(Some(-3.0), 5.0), 15.0);
        assert_eq!(c.resolve_deadline(Some(2.0), 5.0), 7.0);
        assert_eq!(c.resolve_deadline(Some(1e9), 5.0), 65.0);
    }
}
