//! The wasted-memory-access (WMA) metric — Eq. (2), (3), (4) — and the
//! memory model MEM(B) — Eq. (5) — of paper §III-C.
//!
//! WMA counts the number of times a token's key/value tensors are read
//! from the KV cache without contributing to the final response:
//!
//! * `WMA_gen(p)`  = G(p) · (L(B) − L(p)) — pad-token reads while p is
//!   still generating (Eq. 2);
//! * `WMA_wait(p)` = Σ_{g=G(p)}^{G(B)} (g + L(B)) — reads of the whole
//!   (padded request + generated) context during p's waiting phase
//!   (Eq. 3, inclusive bounds as printed);
//! * `WMA(B)`      = max_p (WMA_gen(p) + WMA_wait(p)) (Eq. 4).
//!
//! The batcher evaluates these with *predicted* generation lengths.

use crate::batch::types::Batch;
use crate::workload::PredictedRequest;

/// Eq. (2): pad-token waste of a request inside a batch of length
/// `batch_len`, using generation length `g` for the request.
#[inline]
pub fn wma_gen(req_len: u32, g: u32, batch_len: u32) -> u64 {
    g as u64 * (batch_len - req_len) as u64
}

/// Eq. (3): waiting-phase waste with inclusive bounds g = G(p) ..= G(B).
#[inline]
pub fn wma_wait(g_p: u32, g_batch: u32, batch_len: u32) -> u64 {
    if g_p > g_batch {
        return 0;
    }
    let a = g_p as u64;
    let b = g_batch as u64;
    let n = b - a + 1;
    // Σ_{g=a}^{b} (g + L) = n·L + (a+b)·n/2
    n * batch_len as u64 + (a + b) * n / 2
}

/// Eq. (4) over a hypothetical request set, with a closed form that avoids
/// materialising the batch: the max over requests of
/// `wma_gen + wma_wait`.
pub fn wma_of<'a, I>(requests: I, batch_len: u32, batch_gen: u32) -> u64
where
    I: IntoIterator<Item = &'a PredictedRequest>,
{
    requests
        .into_iter()
        .map(|p| {
            wma_gen(p.len(), p.predicted_gen_len, batch_len)
                + wma_wait(p.predicted_gen_len, batch_gen, batch_len)
        })
        .max()
        .unwrap_or(0)
}

/// Eq. (4) for a queued batch (predicted lengths).
pub fn wma_batch(b: &Batch) -> u64 {
    wma_of(&b.requests, b.len(), b.predicted_gen_len())
}

/// WMA of `batch ∪ {candidate}` WITHOUT copying the batch — the batcher's
/// inner loop (Algorithm 1 line 4-5).
pub fn wma_with(b: &Batch, candidate: &PredictedRequest) -> u64 {
    let new_len = b.len().max(candidate.len());
    let new_gen = b.predicted_gen_len().max(candidate.predicted_gen_len);
    let existing = wma_of(&b.requests, new_len, new_gen);
    let cand = wma_gen(candidate.len(), candidate.predicted_gen_len, new_len)
        + wma_wait(candidate.predicted_gen_len, new_gen, new_len);
    existing.max(cand)
}

/// Eq. (5): KV-cache bytes of a batch with `beta` requests, padded length
/// `batch_len`, generation length `batch_gen`, and per-token KV size
/// `delta` bytes.
#[inline]
pub fn mem_bytes(beta: u32, batch_len: u32, batch_gen: u32, delta: u64) -> u64 {
    beta as u64 * (batch_len as u64 + batch_gen as u64) * delta
}

/// MEM(B ∪ {candidate}) with predicted lengths.
pub fn mem_with(b: &Batch, candidate: &PredictedRequest, delta: u64) -> u64 {
    let new_len = b.len().max(candidate.len());
    let new_gen = b.predicted_gen_len().max(candidate.predicted_gen_len);
    mem_bytes(b.size() + 1, new_len, new_gen, delta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch::types::Batch;
    use crate::util::prop::prop_check;
    use crate::workload::{PredictedRequest, RequestMeta, Span, StoreId, TaskId};

    fn req(len: u32, pred: u32) -> PredictedRequest {
        PredictedRequest {
            meta: RequestMeta {
                id: 0,
                task: TaskId::Gc,
                store: StoreId::DETACHED,
                instr: u32::MAX,
                user_input_len: len,
                request_len: len,
                gen_len: pred,
                arrival: 0.0,
                span: Span::DETACHED,
                uih: 0,
            },
            predicted_gen_len: pred,
        }
    }

    #[test]
    fn wma_gen_eq2() {
        // G(p)=10, L(B)=50, L(p)=30 → 10·20 = 200
        assert_eq!(wma_gen(30, 10, 50), 200);
        // no padding → zero
        assert_eq!(wma_gen(50, 10, 50), 0);
    }

    #[test]
    fn wma_wait_eq3_closed_form_matches_loop() {
        for (gp, gb, l) in [(3u32, 10u32, 7u32), (1, 1, 5), (10, 10, 0), (0, 4, 2)] {
            let loop_sum: u64 =
                (gp..=gb).map(|g| g as u64 + l as u64).sum();
            assert_eq!(wma_wait(gp, gb, l), loop_sum, "gp={gp} gb={gb} l={l}");
        }
    }

    #[test]
    fn homogeneous_batch_has_minimal_wma() {
        // Identical requests: no padding; only the Eq.3 self-term remains.
        let b = {
            let mut b = Batch::new(0, req(20, 10), 0.0);
            b.requests.push(req(20, 10));
            b
        };
        let homo = wma_batch(&b);
        let hetero = {
            let mut b2 = Batch::new(1, req(20, 10), 0.0);
            b2.requests.push(req(5, 100));
            wma_batch(&b2)
        };
        assert!(homo < hetero);
    }

    #[test]
    fn wma_with_equals_materialised_union() {
        prop_check(300, |rng| {
            let mut b = Batch::new(0, req(
                rng.range_u64(1, 200) as u32,
                rng.range_u64(1, 200) as u32,
            ), 0.0);
            for _ in 0..rng.range_usize(0, 6) {
                b.requests.push(req(
                    rng.range_u64(1, 200) as u32,
                    rng.range_u64(1, 200) as u32,
                ));
            }
            let cand = req(
                rng.range_u64(1, 200) as u32,
                rng.range_u64(1, 200) as u32,
            );
            let fast = wma_with(&b, &cand);
            let mut union = b.clone();
            union.requests.push(cand);
            assert_eq!(fast, wma_batch(&union));
        });
    }

    #[test]
    fn mem_eq5() {
        // β=3, L=100, G=200, Δ=458752 → 3·300·458752
        assert_eq!(mem_bytes(3, 100, 200, 458_752), 3 * 300 * 458_752);
    }

    #[test]
    fn mem_with_matches_union() {
        prop_check(200, |rng| {
            let mut b = Batch::new(0, req(
                rng.range_u64(1, 500) as u32,
                rng.range_u64(1, 500) as u32,
            ), 0.0);
            for _ in 0..rng.range_usize(0, 5) {
                b.requests.push(req(
                    rng.range_u64(1, 500) as u32,
                    rng.range_u64(1, 500) as u32,
                ));
            }
            let cand = req(rng.range_u64(1, 500) as u32, rng.range_u64(1, 500) as u32);
            let delta = 1000;
            let fast = mem_with(&b, &cand, delta);
            let mut union = b.clone();
            union.requests.push(cand);
            assert_eq!(
                fast,
                mem_bytes(union.size(), union.len(), union.predicted_gen_len(), delta)
            );
        });
    }

    #[test]
    fn wma_monotone_in_batch_gen_spread() {
        // Increasing the batch gen length (longer-running batch-mate)
        // strictly increases a short request's waiting waste.
        let short = req(10, 5);
        let w1 = wma_gen(10, 5, 10) + wma_wait(5, 20, 10);
        let w2 = wma_gen(10, 5, 10) + wma_wait(5, 200, 10);
        assert!(w2 > w1);
        let _ = short;
    }
}
