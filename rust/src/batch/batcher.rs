//! The WMA-directed adaptive batcher — Algorithm 1 of the paper.
//!
//! On request arrival: scan the waiting queue for the insertable batch
//! whose WMA after insertion is minimal, subject to the memory bound
//! MEM(B∪{p}) ≤ Θ (and, for the GLP ablation, a fixed batch-size cap).
//! Insert if the minimum is below the threshold Φ; otherwise open a new
//! batch.  Batches with similar lengths and predicted generation lengths
//! therefore coalesce, and batch sizes adapt to the memory budget —
//! small/short batches grow large, long batches stay small.

use crate::batch::types::Batch;
use crate::batch::wma::{mem_bytes, wma_gen, wma_wait};
use crate::estimator::BatchShape;
use crate::workload::PredictedRequest;

/// O(1) WMA/memory aggregate for one queued batch.
///
/// Algorithm 1 evaluates WMA(B ∪ {p}) for every queued batch on every
/// insertion; done naively that is O(Σ batch sizes) per request.  But the
/// per-request WMA term decomposes: for a batch evaluated at union shape
/// (L, G) with G ≥ G'(p) for every member,
///
///   wma_gen(p) + wma_wait(p)
///     = G'(p)·(L − L(p)) + Σ_{g=G'(p)}^{G} (g + L)
///     = L·(G+1) + (G² + G)/2  +  [ (G'(p) − G'(p)²)/2 − G'(p)·L(p) ]
///       └──── shape-only, common to all p ────┘   └── request-only s_p ──┘
///
/// so  max_p (…) = L·(G+1) + (G²+G)/2 + max_p s_p,  and `max_s` is an
/// exactly-maintainable scalar (monotone max under insertion).  Batch
/// length, predicted generation length and size are cached alongside,
/// making the whole Algorithm-1 inner loop O(1) per queued batch.
#[derive(Debug, Clone, Copy)]
struct BatchAgg {
    len: u32,
    gen: u32,
    size: u32,
    max_s: i64,
    /// Earliest request arrival — T_q(B) = now − this, maintained so the
    /// dispatch loop never rescans batch members (monotone min under
    /// insertion).
    min_arrival: f64,
}

/// Cached serving-time estimate for one queued batch.
///
/// The estimate is a pure function of (batch shape, estimator state), so
/// it stays valid until the batch mutates (an insert joins it — the cache
/// entry is reset) or the estimator refits (detected by comparing the
/// estimator's generation counter).  `gen == u64::MAX` marks "no value".
#[derive(Debug, Clone, Copy)]
struct EstCache {
    gen: u64,
    value: f64,
}

impl EstCache {
    const EMPTY: EstCache = EstCache {
        gen: u64::MAX,
        value: 0.0,
    };
}

/// s_p of the decomposition above.
#[inline]
fn s_term(len: u32, gen: u32) -> i64 {
    let g = gen as i64;
    let l = len as i64;
    (g - g * g) / 2 - g * l
}

/// Shape-only part of the decomposition: L·(G+1) + (G²+G)/2.
#[inline]
fn shape_term(len: u32, gen: u32) -> i64 {
    let g = gen as i64;
    let l = len as i64;
    l * (g + 1) + (g * g + g) / 2
}

/// Batcher configuration distilled from `ServingConfig`.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Φ — WMA threshold of Algorithm 1.
    pub wma_threshold: f64,
    /// Θ — KV-cache memory budget in bytes.
    pub theta: u64,
    /// Δ — KV bytes per token.
    pub delta: u64,
    /// Max requests per batch (0 = unbounded). GLP ablation sets this to
    /// the vanilla batch size; full Magnus leaves it at 0.
    pub max_batch_size: u32,
}

/// The adaptive batcher: owns the waiting queue of open batches.
pub struct AdaptiveBatcher {
    cfg: BatcherConfig,
    queue: Vec<Batch>,
    next_batch_id: u64,
    /// O(1) per-batch aggregates, index-parallel to `queue` (a HashMap
    /// here costs a lookup per scanned batch — measured 3× slower).
    aggs: Vec<BatchAgg>,
    /// Serving-time estimate cache, index-parallel to `queue`.
    ests: Vec<EstCache>,
}

impl AdaptiveBatcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        AdaptiveBatcher {
            cfg,
            queue: Vec::new(),
            next_batch_id: 0,
            aggs: Vec::new(),
            ests: Vec::new(),
        }
    }

    /// Algorithm 1: insert `p` into the min-WMA feasible batch, or open a
    /// new batch.  Returns the id of the batch that received the request.
    ///
    /// The scan is O(1) per queued batch via the `BatchAgg` decomposition
    /// (see above) — measured ~40× faster than the naive O(Σβ) evaluation
    /// at serving-queue depths (EXPERIMENTS.md §Perf).
    pub fn insert(&mut self, p: PredictedRequest, now: f64) -> u64 {
        let mut phi = i64::MAX;
        let mut best: Option<usize> = None;
        let mut best_id = u64::MAX;
        let cand_s = s_term(p.len(), p.predicted_gen_len);

        for (i, b) in self.queue.iter().enumerate() {
            if !b.insertable {
                continue;
            }
            let agg = self.aggs[i];
            if self.cfg.max_batch_size > 0 && agg.size >= self.cfg.max_batch_size {
                continue;
            }
            let new_len = agg.len.max(p.len());
            let new_gen = agg.gen.max(p.predicted_gen_len);
            // Memory bound: MEM(B') ≤ Θ (Algorithm 1 line 5).
            if mem_bytes(agg.size + 1, new_len, new_gen, self.cfg.delta)
                > self.cfg.theta
            {
                continue;
            }
            // Equal-WMA ties break by batch id so the choice does not
            // depend on queue order (`take` swap-removes).
            let w = shape_term(new_len, new_gen) + agg.max_s.max(cand_s);
            if w < phi || (w == phi && b.id < best_id) {
                phi = w;
                best = Some(i);
                best_id = b.id;
            }
        }

        match best {
            Some(i) if (phi as f64) < self.cfg.wma_threshold => {
                let agg = &mut self.aggs[i];
                agg.len = agg.len.max(p.len());
                agg.gen = agg.gen.max(p.predicted_gen_len);
                agg.size += 1;
                agg.max_s = agg.max_s.max(cand_s);
                agg.min_arrival = agg.min_arrival.min(p.request.arrival);
                self.ests[i] = EstCache::EMPTY; // shape changed
                self.queue[i].requests.push(p);
                self.queue[i].id
            }
            _ => {
                let id = self.next_batch_id;
                self.next_batch_id += 1;
                self.aggs.push(BatchAgg {
                    len: p.len(),
                    gen: p.predicted_gen_len,
                    size: 1,
                    max_s: cand_s,
                    min_arrival: p.request.arrival,
                });
                self.ests.push(EstCache::EMPTY);
                self.queue.push(Batch::new(id, p, now));
                id
            }
        }
    }

    /// Remove and return the batch at `index` (scheduler hand-off).
    ///
    /// O(1) swap-removal: the last queued batch moves into `index`, and
    /// the index-parallel aggregate/cache vectors move with it.  Queue
    /// order is therefore NOT stable — all selection logic tie-breaks on
    /// batch id, never on position.
    pub fn take(&mut self, index: usize) -> Batch {
        self.aggs.swap_remove(index);
        self.ests.swap_remove(index);
        self.queue.swap_remove(index)
    }

    /// Re-queue a batch (OOM-split halves — uninsertable, so no agg is
    /// needed; one is stored anyway to keep the invariant simple).
    pub fn requeue(&mut self, batch: Batch) {
        let agg = BatchAgg {
            len: batch.len(),
            gen: batch.predicted_gen_len(),
            size: batch.size(),
            max_s: batch
                .requests
                .iter()
                .map(|r| s_term(r.len(), r.predicted_gen_len))
                .max()
                .unwrap_or(0),
            min_arrival: batch.earliest_arrival(),
        };
        self.aggs.push(agg);
        self.ests.push(EstCache::EMPTY);
        self.queue.push(batch);
    }

    /// Batch shape from the O(1) aggregates (identical to scanning the
    /// batch members: every field is a maintained maximum).
    pub fn shape_of(&self, index: usize) -> BatchShape {
        let agg = &self.aggs[index];
        BatchShape {
            batch_size: agg.size,
            batch_len: agg.len,
            batch_gen_len: agg.gen,
        }
    }

    /// (earliest arrival, created_at, id) for the batch at `index` — the
    /// scheduler-view fields that do not need an estimator.
    pub fn view_meta(&self, index: usize) -> (f64, f64, u64) {
        (
            self.aggs[index].min_arrival,
            self.queue[index].created_at,
            self.queue[index].id,
        )
    }

    /// Serving-time estimate for the batch at `index`, cached across
    /// dispatch rounds.  `estimator_gen` is the estimator's generation
    /// counter; `compute` runs only when the cache is cold (first query,
    /// batch mutated, or estimator refit since).
    pub fn cached_estimate(
        &mut self,
        index: usize,
        estimator_gen: u64,
        compute: impl FnOnce(&BatchShape) -> f64,
    ) -> f64 {
        debug_assert!(estimator_gen != u64::MAX);
        if self.ests[index].gen != estimator_gen {
            let shape = self.shape_of(index);
            self.ests[index] = EstCache {
                gen: estimator_gen,
                value: compute(&shape),
            };
        }
        self.ests[index].value
    }

    /// Allocate a fresh batch id (for OOM splits).
    pub fn alloc_id(&mut self) -> u64 {
        let id = self.next_batch_id;
        self.next_batch_id += 1;
        id
    }

    pub fn queue(&self) -> &[Batch] {
        &self.queue
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Total queued requests.
    pub fn queued_requests(&self) -> usize {
        self.queue.iter().map(|b| b.requests.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch::wma::mem_bytes;
    use crate::util::prop::prop_check;
    use crate::workload::{PredictedRequest, Request, TaskId};

    fn req(id: u64, len: u32, pred: u32) -> PredictedRequest {
        PredictedRequest {
            request: Request {
                id,
                task: TaskId::Gc,
                instruction: String::new(),
                user_input: String::new(),
                user_input_len: len,
                request_len: len,
                gen_len: pred,
                arrival: 0.0,
            },
            predicted_gen_len: pred,
        }
    }

    fn cfg() -> BatcherConfig {
        BatcherConfig {
            wma_threshold: 50_000.0,
            theta: 6_900_000_000,
            delta: 458_752,
            max_batch_size: 0,
        }
    }

    #[test]
    fn similar_requests_coalesce() {
        let mut b = AdaptiveBatcher::new(cfg());
        let id0 = b.insert(req(0, 20, 15), 0.0);
        let id1 = b.insert(req(1, 22, 16), 0.1);
        let id2 = b.insert(req(2, 18, 14), 0.2);
        assert_eq!(id0, id1);
        assert_eq!(id1, id2);
        assert_eq!(b.queue_len(), 1);
    }

    #[test]
    fn dissimilar_requests_split_into_batches() {
        // A tiny and a huge request: joint WMA far exceeds Φ.
        let mut b = AdaptiveBatcher::new(cfg());
        let id0 = b.insert(req(0, 10, 10), 0.0);
        let id1 = b.insert(req(1, 1000, 1000), 0.1);
        assert_ne!(id0, id1);
        assert_eq!(b.queue_len(), 2);
    }

    #[test]
    fn fig6_case_study_batching() {
        // 18 small (L=G≈10) + 3 large (L=G≈1000) in arrival order
        // small*6, large*1, small*6, large*1, small*6, large*1 →
        // Magnus forms exactly 2 batches: smalls together, larges together.
        let mut b = AdaptiveBatcher::new(cfg());
        let mut rid = 0u64;
        for _ in 0..3 {
            for _ in 0..6 {
                b.insert(req(rid, 10, 10), 0.0);
                rid += 1;
            }
            b.insert(req(rid, 1000, 1000), 0.0);
            rid += 1;
        }
        assert_eq!(b.queue_len(), 2, "queue: {:?}",
            b.queue().iter().map(|x| (x.size(), x.len())).collect::<Vec<_>>());
        let sizes: Vec<u32> = b.queue().iter().map(|x| x.size()).collect();
        assert!(sizes.contains(&18) && sizes.contains(&3));
    }

    #[test]
    fn memory_bound_limits_batch_size() {
        // Θ only fits 4 requests of this shape.
        let delta = 458_752u64;
        let theta = mem_bytes(4, 100, 100, delta);
        let mut b = AdaptiveBatcher::new(BatcherConfig {
            wma_threshold: f64::INFINITY,
            theta,
            delta,
            max_batch_size: 0,
        });
        for i in 0..9 {
            b.insert(req(i, 100, 100), 0.0);
        }
        assert!(b.queue().iter().all(|x| x.size() <= 4));
        assert_eq!(b.queued_requests(), 9);
    }

    #[test]
    fn max_batch_size_cap_respected() {
        let mut c = cfg();
        c.max_batch_size = 7; // GLP ablation
        let mut b = AdaptiveBatcher::new(c);
        for i in 0..20 {
            b.insert(req(i, 50, 50), 0.0);
        }
        assert!(b.queue().iter().all(|x| x.size() <= 7));
    }

    #[test]
    fn uninsertable_batches_are_skipped() {
        let mut b = AdaptiveBatcher::new(cfg());
        b.insert(req(0, 20, 20), 0.0);
        let batch = b.take(0);
        let nid = b.alloc_id();
        let (mut l, r) = batch.split(nid);
        l.requests.push(req(9, 21, 21)); // make it non-empty after split
        b.requeue(l);
        b.requeue(r);
        let before = b.queue_len();
        b.insert(req(1, 20, 20), 1.0);
        // must have opened a NEW batch rather than joining the frozen ones
        assert_eq!(b.queue_len(), before + 1);
    }

    #[test]
    fn never_loses_requests() {
        prop_check(100, |rng| {
            let mut b = AdaptiveBatcher::new(cfg());
            let n = rng.range_usize(1, 120);
            for i in 0..n {
                let len = rng.range_u64(1, 1024) as u32;
                let pred = rng.range_u64(1, 1024) as u32;
                b.insert(req(i as u64, len, pred), i as f64);
            }
            assert_eq!(b.queued_requests(), n);
            // every queued batch satisfies the memory bound w.r.t. predictions
            for batch in b.queue() {
                assert!(
                    mem_bytes(batch.size(), batch.len(), batch.predicted_gen_len(), 458_752)
                        <= 6_900_000_000 || batch.size() == 1,
                    "over-budget batch of size {}",
                    batch.size()
                );
            }
        });
    }

    #[test]
    fn aggregates_match_member_scan_under_churn() {
        // After arbitrary insert/take/requeue churn, the O(1) aggregates
        // must equal a fresh scan of each batch's members (the cached
        // dispatch path depends on this).
        prop_check(60, |rng| {
            let mut b = AdaptiveBatcher::new(cfg());
            let n = rng.range_usize(1, 80);
            for i in 0..n {
                let len = rng.range_u64(1, 1024) as u32;
                let pred = rng.range_u64(1, 1024) as u32;
                let mut r = req(i as u64, len, pred);
                r.request.arrival = rng.f64() * 50.0;
                b.insert(r, i as f64);
                // occasionally dispatch / OOM-split-requeue a random batch
                if b.queue_len() > 1 && rng.range_u64(0, 4) == 0 {
                    let idx = rng.range_usize(0, b.queue_len());
                    let taken = b.take(idx);
                    if taken.size() >= 2 && rng.range_u64(0, 2) == 0 {
                        let nid = b.alloc_id();
                        let (l, r2) = taken.split(nid);
                        b.requeue(l);
                        b.requeue(r2);
                    }
                }
            }
            for i in 0..b.queue_len() {
                let shape = b.shape_of(i);
                let batch = &b.queue()[i];
                assert_eq!(shape.batch_size, batch.size());
                assert_eq!(shape.batch_len, batch.len());
                assert_eq!(shape.batch_gen_len, batch.predicted_gen_len());
                let (min_arrival, created_at, id) = b.view_meta(i);
                assert_eq!(min_arrival, batch.earliest_arrival());
                assert_eq!(created_at, batch.created_at);
                assert_eq!(id, batch.id);
            }
        });
    }

    #[test]
    fn cached_estimate_invalidates_on_mutation_and_generation() {
        let mut b = AdaptiveBatcher::new(cfg());
        b.insert(req(0, 20, 15), 0.0);
        let mut calls = 0;
        let v1 = b.cached_estimate(0, 1, |_| {
            calls += 1;
            7.0
        });
        assert_eq!((v1, calls), (7.0, 1));
        // warm hit: same generation, untouched batch → no recompute
        let v2 = b.cached_estimate(0, 1, |_| {
            calls += 1;
            99.0
        });
        assert_eq!((v2, calls), (7.0, 1));
        // estimator refit → recompute
        let v3 = b.cached_estimate(0, 2, |_| {
            calls += 1;
            8.0
        });
        assert_eq!((v3, calls), (8.0, 2));
        // batch mutation (insert joins it) → recompute even at same gen
        b.insert(req(1, 21, 16), 0.1);
        let v4 = b.cached_estimate(0, 2, |s| {
            calls += 1;
            assert_eq!(s.batch_size, 2);
            9.0
        });
        assert_eq!((v4, calls), (9.0, 3));
    }

    #[test]
    fn take_swap_removal_keeps_vectors_parallel() {
        let mut b = AdaptiveBatcher::new(cfg());
        b.insert(req(0, 10, 10), 0.0);
        b.insert(req(1, 500, 500), 0.1);
        b.insert(req(2, 1000, 1000), 0.2);
        assert_eq!(b.queue_len(), 3);
        let taken = b.take(0);
        // the last batch swapped into slot 0; aggregates must follow
        assert_eq!(b.queue_len(), 2);
        for i in 0..b.queue_len() {
            assert_eq!(b.shape_of(i).batch_len, b.queue()[i].len());
        }
        assert!(taken.size() >= 1);
    }

    #[test]
    fn batch_ids_unique() {
        let mut b = AdaptiveBatcher::new(cfg());
        for i in 0..50 {
            b.insert(req(i, (i as u32 % 10) * 100 + 1, (i as u32 % 7) * 150 + 1), 0.0);
        }
        let mut ids: Vec<u64> = b.queue().iter().map(|x| x.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), b.queue_len());
    }
}
