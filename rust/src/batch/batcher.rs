//! The WMA-directed adaptive batcher — Algorithm 1 of the paper.
//!
//! On request arrival: scan the waiting queue for the insertable batch
//! whose WMA after insertion is minimal, subject to the memory bound
//! MEM(B∪{p}) ≤ Θ (and, for the GLP ablation, a fixed batch-size cap).
//! Insert if the minimum is below the threshold Φ; otherwise open a new
//! batch.  Batches with similar lengths and predicted generation lengths
//! therefore coalesce, and batch sizes adapt to the memory budget —
//! small/short batches grow large, long batches stay small.

use std::collections::HashMap;

use crate::batch::types::Batch;
use crate::batch::wma::{mem_bytes, wma_gen, wma_wait};
use crate::config::SchedPolicy;
use crate::estimator::BatchShape;
use crate::scheduler::index::{Entry, LazyHeap};
use crate::workload::PredictedRequest;

/// O(1) WMA/memory aggregate for one queued batch.
///
/// Algorithm 1 evaluates WMA(B ∪ {p}) for every queued batch on every
/// insertion; done naively that is O(Σ batch sizes) per request.  But the
/// per-request WMA term decomposes: for a batch evaluated at union shape
/// (L, G) with G ≥ G'(p) for every member,
///
///   wma_gen(p) + wma_wait(p)
///     = G'(p)·(L − L(p)) + Σ_{g=G'(p)}^{G} (g + L)
///     = L·(G+1) + (G² + G)/2  +  [ (G'(p) − G'(p)²)/2 − G'(p)·L(p) ]
///       └──── shape-only, common to all p ────┘   └── request-only s_p ──┘
///
/// so  max_p (…) = L·(G+1) + (G²+G)/2 + max_p s_p,  and `max_s` is an
/// exactly-maintainable scalar (monotone max under insertion).  Batch
/// length, predicted generation length and size are cached alongside,
/// making the whole Algorithm-1 inner loop O(1) per queued batch.
#[derive(Debug, Clone, Copy)]
struct BatchAgg {
    len: u32,
    gen: u32,
    size: u32,
    max_s: i64,
    /// Earliest request arrival — T_q(B) = now − this, maintained so the
    /// dispatch loop never rescans batch members (monotone min under
    /// insertion).
    min_arrival: f64,
}

/// Cached serving-time estimate for one queued batch.
///
/// The estimate is a pure function of (batch shape, estimator state), so
/// it stays valid until the batch mutates (an insert joins it — the cache
/// entry is reset) or the estimator refits (detected by comparing the
/// estimator's generation counter).  `gen == u64::MAX` marks "no value".
#[derive(Debug, Clone, Copy)]
struct EstCache {
    gen: u64,
    value: f64,
}

impl EstCache {
    const EMPTY: EstCache = EstCache {
        gen: u64::MAX,
        value: 0.0,
    };
}

/// s_p of the decomposition above.
#[inline]
fn s_term(len: u32, gen: u32) -> i64 {
    let g = gen as i64;
    let l = len as i64;
    (g - g * g) / 2 - g * l
}

/// Shape-only part of the decomposition: L·(G+1) + (G²+G)/2.
#[inline]
fn shape_term(len: u32, gen: u32) -> i64 {
    let g = gen as i64;
    let l = len as i64;
    l * (g + 1) + (g * g + g) / 2
}

/// Batcher configuration distilled from `ServingConfig`.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Φ — WMA threshold of Algorithm 1.
    pub wma_threshold: f64,
    /// Θ — KV-cache memory budget in bytes.
    pub theta: u64,
    /// Δ — KV bytes per token.
    pub delta: u64,
    /// Max requests per batch (0 = unbounded). GLP ablation sets this to
    /// the vanilla batch size; full Magnus leaves it at 0.
    pub max_batch_size: u32,
}

/// The adaptive batcher: owns the waiting queue of open batches and the
/// incremental per-policy selection index over them.
pub struct AdaptiveBatcher {
    cfg: BatcherConfig,
    queue: Vec<Batch>,
    next_batch_id: u64,
    /// O(1) per-batch aggregates, index-parallel to `queue` (a HashMap
    /// here costs a lookup per scanned batch — measured 3× slower).
    aggs: Vec<BatchAgg>,
    /// Serving-time estimate cache, index-parallel to `queue`.
    ests: Vec<EstCache>,
    // --- indexed-select state -------------------------------------------
    // The dispatch loop used to rank every queued batch per round; these
    // lazy heaps keep the per-policy order incrementally so steady-state
    // selection is O(log Q) (see `select_indexed`).  The heaps are only
    // consulted there — `insert`'s Algorithm-1 scan is untouched.
    /// id → queue index, for the heaps' validity checks (only popped
    /// entries pay the lookup, never the Algorithm-1 scan).
    pos: HashMap<u64, usize>,
    /// Mutation stamps, index-parallel to `queue`, drawn from a global
    /// monotone counter so a re-queued id can never revive entries from
    /// its earlier life.
    stamps: Vec<u64>,
    next_stamp: u64,
    /// (created_at, id) min-heap — the FCFS winner; keys are immutable,
    /// so entries stay valid while their batch is queued.  Built lazily
    /// on the first FCFS select (`fcfs_active`), so runs under other
    /// policies never pay its maintenance or memory.
    fcfs_heap: LazyHeap,
    fcfs_active: bool,
    /// (min_arrival, id) min-heap — HRRN's queuing-time upper bound.
    /// Built lazily on the first HRRN select (`arrival_active`).
    arrival_heap: LazyHeap,
    arrival_active: bool,
    /// (estimate, id) min-heap — the SJF winner and HRRN's pruning
    /// order; keyed against `est_gen`.
    est_heap: LazyHeap,
    /// Estimator generation the est-heap keys were computed at
    /// (`u64::MAX` = never keyed; the first estimator select rebuilds).
    est_gen: u64,
    /// Batches whose est-heap entry is missing or stale (newly opened,
    /// joined, re-queued) — re-keyed lazily at the next estimator select.
    /// Tracked only once the est heap is live (`est_gen != u64::MAX`);
    /// before that, the first SJF/HRRN select rebuilds from the queue,
    /// so pure-FCFS runs accumulate nothing here.
    est_dirty: Vec<u64>,
    /// A NaN estimate was pushed this generation.  NaN sorts *last* in
    /// the heap but clamps to the *smallest* HRRN denominator, so the
    /// ascending-estimate pruning bound would skip it; the flag falls
    /// back to a full (still exact) scan.  Never set on product paths —
    /// the estimator clamps its output.
    est_heap_has_nan: bool,
    /// Scratch for the HRRN pruning scan (reused across selects).
    hrrn_scratch: Vec<Entry>,
}

impl AdaptiveBatcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        AdaptiveBatcher {
            cfg,
            queue: Vec::new(),
            next_batch_id: 0,
            aggs: Vec::new(),
            ests: Vec::new(),
            pos: HashMap::new(),
            stamps: Vec::new(),
            next_stamp: 0,
            fcfs_heap: LazyHeap::new(),
            fcfs_active: false,
            arrival_heap: LazyHeap::new(),
            arrival_active: false,
            est_heap: LazyHeap::new(),
            est_gen: u64::MAX,
            est_dirty: Vec::new(),
            est_heap_has_nan: false,
            hrrn_scratch: Vec::new(),
        }
    }

    /// Algorithm 1: insert `p` into the min-WMA feasible batch, or open a
    /// new batch.  Returns the id of the batch that received the request.
    ///
    /// The scan is O(1) per queued batch via the `BatchAgg` decomposition
    /// (see above) — measured ~40× faster than the naive O(Σβ) evaluation
    /// at serving-queue depths (EXPERIMENTS.md §Perf).
    pub fn insert(&mut self, p: PredictedRequest, now: f64) -> u64 {
        let mut phi = i64::MAX;
        let mut best: Option<usize> = None;
        let mut best_id = u64::MAX;
        let cand_s = s_term(p.len(), p.predicted_gen_len);

        for (i, b) in self.queue.iter().enumerate() {
            if !b.insertable {
                continue;
            }
            let agg = self.aggs[i];
            if self.cfg.max_batch_size > 0 && agg.size >= self.cfg.max_batch_size {
                continue;
            }
            let new_len = agg.len.max(p.len());
            let new_gen = agg.gen.max(p.predicted_gen_len);
            // Memory bound: MEM(B') ≤ Θ (Algorithm 1 line 5).
            if mem_bytes(agg.size + 1, new_len, new_gen, self.cfg.delta)
                > self.cfg.theta
            {
                continue;
            }
            // Equal-WMA ties break by batch id so the choice does not
            // depend on queue order (`take` swap-removes).
            let w = shape_term(new_len, new_gen) + agg.max_s.max(cand_s);
            if w < phi || (w == phi && b.id < best_id) {
                phi = w;
                best = Some(i);
                best_id = b.id;
            }
        }

        match best {
            Some(i) if (phi as f64) < self.cfg.wma_threshold => {
                let agg = &mut self.aggs[i];
                agg.len = agg.len.max(p.len());
                agg.gen = agg.gen.max(p.predicted_gen_len);
                agg.size += 1;
                agg.max_s = agg.max_s.max(cand_s);
                agg.min_arrival = agg.min_arrival.min(p.meta.arrival);
                self.ests[i] = EstCache::EMPTY; // shape changed
                self.queue[i].requests.push(p);
                self.touch(i); // shape changed: re-key the index entries
                self.queue[i].id
            }
            _ => {
                let id = self.next_batch_id;
                self.next_batch_id += 1;
                let arrival = p.meta.arrival;
                self.aggs.push(BatchAgg {
                    len: p.len(),
                    gen: p.predicted_gen_len,
                    size: 1,
                    max_s: cand_s,
                    min_arrival: arrival,
                });
                self.ests.push(EstCache::EMPTY);
                self.queue.push(Batch::new(id, p, now));
                self.index_new_slot(self.queue.len() - 1, now, arrival);
                id
            }
        }
    }

    /// Register the freshly-pushed queue slot `i` with the selection
    /// index: position map, mutation stamp, and — for each structure a
    /// select has activated — a heap entry / pending est re-key.
    fn index_new_slot(&mut self, i: usize, created_at: f64, min_arrival: f64) {
        let id = self.queue[i].id;
        let stamp = self.next_stamp;
        self.next_stamp += 1;
        self.pos.insert(id, i);
        self.stamps.push(stamp);
        debug_assert_eq!(self.stamps.len(), self.queue.len());
        if self.fcfs_active {
            self.fcfs_heap.push(created_at, id, stamp);
        }
        if self.arrival_active {
            self.arrival_heap.push(min_arrival, id, stamp);
        }
        if self.est_gen != u64::MAX {
            self.est_dirty.push(id);
        }
    }

    /// Re-key the index after slot `i` mutated: bump the stamp (staling
    /// every existing arrival/est entry for the batch) and, where
    /// active, push a fresh arrival entry and queue an est re-key for
    /// the next estimator select.  FCFS entries survive untouched —
    /// their (created_at, id) key is immutable, so they validate on
    /// liveness alone.
    fn touch(&mut self, i: usize) {
        let stamp = self.next_stamp;
        self.next_stamp += 1;
        self.stamps[i] = stamp;
        let id = self.queue[i].id;
        if self.arrival_active {
            self.arrival_heap.push(self.aggs[i].min_arrival, id, stamp);
        }
        if self.est_gen != u64::MAX {
            self.est_dirty.push(id);
        }
    }

    /// Remove and return the batch at `index` (scheduler hand-off).
    ///
    /// O(1) swap-removal: the last queued batch moves into `index`, and
    /// the index-parallel aggregate/cache vectors move with it.  Queue
    /// order is therefore NOT stable — all selection logic tie-breaks on
    /// batch id, never on position.
    pub fn take(&mut self, index: usize) -> Batch {
        self.aggs.swap_remove(index);
        self.ests.swap_remove(index);
        self.stamps.swap_remove(index);
        let batch = self.queue.swap_remove(index);
        // Index bookkeeping: the departed id's heap entries go stale (no
        // `pos` hit) and are discarded lazily as they surface.
        self.pos.remove(&batch.id);
        if index < self.queue.len() {
            self.pos.insert(self.queue[index].id, index);
        }
        batch
    }

    /// Re-queue a batch (OOM-split halves — uninsertable, so no agg is
    /// needed; one is stored anyway to keep the invariant simple).
    pub fn requeue(&mut self, batch: Batch) {
        let agg = BatchAgg {
            len: batch.len(),
            gen: batch.predicted_gen_len(),
            size: batch.size(),
            max_s: batch
                .requests
                .iter()
                .map(|r| s_term(r.len(), r.predicted_gen_len))
                .max()
                .unwrap_or(0),
            min_arrival: batch.earliest_arrival(),
        };
        let created_at = batch.created_at;
        self.aggs.push(agg);
        self.ests.push(EstCache::EMPTY);
        self.queue.push(batch);
        self.index_new_slot(self.queue.len() - 1, created_at, agg.min_arrival);
    }

    /// Batch shape from the O(1) aggregates (identical to scanning the
    /// batch members: every field is a maintained maximum).
    pub fn shape_of(&self, index: usize) -> BatchShape {
        let agg = &self.aggs[index];
        BatchShape {
            batch_size: agg.size,
            batch_len: agg.len,
            batch_gen_len: agg.gen,
        }
    }

    /// (earliest arrival, created_at, id) for the batch at `index` — the
    /// scheduler-view fields that do not need an estimator.
    pub fn view_meta(&self, index: usize) -> (f64, f64, u64) {
        (
            self.aggs[index].min_arrival,
            self.queue[index].created_at,
            self.queue[index].id,
        )
    }

    /// Serving-time estimate for the batch at `index`, cached across
    /// dispatch rounds.  `estimator_gen` is the estimator's generation
    /// counter; `compute` runs only when the cache is cold (first query,
    /// batch mutated, or estimator refit since).
    pub fn cached_estimate(
        &mut self,
        index: usize,
        estimator_gen: u64,
        compute: impl FnOnce(&BatchShape) -> f64,
    ) -> f64 {
        debug_assert!(estimator_gen != u64::MAX);
        if self.ests[index].gen != estimator_gen {
            let shape = self.shape_of(index);
            self.ests[index] = EstCache {
                gen: estimator_gen,
                value: compute(&shape),
            };
        }
        self.ests[index].value
    }

    /// Indexed batch selection: the incremental replacement for building
    /// a view per queued batch and linear-scanning `scheduler::select`.
    ///
    /// Returns the queue index of the batch to serve next and its cached
    /// serving-time estimate (the value the dispatch loop logs), or
    /// `None` if the queue is empty.  The winner — and the estimate — are
    /// **bit-identical** to the linear-scan reference for every policy:
    ///
    /// * **FCFS** peeks the (created_at, id) heap; keys are immutable, so
    ///   validity is just liveness.
    /// * **SJF** peeks the (estimate, id) heap after syncing it: a new
    ///   estimator generation rebuilds every key (each refit moves every
    ///   estimate, amortised over a generation's many selects), otherwise
    ///   only batches on the dirty list are re-keyed.
    /// * **HRRN** cannot be a static heap — its response ratio
    ///   `T_q(now)/T_s` moves with the clock — but it admits an exact
    ///   pruned scan: pop candidates in ascending-estimate order, and
    ///   stop once `(now − min live arrival) / next estimate`, an upper
    ///   bound on every unseen ratio (waits are ≤ the oldest wait,
    ///   estimates are ≥ the next key, and f64 division is monotone in
    ///   both arguments), falls strictly below the best ratio seen.
    ///   Popped candidates are pushed back afterwards.
    ///
    /// In debug builds every call cross-checks itself against the
    /// scan reference, which turns each sim test into a
    /// golden-equivalence test of the index.
    pub fn select_indexed(
        &mut self,
        policy: SchedPolicy,
        now: f64,
        estimator_gen: u64,
        est: impl Fn(&BatchShape) -> f64,
    ) -> Option<(usize, f64)> {
        debug_assert!(estimator_gen != u64::MAX);
        if self.queue.is_empty() {
            return None;
        }
        let picked = match policy {
            SchedPolicy::Fcfs => self.pick_fcfs(estimator_gen, &est),
            SchedPolicy::Sjf => {
                self.sync_est_heap(estimator_gen, &est);
                self.pick_sjf()
            }
            SchedPolicy::Hrrn => {
                self.sync_est_heap(estimator_gen, &est);
                self.pick_hrrn(now)
            }
        };
        #[cfg(debug_assertions)]
        self.assert_matches_scan(policy, now, estimator_gen, &est, picked);
        picked
    }

    /// FCFS: surface the live minimum of the (created_at, id) heap,
    /// building the heap from the queue on first use.
    fn pick_fcfs(
        &mut self,
        estimator_gen: u64,
        est: &impl Fn(&BatchShape) -> f64,
    ) -> Option<(usize, f64)> {
        if !self.fcfs_active {
            self.fcfs_active = true;
            self.fcfs_heap.clear();
            for i in 0..self.queue.len() {
                self.fcfs_heap
                    .push(self.queue[i].created_at, self.queue[i].id, self.stamps[i]);
            }
        }
        let pos = &self.pos;
        let (_, id) = self.fcfs_heap.peek_valid(|id, _| pos.contains_key(&id))?;
        let i = self.pos[&id];
        let e = self.cached_estimate(i, estimator_gen, |s| est(s));
        Some((i, e))
    }

    /// SJF: surface the live, current-stamp minimum of the est heap.
    fn pick_sjf(&mut self) -> Option<(usize, f64)> {
        let (pos, stamps) = (&self.pos, &self.stamps);
        let (key, id) = self
            .est_heap
            .peek_valid(|id, stamp| pos.get(&id).map_or(false, |&i| stamps[i] == stamp))?;
        Some((self.pos[&id], key))
    }

    /// HRRN: exact pruned scan in ascending-estimate order (see
    /// [`AdaptiveBatcher::select_indexed`] for the bound argument).
    fn pick_hrrn(&mut self, now: f64) -> Option<(usize, f64)> {
        if !self.arrival_active {
            self.arrival_active = true;
            self.arrival_heap.clear();
            for i in 0..self.queue.len() {
                self.arrival_heap
                    .push(self.aggs[i].min_arrival, self.queue[i].id, self.stamps[i]);
            }
        }
        // T_q upper bound from the earliest live arrival.
        let qmax = {
            let (pos, stamps) = (&self.pos, &self.stamps);
            let (a_min, _) = self
                .arrival_heap
                .peek_valid(|id, stamp| pos.get(&id).map_or(false, |&i| stamps[i] == stamp))?;
            (now - a_min).max(0.0)
        };
        let mut best: Option<(f64, u64, usize, f64)> = None; // (ratio, id, index, est)
        let mut scratch = std::mem::take(&mut self.hrrn_scratch);
        loop {
            let entry = {
                let (pos, stamps) = (&self.pos, &self.stamps);
                self.est_heap
                    .pop_valid(|id, stamp| pos.get(&id).map_or(false, |&i| stamps[i] == stamp))
            };
            let entry = match entry {
                Some(e) => e,
                None => break,
            };
            let i = self.pos[&entry.id];
            let q = (now - self.aggs[i].min_arrival).max(0.0);
            // Same formula as `BatchView::ratio`, so values match the
            // scan bit-for-bit.
            let ratio = q / entry.key.max(1e-9);
            let better = match &best {
                None => true,
                Some((br, bid, _, _)) => match ratio.total_cmp(br) {
                    std::cmp::Ordering::Greater => true,
                    std::cmp::Ordering::Less => false,
                    std::cmp::Ordering::Equal => entry.id < *bid,
                },
            };
            if better {
                best = Some((ratio, entry.id, i, entry.key));
            }
            scratch.push(entry);
            let next = {
                let (pos, stamps) = (&self.pos, &self.stamps);
                self.est_heap
                    .peek_valid(|id, stamp| pos.get(&id).map_or(false, |&i| stamps[i] == stamp))
            };
            match (next, &best) {
                (Some((next_key, _)), Some((br, _, _, _))) if !self.est_heap_has_nan => {
                    // Unseen ratios are ≤ qmax / next_key; stop only on a
                    // strict deficit (a tie could still lose on batch id).
                    let bound = qmax / next_key.max(1e-9);
                    if bound.total_cmp(br) == std::cmp::Ordering::Less {
                        break;
                    }
                }
                (None, _) => break,
                _ => {}
            }
        }
        self.est_heap.reinsert(&mut scratch);
        self.hrrn_scratch = scratch;
        best.map(|(_, _, i, e)| (i, e))
    }

    /// Bring the est heap up to date with `estimator_gen`: full rebuild
    /// on a generation change, dirty-list re-keys otherwise.  Keys come
    /// through `cached_estimate`, so they are the exact values the scan
    /// paths would see.
    fn sync_est_heap(&mut self, estimator_gen: u64, est: &impl Fn(&BatchShape) -> f64) {
        if self.est_gen != estimator_gen {
            self.est_heap.clear();
            self.est_dirty.clear();
            self.est_heap_has_nan = false;
            for i in 0..self.queue.len() {
                let e = self.cached_estimate(i, estimator_gen, |s| est(s));
                self.est_heap_has_nan |= e.is_nan();
                self.est_heap.push(e, self.queue[i].id, self.stamps[i]);
            }
            self.est_gen = estimator_gen;
            return;
        }
        if self.est_dirty.is_empty() {
            return;
        }
        let dirty = std::mem::take(&mut self.est_dirty);
        for id in &dirty {
            if let Some(&i) = self.pos.get(id) {
                let e = self.cached_estimate(i, estimator_gen, |s| est(s));
                self.est_heap_has_nan |= e.is_nan();
                self.est_heap.push(e, *id, self.stamps[i]);
            }
        }
        self.est_dirty = dirty;
        self.est_dirty.clear();
    }

    /// Debug-build safety net: the indexed pick must equal the linear
    /// scan over freshly-built views, estimate included.
    #[cfg(debug_assertions)]
    fn assert_matches_scan(
        &mut self,
        policy: SchedPolicy,
        now: f64,
        estimator_gen: u64,
        est: &impl Fn(&BatchShape) -> f64,
        picked: Option<(usize, f64)>,
    ) {
        use crate::scheduler::{select, BatchView};
        let mut views: Vec<BatchView> = Vec::with_capacity(self.queue.len());
        for i in 0..self.queue.len() {
            let e = self.cached_estimate(i, estimator_gen, |s| est(s));
            let (min_arrival, created_at, batch_id) = self.view_meta(i);
            views.push(BatchView {
                queuing_time: (now - min_arrival).max(0.0),
                est_serving_time: e,
                created_at,
                batch_id,
            });
        }
        let reference = select(policy, &views);
        assert_eq!(
            picked.map(|(i, _)| i),
            reference,
            "indexed {policy:?} select diverged from the scan reference"
        );
        if let (Some((_, e)), Some(r)) = (picked, reference) {
            assert_eq!(
                e.to_bits(),
                views[r].est_serving_time.to_bits(),
                "indexed {policy:?} estimate diverged from the scan reference"
            );
        }
    }

    /// Allocate a fresh batch id (for OOM splits).
    pub fn alloc_id(&mut self) -> u64 {
        let id = self.next_batch_id;
        self.next_batch_id += 1;
        id
    }

    pub fn queue(&self) -> &[Batch] {
        &self.queue
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Total queued requests.
    pub fn queued_requests(&self) -> usize {
        self.queue.iter().map(|b| b.requests.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch::wma::mem_bytes;
    use crate::util::prop::prop_check;
    use crate::workload::{PredictedRequest, RequestMeta, Span, StoreId, TaskId};

    fn req(id: u64, len: u32, pred: u32) -> PredictedRequest {
        PredictedRequest {
            meta: RequestMeta {
                id,
                task: TaskId::Gc,
                store: StoreId::DETACHED,
                instr: u32::MAX,
                user_input_len: len,
                request_len: len,
                gen_len: pred,
                arrival: 0.0,
                span: Span::DETACHED,
                uih: 0,
            },
            predicted_gen_len: pred,
        }
    }

    fn cfg() -> BatcherConfig {
        BatcherConfig {
            wma_threshold: 50_000.0,
            theta: 6_900_000_000,
            delta: 458_752,
            max_batch_size: 0,
        }
    }

    #[test]
    fn similar_requests_coalesce() {
        let mut b = AdaptiveBatcher::new(cfg());
        let id0 = b.insert(req(0, 20, 15), 0.0);
        let id1 = b.insert(req(1, 22, 16), 0.1);
        let id2 = b.insert(req(2, 18, 14), 0.2);
        assert_eq!(id0, id1);
        assert_eq!(id1, id2);
        assert_eq!(b.queue_len(), 1);
    }

    #[test]
    fn dissimilar_requests_split_into_batches() {
        // A tiny and a huge request: joint WMA far exceeds Φ.
        let mut b = AdaptiveBatcher::new(cfg());
        let id0 = b.insert(req(0, 10, 10), 0.0);
        let id1 = b.insert(req(1, 1000, 1000), 0.1);
        assert_ne!(id0, id1);
        assert_eq!(b.queue_len(), 2);
    }

    #[test]
    fn fig6_case_study_batching() {
        // 18 small (L=G≈10) + 3 large (L=G≈1000) in arrival order
        // small*6, large*1, small*6, large*1, small*6, large*1 →
        // Magnus forms exactly 2 batches: smalls together, larges together.
        let mut b = AdaptiveBatcher::new(cfg());
        let mut rid = 0u64;
        for _ in 0..3 {
            for _ in 0..6 {
                b.insert(req(rid, 10, 10), 0.0);
                rid += 1;
            }
            b.insert(req(rid, 1000, 1000), 0.0);
            rid += 1;
        }
        assert_eq!(b.queue_len(), 2, "queue: {:?}",
            b.queue().iter().map(|x| (x.size(), x.len())).collect::<Vec<_>>());
        let sizes: Vec<u32> = b.queue().iter().map(|x| x.size()).collect();
        assert!(sizes.contains(&18) && sizes.contains(&3));
    }

    #[test]
    fn memory_bound_limits_batch_size() {
        // Θ only fits 4 requests of this shape.
        let delta = 458_752u64;
        let theta = mem_bytes(4, 100, 100, delta);
        let mut b = AdaptiveBatcher::new(BatcherConfig {
            wma_threshold: f64::INFINITY,
            theta,
            delta,
            max_batch_size: 0,
        });
        for i in 0..9 {
            b.insert(req(i, 100, 100), 0.0);
        }
        assert!(b.queue().iter().all(|x| x.size() <= 4));
        assert_eq!(b.queued_requests(), 9);
    }

    #[test]
    fn max_batch_size_cap_respected() {
        let mut c = cfg();
        c.max_batch_size = 7; // GLP ablation
        let mut b = AdaptiveBatcher::new(c);
        for i in 0..20 {
            b.insert(req(i, 50, 50), 0.0);
        }
        assert!(b.queue().iter().all(|x| x.size() <= 7));
    }

    #[test]
    fn uninsertable_batches_are_skipped() {
        let mut b = AdaptiveBatcher::new(cfg());
        b.insert(req(0, 20, 20), 0.0);
        let batch = b.take(0);
        let nid = b.alloc_id();
        let (mut l, r) = batch.split(nid);
        l.requests.push(req(9, 21, 21)); // make it non-empty after split
        b.requeue(l);
        b.requeue(r);
        let before = b.queue_len();
        b.insert(req(1, 20, 20), 1.0);
        // must have opened a NEW batch rather than joining the frozen ones
        assert_eq!(b.queue_len(), before + 1);
    }

    #[test]
    fn never_loses_requests() {
        prop_check(100, |rng| {
            let mut b = AdaptiveBatcher::new(cfg());
            let n = rng.range_usize(1, 120);
            for i in 0..n {
                let len = rng.range_u64(1, 1024) as u32;
                let pred = rng.range_u64(1, 1024) as u32;
                b.insert(req(i as u64, len, pred), i as f64);
            }
            assert_eq!(b.queued_requests(), n);
            // every queued batch satisfies the memory bound w.r.t. predictions
            for batch in b.queue() {
                assert!(
                    mem_bytes(batch.size(), batch.len(), batch.predicted_gen_len(), 458_752)
                        <= 6_900_000_000 || batch.size() == 1,
                    "over-budget batch of size {}",
                    batch.size()
                );
            }
        });
    }

    #[test]
    fn aggregates_match_member_scan_under_churn() {
        // After arbitrary insert/take/requeue churn, the O(1) aggregates
        // must equal a fresh scan of each batch's members (the cached
        // dispatch path depends on this).
        prop_check(60, |rng| {
            let mut b = AdaptiveBatcher::new(cfg());
            let n = rng.range_usize(1, 80);
            for i in 0..n {
                let len = rng.range_u64(1, 1024) as u32;
                let pred = rng.range_u64(1, 1024) as u32;
                let mut r = req(i as u64, len, pred);
                r.meta.arrival = rng.f64() * 50.0;
                b.insert(r, i as f64);
                // occasionally dispatch / OOM-split-requeue a random batch
                if b.queue_len() > 1 && rng.range_u64(0, 4) == 0 {
                    let idx = rng.range_usize(0, b.queue_len());
                    let taken = b.take(idx);
                    if taken.size() >= 2 && rng.range_u64(0, 2) == 0 {
                        let nid = b.alloc_id();
                        let (l, r2) = taken.split(nid);
                        b.requeue(l);
                        b.requeue(r2);
                    }
                }
            }
            for i in 0..b.queue_len() {
                let shape = b.shape_of(i);
                let batch = &b.queue()[i];
                assert_eq!(shape.batch_size, batch.size());
                assert_eq!(shape.batch_len, batch.len());
                assert_eq!(shape.batch_gen_len, batch.predicted_gen_len());
                let (min_arrival, created_at, id) = b.view_meta(i);
                assert_eq!(min_arrival, batch.earliest_arrival());
                assert_eq!(created_at, batch.created_at);
                assert_eq!(id, batch.id);
            }
        });
    }

    #[test]
    fn cached_estimate_invalidates_on_mutation_and_generation() {
        let mut b = AdaptiveBatcher::new(cfg());
        b.insert(req(0, 20, 15), 0.0);
        let mut calls = 0;
        let v1 = b.cached_estimate(0, 1, |_| {
            calls += 1;
            7.0
        });
        assert_eq!((v1, calls), (7.0, 1));
        // warm hit: same generation, untouched batch → no recompute
        let v2 = b.cached_estimate(0, 1, |_| {
            calls += 1;
            99.0
        });
        assert_eq!((v2, calls), (7.0, 1));
        // estimator refit → recompute
        let v3 = b.cached_estimate(0, 2, |_| {
            calls += 1;
            8.0
        });
        assert_eq!((v3, calls), (8.0, 2));
        // batch mutation (insert joins it) → recompute even at same gen
        b.insert(req(1, 21, 16), 0.1);
        let v4 = b.cached_estimate(0, 2, |s| {
            calls += 1;
            assert_eq!(s.batch_size, 2);
            9.0
        });
        assert_eq!((v4, calls), (9.0, 3));
    }

    #[test]
    fn take_swap_removal_keeps_vectors_parallel() {
        let mut b = AdaptiveBatcher::new(cfg());
        b.insert(req(0, 10, 10), 0.0);
        b.insert(req(1, 500, 500), 0.1);
        b.insert(req(2, 1000, 1000), 0.2);
        assert_eq!(b.queue_len(), 3);
        let taken = b.take(0);
        // the last batch swapped into slot 0; aggregates must follow
        assert_eq!(b.queue_len(), 2);
        for i in 0..b.queue_len() {
            assert_eq!(b.shape_of(i).batch_len, b.queue()[i].len());
        }
        assert!(taken.size() >= 1);
    }

    /// Reference: build views the Cached way and linear-scan them.
    fn scan_select(
        b: &mut AdaptiveBatcher,
        policy: SchedPolicy,
        now: f64,
        gen: u64,
        est: &impl Fn(&BatchShape) -> f64,
    ) -> Option<(usize, f64)> {
        use crate::scheduler::{select, BatchView};
        let mut views = Vec::with_capacity(b.queue_len());
        for i in 0..b.queue_len() {
            let e = b.cached_estimate(i, gen, |s| est(s));
            let (min_arrival, created_at, batch_id) = b.view_meta(i);
            views.push(BatchView {
                queuing_time: (now - min_arrival).max(0.0),
                est_serving_time: e,
                created_at,
                batch_id,
            });
        }
        select(policy, &views).map(|i| (i, views[i].est_serving_time))
    }

    #[test]
    fn indexed_select_matches_scan_under_churn() {
        // Random insert/take/requeue churn with mid-stream estimator
        // generation bumps: the indexed pick (index AND estimate) must
        // equal the linear-scan reference for all three policies.
        for policy in [SchedPolicy::Fcfs, SchedPolicy::Sjf, SchedPolicy::Hrrn] {
            prop_check(40, |rng| {
                let mut b = AdaptiveBatcher::new(cfg());
                let mut gen = 1u64;
                let mut now = 0.0f64;
                // estimate = pure function of (shape, generation)
                let est_of = |gen: u64| {
                    move |s: &BatchShape| {
                        s.batch_gen_len as f64 * 0.05
                            + s.batch_len as f64 * 1e-4
                            + s.batch_size as f64 * 0.01
                            + gen as f64 * 0.13
                    }
                };
                let n = rng.range_usize(2, 60);
                for i in 0..n {
                    now += rng.f64() * 0.5;
                    let len = rng.range_u64(1, 1024) as u32;
                    let pred = rng.range_u64(1, 1024) as u32;
                    let mut r = req(i as u64, len, pred);
                    r.meta.arrival = now - rng.f64();
                    b.insert(r, now);
                    if rng.range_u64(0, 5) == 0 {
                        gen += 1; // estimator refit between selects
                    }
                    let est = est_of(gen);
                    let got = b.select_indexed(policy, now, gen, &est);
                    let want = scan_select(&mut b, policy, now, gen, &est);
                    assert_eq!(got.map(|x| x.0), want.map(|x| x.0), "{policy:?}");
                    let (g, w) = (got.unwrap(), want.unwrap());
                    assert_eq!(g.1.to_bits(), w.1.to_bits(), "{policy:?} estimate");
                    // occasionally dispatch the winner, sometimes with an
                    // OOM split + requeue
                    if rng.range_u64(0, 3) == 0 {
                        let taken = b.take(g.0);
                        if taken.size() >= 2 && rng.range_u64(0, 2) == 0 {
                            let nid = b.alloc_id();
                            let (l, r2) = taken.split(nid);
                            b.requeue(l);
                            b.requeue(r2);
                        }
                    }
                    if !b.is_empty() {
                        let got = b.select_indexed(policy, now, gen, &est);
                        let want = scan_select(&mut b, policy, now, gen, &est);
                        assert_eq!(got.map(|x| x.0), want.map(|x| x.0), "{policy:?} post-churn");
                    }
                }
            });
        }
    }

    #[test]
    fn indexed_select_handles_exact_ties() {
        // Identical created_at / shapes everywhere: every key ties and
        // the smaller batch id must win, from heaps as from the scan.
        let mut b = AdaptiveBatcher::new(BatcherConfig {
            wma_threshold: 0.0, // never coalesce
            ..cfg()
        });
        for i in 0..10 {
            b.insert(req(i, 50, 50), 0.0);
        }
        let est = |_: &BatchShape| 2.0;
        for policy in [SchedPolicy::Fcfs, SchedPolicy::Sjf, SchedPolicy::Hrrn] {
            let (i, _) = b.select_indexed(policy, 1.0, 1, est).unwrap();
            assert_eq!(b.queue()[i].id, 0, "{policy:?}");
        }
        // dispatch the winner; next tie goes to the next id
        let (i, _) = b.select_indexed(SchedPolicy::Fcfs, 1.0, 1, est).unwrap();
        b.take(i);
        let (i, _) = b.select_indexed(SchedPolicy::Fcfs, 1.0, 1, est).unwrap();
        assert_eq!(b.queue()[i].id, 1);
    }

    #[test]
    fn indexed_select_empty_queue_is_none() {
        let mut b = AdaptiveBatcher::new(cfg());
        assert!(b
            .select_indexed(SchedPolicy::Hrrn, 0.0, 1, |_| 1.0)
            .is_none());
        b.insert(req(0, 10, 10), 0.0);
        let (i, _) = b.select_indexed(SchedPolicy::Hrrn, 1.0, 1, |_| 1.0).unwrap();
        b.take(i);
        assert!(b
            .select_indexed(SchedPolicy::Hrrn, 2.0, 1, |_| 1.0)
            .is_none());
    }

    #[test]
    fn batch_ids_unique() {
        let mut b = AdaptiveBatcher::new(cfg());
        for i in 0..50 {
            b.insert(req(i, (i as u32 % 10) * 100 + 1, (i as u32 % 7) * 150 + 1), 0.0);
        }
        let mut ids: Vec<u64> = b.queue().iter().map(|x| x.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), b.queue_len());
    }
}
