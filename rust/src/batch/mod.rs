//! Batching: the WMA metric (Eq. 2–5), the batch type, and the
//! WMA-directed adaptive batcher (Algorithm 1).

pub mod batcher;
pub mod types;
pub mod wma;

pub use batcher::{AdaptiveBatcher, BatcherConfig};
pub use types::Batch;
