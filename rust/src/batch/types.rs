//! The batch type that flows through the batcher → queue → scheduler →
//! engine pipeline.

use crate::estimator::BatchShape;
use crate::workload::{PredictedRequest, TraceStore};

/// A batch of requests awaiting (or under) execution.
#[derive(Debug, Clone)]
pub struct Batch {
    /// Unique batch id.
    pub id: u64,
    pub requests: Vec<PredictedRequest>,
    /// Wall/sim time the batch was created (first request inserted).
    pub created_at: f64,
    /// False after an OOM split (§III-C: split batches are re-queued
    /// uninsertable so they cannot grow past the memory bound again).
    pub insertable: bool,
}

impl Batch {
    pub fn new(id: u64, first: PredictedRequest, now: f64) -> Batch {
        Batch {
            id,
            requests: vec![first],
            created_at: now,
            insertable: true,
        }
    }

    /// One batch over every request of `store`, in trace order, with
    /// predictions set to the true generation lengths — the
    /// perfect-prediction shape real-compute tests and demos batch with.
    /// Panics on an empty store.
    pub fn of_store(id: u64, store: &TraceStore) -> Batch {
        assert!(!store.is_empty(), "cannot batch an empty store");
        Batch {
            id,
            requests: store
                .metas()
                .iter()
                .map(|&meta| PredictedRequest {
                    meta,
                    predicted_gen_len: meta.gen_len,
                })
                .collect(),
            created_at: 0.0,
            insertable: true,
        }
    }

    /// β — number of requests.
    #[inline]
    pub fn size(&self) -> u32 {
        self.requests.len() as u32
    }

    /// L(B) = max_p L(p) — the padded batch length.
    #[inline]
    pub fn len(&self) -> u32 {
        self.requests.iter().map(|r| r.len()).max().unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Predicted G(B) = max_p G'(p) — what the scheduler reasons with.
    #[inline]
    pub fn predicted_gen_len(&self) -> u32 {
        self.requests
            .iter()
            .map(|r| r.predicted_gen_len)
            .max()
            .unwrap_or(0)
    }

    /// Ground-truth G(B) — engine-only (EOS timing).
    #[inline]
    pub fn true_gen_len(&self) -> u32 {
        self.requests
            .iter()
            .map(|r| r.meta.gen_len)
            .max()
            .unwrap_or(0)
    }

    /// Scheduler-facing shape: (β, L(B), **predicted** G(B)) — what the
    /// serving-time estimator is queried with before dispatch.
    #[inline]
    pub fn predicted_shape(&self) -> BatchShape {
        BatchShape {
            batch_size: self.size(),
            batch_len: self.len(),
            batch_gen_len: self.predicted_gen_len(),
        }
    }

    /// Ground-truth shape: (β, L(B), **actual** G(B)) — what batch logs
    /// record after serving (§III-D re-prediction uses the actual G).
    #[inline]
    pub fn true_shape(&self) -> BatchShape {
        BatchShape {
            batch_size: self.size(),
            batch_len: self.len(),
            batch_gen_len: self.true_gen_len(),
        }
    }

    /// Earliest arrival among batched requests; T_q(B) = now − this
    /// (§III-E: the longest queuing time of requests in B).
    #[inline]
    pub fn earliest_arrival(&self) -> f64 {
        self.requests
            .iter()
            .map(|r| r.meta.arrival)
            .fold(f64::INFINITY, f64::min)
    }

    /// Split evenly in two (OOM recovery, §III-C).  Both halves are marked
    /// uninsertable.  Requests are ordered by length so the halves stay
    /// length-homogeneous.
    pub fn split(mut self, next_id: u64) -> (Batch, Batch) {
        self.requests.sort_by_key(|r| r.len());
        let half = self.requests.len() / 2;
        let right = self.requests.split_off(half);
        let left = Batch {
            id: self.id,
            requests: self.requests,
            created_at: self.created_at,
            insertable: false,
        };
        let right = Batch {
            id: next_id,
            requests: right,
            created_at: self.created_at,
            insertable: false,
        };
        (left, right)
    }

    /// Overrun-guard OOM split (ISSUE 6 alternative to the even
    /// [`Batch::split`]): partition on the engine's observed EOS timing —
    /// requests that finished before the OOM iteration (the engine
    /// "samples EOS", so `gen_len < at_iteration` is runtime feedback,
    /// not a scheduling peek at ground truth) go left unchanged, while
    /// the still-generating overrunners go right with their prediction
    /// re-bucketed to at least the iteration they provably reached
    /// (doubled, clamped to `[at_iteration, G_max]`) so the re-queued
    /// half is scheduled against an honest length instead of riding the
    /// same under-prediction back into OOM.  Both halves are marked
    /// uninsertable.  Returns `Err(self)` when either side would be empty
    /// (no split possible — the caller falls back to the even split).
    pub fn split_overrun(
        self,
        next_id: u64,
        at_iteration: u32,
        g_max: u32,
    ) -> Result<(Batch, Batch), Batch> {
        let n_done = self
            .requests
            .iter()
            .filter(|r| r.meta.gen_len < at_iteration)
            .count();
        if n_done == 0 || n_done == self.requests.len() {
            return Err(self);
        }
        let (id, created_at) = (self.id, self.created_at);
        let lo = at_iteration.min(g_max);
        let mut done = Vec::with_capacity(n_done);
        let mut over = Vec::with_capacity(self.requests.len() - n_done);
        for mut r in self.requests {
            if r.meta.gen_len < at_iteration {
                done.push(r);
            } else {
                r.predicted_gen_len = r
                    .predicted_gen_len
                    .saturating_mul(2)
                    .clamp(lo, g_max.max(1));
                over.push(r);
            }
        }
        let left = Batch {
            id,
            requests: done,
            created_at,
            insertable: false,
        };
        let right = Batch {
            id: next_id,
            requests: over,
            created_at,
            insertable: false,
        };
        Ok((left, right))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{RequestMeta, Span, StoreId, TaskId};

    pub(crate) fn req(id: u64, len: u32, gen: u32, pred: u32, arrival: f64) -> PredictedRequest {
        PredictedRequest {
            meta: RequestMeta {
                id,
                task: TaskId::Gc,
                store: StoreId::DETACHED,
                instr: u32::MAX,
                user_input_len: len.saturating_sub(1),
                request_len: len,
                gen_len: gen,
                arrival,
                span: Span::DETACHED,
                uih: 0,
            },
            predicted_gen_len: pred,
        }
    }

    #[test]
    fn aggregates_are_maxima() {
        let mut b = Batch::new(0, req(0, 10, 5, 6, 1.0), 1.0);
        b.requests.push(req(1, 30, 50, 40, 0.5));
        b.requests.push(req(2, 20, 8, 8, 2.0));
        assert_eq!(b.size(), 3);
        assert_eq!(b.len(), 30);
        assert_eq!(b.predicted_gen_len(), 40);
        assert_eq!(b.true_gen_len(), 50);
        assert_eq!(b.earliest_arrival(), 0.5);
    }

    #[test]
    fn split_halves_and_marks_uninsertable() {
        let mut b = Batch::new(7, req(0, 10, 5, 5, 0.0), 0.0);
        for i in 1..6 {
            b.requests.push(req(i, 10 * (i as u32 + 1), 5, 5, 0.0));
        }
        let (l, r) = b.split(8);
        assert_eq!(l.size() + r.size(), 6);
        assert!((l.size() as i32 - r.size() as i32).abs() <= 1);
        assert!(!l.insertable && !r.insertable);
        assert_eq!(r.id, 8);
        // length-sorted halves: every left length <= every right length
        assert!(l.len() <= r.requests.iter().map(|x| x.len()).min().unwrap());
    }

    #[test]
    fn split_overrun_partitions_on_observed_eos() {
        let mut b = Batch::new(3, req(0, 10, 4, 6, 0.0), 0.0);
        b.requests.push(req(1, 12, 7, 6, 0.0)); // done before iter 8
        b.requests.push(req(2, 14, 20, 6, 0.0)); // overruns
        b.requests.push(req(3, 16, 9, 6, 0.0)); // overruns (gen >= 8)
        let (l, r) = b.split_overrun(4, 8, 64).unwrap();
        assert_eq!(l.id, 3);
        assert_eq!(r.id, 4);
        assert!(!l.insertable && !r.insertable);
        let lids: Vec<u64> = l.requests.iter().map(|x| x.meta.id).collect();
        let rids: Vec<u64> = r.requests.iter().map(|x| x.meta.id).collect();
        assert_eq!(lids, vec![0, 1]);
        assert_eq!(rids, vec![2, 3]);
        // finished requests keep their prediction; overrunners re-bucket
        assert!(l.requests.iter().all(|x| x.predicted_gen_len == 6));
        // 6*2 = 12 >= at_iteration=8, within g_max
        assert!(r.requests.iter().all(|x| x.predicted_gen_len == 12));
    }

    #[test]
    fn split_overrun_rebucket_clamps_to_overrun_floor_and_g_max() {
        // prediction so low that doubling stays under the OOM iteration:
        // the floor lifts it to at_iteration
        let mut b = Batch::new(0, req(0, 10, 2, 3, 0.0), 0.0);
        b.requests.push(req(1, 10, 40, 3, 0.0));
        let (_, r) = b.split_overrun(9, 30, 64).unwrap();
        assert_eq!(r.requests[0].predicted_gen_len, 30);
        // g_max caps the floor and the doubling
        let mut b = Batch::new(0, req(0, 10, 2, 3, 0.0), 0.0);
        b.requests.push(req(1, 10, 40, 60, 0.0));
        let (_, r) = b.split_overrun(9, 30, 64).unwrap();
        assert_eq!(r.requests[0].predicted_gen_len, 64);
    }

    #[test]
    fn split_overrun_refuses_empty_sides() {
        // every request overruns -> no split
        let mut b = Batch::new(0, req(0, 10, 50, 5, 0.0), 0.0);
        b.requests.push(req(1, 10, 60, 5, 0.0));
        assert!(b.split_overrun(9, 8, 64).is_err());
        // every request already finished -> no split either
        let mut b = Batch::new(0, req(0, 10, 2, 5, 0.0), 0.0);
        b.requests.push(req(1, 10, 3, 5, 0.0));
        let b = b.split_overrun(9, 8, 64).unwrap_err();
        // the batch comes back intact for the caller's fallback
        assert_eq!(b.size(), 2);
        assert_eq!(b.id, 0);
    }
}
