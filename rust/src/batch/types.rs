//! The batch type that flows through the batcher → queue → scheduler →
//! engine pipeline.

use crate::estimator::BatchShape;
use crate::workload::{PredictedRequest, TraceStore};

/// A batch of requests awaiting (or under) execution.
#[derive(Debug, Clone)]
pub struct Batch {
    /// Unique batch id.
    pub id: u64,
    pub requests: Vec<PredictedRequest>,
    /// Wall/sim time the batch was created (first request inserted).
    pub created_at: f64,
    /// False after an OOM split (§III-C: split batches are re-queued
    /// uninsertable so they cannot grow past the memory bound again).
    pub insertable: bool,
}

impl Batch {
    pub fn new(id: u64, first: PredictedRequest, now: f64) -> Batch {
        Batch {
            id,
            requests: vec![first],
            created_at: now,
            insertable: true,
        }
    }

    /// One batch over every request of `store`, in trace order, with
    /// predictions set to the true generation lengths — the
    /// perfect-prediction shape real-compute tests and demos batch with.
    /// Panics on an empty store.
    pub fn of_store(id: u64, store: &TraceStore) -> Batch {
        assert!(!store.is_empty(), "cannot batch an empty store");
        Batch {
            id,
            requests: store
                .metas()
                .iter()
                .map(|&meta| PredictedRequest {
                    meta,
                    predicted_gen_len: meta.gen_len,
                })
                .collect(),
            created_at: 0.0,
            insertable: true,
        }
    }

    /// β — number of requests.
    #[inline]
    pub fn size(&self) -> u32 {
        self.requests.len() as u32
    }

    /// L(B) = max_p L(p) — the padded batch length.
    #[inline]
    pub fn len(&self) -> u32 {
        self.requests.iter().map(|r| r.len()).max().unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Predicted G(B) = max_p G'(p) — what the scheduler reasons with.
    #[inline]
    pub fn predicted_gen_len(&self) -> u32 {
        self.requests
            .iter()
            .map(|r| r.predicted_gen_len)
            .max()
            .unwrap_or(0)
    }

    /// Ground-truth G(B) — engine-only (EOS timing).
    #[inline]
    pub fn true_gen_len(&self) -> u32 {
        self.requests
            .iter()
            .map(|r| r.meta.gen_len)
            .max()
            .unwrap_or(0)
    }

    /// Scheduler-facing shape: (β, L(B), **predicted** G(B)) — what the
    /// serving-time estimator is queried with before dispatch.
    #[inline]
    pub fn predicted_shape(&self) -> BatchShape {
        BatchShape {
            batch_size: self.size(),
            batch_len: self.len(),
            batch_gen_len: self.predicted_gen_len(),
        }
    }

    /// Ground-truth shape: (β, L(B), **actual** G(B)) — what batch logs
    /// record after serving (§III-D re-prediction uses the actual G).
    #[inline]
    pub fn true_shape(&self) -> BatchShape {
        BatchShape {
            batch_size: self.size(),
            batch_len: self.len(),
            batch_gen_len: self.true_gen_len(),
        }
    }

    /// Earliest arrival among batched requests; T_q(B) = now − this
    /// (§III-E: the longest queuing time of requests in B).
    #[inline]
    pub fn earliest_arrival(&self) -> f64 {
        self.requests
            .iter()
            .map(|r| r.meta.arrival)
            .fold(f64::INFINITY, f64::min)
    }

    /// Split evenly in two (OOM recovery, §III-C).  Both halves are marked
    /// uninsertable.  Requests are ordered by length so the halves stay
    /// length-homogeneous.
    pub fn split(mut self, next_id: u64) -> (Batch, Batch) {
        self.requests.sort_by_key(|r| r.len());
        let half = self.requests.len() / 2;
        let right = self.requests.split_off(half);
        let left = Batch {
            id: self.id,
            requests: self.requests,
            created_at: self.created_at,
            insertable: false,
        };
        let right = Batch {
            id: next_id,
            requests: right,
            created_at: self.created_at,
            insertable: false,
        };
        (left, right)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{RequestMeta, Span, StoreId, TaskId};

    pub(crate) fn req(id: u64, len: u32, gen: u32, pred: u32, arrival: f64) -> PredictedRequest {
        PredictedRequest {
            meta: RequestMeta {
                id,
                task: TaskId::Gc,
                store: StoreId::DETACHED,
                instr: u32::MAX,
                user_input_len: len.saturating_sub(1),
                request_len: len,
                gen_len: gen,
                arrival,
                span: Span::DETACHED,
            },
            predicted_gen_len: pred,
        }
    }

    #[test]
    fn aggregates_are_maxima() {
        let mut b = Batch::new(0, req(0, 10, 5, 6, 1.0), 1.0);
        b.requests.push(req(1, 30, 50, 40, 0.5));
        b.requests.push(req(2, 20, 8, 8, 2.0));
        assert_eq!(b.size(), 3);
        assert_eq!(b.len(), 30);
        assert_eq!(b.predicted_gen_len(), 40);
        assert_eq!(b.true_gen_len(), 50);
        assert_eq!(b.earliest_arrival(), 0.5);
    }

    #[test]
    fn split_halves_and_marks_uninsertable() {
        let mut b = Batch::new(7, req(0, 10, 5, 5, 0.0), 0.0);
        for i in 1..6 {
            b.requests.push(req(i, 10 * (i as u32 + 1), 5, 5, 0.0));
        }
        let (l, r) = b.split(8);
        assert_eq!(l.size() + r.size(), 6);
        assert!((l.size() as i32 - r.size() as i32).abs() <= 1);
        assert!(!l.insertable && !r.insertable);
        assert_eq!(r.id, 8);
        // length-sorted halves: every left length <= every right length
        assert!(l.len() <= r.requests.iter().map(|x| x.len()).min().unwrap());
    }
}
