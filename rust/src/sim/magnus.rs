//! Discrete-event simulation of the Magnus pipeline (and its GLP/ABP
//! ablations): predictor → WMA batcher → serving-time estimator → batch
//! scheduler → N instances, with OOM-split recovery and continuous
//! learning — the full Fig. 7 workflow over the cost-model engine.
//!
//! The pipeline is **zero-copy**: requests arrive from a
//! [`TraceStore`] as `Copy` [`RequestMeta`]s, the predictor borrows text
//! straight from the store's arena, and completions log metas — no
//! per-request `String` is cloned anywhere on the arrival → dispatch →
//! logging path.  The owned-`Request` entry points
//! ([`run_magnus`]/[`run_magnus_with`]) intern their trace once and run
//! the same compact core; `sim::reference` keeps the owned-`Request`
//! pipeline alive as the golden/scale baseline.

use std::collections::{HashMap, HashSet, VecDeque};

use crate::batch::{AdaptiveBatcher, Batch, BatcherConfig};
use crate::config::{SchedPolicy, ServingConfig};
use crate::engine::faulty::{FaultyEngine, InjectedOutcome};
use crate::engine::{BatchOutcome, InferenceEngine};
use crate::estimator::ServingTimeEstimator;
use crate::faults::FaultPlan;
use crate::learning::ContinuousLearner;
use crate::logdb::{BatchLog, LogDb, RequestLog};
use crate::metrics::{RequestRecord, RunMetrics};
use crate::predictor::{
    fallback_prediction, predict_degraded, DriftDetector, DriftEvent, GenLenPredictor,
};
use crate::scheduler::{select, view_of, BatchView};
use crate::sim::events::EventQueue;
use crate::sim::OOM_RELOAD_S;
use crate::workload::{PredictedRequest, Request, RequestView, TraceSource, TraceStore};

/// How the dispatch loop picks the next batch.
///
/// All modes pick bit-for-bit identical batches (the golden-equivalence
/// tests assert it); `Fresh` and `Cached` remain as reference
/// implementations and as the pre-refactor baselines for
/// `benches/bench_sim`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchMode {
    /// Incremental per-policy priority structures owned by the batcher
    /// (`AdaptiveBatcher::select_indexed`): steady-state selection is
    /// O(log Q) instead of an O(Q) scan per dispatch round.
    Indexed,
    /// O(1) per queued batch: shapes come from the batcher's maintained
    /// aggregates and serving-time estimates from its cache, recomputed
    /// only when a batch mutates or the estimator refits — but every
    /// dispatch round still linear-scans the whole queue.
    Cached,
    /// Rebuild every view from scratch each dispatch round: O(Σβ) member
    /// scans plus one estimator query per queued batch per round.
    Fresh,
}

/// Magnus-family policy configuration (full Magnus and its ablations).
#[derive(Debug, Clone)]
pub struct MagnusPolicy {
    /// Cap on batch size (GLP ablation: vanilla β; 0 = adaptive).
    pub max_batch_size: u32,
    /// Batch scheduling policy (Magnus: HRRN; GLP/ABP ablations: FCFS).
    pub sched: SchedPolicy,
    /// Enable the serving-time estimator + continuous learning.
    pub use_estimator: bool,
}

impl MagnusPolicy {
    pub fn magnus() -> Self {
        MagnusPolicy {
            max_batch_size: 0,
            sched: SchedPolicy::Hrrn,
            use_estimator: true,
        }
    }

    /// GLP = VS + generation-length prediction + WMA batching, fixed β.
    pub fn glp(vanilla_beta: u32) -> Self {
        MagnusPolicy {
            max_batch_size: vanilla_beta,
            sched: SchedPolicy::Fcfs,
            use_estimator: false,
        }
    }

    /// ABP = GLP without the batch-size cap (adaptive batching).
    pub fn abp() -> Self {
        MagnusPolicy {
            max_batch_size: 0,
            sched: SchedPolicy::Fcfs,
            use_estimator: false,
        }
    }
}

enum Event {
    Arrival(usize),
    /// Instance finished serving a batch.  Carries the serving-time
    /// estimate captured at dispatch, so completion logging needs no
    /// side map (the seed kept a per-run `HashMap<batch id, f64>` that
    /// churned under OOM re-dispatches).
    BatchDone(usize, Batch, f64, BatchOutcome),
    /// Instance recovered from an OOM reload.
    InstanceReady(usize),
}

/// Result of a simulated run.
pub struct SimOutput {
    pub metrics: RunMetrics,
    pub db: LogDb,
    /// (time, |predicted − actual|) per served request — Fig. 14a input.
    pub pred_errors: Vec<(f64, f64)>,
    /// (time, |estimated − actual|) per served batch — Fig. 14b input.
    pub est_errors: Vec<(f64, f64)>,
}

/// Run the Magnus-family pipeline over an owned `trace` on `engine`.
///
/// The predictor must already be trained (the paper trains on a held-out
/// 2 500-request split before serving, §IV-A).  Interns the trace into a
/// [`TraceStore`] (one pass) and runs the zero-copy core.
pub fn run_magnus(
    cfg: &ServingConfig,
    policy: &MagnusPolicy,
    predictor: GenLenPredictor,
    engine: &dyn InferenceEngine,
    trace: &[Request],
) -> SimOutput {
    run_magnus_with(cfg, policy, predictor, engine, trace, DispatchMode::Indexed)
}

/// [`run_magnus`] with an explicit [`DispatchMode`] (testing/benching).
pub fn run_magnus_with(
    cfg: &ServingConfig,
    policy: &MagnusPolicy,
    predictor: GenLenPredictor,
    engine: &dyn InferenceEngine,
    trace: &[Request],
    mode: DispatchMode,
) -> SimOutput {
    let store = TraceStore::from_requests(trace);
    run_magnus_store_with(cfg, policy, predictor, engine, &store, mode)
}

/// Run the Magnus-family pipeline over any [`TraceSource`] — an interned
/// [`TraceStore`] or a multi-shard [`ShardedTrace`] — the zero-copy scale
/// path (a hundred-million-request sharded trace flows through without a
/// single per-request text clone, and without materialising its metas).
///
/// [`ShardedTrace`]: crate::workload::ShardedTrace
pub fn run_magnus_store<S: TraceSource>(
    cfg: &ServingConfig,
    policy: &MagnusPolicy,
    predictor: GenLenPredictor,
    engine: &dyn InferenceEngine,
    store: &S,
) -> SimOutput {
    run_magnus_store_with(cfg, policy, predictor, engine, store, DispatchMode::Indexed)
}

/// [`run_magnus_store`] with an explicit [`DispatchMode`].  Runs under
/// the explicit no-fault plan — the faulted core takes a byte-identical
/// fast path for it, so goldens over this entry point are unaffected.
pub fn run_magnus_store_with<S: TraceSource>(
    cfg: &ServingConfig,
    policy: &MagnusPolicy,
    predictor: GenLenPredictor,
    engine: &dyn InferenceEngine,
    store: &S,
    mode: DispatchMode,
) -> SimOutput {
    let plan = FaultPlan::none();
    run_magnus_store_faulted(cfg, policy, predictor, engine, store, mode, &plan)
}

/// Per-run fault bookkeeping: dispatch attempt counters (retry salts for
/// the plan's stateless hash, and the bounded-retry cutoff) plus
/// per-instance restart counts (exponential-backoff exponents).
struct FaultState {
    attempts: HashMap<u64, u32>,
    inst_restarts: Vec<u32>,
}

/// [`run_magnus_store_with`] under a [`FaultPlan`] — the chaos-testing
/// core (ISSUE 6).  Injected crashes and transient serve errors re-queue
/// the batch with bounded retries (then shed it, explicitly, into
/// `metrics.shed`), forced-OOM storms ride the §III-C split-and-requeue
/// path (via [`Batch::split_overrun`] when the plan's overrun guard is
/// on), stall windows scale serving times, and predictor outage/noise
/// windows reroute admission through the fallback chain.  Invariant:
/// every admitted request completes exactly once or is recorded as shed.
/// A no-op plan takes the legacy code path byte-for-byte.
#[allow(clippy::too_many_arguments)]
pub fn run_magnus_store_faulted<S: TraceSource>(
    cfg: &ServingConfig,
    policy: &MagnusPolicy,
    mut predictor: GenLenPredictor,
    engine: &dyn InferenceEngine,
    store: &S,
    mode: DispatchMode,
    plan: &FaultPlan,
) -> SimOutput {
    let mut batcher = AdaptiveBatcher::new(BatcherConfig {
        wma_threshold: cfg.wma_threshold,
        theta: (cfg.gpu.theta() as f64 * cfg.mem_margin) as u64,
        delta: cfg.gpu.delta_bytes_per_token,
        max_batch_size: policy.max_batch_size,
    });
    let mut estimator = ServingTimeEstimator::new(cfg.knn_k);
    let mut learner = ContinuousLearner::new(cfg.learning.clone());
    let db = LogDb::new();
    let mut metrics = RunMetrics::new();
    let mut pred_errors = Vec::new();
    let mut est_errors = Vec::new();

    let faulty = FaultyEngine::new(engine, plan);
    let g_max = cfg.gpu.g_max;
    let mut fstate = FaultState {
        attempts: HashMap::new(),
        inst_restarts: vec![0; cfg.n_instances],
    };

    // Uncertainty-aware scheduling state (ISSUE 9): all empty and
    // untouched unless `cfg.uncertainty.enabled`, so the disabled
    // configuration replays the legacy paths byte-for-byte.
    let unc = &cfg.uncertainty;
    let mut drift = DriftDetector::new(unc.drift_config());
    // Ids admitted at their upper-quantile charge (confidence below the
    // threshold) — candidates for the speculative overrun guard.
    let mut low_conf: HashSet<u64> = HashSet::new();
    // Point estimate per in-flight id: the drift detector must observe
    // the *point* error, not the conservatively charged value.
    let mut point_of: HashMap<u64, u32> = HashMap::new();

    let mut events: EventQueue<Event> = EventQueue::new();
    // Seed arrivals via `arrival(i)` — one 8-byte field per request —
    // so a lazily-opened 10⁸-request trace never hashes or validates a
    // record just to schedule it.
    for i in 0..store.len() {
        events.push(store.arrival(i), Event::Arrival(i));
    }

    let mut idle: VecDeque<usize> = (0..cfg.n_instances).collect();

    let mut served = 0usize;
    // Scratch buffers reused across events (no per-event allocation in
    // the hot path).
    let mut views: Vec<BatchView> = Vec::new();
    let mut arrivals: Vec<usize> = Vec::new();
    let mut arrival_views: Vec<RequestView> = Vec::new();
    let mut preds: Vec<u32> = Vec::new();
    while let Some((now, ev)) = events.pop() {
        match ev {
            Event::Arrival(i) => {
                // Drain the run of consecutive same-timestamp arrivals
                // (stopping at any other event type, so event-processing
                // order is untouched) and predict them as one batch over
                // the flattened forest.  Each request is then inserted —
                // and the dispatch loop run — in exactly the order the
                // one-event-at-a-time reference used, so behaviour is
                // bit-for-bit identical; only the predictor cost changes.
                arrivals.clear();
                arrivals.push(i);
                loop {
                    match events.peek() {
                        Some((t, Event::Arrival(j))) if t == now => {
                            arrivals.push(*j);
                            events.pop();
                        }
                        _ => break,
                    }
                }
                arrival_views.clear();
                arrival_views.extend(arrivals.iter().map(|&k| store.view(k)));
                if unc.enabled {
                    // Uncertainty-aware admission: the merged outage
                    // chain (global window → per-app window → drift
                    // demotion) reroutes to the fallback rung; otherwise
                    // trained predictions carry confidence, and a
                    // low-confidence request is *charged* its
                    // upper-quantile length so the batcher packs it
                    // conservatively.  Drift bias models the world
                    // shifting under the forest, so it perturbs trained
                    // predictions only — fallback rungs are immune.
                    preds.clear();
                    for v in &arrival_views {
                        let outage = plan
                            .predictor_outage(now)
                            .or_else(|| plan.app_outage(v.task.app().index(), now))
                            .or_else(|| drift.active_fallback());
                        let (point, admitted) = if let Some(mode) = outage {
                            let p = fallback_prediction(mode, v.user_input_len, g_max);
                            metrics.fallback_predictions += 1;
                            (p, p)
                        } else {
                            let pwc = predictor
                                .predict_with_confidence(*v, unc.upper_quantile as f32);
                            let point = plan.noisy_prediction(
                                plan.drifted_prediction(pwc.point, now, g_max),
                                v.id,
                                g_max,
                            );
                            if f64::from(pwc.confidence) < unc.confidence_threshold {
                                metrics.low_confidence_admissions += 1;
                                low_conf.insert(v.id);
                                let upper = plan.noisy_prediction(
                                    plan.drifted_prediction(pwc.upper_quantile, now, g_max),
                                    v.id,
                                    g_max,
                                );
                                (point, point.max(upper))
                            } else {
                                (point, point)
                            }
                        };
                        point_of.insert(v.id, point);
                        preds.push(admitted);
                    }
                } else if plan.has_predictor_faults() {
                    // Degraded admission: outage windows (global or
                    // per-app) reroute to the fallback chain; drift bias
                    // and noise perturb trained predictions.
                    preds.clear();
                    for v in &arrival_views {
                        let outage = plan
                            .predictor_outage(now)
                            .or_else(|| plan.app_outage(v.task.app().index(), now));
                        let (p, fell_back) = predict_degraded(&mut predictor, outage, v, g_max);
                        if fell_back {
                            metrics.fallback_predictions += 1;
                            preds.push(p);
                        } else {
                            let p = plan.drifted_prediction(p, now, g_max);
                            preds.push(plan.noisy_prediction(p, v.id, g_max));
                        }
                    }
                } else {
                    predictor.predict_many_views(&arrival_views, &mut preds);
                }
                for (k, &ti) in arrivals.iter().enumerate() {
                    let meta = store.meta(ti);
                    let predicted = preds[k];
                    // Fig. 14a telemetry: error of the prediction *as
                    // made*, binned by prediction time (completion-time
                    // binning would confound scheduler ordering with
                    // predictor quality).
                    pred_errors
                        .push((now, (predicted as f64 - meta.gen_len as f64).abs()));
                    batcher.insert(
                        PredictedRequest {
                            meta,
                            predicted_gen_len: predicted,
                        },
                        now,
                    );
                    dispatch_idle(
                        now,
                        mode,
                        policy,
                        &faulty,
                        plan,
                        g_max,
                        unc.enabled,
                        &low_conf,
                        &mut fstate,
                        &mut batcher,
                        &estimator,
                        &mut idle,
                        &mut views,
                        &mut events,
                        &mut metrics,
                    );
                }
            }
            Event::BatchDone(inst, batch, est, outcome) => {
                match outcome {
                    BatchOutcome::Completed {
                        serving_time,
                        per_request,
                    } => {
                        served += per_request.len();
                        for (pr, sr) in batch.requests.iter().zip(&per_request) {
                            metrics.record_prediction(pr.predicted_gen_len, pr.meta.gen_len);
                            metrics.record(RequestRecord {
                                request_id: sr.request_id,
                                arrival: pr.meta.arrival,
                                finish: now,
                                valid_tokens: sr.valid_tokens,
                                invalid_tokens: sr.invalid_tokens,
                            });
                            db.log_request(RequestLog {
                                meta: pr.meta,
                                predicted_gen_len: pr.predicted_gen_len,
                                actual_gen_len: pr.meta.gen_len,
                                at: now,
                            });
                        }
                        est_errors.push((now, (est - serving_time).abs()));
                        db.log_batch(BatchLog {
                            shape: batch.true_shape(),
                            estimated_time: est,
                            actual_time: serving_time,
                            at: now,
                        });
                        if unc.enabled {
                            // Feed the drift detector the *point*-estimate
                            // signed error of each completion (charged
                            // values would mask the bias the charge is
                            // meant to absorb).
                            for pr in &batch.requests {
                                let point = point_of
                                    .remove(&pr.meta.id)
                                    .unwrap_or(pr.predicted_gen_len);
                                low_conf.remove(&pr.meta.id);
                                match drift.observe(
                                    pr.meta.task.app(),
                                    pr.meta.user_input_len,
                                    f64::from(point) - f64::from(pr.meta.gen_len),
                                ) {
                                    DriftEvent::Demoted => metrics.drift_demotions += 1,
                                    DriftEvent::Repromoted => {
                                        metrics.drift_repromotions += 1
                                    }
                                    DriftEvent::None => {}
                                }
                            }
                        }
                    }
                    BatchOutcome::Oom { .. } => {
                        // handled at dispatch; unreachable here
                        unreachable!("OOM resolved at dispatch")
                    }
                }
                if policy.use_estimator {
                    learner.tick(now, &db, &mut predictor, &mut estimator, store);
                }
                idle.push_back(inst);
            }
            Event::InstanceReady(inst) => {
                idle.push_back(inst);
            }
        }

        // Dispatch while instances are idle and batches are queued.
        dispatch_idle(
            now,
            mode,
            policy,
            &faulty,
            plan,
            g_max,
            unc.enabled,
            &low_conf,
            &mut fstate,
            &mut batcher,
            &estimator,
            &mut idle,
            &mut views,
            &mut events,
            &mut metrics,
        );
    }

    debug_assert_eq!(
        served + metrics.shed.len(),
        store.len(),
        "exactly-once accounting must close: every admitted request \
         completes or is explicitly shed"
    );
    SimOutput {
        metrics,
        db,
        pred_errors,
        est_errors,
    }
}

/// Drain the dispatch loop: while instances are idle and batches are
/// queued, build scheduler views (per [`DispatchMode`]), select, and hand
/// the picked batch to an engine instance.  Factored out of the event
/// loop so same-timestamp arrival draining can interleave inserts with
/// dispatch exactly like the one-event-at-a-time reference did.
#[allow(clippy::too_many_arguments)]
fn dispatch_idle(
    now: f64,
    mode: DispatchMode,
    policy: &MagnusPolicy,
    faulty: &FaultyEngine<'_>,
    plan: &FaultPlan,
    g_max: u32,
    unc_enabled: bool,
    low_conf: &HashSet<u64>,
    fstate: &mut FaultState,
    batcher: &mut AdaptiveBatcher,
    estimator: &ServingTimeEstimator,
    idle: &mut VecDeque<usize>,
    views: &mut Vec<BatchView>,
    events: &mut EventQueue<Event>,
    metrics: &mut RunMetrics,
) {
    while !idle.is_empty() && !batcher.is_empty() {
        let (pick, est) = match mode {
            DispatchMode::Indexed => batcher
                .select_indexed(policy.sched, now, estimator.generation(), |shape| {
                    estimator.estimate(shape)
                })
                .unwrap(),
            DispatchMode::Fresh => {
                views.clear();
                for b in batcher.queue() {
                    let est = estimator.estimate(&b.predicted_shape());
                    views.push(view_of(b, now, est));
                }
                let pick = select(policy.sched, views).unwrap();
                (pick, views[pick].est_serving_time)
            }
            DispatchMode::Cached => {
                views.clear();
                let gen = estimator.generation();
                for i in 0..batcher.queue_len() {
                    let est = batcher
                        .cached_estimate(i, gen, |shape| estimator.estimate(shape));
                    let (min_arrival, created_at, batch_id) = batcher.view_meta(i);
                    views.push(BatchView {
                        queuing_time: (now - min_arrival).max(0.0),
                        est_serving_time: est,
                        created_at,
                        batch_id,
                    });
                }
                let pick = select(policy.sched, views).unwrap();
                (pick, views[pick].est_serving_time)
            }
        };
        let batch = batcher.take(pick);
        let inst = idle.pop_front().unwrap();

        if plan.is_noop() {
            // Legacy path, byte-for-byte when uncertainty is off: the
            // golden-equivalence suites replay fault-free runs through
            // here.  The speculative-guard probe is gated on
            // `unc_enabled` (and a non-empty low-confidence set), so the
            // disabled configuration never diverges.
            match faulty.inner().serve_batch(&batch) {
                BatchOutcome::Oom {
                    at_iteration,
                    wasted_time,
                } => {
                    let batch = if unc_enabled {
                        match speculative_rebucket(
                            now,
                            batch,
                            at_iteration,
                            wasted_time,
                            g_max,
                            low_conf,
                            batcher,
                            events,
                            metrics,
                            inst,
                        ) {
                            Ok(()) => continue,
                            Err(b) => b,
                        }
                    } else {
                        batch
                    };
                    // §III-C: split evenly, mark uninsertable, re-queue.
                    metrics.record_oom();
                    let nid = batcher.alloc_id();
                    let (l, r) = batch.split(nid);
                    batcher.requeue(l);
                    batcher.requeue(r);
                    events.push(
                        now + wasted_time + OOM_RELOAD_S,
                        Event::InstanceReady(inst),
                    );
                }
                done @ BatchOutcome::Completed { .. } => {
                    let serving_time = match &done {
                        BatchOutcome::Completed { serving_time, .. } => *serving_time,
                        _ => unreachable!(),
                    };
                    events.push(now + serving_time, Event::BatchDone(inst, batch, est, done));
                }
            }
            continue;
        }

        let attempt = fstate.attempts.get(&batch.id).copied().unwrap_or(0);
        match faulty.serve_batch_at(now, &batch, u64::from(attempt)) {
            InjectedOutcome::Crash { wasted_time } => {
                // The instance dies mid-serve: retry/shed the batch and
                // bring the instance back after a capped exponential
                // backoff (the sim never retires instances — the live
                // supervisor's max_worker_restarts handles that).
                metrics.injected_faults += 1;
                let backoff = plan.restart_backoff(fstate.inst_restarts[inst]);
                fstate.inst_restarts[inst] += 1;
                metrics.worker_restarts += 1;
                retry_or_shed(plan, batcher, metrics, fstate, batch);
                events.push(now + wasted_time + backoff, Event::InstanceReady(inst));
            }
            InjectedOutcome::TransientError { wasted_time } => {
                metrics.injected_faults += 1;
                retry_or_shed(plan, batcher, metrics, fstate, batch);
                events.push(now + wasted_time, Event::InstanceReady(inst));
            }
            InjectedOutcome::Outcome {
                outcome:
                    BatchOutcome::Oom {
                        at_iteration,
                        wasted_time,
                    },
                forced,
            } => {
                if forced {
                    metrics.injected_faults += 1;
                }
                let batch = if unc_enabled {
                    match speculative_rebucket(
                        now,
                        batch,
                        at_iteration,
                        wasted_time,
                        g_max,
                        low_conf,
                        batcher,
                        events,
                        metrics,
                        inst,
                    ) {
                        Ok(()) => continue,
                        Err(b) => b,
                    }
                } else {
                    batch
                };
                metrics.record_oom();
                requeue_oom(plan, batcher, metrics, fstate, batch, at_iteration, g_max);
                events.push(
                    now + wasted_time + OOM_RELOAD_S,
                    Event::InstanceReady(inst),
                );
            }
            InjectedOutcome::Outcome {
                outcome: done @ BatchOutcome::Completed { .. },
                ..
            } => {
                let serving_time = match &done {
                    BatchOutcome::Completed { serving_time, .. } => *serving_time,
                    _ => unreachable!(),
                };
                events.push(now + serving_time, Event::BatchDone(inst, batch, est, done));
            }
        }
    }
}

/// Speculative overrun guard (ISSUE 9): when a batch that contains at
/// least one low-confidence (upper-quantile-charged) member hits OOM,
/// the admission already *knew* it might overrun — so re-bucket it via
/// the EOS-partitioned [`Batch::split_overrun`] as if the guard had
/// caught the overrun before the allocator blew, charging only the
/// wasted iterations and **not** the full [`OOM_RELOAD_S`] model reload
/// (and not counting an OOM event).  Returns `Ok(())` when handled;
/// `Err(batch)` hands the batch back for normal OOM accounting
/// (confident batches, singletons, un-splittable mixes).
#[allow(clippy::too_many_arguments)]
fn speculative_rebucket(
    now: f64,
    batch: Batch,
    at_iteration: u32,
    wasted_time: f64,
    g_max: u32,
    low_conf: &HashSet<u64>,
    batcher: &mut AdaptiveBatcher,
    events: &mut EventQueue<Event>,
    metrics: &mut RunMetrics,
    inst: usize,
) -> Result<(), Batch> {
    if batch.size() < 2
        || !batch
            .requests
            .iter()
            .any(|pr| low_conf.contains(&pr.meta.id))
    {
        return Err(batch);
    }
    let nid = batcher.alloc_id();
    match batch.split_overrun(nid, at_iteration, g_max) {
        Ok((l, r)) => {
            metrics.speculative_rebuckets += 1;
            metrics.rebucketed += r.size();
            batcher.requeue(l);
            batcher.requeue(r);
            events.push(now + wasted_time, Event::InstanceReady(inst));
            Ok(())
        }
        Err(b) => Err(b),
    }
}

/// Bounded-retry policy for a batch lost to an injected crash/error:
/// bump its attempt count, re-queue while attempts remain, otherwise
/// shed every member request explicitly (never silently lost).
fn retry_or_shed(
    plan: &FaultPlan,
    batcher: &mut AdaptiveBatcher,
    metrics: &mut RunMetrics,
    fstate: &mut FaultState,
    batch: Batch,
) {
    let attempt = fstate.attempts.entry(batch.id).or_insert(0);
    *attempt += 1;
    if *attempt > plan.max_retries {
        for pr in &batch.requests {
            metrics.record_shed(pr.meta.id);
        }
    } else {
        metrics.retries += 1;
        batcher.requeue(batch);
    }
}

/// Re-queue an OOM-killed batch: the overrun guard first tries the
/// EOS-partitioned [`Batch::split_overrun`] (re-bucketing overrunners),
/// falling back to the §III-C even split.  A singleton cannot split, so
/// it is marked uninsertable and retried/shed like a failed dispatch.
fn requeue_oom(
    plan: &FaultPlan,
    batcher: &mut AdaptiveBatcher,
    metrics: &mut RunMetrics,
    fstate: &mut FaultState,
    mut batch: Batch,
    at_iteration: u32,
    g_max: u32,
) {
    if batch.size() < 2 {
        batch.insertable = false;
        retry_or_shed(plan, batcher, metrics, fstate, batch);
        return;
    }
    let nid = batcher.alloc_id();
    let batch = if plan.overrun_guard {
        match batch.split_overrun(nid, at_iteration, g_max) {
            Ok((l, r)) => {
                metrics.rebucketed += r.size();
                batcher.requeue(l);
                batcher.requeue(r);
                return;
            }
            Err(b) => b,
        }
    } else {
        batch
    };
    let (l, r) = batch.split(nid);
    batcher.requeue(l);
    batcher.requeue(r);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::cost::CostModelEngine;
    use crate::predictor::Variant;
    use crate::workload::dataset::build_predictor_split;
    use crate::workload::{generate_trace, LlmProfile, TraceSpec};

    fn setup(n: usize, rate: f64) -> (ServingConfig, GenLenPredictor, CostModelEngine, Vec<Request>) {
        let cfg = ServingConfig::default();
        let split = build_predictor_split(LlmProfile::ChatGlm6B, 150, 10, 1024, 30);
        let mut p = GenLenPredictor::new(Variant::Usin, &cfg);
        p.train(&split.train);
        let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
        let trace = generate_trace(&TraceSpec {
            rate,
            n_requests: n,
            ..Default::default()
        });
        (cfg, p, engine, trace)
    }

    #[test]
    fn all_requests_complete() {
        let (cfg, p, engine, trace) = setup(300, 2.0);
        let out = run_magnus(&cfg, &MagnusPolicy::magnus(), p, &engine, &trace);
        assert_eq!(out.metrics.records.len(), 300);
        // every record finishes after it arrives
        assert!(out
            .metrics
            .records
            .iter()
            .all(|r| r.finish >= r.arrival));
    }

    #[test]
    fn magnus_beats_glp_beats_nothing_on_throughput() {
        let (cfg, p, engine, trace) = setup(400, 8.0);
        let split = build_predictor_split(LlmProfile::ChatGlm6B, 150, 10, 1024, 30);
        let mut p2 = GenLenPredictor::new(Variant::Usin, &cfg);
        p2.train(&split.train);

        let magnus = run_magnus(&cfg, &MagnusPolicy::magnus(), p, &engine, &trace)
            .metrics
            .summarise();
        let glp = run_magnus(&cfg, &MagnusPolicy::glp(7), p2, &engine, &trace)
            .metrics
            .summarise();
        assert!(
            magnus.request_throughput >= glp.request_throughput * 0.95,
            "magnus {:.3} vs glp {:.3}",
            magnus.request_throughput,
            glp.request_throughput
        );
    }

    /// Golden equivalence: the indexed and cached dispatch paths must
    /// replay the fresh-view reference bit-for-bit (same batches, same
    /// times, same telemetry) — the whole point of the index and the
    /// cache is to change cost, not behaviour.
    #[test]
    fn optimized_dispatch_replays_fresh_dispatch() {
        for (policy, mode) in [
            (MagnusPolicy::magnus(), DispatchMode::Indexed),
            (MagnusPolicy::glp(7), DispatchMode::Indexed),
            (MagnusPolicy::abp(), DispatchMode::Indexed),
            (MagnusPolicy::magnus(), DispatchMode::Cached),
            (MagnusPolicy::glp(7), DispatchMode::Cached),
            (MagnusPolicy::abp(), DispatchMode::Cached),
        ] {
            let (cfg, p, engine, trace) = setup(350, 9.0);
            let (_, p2, _, _) = setup(350, 9.0); // identically-trained twin
            let a = run_magnus_with(&cfg, &policy, p, &engine, &trace, mode);
            let b = run_magnus_with(&cfg, &policy, p2, &engine, &trace, DispatchMode::Fresh);
            assert_eq!(a.metrics.records.len(), b.metrics.records.len());
            for (x, y) in a.metrics.records.iter().zip(&b.metrics.records) {
                assert_eq!(x.request_id, y.request_id);
                assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
                assert_eq!(x.finish.to_bits(), y.finish.to_bits());
                assert_eq!(x.valid_tokens, y.valid_tokens);
                assert_eq!(x.invalid_tokens, y.invalid_tokens);
            }
            assert_eq!(a.metrics.oom_events, b.metrics.oom_events);
            assert_eq!(a.est_errors.len(), b.est_errors.len());
            for (x, y) in a.est_errors.iter().zip(&b.est_errors) {
                assert_eq!(x.1.to_bits(), y.1.to_bits());
            }
            let (sa, sb) = (a.metrics.summarise(), b.metrics.summarise());
            assert_eq!(sa.request_throughput.to_bits(), sb.request_throughput.to_bits());
            assert_eq!(sa.mean_response_time.to_bits(), sb.mean_response_time.to_bits());
            assert_eq!(sa.token_throughput.to_bits(), sb.token_throughput.to_bits());
        }
    }

    /// The store entry point is the same computation as the owned entry
    /// point — interning changes representation, not behaviour.
    #[test]
    fn store_and_owned_entry_points_agree() {
        let (cfg, p, engine, trace) = setup(250, 6.0);
        let (_, p2, _, _) = setup(250, 6.0);
        let store = TraceStore::from_requests(&trace);
        let a = run_magnus_store(&cfg, &MagnusPolicy::magnus(), p, &engine, &store);
        let b = run_magnus(&cfg, &MagnusPolicy::magnus(), p2, &engine, &trace);
        assert_eq!(a.metrics.records.len(), b.metrics.records.len());
        for (x, y) in a.metrics.records.iter().zip(&b.metrics.records) {
            assert_eq!(x.request_id, y.request_id);
            assert_eq!(x.finish.to_bits(), y.finish.to_bits());
        }
    }

    #[test]
    fn logdb_populated() {
        let (cfg, p, engine, trace) = setup(100, 2.0);
        let out = run_magnus(&cfg, &MagnusPolicy::magnus(), p, &engine, &trace);
        assert_eq!(out.db.n_requests(), 100);
        assert!(out.db.n_batches() > 0);
        assert_eq!(out.pred_errors.len(), 100);
    }
}
