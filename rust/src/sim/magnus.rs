//! Discrete-event simulation of the Magnus pipeline (and its GLP/ABP
//! ablations): predictor → WMA batcher → serving-time estimator → batch
//! scheduler → N instances, with OOM-split recovery and continuous
//! learning — the full Fig. 7 workflow over the cost-model engine.

use std::collections::VecDeque;

use crate::batch::{AdaptiveBatcher, Batch, BatcherConfig};
use crate::config::{SchedPolicy, ServingConfig};
use crate::engine::{BatchOutcome, InferenceEngine};
use crate::estimator::{BatchShape, ServingTimeEstimator};
use crate::learning::ContinuousLearner;
use crate::logdb::{BatchLog, LogDb, RequestLog};
use crate::metrics::{RequestRecord, RunMetrics};
use crate::predictor::GenLenPredictor;
use crate::scheduler::{select, view_of};
use crate::sim::events::EventQueue;
use crate::workload::{PredictedRequest, Request};

/// Magnus-family policy configuration (full Magnus and its ablations).
#[derive(Debug, Clone)]
pub struct MagnusPolicy {
    /// Cap on batch size (GLP ablation: vanilla β; 0 = adaptive).
    pub max_batch_size: u32,
    /// Batch scheduling policy (Magnus: HRRN; GLP/ABP ablations: FCFS).
    pub sched: SchedPolicy,
    /// Enable the serving-time estimator + continuous learning.
    pub use_estimator: bool,
}

impl MagnusPolicy {
    pub fn magnus() -> Self {
        MagnusPolicy {
            max_batch_size: 0,
            sched: SchedPolicy::Hrrn,
            use_estimator: true,
        }
    }

    /// GLP = VS + generation-length prediction + WMA batching, fixed β.
    pub fn glp(vanilla_beta: u32) -> Self {
        MagnusPolicy {
            max_batch_size: vanilla_beta,
            sched: SchedPolicy::Fcfs,
            use_estimator: false,
        }
    }

    /// ABP = GLP without the batch-size cap (adaptive batching).
    pub fn abp() -> Self {
        MagnusPolicy {
            max_batch_size: 0,
            sched: SchedPolicy::Fcfs,
            use_estimator: false,
        }
    }
}

enum Event {
    Arrival(usize),
    /// Instance finished serving a batch.
    BatchDone(usize, Batch, BatchOutcome),
    /// Instance recovered from an OOM reload.
    InstanceReady(usize),
}

/// Post-OOM reload penalty (empty GPU memory + reload LLM, §III-F).
const OOM_RELOAD_S: f64 = 20.0;

/// Result of a simulated run.
pub struct SimOutput {
    pub metrics: RunMetrics,
    pub db: LogDb,
    /// (time, |predicted − actual|) per served request — Fig. 14a input.
    pub pred_errors: Vec<(f64, f64)>,
    /// (time, |estimated − actual|) per served batch — Fig. 14b input.
    pub est_errors: Vec<(f64, f64)>,
}

/// Run the Magnus-family pipeline over `trace` on `engine`.
///
/// The predictor must already be trained (the paper trains on a held-out
/// 2 500-request split before serving, §IV-A).
pub fn run_magnus(
    cfg: &ServingConfig,
    policy: &MagnusPolicy,
    mut predictor: GenLenPredictor,
    engine: &dyn InferenceEngine,
    trace: &[Request],
) -> SimOutput {
    let mut batcher = AdaptiveBatcher::new(BatcherConfig {
        wma_threshold: cfg.wma_threshold,
        theta: (cfg.gpu.theta() as f64 * cfg.mem_margin) as u64,
        delta: cfg.gpu.delta_bytes_per_token,
        max_batch_size: policy.max_batch_size,
    });
    let mut estimator = ServingTimeEstimator::new(cfg.knn_k);
    let mut learner = ContinuousLearner::new(cfg.learning.clone());
    let db = LogDb::new();
    let mut metrics = RunMetrics::new();
    let mut pred_errors = Vec::new();
    let mut est_errors = Vec::new();

    let mut events: EventQueue<Event> = EventQueue::new();
    for (i, r) in trace.iter().enumerate() {
        events.push(r.arrival, Event::Arrival(i));
    }

    let mut idle: VecDeque<usize> = (0..cfg.n_instances).collect();
    // Estimates captured at dispatch time, keyed by batch id (for logging).
    let mut dispatch_est: std::collections::HashMap<u64, f64> =
        std::collections::HashMap::new();

    let mut served = 0usize;
    while let Some((now, ev)) = events.pop() {
        match ev {
            Event::Arrival(i) => {
                let req = trace[i].clone();
                let predicted = predictor.predict(&req);
                // Fig. 14a telemetry: error of the prediction *as made*,
                // binned by prediction time (completion-time binning would
                // confound scheduler ordering with predictor quality).
                pred_errors
                    .push((now, (predicted as f64 - req.gen_len as f64).abs()));
                batcher.insert(
                    PredictedRequest {
                        request: req,
                        predicted_gen_len: predicted,
                    },
                    now,
                );
            }
            Event::BatchDone(inst, batch, outcome) => {
                match outcome {
                    BatchOutcome::Completed {
                        serving_time,
                        per_request,
                    } => {
                        served += per_request.len();
                        for (pr, sr) in batch.requests.iter().zip(&per_request) {
                            metrics.record(RequestRecord {
                                request_id: sr.request_id,
                                arrival: pr.request.arrival,
                                finish: now,
                                valid_tokens: sr.valid_tokens,
                                invalid_tokens: sr.invalid_tokens,
                            });
                            db.log_request(RequestLog {
                                request: pr.request.clone(),
                                predicted_gen_len: pr.predicted_gen_len,
                                actual_gen_len: pr.request.gen_len,
                                at: now,
                            });
                        }
                        let est = dispatch_est.remove(&batch.id).unwrap_or(0.0);
                        est_errors.push((now, (est - serving_time).abs()));
                        db.log_batch(BatchLog {
                            shape: BatchShape {
                                batch_size: batch.size(),
                                batch_len: batch.len(),
                                batch_gen_len: batch.true_gen_len(),
                            },
                            estimated_time: est,
                            actual_time: serving_time,
                            at: now,
                        });
                    }
                    BatchOutcome::Oom { .. } => {
                        // handled at dispatch; unreachable here
                        unreachable!("OOM resolved at dispatch")
                    }
                }
                if policy.use_estimator {
                    learner.tick(now, &db, &mut predictor, &mut estimator);
                }
                idle.push_back(inst);
            }
            Event::InstanceReady(inst) => {
                idle.push_back(inst);
            }
        }

        // Dispatch while instances are idle and batches are queued.
        while !idle.is_empty() && !batcher.is_empty() {
            let views: Vec<_> = batcher
                .queue()
                .iter()
                .map(|b| {
                    let est = estimator.estimate(&BatchShape {
                        batch_size: b.size(),
                        batch_len: b.len(),
                        batch_gen_len: b.predicted_gen_len(),
                    });
                    view_of(b, now, est)
                })
                .collect();
            let pick = select(policy.sched, &views).unwrap();
            let est = views[pick].est_serving_time;
            let batch = batcher.take(pick);
            let inst = idle.pop_front().unwrap();

            match engine.serve_batch(&batch) {
                BatchOutcome::Oom {
                    at_iteration: _,
                    wasted_time,
                } => {
                    // §III-C: split evenly, mark uninsertable, re-queue.
                    metrics.record_oom();
                    let nid = batcher.alloc_id();
                    let (l, r) = batch.split(nid);
                    batcher.requeue(l);
                    batcher.requeue(r);
                    events.push(
                        now + wasted_time + OOM_RELOAD_S,
                        Event::InstanceReady(inst),
                    );
                }
                done @ BatchOutcome::Completed { .. } => {
                    let serving_time = match &done {
                        BatchOutcome::Completed { serving_time, .. } => *serving_time,
                        _ => unreachable!(),
                    };
                    dispatch_est.insert(batch.id, est);
                    events.push(now + serving_time, Event::BatchDone(inst, batch, done));
                }
            }
        }
    }

    debug_assert_eq!(served, trace.len(), "all requests must complete");
    SimOutput {
        metrics,
        db,
        pred_errors,
        est_errors,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::cost::CostModelEngine;
    use crate::predictor::Variant;
    use crate::workload::dataset::build_predictor_split;
    use crate::workload::{generate_trace, LlmProfile, TraceSpec};

    fn setup(n: usize, rate: f64) -> (ServingConfig, GenLenPredictor, CostModelEngine, Vec<Request>) {
        let cfg = ServingConfig::default();
        let split = build_predictor_split(LlmProfile::ChatGlm6B, 150, 10, 1024, 30);
        let mut p = GenLenPredictor::new(Variant::Usin, &cfg);
        p.train(&split.train);
        let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
        let trace = generate_trace(&TraceSpec {
            rate,
            n_requests: n,
            ..Default::default()
        });
        (cfg, p, engine, trace)
    }

    #[test]
    fn all_requests_complete() {
        let (cfg, p, engine, trace) = setup(300, 2.0);
        let out = run_magnus(&cfg, &MagnusPolicy::magnus(), p, &engine, &trace);
        assert_eq!(out.metrics.records.len(), 300);
        // every record finishes after it arrives
        assert!(out
            .metrics
            .records
            .iter()
            .all(|r| r.finish >= r.arrival));
    }

    #[test]
    fn magnus_beats_glp_beats_nothing_on_throughput() {
        let (cfg, p, engine, trace) = setup(400, 8.0);
        let split = build_predictor_split(LlmProfile::ChatGlm6B, 150, 10, 1024, 30);
        let mut p2 = GenLenPredictor::new(Variant::Usin, &cfg);
        p2.train(&split.train);

        let magnus = run_magnus(&cfg, &MagnusPolicy::magnus(), p, &engine, &trace)
            .metrics
            .summarise();
        let glp = run_magnus(&cfg, &MagnusPolicy::glp(7), p2, &engine, &trace)
            .metrics
            .summarise();
        assert!(
            magnus.request_throughput >= glp.request_throughput * 0.95,
            "magnus {:.3} vs glp {:.3}",
            magnus.request_throughput,
            glp.request_throughput
        );
    }

    #[test]
    fn logdb_populated() {
        let (cfg, p, engine, trace) = setup(100, 2.0);
        let out = run_magnus(&cfg, &MagnusPolicy::magnus(), p, &engine, &trace);
        assert_eq!(out.db.n_requests(), 100);
        assert!(out.db.n_batches() > 0);
        assert_eq!(out.pred_errors.len(), 100);
    }
}
