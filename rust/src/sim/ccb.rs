//! Discrete-event simulation of Conservative Continuous Batching (CCB,
//! paper §IV-A): Orca-style iteration-level scheduling with the number of
//! parallel-processing requests capped (paper: 7) to avoid OOM.
//!
//! Semantics per the paper's implementation notes:
//! * finished requests leave the running set immediately (no invalid
//!   tokens are ever generated);
//! * a newly admitted request stalls the running set while it completes
//!   its initialisation phase (only that request's first token is
//!   produced during the stall);
//! * requests are admitted FCFS whenever a slot is free.

use std::collections::VecDeque;

use crate::config::ServingConfig;
use crate::engine::InferenceEngine;
use crate::metrics::{RequestRecord, RunMetrics};
use crate::sim::events::EventQueue;
use crate::workload::{Request, RequestMeta, TraceStore};

#[derive(Debug, Clone)]
struct Running {
    idx: usize,
    /// Tokens generated so far.
    generated: u32,
    /// Context length = request length + generated.
    ctx: u32,
}

enum Event {
    Arrival(usize),
    /// One decode iteration of instance `i` completes.
    Iter(usize),
}

/// Run CCB over an owned trace (metas are extracted once; CCB reads only
/// lengths/ids, never text).
pub fn run_ccb(
    cfg: &ServingConfig,
    parallel_limit: u32,
    engine: &dyn InferenceEngine,
    trace: &[Request],
) -> RunMetrics {
    let metas: Vec<RequestMeta> = trace.iter().map(RequestMeta::detached).collect();
    run_ccb_metas(cfg, parallel_limit, engine, &metas)
}

/// Run CCB over an interned [`TraceStore`] (zero-copy).
pub fn run_ccb_store(
    cfg: &ServingConfig,
    parallel_limit: u32,
    engine: &dyn InferenceEngine,
    store: &TraceStore,
) -> RunMetrics {
    run_ccb_metas(cfg, parallel_limit, engine, store.metas())
}

/// Run CCB with `parallel_limit` concurrent requests per instance.
fn run_ccb_metas(
    cfg: &ServingConfig,
    parallel_limit: u32,
    engine: &dyn InferenceEngine,
    trace: &[RequestMeta],
) -> RunMetrics {
    let mut metrics = RunMetrics::new();
    let mut events: EventQueue<Event> = EventQueue::new();
    for (i, r) in trace.iter().enumerate() {
        events.push(r.arrival, Event::Arrival(i));
    }

    let n_inst = cfg.n_instances;
    let mut running: Vec<Vec<Running>> = vec![Vec::new(); n_inst];
    // Running Σ ctx per instance, maintained incrementally (admissions
    // add len+1, retirements subtract, every decode iteration adds β) —
    // the per-iteration mean context no longer rescans the running set.
    // Integer arithmetic, so the maintained sum is exactly the rescan.
    let mut ctx_sum: Vec<u64> = vec![0; n_inst];
    // Instances with an Iter event in flight.
    let mut busy = vec![false; n_inst];
    let mut fifo: VecDeque<usize> = VecDeque::new();

    // Admit from the FIFO into instance `inst`; returns the admission
    // stall time (sum of initialisation phases, run serially).
    let admit_overhead = cfg.ccb_overhead_s;
    let admit = |running: &mut Vec<Running>,
                 ctx_sum: &mut u64,
                 fifo: &mut VecDeque<usize>,
                 engine: &dyn InferenceEngine,
                 trace: &[RequestMeta]|
     -> f64 {
        let mut stall = 0.0;
        while running.len() < parallel_limit as usize && !fifo.is_empty() {
            let idx = fifo.pop_front().unwrap();
            let len = trace[idx].request_len;
            stall += admit_overhead + engine.prefill_time(1, len);
            running.push(Running {
                idx,
                generated: 1, // prefill produces the first token
                ctx: len + 1,
            });
            *ctx_sum += (len + 1) as u64;
        }
        stall
    };

    while let Some((now, ev)) = events.pop() {
        match ev {
            Event::Arrival(i) => {
                fifo.push_back(i);
                // Wake any idle instance.
                for inst in 0..n_inst {
                    if !busy[inst] && running[inst].len() < parallel_limit as usize {
                        let stall =
                            admit(&mut running[inst], &mut ctx_sum[inst], &mut fifo, engine, trace);
                        if !running[inst].is_empty() {
                            busy[inst] = true;
                            let beta = running[inst].len() as u32;
                            debug_assert_eq!(
                                ctx_sum[inst],
                                running[inst].iter().map(|r| r.ctx as u64).sum::<u64>()
                            );
                            let ctx = (ctx_sum[inst] / beta as u64) as u32;
                            events.push(
                                now + stall + engine.decode_iter_time(beta, ctx),
                                Event::Iter(inst),
                            );
                        }
                        break;
                    }
                }
            }
            Event::Iter(inst) => {
                // Advance every running request by one token (Σ ctx grows
                // by β); retire the finished ones immediately (continuous
                // batching), subtracting their contexts from the sum.
                ctx_sum[inst] += running[inst].len() as u64;
                let mut finished = Vec::new();
                for r in &mut running[inst] {
                    r.generated += 1;
                    r.ctx += 1;
                    if r.generated >= trace[r.idx].gen_len {
                        finished.push(r.idx);
                    }
                }
                let sum = &mut ctx_sum[inst];
                running[inst].retain(|r| {
                    if r.generated < trace[r.idx].gen_len {
                        true
                    } else {
                        *sum -= r.ctx as u64;
                        false
                    }
                });
                for idx in finished {
                    metrics.record(RequestRecord {
                        request_id: trace[idx].id,
                        arrival: trace[idx].arrival,
                        finish: now,
                        valid_tokens: trace[idx].gen_len,
                        invalid_tokens: 0,
                    });
                }

                // Admit newcomers, then run the next iteration.
                let stall =
                    admit(&mut running[inst], &mut ctx_sum[inst], &mut fifo, engine, trace);
                if running[inst].is_empty() {
                    busy[inst] = false;
                } else {
                    let beta = running[inst].len() as u32;
                    debug_assert_eq!(
                        ctx_sum[inst],
                        running[inst].iter().map(|r| r.ctx as u64).sum::<u64>()
                    );
                    let ctx = (ctx_sum[inst] / beta as u64) as u32;
                    events.push(
                        now + stall + engine.decode_iter_time(beta, ctx),
                        Event::Iter(inst),
                    );
                }
            }
        }
    }

    // Handle single-token requests admitted but finished at admission:
    // (gen_len == 1 means the prefill token completes them; they are
    // retired on the first Iter event, so nothing is lost.)
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::cost::CostModelEngine;
    use crate::workload::{generate_trace, TraceSpec};

    fn setup(n: usize, rate: f64) -> (ServingConfig, CostModelEngine, Vec<Request>) {
        let cfg = ServingConfig::default();
        let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
        let trace = generate_trace(&TraceSpec {
            rate,
            n_requests: n,
            ..Default::default()
        });
        (cfg, engine, trace)
    }

    #[test]
    fn completes_all_requests_with_zero_invalid_tokens() {
        let (cfg, engine, trace) = setup(150, 2.0);
        let m = run_ccb(&cfg, 7, &engine, &trace);
        assert_eq!(m.records.len(), 150);
        assert!(m.records.iter().all(|r| r.invalid_tokens == 0));
    }

    #[test]
    fn valid_token_counts_match_trace() {
        let (cfg, engine, trace) = setup(80, 2.0);
        let m = run_ccb(&cfg, 7, &engine, &trace);
        let total: u64 = m.records.iter().map(|r| r.valid_tokens as u64).sum();
        let expect: u64 = trace.iter().map(|r| r.gen_len as u64).sum();
        assert_eq!(total, expect);
    }

    #[test]
    fn ccb_beats_vs_on_response_time() {
        // §IV-B: CCB returns finished requests immediately → shorter RT.
        let (cfg, engine, trace) = setup(250, 3.0);
        let ccb = run_ccb(&cfg, 7, &engine, &trace).summarise();
        let vs = crate::sim::vanilla::run_vanilla(&cfg, 7, &engine, &trace).summarise();
        assert!(
            ccb.mean_response_time < vs.mean_response_time,
            "ccb {:.1}s vs vs {:.1}s",
            ccb.mean_response_time,
            vs.mean_response_time
        );
    }

    #[test]
    fn respects_parallel_limit_one() {
        let (cfg, engine, trace) = setup(30, 5.0);
        let m = run_ccb(&cfg, 1, &engine, &trace);
        assert_eq!(m.records.len(), 30);
    }

    #[test]
    fn store_path_replays_owned_path() {
        let (cfg, engine, trace) = setup(120, 3.0);
        let store = TraceStore::from_requests(&trace);
        let a = run_ccb(&cfg, 7, &engine, &trace);
        let b = run_ccb_store(&cfg, 7, &engine, &store);
        assert_eq!(a.records.len(), b.records.len());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.request_id, y.request_id);
            assert_eq!(x.finish.to_bits(), y.finish.to_bits());
        }
    }
}
