//! Discrete-event simulation of the VS / VSQ baselines (paper §IV-A):
//! FCFS request queue, fixed batch size, no prediction.  VSQ is VS over
//! the quantized engine with its larger fixed batch size.
//!
//! The loop runs over compact [`RequestMeta`] records — vanilla
//! scheduling never reads request text, so both the owned-trace and the
//! [`TraceStore`] entry points feed the same zero-copy core.

use std::collections::VecDeque;

use crate::batch::Batch;
use crate::config::ServingConfig;
use crate::engine::{BatchOutcome, InferenceEngine};
use crate::metrics::{RequestRecord, RunMetrics};
use crate::sim::events::EventQueue;
use crate::sim::OOM_RELOAD_S;
use crate::workload::{PredictedRequest, Request, RequestMeta, TraceStore};

enum Event {
    Arrival(usize),
    BatchDone(usize, Batch, f64, Vec<crate::engine::ServedRequest>),
    InstanceReady(usize),
}

/// Run vanilla scheduling over an owned trace (metas are extracted once;
/// no text is touched).
pub fn run_vanilla(
    cfg: &ServingConfig,
    fixed_batch: u32,
    engine: &dyn InferenceEngine,
    trace: &[Request],
) -> RunMetrics {
    let metas: Vec<RequestMeta> = trace.iter().map(RequestMeta::detached).collect();
    run_vanilla_metas(cfg, fixed_batch, engine, &metas)
}

/// Run vanilla scheduling over an interned [`TraceStore`] (zero-copy).
pub fn run_vanilla_store(
    cfg: &ServingConfig,
    fixed_batch: u32,
    engine: &dyn InferenceEngine,
    store: &TraceStore,
) -> RunMetrics {
    run_vanilla_metas(cfg, fixed_batch, engine, store.metas())
}

/// Run vanilla scheduling with `fixed_batch` requests per batch.
///
/// When an instance is idle and the queue is non-empty, the earliest
/// min(queue, fixed_batch) requests form a batch (production servers
/// flush partial batches on a timeout; an idle instance here flushes
/// immediately, which is the zero-timeout limit).
fn run_vanilla_metas(
    cfg: &ServingConfig,
    fixed_batch: u32,
    engine: &dyn InferenceEngine,
    trace: &[RequestMeta],
) -> RunMetrics {
    let mut metrics = RunMetrics::new();
    let mut events: EventQueue<Event> = EventQueue::new();
    for (i, m) in trace.iter().enumerate() {
        events.push(m.arrival, Event::Arrival(i));
    }

    let mut fifo: VecDeque<usize> = VecDeque::new();
    let mut idle: VecDeque<usize> = (0..cfg.n_instances).collect();
    let mut next_batch_id = 0u64;

    while let Some((now, ev)) = events.pop() {
        match ev {
            Event::Arrival(i) => fifo.push_back(i),
            Event::BatchDone(inst, batch, _t, per_request) => {
                for (pr, sr) in batch.requests.iter().zip(&per_request) {
                    metrics.record(RequestRecord {
                        request_id: sr.request_id,
                        arrival: pr.meta.arrival,
                        finish: now,
                        valid_tokens: sr.valid_tokens,
                        invalid_tokens: sr.invalid_tokens,
                    });
                }
                idle.push_back(inst);
            }
            Event::InstanceReady(inst) => idle.push_back(inst),
        }

        while !idle.is_empty() && !fifo.is_empty() {
            let take = (fixed_batch as usize).min(fifo.len());
            let mut reqs = Vec::with_capacity(take);
            for _ in 0..take {
                let i = fifo.pop_front().unwrap();
                reqs.push(PredictedRequest {
                    meta: trace[i],
                    // vanilla scheduling has no prediction; the field is
                    // unused on this path.
                    predicted_gen_len: 0,
                });
            }
            let mut it = reqs.into_iter();
            let mut batch = Batch::new(next_batch_id, it.next().unwrap(), now);
            next_batch_id += 1;
            batch.requests.extend(it);

            let inst = idle.pop_front().unwrap();
            match engine.serve_batch(&batch) {
                BatchOutcome::Completed {
                    serving_time,
                    per_request,
                } => {
                    events.push(
                        now + serving_time,
                        Event::BatchDone(inst, batch, serving_time, per_request),
                    );
                }
                BatchOutcome::Oom { wasted_time, .. } => {
                    // Eq. (1) guarantees the fixed batch fits under L_max /
                    // G_max, so this only fires with mis-configured β.
                    // Halve and push the requests back to the queue head.
                    metrics.record_oom();
                    let n = batch.requests.len();
                    for pr in batch.requests.into_iter().rev().take(n / 2) {
                        fifo.push_front(pr.meta.id as usize);
                    }
                    events.push(now + wasted_time + OOM_RELOAD_S, Event::InstanceReady(inst));
                }
            }
        }
    }
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::cost::CostModelEngine;
    use crate::engine::quantized::QuantizedEngine;
    use crate::workload::{generate_trace, TraceSpec};

    fn setup(n: usize, rate: f64) -> (ServingConfig, CostModelEngine, Vec<Request>) {
        let cfg = ServingConfig::default();
        let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
        let trace = generate_trace(&TraceSpec {
            rate,
            n_requests: n,
            ..Default::default()
        });
        (cfg, engine, trace)
    }

    #[test]
    fn completes_all_requests() {
        let (cfg, engine, trace) = setup(200, 2.0);
        let m = run_vanilla(&cfg, 7, &engine, &trace);
        assert_eq!(m.records.len(), 200);
        assert_eq!(m.oom_events, 0, "Eq.1 batch must not OOM");
    }

    #[test]
    fn store_path_replays_owned_path() {
        let (cfg, engine, trace) = setup(150, 4.0);
        let store = TraceStore::from_requests(&trace);
        let a = run_vanilla(&cfg, 7, &engine, &trace);
        let b = run_vanilla_store(&cfg, 7, &engine, &store);
        assert_eq!(a.records.len(), b.records.len());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.request_id, y.request_id);
            assert_eq!(x.finish.to_bits(), y.finish.to_bits());
            assert_eq!(x.valid_tokens, y.valid_tokens);
        }
    }

    #[test]
    fn batch_sizes_respect_fixed_limit() {
        // With a huge batch size limit everything still completes.
        let (cfg, engine, trace) = setup(50, 5.0);
        let m = run_vanilla(&cfg, 1, &engine, &trace);
        assert_eq!(m.records.len(), 50);
    }

    #[test]
    fn vsq_slower_than_vs() {
        let (cfg, engine, trace) = setup(200, 3.0);
        let vs = run_vanilla(&cfg, 7, &engine, &trace).summarise();
        let qengine = QuantizedEngine::new(
            CostModelEngine::new(cfg.cost.clone(), &cfg.gpu),
            cfg.quant.clone(),
        );
        let vsq = run_vanilla(&cfg, cfg.quant.batch_size, &qengine, &trace).summarise();
        // §IV-B: VSQ has larger batches but lower request throughput and
        // longer response times.
        assert!(
            vsq.mean_response_time > vs.mean_response_time,
            "vsq {:.1}s vs vs {:.1}s",
            vsq.mean_response_time,
            vs.mean_response_time
        );
    }

    #[test]
    fn invalid_tokens_exist_under_mixed_lengths() {
        let (cfg, engine, trace) = setup(100, 3.0);
        let m = run_vanilla(&cfg, 7, &engine, &trace);
        let invalid: u64 = m.records.iter().map(|r| r.invalid_tokens as u64).sum();
        assert!(invalid > 0, "FCFS mixing must produce request waiting");
    }
}
